"""Training step: loss -> grads -> AdamW, with microbatch accumulation,
optional int8 error-feedback compression on the gradient reduction, and
donated buffers.  Pure function of (params, opt, batch) — the launcher jits
it with mesh shardings (see launch/train.py, launch/dryrun.py)."""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.dist.overlap import make_ring_all_reduce
from repro.models import transformer as tf
from repro.optim import adamw
from repro.optim.compress import compress_tree, decompress_tree, init_error


def make_grad_reduce(mesh, axis: str, reduce: str = "mean"
                     ) -> Callable[[Any], Any]:
    """Build the ``grad_reduce`` hook for a shard_map DP training loop
    (ROADMAP item 3 leftover): the chunked-ppermute ring all-reduce of
    ``repro.dist.overlap``, applied leaf-wise to the gradient pytree.

    ``reduce="mean"`` matches ``jax.lax.pmean`` — the correct reduction for
    data-parallel gradients (``tests/distrib/test_dist_unit.py`` proves
    parity on a fake 4-device mesh).  The returned callable uses
    ``axis_index``/``ppermute`` on ``axis``, so it must run *inside* a
    ``shard_map`` (or pmap) that binds ``axis`` — exactly where the
    ``train_step(grad_reduce=...)`` hook sits; the ring body is obtained
    with ``shard_mapped=False`` because shard_map does not nest."""
    ring = make_ring_all_reduce(mesh, axis, reduce=reduce,
                                shard_mapped=False)
    return lambda grads: jax.tree.map(ring, grads)


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    err: Any            # error-feedback carry (None-like zeros when unused)
    step: jax.Array


def init_state(cfg: ModelConfig, key, dtype=jnp.float32,
               compression: bool = False) -> TrainState:
    params = tf.init_params(cfg, key, dtype=dtype)
    return TrainState(
        params=params,
        opt=adamw.init(params),
        err=init_error(params) if compression else jax.tree.map(
            lambda p: jnp.zeros((1,), jnp.float32), params),
        step=jnp.int32(0),
    )


def train_step(
    state: TrainState,
    batch: Dict[str, jax.Array],
    cfg: ModelConfig,
    run: RunConfig,
    grad_reduce: Optional[Callable[[Any], Any]] = None,
) -> Tuple[TrainState, Dict[str, jax.Array]]:
    """One optimizer step.  batch tokens: [global_batch, seq].

    ``grad_reduce`` optionally reduces the gradient pytree across data-
    parallel replicas *explicitly* (e.g. ``repro.dist.overlap``'s ring
    all-reduce inside a shard_map training loop).  It runs *after* the
    compression round-trip so the values crossing the reduction boundary are
    the quantized ones, as the compression path documents.  Under plain
    jit+GSPMD the reduction is implicit in the batch sharding and this stays
    None."""
    mb = run.microbatches

    def loss_of(params, b):
        loss, _ = tf.loss_fn(params, cfg, b, remat=(run.remat != "none"))
        return loss

    if mb > 1:
        B = batch["tokens"].shape[0]
        def resh(x):
            return x.reshape(mb, B // mb, *x.shape[1:])
        mbatch = jax.tree.map(resh, batch)

        def body(acc, b):
            loss, g = jax.value_and_grad(loss_of)(state.params, b)
            return (jax.tree.map(jnp.add, acc[0], g), acc[1] + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             state.params)
        (grads, loss), _ = jax.lax.scan(body, (zeros, jnp.float32(0)), mbatch)
        grads = jax.tree.map(lambda g: g / mb, grads)
        loss = loss / mb
    else:
        loss, grads = jax.value_and_grad(loss_of)(state.params, batch)

    err = state.err
    if run.grad_compression:
        # int8 + error feedback across the (DCN-bound) reduction boundary
        q, scales, err = compress_tree(grads, state.err)
        grads = decompress_tree(q, scales)

    if grad_reduce is not None:
        grads = grad_reduce(grads)

    lr = adamw.cosine_schedule(state.opt.step, base_lr=run.lr)
    params, opt, om = adamw.apply(
        state.params, grads, state.opt, lr=lr,
        weight_decay=run.weight_decay, grad_clip=run.grad_clip)
    new_state = TrainState(params, opt, err, state.step + 1)
    return new_state, {"loss": loss, "lr": lr, **om}
