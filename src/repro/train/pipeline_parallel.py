"""Pipeline parallelism (GPipe-style) over a ``stage`` axis via shard_map +
collective_permute.

Included as the PP building block of the parallelism menu (DP/TP/PP/EP/SP):
the stage axis is carved out of the mesh; each stage holds a contiguous slice
of superblocks; microbatches stream through with ``ppermute`` handoffs.  A
scan over (num_microbatches + num_stages - 1) ticks realizes the classic
GPipe schedule (bubble = (S-1)/(M+S-1)); activations for in-flight
microbatches are the only cross-tick state.

This module is deliberately model-agnostic: it pipelines any per-stage
``apply_fn(stage_params, x) -> x``.  The dry-run exercises it via
``--pp`` on a (pp, data, model) mesh reshape; tests validate equivalence to
the unpipelined forward on CPU with 4 fake stages.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax import shard_map


def pipeline_forward(
    apply_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,        # pytree with leading [S] stage dim (sharded on "stage")
    x: jax.Array,             # [M, mb, ...] microbatched input (replicated)
    *,
    mesh: Mesh,
    axis: str = "stage",
) -> jax.Array:
    """Returns y [M, mb, ...]: x pushed through all S stages in GPipe order."""
    S = mesh.shape[axis]
    M = x.shape[0]

    def _local(params_local, x_all):
        # params_local: stage's own slice (leading dim 1); x_all: full [M, ...]
        sid = jax.lax.axis_index(axis)
        p = jax.tree.map(lambda a: a[0], params_local)
        n_ticks = M + S - 1
        buf = jnp.zeros_like(x_all)                     # outputs (stage S-1)
        carry = jnp.zeros_like(x_all[0])                # inbound activation

        def tick(t, state):
            carry, buf = state
            m = t - sid                                  # microbatch index here
            # stage 0 ingests fresh microbatches; others use the carry
            inp = jnp.where(sid == 0,
                            x_all[jnp.clip(t, 0, M - 1)], carry)
            active = (m >= 0) & (m < M)
            out = apply_fn(p, inp)
            out = jnp.where(active, out, inp)
            # last stage banks its result; others pass it right
            buf = jax.lax.cond(
                (sid == S - 1) & active,
                lambda b: b.at[jnp.clip(m, 0, M - 1)].set(out),
                lambda b: b, buf)
            nxt = jax.lax.ppermute(out, axis,
                                   [(i, (i + 1) % S) for i in range(S)])
            return nxt, buf

        _, buf = jax.lax.fori_loop(0, n_ticks, tick, (carry, buf))
        # only stage S-1's buf holds real outputs; broadcast it
        buf = jax.lax.psum(
            jnp.where(sid == S - 1, buf, jnp.zeros_like(buf)), axis)
        return buf

    fn = shard_map(
        _local, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_vma=False,
    )
    return fn(stage_params, x)
