"""repro: jax_pallas reproduction of "Practically and Theoretically Efficient
Garbage Collection for Multiversioning".

Importing any ``repro.*`` module installs the forward-compat aliases in
:mod:`repro._jax_compat` so code written against the current jax API
(``jax.set_mesh``, ``jax.shard_map``, ``jax.P``, ``jax.sharding.AxisType``,
``jax.make_mesh(..., axis_types=...)``) also runs on the 0.4.x jax baked into
this container.  Importing jax here does NOT initialize backends — device
state is still created lazily, after XLA_FLAGS overrides (see launch/dryrun).
"""
from repro import _jax_compat

_jax_compat.install()
