"""TPU-native version store: structure-of-arrays version slabs.

The paper's pointer-linked version lists become fixed-capacity **version
slabs**: each versioned object (a *slot* — e.g. a KV page-table entry) owns a
row of ``V`` entries.  An entry is a version ``(ts, succ, payload)`` where
``succ`` is the timestamp at which it was overwritten (``TS_MAX`` while
current).  The whole store is a pytree of ``[S, V]`` arrays — shardable along
``S`` with the data it versions, updatable with masked scatters, and
sweepable with VPU-friendly elementwise passes.  This is the hardware
adaptation recorded in DESIGN.md §2: index-linked SoA version pool instead of pointer
chasing, bulk-synchronous masked updates instead of CAS.

Capacity discipline: the paper's L-R+P bound becomes "occupancy stays below
V provided GC runs at the configured cadence"; ``write`` returns an
``overflow`` flag the engine must handle (it forces a GC pass — trivially
possible under bulk synchrony).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

TS_MAX = jnp.iinfo(jnp.int32).max  # "current version" successor / padding
EMPTY = jnp.int32(-1)


class VersionStore(NamedTuple):
    """[S, V] version slabs.  Entry invalid iff ts == EMPTY."""

    ts: jax.Array        # i32[S, V]  version timestamp (EMPTY = free entry)
    succ: jax.Array      # i32[S, V]  successor timestamp (TS_MAX = current)
    payload: jax.Array   # i32[S, V]  opaque handle (e.g. page index), EMPTY = none

    @property
    def num_slots(self) -> int:
        return self.ts.shape[0]

    @property
    def versions_per_slot(self) -> int:
        return self.ts.shape[1]


def make_store(num_slots: int, versions_per_slot: int) -> VersionStore:
    shape = (num_slots, versions_per_slot)
    return VersionStore(
        ts=jnp.full(shape, EMPTY, jnp.int32),
        succ=jnp.full(shape, TS_MAX, jnp.int32),
        payload=jnp.full(shape, EMPTY, jnp.int32),
    )


def valid_mask(store: VersionStore) -> jax.Array:
    return store.ts != EMPTY


def occupancy(store: VersionStore) -> jax.Array:
    """Versions currently held per slot: i32[S]."""
    return valid_mask(store).sum(axis=1).astype(jnp.int32)


def current_index(store: VersionStore) -> jax.Array:
    """Index (into V) of the current version per slot; -1 if slot empty.

    The current version is the one with succ == TS_MAX; there is at most one
    per slot by construction.  i32[S]."""
    cur = (store.succ == TS_MAX) & valid_mask(store)
    idx = jnp.argmax(cur, axis=1).astype(jnp.int32)
    return jnp.where(cur.any(axis=1), idx, EMPTY)


def write(
    store: VersionStore,
    slot_ids: jax.Array,   # i32[B] distinct slots to write this step
    new_ts: jax.Array,     # i32[] or i32[B] timestamp of the new versions
    payloads: jax.Array,   # i32[B] payload handles for the new versions
    write_mask: jax.Array, # bool[B] lanes actually writing
) -> Tuple[VersionStore, jax.Array]:
    """Append one new version to each (masked) slot.

    The paper's ``tryAppend`` under bulk synchrony: the overwritten current
    version gets ``succ = new_ts`` (closing its interval — this is what the
    sim layer reports to the RangeTracker), and the new version lands in the
    slot's first free entry.  Returns (new_store, overflow_mask[B]).
    Precondition: slot_ids are unique among masked lanes (engine guarantees —
    one writer per object per step, the SPMD analogue of CAS success).
    """
    S, V = store.ts.shape
    B = slot_ids.shape[0]
    new_ts = jnp.broadcast_to(jnp.asarray(new_ts, jnp.int32), (B,))
    rows_ts = store.ts[slot_ids]          # [B, V]
    rows_succ = store.succ[slot_ids]
    rows_valid = rows_ts != EMPTY

    # first free entry per row; a full row means the append fails (overflow)
    free = ~rows_valid
    has_free = free.any(axis=1)
    ins = jnp.argmax(free, axis=1)        # first free position
    overflow = write_mask & ~has_free
    do = write_mask & has_free            # lanes that actually append

    # close the overwritten current version's interval (only if appending)
    is_cur = (rows_succ == TS_MAX) & rows_valid
    rows_succ = jnp.where(is_cur & do[:, None], new_ts[:, None], rows_succ)

    onehot = jax.nn.one_hot(ins, V, dtype=jnp.bool_) & do[:, None]
    rows_ts = jnp.where(onehot, new_ts[:, None], rows_ts)
    rows_succ = jnp.where(onehot, TS_MAX, rows_succ)
    rows_pay = jnp.where(onehot, payloads[:, None], store.payload[slot_ids])

    # scatter back only the appending lanes; inert lanes are routed to an
    # out-of-range row and dropped, so duplicates/masked lanes can't clobber
    dest = jnp.where(do, slot_ids, S)
    new_store = VersionStore(
        ts=store.ts.at[dest].set(rows_ts, mode="drop"),
        succ=store.succ.at[dest].set(rows_succ, mode="drop"),
        payload=store.payload.at[dest].set(rows_pay, mode="drop"),
    )
    return new_store, overflow


def read_at(
    store: VersionStore,
    slot_ids: jax.Array,  # i32[B]
    t: jax.Array,         # i32[] or i32[B] snapshot timestamps
) -> Tuple[jax.Array, jax.Array]:
    """The rtx read path (paper ``search(t)``): latest version with ts <= t.

    Returns (payload[B], found[B]).  A data-parallel masked argmax over the
    V-wide slab replaces the list traversal."""
    B = slot_ids.shape[0]
    t = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (B,))
    rows_ts = store.ts[slot_ids]                     # [B, V]
    ok = (rows_ts != EMPTY) & (rows_ts <= t[:, None])
    # argmax of ts with invalid lanes at -inf
    masked = jnp.where(ok, rows_ts, jnp.int32(-2_147_483_648))
    idx = jnp.argmax(masked, axis=1)
    found = ok.any(axis=1)
    payload = jnp.take_along_axis(store.payload[slot_ids], idx[:, None], axis=1)[:, 0]
    return jnp.where(found, payload, EMPTY), found


def read_current(
    store: VersionStore, slot_ids: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """peekHead: payload of the current version per queried slot."""
    rows_succ = store.succ[slot_ids]
    rows_ts = store.ts[slot_ids]
    cur = (rows_succ == TS_MAX) & (rows_ts != EMPTY)
    idx = jnp.argmax(cur, axis=1)
    found = cur.any(axis=1)
    payload = jnp.take_along_axis(store.payload[slot_ids], idx[:, None], axis=1)[:, 0]
    return jnp.where(found, payload, EMPTY), found


def epoch_kill_mask(store: VersionStore, bound: jax.Array) -> jax.Array:
    """bool[S, V]: entries whose interval closed strictly before ``bound``
    (``succ <= bound`` and valid) — the EBR epoch-quiescence splice set.

    ``bound`` is the reclamation low-water mark: locally the oldest pin on
    this host's board (or ``now`` when pin-free), and under the sharded
    stack the mesh-wide ``min`` of every host's contribution, clamped by
    any injected ``extra_pins`` — a version closed before *every* pin in
    the system can never be read again (DESIGN.md §13)."""
    return (store.succ <= bound) & (store.ts != EMPTY)


def free_entries(store: VersionStore, kill: jax.Array) -> VersionStore:
    """Free every entry where kill[S, V] is True (the splice)."""
    return VersionStore(
        ts=jnp.where(kill, EMPTY, store.ts),
        succ=jnp.where(kill, TS_MAX, store.succ),
        payload=jnp.where(kill, EMPTY, store.payload),
    )
