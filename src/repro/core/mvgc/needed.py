"""The needed(A, t) predicate, vectorized — the heart of the TPU adaptation.

Paper §5: a version x is needed(A, t) iff
  (1) x.ts > t (appended after the scan threshold), or
  (2) x is the last appended node with ts <= t (i.e. still current at t), or
  (3) for some announced a in A, x is the last appended node with ts <= a.

With interval form (every version carries ``[ts, succ)``; succ = TS_MAX while
current) this collapses to:

    needed(x)  <=>  succ(x) > t   OR   exists a in A:  ts(x) <= a < succ(x)

which is one ``searchsorted`` over the sorted announcement array per version —
a pure VPU sweep with the announcement array resident in VMEM.  The SSL
``compact`` merge pass computed exactly this predicate list-element by
list-element; here it is evaluated for a whole [S, V] slab (or a gathered
batch of retired entries) in one shot.  The Pallas kernel in
``repro.kernels.compact`` implements the same contraction with explicit
BlockSpec tiling; this module is its jnp reference and the jit fallback.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.mvgc.pool import TS_MAX, EMPTY, VersionStore


def needed_intervals(
    ts: jax.Array,        # i32[...]: version timestamps (EMPTY entries allowed)
    succ: jax.Array,      # i32[...]: successor timestamps (TS_MAX = current)
    ann_sorted: jax.Array,  # i32[P]: sorted announcements, TS_MAX padding
    now: jax.Array,       # i32[]: scan threshold t (the current global time)
) -> jax.Array:
    """bool[...] — True where the version is needed(A, now)."""
    P = ann_sorted.shape[0]
    idx = jnp.searchsorted(ann_sorted, ts, side="left")  # first a >= ts
    a = ann_sorted[jnp.minimum(idx, P - 1)]
    pinned = (idx < P) & (a < succ)        # exists a: ts <= a < succ
    current_or_future = succ > now         # case (1)/(2): interval still open
    valid = ts != EMPTY
    return valid & (pinned | current_or_future)


def needed_mask(
    store: VersionStore, ann_sorted: jax.Array, now: jax.Array
) -> jax.Array:
    """needed(A, now) for every entry of the store: bool[S, V]."""
    return needed_intervals(store.ts, store.succ, ann_sorted, now)


def sort_announcements(ann: jax.Array) -> jax.Array:
    """Sort an announcement board into searchsorted form.

    Un-announced lanes hold EMPTY (-1); they are mapped to TS_MAX so they sort
    to the end and can never pin anything (TS_MAX < succ is False for every
    closed interval, and open intervals are kept by the `succ > now` term).
    This replaces the paper's GlobalAnnScan protocol: under bulk synchrony the
    board is snapshotted collectively, which is strictly stronger than
    Lemma 11's consistency requirement."""
    ann = jnp.where(ann == EMPTY, TS_MAX, ann)
    return jnp.sort(ann)
