"""Versioned object store: the deployable MVGC facade.

Bundles the version slabs, announcement board, retire ring and the global
timestamp into one pytree (`MVState`) with pure step functions, and exposes
the paper's scheme menu as GC *policies* over identical state:

* ``ebr``    — free every version whose interval closed before the oldest
               pinned timestamp (epoch quiescence; cannot free "middle"
               versions that closed while any older reader is live).
* ``steam``  — compact-on-append: after each write step, sweep exactly the
               written slots' slabs with needed(A, now).
* ``dlrt``   — RangeTracker ring; flush frees exactly the retired entries
               that became obsolete (the PDL splice-by-handle analogue).
* ``slrt``   — ring flush *plus* a needed-sweep of the implicated slots'
               whole slabs (SSL compact's preemptive splicing; default).
* ``sweep``  — GVM/HANA analogue: sweep every slab each ``gc_every`` steps,
               regardless of update activity (the baseline the paper's
               related work improves on).

All functions are jit/shard_map friendly: fixed shapes, masked updates, no
host control flow on traced values.  Policy strings specialize at trace time.

Every GC entry point takes an optional ``extra_pins`` array of externally
announced timestamps (``TS_MAX`` sentinel = no pin) that is honoured exactly
like a local board lane.  Single-host callers leave it ``None`` (bit-for-bit
the pre-existing behaviour); the sharded stack (``repro.dist.mvgc``)
injects the mesh-wide low-water mark so no shard reclaims a version pinned
by *any* host (DESIGN.md §13).

``gc_step`` / ``reclaim_on_pressure`` additionally take an optional
``ckpt_max`` — the highest durably checkpointed timestamp (``EMPTY`` = no
checkpoint).  It unlocks turso's *sole-survivor* rule (SNIPPETS.md §1,
DESIGN.md §14): a slot's only live version, durable at-or-before
``ckpt_max`` and older than every pin, may be evicted even though it is
current — durable storage has the data, ``restore()`` brings it back.  The
kill is applied as one shared post-pass (:func:`evict_checkpointed`) after
the policy's own collection, so all five policies inherit it with zero
policy-specific code — exactly like the ``extra_pins`` threading.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.mvgc import announce as ann
from repro.core.mvgc import pool, rangetracker as rt
from repro.core.mvgc.needed import needed_intervals, sort_announcements
from repro.core.mvgc.pool import EMPTY, TS_MAX, VersionStore
from repro.core.telemetry import GCConfig, PressureSignal
from repro.kernels.compact import ops as compact_ops
from repro.kernels.version_search import ops as search_ops

POLICIES = ("ebr", "steam", "dlrt", "slrt", "sweep")


class MVState(NamedTuple):
    store: VersionStore          # [S, V] version slabs
    board: ann.AnnounceBoard     # [P] reader pins
    ring: rt.RetireRing          # [B] retired intervals (RT policies)
    now: jax.Array               # i32[] global timestamp (one tick per step)
    overflow_count: jax.Array    # i32[] slab-overflow events (monitoring)
    dropped_retires: jax.Array   # i32[] ring-overflow events (monitoring)


def make_state(
    num_slots: int,
    versions_per_slot: Optional[int] = None,
    num_reader_lanes: Optional[int] = None,
    ring_capacity: Optional[int] = None,
    *,
    gc: Optional[GCConfig] = None,
) -> MVState:
    """Build an empty MVState.  Sizing comes from the positional args when
    given, else from ``gc`` (:class:`repro.core.telemetry.GCConfig`), so both
    the legacy ``make_state(S, V, P)`` call shape and the redesigned
    ``make_state(S, gc=cfg)`` shape work."""
    cfg = gc if gc is not None else GCConfig()
    if versions_per_slot is None:
        versions_per_slot = cfg.versions_per_slot
    if num_reader_lanes is None:
        num_reader_lanes = cfg.reader_lanes
    if ring_capacity is None:
        ring_capacity = cfg.ring_capacity
    ring_capacity = ring_capacity or max(64, num_slots // 2)
    return MVState(
        store=pool.make_store(num_slots, versions_per_slot),
        board=ann.make_board(num_reader_lanes),
        ring=rt.make_ring(ring_capacity),
        now=jnp.int32(0),
        overflow_count=jnp.int32(0),
        dropped_retires=jnp.int32(0),
    )


# ---------------------------------------------------------------------------
# Write path
# ---------------------------------------------------------------------------
def write_step(
    state: MVState,
    slot_ids: jax.Array,   # i32[K] slots written this step (unique when masked)
    payloads: jax.Array,   # i32[K] new payload handles
    mask: jax.Array,       # bool[K]
    policy: str = "slrt",
    use_kernel: bool = False,
    interpret: bool = True,
    extra_pins: Optional[jax.Array] = None,
) -> Tuple[MVState, jax.Array, jax.Array]:
    """One bulk-synchronous update step: tick the clock, append versions,
    retire the overwritten ones into the ring (RT policies), and return the
    payload handles freed by any immediate policy action.

    Returns (state', freed_payloads, overflow[K]) — freed_payloads is i32[...]
    with EMPTY holes (callers recycle them, e.g. return KV pages to the free
    pool); overflow marks lanes whose append failed because the slot's slab
    was full — the engine must force a GC pass and retry those lanes (or, for
    EBR, provision larger slabs: this is precisely the paper's unbounded-EBR
    space pathology surfacing as a capacity requirement)."""
    assert policy in POLICIES, policy
    freed = jnp.full(slot_ids.shape, EMPTY, jnp.int32)
    if policy == "steam":
        # Steam compacts the list *when appending to it* (paper §2): sweep the
        # written slots before the append so reclaimed entries make room.
        state, freed = _sweep_slots(state, slot_ids, mask,
                                    use_kernel=use_kernel, interpret=interpret,
                                    extra_pins=extra_pins)
    now = state.now + 1
    store = state.store
    S, V = store.ts.shape

    # capture the overwritten (current) version per written slot BEFORE write
    rows_ts = store.ts[slot_ids]
    rows_succ = store.succ[slot_ids]
    is_cur = (rows_succ == TS_MAX) & (rows_ts != EMPTY)
    had_cur = is_cur.any(axis=1) & mask
    cur_v = jnp.argmax(is_cur, axis=1).astype(jnp.int32)
    retired_flat = slot_ids * V + cur_v
    retired_low = jnp.take_along_axis(rows_ts, cur_v[:, None], axis=1)[:, 0]

    store, overflow = pool.write(store, slot_ids, now, payloads, mask)
    state = state._replace(
        store=store,
        now=now,
        overflow_count=state.overflow_count + overflow.sum(),
    )

    if policy in ("dlrt", "slrt"):
        ring, dropped = rt.push(
            state.ring, retired_flat, retired_low, jnp.broadcast_to(now, retired_low.shape),
            had_cur & ~overflow,  # overflowed lanes closed nothing
        )
        state = state._replace(
            ring=ring, dropped_retires=state.dropped_retires + dropped.sum()
        )
    # ebr / sweep: nothing on the write path
    return state, freed, overflow


# ---------------------------------------------------------------------------
# Reader path
# ---------------------------------------------------------------------------
def begin_snapshot(state: MVState, lanes: jax.Array, mask: jax.Array) -> Tuple[MVState, jax.Array]:
    """Pin the current timestamp for the given reader lanes; returns their ts."""
    board = ann.announce(state.board, lanes, state.now, mask)
    return state._replace(board=board), jnp.broadcast_to(state.now, lanes.shape)


def end_snapshot(state: MVState, lanes: jax.Array, mask: jax.Array) -> MVState:
    return state._replace(board=ann.unannounce(state.board, lanes, mask))


def snapshot_read(
    state: MVState,
    slot_ids: jax.Array,
    t: jax.Array,
    use_kernel: bool = False,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """rtx read: latest payload at-or-before t per slot (search(t)).

    ``use_kernel`` dispatches to the Pallas version_search kernel (interpret
    mode validates it on CPU); the default is the lax masked-argmax path."""
    if use_kernel:
        t_b = jnp.broadcast_to(jnp.asarray(t, jnp.int32), slot_ids.shape)
        return search_ops.search(
            state.store.ts, state.store.payload, slot_ids, t_b,
            use_kernel=True, interpret=interpret,
        )
    return pool.read_at(state.store, slot_ids, t)


def snapshot_gather(
    state: MVState,
    slot_ids: jax.Array,  # i32[B]
    t: jax.Array,         # i32[] or i32[B] pinned timestamp(s)
    values: jax.Array,    # i32[T, M] payload-indexed value rows
    use_kernel: bool = False,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused rtx read: resolve search(t) per slot AND gather the value rows
    the resolved payloads index — one launch on the kernel path, one fused
    jit program on the lax path.  Returns ``(rows[B, M], payload[B],
    found[B])``; rows for not-found slots are EMPTY-filled.  This is the
    reader-lane primitive `mvkv.paged.snapshot_view` builds on (payload =
    page-table version index, values = page tables)."""
    t_b = jnp.broadcast_to(jnp.asarray(t, jnp.int32), slot_ids.shape)
    return search_ops.search_gather(
        state.store.ts, state.store.payload, values, slot_ids, t_b,
        use_kernel=use_kernel, interpret=interpret,
    )


def current_read(state: MVState, slot_ids: jax.Array) -> Tuple[jax.Array, jax.Array]:
    return pool.read_current(state.store, slot_ids)


# ---------------------------------------------------------------------------
# GC step
# ---------------------------------------------------------------------------
def _ann_scan(state: MVState, extra_pins: Optional[jax.Array]) -> jax.Array:
    """Sorted announcement snapshot for needed(), with any external pins
    appended as extra virtual lanes.

    ``extra_pins`` entries use the same vocabulary as board lanes: a real
    timestamp pins it, ``TS_MAX`` (or ``EMPTY``) pins nothing — ``needed()``
    treats both sentinels as inert, so padding is free.  The sharded stack
    passes the mesh-wide LWM here (DESIGN.md §13)."""
    if extra_pins is None:
        return ann.scan(state.board)
    extra = jnp.atleast_1d(jnp.asarray(extra_pins, jnp.int32))
    return sort_announcements(
        jnp.concatenate([state.board.slots, extra]))


def _ebr_bound(state: MVState, extra_pins: Optional[jax.Array]) -> jax.Array:
    """EBR epoch boundary: oldest local pin (or ``now``), clamped by the
    oldest external pin (``TS_MAX`` sentinels drop out of the min)."""
    bound = ann.oldest(state.board, state.now)
    if extra_pins is not None:
        extra = jnp.atleast_1d(jnp.asarray(extra_pins, jnp.int32))
        bound = jnp.minimum(bound, extra.min())
    return bound


def ckpt_kill_mask(state: MVState, ckpt_max: jax.Array,
                   extra_pins: Optional[jax.Array] = None) -> jax.Array:
    """bool[S, V]: turso's sole-survivor rule (SNIPPETS.md §1 rule 3,
    DESIGN.md §14).  An entry is evictable iff it is the *current* version
    (``succ == TS_MAX``), its slot's **only** live version (chain length 1 —
    older versions must drain through the normal policies first), it began
    at-or-before the durable checkpoint (``ts <= ckpt_max``: the slot has
    not been written since the checkpoint, so durable storage holds exactly
    this state), and it began before every pin in the system (``ts <
    bound``, the same LWM every policy honours).  ``ckpt_max`` is a traced
    i32 scalar; the ``EMPTY`` (-1) sentinel disables the rule entirely, so
    the mask composes under jit without retracing."""
    store = state.store
    ckpt = jnp.asarray(ckpt_max, jnp.int32)
    bound = _ebr_bound(state, extra_pins)
    valid = store.ts != EMPTY
    sole = (valid.sum(axis=1) == 1)[:, None]
    cur = (store.succ == TS_MAX) & valid
    return (cur & sole & (store.ts <= ckpt) & (store.ts < bound)
            & (ckpt >= 0))


def evict_checkpointed(
    state: MVState,
    ckpt_max: jax.Array,
    extra_pins: Optional[jax.Array] = None,
) -> Tuple[MVState, jax.Array, jax.Array]:
    """Free every entry :func:`ckpt_kill_mask` marks.  Returns
    (state', freed_payloads[S*V] with EMPTY holes, n_evicted).

    This is the checkpoint-coupled reclamation edge no policy can make on
    its own: current versions are by definition needed(A, t), so without a
    durable copy they are pinned forever.  With one, an idle-since-
    checkpoint slot's last version (and every page it pins, in the paged
    stack) becomes free — ``restore()`` resurrects it on demand.  Callers
    treat an evicted slot like a cold-miss: reading it finds no current
    version until the slot is restored or rewritten."""
    kill = ckpt_kill_mask(state, ckpt_max, extra_pins)
    freed = jnp.where(kill, state.store.payload, EMPTY).reshape(-1)
    n = kill.sum().astype(jnp.int32)
    return state._replace(store=pool.free_entries(state.store, kill)), freed, n


def gc_step(
    state: MVState,
    policy: str = "slrt",
    force: bool = False,
    flush_fraction: float = 0.5,
    use_kernel: bool = False,
    interpret: bool = True,
    extra_pins: Optional[jax.Array] = None,
    ckpt_max: Optional[jax.Array] = None,
) -> Tuple[MVState, jax.Array]:
    """Run the policy's collection pass.  Returns (state', freed_payloads).

    For RT policies the flush triggers when ring occupancy crosses
    ``flush_fraction`` (or unconditionally when ``force``) — the batched
    analogue of flushing every Θ(P log P) adds.  ``extra_pins`` (i32[...],
    ``TS_MAX`` = no pin) injects external announcements — e.g. the sharded
    stack's global LWM — honoured by every policy exactly like board lanes.
    ``ckpt_max`` (i32[], ``EMPTY`` = none) appends the checkpoint-coupled
    sole-survivor post-pass (:func:`evict_checkpointed`) after the policy's
    own collection — every policy inherits it unchanged (DESIGN.md §14)."""
    state, freed = _policy_gc_step(
        state, policy=policy, force=force, flush_fraction=flush_fraction,
        use_kernel=use_kernel, interpret=interpret, extra_pins=extra_pins)
    if ckpt_max is not None:
        state, freed_ck, _ = evict_checkpointed(state, ckpt_max, extra_pins)
        freed = jnp.concatenate([freed.reshape(-1), freed_ck])
    return state, freed


def _policy_gc_step(
    state: MVState,
    policy: str = "slrt",
    force: bool = False,
    flush_fraction: float = 0.5,
    use_kernel: bool = False,
    interpret: bool = True,
    extra_pins: Optional[jax.Array] = None,
) -> Tuple[MVState, jax.Array]:
    """The per-policy collection pass proper (no checkpoint post-pass)."""
    assert policy in POLICIES, policy
    S, V = state.store.ts.shape
    if policy == "ebr":
        bound = _ebr_bound(state, extra_pins)
        kill = pool.epoch_kill_mask(state.store, bound)
        freed = jnp.where(kill, state.store.payload, EMPTY).reshape(-1)
        return state._replace(store=pool.free_entries(state.store, kill)), freed

    if policy == "sweep":
        return _sweep_all_needed(state, use_kernel=use_kernel,
                                 interpret=interpret, extra_pins=extra_pins)

    if policy == "steam":
        # steam does its work on the write path; the periodic GC step is a
        # no-op (dusty corners live until the next append).  force=True is
        # the engine's shutdown/pressure escape hatch: one full sweep.
        if force:
            return _sweep_all_needed(state, use_kernel=use_kernel,
                                     interpret=interpret,
                                     extra_pins=extra_pins)
        return state, jnp.full((state.ring.capacity,), EMPTY, jnp.int32)

    # dlrt / slrt
    size = rt.ring_size(state.ring)
    thresh = int(state.ring.capacity * flush_fraction)
    do_flush = jnp.logical_or(size >= thresh, jnp.bool_(force))

    B = state.ring.capacity

    def _flush(st: MVState):
        A = _ann_scan(st, extra_pins)
        # slots implicated by the ring content (the paper: the lists whose
        # nodes the range tracker returned)
        occ = st.ring.idx != EMPTY
        touched = jnp.where(occ, st.ring.idx // V, 0)
        ring, store, freed = rt.flush(st.ring, st.store, A, st.now)
        st = st._replace(ring=ring, store=store)
        if policy == "slrt":
            # preemptive compaction of implicated slots (SSL compact): may
            # free entries never returned by the tracker.  freed handles can
            # repeat; payload recycling must be idempotent (bitmap set).
            st, freed2 = _sweep_slots(st, touched, occ,
                                      use_kernel=use_kernel,
                                      interpret=interpret,
                                      extra_pins=extra_pins)
            freed = jnp.concatenate([freed, freed2])
        else:
            freed = jnp.concatenate([freed, jnp.full((B * V,), EMPTY, jnp.int32)])
        return st, freed

    def _skip(st: MVState):
        return st, jnp.full((B + B * V,), EMPTY, jnp.int32)

    return jax.lax.cond(do_flush, _flush, _skip, state)


def _sweep_all_needed(
    state: MVState, use_kernel: bool = False, interpret: bool = True,
    extra_pins: Optional[jax.Array] = None,
) -> Tuple[MVState, jax.Array]:
    """Full-store needed-sweep: the fused compact primitive over every slab
    (mask all-true).  The Pallas kernel and the lax path share the same
    contract (one pass: splice + freed handles + count)."""
    S, V = state.store.ts.shape
    A = _ann_scan(state, extra_pins)
    new_ts, new_succ, new_pay, freed, _ = compact_ops.compact(
        state.store.ts, state.store.succ, state.store.payload,
        jnp.ones((S,), bool), A, state.now,
        use_kernel=use_kernel, interpret=interpret,
    )
    store = VersionStore(ts=new_ts, succ=new_succ, payload=new_pay)
    return state._replace(store=store), freed.reshape(-1)


def _sweep_slots(
    state: MVState,
    slot_ids: jax.Array,
    mask: jax.Array,
    use_kernel: bool = False,
    interpret: bool = True,
    extra_pins: Optional[jax.Array] = None,
) -> Tuple[MVState, jax.Array]:
    """needed-sweep restricted to the given slots (steam / slrt locality).

    ``use_kernel`` dispatches the gathered rows through the fused Pallas
    compaction kernel; otherwise the lax searchsorted form runs (the two are
    differentially tested in tests/mvgc/test_vstore.py)."""
    A = _ann_scan(state, extra_pins)
    rows_ts = state.store.ts[slot_ids]
    rows_succ = state.store.succ[slot_ids]
    rows_pay = state.store.payload[slot_ids]
    if use_kernel:
        new_ts, new_succ, new_pay, freed2d, _ = compact_ops.compact(
            rows_ts, rows_succ, rows_pay, mask, A, state.now,
            use_kernel=True, interpret=interpret,
        )
        freed = freed2d.reshape(-1)
    else:
        needed = needed_intervals(rows_ts, rows_succ, A, state.now)
        kill = ~needed & (rows_ts != EMPTY) & mask[:, None]
        freed = jnp.where(kill, rows_pay, EMPTY).reshape(-1)
        new_ts = jnp.where(kill, EMPTY, rows_ts)
        new_succ = jnp.where(kill, TS_MAX, rows_succ)
        new_pay = jnp.where(kill, EMPTY, rows_pay)
    store = VersionStore(
        ts=state.store.ts.at[slot_ids].set(new_ts, mode="drop"),
        succ=state.store.succ.at[slot_ids].set(new_succ, mode="drop"),
        payload=state.store.payload.at[slot_ids].set(new_pay, mode="drop"),
    )
    return state._replace(store=store), freed


# ---------------------------------------------------------------------------
# Pressure path (DESIGN.md §11): capacity gate -> hot slots -> reclaim
# ---------------------------------------------------------------------------
#: Deprecated alias: ``capacity_gate`` now returns the unified
#: :class:`repro.core.telemetry.PressureSignal` (DESIGN.md §13).  The old
#: per-layer fields map as level = max(slab frac, ring frac), live = total
#: live versions, capacity = S * V; ``under_pressure`` / ``deficit`` / ``live``
#: keep their names and meanings.
PressureReport = PressureSignal


def capacity_gate(
    state: MVState,
    slab_watermark: float = 0.75,
    ring_watermark: float = 0.5,
) -> PressureSignal:
    """Evaluate the slab- and ring-occupancy watermarks (turso's LWM rule:
    reclamation is *triggered by events* crossing a watermark, never by a
    timer alone).  ``deficit`` is the number of versions that must be freed
    to bring every slab under ``slab_watermark`` and the ring under
    ``ring_watermark`` — the quantity `reclaim_on_pressure` chases, mirroring
    the sim's ``ReclaimRequest.deficit``.  Returns the unified
    :class:`repro.core.telemetry.PressureSignal` (``level`` is the worse of
    the slab and ring occupancy fractions); all fields are traced values, so
    the gate composes under jit/shard_map."""
    S, V = state.store.ts.shape
    occ = (state.store.ts != EMPTY).sum(axis=1)
    slab_hi = max(1, int(slab_watermark * V))
    ring_hi = max(1, int(ring_watermark * state.ring.capacity))
    ring_size = rt.ring_size(state.ring)
    slab_over = jnp.maximum(occ - slab_hi, 0)
    deficit = slab_over.sum() + jnp.maximum(ring_size - ring_hi, 0)
    slab_frac = occ.max().astype(jnp.float32) / V
    ring_frac = ring_size.astype(jnp.float32) / state.ring.capacity
    return PressureSignal(
        level=jnp.maximum(slab_frac, ring_frac),
        under_pressure=(occ.max() > slab_hi) | (ring_size > ring_hi),
        deficit=deficit,
        live=occ.sum(),
        capacity=jnp.int32(S * V),
    )


def hot_slots(state: MVState, k: int) -> jax.Array:
    """Top-k slots by live-version occupancy — the deployable analogue of the
    sim's ``hot_keys`` resolution (the slots holding the most stale versions
    are where compaction pays first).  Returns i32[k], -1-padded for slots
    with <= 1 live version (nothing reclaimable: the current version stays)."""
    occ = (state.store.ts != EMPTY).sum(axis=1)
    vals, idx = jax.lax.top_k(occ, min(k, occ.shape[0]))
    return jnp.where(vals > 1, idx.astype(jnp.int32), -1)


def reclaim_on_pressure(
    state: MVState,
    hot_keys: jax.Array,  # i32[K] hot slot ids (-1 = inert lane), cf. hot_slots()
    deficit: jax.Array,   # i32[]  versions to free (capacity_gate().deficit)
    policy: str = "slrt",
    use_kernel: bool = False,
    interpret: bool = True,
    extra_pins: Optional[jax.Array] = None,
    ckpt_max: Optional[jax.Array] = None,
) -> Tuple[MVState, jax.Array, jax.Array]:
    """Synchronous pressure response with the optional checkpoint-coupled
    post-pass: the policy reclaim runs first (:func:`_policy_reclaim`), then
    — when ``ckpt_max`` is given (i32[], ``EMPTY`` = none) — the sole-
    survivor eviction frees idle-since-checkpoint slots the policy cannot
    touch (DESIGN.md §14).  Returns (state', freed_payloads, n_freed); the
    interface is otherwise exactly :func:`_policy_reclaim`'s."""
    live0 = live_versions(state)
    state, freed, _ = _policy_reclaim(
        state, hot_keys, deficit, policy=policy, use_kernel=use_kernel,
        interpret=interpret, extra_pins=extra_pins)
    if ckpt_max is not None:
        state, freed_ck, _ = evict_checkpointed(state, ckpt_max, extra_pins)
        freed = jnp.concatenate([freed.reshape(-1), freed_ck])
    return state, freed, live0 - live_versions(state)


def _policy_reclaim(
    state: MVState,
    hot_keys: jax.Array,
    deficit: jax.Array,
    policy: str = "slrt",
    use_kernel: bool = False,
    interpret: bool = True,
    extra_pins: Optional[jax.Array] = None,
) -> Tuple[MVState, jax.Array, jax.Array]:
    """Synchronous pressure response: run the policy's sweep over the hot
    slots first, spilling to the cold slabs only while the deficit is unmet —
    the jit-friendly port of the sim's ``SchemeBase.reclaim_on_pressure``
    (hot-first, then cold until ``freed >= deficit``), with the cold spill
    specialized through ``lax.cond``.

    Per policy (mirroring the sim's ``_reclaim`` overrides):

    * ``ebr``   — forced epoch turnover: free everything that closed before
                  the oldest pin; hot slots are irrelevant (EBR cannot target
                  a list — the paper's pathology, preserved deliberately).
    * ``steam`` — compact the hot slots' slabs, then cond-spill to a full
                  needed-sweep while the deficit is unmet.
    * ``dlrt``  — force-flush the retire ring (the tracker backlog *is* the
                  reclaimable set; exact entries only, like PDL.remove).
    * ``slrt``  — forced ring flush + implicated-slot sweep, then the hot
                  slots, then the cond cold spill (SSL compact's preemptive
                  splicing under pressure; the default).
    * ``sweep`` — the baseline: one full sweep, hot set ignored.

    Returns (state', freed_payloads, n_freed) — freed_payloads has EMPTY
    holes and may repeat handles (recycling must be idempotent); n_freed is
    the exact live-version delta."""
    assert policy in POLICIES, policy
    S, V = state.store.ts.shape
    live0 = live_versions(state)
    deficit = jnp.asarray(deficit, jnp.int32)

    if policy == "ebr":
        state, freed = gc_step(state, policy="ebr", extra_pins=extra_pins)
        return state, freed, live0 - live_versions(state)
    if policy == "sweep":
        state, freed = _sweep_all_needed(state, use_kernel=use_kernel,
                                         interpret=interpret,
                                         extra_pins=extra_pins)
        return state, freed, live0 - live_versions(state)
    if policy == "dlrt":
        state, freed = gc_step(state, policy="dlrt", force=True,
                               extra_pins=extra_pins)
        return state, freed, live0 - live_versions(state)

    # steam / slrt: hot-first, cold spill only while the deficit is unmet
    if policy == "slrt":
        state, freed_rt = gc_step(state, policy="slrt", force=True,
                                  use_kernel=use_kernel, interpret=interpret,
                                  extra_pins=extra_pins)
    else:
        freed_rt = jnp.full((0,), EMPTY, jnp.int32)
    state, freed_hot = _sweep_slots(state, jnp.maximum(hot_keys, 0),
                                    hot_keys >= 0, use_kernel=use_kernel,
                                    interpret=interpret,
                                    extra_pins=extra_pins)
    hot_met = (live0 - live_versions(state)) >= deficit

    def _cold(st: MVState):
        return _sweep_all_needed(st, use_kernel=use_kernel,
                                 interpret=interpret,
                                 extra_pins=extra_pins)

    def _skip(st: MVState):
        return st, jnp.full((S * V,), EMPTY, jnp.int32)

    state, freed_cold = jax.lax.cond(hot_met, _skip, _cold, state)
    freed = jnp.concatenate(
        [freed_rt.reshape(-1), freed_hot.reshape(-1), freed_cold.reshape(-1)])
    return state, freed, live0 - live_versions(state)


# ---------------------------------------------------------------------------
# Monitoring
# ---------------------------------------------------------------------------
def live_versions(state: MVState) -> jax.Array:
    return (state.store.ts != EMPTY).sum()


def space_report(state: MVState) -> dict:
    occ = pool.occupancy(state.store)
    return {
        "live_versions": int(live_versions(state)),
        "max_slot_occupancy": int(occ.max()),
        "ring_size": int(rt.ring_size(state.ring)),
        "overflows": int(state.overflow_count),
        "dropped_retires": int(state.dropped_retires),
    }
