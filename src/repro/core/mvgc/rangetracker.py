"""Batched RangeTracker — the BBF+ range-tracking object under bulk synchrony.

The sim layer's RangeTracker keeps per-process local lists flushed through a
shared queue; the TPU adaptation keeps one fixed-capacity **retire ring** per
shard: retired versions (flat store index + closed interval) are pushed as
they are overwritten; when occupancy crosses the flush threshold the whole
ring is intersected against the sorted announcements *in one vectorized
pass* — obsolete entries are freed from the store, still-needed ones are
compacted to the front of the ring.  Amortized O(1) per retirement, O(B) per
flush, exactly the BBF+ bound with the merge realized as a masked sweep
instead of a sorted-list merge.

Capacity = the paper's O(H + P^2 log P) space term: ring capacity must cover
needed-retired versions (H) plus one flush batch; overflow is reported and
handled by forcing a flush.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.mvgc.needed import needed_intervals
from repro.core.mvgc.pool import EMPTY, TS_MAX, VersionStore, free_entries


class RetireRing(NamedTuple):
    idx: jax.Array    # i32[B]: flat store index (slot * V + v); EMPTY = hole
    low: jax.Array    # i32[B]: interval start (version ts)
    high: jax.Array   # i32[B]: interval end (successor ts)

    @property
    def capacity(self) -> int:
        return self.idx.shape[0]


def make_ring(capacity: int) -> RetireRing:
    return RetireRing(
        idx=jnp.full((capacity,), EMPTY, jnp.int32),
        low=jnp.full((capacity,), EMPTY, jnp.int32),
        high=jnp.full((capacity,), TS_MAX, jnp.int32),
    )


def ring_size(ring: RetireRing) -> jax.Array:
    return (ring.idx != EMPTY).sum().astype(jnp.int32)


def push(
    ring: RetireRing,
    flat_idx: jax.Array,   # i32[K] flat store indices being retired
    low: jax.Array,        # i32[K]
    high: jax.Array,       # i32[K]
    mask: jax.Array,       # bool[K]
) -> Tuple[RetireRing, jax.Array]:
    """Append retired intervals into ring holes.  Returns (ring, dropped[K]):
    dropped lanes found no hole (caller must flush and retry — bulk-synchrony
    makes that a pure control-flow decision)."""
    B = ring.capacity
    holes = ring.idx == EMPTY                       # bool[B]
    # rank masked pushes and match them to hole positions in ascending order
    want = mask
    push_rank = jnp.cumsum(want.astype(jnp.int32)) - 1          # [K]
    n_holes = holes.sum()
    ok = want & (push_rank < n_holes)
    hole_pos = jnp.sort(jnp.where(holes, jnp.arange(B, dtype=jnp.int32), B))
    dest = jnp.where(ok, hole_pos[jnp.minimum(push_rank, B - 1)], B)  # B = drop
    new_ring = RetireRing(
        idx=ring.idx.at[dest].set(jnp.where(ok, flat_idx, EMPTY), mode="drop"),
        low=ring.low.at[dest].set(jnp.where(ok, low, EMPTY), mode="drop"),
        high=ring.high.at[dest].set(jnp.where(ok, high, TS_MAX), mode="drop"),
    )
    return new_ring, want & ~ok


def flush(
    ring: RetireRing,
    store: VersionStore,
    ann_sorted: jax.Array,
    now: jax.Array,
) -> Tuple[RetireRing, VersionStore, jax.Array]:
    """Intersect the ring against announcements; free obsolete store entries.

    Returns (ring', store', freed_payloads[B]) where freed_payloads holds the
    payload handles of reclaimed versions (EMPTY elsewhere) so the caller can
    return pages to its free pool."""
    S, V = store.ts.shape
    occupied = ring.idx != EMPTY
    needed = needed_intervals(
        jnp.where(occupied, ring.low, EMPTY), ring.high, ann_sorted, now
    )
    reclaim = occupied & ~needed
    # free the store entries (out-of-range sentinel index drops masked lanes)
    kill_flat = jnp.zeros((S * V,), jnp.bool_).at[
        jnp.where(reclaim, ring.idx, S * V)
    ].set(True, mode="drop")
    freed_payloads = jnp.where(
        reclaim, store.payload.reshape(-1)[jnp.minimum(ring.idx, S * V - 1)], EMPTY
    )
    store = free_entries(store, kill_flat.reshape(S, V))
    # keep needed entries, compacted to the front of the ring
    keep = occupied & needed
    ring = _compact_ring(ring, keep)
    return ring, store, freed_payloads


def _compact_ring(ring: RetireRing, keep: jax.Array) -> RetireRing:
    B = ring.capacity
    rank = jnp.cumsum(keep.astype(jnp.int32)) - 1
    dest = jnp.where(keep, rank, B)  # dropped
    def scatter(arr, fill):
        base = jnp.full((B,), fill, arr.dtype)
        return base.at[dest].set(jnp.where(keep, arr, fill), mode="drop")
    return RetireRing(
        idx=scatter(ring.idx, EMPTY),
        low=scatter(ring.low, EMPTY),
        high=scatter(ring.high, TS_MAX),
    )
