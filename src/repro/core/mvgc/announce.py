"""Announcement board: the bulk-synchronous analogue of the paper's
Announce[1..P] array plus the A1-A3 announce protocol (appendix B.2).

One lane per concurrent snapshot reader (a serving request performing a
multi-page snapshot read, an evaluator pinning a checkpoint, a speculative
branch scoring pass).  Under SPMD the board is a small replicated-or-sharded
i32 vector; announce/unannounce are masked scatters; the scan is a sort.
Readers are sharded with their data shard, so each shard's GC pass only needs
its local board — sharding gives the locality the GlobalAnnScan protocol had
to engineer.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.mvgc.pool import EMPTY, TS_MAX
from repro.core.mvgc.needed import sort_announcements


class AnnounceBoard(NamedTuple):
    slots: jax.Array  # i32[P]: announced timestamp per reader lane; EMPTY = idle

    @property
    def num_lanes(self) -> int:
        return self.slots.shape[0]


def make_board(num_lanes: int) -> AnnounceBoard:
    return AnnounceBoard(slots=jnp.full((num_lanes,), EMPTY, jnp.int32))


def announce(
    board: AnnounceBoard, lanes: jax.Array, ts: jax.Array, mask: jax.Array
) -> AnnounceBoard:
    """Pin timestamps: lanes[i] announces ts[i] where mask[i].

    The A1-A3 validation loop is unnecessary here: the timestamp is taken and
    published in the same synchronous step, so it can never be stale."""
    ts = jnp.broadcast_to(jnp.asarray(ts, jnp.int32), lanes.shape)
    upd = jnp.where(mask, ts, board.slots[lanes])
    return AnnounceBoard(slots=board.slots.at[lanes].set(upd, mode="drop"))


def unannounce(
    board: AnnounceBoard, lanes: jax.Array, mask: jax.Array
) -> AnnounceBoard:
    upd = jnp.where(mask, EMPTY, board.slots[lanes])
    return AnnounceBoard(slots=board.slots.at[lanes].set(upd, mode="drop"))


def scan(board: AnnounceBoard) -> jax.Array:
    """Sorted announcement snapshot (TS_MAX padded) for needed()."""
    return sort_announcements(board.slots)


def oldest(board: AnnounceBoard, now: jax.Array) -> jax.Array:
    """Oldest pinned timestamp, or ``now`` if nothing is pinned (the EBR
    epoch boundary)."""
    active = board.slots != EMPTY
    vals = jnp.where(active, board.slots, TS_MAX)
    m = vals.min()
    return jnp.where(active.any(), m, now).astype(jnp.int32)


def lwm(board: AnnounceBoard) -> jax.Array:
    """This board's low-water-mark contribution: the oldest pinned
    timestamp, or the ``TS_MAX`` sentinel when nothing is pinned.

    Unlike :func:`oldest` (whose no-pins fallback is the *local* ``now``),
    the sentinel is host-independent — it is the identity of ``min``, so a
    pin-free host drops out of the cross-host
    ``make_ring_all_reduce(reduce="min")`` reduction instead of capping the
    global LWM at its own clock (DESIGN.md §13)."""
    return jnp.where(board.slots != EMPTY, board.slots, TS_MAX) \
        .min().astype(jnp.int32)
