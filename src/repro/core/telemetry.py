"""One pressure/telemetry vocabulary for the whole MVGC stack (DESIGN.md §13).

Before this module the repo spoke three disjoint dialects for the same
signal: the sim's ``ContentionManager.pressure()`` (a 0..1 float),
``vstore.PressureReport`` (slab/ring watermark scalars) and
``mvkv.paged.PagePressure`` (free-bitmap watermark scalars), with the serve
engines flattening either into ad-hoc counter dicts.  The sharded multi-host
stack (``repro.dist.mvgc``) would have added a fourth.  Everything now
produces/consumes two types:

* :class:`PressureSignal` — the instantaneous *how full are we* gate output.
  A NamedTuple of traced-friendly scalars (or ``[H]`` vectors on a stacked
  multi-host state), so it composes under jit / shard_map / vmap exactly
  like the per-layer reports it replaces.  ``vstore.capacity_gate``,
  ``mvkv.paged.page_pressure`` and ``ContentionManager.pressure_signal``
  all return it; the old names (``PressureReport``, ``PagePressure``,
  ``pressure()``) remain as thin deprecated aliases for one release.
* :class:`ReclaimStats` — the host-side *what did reclamation do about it*
  accounting: a mutable counter bundle whose :meth:`ReclaimStats.as_row`
  emits the schema-v4 BENCH field names (``pressure_events``,
  ``reclaims_triggered``, ``pages_reclaimed``, ...), so BENCH payloads and
  existing tests stay valid while the engines share one implementation.

:class:`GCConfig` collapses the GC/pressure kwarg sprawl that had crept into
``make_paged_kv`` / ``PagedKVEngine`` / ``RunConfig`` (policy, slab depth,
reader lanes, ring capacity, kernel dispatch, watermarks, reclaim rounds)
into one frozen dataclass threaded through the engines, the vstore and the
benchmarks; the old kwargs emit ``DeprecationWarning`` for one release.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Any, Dict, NamedTuple, Optional


class PressureSignal(NamedTuple):
    """Unified capacity-gate output (DESIGN.md §13).

    All fields are traced-friendly scalars — or per-host vectors when the
    producer runs over a host-stacked state — so the signal flows through
    ``lax.cond`` triggers and shard_map boundaries unchanged.  Producers map
    their native vocabulary onto it:

    ======================  ======================================  =========
    field                   vstore (descriptor slabs)               paged pool
    ======================  ======================================  =========
    ``level``               max(slab frac, ring frac)               1 - free frac
    ``under_pressure``      either watermark crossed                below watermark
    ``deficit``             versions to free                        pages to free
    ``live``                live versions                           live pages
    ``capacity``            slots x versions_per_slot               pool pages
    ======================  ======================================  =========
    """

    level: Any            # f32 0..1 resource-fullness (1.0 = exhausted)
    under_pressure: Any   # bool: a watermark is crossed — reclaim now
    deficit: Any          # i32 units (versions/pages) to free to clear it
    live: Any             # i32 currently-live units
    capacity: Any         # i32 total units the resource can hold

    @property
    def free_frac(self):
        """Deprecated ``PagePressure.free_frac`` alias (= 1 - level)."""
        return 1.0 - self.level

    @property
    def free_pages(self):
        """Deprecated ``PagePressure.free_pages`` alias (= capacity - live)."""
        return self.capacity - self.live


@dataclasses.dataclass
class ReclaimStats:
    """Host-side reclamation accounting shared by every engine.

    ``unit`` names what ``reclaimed``/``peak_live`` count (``"pages"`` for
    the paged engines, ``"versions"`` for descriptor-only ones).  The field
    names are engine-neutral; :meth:`as_row` maps them back onto the
    schema-v4 BENCH vocabulary (``pages_reclaimed``, ``peak_pages``, ...)
    so committed payloads and their checkers keep working unchanged.
    """

    unit: str = "pages"
    pressure_events: int = 0        # gate triggers (failed op or watermark)
    reclaims_triggered: int = 0     # synchronous reclaim passes actually run
    reclaimed: int = 0              # units returned to the free pool
    give_ups: int = 0               # lanes abandoned after max reclaim rounds
    peak_live: int = 0              # max live units ever observed
    peak_live_post_reclaim: int = 0  # max live units right after a reclaim
    stale_lanes_aged: int = 0       # dist: stale host announcements aged out
    ckpt_evictions: int = 0         # sole-survivor evictions (DESIGN.md §14)
    ckpt_freed: int = 0             # units freed by checkpoint eviction alone

    def note_event(self) -> None:
        """One pressure event (a failed append/fork/reset or a watermark
        crossing) — the trigger, not the response."""
        self.pressure_events += 1

    def note_reclaim(self, freed: int, live_after: int) -> None:
        """One synchronous reclaim pass that freed ``freed`` units, leaving
        ``live_after`` live (feeds the post-reclaim peak)."""
        self.reclaims_triggered += 1
        self.reclaimed += max(0, int(freed))
        self.peak_live_post_reclaim = max(self.peak_live_post_reclaim,
                                          int(live_after))

    def note_ckpt_eviction(self, evicted: int, freed: int) -> None:
        """One checkpoint-eviction pass: ``evicted`` sole-survivor versions
        dropped because durable storage has them, freeing ``freed`` units no
        GC policy could otherwise reclaim (DESIGN.md §14)."""
        self.ckpt_evictions += max(0, int(evicted))
        self.ckpt_freed += max(0, int(freed))

    def note_live(self, live: int) -> None:
        """Track the all-time live peak."""
        self.peak_live = max(self.peak_live, int(live))

    def as_row(self) -> Dict[str, int]:
        """The schema-v4 BENCH serve-field names (``units['serve_pressure']``)."""
        return {
            "pressure_events": self.pressure_events,
            "reclaims_triggered": self.reclaims_triggered,
            f"{self.unit}_reclaimed": self.reclaimed,
            "give_ups": self.give_ups,
            f"peak_{self.unit}": self.peak_live,
            f"peak_{self.unit}_post_reclaim": self.peak_live_post_reclaim,
            "stale_lanes_aged": self.stale_lanes_aged,
            "ckpt_evictions": self.ckpt_evictions,
            f"ckpt_{self.unit}_freed": self.ckpt_freed,
        }


@dataclasses.dataclass(frozen=True)
class GCConfig:
    """Every GC/pressure knob in one place (DESIGN.md §13).

    Threaded through ``vstore.make_state`` / ``mvkv.paged.make_paged_kv`` /
    ``serve.engine.PagedKVEngine`` / ``configs.base.RunConfig`` and the
    benchmarks, replacing the per-call kwarg sprawl (``ring_capacity``,
    ``use_kernel``, ``kernel_interpret``, pool sizes, watermarks).  The old
    kwargs still work for one release but emit ``DeprecationWarning``.
    """

    policy: str = "slrt"            # ebr | steam | dlrt | slrt | sweep
    versions_per_slot: int = 8      # descriptor slab depth
    reader_lanes: int = 8           # announcement-board lanes
    ring_capacity: int = 0          # retire ring; 0 = sized from the store
    use_kernel: bool = False        # dispatch sweeps to the Pallas kernels
    kernel_interpret: bool = True   # interpret mode (CPU validation)
    slab_watermark: float = 0.75    # vstore capacity_gate slab threshold
    ring_watermark: float = 0.5     # vstore capacity_gate ring threshold
    page_watermark: float = 0.25    # paged-pool free-fraction threshold
    hot_k: int = 8                  # hot-slot count for targeted reclaim
    max_reclaim_rounds: int = 3     # reclaim-and-retry attempts per step
    # multi-host (repro.dist.mvgc): a stalled host's stale announcement is
    # aged out of the global LWM after this budget; inf = defer to the
    # engine's StepWatchdog-derived budget (StepWatchdog.budget_s)
    stale_after_s: float = math.inf

    def kernel_kwargs(self) -> Dict[str, bool]:
        """The (use_kernel, interpret) pair most vstore/paged calls take."""
        return {"use_kernel": self.use_kernel,
                "interpret": self.kernel_interpret}

    def replace(self, **kw) -> "GCConfig":
        """``dataclasses.replace`` shorthand."""
        return dataclasses.replace(self, **kw)


def resolve_gc_config(gc: Optional[GCConfig], where: str,
                      **legacy: Any) -> GCConfig:
    """Fold deprecated per-call GC kwargs into a :class:`GCConfig`.

    ``legacy`` maps GCConfig field names to the values the caller passed for
    the old kwargs (``None`` = not passed).  Any non-``None`` legacy value
    emits one :class:`DeprecationWarning` naming ``where`` and overrides the
    corresponding field — matching the pre-redesign behaviour exactly while
    steering callers to ``gc=GCConfig(...)``.
    """
    base = gc if gc is not None else GCConfig()
    passed = {k: v for k, v in legacy.items() if v is not None}
    if passed:
        warnings.warn(
            f"{where}: keyword(s) {sorted(passed)} are deprecated; pass "
            f"gc=GCConfig(...) instead (DESIGN.md §13)",
            DeprecationWarning, stacklevel=3)
        base = dataclasses.replace(base, **passed)
    return base
