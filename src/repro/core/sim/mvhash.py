"""Multiversion hash table (paper §6.1).

Separate chaining with **immutable** chains: insert/delete path-copy the
bucket's chain (a sorted tuple of (key, value) pairs) and CAS the bucket's
vCAS head to the new copy.  Load factor ~0.5 as in the paper.  Crucially, the
values stored in versions are flat tuples — vCAS objects never point
(indirectly) to other vCAS objects, which is what makes Steam behave well
here and badly on the tree.

Range scans (``range_scan``, DESIGN.md §7) are explicit multi-slice
operations: a scan announced inside a read-only transaction (rtx) at
timestamp ``t`` probes each key of its interval through the owning bucket's
version list at ``t``, yielding between bucket reads so updates interleave
while the rtx pins its snapshot — the hash table has no key order, so the
paper's rtx over [lo, hi) is exactly this per-key probe loop.
"""
from __future__ import annotations

from typing import Any, Generator, List, Optional, Tuple

from repro.core.sim.machine import drain
from repro.core.sim.vcas import VCas


class MVHashTable:
    def __init__(self, env, scheme, expected_keys: int, load_factor: float = 0.5):
        self.env = env
        self.scheme = scheme
        self.num_buckets = max(8, int(expected_keys / load_factor))
        self.buckets: List[VCas] = [
            VCas(env, scheme, ()) for _ in range(self.num_buckets)
        ]

    def _bucket(self, k: int) -> VCas:
        # Fibonacci hashing: cheap, deterministic, well-spread for int keys.
        h = (k * 11400714819323198485) & 0xFFFFFFFFFFFFFFFF
        return self.buckets[h % self.num_buckets]

    # -- update operations ---------------------------------------------------
    def insert(self, pid: int, k: int, v: Any) -> bool:
        """Upsert; returns True if the key was newly inserted."""
        b = self._bucket(k)
        while True:
            head = b.head_node()
            chain: Tuple = head.val
            idx = _find(chain, k)
            if idx >= 0:
                new_chain = chain[:idx] + ((k, v),) + chain[idx + 1 :]
                fresh = False
            else:
                new_chain = tuple(sorted(chain + ((k, v),)))
                fresh = True
            if b.cas_from_head(pid, head, new_chain):
                return fresh

    def delete(self, pid: int, k: int) -> bool:
        b = self._bucket(k)
        while True:
            head = b.head_node()
            chain: Tuple = head.val
            idx = _find(chain, k)
            if idx < 0:
                return False
            new_chain = chain[:idx] + chain[idx + 1 :]
            if b.cas_from_head(pid, head, new_chain):
                return True

    # -- read operations -------------------------------------------------------
    def lookup(self, pid: int, k: int) -> Optional[Any]:
        chain = self._bucket(k).read()
        idx = _find(chain, k)
        return chain[idx][1] if idx >= 0 else None

    def rtx_lookup(self, pid: int, k: int, t: float) -> Optional[Any]:
        """Read key k in the snapshot at timestamp t (one key of an rtx)."""
        return self.rtx_lookup_versioned(pid, k, t)[0]

    def rtx_lookup_versioned(self, pid: int, k: int,
                             t: float) -> Tuple[Optional[Any], float]:
        """Snapshot read of key k at t returning ``(value, version_ts)``
        where ``version_ts`` stamps the *governing version* — the bucket's
        chain version that supplied the value.  The bucket is the CAS
        granule of this structure (updates path-copy and swing the whole
        chain), so the chain version is exactly the "object version" a
        MV-RLU-style try-lock would contend on (DESIGN.md §9)."""
        node = self._bucket(k).read_version_node(t)
        idx = _find(node.val, k)
        return (node.val[idx][1] if idx >= 0 else None), node.ts

    def range_scan(self, pid: int, lo: int, hi: int, t: float) -> Generator:
        """Sliced snapshot range scan at timestamp ``t``: one yield per
        bucket-version read; ``return``s the sorted [(key, val)] snapshot of
        [lo, hi) as of ``t``."""
        out: List[Tuple] = []
        for k in range(lo, hi):
            chain = self._bucket(k).read_version(t)
            yield
            idx = _find(chain, k)
            if idx >= 0:
                out.append((k, chain[idx][1]))
        return out

    def range_query(self, pid: int, lo: int, hi: int, t: float) -> List[Tuple]:
        """Atomic convenience form of ``range_scan`` (drained in one slice)."""
        return drain(self.range_scan(pid, lo, hi, t))

    # -- targeted reclamation (DESIGN.md §10) ------------------------------------
    def version_lists_for(self, k: int) -> List[Any]:
        """The version lists that govern key ``k`` — here just the owning
        bucket's list (the bucket is this structure's CAS granule).  This is
        the targeted-compaction entry point the reclamation feedback loop
        hands to ``SchemeBase.set_key_resolver`` so hot-set-aware schemes
        can compact exactly where a capacity storm allocates versions."""
        return [self._bucket(k).lst]

    # -- space accounting --------------------------------------------------------
    def root_vcas(self) -> List[VCas]:
        return self.buckets


def _find(chain: Tuple, k: int) -> int:
    for i, (key, _) in enumerate(chain):
        if key == k:
            return i
    return -1
