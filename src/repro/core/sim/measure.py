"""Shared operation-mix and measurement plumbing for the sim benchmarks.

All three benchmark drivers (``benchmarks/gc_comparison.py`` — the paper's
Figures 4-8 —, ``benchmarks/range_query.py`` — the EEMARQ-style range-scan
family, DESIGN.md §7 — and ``benchmarks/txn_mix.py`` — the read-write
update-in-scan txn family, DESIGN.md §8) build their workloads from
:class:`OpMix` and serialize their results through :class:`Measurement` /
:func:`write_bench_json`, so the trajectories stay apples-to-apples: same
space units (Java-reachability words, DESIGN.md §5), same throughput proxy
(completed operations per million simulated work units), same JSON schema
(which ``tools/compare_bench.py`` — the CI bench-trajectory gate — diffs
against the committed repo-root files).

``BENCH_*.json`` schema (``SCHEMA_VERSION`` = 4).  Field-by-field changelog:

* **v2** added the read-write transaction row fields ``txn_size`` /
  ``rw_ratio`` / ``txns_committed`` / ``txns_aborted`` / ``abort_rate``
  (DESIGN.md §8);
* **v3** added the MV-RLU-style multi-interval/contention fields
  ``txn_ranges`` / ``point_reads`` / ``aborts_footprint`` / ``aborts_wcc`` /
  ``aborts_capacity`` / ``txn_giveups`` / ``backoff_slices`` (DESIGN.md §9);
* **v4** added the abort ⇒ reclaim ⇒ retry fields (DESIGN.md §10):
  ``reclaims_triggered`` (synchronous reclaim passes driven by capacity
  aborts; always ≤ ``aborts_capacity``), ``versions_reclaimed_on_abort``
  (versions those passes spliced out of reachability — each refunds one
  budget token), ``reclaim_latency_slices`` (scheduler slices aborting
  processes stalled paying for their reclaims), and
  ``peak_space_post_reclaim`` (max space in words sampled immediately
  *after* a reclaim pass — the bounded-space signal: how high space stays
  even right after reclamation has run)::

    {
      "bench": "<driver name>",
      "schema_version": 4,
      "units": {...},                 # human-readable unit strings
      "meta": {...},                  # driver-specific run parameters
      "rows": [<Measurement dict>, ...]
    }

Every row carries the keys in ``REQUIRED_ROW_KEYS``; ``tools/
check_bench_json.py`` (run by the CI ``bench-smoke`` step) enforces this.

Since v4.1 each payload also **declares its row schema** (top-level
``row_schema`` key) against the registry below (:class:`BenchSchema` /
:func:`register_bench_schema`): a schema names its row type, identity key
fields, trajectory-compared value fields, and row invariants, so
``check_bench_json`` / ``compare_bench`` / ``plot_bench`` dispatch on the
payload instead of growing per-bench flags.  Legacy payloads without
``row_schema`` are inferred from their ``bench`` name.
"""
from __future__ import annotations

import json
import os
import sys
from dataclasses import asdict, dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

SCHEMA_VERSION = 4

UNITS = {
    "space": "words, Java-style reachability from the structure roots "
             "(version nodes at the scheme's per-node cost + payloads + GC "
             "metadata; DESIGN.md §5)",
    "throughput": "completed operations per 1e6 simulated work units "
                  "(work unit = one shared-memory access of the lock-free "
                  "algorithm; DESIGN.md §5)",
    "scan_size": "keys per range scan (half-open key interval [lo, lo+s))",
    "txn_size": "buffered writes per read-write transaction (DESIGN.md §8)",
    "abort_rate": "aborted commit attempts / all commit attempts, in [0, 1]",
    "rw_ratio": "read-write transactions / all transactions (scan-only rtxs "
                "+ read-write txns), in [0, 1]",
    "txn_ranges": "disjoint scan intervals per read-write transaction "
                  "(multi-interval footprint, DESIGN.md §9)",
    "point_reads": "tracked version-wise point reads per read-write "
                   "transaction (revalidated at commit, DESIGN.md §9)",
    "abort_reasons": "aborts_footprint / aborts_wcc / aborts_capacity "
                     "partition txns_aborted by cause: full-footprint "
                     "validation failure / eager write-commit (first-"
                     "updater-wins) conflict / version-budget exhaustion "
                     "(DESIGN.md §9)",
    "backoff_slices": "scheduler slices spent in contention-manager backoff "
                      "between txn retries (bounded exponential)",
    "reclaims": "reclaims_triggered counts synchronous reclaim passes "
                "driven by capacity aborts (abort => reclaim => retry, "
                "DESIGN.md §10; <= aborts_capacity); "
                "versions_reclaimed_on_abort counts versions those passes "
                "spliced out of reachability (each refunds one version-"
                "budget token); reclaim_latency_slices counts scheduler "
                "slices aborting processes stalled paying for them",
    "peak_space_post_reclaim": "max space (words) sampled immediately after "
                               "a reclaim pass — the bounded-space signal "
                               "(0 when no reclaim ever ran)",
    "pages": "KV-cache pages in the paged pool (BENCH_serve rows measure "
             "space in pages: peak_space_words/end_space_words are "
             "peak/end live-page counts; DESIGN.md §11)",
    "serve_pressure": "pressure_events counts triggers (a failed append or "
                      "a post-step watermark crossing); reclaims_triggered "
                      "counts the synchronous reclaim passes they drove "
                      "(<= pressure_events); pages_reclaimed counts pages "
                      "returned to the free bitmap by those passes; "
                      "peak_pages_post_reclaim is the max live-page count "
                      "sampled immediately after a reclaim pass (0 when no "
                      "reclaim ever ran; DESIGN.md §11)",
    "kernel_bench": "BENCH_kernel rows time one fused GC/read primitive "
                    "(us_fused, best-of-iters) against the unfused two-"
                    "dispatch lax baseline (us_unfused); bytes_moved is the "
                    "analytic per-launch traffic model, gb_s = bytes_moved / "
                    "us_fused, and target_gb_s = target_frac * the roofline "
                    "bandwidth peak for the timed backend (launch/roofline."
                    "py; DESIGN.md §12).  Deterministic cells (bytes_moved, "
                    "target_*) are trajectory-gated; timing cells are not.",
    "fork_bench": "BENCH_fork rows measure one fork-DAG serving run "
                  "(DESIGN.md §14): forks/joins/releases count successful "
                  "engine lineage ops; pages_shared_peak is the max count "
                  "of pages referenced by >1 live table version (COW "
                  "sharing the eager-copy control cannot have); "
                  "eager_peak_pages is the peak of the same cell re-run "
                  "with fork_sequence(copy_pages=True) and "
                  "shared_savings_pages = eager_peak_pages - peak_pages; "
                  "prefix_checks/prefix_violations count ForkValidator "
                  "byte-stability replays of inherited prefixes (must be "
                  "0 violations); ckpt_saves counts engine checkpoints "
                  "taken, ckpt_evictions/ckpt_pages_freed the sole-"
                  "survivor evictions they enabled, and control_ckpt_"
                  "pages_freed/control_end_pages the same cell re-run "
                  "without any checkpoint — the control provably cannot "
                  "make those reclaims (ckpt freed stays 0, end pages "
                  "stay higher)",
    "dist_bench": "BENCH_dist rows measure one sharded multi-host serving "
                  "run (repro.dist.mvgc; DESIGN.md §13): page counts are "
                  "summed over every host's pool; lwm is the final "
                  "mesh-wide low-water mark (ring-min over per-host oldest "
                  "pins; 2147483647 = the pin-free TS_MAX sentinel) and "
                  "lwm_advances counts its upward moves; stale_lanes_aged "
                  "counts stalled hosts' announcements aged out of the "
                  "reduction past their watchdog budget (nonzero only when "
                  "stalled_hosts > 0); pin_violations counts snapshot "
                  "reads that lost a version pinned by *any* host to a "
                  "reclaim pass — the global-LWM safety invariant demands "
                  "exactly 0",
}

REQUIRED_TOP_KEYS = ("bench", "schema_version", "units", "meta", "rows")

REQUIRED_ROW_KEYS = (
    "bench", "figure", "ds", "scheme", "mix", "scan_size", "zipf",
    "n_keys", "num_procs", "ops_per_proc", "seed",
    "updates", "lookups", "scans", "scan_keys", "total_work",
    "ops_per_mwork", "updates_per_mwork", "scan_keys_per_mwork",
    "peak_space_words", "peak_versions", "avg_space_words",
    "end_space_words", "end_versions_per_list",
    "scans_validated", "scan_violations", "wall_s",
    # read-write transactions (schema v2, DESIGN.md §8)
    "txn_size", "rw_ratio", "txns_committed", "txns_aborted", "abort_rate",
    # multi-interval footprints + contention (schema v3, DESIGN.md §9)
    "txn_ranges", "point_reads", "aborts_footprint", "aborts_wcc",
    "aborts_capacity", "txn_giveups", "backoff_slices",
    # abort => reclaim => retry (schema v4, DESIGN.md §10)
    "reclaims_triggered", "versions_reclaimed_on_abort",
    "reclaim_latency_slices", "peak_space_post_reclaim",
)


# ---------------------------------------------------------------------------
# Operation mix
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class OpMix:
    """A mixed workload's operation distribution.

    Fractions are per-operation probabilities (update / point lookup / range
    scan / read-write transaction) and must sum to 1.  ``scan_size`` is the
    number of keys each range scan covers — read-write transactions scan
    ``txn_ranges`` *disjoint* intervals of that size (a multi-interval
    footprint), perform ``txn_point_reads`` tracked version-wise point
    reads, and buffer ``txn_size`` writes spread across the scanned
    intervals, all committed at one validated timestamp (EEMARQ-style
    update-in-scan pushed to MV-RLU's full footprint model, DESIGN.md
    §8-§9).  EEMARQ (Sheffi et al., 2022) names its mixes
    "update/lookup/scan" percentage triples; ``name`` carries that label
    (four components when ``rwtxn_frac`` > 0).
    """

    update_frac: float
    lookup_frac: float
    scan_frac: float
    scan_size: int = 64
    name: str = ""
    rwtxn_frac: float = 0.0
    txn_size: int = 4
    txn_ranges: int = 1
    txn_point_reads: int = 0

    def __post_init__(self):
        for f in (self.update_frac, self.lookup_frac, self.scan_frac,
                  self.rwtxn_frac):
            if not (0.0 <= f <= 1.0):
                raise ValueError(f"OpMix fraction {f} outside [0, 1]")
        total = (self.update_frac + self.lookup_frac + self.scan_frac
                 + self.rwtxn_frac)
        if abs(total - 1.0) > 1e-9:
            raise ValueError(f"OpMix fractions sum to {total}, expected 1.0")
        if (self.scan_frac > 0 or self.rwtxn_frac > 0) and self.scan_size < 1:
            raise ValueError("scan/rwtxn fractions > 0 require scan_size >= 1")
        if self.rwtxn_frac > 0 and self.txn_size < 1:
            raise ValueError("rwtxn_frac > 0 requires txn_size >= 1")
        if self.txn_ranges < 1:
            raise ValueError("txn_ranges must be >= 1")
        if self.txn_point_reads < 0:
            raise ValueError("txn_point_reads must be >= 0")

    @property
    def label(self) -> str:
        """The mix's display name (EEMARQ-style percentage triple/quad)."""
        if self.name:
            return self.name
        parts = [self.update_frac, self.lookup_frac, self.scan_frac]
        if self.rwtxn_frac > 0:
            parts.append(self.rwtxn_frac)
        return "/".join(str(round(100 * p)) for p in parts)

    @property
    def rw_ratio(self) -> float:
        """Share of transactions (scan-only rtxs + rw txns) that read-write."""
        txn_frac = self.scan_frac + self.rwtxn_frac
        return round(self.rwtxn_frac / txn_frac, 4) if txn_frac > 0 else 0.0


# The EEMARQ-style range-heavy mixes (update/lookup/scan).
EEMARQ_MIXES = (
    OpMix(0.50, 0.25, 0.25, name="50/25/25"),
    OpMix(0.10, 0.10, 0.80, name="10/10/80"),
)
EEMARQ_SCAN_SIZES = (8, 64, 1024, 8192)
EEMARQ_ZIPFS = (0.0, 0.99)   # uniform + the YCSB-default Zipfian

# The read-write update-in-scan mixes (update/lookup/scan/rwtxn; DESIGN.md
# §8): a balanced mix (half of all txns read-write) and a txn-heavy one
# (three quarters read-write), spanning the rw/ro-ratio axis.
EEMARQ_RW_MIXES = (
    OpMix(0.30, 0.20, 0.25, rwtxn_frac=0.25, name="30/20/25/25"),
    OpMix(0.10, 0.10, 0.20, rwtxn_frac=0.60, name="10/10/20/60"),
)
EEMARQ_TXN_SIZES = (2, 8)
EEMARQ_RW_SCAN_SIZES = (16, 128)
# multi-interval footprints (MV-RLU-style, DESIGN.md §9): r disjoint scan
# intervals per txn; the high-contention tier concentrates the key draws
# (Zipf 1.2 vs the YCSB-default 0.99) so abort/retry storms actually form
EEMARQ_TXN_RANGES = (2, 4)
EEMARQ_HC_ZIPF = 1.2


# ---------------------------------------------------------------------------
# Measurement rows
# ---------------------------------------------------------------------------
@dataclass
class Measurement:
    """One benchmark cell: (driver, figure, structure, scheme, mix) with its
    space + throughput measurements, flattened for JSON serialization."""

    bench: str
    figure: str
    ds: str
    scheme: str
    mix: str
    scan_size: int
    zipf: float
    n_keys: int
    num_procs: int
    ops_per_proc: int
    seed: int
    updates: int
    lookups: int
    scans: int
    scan_keys: int
    total_work: int
    ops_per_mwork: float
    updates_per_mwork: float
    scan_keys_per_mwork: float
    peak_space_words: int
    peak_versions: int
    avg_space_words: int
    end_space_words: int
    end_versions_per_list: float
    scans_validated: int
    scan_violations: int
    wall_s: float
    txn_size: int = 0
    rw_ratio: float = 0.0
    txns_committed: int = 0
    txns_aborted: int = 0
    abort_rate: float = 0.0
    txn_ranges: int = 0
    point_reads: int = 0
    aborts_footprint: int = 0
    aborts_wcc: int = 0
    aborts_capacity: int = 0
    txn_giveups: int = 0
    backoff_slices: int = 0
    reclaims_triggered: int = 0
    versions_reclaimed_on_abort: int = 0
    reclaim_latency_slices: int = 0
    peak_space_post_reclaim: int = 0
    scheme_stats: Dict[str, Any] = field(default_factory=dict)

    @classmethod
    def from_result(cls, bench: str, figure: str, result: Dict[str, Any],
                    wall_s: float = 0.0) -> "Measurement":
        """Build a row from a ``run_workload`` result dict."""
        cfg = result["config"]
        c = result["counters"]
        mix = getattr(cfg, "op_mix", None)
        if cfg.mode == "split":
            mix_label = "split"
            scan_size = cfg.scan_size
        else:
            mix_label = mix.label if mix is not None else "mixed"
            scan_size = mix.scan_size if mix is not None else 0
        return cls(
            bench=bench,
            figure=figure,
            ds=cfg.ds,
            scheme=cfg.scheme,
            mix=mix_label,
            scan_size=scan_size,
            zipf=cfg.zipf,
            n_keys=cfg.n_keys,
            num_procs=cfg.num_procs,
            ops_per_proc=cfg.ops_per_proc,
            seed=cfg.seed,
            updates=c["updates"],
            lookups=c["lookups"],
            scans=c["scans"],
            scan_keys=c["scan_keys"],
            total_work=result["total_work"],
            ops_per_mwork=round(result["ops_per_mwork"], 3),
            updates_per_mwork=round(result["updates_per_mwork"], 3),
            scan_keys_per_mwork=round(result["scan_keys_per_mwork"], 3),
            peak_space_words=result["peak_space"]["words"],
            peak_versions=result["peak_space"].get("versions", 0),
            avg_space_words=int(result["avg_space"]),
            end_space_words=result["end_space"]["words"],
            end_versions_per_list=round(
                result["end_space"]["versions_per_list"], 4),
            scans_validated=result.get("scans_validated", 0),
            scan_violations=result.get("scan_violations", 0),
            wall_s=round(wall_s, 2),
            txn_size=(mix.txn_size if mix is not None and mix.rwtxn_frac > 0
                      else 0),
            rw_ratio=(mix.rw_ratio if mix is not None else 0.0),
            txns_committed=c.get("txn_commits", 0),
            txns_aborted=c.get("txn_aborts", 0),
            abort_rate=round(
                c.get("txn_aborts", 0)
                / max(1, c.get("txn_commits", 0) + c.get("txn_aborts", 0)), 4),
            txn_ranges=(mix.txn_ranges
                        if mix is not None and mix.rwtxn_frac > 0 else 0),
            point_reads=(mix.txn_point_reads
                         if mix is not None and mix.rwtxn_frac > 0 else 0),
            aborts_footprint=c.get("txn_aborts_footprint", 0),
            aborts_wcc=c.get("txn_aborts_wcc", 0),
            aborts_capacity=c.get("txn_aborts_capacity", 0),
            txn_giveups=c.get("txn_giveups", 0),
            backoff_slices=int(
                result.get("contention_stats", {}).get("backoff_slices", 0)),
            reclaims_triggered=int(
                result.get("contention_stats", {})
                .get("reclaims_triggered", 0)),
            versions_reclaimed_on_abort=int(
                result.get("contention_stats", {})
                .get("versions_reclaimed_on_abort", 0)),
            reclaim_latency_slices=int(
                result.get("contention_stats", {})
                .get("reclaim_latency_slices", 0)),
            peak_space_post_reclaim=c.get("peak_space_post_reclaim", 0),
            scheme_stats=dict(result.get("scheme_stats", {})),
        )

    def to_row(self) -> Dict[str, Any]:
        """Flatten to the dict serialized as one BENCH json row."""
        return asdict(self)


@dataclass
class ServeMeasurement(Measurement):
    """One ``BENCH_serve.json`` cell: a paged-KV serving run under one GC
    policy and one pressure tier (DESIGN.md §11).

    Reuses the base row contract so ``write_bench_json`` /
    ``tools/compare_bench.py`` work unchanged: ``scheme`` is the vstore GC
    policy, ``ds`` is ``paged_kv``, space is measured in **pages** —
    ``peak_space_words`` / ``end_space_words`` carry peak/end live-page
    counts, ``peak_space_post_reclaim`` carries ``peak_pages_post_reclaim``
    — and ``scans_validated`` / ``scan_violations`` count pinned-snapshot
    stability checks.  ``reclaims_triggered`` (inherited) counts synchronous
    reclaim passes; the serve-only fields below add the pressure-loop
    accounting (``units["serve_pressure"]``)."""

    pressure_events: int = 0
    pages_reclaimed: int = 0
    peak_pages: int = 0
    peak_pages_post_reclaim: int = 0
    page_pool: int = 0
    page_size: int = 0
    decode_steps: int = 0
    tokens_appended: int = 0
    sequences_completed: int = 0
    forks: int = 0
    give_ups: int = 0
    snapshot_pins: int = 0
    overflow_count: int = 0
    dropped_retires: int = 0


@dataclass
class DistMeasurement(ServeMeasurement):
    """One ``BENCH_dist.json`` cell: a sharded multi-host serving run under
    global-LWM reclamation (``repro.dist.mvgc``, DESIGN.md §13).

    Extends the serve row — the space/pressure fields keep their serve
    meaning but are summed over every host's shard (``page_pool`` is the
    global pool, ``peak_pages`` the global live peak) — with the dist-only
    accounting in ``units["dist_bench"]``.  ``pin_violations`` is the
    committed safety signal: snapshot reads on any host that observed a
    version reclaimed while pinned by *any* host.  It must be zero."""

    hosts: int = 0
    lwm: int = 0
    lwm_advances: int = 0
    stale_lanes_aged: int = 0
    stalled_hosts: int = 0
    under_pressure_hosts: int = 0
    pin_violations: int = 0


@dataclass
class ForkMeasurement(ServeMeasurement):
    """One ``BENCH_fork.json`` cell: a fork-DAG serving run (DESIGN.md §14).

    Extends the serve row — space/pressure fields keep their serve meaning;
    the inherited ``forks`` field (dormant in serve rows) carries the real
    engine fork count here — with the COW-vs-eager and checkpoint-coupling
    evidence in ``units["fork_bench"]``.  Every cell embeds its own
    controls: ``eager_peak_pages`` is the same workload re-run with eager
    page copying (``shared_savings_pages`` is what COW saved), and
    ``control_ckpt_pages_freed`` / ``control_end_pages`` are the same
    workload re-run with no checkpoint (the reclaims checkpoint coupling
    enabled are exactly the ones that control cannot make)."""

    joins: int = 0
    releases: int = 0
    pages_shared_peak: int = 0
    eager_peak_pages: int = 0
    shared_savings_pages: int = 0
    prefix_checks: int = 0
    prefix_violations: int = 0
    ckpt_saves: int = 0
    ckpt_evictions: int = 0
    ckpt_pages_freed: int = 0
    control_ckpt_pages_freed: int = 0
    control_end_pages: int = 0


@dataclass
class KernelMeasurement(Measurement):
    """One ``BENCH_kernel.json`` cell: a fused Pallas primitive timed on one
    shape against the unfused lax baseline, with its roofline-derived
    bandwidth target (``units["kernel_bench"]``, DESIGN.md §12).

    Base-field mapping: ``scheme`` is the kernel name, ``ds`` is ``slab``,
    ``mix`` is the tier, ``figure`` is ``<kernel>/<tier>``; throughput/space
    base fields are zero (kernels have no workload counters)."""

    kernel: str = ""              # compact | search_gather
    shape: str = ""               # human-readable dim string, e.g. S4096xV16xP256
    backend: str = "cpu"          # jax backend the timings were taken on
    path: str = "ref_fused"       # ref_fused (CPU single-jit) | pallas (TPU)
    bytes_moved: int = 0          # analytic traffic model for one launch
    iters: int = 0                # timing iterations (best-of)
    us_fused: float = 0.0         # fused single-dispatch time, microseconds
    us_unfused: float = 0.0       # unfused two-dispatch lax baseline
    speedup: float = 0.0          # us_unfused / us_fused
    gb_s: float = 0.0             # bytes_moved / us_fused
    peak_bw_gb_s: float = 0.0     # roofline bandwidth peak for `backend`
    bw_frac: float = 0.0          # gb_s / peak_bw_gb_s (achieved fraction)
    target_frac: float = 0.0      # stated fraction of peak the kernel targets
    target_gb_s: float = 0.0      # target_frac * peak_bw_gb_s
    kernel_validated: bool = False  # Pallas interpret parity checked this run


# ---------------------------------------------------------------------------
# Schema registry: the bench-measurement API
# ---------------------------------------------------------------------------
# Every BENCH payload declares one of these; the tools (check_bench_json,
# compare_bench, plot_bench) dispatch on it.  Registering a new bench row
# type here is the whole integration — zero tool changes.
Invariant = Callable[[List[Dict[str, Any]], Dict[str, Any]], List[str]]


@dataclass(frozen=True)
class BenchSchema:
    """One registered row schema.

    * ``row_type`` — the Measurement subclass whose rows the payload carries;
    * ``key_fields`` — row identity for trajectory cell matching
      (``compare_bench`` pairs committed/fresh rows on these);
    * ``compare_fields`` — the value cells diffed within tolerance on each
      matched pair (only deterministic fields belong here);
    * ``required_row_fields`` — row keys required beyond the base contract;
    * ``invariants`` — callables ``(rows, options) -> [problems]`` run by
      ``check_bench_json`` (options carries tool strictness knobs, e.g.
      ``min_txn_sizes``, ``require_pressure``);
    * ``panel`` — the plot_bench panel family for this schema.
    """

    name: str
    row_type: type
    key_fields: Tuple[str, ...]
    compare_fields: Tuple[str, ...]
    required_row_fields: Tuple[str, ...] = ()
    invariants: Tuple[Invariant, ...] = ()
    panel: str = "sim"


_SCHEMA_REGISTRY: Dict[str, BenchSchema] = {}
# legacy payloads (no top-level row_schema key) are inferred from bench name
_BENCH_TO_SCHEMA: Dict[str, str] = {}


def register_bench_schema(schema: BenchSchema,
                          benches: Sequence[str] = ()) -> BenchSchema:
    """Register a row schema (and the bench names that default to it)."""
    _SCHEMA_REGISTRY[schema.name] = schema
    for b in benches:
        _BENCH_TO_SCHEMA[b] = schema.name
    return schema


def get_bench_schema(name: str) -> BenchSchema:
    """Look up a registered row schema by name; raises KeyError with the
    registered names on a miss (tools fail fast on unknown payloads)."""
    if name not in _SCHEMA_REGISTRY:
        raise KeyError(
            f"unknown bench schema {name!r} (have {sorted(_SCHEMA_REGISTRY)})")
    return _SCHEMA_REGISTRY[name]


def schema_of_payload(payload: Dict[str, Any]) -> BenchSchema:
    """Resolve a payload's declared schema, inferring legacy payloads from
    their bench name (committed files predate the ``row_schema`` key)."""
    name = payload.get("row_schema")
    if name is None:
        name = _BENCH_TO_SCHEMA.get(payload.get("bench", ""), "sim")
    return get_bench_schema(name)


# -- registered row invariants ----------------------------------------------
TXN_FIELDS = ("txn_size", "rw_ratio", "txns_committed", "txns_aborted",
              "abort_rate", "txn_ranges", "point_reads", "aborts_footprint",
              "aborts_wcc", "aborts_capacity", "txn_giveups",
              "backoff_slices", "reclaims_triggered",
              "versions_reclaimed_on_abort", "reclaim_latency_slices",
              "peak_space_post_reclaim")

RECLAIM_FIELDS = ("reclaims_triggered", "versions_reclaimed_on_abort",
                  "reclaim_latency_slices", "peak_space_post_reclaim")

SERVE_FIELDS = ("pressure_events", "pages_reclaimed", "peak_pages",
                "peak_pages_post_reclaim", "page_pool", "page_size",
                "decode_steps", "tokens_appended", "sequences_completed",
                "give_ups", "snapshot_pins", "overflow_count",
                "dropped_retires", "reclaims_triggered")

DIST_FIELDS = SERVE_FIELDS + ("hosts", "lwm", "lwm_advances",
                              "stale_lanes_aged", "stalled_hosts",
                              "under_pressure_hosts", "pin_violations")

FORK_FIELDS = SERVE_FIELDS + (
    "forks", "joins", "releases", "pages_shared_peak", "eager_peak_pages",
    "shared_savings_pages", "prefix_checks", "prefix_violations",
    "ckpt_saves", "ckpt_evictions", "ckpt_pages_freed",
    "control_ckpt_pages_freed", "control_end_pages")

KERNEL_FIELDS = ("kernel", "shape", "backend", "path", "bytes_moved",
                 "iters", "us_fused", "us_unfused", "speedup", "gb_s",
                 "peak_bw_gb_s", "bw_frac", "target_frac", "target_gb_s",
                 "kernel_validated")


def check_txn_rows(rows: List[Dict[str, Any]],
                   options: Dict[str, Any]) -> List[str]:
    """txn-schema invariants (DESIGN.md §8-§10): rate/counter consistency,
    the abort-reason taxonomy partitioning the aborts, and the abort =>
    reclaim => retry accounting.  ``options["min_txn_sizes"]`` (default 1)
    sets the minimum distinct write-set sizes with committed txns."""
    min_txn_sizes = int(options.get("min_txn_sizes", 1))
    problems = []
    txn_rows = []
    for i, r in enumerate(rows):
        missing = [k for k in TXN_FIELDS if k not in r]
        if missing:
            problems.append(f"row {i} missing txn fields: {missing}")
            continue
        for f in ("rw_ratio", "abort_rate"):
            if not (0.0 <= r[f] <= 1.0):
                problems.append(f"row {i}: {f}={r[f]} outside [0, 1]")
        attempts = r["txns_committed"] + r["txns_aborted"]
        if attempts:
            txn_rows.append(r)
            if r["txn_size"] < 1:
                problems.append(f"row {i}: txns ran but txn_size="
                                f"{r['txn_size']} < 1")
            if r["txn_ranges"] < 1:
                problems.append(f"row {i}: txns ran but txn_ranges="
                                f"{r['txn_ranges']} < 1")
            if r["rw_ratio"] <= 0.0:
                problems.append(f"row {i}: txns ran but rw_ratio="
                                f"{r['rw_ratio']} <= 0")
            want = round(r["txns_aborted"] / attempts, 4)
            if abs(r["abort_rate"] - want) > 1e-4:
                problems.append(f"row {i}: abort_rate {r['abort_rate']} != "
                                f"aborted/attempts {want}")
            reasons = (r["aborts_footprint"] + r["aborts_wcc"]
                       + r["aborts_capacity"])
            if reasons != r["txns_aborted"]:
                problems.append(
                    f"row {i}: abort reasons sum to {reasons} but "
                    f"txns_aborted={r['txns_aborted']} (taxonomy must "
                    f"partition the aborts)")
        # schema v4: abort => reclaim => retry fields (DESIGN.md §10)
        for f in RECLAIM_FIELDS:
            if r[f] < 0:
                problems.append(f"row {i}: {f}={r[f]} < 0")
        if r["reclaims_triggered"] > r["aborts_capacity"]:
            problems.append(
                f"row {i}: reclaims_triggered={r['reclaims_triggered']} > "
                f"aborts_capacity={r['aborts_capacity']} (only capacity "
                f"aborts trigger reclaims)")
        if r["reclaim_latency_slices"] < r["reclaims_triggered"]:
            problems.append(
                f"row {i}: reclaim_latency_slices="
                f"{r['reclaim_latency_slices']} < reclaims_triggered="
                f"{r['reclaims_triggered']} (every reclaim pass stalls "
                f"at least one slice)")
        if r["reclaims_triggered"] == 0 and (
                r["versions_reclaimed_on_abort"] or
                r["peak_space_post_reclaim"]):
            problems.append(
                f"row {i}: reclaim outputs nonzero "
                f"(versions={r['versions_reclaimed_on_abort']}, "
                f"peak_post={r['peak_space_post_reclaim']}) with "
                f"reclaims_triggered=0")
    if not txn_rows:
        problems.append("txn schema: no row has any committed or aborted "
                        "txns")
    sizes = {r["txn_size"] for r in txn_rows}
    if len(sizes) < min_txn_sizes:
        problems.append(f"only {len(sizes)} distinct txn sizes "
                        f"({sorted(sizes)}), need >= {min_txn_sizes}")
    return problems


def check_serve_rows(rows: List[Dict[str, Any]],
                     options: Dict[str, Any]) -> List[str]:
    """serve-schema invariants (DESIGN.md §11): every reclaim pass was driven
    by a pressure event, the post-reclaim peak never exceeds the overall
    peak, and a cell that never reclaimed reports zero reclaim output.  With
    ``options["require_pressure"]``, the tier with the most reclaims must
    show the pressure loop actually working — reclaims > 0, pages freed > 0,
    post-reclaim peak < peak — in a majority of its policy cells."""
    require_pressure = bool(options.get("require_pressure", False))
    problems = []
    for i, r in enumerate(rows):
        missing = [k for k in SERVE_FIELDS if k not in r]
        if missing:
            problems.append(f"row {i} missing serve fields: {missing}")
            continue
        for f in SERVE_FIELDS:
            if r[f] < 0:
                problems.append(f"row {i}: {f}={r[f]} < 0")
        if r["reclaims_triggered"] > r["pressure_events"]:
            problems.append(
                f"row {i}: reclaims_triggered={r['reclaims_triggered']} > "
                f"pressure_events={r['pressure_events']} (every reclaim "
                f"pass must be driven by a pressure event — the LWM rule)")
        if r["peak_pages_post_reclaim"] > r["peak_pages"]:
            problems.append(
                f"row {i}: peak_pages_post_reclaim="
                f"{r['peak_pages_post_reclaim']} > peak_pages="
                f"{r['peak_pages']}")
        if r["peak_pages"] > r["page_pool"]:
            problems.append(f"row {i}: peak_pages={r['peak_pages']} > "
                            f"page_pool={r['page_pool']}")
        if r["reclaims_triggered"] == 0 and (
                r["pages_reclaimed"] or r["peak_pages_post_reclaim"]):
            problems.append(
                f"row {i}: reclaim outputs nonzero (pages="
                f"{r['pages_reclaimed']}, peak_post="
                f"{r['peak_pages_post_reclaim']}) with reclaims_triggered=0")
        if r["peak_space_words"] != r["peak_pages"]:
            problems.append(
                f"row {i}: peak_space_words={r['peak_space_words']} != "
                f"peak_pages={r['peak_pages']} (serve rows measure space "
                f"in pages)")
    if require_pressure and not problems:
        serve_rows = [r for r in rows if "pressure_events" in r]
        by_fig: Dict[str, List[Dict[str, Any]]] = {}
        for r in serve_rows:
            by_fig.setdefault(r.get("figure"), []).append(r)
        fig, cells = max(
            by_fig.items(),
            key=lambda kv: sum(c["reclaims_triggered"] for c in kv[1]))
        good = [c for c in cells
                if c["reclaims_triggered"] > 0 and c["pages_reclaimed"] > 0
                and c["peak_pages_post_reclaim"] < c["peak_pages"]]
        if len(good) * 2 <= len(cells):
            problems.append(
                f"require_pressure: only {len(good)}/{len(cells)} cells "
                f"of {fig} show working pressure reclamation (need a "
                f"majority with reclaims > 0, pages freed > 0, "
                f"post-reclaim peak < peak)")
    return problems


def check_dist_rows(rows: List[Dict[str, Any]],
                    options: Dict[str, Any]) -> List[str]:
    """dist-schema invariants (DESIGN.md §13), layered on top of the serve
    per-row checks: the global-LWM safety signal is clean
    (``pin_violations == 0`` on every row), staleness aging fires exactly
    when a host is stalled, and the per-host counters stay inside the mesh.

    ``options["require_pressure"]`` swaps in a dist-appropriate working-
    pressure proof instead of serve's: the most-reclaiming tier must show
    reclaims > 0, pages freed > 0 and the LWM actually advancing in a
    majority of its cells (serve's strict post-reclaim-peak < peak does not
    hold under a stalled host, whose live pages are legitimately
    unreclaimable at peak), and at least one cell must exercise the
    straggler path (``stalled_hosts > 0``) so the committed payload proves
    aged-out reclamation, not just the happy path."""
    require_pressure = bool(options.get("require_pressure", False))
    problems = check_serve_rows(rows, {**options, "require_pressure": False})
    any_stall = False
    for i, r in enumerate(rows):
        missing = [k for k in DIST_FIELDS if k not in r]
        if missing:
            problems.append(f"row {i} missing dist fields: {missing}")
            continue
        if r["hosts"] < 1:
            problems.append(f"row {i}: hosts={r['hosts']} < 1")
            continue
        for f in ("lwm_advances", "stale_lanes_aged", "stalled_hosts",
                  "under_pressure_hosts", "pin_violations"):
            if r[f] < 0:
                problems.append(f"row {i}: {f}={r[f]} < 0")
        if r["pin_violations"] != 0:
            problems.append(
                f"row {i} ({r['figure']}): pin_violations="
                f"{r['pin_violations']} != 0 — a shard reclaimed a version "
                f"pinned by some host (global-LWM safety broken)")
        for f in ("stalled_hosts", "under_pressure_hosts"):
            if r[f] > r["hosts"]:
                problems.append(f"row {i}: {f}={r[f]} > hosts={r['hosts']}")
        if r["stalled_hosts"] > 0:
            any_stall = True
            if r["stale_lanes_aged"] == 0:
                problems.append(
                    f"row {i} ({r['figure']}): stalled_hosts="
                    f"{r['stalled_hosts']} but stale_lanes_aged=0 — a host "
                    f"past its watchdog budget must be aged out of the LWM")
        elif r["stale_lanes_aged"] != 0:
            problems.append(
                f"row {i} ({r['figure']}): stale_lanes_aged="
                f"{r['stale_lanes_aged']} with stalled_hosts=0 — aging "
                f"fired without a stalled host")
    if require_pressure and not problems:
        if not any_stall:
            problems.append(
                "require_pressure: no dist row exercises the straggler path "
                "(need at least one cell with stalled_hosts > 0 proving "
                "reclamation proceeds with the stale lane aged out)")
        by_fig: Dict[str, List[Dict[str, Any]]] = {}
        for r in rows:
            by_fig.setdefault(r.get("figure"), []).append(r)
        fig, cells = max(
            by_fig.items(),
            key=lambda kv: sum(c["reclaims_triggered"] for c in kv[1]))
        good = [c for c in cells
                if c["reclaims_triggered"] > 0 and c["pages_reclaimed"] > 0
                and c["lwm_advances"] > 0]
        if len(good) * 2 <= len(cells):
            problems.append(
                f"require_pressure: only {len(good)}/{len(cells)} cells of "
                f"{fig} show working global-LWM reclamation (need a "
                f"majority with reclaims > 0, pages freed > 0, "
                f"lwm_advances > 0)")
    return problems


def check_fork_rows(rows: List[Dict[str, Any]],
                    options: Dict[str, Any]) -> List[str]:
    """fork-schema invariants (DESIGN.md §14), layered on the serve per-row
    checks.  Hard per-row rules: the replay validator is clean
    (``prefix_violations == 0``), sharing stays inside the live set
    (``pages_shared_peak <= peak_pages``), lineage ops are consistent
    (``forks >= joins``; a fork-free cell reports zero sharing, joins,
    releases and savings), every forking cell with a measured eager control
    shows a **strict** COW saving (``eager_peak_pages > peak_pages``), and
    checkpoint accounting only appears when a checkpoint was taken — with
    the no-checkpoint control proving the converse (``control_ckpt_pages_
    freed == 0`` always; a cell with ckpt-freed pages must also show
    ``control_end_pages > end_space_words``, the pages the control could
    not free).  With ``options["require_pressure"]``, the most-reclaiming
    tier must show working reclamation in a majority of its cells and at
    least one row must prove the checkpoint edge (``ckpt_pages_freed >
    0``)."""
    require_pressure = bool(options.get("require_pressure", False))
    problems = check_serve_rows(rows, {**options, "require_pressure": False})
    for i, r in enumerate(rows):
        missing = [k for k in FORK_FIELDS if k not in r]
        if missing:
            problems.append(f"row {i} missing fork fields: {missing}")
            continue
        for f in ("forks", "joins", "releases", "pages_shared_peak",
                  "eager_peak_pages", "shared_savings_pages",
                  "prefix_checks", "prefix_violations", "ckpt_saves",
                  "ckpt_evictions", "ckpt_pages_freed",
                  "control_ckpt_pages_freed", "control_end_pages"):
            if r[f] < 0:
                problems.append(f"row {i}: {f}={r[f]} < 0")
        if r["prefix_violations"] != 0:
            problems.append(
                f"row {i} ({r['figure']}): prefix_violations="
                f"{r['prefix_violations']} != 0 — a fork child's inherited "
                f"prefix changed under it (shared-page safety broken)")
        if r["pages_shared_peak"] > r["peak_pages"]:
            problems.append(
                f"row {i}: pages_shared_peak={r['pages_shared_peak']} > "
                f"peak_pages={r['peak_pages']}")
        if r["forks"] < r["joins"]:
            problems.append(
                f"row {i}: forks={r['forks']} < joins={r['joins']} (every "
                f"join consumes a forked child)")
        if r["forks"] == 0:
            for f in ("joins", "releases", "pages_shared_peak",
                      "shared_savings_pages"):
                if r[f]:
                    problems.append(
                        f"row {i}: {f}={r[f]} nonzero with forks=0 "
                        f"(zero-fork consistency)")
        elif r["eager_peak_pages"]:
            if r["eager_peak_pages"] <= r["peak_pages"]:
                problems.append(
                    f"row {i} ({r['figure']}): eager_peak_pages="
                    f"{r['eager_peak_pages']} <= peak_pages="
                    f"{r['peak_pages']} — COW forking must strictly beat "
                    f"the eager-copy control")
            want = r["eager_peak_pages"] - r["peak_pages"]
            if r["shared_savings_pages"] != want:
                problems.append(
                    f"row {i}: shared_savings_pages="
                    f"{r['shared_savings_pages']} != eager_peak - peak "
                    f"= {want}")
        if r["control_ckpt_pages_freed"] != 0:
            problems.append(
                f"row {i}: control_ckpt_pages_freed="
                f"{r['control_ckpt_pages_freed']} != 0 — the no-checkpoint "
                f"control made a checkpoint-coupled reclaim")
        if r["ckpt_saves"] == 0 and (r["ckpt_evictions"]
                                     or r["ckpt_pages_freed"]):
            problems.append(
                f"row {i}: checkpoint eviction outputs nonzero (evictions="
                f"{r['ckpt_evictions']}, pages={r['ckpt_pages_freed']}) "
                f"with ckpt_saves=0")
        if r["ckpt_pages_freed"] > 0 and (
                r["control_end_pages"] <= r["end_space_words"]):
            problems.append(
                f"row {i} ({r['figure']}): ckpt_pages_freed="
                f"{r['ckpt_pages_freed']} but control_end_pages="
                f"{r['control_end_pages']} <= end pages="
                f"{r['end_space_words']} — the no-checkpoint control "
                f"should be stuck holding the pages eviction freed")
    if require_pressure and not problems:
        by_fig: Dict[str, List[Dict[str, Any]]] = {}
        for r in rows:
            by_fig.setdefault(r.get("figure"), []).append(r)
        fig, cells = max(
            by_fig.items(),
            key=lambda kv: sum(c["reclaims_triggered"] for c in kv[1]))
        good = [c for c in cells
                if c["reclaims_triggered"] > 0 and c["pages_reclaimed"] > 0]
        if len(good) * 2 <= len(cells):
            problems.append(
                f"require_pressure: only {len(good)}/{len(cells)} cells of "
                f"{fig} show working pressure reclamation (need a majority "
                f"with reclaims > 0 and pages freed > 0)")
        if not any(r["ckpt_pages_freed"] > 0 for r in rows):
            problems.append(
                "require_pressure: no fork row proves the checkpoint "
                "reclamation edge (need at least one cell with "
                "ckpt_pages_freed > 0 that its no-checkpoint control "
                "cannot match)")
    return problems


def check_kernel_rows(rows: List[Dict[str, Any]],
                      options: Dict[str, Any]) -> List[str]:
    """kernel-schema invariants (DESIGN.md §12): the traffic model and the
    roofline target are populated and self-consistent on every row, and the
    fused path beats the unfused lax baseline on every standard/full-tier
    shape (``options["min_speedup"]``, default 1.0; smoke rows are exempt —
    they are re-timed per-PR on noisy CI runners)."""
    min_speedup = float(options.get("min_speedup", 1.0))
    problems = []
    for i, r in enumerate(rows):
        missing = [k for k in KERNEL_FIELDS if k not in r]
        if missing:
            problems.append(f"row {i} missing kernel fields: {missing}")
            continue
        for f in ("bytes_moved", "iters", "us_fused", "us_unfused",
                  "gb_s", "peak_bw_gb_s", "target_gb_s"):
            if not r[f] > 0:
                problems.append(f"row {i} ({r['figure']}): {f}={r[f]} "
                                f"must be > 0")
        if not (0.0 < r["target_frac"] <= 1.0):
            problems.append(f"row {i}: target_frac={r['target_frac']} "
                            f"outside (0, 1]")
        if r["us_fused"] > 0:
            want = r["us_unfused"] / r["us_fused"]
            if abs(r["speedup"] - want) > 0.01 * max(want, 1.0):
                problems.append(f"row {i}: speedup={r['speedup']} != "
                                f"us_unfused/us_fused={want:.3f}")
        if r["peak_bw_gb_s"] > 0:
            want = r["gb_s"] / r["peak_bw_gb_s"]
            if abs(r["bw_frac"] - want) > 0.01 * max(want, 1e-6):
                problems.append(f"row {i}: bw_frac={r['bw_frac']} != "
                                f"gb_s/peak={want:.4f}")
        if r["mix"] in ("standard", "full") and r["speedup"] < min_speedup:
            problems.append(
                f"row {i} ({r['figure']} {r['shape']}): fused path does not "
                f"beat the unfused lax baseline (speedup={r['speedup']} < "
                f"{min_speedup})")
    return problems


# ---------------------------------------------------------------------------
# Shared CLI scaffolding for the tiered bench drivers
# ---------------------------------------------------------------------------
def parse_tier_argv(argv: Sequence[str], tiers: Dict[str, Any],
                    default_tier: str = "standard"):
    """Shared ``--smoke`` / ``--full`` / ``--tiers a,b`` parsing for
    ``benchmarks/range_query.py`` and ``benchmarks/txn_mix.py``.  Returns
    ``(tier_names, None)`` or ``(None, error_message)``."""
    names = [default_tier]
    if "--smoke" in argv:
        names = ["smoke"]
    elif "--full" in argv:
        names = ["full"]
    if "--tiers" in argv:
        i = argv.index("--tiers") + 1
        if i >= len(argv):
            return None, "--tiers needs a comma-separated value"
        names = argv[i].split(",")
    unknown = [t for t in names if t not in tiers]
    if unknown:
        return None, f"unknown tier(s) {unknown} (have {list(tiers)})"
    return names, None


def parse_out_argv(argv: Sequence[str], default_out: str):
    """Shared ``--out PATH`` parsing; returns ``(path, None)`` or
    ``(None, error_message)``."""
    if "--out" in argv:
        i = argv.index("--out") + 1
        if i >= len(argv):
            return None, "--out needs a path"
        return argv[i], None
    return default_out, None


def tier_meta(tier_names: Sequence[str],
              tiers: Dict[str, Any]) -> Dict[str, Any]:
    """BENCH ``meta`` block for a (possibly concatenated) tier run."""
    meta: Dict[str, Any] = {
        "tier": tier_names[0] if len(tier_names) == 1 else "+".join(tier_names),
        "tiers": list(tier_names),
    }
    for t in tier_names:
        meta[t] = {k: list(v) if isinstance(v, tuple) else v
                   for k, v in tiers[t].items()}
    return meta


def print_rows_by_figure(rows: Sequence[Measurement],
                         cols: Sequence[str], width: int = 18) -> None:
    """Group measurement rows by figure and print fixed-width tables."""
    by_figure: Dict[str, List[Dict[str, Any]]] = {}
    for m in rows:
        by_figure.setdefault(m.figure, []).append(m.to_row())
    for figure, rs in by_figure.items():
        print(f"\n== {figure} ==")
        print("  ".join(f"{c:>{width}s}" for c in cols))
        for r in rs:
            print("  ".join(f"{str(r[c]):>{width}s}" for c in cols))


# ---------------------------------------------------------------------------
# BENCH_*.json serialization
# ---------------------------------------------------------------------------
def bench_payload(bench: str, measurements: Sequence[Measurement],
                  meta: Optional[Dict[str, Any]] = None,
                  schema: Optional[str] = None) -> Dict[str, Any]:
    """Assemble the BENCH json payload dict (see the module docstring).
    ``schema`` declares the row schema; omitted, it is resolved from the
    bench name (every registered bench has a default)."""
    schema_name = schema or _BENCH_TO_SCHEMA.get(bench, "sim")
    get_bench_schema(schema_name)  # fail fast on unregistered schemas
    return {
        "bench": bench,
        "schema_version": SCHEMA_VERSION,
        "row_schema": schema_name,
        "units": dict(UNITS),
        "meta": dict(meta or {}),
        "rows": [m.to_row() for m in measurements],
    }


def write_bench_json(path: str, bench: str,
                     measurements: Sequence[Measurement],
                     meta: Optional[Dict[str, Any]] = None,
                     schema: Optional[str] = None) -> Dict[str, Any]:
    """Serialize measurements to ``path`` in the BENCH schema; returns the
    payload dict (also used by in-process tests)."""
    payload = bench_payload(bench, measurements, meta, schema=schema)
    parent = os.path.dirname(os.path.abspath(path))
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    return payload


def validate_bench_payload(payload: Dict[str, Any]) -> List[str]:
    """Return a list of schema problems (empty = valid).  Shared by
    ``tools/check_bench_json.py`` and the unit tests.  Checks the base row
    contract plus the declared (or inferred) schema's required row fields;
    row *invariants* are the tools' job (``BenchSchema.invariants``)."""
    problems = []
    for k in REQUIRED_TOP_KEYS:
        if k not in payload:
            problems.append(f"missing top-level key: {k}")
    declared = payload.get("row_schema")
    if declared is not None and declared not in _SCHEMA_REGISTRY:
        problems.append(f"unknown row_schema: {declared!r} "
                        f"(registered: {sorted(_SCHEMA_REGISTRY)})")
        declared = None
    schema = (get_bench_schema(declared) if declared is not None
              else schema_of_payload(payload))
    rows = payload.get("rows", [])
    if not rows:
        problems.append("rows is empty")
    for i, row in enumerate(rows):
        missing = [k for k in REQUIRED_ROW_KEYS if k not in row]
        if missing:
            problems.append(f"row {i} missing keys: {missing}")
        extra = [k for k in schema.required_row_fields if k not in row]
        if extra:
            problems.append(f"row {i} missing {schema.name}-schema keys: "
                            f"{extra}")
    return problems


# ---------------------------------------------------------------------------
# BenchDriver: the one CLI entrypoint shared by benchmarks/*.py
# ---------------------------------------------------------------------------
class BenchDriver:
    """Uniform tiered-driver scaffolding: tier selection (``--smoke`` /
    ``--full`` / ``--tiers a,b``), ``--out PATH``, tier meta, per-figure row
    tables, and serialization through :func:`write_bench_json` with the
    declared row schema.  ``run.py`` and the CI bench steps invoke every
    driver through this one interface::

        DRIVER = BenchDriver(bench="txn_mix", schema="txn", tiers=TIERS,
                             run_tier=run_tier, default_out="BENCH_txn_mix.json",
                             table_cols=[...])
        if __name__ == "__main__":
            raise SystemExit(DRIVER.main(sys.argv[1:]))

    ``run_tier(name)`` returns the tier's measurement rows; everything else
    (parsing, printing, meta, writing) is shared here instead of copy-pasted
    per driver.  ``summarize(rows)`` may return an extra summary line printed
    after the tables; ``post_check(rows)`` returns failure strings that make
    the driver exit 1 (e.g. snapshot-consistency violations)."""

    def __init__(self, bench: str, tiers: Dict[str, Any],
                 run_tier: Callable[[str], List[Measurement]],
                 default_out: str, table_cols: Sequence[str],
                 schema: Optional[str] = None,
                 default_tier: str = "standard", col_width: int = 18,
                 meta_extra: Optional[Dict[str, Any]] = None,
                 summarize: Optional[Callable[[List[Measurement]],
                                              Optional[str]]] = None,
                 post_check: Optional[Callable[[List[Measurement]],
                                               List[str]]] = None):
        self.bench = bench
        self.tiers = tiers
        self.run_tier = run_tier
        self.default_out = default_out
        self.table_cols = list(table_cols)
        self.schema = schema or _BENCH_TO_SCHEMA.get(bench, "sim")
        self.default_tier = default_tier
        self.col_width = col_width
        self.meta_extra = dict(meta_extra or {})
        self.summarize = summarize
        self.post_check = post_check

    def run(self, tier_names: Sequence[str]) -> List[Measurement]:
        """Run the named tiers in order and return the concatenated rows
        (the in-process entry point — ``benchmarks/run.py`` uses this)."""
        rows: List[Measurement] = []
        for t in tier_names:
            rows.extend(self.run_tier(t))
        return rows

    def main(self, argv: Optional[Sequence[str]] = None) -> int:
        """CLI entry point: parse ``--smoke``/``--full``/``--tiers``/
        ``--out``, run the tiers, print per-figure tables + the summary
        line, write the BENCH json, and return the exit code (nonzero when
        ``post_check`` reports problems)."""
        argv = list(sys.argv[1:] if argv is None else argv)
        names, err = parse_tier_argv(argv, self.tiers, self.default_tier)
        if err:
            print(err)
            return 2
        out, err = parse_out_argv(argv, self.default_out)
        if err:
            print(err)
            return 2
        rows = self.run(names)
        print_rows_by_figure(rows, self.table_cols, self.col_width)
        meta = tier_meta(names, self.tiers)
        meta.update(self.meta_extra)
        payload = write_bench_json(out, self.bench, rows, meta,
                                   schema=self.schema)
        problems = validate_bench_payload(payload)
        extra = self.summarize(rows) if self.summarize else None
        print(f"\nwrote {out} ({len(rows)} rows, schema {self.schema}"
              + (f"; {extra}" if extra else "") + ")")
        if self.post_check:
            problems = problems + list(self.post_check(rows))
        if problems:
            for p in problems:
                print(f"  FAIL: {p}", file=sys.stderr)
            return 1
        return 0


# ---------------------------------------------------------------------------
# The built-in schemas (one per committed BENCH file)
# ---------------------------------------------------------------------------
SIM_KEY_FIELDS = ("figure", "ds", "scheme", "mix", "scan_size", "txn_size",
                  "txn_ranges", "zipf", "n_keys", "num_procs",
                  "ops_per_proc", "seed")
SPACE_COMPARE_FIELDS = ("peak_space_words", "end_space_words")

register_bench_schema(BenchSchema(
    name="sim",
    row_type=Measurement,
    key_fields=SIM_KEY_FIELDS,
    compare_fields=SPACE_COMPARE_FIELDS,
    panel="sim",
), benches=("range_query", "gc_comparison"))

register_bench_schema(BenchSchema(
    name="txn",
    row_type=Measurement,
    key_fields=SIM_KEY_FIELDS,
    compare_fields=SPACE_COMPARE_FIELDS,
    required_row_fields=TXN_FIELDS,
    invariants=(check_txn_rows,),
    panel="sim",
), benches=("txn_mix",))

register_bench_schema(BenchSchema(
    name="serve",
    row_type=ServeMeasurement,
    key_fields=SIM_KEY_FIELDS,
    compare_fields=SPACE_COMPARE_FIELDS + (
        "peak_pages", "peak_pages_post_reclaim", "pages_reclaimed"),
    required_row_fields=SERVE_FIELDS,
    invariants=(check_serve_rows,),
    panel="serve",
), benches=("serve",))

register_bench_schema(BenchSchema(
    name="dist",
    row_type=DistMeasurement,
    key_fields=SIM_KEY_FIELDS,
    compare_fields=SPACE_COMPARE_FIELDS + (
        "peak_pages", "peak_pages_post_reclaim", "pages_reclaimed",
        "stale_lanes_aged", "pin_violations"),
    # check_dist_rows runs the serve per-row checks itself (with serve's
    # require_pressure majority rule swapped for the dist one)
    required_row_fields=DIST_FIELDS,
    invariants=(check_dist_rows,),
    panel="serve",
), benches=("dist",))

register_bench_schema(BenchSchema(
    name="fork",
    row_type=ForkMeasurement,
    key_fields=SIM_KEY_FIELDS,
    compare_fields=SPACE_COMPARE_FIELDS + (
        "peak_pages", "peak_pages_post_reclaim", "pages_reclaimed",
        "forks", "joins", "releases", "pages_shared_peak",
        "eager_peak_pages", "shared_savings_pages", "prefix_checks",
        "prefix_violations", "ckpt_saves", "ckpt_pages_freed",
        "control_ckpt_pages_freed", "control_end_pages"),
    # check_fork_rows runs the serve per-row checks itself (with serve's
    # require_pressure majority rule swapped for the fork one)
    required_row_fields=FORK_FIELDS,
    invariants=(check_fork_rows,),
    panel="serve",
), benches=("fork",))

register_bench_schema(BenchSchema(
    name="kernel",
    row_type=KernelMeasurement,
    key_fields=("figure", "ds", "scheme", "mix", "kernel", "shape", "seed"),
    # only the deterministic cells are trajectory-gated; timings re-measured
    # on CI runners would flake any cell-for-cell comparison
    compare_fields=("bytes_moved", "target_gb_s", "target_frac"),
    required_row_fields=KERNEL_FIELDS,
    invariants=(check_kernel_rows,),
    panel="kernel",
), benches=("kernel",))
