"""Shared operation-mix and measurement plumbing for the sim benchmarks.

All three benchmark drivers (``benchmarks/gc_comparison.py`` — the paper's
Figures 4-8 —, ``benchmarks/range_query.py`` — the EEMARQ-style range-scan
family, DESIGN.md §7 — and ``benchmarks/txn_mix.py`` — the read-write
update-in-scan txn family, DESIGN.md §8) build their workloads from
:class:`OpMix` and serialize their results through :class:`Measurement` /
:func:`write_bench_json`, so the trajectories stay apples-to-apples: same
space units (Java-reachability words, DESIGN.md §5), same throughput proxy
(completed operations per million simulated work units), same JSON schema
(which ``tools/compare_bench.py`` — the CI bench-trajectory gate — diffs
against the committed repo-root files).

``BENCH_*.json`` schema (``SCHEMA_VERSION`` = 4).  Field-by-field changelog:

* **v2** added the read-write transaction row fields ``txn_size`` /
  ``rw_ratio`` / ``txns_committed`` / ``txns_aborted`` / ``abort_rate``
  (DESIGN.md §8);
* **v3** added the MV-RLU-style multi-interval/contention fields
  ``txn_ranges`` / ``point_reads`` / ``aborts_footprint`` / ``aborts_wcc`` /
  ``aborts_capacity`` / ``txn_giveups`` / ``backoff_slices`` (DESIGN.md §9);
* **v4** added the abort ⇒ reclaim ⇒ retry fields (DESIGN.md §10):
  ``reclaims_triggered`` (synchronous reclaim passes driven by capacity
  aborts; always ≤ ``aborts_capacity``), ``versions_reclaimed_on_abort``
  (versions those passes spliced out of reachability — each refunds one
  budget token), ``reclaim_latency_slices`` (scheduler slices aborting
  processes stalled paying for their reclaims), and
  ``peak_space_post_reclaim`` (max space in words sampled immediately
  *after* a reclaim pass — the bounded-space signal: how high space stays
  even right after reclamation has run)::

    {
      "bench": "<driver name>",
      "schema_version": 4,
      "units": {...},                 # human-readable unit strings
      "meta": {...},                  # driver-specific run parameters
      "rows": [<Measurement dict>, ...]
    }

Every row carries the keys in ``REQUIRED_ROW_KEYS``; ``tools/
check_bench_json.py`` (run by the CI ``bench-smoke`` step) enforces this.
"""
from __future__ import annotations

import json
import os
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional, Sequence

SCHEMA_VERSION = 4

UNITS = {
    "space": "words, Java-style reachability from the structure roots "
             "(version nodes at the scheme's per-node cost + payloads + GC "
             "metadata; DESIGN.md §5)",
    "throughput": "completed operations per 1e6 simulated work units "
                  "(work unit = one shared-memory access of the lock-free "
                  "algorithm; DESIGN.md §5)",
    "scan_size": "keys per range scan (half-open key interval [lo, lo+s))",
    "txn_size": "buffered writes per read-write transaction (DESIGN.md §8)",
    "abort_rate": "aborted commit attempts / all commit attempts, in [0, 1]",
    "rw_ratio": "read-write transactions / all transactions (scan-only rtxs "
                "+ read-write txns), in [0, 1]",
    "txn_ranges": "disjoint scan intervals per read-write transaction "
                  "(multi-interval footprint, DESIGN.md §9)",
    "point_reads": "tracked version-wise point reads per read-write "
                   "transaction (revalidated at commit, DESIGN.md §9)",
    "abort_reasons": "aborts_footprint / aborts_wcc / aborts_capacity "
                     "partition txns_aborted by cause: full-footprint "
                     "validation failure / eager write-commit (first-"
                     "updater-wins) conflict / version-budget exhaustion "
                     "(DESIGN.md §9)",
    "backoff_slices": "scheduler slices spent in contention-manager backoff "
                      "between txn retries (bounded exponential)",
    "reclaims": "reclaims_triggered counts synchronous reclaim passes "
                "driven by capacity aborts (abort => reclaim => retry, "
                "DESIGN.md §10; <= aborts_capacity); "
                "versions_reclaimed_on_abort counts versions those passes "
                "spliced out of reachability (each refunds one version-"
                "budget token); reclaim_latency_slices counts scheduler "
                "slices aborting processes stalled paying for them",
    "peak_space_post_reclaim": "max space (words) sampled immediately after "
                               "a reclaim pass — the bounded-space signal "
                               "(0 when no reclaim ever ran)",
    "pages": "KV-cache pages in the paged pool (BENCH_serve rows measure "
             "space in pages: peak_space_words/end_space_words are "
             "peak/end live-page counts; DESIGN.md §11)",
    "serve_pressure": "pressure_events counts triggers (a failed append or "
                      "a post-step watermark crossing); reclaims_triggered "
                      "counts the synchronous reclaim passes they drove "
                      "(<= pressure_events); pages_reclaimed counts pages "
                      "returned to the free bitmap by those passes; "
                      "peak_pages_post_reclaim is the max live-page count "
                      "sampled immediately after a reclaim pass (0 when no "
                      "reclaim ever ran; DESIGN.md §11)",
}

REQUIRED_TOP_KEYS = ("bench", "schema_version", "units", "meta", "rows")

REQUIRED_ROW_KEYS = (
    "bench", "figure", "ds", "scheme", "mix", "scan_size", "zipf",
    "n_keys", "num_procs", "ops_per_proc", "seed",
    "updates", "lookups", "scans", "scan_keys", "total_work",
    "ops_per_mwork", "updates_per_mwork", "scan_keys_per_mwork",
    "peak_space_words", "peak_versions", "avg_space_words",
    "end_space_words", "end_versions_per_list",
    "scans_validated", "scan_violations", "wall_s",
    # read-write transactions (schema v2, DESIGN.md §8)
    "txn_size", "rw_ratio", "txns_committed", "txns_aborted", "abort_rate",
    # multi-interval footprints + contention (schema v3, DESIGN.md §9)
    "txn_ranges", "point_reads", "aborts_footprint", "aborts_wcc",
    "aborts_capacity", "txn_giveups", "backoff_slices",
    # abort => reclaim => retry (schema v4, DESIGN.md §10)
    "reclaims_triggered", "versions_reclaimed_on_abort",
    "reclaim_latency_slices", "peak_space_post_reclaim",
)


# ---------------------------------------------------------------------------
# Operation mix
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class OpMix:
    """A mixed workload's operation distribution.

    Fractions are per-operation probabilities (update / point lookup / range
    scan / read-write transaction) and must sum to 1.  ``scan_size`` is the
    number of keys each range scan covers — read-write transactions scan
    ``txn_ranges`` *disjoint* intervals of that size (a multi-interval
    footprint), perform ``txn_point_reads`` tracked version-wise point
    reads, and buffer ``txn_size`` writes spread across the scanned
    intervals, all committed at one validated timestamp (EEMARQ-style
    update-in-scan pushed to MV-RLU's full footprint model, DESIGN.md
    §8-§9).  EEMARQ (Sheffi et al., 2022) names its mixes
    "update/lookup/scan" percentage triples; ``name`` carries that label
    (four components when ``rwtxn_frac`` > 0).
    """

    update_frac: float
    lookup_frac: float
    scan_frac: float
    scan_size: int = 64
    name: str = ""
    rwtxn_frac: float = 0.0
    txn_size: int = 4
    txn_ranges: int = 1
    txn_point_reads: int = 0

    def __post_init__(self):
        for f in (self.update_frac, self.lookup_frac, self.scan_frac,
                  self.rwtxn_frac):
            if not (0.0 <= f <= 1.0):
                raise ValueError(f"OpMix fraction {f} outside [0, 1]")
        total = (self.update_frac + self.lookup_frac + self.scan_frac
                 + self.rwtxn_frac)
        if abs(total - 1.0) > 1e-9:
            raise ValueError(f"OpMix fractions sum to {total}, expected 1.0")
        if (self.scan_frac > 0 or self.rwtxn_frac > 0) and self.scan_size < 1:
            raise ValueError("scan/rwtxn fractions > 0 require scan_size >= 1")
        if self.rwtxn_frac > 0 and self.txn_size < 1:
            raise ValueError("rwtxn_frac > 0 requires txn_size >= 1")
        if self.txn_ranges < 1:
            raise ValueError("txn_ranges must be >= 1")
        if self.txn_point_reads < 0:
            raise ValueError("txn_point_reads must be >= 0")

    @property
    def label(self) -> str:
        """The mix's display name (EEMARQ-style percentage triple/quad)."""
        if self.name:
            return self.name
        parts = [self.update_frac, self.lookup_frac, self.scan_frac]
        if self.rwtxn_frac > 0:
            parts.append(self.rwtxn_frac)
        return "/".join(str(round(100 * p)) for p in parts)

    @property
    def rw_ratio(self) -> float:
        """Share of transactions (scan-only rtxs + rw txns) that read-write."""
        txn_frac = self.scan_frac + self.rwtxn_frac
        return round(self.rwtxn_frac / txn_frac, 4) if txn_frac > 0 else 0.0


# The EEMARQ-style range-heavy mixes (update/lookup/scan).
EEMARQ_MIXES = (
    OpMix(0.50, 0.25, 0.25, name="50/25/25"),
    OpMix(0.10, 0.10, 0.80, name="10/10/80"),
)
EEMARQ_SCAN_SIZES = (8, 64, 1024, 8192)
EEMARQ_ZIPFS = (0.0, 0.99)   # uniform + the YCSB-default Zipfian

# The read-write update-in-scan mixes (update/lookup/scan/rwtxn; DESIGN.md
# §8): a balanced mix (half of all txns read-write) and a txn-heavy one
# (three quarters read-write), spanning the rw/ro-ratio axis.
EEMARQ_RW_MIXES = (
    OpMix(0.30, 0.20, 0.25, rwtxn_frac=0.25, name="30/20/25/25"),
    OpMix(0.10, 0.10, 0.20, rwtxn_frac=0.60, name="10/10/20/60"),
)
EEMARQ_TXN_SIZES = (2, 8)
EEMARQ_RW_SCAN_SIZES = (16, 128)
# multi-interval footprints (MV-RLU-style, DESIGN.md §9): r disjoint scan
# intervals per txn; the high-contention tier concentrates the key draws
# (Zipf 1.2 vs the YCSB-default 0.99) so abort/retry storms actually form
EEMARQ_TXN_RANGES = (2, 4)
EEMARQ_HC_ZIPF = 1.2


# ---------------------------------------------------------------------------
# Measurement rows
# ---------------------------------------------------------------------------
@dataclass
class Measurement:
    """One benchmark cell: (driver, figure, structure, scheme, mix) with its
    space + throughput measurements, flattened for JSON serialization."""

    bench: str
    figure: str
    ds: str
    scheme: str
    mix: str
    scan_size: int
    zipf: float
    n_keys: int
    num_procs: int
    ops_per_proc: int
    seed: int
    updates: int
    lookups: int
    scans: int
    scan_keys: int
    total_work: int
    ops_per_mwork: float
    updates_per_mwork: float
    scan_keys_per_mwork: float
    peak_space_words: int
    peak_versions: int
    avg_space_words: int
    end_space_words: int
    end_versions_per_list: float
    scans_validated: int
    scan_violations: int
    wall_s: float
    txn_size: int = 0
    rw_ratio: float = 0.0
    txns_committed: int = 0
    txns_aborted: int = 0
    abort_rate: float = 0.0
    txn_ranges: int = 0
    point_reads: int = 0
    aborts_footprint: int = 0
    aborts_wcc: int = 0
    aborts_capacity: int = 0
    txn_giveups: int = 0
    backoff_slices: int = 0
    reclaims_triggered: int = 0
    versions_reclaimed_on_abort: int = 0
    reclaim_latency_slices: int = 0
    peak_space_post_reclaim: int = 0
    scheme_stats: Dict[str, Any] = field(default_factory=dict)

    @classmethod
    def from_result(cls, bench: str, figure: str, result: Dict[str, Any],
                    wall_s: float = 0.0) -> "Measurement":
        """Build a row from a ``run_workload`` result dict."""
        cfg = result["config"]
        c = result["counters"]
        mix = getattr(cfg, "op_mix", None)
        if cfg.mode == "split":
            mix_label = "split"
            scan_size = cfg.scan_size
        else:
            mix_label = mix.label if mix is not None else "mixed"
            scan_size = mix.scan_size if mix is not None else 0
        return cls(
            bench=bench,
            figure=figure,
            ds=cfg.ds,
            scheme=cfg.scheme,
            mix=mix_label,
            scan_size=scan_size,
            zipf=cfg.zipf,
            n_keys=cfg.n_keys,
            num_procs=cfg.num_procs,
            ops_per_proc=cfg.ops_per_proc,
            seed=cfg.seed,
            updates=c["updates"],
            lookups=c["lookups"],
            scans=c["scans"],
            scan_keys=c["scan_keys"],
            total_work=result["total_work"],
            ops_per_mwork=round(result["ops_per_mwork"], 3),
            updates_per_mwork=round(result["updates_per_mwork"], 3),
            scan_keys_per_mwork=round(result["scan_keys_per_mwork"], 3),
            peak_space_words=result["peak_space"]["words"],
            peak_versions=result["peak_space"].get("versions", 0),
            avg_space_words=int(result["avg_space"]),
            end_space_words=result["end_space"]["words"],
            end_versions_per_list=round(
                result["end_space"]["versions_per_list"], 4),
            scans_validated=result.get("scans_validated", 0),
            scan_violations=result.get("scan_violations", 0),
            wall_s=round(wall_s, 2),
            txn_size=(mix.txn_size if mix is not None and mix.rwtxn_frac > 0
                      else 0),
            rw_ratio=(mix.rw_ratio if mix is not None else 0.0),
            txns_committed=c.get("txn_commits", 0),
            txns_aborted=c.get("txn_aborts", 0),
            abort_rate=round(
                c.get("txn_aborts", 0)
                / max(1, c.get("txn_commits", 0) + c.get("txn_aborts", 0)), 4),
            txn_ranges=(mix.txn_ranges
                        if mix is not None and mix.rwtxn_frac > 0 else 0),
            point_reads=(mix.txn_point_reads
                         if mix is not None and mix.rwtxn_frac > 0 else 0),
            aborts_footprint=c.get("txn_aborts_footprint", 0),
            aborts_wcc=c.get("txn_aborts_wcc", 0),
            aborts_capacity=c.get("txn_aborts_capacity", 0),
            txn_giveups=c.get("txn_giveups", 0),
            backoff_slices=int(
                result.get("contention_stats", {}).get("backoff_slices", 0)),
            reclaims_triggered=int(
                result.get("contention_stats", {})
                .get("reclaims_triggered", 0)),
            versions_reclaimed_on_abort=int(
                result.get("contention_stats", {})
                .get("versions_reclaimed_on_abort", 0)),
            reclaim_latency_slices=int(
                result.get("contention_stats", {})
                .get("reclaim_latency_slices", 0)),
            peak_space_post_reclaim=c.get("peak_space_post_reclaim", 0),
            scheme_stats=dict(result.get("scheme_stats", {})),
        )

    def to_row(self) -> Dict[str, Any]:
        """Flatten to the dict serialized as one BENCH json row."""
        return asdict(self)


@dataclass
class ServeMeasurement(Measurement):
    """One ``BENCH_serve.json`` cell: a paged-KV serving run under one GC
    policy and one pressure tier (DESIGN.md §11).

    Reuses the base row contract so ``write_bench_json`` /
    ``tools/compare_bench.py`` work unchanged: ``scheme`` is the vstore GC
    policy, ``ds`` is ``paged_kv``, space is measured in **pages** —
    ``peak_space_words`` / ``end_space_words`` carry peak/end live-page
    counts, ``peak_space_post_reclaim`` carries ``peak_pages_post_reclaim``
    — and ``scans_validated`` / ``scan_violations`` count pinned-snapshot
    stability checks.  ``reclaims_triggered`` (inherited) counts synchronous
    reclaim passes; the serve-only fields below add the pressure-loop
    accounting (``units["serve_pressure"]``)."""

    pressure_events: int = 0
    pages_reclaimed: int = 0
    peak_pages: int = 0
    peak_pages_post_reclaim: int = 0
    page_pool: int = 0
    page_size: int = 0
    decode_steps: int = 0
    tokens_appended: int = 0
    sequences_completed: int = 0
    forks: int = 0
    give_ups: int = 0
    snapshot_pins: int = 0
    overflow_count: int = 0
    dropped_retires: int = 0


# ---------------------------------------------------------------------------
# Shared CLI scaffolding for the tiered bench drivers
# ---------------------------------------------------------------------------
def parse_tier_argv(argv: Sequence[str], tiers: Dict[str, Any],
                    default_tier: str = "standard"):
    """Shared ``--smoke`` / ``--full`` / ``--tiers a,b`` parsing for
    ``benchmarks/range_query.py`` and ``benchmarks/txn_mix.py``.  Returns
    ``(tier_names, None)`` or ``(None, error_message)``."""
    names = [default_tier]
    if "--smoke" in argv:
        names = ["smoke"]
    elif "--full" in argv:
        names = ["full"]
    if "--tiers" in argv:
        i = argv.index("--tiers") + 1
        if i >= len(argv):
            return None, "--tiers needs a comma-separated value"
        names = argv[i].split(",")
    unknown = [t for t in names if t not in tiers]
    if unknown:
        return None, f"unknown tier(s) {unknown} (have {list(tiers)})"
    return names, None


def parse_out_argv(argv: Sequence[str], default_out: str):
    """Shared ``--out PATH`` parsing; returns ``(path, None)`` or
    ``(None, error_message)``."""
    if "--out" in argv:
        i = argv.index("--out") + 1
        if i >= len(argv):
            return None, "--out needs a path"
        return argv[i], None
    return default_out, None


def tier_meta(tier_names: Sequence[str],
              tiers: Dict[str, Any]) -> Dict[str, Any]:
    """BENCH ``meta`` block for a (possibly concatenated) tier run."""
    meta: Dict[str, Any] = {
        "tier": tier_names[0] if len(tier_names) == 1 else "+".join(tier_names),
        "tiers": list(tier_names),
    }
    for t in tier_names:
        meta[t] = {k: list(v) if isinstance(v, tuple) else v
                   for k, v in tiers[t].items()}
    return meta


def print_rows_by_figure(rows: Sequence[Measurement],
                         cols: Sequence[str], width: int = 18) -> None:
    """Group measurement rows by figure and print fixed-width tables."""
    by_figure: Dict[str, List[Dict[str, Any]]] = {}
    for m in rows:
        by_figure.setdefault(m.figure, []).append(m.to_row())
    for figure, rs in by_figure.items():
        print(f"\n== {figure} ==")
        print("  ".join(f"{c:>{width}s}" for c in cols))
        for r in rs:
            print("  ".join(f"{str(r[c]):>{width}s}" for c in cols))


# ---------------------------------------------------------------------------
# BENCH_*.json serialization
# ---------------------------------------------------------------------------
def bench_payload(bench: str, measurements: Sequence[Measurement],
                  meta: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Assemble the BENCH json payload dict (see the module docstring)."""
    return {
        "bench": bench,
        "schema_version": SCHEMA_VERSION,
        "units": dict(UNITS),
        "meta": dict(meta or {}),
        "rows": [m.to_row() for m in measurements],
    }


def write_bench_json(path: str, bench: str,
                     measurements: Sequence[Measurement],
                     meta: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Serialize measurements to ``path`` in the BENCH schema; returns the
    payload dict (also used by in-process tests)."""
    payload = bench_payload(bench, measurements, meta)
    parent = os.path.dirname(os.path.abspath(path))
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    return payload


def validate_bench_payload(payload: Dict[str, Any]) -> List[str]:
    """Return a list of schema problems (empty = valid).  Shared by
    ``tools/check_bench_json.py`` and the unit tests."""
    problems = []
    for k in REQUIRED_TOP_KEYS:
        if k not in payload:
            problems.append(f"missing top-level key: {k}")
    rows = payload.get("rows", [])
    if not rows:
        problems.append("rows is empty")
    for i, row in enumerate(rows):
        missing = [k for k in REQUIRED_ROW_KEYS if k not in row]
        if missing:
            problems.append(f"row {i} missing keys: {missing}")
    return problems
