"""Shared operation-mix and measurement plumbing for the sim benchmarks.

Both benchmark drivers (``benchmarks/gc_comparison.py`` — the paper's Figures
4-8 — and ``benchmarks/range_query.py`` — the EEMARQ-style range-scan family,
DESIGN.md §7) build their workloads from :class:`OpMix` and serialize their
results through :class:`Measurement` / :func:`write_bench_json`, so the two
trajectories stay apples-to-apples: same space units (Java-reachability
words, DESIGN.md §5), same throughput proxy (completed operations per million
simulated work units), same JSON schema.

``BENCH_*.json`` schema (``SCHEMA_VERSION`` = 1)::

    {
      "bench": "<driver name>",
      "schema_version": 1,
      "units": {...},                 # human-readable unit strings
      "meta": {...},                  # driver-specific run parameters
      "rows": [<Measurement dict>, ...]
    }

Every row carries the keys in ``REQUIRED_ROW_KEYS``; ``tools/
check_bench_json.py`` (run by the CI ``bench-smoke`` step) enforces this.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional, Sequence

SCHEMA_VERSION = 1

UNITS = {
    "space": "words, Java-style reachability from the structure roots "
             "(version nodes at the scheme's per-node cost + payloads + GC "
             "metadata; DESIGN.md §5)",
    "throughput": "completed operations per 1e6 simulated work units "
                  "(work unit = one shared-memory access of the lock-free "
                  "algorithm; DESIGN.md §5)",
    "scan_size": "keys per range scan (half-open key interval [lo, lo+s))",
}

REQUIRED_TOP_KEYS = ("bench", "schema_version", "units", "meta", "rows")

REQUIRED_ROW_KEYS = (
    "bench", "figure", "ds", "scheme", "mix", "scan_size", "zipf",
    "n_keys", "num_procs", "ops_per_proc", "seed",
    "updates", "lookups", "scans", "scan_keys", "total_work",
    "ops_per_mwork", "updates_per_mwork", "scan_keys_per_mwork",
    "peak_space_words", "peak_versions", "avg_space_words",
    "end_space_words", "end_versions_per_list",
    "scans_validated", "scan_violations", "wall_s",
)


# ---------------------------------------------------------------------------
# Operation mix
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class OpMix:
    """A mixed workload's operation distribution.

    Fractions are per-operation probabilities (update / point lookup / range
    scan) and must sum to 1.  ``scan_size`` is the number of keys each range
    scan covers.  EEMARQ (Sheffi et al., 2022) names its mixes
    "update/lookup/scan" percentage triples; ``name`` carries that label.
    """

    update_frac: float
    lookup_frac: float
    scan_frac: float
    scan_size: int = 64
    name: str = ""

    def __post_init__(self):
        for f in (self.update_frac, self.lookup_frac, self.scan_frac):
            if not (0.0 <= f <= 1.0):
                raise ValueError(f"OpMix fraction {f} outside [0, 1]")
        total = self.update_frac + self.lookup_frac + self.scan_frac
        if abs(total - 1.0) > 1e-9:
            raise ValueError(f"OpMix fractions sum to {total}, expected 1.0")
        if self.scan_frac > 0 and self.scan_size < 1:
            raise ValueError("scan_frac > 0 requires scan_size >= 1")

    @property
    def label(self) -> str:
        if self.name:
            return self.name
        return (f"{round(100 * self.update_frac)}/"
                f"{round(100 * self.lookup_frac)}/"
                f"{round(100 * self.scan_frac)}")


# The EEMARQ-style range-heavy mixes (update/lookup/scan).
EEMARQ_MIXES = (
    OpMix(0.50, 0.25, 0.25, name="50/25/25"),
    OpMix(0.10, 0.10, 0.80, name="10/10/80"),
)
EEMARQ_SCAN_SIZES = (8, 64, 1024, 8192)
EEMARQ_ZIPFS = (0.0, 0.99)   # uniform + the YCSB-default Zipfian


# ---------------------------------------------------------------------------
# Measurement rows
# ---------------------------------------------------------------------------
@dataclass
class Measurement:
    """One benchmark cell: (driver, figure, structure, scheme, mix) with its
    space + throughput measurements, flattened for JSON serialization."""

    bench: str
    figure: str
    ds: str
    scheme: str
    mix: str
    scan_size: int
    zipf: float
    n_keys: int
    num_procs: int
    ops_per_proc: int
    seed: int
    updates: int
    lookups: int
    scans: int
    scan_keys: int
    total_work: int
    ops_per_mwork: float
    updates_per_mwork: float
    scan_keys_per_mwork: float
    peak_space_words: int
    peak_versions: int
    avg_space_words: int
    end_space_words: int
    end_versions_per_list: float
    scans_validated: int
    scan_violations: int
    wall_s: float
    scheme_stats: Dict[str, Any] = field(default_factory=dict)

    @classmethod
    def from_result(cls, bench: str, figure: str, result: Dict[str, Any],
                    wall_s: float = 0.0) -> "Measurement":
        """Build a row from a ``run_workload`` result dict."""
        cfg = result["config"]
        c = result["counters"]
        mix = getattr(cfg, "op_mix", None)
        if cfg.mode == "split":
            mix_label = "split"
            scan_size = cfg.scan_size
        else:
            mix_label = mix.label if mix is not None else "mixed"
            scan_size = mix.scan_size if mix is not None else 0
        return cls(
            bench=bench,
            figure=figure,
            ds=cfg.ds,
            scheme=cfg.scheme,
            mix=mix_label,
            scan_size=scan_size,
            zipf=cfg.zipf,
            n_keys=cfg.n_keys,
            num_procs=cfg.num_procs,
            ops_per_proc=cfg.ops_per_proc,
            seed=cfg.seed,
            updates=c["updates"],
            lookups=c["lookups"],
            scans=c["scans"],
            scan_keys=c["scan_keys"],
            total_work=result["total_work"],
            ops_per_mwork=round(result["ops_per_mwork"], 3),
            updates_per_mwork=round(result["updates_per_mwork"], 3),
            scan_keys_per_mwork=round(result["scan_keys_per_mwork"], 3),
            peak_space_words=result["peak_space"]["words"],
            peak_versions=result["peak_space"].get("versions", 0),
            avg_space_words=int(result["avg_space"]),
            end_space_words=result["end_space"]["words"],
            end_versions_per_list=round(
                result["end_space"]["versions_per_list"], 4),
            scans_validated=result.get("scans_validated", 0),
            scan_violations=result.get("scan_violations", 0),
            wall_s=round(wall_s, 2),
            scheme_stats=dict(result.get("scheme_stats", {})),
        )

    def to_row(self) -> Dict[str, Any]:
        return asdict(self)


# ---------------------------------------------------------------------------
# BENCH_*.json serialization
# ---------------------------------------------------------------------------
def bench_payload(bench: str, measurements: Sequence[Measurement],
                  meta: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    return {
        "bench": bench,
        "schema_version": SCHEMA_VERSION,
        "units": dict(UNITS),
        "meta": dict(meta or {}),
        "rows": [m.to_row() for m in measurements],
    }


def write_bench_json(path: str, bench: str,
                     measurements: Sequence[Measurement],
                     meta: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Serialize measurements to ``path`` in the BENCH schema; returns the
    payload dict (also used by in-process tests)."""
    payload = bench_payload(bench, measurements, meta)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    return payload


def validate_bench_payload(payload: Dict[str, Any]) -> List[str]:
    """Return a list of schema problems (empty = valid).  Shared by
    ``tools/check_bench_json.py`` and the unit tests."""
    problems = []
    for k in REQUIRED_TOP_KEYS:
        if k not in payload:
            problems.append(f"missing top-level key: {k}")
    rows = payload.get("rows", [])
    if not rows:
        problems.append("rows is empty")
    for i, row in enumerate(rows):
        missing = [k for k in REQUIRED_ROW_KEYS if k not in row]
        if missing:
            problems.append(f"row {i} missing keys: {missing}")
    return problems
