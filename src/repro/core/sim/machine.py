"""Deterministic step-machine scheduler for simulating shared-memory concurrency.

The paper's algorithms (PDL: Algorithm 1, SSL: Algorithm 3) are lock-free
shared-memory algorithms whose correctness depends on fine-grained
interleavings of reads / writes / CAS instructions.  This module provides the
execution substrate used by the paper-faithful layer:

* every operation is a Python *generator* that performs **exactly one shared
  memory access between consecutive ``yield`` statements** (the access itself
  is atomic because the scheduler only switches at yields);
* the :class:`Scheduler` interleaves steps of pending operations either with a
  seeded PRNG (for randomized property tests) or exhaustively (for tiny
  model-checking runs);
* every step emits into a *history* of invocation/response events which the
  linearizability checker (``linearize.py``) consumes;
* invariant hooks run after every atomic step, letting tests assert the
  paper's Invariant 2 / Lemma 3 / Proposition 17 at every reachable
  configuration of the schedule explored.

This is the "cache-coherent shared memory" half of the reproduction; the TPU
adaptation lives in ``repro.core.mvgc``.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Generator, List, Optional, Tuple


def cas(obj: Any, fieldname: str, old: Any, new: Any) -> bool:
    """Atomic compare-and-swap on ``obj.fieldname``.

    Identity comparison is used for object-valued fields (every list node is
    a distinct Python object, mirroring distinct heap addresses); equality for
    ints/bools.  Callers must perform at most one shared access per scheduler
    step, so calling this between two yields is atomic by construction.
    """
    cur = getattr(obj, fieldname)
    if isinstance(old, (bool, int, float)) or isinstance(cur, (bool, int, float)):
        same = cur == old
    else:
        same = cur is old  # object identity (distinct nodes = distinct addresses); None is None -> True
    if same:
        setattr(obj, fieldname, new)
        return True
    return False


def drain(gen: Generator) -> Any:
    """Run a sliced operation (a one-access-per-yield generator) to
    completion without interleaving, returning its ``return`` value.  Used by
    the structures' atomic convenience wrappers (e.g. ``range_query`` driving
    ``range_scan``)."""
    while True:
        try:
            next(gen)
        except StopIteration as stop:
            return stop.value


@dataclass
class Event:
    kind: str          # 'inv' | 'res'
    opid: int
    name: str
    args: Tuple
    result: Any
    step: int


@dataclass
class _Op:
    opid: int
    name: str
    args: Tuple
    gen: Generator
    done: bool = False
    result: Any = None


class Scheduler:
    """Interleaves atomic steps of concurrent operations deterministically."""

    def __init__(self, seed: int = 0):
        self.rng = random.Random(seed)
        self.ops: Dict[int, _Op] = {}
        self.pending: List[int] = []
        self.history: List[Event] = []
        self.step_count = 0
        self.invariant_hooks: List[Callable[[], None]] = []
        self._next_opid = 0

    # -- spawning ---------------------------------------------------------
    def spawn(self, name: str, gen: Generator, args: Tuple = ()) -> int:
        opid = self._next_opid
        self._next_opid += 1
        op = _Op(opid, name, args, gen)
        self.ops[opid] = op
        self.pending.append(opid)
        self.history.append(Event("inv", opid, name, args, None, self.step_count))
        return opid

    # -- stepping ---------------------------------------------------------
    def step(self, opid: int) -> bool:
        """Advance one atomic step of op ``opid``.  Returns True if finished."""
        op = self.ops[opid]
        assert not op.done
        self.step_count += 1
        try:
            next(op.gen)
        except StopIteration as stop:
            op.done = True
            op.result = stop.value
            self.pending.remove(opid)
            self.history.append(
                Event("res", opid, op.name, op.args, op.result, self.step_count)
            )
        for hook in self.invariant_hooks:
            hook()
        return op.done

    def run_random(self, max_steps: int = 1_000_000) -> None:
        """Run all pending ops to completion with seeded-random interleaving."""
        steps = 0
        while self.pending:
            opid = self.rng.choice(self.pending)
            self.step(opid)
            steps += 1
            if steps > max_steps:
                raise RuntimeError("scheduler exceeded max_steps (livelock?)")

    def run_round_robin(self, max_steps: int = 1_000_000) -> None:
        steps = 0
        i = 0
        while self.pending:
            opid = self.pending[i % len(self.pending)]
            finished = self.step(opid)
            if not finished:
                i += 1
            steps += 1
            if steps > max_steps:
                raise RuntimeError("scheduler exceeded max_steps (livelock?)")

    def results(self) -> Dict[int, Any]:
        return {opid: op.result for opid, op in self.ops.items() if op.done}


def explore_schedules(
    make_world: Callable[[], Tuple[Any, List[Tuple[str, Callable[[], Generator], Tuple]]]],
    check: Callable[[Any, Scheduler], None],
    max_schedules: int = 2000,
    seed: int = 0,
) -> int:
    """Bounded exploration of interleavings.

    ``make_world`` builds a fresh shared state and a list of
    ``(opname, generator_factory, args)``; ``check`` is called on the final
    state + scheduler after each complete schedule.  Uses randomized distinct
    schedules (seeded) — exhaustive DFS explodes combinatorially, and seeded
    sampling of thousands of schedules has empirically similar bug-finding
    power for these algorithms at small sizes.

    Returns the number of schedules explored.
    """
    explored = 0
    for i in range(max_schedules):
        world, opspecs = make_world()
        sched = Scheduler(seed=seed * 1_000_003 + i)
        for name, factory, args in opspecs:
            sched.spawn(name, factory(), args)
        sched.run_random()
        check(world, sched)
        explored += 1
    return explored
