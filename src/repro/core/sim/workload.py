"""Discrete-event workload driver for the MVGC scheme comparison (paper §6).

Reproduces the paper's benchmark methodology on this container's single core:
P logical processes execute a mix of updates (insert/delete, equal numbers),
lookups and read-only transactions (range queries of size s) against one of
the two multiversion data structures, with keys drawn uniformly or Zipfian
(0.99, the YCSB default).  Processes interleave at *sub-operation* slices —
an rtx spans many slices, pinning its timestamp/epoch while updates create
versions — which is exactly the dynamic that differentiates the schemes'
space behaviour.

Measurements:
* **space**: words reachable from the data structure roots (Java GC model —
  version nodes at the scheme's per-node cost, chain cells, tree nodes
  reachable through old child-pointer versions, GC metadata).  Peak + final.
* **throughput proxy**: completed ops per million *work units*, where work
  units count the shared-memory accesses the lock-free algorithms would
  execute (list traversals, compactions, RT flushes, announcement scans).
  Wall-clock threading is meaningless on a single hyperthread; relative work
  is the faithful signal and reproduces the paper's qualitative ordering.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Any, Dict, Generator, List, Optional

import numpy as np

from repro.core.sim.mvhash import MVHashTable
from repro.core.sim.mvtree import MVTree, Leaf, Internal
from repro.core.sim.schemes import SchemeBase, make_scheme
from repro.core.sim.ssl_list import MVEnv


# ---------------------------------------------------------------------------
# Space accounting (Java reachability model)
# ---------------------------------------------------------------------------
def measure_space(ds, scheme: SchemeBase) -> Dict[str, int]:
    words = 0
    versions = 0
    lists_seen = 0
    seen_vcas, seen_obj = set(), set()
    stack = list(ds.root_vcas())
    while stack:
        vc = stack.pop()
        if id(vc) in seen_vcas:
            continue
        seen_vcas.add(id(vc))
        lists_seen += 1
        words += 2  # the vCAS head cell + header
        for n in vc.lst.reachable_nodes():
            versions += 1
            words += scheme.node_words
            words += _payload_words(n.val, stack, seen_obj)
    words += scheme.aux_space_words()
    return {
        "words": words,
        "versions": versions,
        "lists": lists_seen,
        "versions_per_list": versions / max(1, lists_seen),
    }


def _payload_words(val, stack, seen_obj) -> int:
    if val is None:
        return 0
    if isinstance(val, tuple):  # hash chain (path-copied, immutable)
        return 1 + 2 * len(val)
    if isinstance(val, Leaf):
        if id(val) in seen_obj:
            return 0
        seen_obj.add(id(val))
        return Leaf.WORDS
    if isinstance(val, Internal):
        if id(val) in seen_obj:
            return 0
        seen_obj.add(id(val))
        stack.append(val.left_v)
        stack.append(val.right_v)
        return Internal.WORDS
    return 1


# ---------------------------------------------------------------------------
# Key samplers
# ---------------------------------------------------------------------------
class KeySampler:
    def __init__(self, key_range: int, zipf: float, seed: int):
        self.key_range = key_range
        self.rng = np.random.default_rng(seed)
        if zipf and zipf > 0:
            ranks = np.arange(1, key_range + 1, dtype=np.float64)
            p = 1.0 / ranks**zipf
            p /= p.sum()
            # shuffle so hot keys are spread across the key space
            perm = self.rng.permutation(key_range)
            self.p = p[perm]
        else:
            self.p = None
        self._buf: List[int] = []

    def __call__(self) -> int:
        if not self._buf:
            if self.p is None:
                self._buf = list(self.rng.integers(1, self.key_range + 1, 4096))
            else:
                self._buf = list(
                    self.rng.choice(self.key_range, size=4096, p=self.p) + 1
                )
        return int(self._buf.pop())


# ---------------------------------------------------------------------------
# Workload configuration
# ---------------------------------------------------------------------------
@dataclass
class WorkloadConfig:
    ds: str = "hash"                  # 'hash' | 'tree'
    scheme: str = "slrt"              # ebr | steam | dlrt | slrt | bbf
    n_keys: int = 1024
    num_procs: int = 24
    mode: str = "split"               # 'split' (Figs 4-6) | 'mixed' (Figs 7-8)
    # split mode: procs divided update / fixed-rtx / variable-rtx (paper ratio)
    rtx_size: int = 16
    variable_rtx_max: Optional[int] = None   # default: n_keys
    # mixed mode fractions (paper: 50% updates, 49% lookups, 1% rtx of 1024)
    mixed_update_frac: float = 0.5
    mixed_lookup_frac: float = 0.49
    mixed_rtx_size: int = 256
    ops_per_proc: int = 200
    zipf: float = 0.99                # 0 => uniform
    seed: int = 0
    rtx_chunk: int = 8                # keys per rtx slice
    sample_every: int = 256           # slices between space samples
    scheme_kwargs: Dict[str, Any] = field(default_factory=dict)


# ---------------------------------------------------------------------------
# Process scripts (generators; one yield per slice)
# ---------------------------------------------------------------------------
def _do_update(pid, ds, env, scheme, sampler, rng, counters):
    ctx = scheme.begin_update(pid)
    env.advance_ts()
    k = sampler()
    if rng.random() < 0.5:
        ds.insert(pid, k, rng.randrange(1 << 30))
    else:
        ds.delete(pid, k)
    scheme.end_update(pid, ctx)
    counters["updates"] += 1


def _rtx_slices(pid, ds, env, scheme, rng, size, key_range, chunk, counters):
    t = scheme.begin_rtx(pid)
    a = rng.randrange(1, max(2, key_range - size + 1))
    done = 0
    while done < size:
        c = min(chunk, size - done)
        ds.range_query(pid, a + done, a + done + c, t)
        done += c
        yield
    scheme.end_rtx(pid)
    counters["rtx"] += 1
    counters["rtx_keys"] += size


def update_script(pid, ds, env, scheme, sampler, rng, n_ops, counters) -> Generator:
    for _ in range(n_ops):
        _do_update(pid, ds, env, scheme, sampler, rng, counters)
        yield


def rtx_script(
    pid, ds, env, scheme, rng, n_ops, size_fn, key_range, chunk, counters
) -> Generator:
    for _ in range(n_ops):
        yield from _rtx_slices(
            pid, ds, env, scheme, rng, size_fn(), key_range, chunk, counters
        )
        yield


def mixed_script(
    pid, ds, env, scheme, sampler, rng, cfg: WorkloadConfig, key_range, counters
) -> Generator:
    for _ in range(cfg.ops_per_proc):
        r = rng.random()
        if r < cfg.mixed_update_frac:
            _do_update(pid, ds, env, scheme, sampler, rng, counters)
            yield
        elif r < cfg.mixed_update_frac + cfg.mixed_lookup_frac:
            ds.lookup(pid, sampler())
            counters["lookups"] += 1
            yield
        else:
            yield from _rtx_slices(
                pid, ds, env, scheme, rng, cfg.mixed_rtx_size, key_range,
                cfg.rtx_chunk, counters,
            )
            yield


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------
def run_workload(cfg: WorkloadConfig) -> Dict[str, Any]:
    env = MVEnv(cfg.num_procs)
    scheme = make_scheme(cfg.scheme, env, **cfg.scheme_kwargs)
    rng = random.Random(cfg.seed)
    key_range = 2 * cfg.n_keys
    sampler = KeySampler(key_range, cfg.zipf, cfg.seed + 1)

    ds = MVHashTable(env, scheme, cfg.n_keys) if cfg.ds == "hash" else MVTree(env, scheme)
    # prefill to ~n_keys live keys
    prefill = rng.sample(range(1, key_range + 1), cfg.n_keys)
    for k in prefill:
        env.advance_ts()
        ds.insert(0, k, k)
    scheme.quiesce()
    base_work = _total_work(scheme)
    counters: Dict[str, int] = {"updates": 0, "rtx": 0, "rtx_keys": 0, "lookups": 0}

    scripts: List[Generator] = []
    if cfg.mode == "split":
        per = cfg.num_procs // 3
        vmax = cfg.variable_rtx_max or cfg.n_keys
        for pid in range(per):  # update threads
            scripts.append(
                update_script(pid, ds, env, scheme, sampler, rng, cfg.ops_per_proc, counters)
            )
        for pid in range(per, 2 * per):  # fixed-size rtx threads
            scripts.append(
                rtx_script(pid, ds, env, scheme, rng,
                           max(1, cfg.ops_per_proc // 4),
                           lambda: cfg.rtx_size, key_range, cfg.rtx_chunk, counters)
            )
        sizes = [max(1, vmax >> i) for i in range(per)] or [vmax]
        for j, pid in enumerate(range(2 * per, cfg.num_procs)):  # variable-size rtx
            size = sizes[j % len(sizes)]
            scripts.append(
                rtx_script(pid, ds, env, scheme, rng,
                           max(1, cfg.ops_per_proc // 8),
                           lambda s=size: s, key_range, cfg.rtx_chunk, counters)
            )
    else:
        for pid in range(cfg.num_procs):
            scripts.append(
                mixed_script(pid, ds, env, scheme, sampler, rng, cfg, key_range, counters)
            )

    # round-robin at slice granularity
    live = list(scripts)
    slices = 0
    peak = {"words": 0}
    space_samples: List[int] = []
    while live:
        nxt = []
        for g in live:
            try:
                next(g)
                nxt.append(g)
            except StopIteration:
                pass
            slices += 1
            if slices % cfg.sample_every == 0:
                s = measure_space(ds, scheme)
                space_samples.append(s["words"])
                if s["words"] > peak["words"]:
                    peak = s
        live = nxt

    end_space_pre_quiesce = measure_space(ds, scheme)
    space_samples.append(end_space_pre_quiesce["words"])
    if end_space_pre_quiesce["words"] > peak["words"]:
        peak = end_space_pre_quiesce
    scheme.quiesce()
    end_space = measure_space(ds, scheme)
    total_work = _total_work(scheme) - base_work

    return {
        "config": cfg,
        "counters": dict(counters),
        "total_work": total_work,
        "updates_per_mwork": counters["updates"] * 1e6 / max(1, total_work),
        "rtx_keys_per_mwork": counters["rtx_keys"] * 1e6 / max(1, total_work),
        "ops_per_mwork": (counters["updates"] + counters["rtx"] + counters["lookups"])
        * 1e6 / max(1, total_work),
        "peak_space": peak,
        "avg_space": sum(space_samples) / max(1, len(space_samples)),
        "end_space": end_space,
        "end_space_pre_quiesce": end_space_pre_quiesce,
        "scheme_stats": scheme.stats(),
    }


def _total_work(scheme: SchemeBase) -> int:
    return scheme.work + sum(l.work for l in scheme.lists)
