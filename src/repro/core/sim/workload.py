"""Discrete-event workload driver for the MVGC scheme comparison (paper §6)
and the EEMARQ-style range-scan workload family (DESIGN.md §7).

Reproduces the paper's benchmark methodology on this container's single core:
P logical processes execute a mix of updates (insert/delete, equal numbers),
point lookups and read-only transactions — each rtx performs one **range
scan** of size s through the structure's versions at the rtx timestamp —
against one of the two multiversion data structures, with keys drawn
uniformly or Zipfian (0.99, the YCSB default).  Processes interleave at
*sub-operation* slices: a range scan is an explicit multi-slice operation
(``MVTree.range_scan`` / ``MVHashTable.range_scan``) that yields between
versioned pointer reads, pinning its timestamp/epoch while updates create
versions — which is exactly the dynamic that differentiates the schemes'
space behaviour, and which EEMARQ (Sheffi et al., 2022) shows is where
reclamation schemes diverge most.

Terminology (unified; see DESIGN.md §7): an **rtx** is the read-only
transaction — the announce/unannounce pair that pins a snapshot timestamp
(``scheme.begin_rtx`` / ``end_rtx``).  A **range scan** is the sliced
traversal the rtx executes at that timestamp.  Earlier revisions used "rtx"
for both; counters and config fields now say ``scan``.

Workload shapes:
* **split** mode (paper Figs 4-6): processes divided update / fixed-size-scan
  / variable-size-scan in the paper's ratio.
* **mixed** mode (paper Figs 7-8 and the EEMARQ matrix): every process draws
  each operation from an :class:`~repro.core.sim.measure.OpMix`
  (update/lookup/scan/rwtxn fractions + scan size).  ``eemarq_matrix``
  enumerates the range-heavy family: mixes 50/25/25 and 10/10/80, scan sizes
  s ∈ {8, 64, 1024, 8192}, uniform + Zipfian 0.99, all five schemes, both
  structures.
* **read-write transactions** (DESIGN.md §8-§9): when ``OpMix.rwtxn_frac`` >
  0, a process draws MV-RLU-style multi-interval txns
  (:class:`~repro.core.sim.txn.Txn`): scan ``txn_ranges`` *disjoint*
  ``scan_size`` intervals at the begin snapshot, perform
  ``txn_point_reads`` tracked version-wise point reads, buffer ``txn_size``
  writes spread across the intervals, and commit everything at one
  validated commit timestamp.  On abort (reason ``capacity`` / ``wcc`` /
  ``footprint`` — the taxonomy in ``contention.ABORT_REASONS``) the process
  backs off for a contention-manager-chosen number of slices
  (bounded-exponential per pid) and retries with a fresh snapshot, giving
  up after ``max_retries``.  The txn's snapshot pin survives its write
  phase, and under an abort/retry storm each retry re-executes the whole
  multi-interval read phase — exactly the regime where the schemes'
  version-list truncation must hold both the scans' pins and the txns' own
  writes live, and where per-scheme space divergence becomes visible.
  ``eemarq_rw_matrix`` enumerates the family (rw mixes × scan/txn sizes ×
  interval counts × distributions × schemes × structures).

Measurements (serialized via :class:`~repro.core.sim.measure.Measurement`):
* **space**: words reachable from the data structure roots (Java GC model —
  version nodes at the scheme's per-node cost, chain cells, tree nodes
  reachable through old child-pointer versions, GC metadata).  Peak + final.
* **throughput proxy**: completed ops per million *work units*, where work
  units count the shared-memory accesses the lock-free algorithms would
  execute (list traversals, compactions, RT flushes, announcement scans).
  Wall-clock threading is meaningless on a single hyperthread; relative work
  is the faithful signal and reproduces the paper's qualitative ordering.

Validation: with ``WorkloadConfig.validate_scans`` every committed update is
recorded in a :class:`~repro.core.sim.linearize.UpdateLog` and every
completed scan is replayed against it at the scan's timestamp
(:class:`~repro.core.sim.linearize.ScanValidator`) — a scheme that reclaims a
version a pinned rtx still needs fails here, not silently.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field, replace
from typing import Any, Dict, Generator, List, Optional, Sequence

import numpy as np

from repro.core.sim.contention import ContentionManager
from repro.core.sim.linearize import ScanValidator, UpdateLog
from repro.core.sim.measure import (EEMARQ_MIXES, EEMARQ_RW_MIXES,
                                    EEMARQ_RW_SCAN_SIZES, EEMARQ_SCAN_SIZES,
                                    EEMARQ_TXN_RANGES, EEMARQ_TXN_SIZES,
                                    EEMARQ_ZIPFS, OpMix)
from repro.core.sim.mvhash import MVHashTable
from repro.core.sim.mvtree import MVTree, Leaf, Internal
from repro.core.sim.schemes import SCHEMES, SchemeBase, make_scheme
from repro.core.sim.ssl_list import MVEnv
from repro.core.sim.txn import Txn

# paper Figs 7-8: 50% updates, 49% lookups, 1% scans.  The paper uses
# 1024-key scans; drivers size the scan to their key range via
# dataclasses.replace (gc_comparison uses min(1024, n_keys)); 256 is the
# standalone default for small test configs.
PAPER_MIXED = OpMix(0.50, 0.49, 0.01, scan_size=256, name="paper-mixed")


# ---------------------------------------------------------------------------
# Space accounting (Java reachability model)
# ---------------------------------------------------------------------------
def measure_space(ds, scheme: SchemeBase) -> Dict[str, int]:
    words = 0
    versions = 0
    lists_seen = 0
    seen_vcas, seen_obj = set(), set()
    stack = list(ds.root_vcas())
    while stack:
        vc = stack.pop()
        if id(vc) in seen_vcas:
            continue
        seen_vcas.add(id(vc))
        lists_seen += 1
        words += 2  # the vCAS head cell + header
        for n in vc.lst.reachable_nodes():
            versions += 1
            words += scheme.node_words
            words += _payload_words(n.val, stack, seen_obj)
    words += scheme.aux_space_words()
    return {
        "words": words,
        "versions": versions,
        "lists": lists_seen,
        "versions_per_list": versions / max(1, lists_seen),
    }


def _payload_words(val, stack, seen_obj) -> int:
    if val is None:
        return 0
    if isinstance(val, tuple):  # hash chain (path-copied, immutable)
        return 1 + 2 * len(val)
    if isinstance(val, Leaf):
        if id(val) in seen_obj:
            return 0
        seen_obj.add(id(val))
        return Leaf.WORDS
    if isinstance(val, Internal):
        if id(val) in seen_obj:
            return 0
        seen_obj.add(id(val))
        stack.append(val.left_v)
        stack.append(val.right_v)
        return Internal.WORDS
    return 1


# ---------------------------------------------------------------------------
# Key samplers
# ---------------------------------------------------------------------------
class KeySampler:
    def __init__(self, key_range: int, zipf: float, seed: int):
        self.key_range = key_range
        self.rng = np.random.default_rng(seed)
        if zipf and zipf > 0:
            ranks = np.arange(1, key_range + 1, dtype=np.float64)
            p = 1.0 / ranks**zipf
            p /= p.sum()
            # shuffle so hot keys are spread across the key space
            perm = self.rng.permutation(key_range)
            self.p = p[perm]
        else:
            self.p = None
        self._buf: List[int] = []

    def __call__(self) -> int:
        if not self._buf:
            if self.p is None:
                self._buf = list(self.rng.integers(1, self.key_range + 1, 4096))
            else:
                self._buf = list(
                    self.rng.choice(self.key_range, size=4096, p=self.p) + 1
                )
        return int(self._buf.pop())


# ---------------------------------------------------------------------------
# Workload configuration
# ---------------------------------------------------------------------------
@dataclass
class WorkloadConfig:
    ds: str = "hash"                  # 'hash' | 'tree'
    scheme: str = "slrt"              # ebr | steam | dlrt | slrt | bbf
    n_keys: int = 1024
    num_procs: int = 24
    mode: str = "split"               # 'split' (Figs 4-6) | 'mixed' (Figs 7-8, EEMARQ)
    # split mode: procs divided update / fixed-scan / variable-scan (paper ratio)
    scan_size: int = 16
    variable_scan_max: Optional[int] = None   # default: n_keys
    # mixed mode: operation distribution (default = the paper's Figs 7-8 mix)
    op_mix: Optional[OpMix] = None
    ops_per_proc: int = 200
    zipf: float = 0.99                # 0 => uniform
    seed: int = 0
    scan_chunk: int = 8               # versioned reads per scan slice
    sample_every: int = 256           # slices between space samples
    validate_scans: bool = False      # replay every scan against an UpdateLog
    # read-write txn contention knobs (DESIGN.md §9)
    max_retries: int = 16             # txn attempts before giving up
    backoff_base: int = 1             # contention-manager backoff: base slices
    backoff_cap: int = 64             # ...and the bound on one backoff
    txn_capacity: Optional[int] = None  # version budget (None = unbounded)
    txn_refill_every: int = 4         # ts ticks per budget token refill
    scheme_kwargs: Dict[str, Any] = field(default_factory=dict)

    def resolved_mix(self) -> OpMix:
        return self.op_mix if self.op_mix is not None else PAPER_MIXED


def eemarq_matrix(
    *,
    structures: Sequence[str] = ("hash", "tree"),
    schemes: Sequence[str] = tuple(SCHEMES),
    mixes: Sequence[OpMix] = EEMARQ_MIXES,
    scan_sizes: Sequence[int] = EEMARQ_SCAN_SIZES,
    zipfs: Sequence[float] = EEMARQ_ZIPFS,
    n_keys: int = 1024,
    num_procs: int = 16,
    ops_per_proc: int = 120,
    seed: int = 7,
    **overrides,
) -> List[WorkloadConfig]:
    """Enumerate the EEMARQ-style range-scan workload matrix as ready-to-run
    configs (mix × scan size × key distribution × scheme × structure).  The
    defaults are the full family; drivers pass subsets for smoke/fast runs.
    """
    cfgs = []
    for ds in structures:
        for mix in mixes:
            for size in scan_sizes:
                for z in zipfs:
                    for scheme in schemes:
                        kw = ({"batch_size": max(8, num_procs)}
                              if scheme in ("dlrt", "slrt", "bbf") else {})
                        cfgs.append(WorkloadConfig(
                            ds=ds, scheme=scheme, n_keys=n_keys,
                            num_procs=num_procs, mode="mixed",
                            op_mix=replace(mix, scan_size=size),
                            ops_per_proc=ops_per_proc, zipf=z, seed=seed,
                            scheme_kwargs=kw, **overrides,
                        ))
    return cfgs


def eemarq_rw_matrix(
    *,
    structures: Sequence[str] = ("hash", "tree"),
    schemes: Sequence[str] = tuple(SCHEMES),
    mixes: Sequence[OpMix] = EEMARQ_RW_MIXES,
    scan_sizes: Sequence[int] = EEMARQ_RW_SCAN_SIZES,
    txn_sizes: Sequence[int] = EEMARQ_TXN_SIZES,
    txn_ranges: Sequence[int] = EEMARQ_TXN_RANGES,
    point_reads: int = 2,
    zipfs: Sequence[float] = EEMARQ_ZIPFS,
    n_keys: int = 1024,
    num_procs: int = 16,
    ops_per_proc: int = 120,
    seed: int = 7,
    **overrides,
) -> List[WorkloadConfig]:
    """Enumerate the MV-RLU-style read-write transaction matrix (DESIGN.md
    §8-§9): rw mix × scan size × txn size × interval count × key
    distribution × scheme × structure, each txn carrying a multi-interval
    footprint (``txn_ranges`` disjoint scans + ``point_reads`` tracked point
    reads).  Defaults are the full family; ``benchmarks/txn_mix.py`` passes
    tiered subsets (including the high-contention Zipf tier)."""
    cfgs = []
    for ds in structures:
        for mix in mixes:
            for size in scan_sizes:
                for tsize in txn_sizes:
                    for r in txn_ranges:
                        for z in zipfs:
                            for scheme in schemes:
                                kw = ({"batch_size": max(8, num_procs)}
                                      if scheme in ("dlrt", "slrt", "bbf")
                                      else {})
                                cfgs.append(WorkloadConfig(
                                    ds=ds, scheme=scheme, n_keys=n_keys,
                                    num_procs=num_procs, mode="mixed",
                                    op_mix=replace(
                                        mix, scan_size=size, txn_size=tsize,
                                        txn_ranges=r,
                                        txn_point_reads=point_reads),
                                    ops_per_proc=ops_per_proc, zipf=z,
                                    seed=seed, scheme_kwargs=kw, **overrides,
                                ))
    return cfgs


# ---------------------------------------------------------------------------
# Process scripts (generators; one yield per slice)
# ---------------------------------------------------------------------------
def _do_update(pid, ds, env, scheme, sampler, rng, counters, log=None):
    ctx = scheme.begin_update(pid)
    env.advance_ts()
    k = sampler()
    if rng.random() < 0.5:
        v = rng.randrange(1 << 30)
        ds.insert(pid, k, v)
    else:
        ds.delete(pid, k)
        v = None
    if log is not None:
        # updates are slice-atomic and stamp versions with the post-advance
        # global timestamp, so (read_ts, k, v) is the committed linearization
        log.record(env.read_ts(), k, v)
    scheme.end_update(pid, ctx)
    counters["updates"] += 1


def _scan_slices(pid, ds, env, scheme, rng, size, key_range, chunk, counters,
                 validator=None):
    """One rtx executing one range scan of ``size`` keys, sliced every
    ``chunk`` versioned reads.  Sizes above the key range clamp to a
    full-range scan so interval placement stays randomized and
    ``scan_keys`` counts keys that can actually exist."""
    size = min(size, key_range)
    t = scheme.begin_rtx(pid)
    a = rng.randrange(1, max(2, key_range - size + 1))
    gen = ds.range_scan(pid, a, a + size, t)
    steps = 0
    while True:
        try:
            next(gen)
        except StopIteration as stop:
            result = stop.value
            break
        steps += 1
        if steps % chunk == 0:
            yield
    scheme.end_rtx(pid)
    counters["scans"] += 1
    counters["scan_keys"] += size
    if validator is not None:
        validator.check(a, a + size, t, result)


def _txn_intervals(rng, ranges: int, size: int,
                   key_range: int) -> List[Tuple[int, int]]:
    """``ranges`` disjoint half-open scan intervals of ~``size`` keys each:
    the key space is cut into ``ranges`` equal segments and one interval is
    placed uniformly inside each segment (clamped to the segment width), so
    intervals never overlap while placement stays randomized."""
    seg = max(2, key_range // ranges)   # degenerate configs: tiny segments
    out = []
    for j in range(ranges):
        lo_bound = 1 + j * seg
        s = min(size, max(1, seg - 1))
        a = lo_bound + rng.randrange(max(1, seg - s))
        out.append((a, a + s))
    return out


def _rwtxn_slices(pid, ds, env, scheme, rng, mix: OpMix, key_range, chunk,
                  counters, cm: ContentionManager, log=None, validator=None,
                  max_retries=16):
    """One MV-RLU-style read-write transaction (DESIGN.md §9), retried with
    a fresh snapshot on abort: scan ``txn_ranges`` disjoint ``scan_size``
    intervals at the begin timestamp, perform ``txn_point_reads`` tracked
    version-wise point reads, buffer ``txn_size`` writes spread across the
    scanned intervals, then commit everything at one validated commit
    timestamp.  The snapshot pin survives into the write phase; commit is
    slice-atomic like updates.  Aborts are classified (``capacity`` /
    ``wcc`` / ``footprint``), recorded in the contention manager's per-key
    stats, and followed by a bounded-exponential backoff whose length the
    manager chooses — so retry storms thin out instead of convoying, while
    every retry's full multi-interval re-scan stretches pin lifetimes.  A
    ``capacity`` abort additionally runs the abort ⇒ reclaim ⇒ retry loop
    (DESIGN.md §10): the scheme synchronously reclaims obsolete versions,
    the freed versions refund the budget, and this process stalls for the
    reclaim's latency slices before its backoff — so the retry commits
    against a refilled budget instead of burning the whole ladder."""
    size = min(mix.scan_size, max(1, key_range // max(1, mix.txn_ranges) - 1))
    for attempt in range(max_retries):
        txn = Txn(pid, ds, env, scheme, log=log, cm=cm)
        intervals = _txn_intervals(rng, mix.txn_ranges, size, key_range)
        for a, b in intervals:
            gen = txn.range_scan(a, b)
            steps = 0
            while True:
                try:
                    next(gen)
                except StopIteration:
                    break
                steps += 1
                if steps % chunk == 0:
                    yield
        for _ in range(mix.txn_point_reads):
            txn.get(rng.randrange(1, key_range + 1))
            yield  # one traversal per tracked point read
        # update-in-scan: writes spread across the scanned intervals
        for i in range(mix.txn_size):
            a, b = intervals[i % len(intervals)]
            k = rng.randrange(a, b)
            if rng.random() < 0.5:
                txn.put(k, rng.randrange(1 << 30))
            else:
                txn.delete(k)
        yield  # slice boundary between read phase and the atomic commit
        committed = txn.try_commit()
        if validator is not None:
            validator.check_txn(txn)
        counters["txn_scan_keys"] += sum(b - a for a, b in intervals)
        if committed:
            counters["txn_commits"] += 1
            cm.record_commit(pid)
            return
        counters["txn_aborts"] += 1
        counters[f"txn_aborts_{txn.abort_reason}"] += 1
        cm.record_conflict(pid, txn.abort_reason, txn.conflict_keys,
                           env.read_ts())
        if txn.reclaim_stall_slices:
            # abort => reclaim => retry (DESIGN.md §10): the capacity abort
            # already drove the scheme's synchronous reclaim inside
            # try_commit (the contention manager accounts the reclaim
            # counters); serve its latency here — the aborting process
            # stalls for the reclaim's work before backoff even starts —
            # and sample space *post-reclaim* (the bounded-space signal).
            post = measure_space(ds, scheme)["words"]
            if post > counters["peak_space_post_reclaim"]:
                counters["peak_space_post_reclaim"] = post
            for _ in range(txn.reclaim_stall_slices):
                yield
        if attempt + 1 < max_retries:
            # backoff only precedes an actual retry — the final abort falls
            # straight through to the give-up, so backoff_slices measures
            # exactly the slices spent between attempts
            for _ in range(cm.backoff_slices(pid)):
                yield
    counters["txn_giveups"] += 1


def update_script(pid, ds, env, scheme, sampler, rng, n_ops, counters,
                  log=None) -> Generator:
    for _ in range(n_ops):
        _do_update(pid, ds, env, scheme, sampler, rng, counters, log)
        yield


def scan_script(
    pid, ds, env, scheme, rng, n_ops, size_fn, key_range, chunk, counters,
    validator=None
) -> Generator:
    for _ in range(n_ops):
        yield from _scan_slices(
            pid, ds, env, scheme, rng, size_fn(), key_range, chunk, counters,
            validator
        )
        yield


def mixed_script(
    pid, ds, env, scheme, sampler, rng, cfg: WorkloadConfig, key_range,
    counters, log=None, validator=None, cm: Optional[ContentionManager] = None
) -> Generator:
    mix = cfg.resolved_mix()
    for _ in range(cfg.ops_per_proc):
        r = rng.random()
        if r < mix.update_frac:
            _do_update(pid, ds, env, scheme, sampler, rng, counters, log)
            yield
        elif r < mix.update_frac + mix.lookup_frac:
            ds.lookup(pid, sampler())
            counters["lookups"] += 1
            yield
        elif (mix.rwtxn_frac > 0
              and r >= mix.update_frac + mix.lookup_frac + mix.scan_frac):
            yield from _rwtxn_slices(
                pid, ds, env, scheme, rng, mix, key_range, cfg.scan_chunk,
                counters, cm, log, validator, max_retries=cfg.max_retries,
            )
            yield
        else:
            yield from _scan_slices(
                pid, ds, env, scheme, rng, mix.scan_size, key_range,
                cfg.scan_chunk, counters, validator,
            )
            yield


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------
def run_workload(cfg: WorkloadConfig) -> Dict[str, Any]:
    env = MVEnv(cfg.num_procs)
    scheme = make_scheme(cfg.scheme, env, **cfg.scheme_kwargs)
    rng = random.Random(cfg.seed)
    key_range = 2 * cfg.n_keys
    sampler = KeySampler(key_range, cfg.zipf, cfg.seed + 1)
    log = UpdateLog() if cfg.validate_scans else None
    validator = ScanValidator(log) if cfg.validate_scans else None

    mix = cfg.resolved_mix()
    cm: Optional[ContentionManager] = None
    if cfg.mode == "mixed" and mix.rwtxn_frac > 0:
        cm = ContentionManager(
            cfg.num_procs, backoff_base=cfg.backoff_base,
            backoff_cap=cfg.backoff_cap, capacity=cfg.txn_capacity,
            refill_every=cfg.txn_refill_every,
        )
        scheme.set_contention(cm)

    ds = MVHashTable(env, scheme, cfg.n_keys) if cfg.ds == "hash" else MVTree(env, scheme)
    # targeted-compaction entry point for the reclamation feedback loop
    # (DESIGN.md §10): hot-set-aware schemes compact the lists governing
    # the contention manager's most-conflicted keys first
    scheme.set_key_resolver(ds.version_lists_for)
    # prefill to ~n_keys live keys
    prefill = rng.sample(range(1, key_range + 1), cfg.n_keys)
    for k in prefill:
        env.advance_ts()
        ds.insert(0, k, k)
        if log is not None:
            log.record(env.read_ts(), k, k)
    scheme.quiesce()
    base_work = _total_work(scheme)
    counters: Dict[str, int] = {"updates": 0, "scans": 0, "scan_keys": 0,
                                "lookups": 0, "txn_commits": 0,
                                "txn_aborts": 0, "txn_giveups": 0,
                                "txn_scan_keys": 0,
                                "txn_aborts_footprint": 0,
                                "txn_aborts_wcc": 0,
                                "txn_aborts_capacity": 0,
                                # max-tracked gauge: space sampled right
                                # after each reclaim pass (DESIGN.md §10);
                                # the reclaim *counts* live in the
                                # contention manager's stats
                                "peak_space_post_reclaim": 0}

    scripts: List[Generator] = []
    if cfg.mode == "split":
        per = cfg.num_procs // 3
        vmax = cfg.variable_scan_max or cfg.n_keys
        for pid in range(per):  # update threads
            scripts.append(
                update_script(pid, ds, env, scheme, sampler, rng,
                              cfg.ops_per_proc, counters, log)
            )
        for pid in range(per, 2 * per):  # fixed-size scan threads
            scripts.append(
                scan_script(pid, ds, env, scheme, rng,
                            max(1, cfg.ops_per_proc // 4),
                            lambda: cfg.scan_size, key_range, cfg.scan_chunk,
                            counters, validator)
            )
        sizes = [max(1, vmax >> i) for i in range(per)] or [vmax]
        for j, pid in enumerate(range(2 * per, cfg.num_procs)):  # variable-size
            size = sizes[j % len(sizes)]
            scripts.append(
                scan_script(pid, ds, env, scheme, rng,
                            max(1, cfg.ops_per_proc // 8),
                            lambda s=size: s, key_range, cfg.scan_chunk,
                            counters, validator)
            )
    else:
        for pid in range(cfg.num_procs):
            scripts.append(
                mixed_script(pid, ds, env, scheme, sampler, rng, cfg,
                             key_range, counters, log, validator, cm)
            )

    # round-robin at slice granularity
    live = list(scripts)
    slices = 0
    peak = {"words": 0}
    space_samples: List[int] = []
    while live:
        nxt = []
        for g in live:
            try:
                next(g)
                nxt.append(g)
            except StopIteration:
                pass
            slices += 1
            if slices % cfg.sample_every == 0:
                s = measure_space(ds, scheme)
                space_samples.append(s["words"])
                if s["words"] > peak["words"]:
                    peak = s
        live = nxt

    end_space_pre_quiesce = measure_space(ds, scheme)
    space_samples.append(end_space_pre_quiesce["words"])
    if end_space_pre_quiesce["words"] > peak["words"]:
        peak = end_space_pre_quiesce
    scheme.quiesce()
    end_space = measure_space(ds, scheme)
    total_work = _total_work(scheme) - base_work

    return {
        "config": cfg,
        "counters": dict(counters),
        "total_work": total_work,
        "updates_per_mwork": counters["updates"] * 1e6 / max(1, total_work),
        "scan_keys_per_mwork": counters["scan_keys"] * 1e6 / max(1, total_work),
        "ops_per_mwork": (counters["updates"] + counters["scans"]
                          + counters["lookups"] + counters["txn_commits"])
        * 1e6 / max(1, total_work),
        "peak_space": peak,
        "avg_space": sum(space_samples) / max(1, len(space_samples)),
        "end_space": end_space,
        "end_space_pre_quiesce": end_space_pre_quiesce,
        "scheme_stats": scheme.stats(),
        "contention_stats": cm.stats() if cm is not None else {},
        "cm_commits_by_pid": list(cm.commits_by_pid) if cm is not None else None,
        "scans_validated": validator.checked if validator else 0,
        "scan_violations": validator.violations if validator else 0,
        "txns_validated": validator.txns_checked if validator else 0,
        "txn_violations": validator.txn_violations if validator else 0,
        "violation_examples": validator.examples if validator else [],
    }


def _total_work(scheme: SchemeBase) -> int:
    return scheme.work + sum(l.work for l in scheme.lists)
