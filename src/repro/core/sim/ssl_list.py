"""SSL — the paper's Simple Singly-linked List with compaction (Algorithm 3).

Faithful transcription including the ``scanAnnounce`` / ``GlobalAnnScan``
protocol that makes concurrently-taken ``(A, t)`` snapshots mutually
consistent (paper §5, Lemma 11), and the ``needed(A, t)`` predicate used by
``compact``:

    a node x is needed(A, t) iff
      (1) x.ts > t, or
      (2) x is the last appended node with timestamp <= t, or
      (3) for some A[i], x is the last appended node with ts <= A[i].

Stepped generator forms (one shared access per yield) drive the
linearizability / Proposition 17 tests; direct forms drive the scheme-level
benchmarks with work accounting.
"""
from __future__ import annotations

import math
from typing import Generator, List, Optional

from repro.core.sim.machine import cas

NEG_INF = -math.inf


class SNode:
    __slots__ = ("ts", "val", "left", "order")

    def __init__(self, ts, val):
        self.ts = ts
        self.val = val
        self.left: Optional["SNode"] = None
        self.order = -1  # append rank (instrumentation only)

    def __repr__(self):
        return f"SNode(ts={self.ts}, order={self.order})"


class AnnScan:
    __slots__ = ("A", "t")

    def __init__(self, A: List[float], t: float):
        self.A = A  # sorted announcement snapshot
        self.t = t  # global timestamp read *before* A was collected


class MVEnv:
    """Shared multiversioning environment: global timestamp, announcement
    array, and the GlobalAnnScan variable of Algorithm 3."""

    def __init__(self, num_procs: int):
        self.P = num_procs
        self.global_ts: int = 0
        self.announce: List[Optional[float]] = [None] * num_procs
        self.global_ann_scan = AnnScan([], -1)

    # -- timestamp management (paper §6.1 backoff counter, simplified) ----
    def advance_ts(self) -> int:
        self.global_ts += 1
        return self.global_ts

    def read_ts(self) -> int:
        return self.global_ts

    # -- rtx announcement (appendix B.2 lock-free scheme, direct form) ----
    def announce_ts(self, pid: int) -> int:
        while True:
            t = self.global_ts                     # A1
            self.announce[pid] = t                 # A2
            if self.global_ts == t:                # A3 (validate)
                return t

    def unannounce(self, pid: int) -> None:
        self.announce[pid] = None

    # -- scanAnnounce, direct form (lines 3-10) ----------------------------
    def scan_announce(self) -> AnnScan:
        for _ in range(2):                         # line 5: repeat twice
            old = self.global_ann_scan             # line 6
            t = self.global_ts                     # line 7
            A = sorted(a for a in self.announce if a is not None)  # line 8
            new = AnnScan(A, t)
            if cas(self, "global_ann_scan", old, new):  # line 9
                return new
        return self.global_ann_scan                # line 10

    # -- scanAnnounce, stepped form ----------------------------------------
    def scan_announce_steps(self) -> Generator:
        for _ in range(2):
            old = self.global_ann_scan             # line 6
            yield
            t = self.global_ts                     # line 7
            yield
            vals = []
            for i in range(self.P):                # line 8: one read per step
                vals.append(self.announce[i])
                yield
            new = AnnScan(sorted(v for v in vals if v is not None), t)
            ok = cas(self, "global_ann_scan", old, new)  # line 9
            yield
            if ok:
                return new
        scan = self.global_ann_scan                # line 10
        yield
        return scan


class SSL:
    """Singly-linked version list with wait-free compact (Algorithm 3)."""

    def __init__(self):
        self.sentinel = SNode(NEG_INF, None)
        self.sentinel.order = 0
        self.head: SNode = self.sentinel
        self.added: List[SNode] = [self.sentinel]
        self.appends = 0
        self.work = 0

    def _record_add(self, y: SNode) -> None:
        y.order = len(self.added)
        self.added.append(y)
        self.appends += 1

    # ------------------------------------------------------------------
    # Stepped forms.
    # ------------------------------------------------------------------
    def tryAppend_steps(self, x: SNode, y: SNode) -> Generator:
        y.left = x                                  # line 33 (y private)
        yield
        ok = cas(self, "head", x, y)                # line 34
        if ok:
            self._record_add(y)
        yield
        return ok

    def readHead_steps(self) -> Generator:
        h = self.head
        yield
        return h

    def search_steps(self, k) -> Generator:
        x = self.head                               # line 36
        yield
        while x.ts > k:                             # line 37 (ts immutable)
            x = x.left                              # line 38
            yield
        return x.val                                # line 39

    def compact_steps(self, A: List[float], t: float, h: SNode) -> Generator:
        """Lines 11-31.  ``A`` must be sorted ascending; ``h`` read from head
        together with (A, t) per the snapshot discipline of §5."""
        A = [-1.0] + list(A)                        # line 12: padding
        i = len(A) - 1                              # line 13
        cur = h                                     # line 14
        while cur is not self.sentinel:             # line 15
            nxt = cur.left                          # line 16
            yield
            if cur.ts > t:                          # line 18
                cur = nxt                           # line 19
            else:
                while A[i] >= cur.ts:               # line 21
                    i -= 1
                if A[i] >= nxt.ts:                  # line 22: next is needed
                    cur = nxt                       # line 23
                else:                               # line 24: next not needed
                    newNext = nxt.left              # line 25
                    yield
                    while A[i] < newNext.ts:        # line 26
                        newNext = newNext.left      # line 27
                        yield
                    while True:                     # line 28
                        ok = cas(cur, "left", nxt, newNext)
                        yield
                        if ok:
                            break
                        nxt = cur.left              # line 29
                        yield
                        if nxt.ts <= newNext.ts:    # line 30
                            break
                    cur = cur.left                  # line 31
                    yield
        return None

    # ------------------------------------------------------------------
    # Direct forms (atomic per call, work-accounted).
    # ------------------------------------------------------------------
    def peek_head(self) -> SNode:
        self.work += 1
        return self.head

    def try_append(self, x: SNode, y: SNode) -> bool:
        self.work += 2
        y.left = x
        if cas(self, "head", x, y):
            self._record_add(y)
            return True
        return False

    def search(self, k):
        return self.search_node(k).val

    def search_node(self, k) -> SNode:
        x = self.head
        self.work += 1
        while x.ts > k:
            x = x.left
            self.work += 1
        return x

    def compact(self, A: List[float], t: float, h: SNode) -> int:
        """Direct single-threaded compact.  Returns #nodes spliced out."""
        A = [-1.0] + list(A)
        i = len(A) - 1
        cur = h
        spliced = 0
        self.work += 1
        while cur is not self.sentinel:
            nxt = cur.left
            self.work += 1
            if cur.ts > t:
                cur = nxt
            else:
                while A[i] >= cur.ts:
                    i -= 1
                    self.work += 1
                if A[i] >= nxt.ts:
                    cur = nxt
                else:
                    newNext = nxt.left
                    self.work += 1
                    while A[i] < newNext.ts:
                        newNext = newNext.left
                        self.work += 1
                    # count reachable nodes being spliced: hops nxt -> newNext
                    n = nxt
                    while n is not newNext:
                        spliced += 1
                        n = n.left
                    cur.left = newNext
                    self.work += 1
                    cur = cur.left
        return spliced

    # ------------------------------------------------------------------
    # Instrumentation.
    # ------------------------------------------------------------------
    def abstract_list(self) -> List[SNode]:
        out = []
        x = self.head
        seen = set()
        while x is not None:
            assert id(x) not in seen, "cycle in left pointers!"
            seen.add(id(x))
            out.append(x)
            x = x.left
        return list(reversed(out))

    def reachable_count(self) -> int:
        return len(self.abstract_list()) - 1  # excl. sentinel

    def reachable_nodes(self) -> List[SNode]:
        return [n for n in self.abstract_list() if n is not self.sentinel]

    def needed(self, x: SNode, A: List[float], t: float) -> bool:
        """Reference needed(A, t) predicate over the *full appended history*."""
        if x.ts > t:
            return True
        if self._is_last_leq(x, t):
            return True
        return any(self._is_last_leq(x, a) for a in A)

    def _is_last_leq(self, x: SNode, bound: float) -> bool:
        if x.ts > bound:
            return False
        for y in self.added[x.order + 1 :]:
            if y.ts <= bound:
                return False
        return True

    def check_sorted(self) -> None:
        al = self.abstract_list()
        assert al[0] is self.sentinel
        for a, b in zip(al, al[1:]):
            assert a.order < b.order and a.ts <= b.ts
