"""PDL — the paper's Practical Doubly-linked List (Algorithm 1), faithful.

Two execution forms are provided:

* **stepped** generators (``tryAppend_steps`` etc.) for the step-machine
  scheduler: exactly one shared-memory access per ``yield``, transcribing the
  pseudocode line-by-line.  Used by linearizability / invariant tests.
* **direct** methods (``try_append`` etc.) that execute the same logic
  atomically per call.  Used by the scheme-level benchmarks where operations
  are interleaved at operation granularity by the discrete-event workload
  driver; they additionally *account work* (number of shared accesses the
  lock-free algorithm would perform) so throughput proxies stay faithful.

Interface (paper §3): ``tryAppend(x, y)``, ``remove(x)``, ``peekHead()``,
``search(key)``.  Preconditions (paper §4.1): ``y`` fresh; ``x`` read from
``head``; keys nondecreasing; at most one ``remove`` per node, never on the
sentinel, and only after ``tryAppend(x, *)`` returned true.
"""
from __future__ import annotations

import math
from typing import Generator, List, Optional

from repro.core.sim.machine import cas


class Node:
    __slots__ = ("key", "val", "mark", "left", "right", "order", "_removed")

    def __init__(self, key, val):
        self.key = key
        self.val = val
        self.mark = False          # line 2: initially false
        self.left: Optional[Node] = None
        self.right: Optional[Node] = None
        self.order = -1            # append rank; bookkeeping for invariants only
        self._removed = False      # bookkeeping: remove() invoked

    def __repr__(self):
        return f"Node(key={self.key}, order={self.order})"

    @property
    def ts(self):
        """Version lists use the timestamp as the sort key (paper §3)."""
        return self.key


class PDL:
    """Doubly linked list; head points at the rightmost (newest) node."""

    def __init__(self):
        self.sentinel = Node(-math.inf, None)
        self.sentinel.order = 0
        self.head: Node = self.sentinel
        # bookkeeping (not part of the algorithm): append order tracking for
        # invariant checks and space accounting.
        self.added: List[Node] = [self.sentinel]
        self.appends = 0
        self.removes_completed = 0
        self.work = 0              # shared-access count for direct ops
        self.remove_chain_total = 0   # sum of observed chain lengths c
        self.remove_chain_max = 0

    # ------------------------------------------------------------------
    # bookkeeping helper: called at the linearization point of an append
    def _record_add(self, y: Node) -> None:
        y.order = len(self.added)
        self.added.append(y)
        self.appends += 1

    # ------------------------------------------------------------------
    # Stepped (generator) forms — one shared access per yield.
    # ------------------------------------------------------------------
    def peekHead_steps(self) -> Generator:
        h = self.head                                   # line 6 (read head)
        yield
        return h.val

    def readHead_steps(self) -> Generator:
        """Atomic read of head returning the node (driver helper for vCAS use)."""
        h = self.head
        yield
        return h

    def search_steps(self, k) -> Generator:
        x = self.head                                   # line 8
        yield
        while x.key > k:                                # line 9 (key immutable)
            x = x.left                                  # line 10
            yield
        return x.val                                    # line 11

    def tryAppend_steps(self, x: Node, y: Node) -> Generator:
        w = x.left                                      # line 13
        yield
        if w is not None:                               # line 15: help tryAppend(w, x)
            cas(w, "right", None, x)
            yield
        y.left = x                                      # line 16 (y is private until line 17)
        yield
        ok = cas(self, "head", x, y)                    # line 17
        if ok:
            self._record_add(y)
        yield
        if ok:
            cas(x, "right", None, y)                    # line 18
            yield
            return True                                 # line 19
        return False                                    # line 20

    def remove_steps(self, x: Node) -> Generator:
        x._removed = True
        x.mark = True                                   # line 22 (plain write)
        yield
        left = x.left                                   # line 23
        yield
        right = x.right                                 # line 24
        yield
        chain = 0
        while True:                                     # line 26
            while True:                                 # line 27: while(left->marked)
                m = left.mark
                yield
                if not m:
                    break
                left = left.left
                chain += 1
                yield
            while True:                                 # line 28: while(right->marked)
                m = right.mark
                yield
                if not m:
                    break
                right = right.right
                chain += 1
                yield
            rightLeft = right.left                      # line 29
            yield
            leftRight = left.right                      # line 30
            yield
            m1 = left.mark                              # line 31 (two reads)
            yield
            m2 = right.mark
            yield
            if m1 or m2:
                continue
            ok = cas(right, "left", rightLeft, left)    # line 32
            yield
            if not ok:
                continue
            ok = cas(left, "right", leftRight, right)   # line 33
            yield
            if not ok:
                continue
            break                                       # line 34
        self.removes_completed += 1
        self.remove_chain_total += max(1, chain)
        self.remove_chain_max = max(self.remove_chain_max, max(1, chain))
        return None

    # ------------------------------------------------------------------
    # Direct forms (atomic per call, with work accounting).
    # ------------------------------------------------------------------
    def peek_head(self) -> Node:
        self.work += 1
        return self.head

    def search(self, k):
        return self.search_node(k).val

    def search_node(self, k) -> Node:
        x = self.head
        self.work += 1
        while x.key > k:
            x = x.left
            self.work += 1
        return x

    def try_append(self, x: Node, y: Node) -> bool:
        self.work += 3
        if x.left is not None:
            cas(x.left, "right", None, x)
        y.left = x
        if cas(self, "head", x, y):
            self._record_add(y)
            cas(x, "right", None, y)
            self.work += 2
            return True
        return False

    def remove(self, x: Node) -> None:
        """Direct remove; in atomic-per-call mode the CAS'es always succeed,
        but we still walk past marked neighbours (concurrent removes that
        were interleaved at operation granularity)."""
        x._removed = True
        x.mark = True
        self.work += 3
        left = x.left
        right = x.right
        chain = 0
        while left.mark:
            left = left.left
            chain += 1
            self.work += 1
        while right.mark:
            right = right.right
            chain += 1
            self.work += 1
        right.left = left
        left.right = right
        self.work += 2
        self.removes_completed += 1
        self.remove_chain_total += max(1, chain)
        self.remove_chain_max = max(self.remove_chain_max, max(1, chain))

    # ------------------------------------------------------------------
    # Abstract list & invariants (test instrumentation, not the algorithm).
    # ------------------------------------------------------------------
    def abstract_list(self) -> List[Node]:
        """AL = nodes reachable from head via left pointers, oldest first."""
        out = []
        x = self.head
        seen = set()
        while x is not None:
            assert id(x) not in seen, "cycle in left pointers!"
            seen.add(id(x))
            out.append(x)
            x = x.left
        return list(reversed(out))

    def reachable_nodes(self) -> List[Node]:
        """Non-sentinel nodes reachable via access pointers (left+right) from
        head — the paper's reachability notion for the space bounds."""
        seen = {}
        stack = [self.head]
        while stack:
            n = stack.pop()
            if n is None or id(n) in seen:
                continue
            seen[id(n)] = n
            stack.append(n.left)
            stack.append(n.right)
        return [n for n in seen.values() if n is not self.sentinel]

    def reachable_count(self) -> int:
        return len(self.reachable_nodes())

    def check_invariant2(self) -> None:
        """Paper Invariant 2 (parts 1, 2, 4) at the current configuration."""
        order = {id(n): n.order for n in self.added}
        for y in self.added:
            if y is self.sentinel:
                assert y.left is None, "Invariant 2.4 violated: sentinel.left != null"
                continue
            if y.order < 0:
                continue  # not yet added
            lf = y.left
            assert lf is not None and lf.order >= 0, "2.1: left not an added node"
            assert lf.order < y.order, "2.1: y.left must be older than y"
            for w in self.added[lf.order + 1 : y.order]:
                assert w.mark, f"2.1: skipped node {w} not marked"
            rt = y.right
            if rt is not None:
                assert rt.order >= 0 and rt.order > y.order, "2.2: right must be newer"
                for w in self.added[y.order + 1 : rt.order]:
                    assert w.mark, f"2.2: skipped node {w} not marked"

    def check_al_sorted(self) -> None:
        al = self.abstract_list()
        assert al[0] is self.sentinel, "sentinel must stay at the left end"
        for a, b in zip(al, al[1:]):
            assert a.order < b.order, "AL must be ordered by append rank"
            assert a.key <= b.key, "AL must be sorted by key"

    def avg_remove_chain(self) -> float:
        if self.removes_completed == 0:
            return 1.0
        return self.remove_chain_total / self.removes_completed
