"""Contention manager for the read-write transaction family (DESIGN.md §9).

MV-RLU (Kim et al.) and EEMARQ both pair optimistic multiversion
transactions with a *contention manager*: aborted transactions back off
before retrying (bounded exponential, so storms thin out instead of
convoying), and the system tracks which objects conflict so both the
workload and the reclamation layer can react.  Under an abort/retry storm
each retry re-executes its full multi-interval read phase at a fresh
snapshot, so pins live longer and version lists grow — exactly the
worst-case-space regime of "Space and Time Bounded Multiversion Garbage
Collection" (Ben-David et al.; ``PAPERS.md``).  :class:`ContentionManager`
makes that regime first-class in the sim:

* **per-key conflict stats** — every abort records the keys implicated
  (write-set keys for ``wcc``, footprint keys for ``footprint``), so hot-key
  storms are observable (``hot_keys``) and the aggregate conflict recency is
  available as a 0..1 ``pressure`` signal.
* **bounded exponential backoff** — ``backoff_slices(pid)`` grows
  ``base * 2^retries`` up to ``cap`` slices, with a deterministic per-pid
  jitter so colliding processes desynchronize.  Because the backoff (not the
  retry count) is what's bounded, every transaction gets its full retry
  budget — the fairness property ``tests/sim/test_contention.py`` checks.
* **a version-budget capacity gate** — an optional token bucket modelling
  the bounded version-log of MV-RLU: commits consume one token per buffered
  write, the bucket refills with global-timestamp progress (the stand-in for
  background reclamation).  When the bucket runs dry the commit aborts with
  reason ``capacity`` — the abort class that only appears when GC cannot
  keep up with the write rate, i.e. the paper's bounded-space story told
  from the transaction side.  ``capacity=None`` (the default) disables the
  gate so read-mostly workloads are unaffected.
* **a GC pressure signal schemes consult** — ``pressure()`` decays with
  timestamp progress since the last conflict.  ``EBRScheme`` and
  ``SteamLFScheme`` (``schemes.py``) shorten their epoch-advance /
  announce-scan-refresh intervals while pressure is high: under a storm,
  pins churn quickly, so a stale announcement scan retains garbage for
  longer than it should — consulting the manager models the adaptive GC
  cadence both papers describe.
* **the abort ⇒ reclaim ⇒ retry loop** (DESIGN.md §10) — a capacity abort
  means reclamation fell behind the write rate, so merely backing off and
  retrying would fail again against the same drained budget.  Instead the
  aborting transaction builds a :class:`ReclaimRequest` (``reclaim_request``)
  — the budget *deficit* to make up (enough tokens to refill the bucket) plus
  the current **hot set**, the top-k keys by *decayed* conflict score
  (``hot_set``; recent conflicts dominate, old ones fade with timestamp
  progress) — and hands it to the scheme's
  ``SchemeBase.reclaim_on_pressure`` hook, which synchronously reclaims
  obsolete versions.  The versions actually freed are refunded to the token
  bucket (``record_reclaim`` → ``refund``), so the retry's commit finds a
  refilled budget: MV-RLU's synchronous "abort ⇒ reclaim ⇒ retry" cycle,
  and the mechanism that turns capacity aborts from a throttle into the
  space-*bounding* feedback loop of the source paper.

Abort taxonomy ordering (``ABORT_REASONS``, checked in exactly this order by
``Txn.try_commit``): ``wcc`` is the eager first-updater-wins check on the
write set, ``footprint`` is full validation, and ``capacity`` gates the
final apply — charged only for versions actually about to be installed, so
doomed transactions never drain the budget.

Backoff ladder semantics: ``backoff_slices(pid)`` is bounded exponential in
the pid's consecutive-abort count (``base * 2^retries``, capped at
``backoff_cap`` slices) with a deterministic per-(pid, retry) jitter; a
commit resets the ladder.  Because the *backoff* (not the retry count) is
bounded, every transaction keeps its full retry budget — the fairness
property ``tests/sim/test_contention.py`` checks.
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

from repro.core.telemetry import PressureSignal

# Abort reasons, in check order (wcc is the eager first-updater-wins check
# on the write set, footprint is full validation, capacity gates the final
# apply — charged only for versions actually about to be installed, so
# doomed txns never drain the budget).
ABORT_REASONS = ("wcc", "footprint", "capacity")


@dataclass(frozen=True)
class ReclaimRequest:
    """What a capacity-aborting transaction asks its scheme to reclaim
    (DESIGN.md §10).

    ``deficit`` is the number of obsolete versions the scheme should try to
    splice out — sized to *refill* the version budget (``capacity -
    budget``), not merely to cover the aborted write set, so one reclaim
    pays for a whole burst of retries.  ``hot_keys`` is the contention
    manager's current decayed hot set, most-conflicted first: schemes with
    targeted compaction (STEAM, SL-RT) compact the version lists governing
    these keys before touching cold lists, because hot keys are where the
    abort/retry storm is allocating versions fastest.
    """

    deficit: int
    hot_keys: List[int] = field(default_factory=list)
    now: float = 0.0


class ContentionManager:
    """Per-workload conflict statistics + bounded-exponential backoff.

    One instance is shared by every process of a workload run (the driver
    threads it through ``_rwtxn_slices`` and hands it to the scheme via
    ``SchemeBase.set_contention``).  All state is deterministic — jitter is
    derived from (pid, retry count), never from a shared RNG — so workload
    runs stay reproducible slice-for-slice.
    """

    def __init__(self, num_procs: int, *, backoff_base: int = 1,
                 backoff_cap: int = 64, capacity: Optional[int] = None,
                 refill_every: int = 4, pressure_window: int = 256,
                 hot_half_life: int = 128):
        if backoff_base < 1 or backoff_cap < backoff_base:
            raise ValueError("need 1 <= backoff_base <= backoff_cap")
        self.P = num_procs
        self.backoff_base = backoff_base
        self.backoff_cap = backoff_cap
        self.key_conflicts: Counter = Counter()
        self.reason_counts: Counter = Counter()
        self.retries: List[int] = [0] * num_procs
        self.commits_by_pid: List[int] = [0] * num_procs
        self.max_retries_seen = 0
        self.backoff_slices_total = 0
        self.conflicts = 0
        self.commits = 0
        # capacity gate (token bucket in "versions"; None = unbounded)
        self.capacity = capacity
        self.budget = capacity if capacity is not None else 0
        self.refill_every = max(1, refill_every)
        self._last_refill_ts = 0.0
        # pressure: decays with timestamp progress since the last conflict
        self.pressure_window = max(1, pressure_window)
        self._last_conflict_ts = float("-inf")
        # decayed per-key conflict heat: key -> (score, last-bump ts).  The
        # score halves every hot_half_life timestamp ticks, so the hot set
        # tracks where the storm is *now*, not its whole history.
        self.hot_half_life = max(1, hot_half_life)
        self._key_heat: Dict[int, Tuple[float, float]] = {}
        # abort => reclaim => retry accounting (DESIGN.md §10)
        self.reclaims_triggered = 0
        self.versions_reclaimed = 0
        self.reclaim_latency_slices = 0

    # -- conflict recording -------------------------------------------------
    def record_conflict(self, pid: int, reason: str,
                        keys: Iterable[int] = (), now: float = 0.0) -> None:
        """One aborted commit attempt: bump the per-key stats and the pid's
        retry counter (which drives its next backoff)."""
        if reason not in ABORT_REASONS:
            raise ValueError(f"unknown abort reason {reason!r}")
        self.conflicts += 1
        self.reason_counts[reason] += 1
        self.retries[pid] += 1
        self.max_retries_seen = max(self.max_retries_seen, self.retries[pid])
        self._last_conflict_ts = max(self._last_conflict_ts, now)
        for k in keys:
            self.key_conflicts[k] += 1
            score, last = self._key_heat.get(k, (0.0, now))
            self._key_heat[k] = (self._decay(score, last, now) + 1.0, now)

    def record_commit(self, pid: int) -> None:
        """A successful commit resets the pid's exponential-backoff ladder."""
        self.commits += 1
        self.commits_by_pid[pid] += 1
        self.retries[pid] = 0

    # -- backoff -------------------------------------------------------------
    def backoff_slices(self, pid: int) -> int:
        """Slices to wait before this pid's next attempt: bounded exponential
        in its consecutive-abort count, plus a deterministic per-(pid, retry)
        jitter in [0, base] so colliding processes desynchronize."""
        r = self.retries[pid]
        if r <= 0:
            return 0
        raw = self.backoff_base << min(r - 1, 16)
        jitter = (pid * 2654435761 + r * 40503) % (self.backoff_base + 1)
        slices = min(self.backoff_cap, raw + jitter)
        self.backoff_slices_total += slices
        return slices

    # -- capacity gate (MV-RLU log model) ------------------------------------
    def try_consume(self, n_versions: int, now: float) -> bool:
        """Commit-time version-budget check: ``n_versions`` new versions are
        about to be installed.  Refills ``1`` token per ``refill_every``
        timestamp ticks (reclamation keeping pace with global progress), then
        consumes.  Returns False — the caller must abort with reason
        ``capacity`` — when the bucket cannot cover the commit."""
        if self.capacity is None:
            return True
        elapsed = now - self._last_refill_ts
        whole = int(elapsed // self.refill_every) if elapsed > 0 else 0
        if whole > 0:
            self.budget = min(self.capacity, self.budget + whole)
            # advance by the whole intervals actually granted, so fractional
            # refill progress carries over to the next call
            self._last_refill_ts += whole * self.refill_every
        if self.budget < n_versions:
            return False
        self.budget -= n_versions
        return True

    # -- abort => reclaim => retry (DESIGN.md §10) ---------------------------
    def refund(self, n_versions: int) -> None:
        """Return ``n_versions`` freed tokens to the budget (capped at
        ``capacity``): reclamation made room in the bounded version log."""
        if self.capacity is not None and n_versions > 0:
            self.budget = min(self.capacity, self.budget + n_versions)

    def deficit(self) -> int:
        """Versions the bucket is short of full — the reclaim target.  A
        capacity abort asks the scheme for this many (at least 1), so one
        synchronous reclaim refills the whole budget rather than barely
        covering the aborted write set."""
        if self.capacity is None:
            return 0
        return max(1, self.capacity - self.budget)

    def reclaim_request(self, now: float, top_k: int = 16) -> ReclaimRequest:
        """Build the :class:`ReclaimRequest` a capacity-aborting txn hands to
        ``SchemeBase.reclaim_on_pressure``: the budget deficit plus the
        current decayed hot set (most-conflicted keys first)."""
        return ReclaimRequest(deficit=self.deficit(),
                              hot_keys=[k for k, _ in self.hot_set(now, top_k)],
                              now=now)

    def record_reclaim(self, versions: int, latency_slices: int) -> None:
        """Account one synchronous reclaim pass: refund the freed versions to
        the budget and accumulate the schema-v4 reclaim counters."""
        self.reclaims_triggered += 1
        self.versions_reclaimed += max(0, versions)
        self.reclaim_latency_slices += max(0, latency_slices)
        self.refund(versions)

    # -- signals for schemes and tests ---------------------------------------
    def pressure_signal(self, now: float) -> PressureSignal:
        """The manager's view in the unified telemetry vocabulary
        (:class:`repro.core.telemetry.PressureSignal`, DESIGN.md §13):
        ``level`` is the 0..1 conflict-recency decay, ``deficit`` / ``live``
        / ``capacity`` come from the version-budget token bucket (all zero
        when the gate is disabled), and ``under_pressure`` is true while the
        bucket is short of full."""
        age = now - self._last_conflict_ts
        level = 1.0 if age < 0 else max(0.0, 1.0 - age / self.pressure_window)
        cap = self.capacity or 0
        short = max(0, cap - self.budget) if self.capacity is not None else 0
        return PressureSignal(
            level=level,
            under_pressure=short > 0,
            deficit=short,
            live=cap - self.budget if self.capacity is not None else 0,
            capacity=cap,
        )

    def pressure(self, now: float) -> float:
        """0..1 conflict-recency signal: 1.0 at the instant of a conflict,
        decaying linearly to 0 over ``pressure_window`` timestamp ticks.
        Deprecated alias for ``pressure_signal(now).level`` — kept (without a
        warning; schemes call it per-slice) for one release."""
        return float(self.pressure_signal(now).level)

    def hot_keys(self, n: int = 8) -> List[Tuple[int, int]]:
        """The ``n`` most-conflicted keys as (key, conflicts) — raw lifetime
        counts; use :meth:`hot_set` for the decayed (recency-weighted) view
        the reclamation loop consumes."""
        return self.key_conflicts.most_common(n)

    def _decay(self, score: float, last: float, now: float) -> float:
        """Halve ``score`` once per ``hot_half_life`` ticks elapsed."""
        age = now - last
        if age <= 0:
            return score
        return score * 0.5 ** (age / self.hot_half_life)

    def hot_set(self, now: float, n: int = 16,
                min_score: float = 0.05) -> List[Tuple[int, float]]:
        """The hot set: up to ``n`` (key, decayed score) pairs, hottest
        first.  Scores halve every ``hot_half_life`` timestamp ticks, so keys
        that stopped conflicting cool off and drop out (below ``min_score``)
        instead of pinning reclamation effort on stale history."""
        scored = [(k, self._decay(s, last, now))
                  for k, (s, last) in self._key_heat.items()]
        scored = [(k, s) for k, s in scored if s >= min_score]
        scored.sort(key=lambda kv: (-kv[1], kv[0]))
        return scored[:n]

    def stats(self) -> Dict[str, float]:
        """Flat counters for ``Measurement``/tests: conflict totals, the
        abort taxonomy, backoff totals, and the reclaim-loop counters."""
        return {
            "conflicts": self.conflicts,
            "commits": self.commits,
            "max_consecutive_aborts": self.max_retries_seen,
            "backoff_slices": self.backoff_slices_total,
            "hot_key_conflicts": (self.key_conflicts.most_common(1)[0][1]
                                  if self.key_conflicts else 0),
            "reclaims_triggered": self.reclaims_triggered,
            "versions_reclaimed_on_abort": self.versions_reclaimed,
            "reclaim_latency_slices": self.reclaim_latency_slices,
            **{f"aborts_{r}": self.reason_counts.get(r, 0)
               for r in ABORT_REASONS},
        }
