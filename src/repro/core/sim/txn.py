"""Read-write transactions over the multiversion structures (DESIGN.md §8).

EEMARQ (Sheffi, Ramalhete, Petrank 2022 — ``PAPERS.md``) extends the
range-scan family this sim already reproduces with *read-write* transactions
whose range scans and updates commit atomically: all of a txn's reads observe
one snapshot and all of its writes become visible at one timestamp.  This is
the regime that stresses MVGC hardest — the txn's snapshot pin must survive
into its own write phase, so every version a scan at the begin timestamp
still needs stays live while the txn itself allocates new versions.

:class:`Txn` implements that model generically over both ``MVTree`` and
``MVHashTable`` (anything exposing ``insert``/``delete``/``rtx_lookup``/
``range_scan``/``range_query``):

* **begin** — ``scheme.begin_txn(pid)`` pins a snapshot at the begin
  timestamp ``tb`` (announce + for EBR the epoch pin; the pin is released
  only by commit/abort, *after* the write phase).
* **read phase** — ``get`` / ``range_scan`` read the ``tb`` snapshot through
  the structures' versioned read paths, overlaid with the txn's own buffered
  writes (read-your-writes).  Scans are the same sliced multi-yield
  operations as read-only rtx scans, so updates interleave inside them.
* **write phase** — ``put`` / ``delete`` buffer into a private write set;
  nothing touches shared state before commit, so an aborted txn leaves no
  versions anywhere.
* **commit** — ``try_commit`` linearizes the whole txn at a single commit
  timestamp ``tc``: it advances the global timestamp once, validates that
  every key in the txn's *footprint* (point reads, scanned intervals,
  buffered writes) still has its ``tb``-snapshot value, and only then applies
  all buffered writes — each stamped ``tc`` — and records them in the shared
  ``UpdateLog``.  On validation failure it aborts (releasing the pin) and the
  caller retries with a fresh snapshot.  A txn with an empty write set is
  read-only and commits validation-free: its snapshot reads linearize at
  ``tb``.

Commit is slice-atomic in the discrete-event driver, mirroring the sim's
slice-atomic updates: validation + apply happen between two scheduler yields,
which models the commit's single linearization point (DESIGN.md §8 records
why this is faithful for the GC dynamics under study).  Validation is
value-level per key (ABA-tolerant: a key overwritten back to its snapshot
value revalidates — the reads are still serializable at ``tc``), and its
reads go through the version lists, so long-footprint txns pay their
validation cost in work units like every other traversal.
"""
from __future__ import annotations

from typing import Any, Dict, Generator, List, Optional, Tuple


class Txn:
    """One read-write transaction.  Lifecycle::

        txn = Txn(pid, ds, env, scheme, log=log)   # pins the snapshot
        gen = txn.range_scan(lo, hi)                # sliced snapshot scan
        ... drive gen, buffer writes via txn.put / txn.delete ...
        if not txn.try_commit():                    # atomic validate+apply
            ...retry with a fresh Txn...

    ``log`` (an ``UpdateLog``) receives the committed writes at the commit
    timestamp so subsequent validated scans hold the txn's writes visible
    exactly at ``tc``; aborted txns never touch it.
    """

    __slots__ = ("pid", "ds", "env", "scheme", "log", "begin_ts", "commit_ts",
                 "writes", "read_footprint", "scan_footprint", "state")

    def __init__(self, pid: int, ds, env, scheme, log=None):
        self.pid = pid
        self.ds = ds
        self.env = env
        self.scheme = scheme
        self.log = log
        self.begin_ts: float = scheme.begin_txn(pid)
        self.commit_ts: Optional[float] = None
        self.writes: Dict[int, Any] = {}          # key -> value (None = delete)
        self.read_footprint: Dict[int, Any] = {}  # key -> tb-snapshot value
        self.scan_footprint: List[Tuple[int, int, List[Tuple[int, Any]]]] = []
        self.state = "active"                     # active | committed | aborted

    # -- read phase ---------------------------------------------------------
    def get(self, k: int) -> Optional[Any]:
        """Snapshot read of one key, overlaid with the txn's own writes."""
        assert self.state == "active"
        if k in self.writes:
            return self.writes[k]
        if k in self.read_footprint:
            return self.read_footprint[k]
        v = self.ds.rtx_lookup(self.pid, k, self.begin_ts)
        self.read_footprint[k] = v
        return v

    def range_scan(self, lo: int, hi: int) -> Generator:
        """Sliced snapshot scan of [lo, hi) at the begin timestamp (one yield
        per versioned read, like the read-only rtx scans); ``return``s the
        sorted [(key, val)] snapshot overlaid with the txn's own writes."""
        assert self.state == "active"
        raw = yield from self.ds.range_scan(self.pid, lo, hi, self.begin_ts)
        self.scan_footprint.append((lo, hi, list(raw)))
        return self._overlay(lo, hi, raw)

    def range_query(self, lo: int, hi: int) -> List[Tuple[int, Any]]:
        """Atomic convenience form of :meth:`range_scan`."""
        gen = self.range_scan(lo, hi)
        while True:
            try:
                next(gen)
            except StopIteration as stop:
                return stop.value

    def _overlay(self, lo: int, hi: int, raw) -> List[Tuple[int, Any]]:
        merged = {k: v for k, v in raw}
        for k, v in self.writes.items():
            if lo <= k < hi:
                if v is None:
                    merged.pop(k, None)
                else:
                    merged[k] = v
        return sorted(merged.items())

    # -- write phase (buffered) ----------------------------------------------
    def put(self, k: int, v: Any) -> None:
        assert self.state == "active" and v is not None
        self.writes[k] = v

    def delete(self, k: int) -> None:
        assert self.state == "active"
        self.writes[k] = None

    # -- commit / abort -------------------------------------------------------
    def try_commit(self) -> bool:
        """Validate + apply atomically; returns False (and aborts) on
        conflict.  The snapshot pin is released either way."""
        assert self.state == "active"
        if not self.writes:
            # read-only: linearizes at begin_ts, no validation needed
            self.commit_ts = self.begin_ts
            self.state = "committed"
            self.scheme.commit_txn(self.pid)
            return True
        tc = self.env.advance_ts()
        if not self._validate():
            self.abort()
            return False
        for k in sorted(self.writes):
            v = self.writes[k]
            if v is None:
                self.ds.delete(self.pid, k)
            else:
                self.ds.insert(self.pid, k, v)
            if self.log is not None:
                self.log.record(tc, k, v)
        self.commit_ts = tc
        self.state = "committed"
        self.scheme.commit_txn(self.pid)
        return True

    def abort(self) -> None:
        """Discard buffered writes and release the snapshot pin."""
        if self.state == "active":
            self.state = "aborted"
            self.scheme.abort_txn(self.pid)

    def _validate(self) -> bool:
        """Footprint validation at the commit timestamp: every key the txn
        read or is about to write must still hold its begin-ts snapshot
        value.  Reads go through the current version-list heads (= the state
        at tc — commit is slice-atomic), charging work like any traversal."""
        now = self.env.read_ts()
        for lo, hi, raw in self.scan_footprint:
            if self.ds.range_query(self.pid, lo, hi, now) != raw:
                return False
        for k, seen in self.read_footprint.items():
            if self.ds.lookup(self.pid, k) != seen:
                return False
        for k in self.writes:
            if k in self.read_footprint:
                continue  # already validated above
            if any(lo <= k < hi for lo, hi, _ in self.scan_footprint):
                continue  # covered by an interval check
            snap = self.ds.rtx_lookup(self.pid, k, self.begin_ts)
            if self.ds.lookup(self.pid, k) != snap:
                return False
        return True
