"""Read-write transactions over the multiversion structures (DESIGN.md §8-§9).

EEMARQ (Sheffi, Ramalhete, Petrank 2022 — ``PAPERS.md``) extends the
range-scan family this sim already reproduces with *read-write* transactions
whose range scans and updates commit atomically: all of a txn's reads observe
one snapshot and all of its writes become visible at one timestamp.  This is
the regime that stresses MVGC hardest — the txn's snapshot pin must survive
into its own write phase, so every version a scan at the begin timestamp
still needs stays live while the txn itself allocates new versions.

:class:`Txn` implements the full MV-RLU-style model generically over both
``MVTree`` and ``MVHashTable`` (anything exposing ``insert``/``delete``/
``rtx_lookup``/``rtx_lookup_versioned``/``range_scan``/``range_query``):

* **begin** — ``scheme.begin_txn(pid)`` pins a snapshot at the begin
  timestamp ``tb`` (announce + for EBR the epoch pin; the pin is released
  only by commit/abort, *after* the write phase).
* **read phase** — ``get`` / ``range_scan`` read the ``tb`` snapshot through
  the structures' versioned read paths, overlaid with the txn's own buffered
  writes (read-your-writes).  A txn's *footprint* may span several disjoint
  scan intervals (call ``range_scan`` repeatedly) plus tracked point reads;
  every piece is validated at commit.  Point reads are tracked
  **version-wise**: ``get`` records the governing version's timestamp
  (``rtx_lookup_versioned``), and commit re-reads the version — a point read
  revalidates only if its governing version is unchanged, not merely if the
  value happens to match (no ABA tolerance for point reads; DESIGN.md §9).
* **write phase** — ``put`` / ``delete`` buffer into a private write set;
  nothing touches shared state before commit, so an aborted txn leaves no
  versions anywhere.
* **commit** — ``try_commit`` linearizes the whole txn at a single commit
  timestamp ``tc``: it advances the global timestamp once, then runs the
  abort taxonomy in order (``contention.ABORT_REASONS``):

  1. **wcc** (write-commit conflict) — eager first-updater-wins: every
     write-set key's *governing version* (the CAS granule an update swings —
     hash bucket chain / terminal tree pointer) must still be ``<= tb``; a
     version committed after ``tb`` aborts the txn before full validation,
     exactly like a failed MV-RLU try-lock;
  2. **footprint** — full validation: every scanned interval re-read at
     ``tc`` must equal the raw ``tb`` scan result (value-level, ABA-tolerant
     — an interval restored to its snapshot contents revalidates), and every
     tracked point read must still be served by its recorded version;
  3. **capacity** — when a :class:`~repro.core.sim.contention.
     ContentionManager` with a version budget is attached, a txn that would
     otherwise commit must cover its write set from the budget (the MV-RLU
     bounded-log model: reclamation not keeping up ⇒ capacity aborts).
     Checked last so only versions actually about to be installed are
     charged — doomed txns never drain the budget.  A capacity abort then
     closes the loop (DESIGN.md §10): after the pin is released, the txn
     builds the manager's :class:`~repro.core.sim.contention.ReclaimRequest`
     (budget deficit + decayed hot set) and drives
     ``scheme.reclaim_on_pressure`` — a synchronous reclamation pass whose
     freed versions are refunded to the budget, and whose list work is
     converted into a reclaim *stall* (``reclaim_stall_slices``) the driver
     serves before the backoff ladder permits the retry.  This is MV-RLU's
     abort ⇒ reclaim ⇒ retry cycle: the retry re-runs against a refilled
     budget instead of burning its whole retry ladder on a drained one.

  Only then are all buffered writes applied — each stamped ``tc`` — and
  recorded in the shared ``UpdateLog``.  On abort the reason lands in
  ``abort_reason`` and the implicated keys in ``conflict_keys`` so the
  driver can feed the contention manager's per-key stats; the caller
  retries with a fresh snapshot after a bounded-exponential backoff.
  A txn with an empty write set is read-only and commits validation-free:
  its snapshot reads linearize at ``tb``.

Commit is slice-atomic in the discrete-event driver, mirroring the sim's
slice-atomic updates: validation + apply happen between two scheduler yields,
which models the commit's single linearization point (DESIGN.md §8 records
why this is faithful for the GC dynamics under study).  All validation reads
go through the version lists, so long-footprint txns pay their validation
cost in work units like every other traversal.
"""
from __future__ import annotations

from typing import Any, Dict, Generator, List, Optional, Tuple

# Conversion rate from reclaim work units (shared-memory accesses the
# synchronous reclamation pass performs) to the scheduler slices the aborting
# process stalls before its retry; capped so one huge sweep cannot stall a
# process longer than a maxed-out backoff (DESIGN.md §10).
RECLAIM_WORK_PER_SLICE = 32
RECLAIM_STALL_CAP = 64


class Txn:
    """One read-write transaction.  Lifecycle::

        txn = Txn(pid, ds, env, scheme, log=log, cm=cm)  # pins the snapshot
        gen = txn.range_scan(lo, hi)                # sliced snapshot scan
        ... drive gen (repeat for more intervals), txn.get point reads,
        ... buffer writes via txn.put / txn.delete ...
        if not txn.try_commit():                    # atomic validate+apply
            ...txn.abort_reason in ("capacity", "wcc", "footprint");
            ...back off, retry with a fresh Txn...

    ``log`` (an ``UpdateLog``) receives the committed writes at the commit
    timestamp so subsequent validated scans hold the txn's writes visible
    exactly at ``tc``; aborted txns never touch it.  ``cm`` (a
    ``ContentionManager``) supplies the optional commit-time version budget;
    conflict recording and backoff stay in the workload driver.
    """

    __slots__ = ("pid", "ds", "env", "scheme", "log", "cm",
                 "begin_ts", "commit_ts", "writes", "read_footprint",
                 "read_versions", "scan_footprint", "state",
                 "abort_reason", "conflict_keys",
                 "reclaim_stall_slices", "reclaimed_versions")

    def __init__(self, pid: int, ds, env, scheme, log=None, cm=None):
        self.pid = pid
        self.ds = ds
        self.env = env
        self.scheme = scheme
        self.log = log
        self.cm = cm
        self.begin_ts: float = scheme.begin_txn(pid)
        self.commit_ts: Optional[float] = None
        self.writes: Dict[int, Any] = {}          # key -> value (None = delete)
        self.read_footprint: Dict[int, Any] = {}  # key -> tb-snapshot value
        self.read_versions: Dict[int, float] = {}  # key -> governing version ts
        self.scan_footprint: List[Tuple[int, int, List[Tuple[int, Any]]]] = []
        self.state = "active"                     # active | committed | aborted
        self.abort_reason: Optional[str] = None   # capacity | wcc | footprint
        self.conflict_keys: List[int] = []
        self.reclaim_stall_slices = 0             # set by a capacity abort
        self.reclaimed_versions = 0               # ...along with the reclaim

    # -- read phase ---------------------------------------------------------
    def get(self, k: int) -> Optional[Any]:
        """Snapshot read of one key, overlaid with the txn's own writes.
        Tracked version-wise: the governing version's timestamp joins the
        footprint and is revalidated (not just value-compared) at commit."""
        assert self.state == "active"
        if k in self.writes:
            return self.writes[k]
        if k in self.read_footprint:
            return self.read_footprint[k]
        v, vts = self.ds.rtx_lookup_versioned(self.pid, k, self.begin_ts)
        self.read_footprint[k] = v
        self.read_versions[k] = vts
        return v

    def range_scan(self, lo: int, hi: int) -> Generator:
        """Sliced snapshot scan of [lo, hi) at the begin timestamp (one yield
        per versioned read, like the read-only rtx scans); ``return``s the
        sorted [(key, val)] snapshot overlaid with the txn's own writes.
        Call repeatedly for a multi-interval footprint — every interval is
        validated at commit."""
        assert self.state == "active"
        raw = yield from self.ds.range_scan(self.pid, lo, hi, self.begin_ts)
        self.scan_footprint.append((lo, hi, list(raw)))
        return self._overlay(lo, hi, raw)

    def range_query(self, lo: int, hi: int) -> List[Tuple[int, Any]]:
        """Atomic convenience form of :meth:`range_scan`."""
        gen = self.range_scan(lo, hi)
        while True:
            try:
                next(gen)
            except StopIteration as stop:
                return stop.value

    def _overlay(self, lo: int, hi: int, raw) -> List[Tuple[int, Any]]:
        merged = {k: v for k, v in raw}
        for k, v in self.writes.items():
            if lo <= k < hi:
                if v is None:
                    merged.pop(k, None)
                else:
                    merged[k] = v
        return sorted(merged.items())

    # -- write phase (buffered) ----------------------------------------------
    def put(self, k: int, v: Any) -> None:
        """Buffer an insert/update of ``k``; applied only if commit wins."""
        assert self.state == "active" and v is not None
        self.writes[k] = v

    def delete(self, k: int) -> None:
        """Buffer a delete of ``k``; applied only if commit wins."""
        assert self.state == "active"
        self.writes[k] = None

    # -- commit / abort -------------------------------------------------------
    def try_commit(self) -> bool:
        """Validate + apply atomically; returns False (and aborts, setting
        ``abort_reason``/``conflict_keys``) on conflict.  The snapshot pin is
        released either way."""
        assert self.state == "active"
        if not self.writes:
            # read-only: linearizes at begin_ts, no validation needed
            self.commit_ts = self.begin_ts
            self.state = "committed"
            self.scheme.commit_txn(self.pid)
            return True
        tc = self.env.advance_ts()
        wcc = self._wcc_conflicts()
        if wcc:
            return self._fail("wcc", wcc)
        bad = self._validate()
        if bad is not None:
            return self._fail("footprint", bad)
        # capacity last: only a txn that would otherwise commit charges the
        # version budget — aborted txns install no versions, so they must
        # not drain it (contention.ABORT_REASONS documents the order)
        if self.cm is not None and not self.cm.try_consume(len(self.writes),
                                                           tc):
            self._fail("capacity", [])
            # abort => reclaim: the pin is released, so the scheme may now
            # reclaim this txn's own snapshot too (DESIGN.md §10)
            self._reclaim_after_capacity_abort(tc)
            return False
        for k in sorted(self.writes):
            v = self.writes[k]
            if v is None:
                self.ds.delete(self.pid, k)
            else:
                self.ds.insert(self.pid, k, v)
            if self.log is not None:
                self.log.record(tc, k, v)
        self.commit_ts = tc
        self.state = "committed"
        self.scheme.commit_txn(self.pid)
        return True

    def abort(self) -> None:
        """Discard buffered writes and release the snapshot pin."""
        if self.state == "active":
            self.state = "aborted"
            self.scheme.abort_txn(self.pid)

    def _fail(self, reason: str, keys: List[int]) -> bool:
        self.abort_reason = reason
        self.conflict_keys = keys
        self.abort()
        return False

    def _reclaim_after_capacity_abort(self, now: float) -> None:
        """The reclaim half of abort ⇒ reclaim ⇒ retry (DESIGN.md §10):
        build the contention manager's :class:`~repro.core.sim.contention.
        ReclaimRequest` (budget deficit + decayed hot set), drive the
        scheme's synchronous ``reclaim_on_pressure`` pass, refund the freed
        versions to the budget, and convert the pass's list work into the
        stall slices (``reclaim_stall_slices``) the workload driver serves
        before this process's backoff — reclamation latency is paid by the
        process that hit the wall, exactly like MV-RLU's synchronous log
        reclamation."""
        req = self.cm.reclaim_request(now)
        w0 = self.scheme.work + self.scheme.gc_list_work
        freed = self.scheme.reclaim_on_pressure(req.hot_keys, req.deficit)
        spent = self.scheme.work + self.scheme.gc_list_work - w0
        self.reclaim_stall_slices = min(RECLAIM_STALL_CAP,
                                        1 + spent // RECLAIM_WORK_PER_SLICE)
        self.reclaimed_versions = freed
        self.cm.record_reclaim(freed, self.reclaim_stall_slices)

    def _wcc_conflicts(self) -> List[int]:
        """Eager first-updater-wins check on the write set: a write key whose
        governing version postdates ``tb`` lost the update race (another
        commit swung its CAS granule since the snapshot) — the MV-RLU
        try-lock failure, detected version-wise, before full validation."""
        bad = []
        for k in self.writes:
            _, vts = self.ds.rtx_lookup_versioned(self.pid, k,
                                                  self.env.read_ts())
            if vts > self.begin_ts:
                bad.append(k)
        return bad

    def _validate(self) -> Optional[List[int]]:
        """Footprint validation at the commit timestamp; returns the
        implicated keys on failure, None when the footprint revalidates.
        Scanned intervals are re-read at ``tc`` and compared against the raw
        ``tb`` result (value-level, ABA-tolerant); tracked point reads are
        revalidated version-wise — the governing version recorded at read
        time must still serve the key.  Reads go through the current
        version-list heads (= the state at tc — commit is slice-atomic),
        charging work like any traversal."""
        now = self.env.read_ts()
        for lo, hi, raw in self.scan_footprint:
            cur = self.ds.range_query(self.pid, lo, hi, now)
            if cur != raw:
                return sorted({k for k, _ in set(cur) ^ set(raw)})
        for k, vts in self.read_versions.items():
            _, vts_now = self.ds.rtx_lookup_versioned(self.pid, k, now)
            if vts_now != vts:
                return [k]
        return None
