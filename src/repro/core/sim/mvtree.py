"""Multiversion external BST (paper §6.1's chromatic tree, simplified).

Leaf-oriented BST whose child pointers are vCAS objects, so versions of a
child pointer reference tree nodes that contain *other* vCAS objects — the
indirection pattern ("vCAS objects do point indirectly to others") that makes
Steam's dusty-corners problem cost up to 8x space on trees (paper §6.2).

Simplification vs. the paper (recorded in DESIGN.md §3): the chromatic tree's
lazy red-black rebalancing is dropped; with uniformly/zipf-drawn integer keys
an unbalanced external BST has expected O(log n) depth, and rebalancing does
not change the GC dynamics under study (it only adds more child-pointer
writes, i.e. *more* versions — our variant is conservative for Steam).

* insert(k): replace leaf l by internal(router, l, new-leaf) via one child
  vCAS CAS — creates one internal + one leaf node.
* delete(k): splice leaf + parent out by CAS'ing the grandparent's child
  pointer to the sibling.
* updates of an existing key's value replace the leaf node.
* range scan (``range_scan``, DESIGN.md §7): explicit multi-slice snapshot
  traversal inside a read-only transaction (rtx) — the scan walks the child
  pointers' *versions* at the rtx timestamp t, yielding once per vCAS version
  read, so concurrent updates interleave at pointer-dereference granularity
  while the rtx pins its snapshot.
"""
from __future__ import annotations

import math
from typing import Any, Generator, List, Optional, Tuple

from repro.core.sim.machine import drain
from repro.core.sim.vcas import VCas

INF = math.inf


class Leaf:
    __slots__ = ("key", "val")
    WORDS = 2

    def __init__(self, key, val):
        self.key = key
        self.val = val


class Internal:
    __slots__ = ("router", "left_v", "right_v")
    WORDS = 3

    def __init__(self, env, scheme, router, left, right):
        self.router = router          # keys < router go left; >= router go right
        self.left_v = VCas(env, scheme, left)
        self.right_v = VCas(env, scheme, right)


class MVTree:
    def __init__(self, env, scheme):
        self.env = env
        self.scheme = scheme
        self.root_v = VCas(env, scheme, None)  # points at Leaf | Internal | None

    # -- traversal helpers ----------------------------------------------------
    def _descend(self, k: int):
        """Return (grandparent_vcas, parent_vcas, leaf_or_none) at current time.
        grandparent_vcas is the vCAS holding the parent Internal (or root_v)."""
        g_v: Optional[VCas] = None
        p_v: VCas = self.root_v
        node = p_v.read()
        while isinstance(node, Internal):
            g_v = p_v
            p_v = node.left_v if k < node.router else node.right_v
            node = p_v.read()
        return g_v, p_v, node

    # -- updates ----------------------------------------------------------------
    def insert(self, pid: int, k: int, v: Any) -> bool:
        while True:
            g_v, p_v, node = self._descend(k)
            head = p_v.head_node()
            if head.val is not node:
                continue  # raced; retry with consistent head
            if node is None:
                if p_v.cas_from_head(pid, head, Leaf(k, v)):
                    return True
                continue
            assert isinstance(node, Leaf)
            if node.key == k:
                if p_v.cas_from_head(pid, head, Leaf(k, v)):
                    return False  # value update, not a fresh insert
                continue
            lo, hi = (node, Leaf(k, v)) if node.key < k else (Leaf(k, v), node)
            internal = Internal(self.env, self.scheme, hi.key, lo, hi)
            if p_v.cas_from_head(pid, head, internal):
                return True

    def delete(self, pid: int, k: int) -> bool:
        while True:
            g_v, p_v, node = self._descend(k)
            if node is None or not isinstance(node, Leaf) or node.key != k:
                return False
            if g_v is None:
                head = self.root_v.head_node()
                if head.val is not node:
                    continue
                if self.root_v.cas_from_head(pid, head, None):
                    return True
                continue
            parent = g_v.read()
            if not isinstance(parent, Internal):
                continue
            # which side holds the leaf?
            if p_v is parent.left_v:
                sibling = parent.right_v.read()
            elif p_v is parent.right_v:
                sibling = parent.left_v.read()
            else:
                continue  # stale parent; retry
            head = g_v.head_node()
            if head.val is not parent:
                continue
            if g_v.cas_from_head(pid, head, sibling):
                return True

    # -- reads ---------------------------------------------------------------------
    def lookup(self, pid: int, k: int) -> Optional[Any]:
        _, _, node = self._descend(k)
        return node.val if isinstance(node, Leaf) and node.key == k else None

    def rtx_lookup(self, pid: int, k: int, t: float) -> Optional[Any]:
        """Read key k in the snapshot at timestamp t: descend through the
        child pointers' *versions* at t (one key of an rtx / txn read set)."""
        return self.rtx_lookup_versioned(pid, k, t)[0]

    def rtx_lookup_versioned(self, pid: int, k: int,
                             t: float) -> Tuple[Optional[Any], float]:
        """Snapshot read of key k at t returning ``(value, version_ts)``
        where ``version_ts`` stamps the *governing version* — the terminal
        child-pointer version whose read ended the descent.  That pointer is
        the CAS granule an update to k swings (leaf replacement / splice),
        so its version is the "object version" a MV-RLU-style try-lock would
        contend on (DESIGN.md §9)."""
        vnode = self.root_v.read_version_node(t)
        node = vnode.val
        while isinstance(node, Internal):
            child = node.left_v if k < node.router else node.right_v
            vnode = child.read_version_node(t)
            node = vnode.val
        val = node.val if isinstance(node, Leaf) and node.key == k else None
        return val, vnode.ts

    def range_scan(self, pid: int, lo: int, hi: int, t: float) -> Generator:
        """Sliced snapshot range scan at timestamp ``t``: in-order traversal
        through child-pointer versions, one yield per vCAS version read;
        ``return``s the sorted [(key, val)] snapshot of [lo, hi) as of t."""
        out: List[Tuple] = []
        stack = [self.root_v]
        while stack:
            node = stack.pop().read_version(t)
            yield
            if node is None:
                continue
            if isinstance(node, Leaf):
                if lo <= node.key < hi:
                    out.append((node.key, node.val))
                continue
            # push right first so the left subtree pops (and emits) first
            if hi > node.router:
                stack.append(node.right_v)
            if lo < node.router:
                stack.append(node.left_v)
        return out

    def range_query(self, pid: int, lo: int, hi: int, t: float) -> List[Tuple]:
        """Atomic convenience form of ``range_scan`` (drained in one slice)."""
        return drain(self.range_scan(pid, lo, hi, t))

    # -- targeted reclamation (DESIGN.md §10) -------------------------------------
    def version_lists_for(self, k: int) -> List[Any]:
        """The version lists along the *current* root-to-leaf descent path
        for key ``k``, terminal pointer last.  Updates to ``k`` swing the
        terminal child pointer, but splices (deletes) also version the
        ancestors' pointers, so a hot key's garbage accumulates along its
        whole path — the reclamation feedback loop compacts all of it
        (``SchemeBase.set_key_resolver``, DESIGN.md §10)."""
        out = [self.root_v.lst]
        node = self.root_v.read()
        while isinstance(node, Internal):
            child = node.left_v if k < node.router else node.right_v
            out.append(child.lst)
            node = child.read()
        return out

    # -- space accounting -------------------------------------------------------------
    def root_vcas(self) -> List[VCas]:
        return [self.root_v]
