"""The five MVGC schemes compared by the paper (§3, §6).

=========  ==========  ===================  =================================  ====================================
scheme     list        identifies obsolete  removes them by                    range-scan cost accounting
=========  ==========  ===================  =================================  ====================================
EBR        SSL         epoch quiescence     truncating list tails (oldest      O(c) ``SSL.search`` hops per key;
                                            suffix)                            c grows with the mid-list garbage
                                                                               EBR can never truncate, so long
                                                                               scans slow themselves down
STEAM+LF   SSL         compact on every     SSL.compact with cached AnnScan    O(c) search hops per key, c kept
                       append               (periodic-scan heuristic, §6.1)    small by per-append compaction —
                                                                               but each append near a hot scanned
                                                                               key pays an O(list) compact
BBF+       PDL         RangeTracker         TreeDL-lite splice (deferred       O(c) ``PDL`` hops per key plus the
                                            internal nodes; emulation, see     deferred internal nodes a scan
                                            DESIGN.md §2)                      must still traverse (≤ 2x nodes)
DL-RT      PDL         RangeTracker         PDL.remove on the exact node       O(c) hops per key; scans read
                                                                               through remove chains of expected
                                                                               length c ≈ 1 (Proposition 17)
SL-RT      SSL         RangeTracker         SSL.compact on the implicated      O(c) search hops per key with c
                                            list                               bounded by needed(A, t) versions
=========  ==========  ===================  =================================  ====================================

Range-scan cost is charged where it falls: every versioned read a scan
performs goes through ``SSL.search`` / ``PDL.search``, which increment the
owning list's ``work`` per hop, so the throughput proxy automatically charges
schemes whose reclamation leaves longer version lists for scans to wade
through (the effect the EEMARQ-style workload family in ``workload.py``
measures; DESIGN.md §7).

Terminology: an **rtx** (read-only transaction) is the announce/unannounce
window that pins a snapshot timestamp — ``begin_rtx``/``end_rtx`` below.  A
**range scan** is the sliced traversal executed inside an rtx
(``MVTree.range_scan`` / ``MVHashTable.range_scan``).  A **read-write txn**
(``repro.core.sim.txn.Txn``, DESIGN.md §8) pins its snapshot the same way via
``begin_txn`` but keeps the pin through its commit-time writes; reclamation
must respect these write-phase pins exactly like scan pins
(``commit_txn``/``abort_txn`` release them).

All schemes run in the discrete-event harness (``workload.py``): updates and
range scans interleave at sub-operation granularity, which is what drives the
space dynamics (long scans pinning timestamps/epochs while updates allocate
versions).  Work units model the shared-memory accesses the lock-free
algorithms would perform, so throughput proxies remain faithful; the
fine-grained interleavings themselves are validated separately by the
step-machine tests.

Reclamation under pressure (DESIGN.md §10): every scheme also implements the
``reclaim_on_pressure(hot_keys, deficit)`` hook — the synchronous half of
the MV-RLU abort ⇒ reclaim ⇒ retry cycle.  When a transaction aborts with
reason ``capacity`` (the contention manager's version budget ran dry), the
scheme must immediately splice obsolete versions out of its lists so the
budget can be refunded before the retry.  Per-scheme strategy:

* **EBR** forces epoch turnover: scan announcements, advance if no pin lags,
  sweep every bucket old enough to be safe — repeating until the deficit is
  met or pinned epochs block further advances.
* **STEAM+LF** refreshes its cached announcement scan and compacts version
  lists, *hot-set first*: the lists governing the contention manager's
  most-conflicted keys (resolved through ``set_key_resolver``) are where the
  storm allocates fastest, so compacting them buys the most space per unit
  of work.  Cold lists follow only while the deficit is unmet.
* **SL-RT** drains its RangeTracker against the *current* announcement set
  and compacts every implicated list; if the deficit survives that, it
  compacts hot-set lists like STEAM.
* **DL-RT** drains its RangeTracker against the current announcement set and
  splices the returned nodes exactly (``PDL.remove``).
* **BBF+** drains its RangeTracker and splices what the TreeDL deferral rule
  permits — the rule is a correctness invariant of the emulation, so unlike
  ``quiesce`` the pressure path never bypasses it.

Space model (paper: Java reachability): a version node costs ``NODE_WORDS``
words (5 for PDL — key/val/left/right/mark; 3 for SSL — ts/val/left),
matching the paper's observation that DL-RT pays for back pointers.
"""
from __future__ import annotations

import math
from collections import defaultdict
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.sim.pdl import PDL, Node
from repro.core.sim.rangetracker import RangeTracker
from repro.core.sim.ssl_list import SSL, SNode, MVEnv

PDL_NODE_WORDS = 5  # key, val, left, right, mark
SSL_NODE_WORDS = 3  # ts, val, left


class SchemeBase:
    """Common interface used by vCAS objects and the workload driver."""

    name = "base"
    node_words = SSL_NODE_WORDS

    def __init__(self, env: MVEnv):
        self.env = env
        self.work = 0           # scheme-only overhead (list work is in lst.work)
        self.gc_list_work = 0   # list work performed on behalf of GC (reporting)
        self.txn_pins = 0       # read-write txn snapshot pins taken
        self.contention = None  # optional ContentionManager (DESIGN.md §9)
        self.key_lists = None   # optional key -> [version lists] resolver (§10)
        self.reclaims = 0       # reclaim_on_pressure invocations
        self.reclaimed_on_pressure = 0  # versions freed by those invocations
        self.lists: List[Any] = []

    # -- contention consultation (DESIGN.md §9) -----------------------------
    def set_contention(self, cm) -> None:
        """Attach the workload's :class:`~repro.core.sim.contention.
        ContentionManager`.  Schemes with a *cadence* (EBR's epoch advance,
        Steam's cached announce-scan refresh) consult its pressure signal:
        under an abort/retry storm pins churn quickly, so stale announcement
        state retains garbage longer — the schemes shorten their intervals
        while pressure is high, the adaptive reaction MV-RLU/EEMARQ describe.
        Schemes without a cadence (the RangeTracker family flushes on batch
        boundaries) ignore it."""
        self.contention = cm

    def _pressure(self) -> float:
        """Current 0..1 contention pressure (0 with no manager attached)."""
        if self.contention is None:
            return 0.0
        return self.contention.pressure(self.env.read_ts())

    # -- the reclamation feedback loop (DESIGN.md §10) -----------------------
    def set_key_resolver(self, fn) -> None:
        """Attach the structure's targeted-compaction entry point: a callable
        ``key -> [version lists]`` returning the lists that govern a key
        (``MVHashTable.version_lists_for`` / ``MVTree.version_lists_for``).
        Schemes that compact hot-set lists preferentially (STEAM, SL-RT) need
        it; ``None`` (the default) degrades them to untargeted reclaim."""
        self.key_lists = fn

    def reclaim_on_pressure(self, hot_keys: List[int], deficit: int) -> int:
        """Synchronously reclaim obsolete versions because the version budget
        ran dry (a ``capacity`` abort; DESIGN.md §10).  ``hot_keys`` is the
        contention manager's decayed hot set (most-conflicted first) and
        ``deficit`` the number of versions needed to refill the budget.
        Returns the number of versions actually spliced out of reachability —
        the caller refunds exactly that many budget tokens, so the count must
        be honest.  Reclaim can legitimately return less than ``deficit``
        (or 0) when pins hold everything live; the retry then rides on the
        passive timestamp-progress refill instead."""
        self.reclaims += 1
        freed = self._reclaim(list(hot_keys), max(0, deficit))
        self.reclaimed_on_pressure += freed
        return freed

    def _reclaim(self, hot_keys: List[int], deficit: int) -> int:
        """Per-scheme reclaim strategy; the base scheme holds no garbage."""
        return 0

    def _hot_lists(self, hot_keys: List[int]) -> List[Any]:
        """Resolve ``hot_keys`` to their governing version lists, hottest
        first, deduplicated (several keys may share a bucket/pointer)."""
        if self.key_lists is None:
            return []
        seen, out = set(), []
        for k in hot_keys:
            for lst in self.key_lists(k):
                if id(lst) not in seen:
                    seen.add(id(lst))
                    out.append(lst)
        return out

    # -- list/node factories ----------------------------------------------
    def new_list(self):
        """Create this scheme's version-list flavour (SSL or PDL)."""
        raise NotImplementedError

    def new_node(self, ts, val):
        """Create one version node for ``new_list``'s list flavour."""
        raise NotImplementedError

    def register_list(self, lst) -> None:
        """Track a list for quiescence sweeps and work/space accounting."""
        self.lists.append(lst)

    # -- operation lifecycle -----------------------------------------------
    def begin_update(self, pid: int) -> Any:
        """Start one update op; returns an opaque ctx for ``end_update``."""
        return None

    def end_update(self, pid: int, ctx: Any) -> None:
        """Finish the update op started with ``ctx``."""
        pass

    def begin_rtx(self, pid: int) -> float:
        """Announce and return the rtx timestamp."""
        ts = self.env.announce_ts(pid)
        self.work += 2
        return ts

    def end_rtx(self, pid: int) -> None:
        """Unannounce, releasing the rtx's snapshot pin."""
        self.env.unannounce(pid)
        self.work += 1

    # -- read-write transactions (DESIGN.md §8) -----------------------------
    # A txn's snapshot pin is the same announce/unannounce (plus, for EBR,
    # epoch-pin) window as an rtx — but it *survives into the write phase*:
    # commit-time writes run under the begin_txn pin, with no per-write
    # begin_update/end_update (which would, for EBR, re-pin at the current
    # epoch and release the snapshot mid-transaction).  Every scheme's
    # reclamation therefore respects write-phase pins exactly as it respects
    # scan pins: the announce array (RangeTracker schemes, Steam's AnnScan)
    # or the pinned epoch (EBR) keeps the begin-ts snapshot live until
    # commit_txn/abort_txn releases it.
    def begin_txn(self, pid: int) -> float:
        """Pin a snapshot for a read-write transaction; returns begin ts."""
        self.txn_pins += 1
        return self.begin_rtx(pid)

    def commit_txn(self, pid: int) -> None:
        """Release the pin after the commit's writes are all applied."""
        self.end_rtx(pid)

    def abort_txn(self, pid: int) -> None:
        """Release the pin of an aborted txn (no writes were applied)."""
        self.end_rtx(pid)

    # -- the GC hook ---------------------------------------------------------
    def on_overwrite(self, pid: int, lst, old_node, low: float, high: float) -> None:
        """Receive one overwritten version (``old_node`` of ``lst``, current
        over ``[low, high)``) — the scheme's per-version retire hook."""
        raise NotImplementedError

    def quiesce(self) -> None:
        """Drain deferred reclamation at workload quiescence."""
        pass

    # -- accounting ----------------------------------------------------------
    def aux_space_words(self) -> int:
        """Words held by GC metadata (RT buffers, EBR buckets, ...)."""
        return 0

    def stats(self) -> Dict[str, Any]:
        """Scheme-level counters for the benchmark rows (``scheme_stats``);
        subclasses extend this dict with their own."""
        return {"gc_work": self.work, "reclaims": self.reclaims,
                "reclaimed_on_pressure": self.reclaimed_on_pressure}

    def _announced(self) -> List[float]:
        self.work += self.env.P
        return [a for a in self.env.announce if a is not None]


# ---------------------------------------------------------------------------
# EBR
# ---------------------------------------------------------------------------
class EBRScheme(SchemeBase):
    """Epoch-based MVGC (paper §2): versions overwritten before the previous
    epoch are reclaimed; only list *tails* are ever truncated, so obsolete
    versions in the middle of a list are never collected."""

    name = "ebr"
    node_words = SSL_NODE_WORDS

    def __init__(self, env: MVEnv, advance_every: int = 64):
        super().__init__(env)
        self.epoch = 0
        self.ann_epoch: List[Optional[int]] = [None] * env.P
        self.buckets: Dict[int, List[Tuple[SSL, SNode]]] = defaultdict(list)
        self.advance_every = advance_every
        self._ops_since_advance = 0
        self.freed = 0
        self.truncated = 0  # nodes actually dropped from reachability

    def new_list(self):
        """EBR runs on SSL version lists."""
        return SSL()

    def new_node(self, ts, val):
        """One SSL version node."""
        return SNode(ts, val)

    # every operation (update or rtx) participates in the epoch protocol
    def begin_update(self, pid: int):
        """Pin the current epoch for the duration of the update."""
        self.ann_epoch[pid] = self.epoch
        self.work += 2
        return None

    def end_update(self, pid: int, ctx) -> None:
        """Release the epoch pin; maybe advance the epoch (cadence)."""
        self.ann_epoch[pid] = None
        self.work += 1
        self._maybe_advance()

    def begin_rtx(self, pid: int) -> float:
        """Pin the current epoch *and* announce the rtx timestamp."""
        self.ann_epoch[pid] = self.epoch
        ts = self.env.announce_ts(pid)  # rtx still needs its read timestamp
        self.work += 3
        return ts

    def end_rtx(self, pid: int) -> None:
        """Release the epoch pin and the announcement."""
        self.ann_epoch[pid] = None
        self.env.unannounce(pid)
        self.work += 2
        self._maybe_advance()

    def on_overwrite(self, pid, lst, old_node, low, high) -> None:
        """Bucket the overwritten version under the current epoch."""
        self.buckets[self.epoch].append((lst, old_node))
        self.work += 1

    def _maybe_advance(self) -> None:
        self._ops_since_advance += 1
        # contention-aware cadence: under an abort/retry storm the epoch must
        # try to turn over faster — pinned snapshots churn, and every missed
        # advance strands whole list suffixes (DESIGN.md §9)
        eff = max(1, int(self.advance_every * (1.0 - 0.75 * self._pressure())))
        if self._ops_since_advance < eff:
            return
        self._ops_since_advance = 0
        self.work += self.env.P  # scan announcement epochs
        cur = self.epoch
        if all(e is None or e >= cur for e in self.ann_epoch):
            self.epoch = cur + 1
            self._free_old()

    def _free_old(self) -> int:
        """Sweep every epoch bucket old enough to be safe (<= epoch - 2);
        returns the number of nodes dropped from reachability."""
        safe = self.epoch - 2
        dropped = 0
        for e in sorted(e for e in self.buckets if e <= safe):
            by_list: Dict[int, Tuple[SSL, SNode]] = {}
            for lst, node in self.buckets.pop(e):
                self.freed += 1
                key = id(lst)
                prev = by_list.get(key)
                # newest reclaimable version per list wins (append rank ties ts)
                if prev is None or node.order > prev[1].order:
                    by_list[key] = (lst, node)
                self.work += 1
            for lst, node in by_list.values():
                dropped += self._truncate(lst, node)
        self.truncated += dropped
        return dropped

    def _truncate(self, lst: SSL, node: SNode) -> int:
        """Drop the list suffix ending at ``node`` (the newest reclaimable
        version of this list; the reclaimable set is always a suffix because
        overwrite epochs are nondecreasing along a list).  Returns the number
        of nodes the cut removed from reachability."""
        x = lst.head
        self.work += 1
        while x is not lst.sentinel and x.left is not node:
            x = x.left
            self.work += 1
        if x is lst.sentinel:
            return 0
        dropped = 0
        y = x.left  # == node
        while y is not lst.sentinel:
            dropped += 1
            y = y.left
            self.work += 1
        x.left = lst.sentinel
        self.work += 1
        return dropped

    def _reclaim(self, hot_keys, deficit) -> int:
        """Capacity-abort reclaim (DESIGN.md §10): force epoch turnover —
        scan announcement epochs, advance when no pin lags behind, sweep the
        now-safe buckets — until the deficit is met or a pinned epoch blocks
        further advances.  EBR has no per-key targeting (it only ever
        truncates tails), so the hot set is unused."""
        freed = 0
        for _ in range(4):
            self.work += self.env.P  # scan announcement epochs
            cur = self.epoch
            if all(e is None or e >= cur for e in self.ann_epoch):
                self.epoch = cur + 1
            freed += self._free_old()
            if freed >= deficit or self.epoch == cur:
                break  # met the target, or an old pin blocks any progress
        self._ops_since_advance = 0
        return freed

    def quiesce(self) -> None:
        """Advance epochs with no active ops until everything frees."""
        for _ in range(4):
            self.epoch += 1
            self._free_old()

    def aux_space_words(self) -> int:
        """One word per version still parked in an epoch bucket."""
        return sum(len(b) for b in self.buckets.values())

    def stats(self):
        """Base counters plus the epoch clock and free totals."""
        s = super().stats()
        s.update({"epoch": self.epoch, "freed": self.freed,
                  "truncated": self.truncated})
        return s


# ---------------------------------------------------------------------------
# STEAM+LF
# ---------------------------------------------------------------------------
class SteamLFScheme(SchemeBase):
    """Lock-free Steam (paper's STEAM+LF): compact a version list on every
    append to it, using a cached announcement scan refreshed every
    ``scan_every`` GC events (the paper's 1 ms heuristic, §6.1; this trades
    the O(P) per-list bound for speed, exactly as the paper describes)."""

    name = "steam"
    node_words = SSL_NODE_WORDS

    def __init__(self, env: MVEnv, scan_every: int = 64):
        super().__init__(env)
        self.scan_every = scan_every
        self._since_scan = scan_every  # force scan on first use
        self._cached = None
        self.compactions = 0
        self.spliced = 0

    def new_list(self):
        """STEAM runs on SSL version lists."""
        return SSL()

    def new_node(self, ts, val):
        """One SSL version node."""
        return SNode(ts, val)

    def _scan(self):
        self._since_scan += 1
        # contention-aware cadence: a cached announcement scan goes stale
        # fast under an abort/retry storm (pins are taken and dropped every
        # few slices), and compacting against a stale scan retains every
        # version any *recently released* pin needed — refresh more eagerly
        # while the contention manager reports pressure (DESIGN.md §9)
        eff = max(1, int(self.scan_every * (1.0 - 0.75 * self._pressure())))
        if self._cached is None or self._since_scan >= eff:
            self._cached = self.env.scan_announce()
            self.work += self.env.P + 2
            self._since_scan = 0
        return self._cached

    def on_overwrite(self, pid, lst, old_node, low, high) -> None:
        """Compact the overwritten list against the cached announce scan."""
        self._compact_one(lst, self._scan())

    def _compact_one(self, lst, scan) -> int:
        """Compact one list against ``scan``; returns nodes spliced."""
        h = lst.peek_head()
        w0 = lst.work
        n = lst.compact(scan.A, scan.t, h)
        self.gc_list_work += lst.work - w0
        self.compactions += 1
        self.spliced += n
        return n

    def _reclaim(self, hot_keys, deficit) -> int:
        """Capacity-abort reclaim (DESIGN.md §10): refresh the announcement
        scan unconditionally (the cached one is what let garbage linger),
        then compact **hot-set lists first** — the version lists governing
        the most-conflicted keys, resolved via ``set_key_resolver`` — and
        spill over to the remaining lists only while the deficit is unmet.
        Hot lists are where the abort/retry storm allocates versions
        fastest, so this ordering maximizes versions freed per unit of
        reclaim latency the aborting transaction pays."""
        self._cached = self.env.scan_announce()
        self.work += self.env.P + 2
        self._since_scan = 0
        scan = self._cached
        freed = 0
        hot = self._hot_lists(hot_keys)
        seen = {id(lst) for lst in hot}
        for lst in hot:
            if freed >= deficit:
                return freed
            freed += self._compact_one(lst, scan)
        for lst in self.lists:
            if freed >= deficit:
                break
            if id(lst) not in seen:
                freed += self._compact_one(lst, scan)
        return freed

    def quiesce(self) -> None:
        """Final full compaction pass against a fresh announce scan."""
        scan = self.env.scan_announce()
        for lst in self.lists:
            self.spliced += lst.compact(scan.A, scan.t, lst.peek_head())

    def stats(self):
        """Base counters plus compaction totals."""
        s = super().stats()
        s.update({"compactions": self.compactions, "spliced": self.spliced})
        return s


# ---------------------------------------------------------------------------
# RangeTracker-based schemes
# ---------------------------------------------------------------------------
class _RTScheme(SchemeBase):
    """Shared RangeTracker plumbing for DL-RT, SL-RT and BBF+."""

    def __init__(self, env: MVEnv, batch_size: Optional[int] = None):
        super().__init__(env)
        self.rt = RangeTracker(env.P, batch_size=batch_size)
        self.reclaimed = 0

    def aux_space_words(self) -> int:
        """Three words (payload, low, high) per tracked version."""
        return 3 * self.rt.size()  # payload, low, high

    def _rt_add(self, pid, payload, low, high) -> List[Any]:
        w0 = self.rt.work
        out = self.rt.add(pid, payload, low, high, self._announced_nowork)
        self.work += self.rt.work - w0
        return out

    def _rt_drain(self) -> List[Any]:
        """Force-flush the tracker against the *current* announcement set
        (the reclamation-loop prune, DESIGN.md §10) with work accounting."""
        w0 = self.rt.work
        out = self.rt.drain(self._announced_nowork)
        self.work += self.rt.work - w0
        return out

    def _announced_nowork(self) -> List[float]:
        return [a for a in self.env.announce if a is not None]

    def stats(self):
        """Base counters plus RangeTracker totals."""
        s = super().stats()
        s.update({"reclaimed": self.reclaimed, "rt_size": self.rt.size(),
                  "rt_flushes": self.rt.flushes})
        return s


class DLRTScheme(_RTScheme):
    """DL-RT: RangeTracker identifies the exact obsolete node; PDL.remove
    splices it out given only the node pointer (paper §3, §4)."""

    name = "dlrt"
    node_words = PDL_NODE_WORDS

    def new_list(self):
        """DL-RT runs on doubly-linked PDL version lists."""
        return PDL()

    def new_node(self, ts, val):
        """One PDL version node."""
        return Node(ts, val)

    def on_overwrite(self, pid, lst, old_node, low, high) -> None:
        """Track the version; splice whatever the tracker returns."""
        for plst, pnode in self._rt_add(pid, (lst, old_node), low, high):
            w0 = plst.work
            plst.remove(pnode)
            self.gc_list_work += plst.work - w0
            self.reclaimed += 1

    def _reclaim(self, hot_keys, deficit) -> int:
        """Capacity-abort reclaim (DESIGN.md §10): prune the RangeTracker
        against the current announcement set and splice every returned node
        exactly (``PDL.remove`` needs only the node pointer).  DL-RT removal
        is already exact-node, so there is nothing extra to target with the
        hot set — the deferred tracker backlog *is* the reclaimable space."""
        freed = 0
        for plst, pnode in self._rt_drain():
            w0 = plst.work
            plst.remove(pnode)
            self.gc_list_work += plst.work - w0
            self.reclaimed += 1
            freed += 1
        return freed

    def quiesce(self) -> None:
        """Drain the tracker and splice everything it returns."""
        for plst, pnode in self.rt.drain(self._announced_nowork):
            plst.remove(pnode)
            self.reclaimed += 1

    def avg_chain(self) -> float:
        """Mean remove-chain length c (Proposition 17's expectation ~1)."""
        tot = sum(l.remove_chain_total for l in self.lists)
        cnt = sum(l.removes_completed for l in self.lists)
        return tot / cnt if cnt else 1.0

    def stats(self):
        """RT counters plus the observed remove-chain constant."""
        s = super().stats()
        s["avg_remove_chain_c"] = round(self.avg_chain(), 4)
        return s


class SLRTScheme(_RTScheme):
    """SL-RT: RangeTracker identifies obsolete versions; the implicated lists
    are compacted with SSL.compact (paper §3, §5).  Compacting preemptively
    splices *all* currently-unneeded versions of those lists, not just the
    returned ones — the paper credits this for SL-RT's space advantage."""

    name = "slrt"
    node_words = SSL_NODE_WORDS

    def new_list(self):
        """SL-RT runs on SSL version lists."""
        return SSL()

    def new_node(self, ts, val):
        """One SSL version node."""
        return SNode(ts, val)

    def on_overwrite(self, pid, lst, old_node, low, high) -> None:
        """Track the version; compact the lists a flush implicates."""
        returned = self._rt_add(pid, (lst, old_node), low, high)
        self._compact_lists(returned)

    def _compact_lists(self, returned) -> None:
        unique: Dict[int, SSL] = {}
        for plst, _ in returned:
            unique[id(plst)] = plst
        if not unique:
            return
        # one GlobalAnnScan per flush batch (paper §5: compact takes its
        # (A, t) from the shared AnnScan object, re-reading only head per list)
        scan = self.env.scan_announce()
        self.work += self.env.P + 2
        for plst in unique.values():
            self._compact_list(plst, scan)

    def _compact_list(self, plst, scan) -> int:
        """Compact one list against ``scan``; returns nodes spliced."""
        h = plst.peek_head()
        w0 = plst.work
        n = plst.compact(scan.A, scan.t, h)
        self.reclaimed += n
        self.gc_list_work += plst.work - w0
        return n

    def _reclaim(self, hot_keys, deficit) -> int:
        """Capacity-abort reclaim (DESIGN.md §10): prune the RangeTracker
        against the current announcement set and compact every implicated
        list; if the deficit survives the prune, keep compacting along the
        hot set (the lists governing the most-conflicted keys), where the
        storm's version churn concentrates."""
        r0 = self.reclaimed
        self._compact_lists(self._rt_drain())
        if self.reclaimed - r0 < deficit and self.key_lists is not None:
            scan = self.env.scan_announce()
            self.work += self.env.P + 2
            for plst in self._hot_lists(hot_keys):
                if self.reclaimed - r0 >= deficit:
                    break
                self._compact_list(plst, scan)
        return self.reclaimed - r0

    def quiesce(self) -> None:
        """Drain the tracker and compact everything it implicates."""
        self._compact_lists(self.rt.drain(self._announced_nowork))


class BBFScheme(_RTScheme):
    """BBF+ emulation: RangeTracker + TreeDL-lite.

    TreeDL lays an implicit binary tree over the list; only nodes whose
    implicit subtree is otherwise empty can be spliced, so obsolete internal
    nodes wait for their subtrees (the paper's 2(L-R) + O(P log Lmax) space
    bound, vs. L-R+P for PDL/SSL).  We emulate exactly that deferral rule on
    top of PDL splicing, plus a constant helping-overhead factor per removal;
    see DESIGN.md §2 for the emulation rationale."""

    name = "bbf"
    node_words = PDL_NODE_WORDS + 2  # TreeDL carries extra per-node tree state
    TREEDL_OVERHEAD = 6              # helping/consistency steps per splice

    def __init__(self, env: MVEnv, batch_size: Optional[int] = None):
        super().__init__(env, batch_size)
        # per-list: rank -> pending node; set of spliced ranks
        self.pending: Dict[int, Dict[int, Tuple[PDL, Node]]] = defaultdict(dict)
        self.spliced_ranks: Dict[int, set] = defaultdict(set)

    def new_list(self):
        """BBF+ runs on doubly-linked PDL version lists."""
        return PDL()

    def new_node(self, ts, val):
        """One PDL version node."""
        return Node(ts, val)

    @staticmethod
    def _height(rank: int) -> int:
        """In-order complete-BST height of a 1-indexed position: number of
        trailing zero bits (odd ranks are leaves)."""
        if rank <= 0:
            return 0
        h = 0
        while rank % 2 == 0:
            rank //= 2
            h += 1
        return h

    def _removable(self, lid: int, lst: PDL, rank: int) -> bool:
        h = self._height(rank)
        if h == 0:
            return True
        lo, hi = rank - (1 << h) + 1, rank + (1 << h) - 1
        done = self.spliced_ranks[lid]
        self.work += 1 + (hi - lo) // 2
        for r in range(lo, hi + 1):
            if r == rank or r > lst.appends:  # own rank / not yet appended
                continue
            if r not in done:                 # any live occupant blocks removal
                return False
        return True

    def on_overwrite(self, pid, lst, old_node, low, high) -> None:
        """Track the version; splice what the TreeDL rule permits."""
        for plst, pnode in self._rt_add(pid, (lst, old_node), low, high):
            self._try_splice(plst, pnode)

    def _reclaim(self, hot_keys, deficit) -> int:
        """Capacity-abort reclaim (DESIGN.md §10): prune the RangeTracker
        against the current announcement set and feed the returned nodes
        through ``_try_splice``.  Unlike ``quiesce``, the TreeDL deferral
        rule is **never** bypassed — the system is not quiescent, so a
        deferred internal node must keep waiting for its subtree; BBF+
        therefore reclaims least per pass, exactly its paper-predicted
        2(L-R) space disadvantage showing up in the feedback loop too."""
        r0 = self.reclaimed
        for plst, pnode in self._rt_drain():
            self._try_splice(plst, pnode)
        return self.reclaimed - r0

    def _try_splice(self, lst: PDL, node: Node) -> None:
        lid = id(lst)
        self.pending[lid][node.order] = (lst, node)
        # repeatedly splice any pending node whose constraint is satisfied
        progress = True
        while progress:
            progress = False
            for rank in sorted(self.pending[lid]):
                plst, pnode = self.pending[lid][rank]
                # height check must ignore the node's own pending entry
                del self.pending[lid][rank]
                if self._removable(lid, plst, rank):
                    w0 = plst.work
                    plst.remove(pnode)
                    self.gc_list_work += plst.work - w0
                    self.work += self.TREEDL_OVERHEAD
                    self.spliced_ranks[lid].add(rank)
                    self.reclaimed += 1
                    progress = True
                else:
                    self.pending[lid][rank] = (plst, pnode)

    def quiesce(self) -> None:
        """Drain the tracker, then splice everything still pending — the
        deferral rule may be bypassed only here, at true quiescence."""
        for plst, pnode in self.rt.drain(self._announced_nowork):
            self._try_splice(plst, pnode)
        # final pass: splice everything still pending (system quiescent)
        for lid in list(self.pending):
            for rank in sorted(self.pending[lid]):
                plst, pnode = self.pending[lid][rank]
                plst.remove(pnode)
                self.spliced_ranks[lid].add(rank)
                self.reclaimed += 1
            self.pending[lid] = {}

    def aux_space_words(self) -> int:
        """RT words plus two per TreeDL-deferred pending node."""
        return super().aux_space_words() + 2 * sum(
            len(p) for p in self.pending.values()
        )


SCHEMES: Dict[str, Callable[..., SchemeBase]] = {
    "ebr": EBRScheme,
    "steam": SteamLFScheme,
    "dlrt": DLRTScheme,
    "slrt": SLRTScheme,
    "bbf": BBFScheme,
}


def make_scheme(name: str, env: MVEnv, **kw) -> SchemeBase:
    """Instantiate a scheme by its registry name (``SCHEMES`` keys)."""
    return SCHEMES[name](env, **kw)
