"""RangeTracker — the range-tracking object of BBF+ [Ben-David et al., 5].

Tracks non-current versions, each tagged with the integer range
``[low, high)`` of timestamps during which it was current.  A tracked version
may be reclaimed once its range contains no announced rtx timestamp.

Faithful to the structure described in the paper (§2, Range-tracking):

* each process appends retired versions to a **local list**; when the list
  reaches size ``B`` (Θ(P log P)) the process performs a **flush**;
* a flush enqueues the local list onto a shared FIFO queue ``Q`` *of lists*,
  then dequeues two lists, merges them (sorted by ``low``), intersects the
  merged list against the sorted current announcements, re-enqueues the
  still-needed versions as one list and returns the obsolete ones;
* amortized O(1) work per ``add`` (each flush is O(P log P) work every
  Θ(P log P) adds) — we account work units accordingly;
* space O(H + P² log P) where H is the max #needed versions (Theorem 1's
  ingredient) — asserted in tests/benchmarks.

The optimization from §6.1 is included: when adding a list to Q we drop
already-obsolete versions.
"""
from __future__ import annotations

import math
from bisect import bisect_left, bisect_right
from collections import deque
from typing import Any, Callable, List, Optional, Sequence, Tuple


class TrackedVersion:
    __slots__ = ("payload", "low", "high")

    def __init__(self, payload: Any, low: float, high: float):
        self.payload = payload  # opaque handle (e.g. a list node)
        self.low = low
        self.high = high

    def intersects(self, sorted_ann: Sequence[float]) -> bool:
        """True iff some announced timestamp a satisfies low <= a < high."""
        i = bisect_left(sorted_ann, self.low)
        return i < len(sorted_ann) and sorted_ann[i] < self.high


class RangeTracker:
    def __init__(self, num_procs: int, batch_size: Optional[int] = None):
        self.P = max(1, num_procs)
        # B = Θ(P log P) per the paper; floor at a small constant so tiny
        # tests still exercise flushes.
        self.B = batch_size or max(4, int(self.P * max(1.0, math.log2(self.P))))
        self.local: List[List[TrackedVersion]] = [[] for _ in range(self.P)]
        self.Q: deque[List[TrackedVersion]] = deque()
        self.work = 0
        self.adds = 0
        self.flushes = 0

    # ------------------------------------------------------------------
    def size(self) -> int:
        return sum(len(l) for l in self.local) + sum(len(l) for l in self.Q)

    def add(
        self,
        pid: int,
        payload: Any,
        low: float,
        high: float,
        announced: Callable[[], List[float]],
    ) -> List[Any]:
        """Register an overwritten version; returns payloads now reclaimable
        (non-empty only when this add triggered a flush)."""
        self.adds += 1
        self.work += 1
        self.local[pid].append(TrackedVersion(payload, low, high))
        if len(self.local[pid]) >= self.B:
            return self.flush(pid, announced)
        return []

    def flush(self, pid: int, announced: Callable[[], List[float]]) -> List[Any]:
        """Flush pid's local list through the shared queue (paper's protocol)."""
        self.flushes += 1
        ann = sorted(announced())
        # Optimization (paper §6.1): drop already-obsolete versions before
        # enqueueing the local list.
        keep, obsolete = self._partition(self.local[pid], ann)
        self.local[pid] = []
        self.Q.append(sorted(keep, key=lambda v: v.low))
        self.work += len(keep) + len(obsolete)
        # Dequeue two lists, merge, intersect with announcements.
        merged: List[TrackedVersion] = []
        for _ in range(2):
            if self.Q:
                merged.extend(self.Q.popleft())
        merged.sort(key=lambda v: v.low)
        self.work += len(merged) + len(ann) * int(math.log2(len(merged) + 2))
        still_needed, newly_obsolete = self._partition(merged, ann)
        if still_needed:
            self.Q.append(still_needed)
        return [v.payload for v in obsolete + newly_obsolete]

    def drain(self, announced: Callable[[], List[float]]) -> List[Any]:
        """Flush everything (used at workload quiescence / shutdown)."""
        out: List[Any] = []
        for pid in range(self.P):
            if self.local[pid]:
                out.extend(self.flush(pid, announced))
        # Keep merging until a full pass over Q frees nothing.
        progress = True
        while progress and self.Q:
            progress = False
            ann = sorted(announced())
            nq: deque[List[TrackedVersion]] = deque()
            while self.Q:
                lst = self.Q.popleft()
                needed, obsolete = self._partition(lst, ann)
                self.work += len(lst)
                if obsolete:
                    progress = True
                    out.extend(v.payload for v in obsolete)
                if needed:
                    nq.append(needed)
            self.Q = nq
        return out

    @staticmethod
    def _partition(
        versions: Sequence[TrackedVersion], sorted_ann: Sequence[float]
    ) -> Tuple[List[TrackedVersion], List[TrackedVersion]]:
        needed, obsolete = [], []
        for v in versions:
            (needed if v.intersects(sorted_ann) else obsolete).append(v)
        return needed, obsolete
