"""Versioned CAS objects (Wei et al. [53]) backed by PDL or SSL version lists.

A vCAS object is a CAS object that additionally supports reading older values
given a timestamp.  ``cas(old, new)`` peeks the head version, validates the
value, and tryAppends a new version stamped with the current global
timestamp; on success the overwritten version (interval ``[old.ts, new.ts)``)
is handed to the active MVGC scheme.  ``read_version(t)`` is the rtx read
path: the latest version with ``ts <= t``.

Per the recorded-once optimization (paper §6.1) a real implementation inlines
the head version into the object; here the head pointer *is* the list head,
which models the same single-indirection layout.
"""
from __future__ import annotations

from typing import Any

from repro.core.sim.pdl import PDL, Node
from repro.core.sim.ssl_list import SSL, SNode, MVEnv


class VCas:
    __slots__ = ("env", "scheme", "lst")

    def __init__(self, env: MVEnv, scheme, init_val: Any, init_ts: float = 0.0):
        self.env = env
        self.scheme = scheme
        self.lst = scheme.new_list()
        scheme.register_list(self.lst)
        node = scheme.new_node(init_ts, init_val)
        ok = self.lst.try_append(self.lst.head, node)
        assert ok

    # -- current-value ops -------------------------------------------------
    def read(self) -> Any:
        return self.lst.peek_head().val

    def head_node(self):
        return self.lst.peek_head()

    def read_version(self, t: float) -> Any:
        """rtx read: latest value whose version timestamp is <= t."""
        return self.lst.search(t)

    def read_version_node(self, t: float):
        """Like :meth:`read_version` but returns the version *node* itself,
        so callers can compare version identity/timestamp (the txn commit
        path's version-wise point-read revalidation, DESIGN.md §9)."""
        return self.lst.search_node(t)

    def cas(self, pid: int, old: Any, new: Any) -> bool:
        h = self.lst.peek_head()
        if h.val is not old and h.val != old:
            return False
        ts = max(self.env.read_ts(), h.ts)
        node = self.scheme.new_node(ts, new)
        if self.lst.try_append(h, node):
            # h is never the sentinel (ctor installs an initial version)
            self.scheme.on_overwrite(pid, self.lst, h, low=h.ts, high=ts)
            return True
        return False

    def cas_from_head(self, pid: int, h, new: Any) -> bool:
        """CAS given an already-peeked head node (saves the re-peek)."""
        ts = max(self.env.read_ts(), h.ts)
        node = self.scheme.new_node(ts, new)
        if self.lst.try_append(h, node):
            self.scheme.on_overwrite(pid, self.lst, h, low=h.ts, high=ts)
            return True
        return False
