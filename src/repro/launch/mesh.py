"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the pod
    axis crosses the DCN; gradient reduction over it is what the int8
    compression path targets."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many local devices exist (tests/examples)."""
    n = len(jax.devices())
    data = min(data, n)
    return jax.make_mesh(
        (data, max(1, min(model, n // data))), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def make_gc_mesh(hosts: int = 0, axis: str = "gc_hosts"):
    """1-D mesh for the sharded MVGC stack (``repro.dist.mvgc``): one
    position per host along ``axis``.  ``hosts=0`` uses every available
    device.  The global-LWM ring all-reduce and the per-shard GC shard_maps
    both run over this axis (DESIGN.md §13)."""
    n = len(jax.devices())
    hosts = n if hosts <= 0 else min(hosts, n)
    return jax.make_mesh(
        (hosts,), (axis,), axis_types=(jax.sharding.AxisType.Auto,),
    )
