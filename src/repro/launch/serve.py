"""Serving driver: MV-Serve engine with batched requests + snapshot readers.

Local run (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
      --batch 4 --steps 32 --gc-policy slrt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.configs.base import RunConfig, SHAPES
from repro.models import transformer as tf
from repro.serve.engine import MVServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--gc-policy", default="slrt",
                    choices=["slrt", "dlrt", "steam", "ebr", "sweep"])
    ap.add_argument("--pin-every", type=int, default=8,
                    help="start a snapshot reader every N steps")
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    run = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                    gc_policy=args.gc_policy, versions_per_slot=16,
                    reader_lanes=8)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    engine = MVServeEngine(cfg, run, params, batch=args.batch,
                           max_len=args.max_len)

    rng = np.random.default_rng(0)
    prompt = jnp.array(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    t0 = time.time()
    engine.prefill(prompt)
    print(f"[prefill] {args.batch}x{args.prompt_len} in {time.time()-t0:.2f}s")

    pins = {}
    for i in range(args.steps):
        toks = engine.step()
        if args.pin_every and i % args.pin_every == 0 and len(pins) < 4:
            lane = len(pins)
            pins[lane] = engine.pin(lane)
            print(f"[rtx] lane {lane} pinned t={pins[lane]}")
        if i % 8 == 0:
            rep = engine.space()
            print(f"step {i:3d}  tokens {np.asarray(toks[:, 0])[:4]}  "
                  f"live_versions {rep['live_versions']}  "
                  f"ring {rep['ring_size']}  overflow {rep['overflows']}")
    for lane, t in pins.items():
        lens = engine.lengths_at(t)
        print(f"[rtx] lane {lane} snapshot@{t}: lengths {np.asarray(lens)}")
        engine.unpin(lane)
    print(f"[done] space report: {engine.space()}")


if __name__ == "__main__":
    main()
