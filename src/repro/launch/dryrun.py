import os
import sys as _sys
# --smoke compiles one tiny cell on a single host device (two for the
# --mesh host2 leg, which proves multi-device host meshes lower/compile);
# everything else fakes a pod's worth of devices.  Must be decided before
# jax imports.
_FAKE_DEVICES = ((2 if "host2" in _sys.argv else 1)
                 if "--smoke" in _sys.argv else 512)
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={_FAKE_DEVICES}" + (
        " " + os.environ["XLA_FLAGS"] if "XLA_FLAGS" in os.environ else ""))
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
  * builds the jitted step (train_step / prefill / MV-Serve decode) with
    production in/out shardings,
  * ``.lower(**ShapeDtypeStructs).compile()`` — success proves the sharding
    config is coherent; failures are bugs,
  * records ``memory_analysis()`` (fits-per-device), ``cost_analysis()``
    (FLOPs/bytes) and the collective-byte census parsed from the optimized
    HLO into ``results/dryrun/<arch>__<shape>__<mesh>.json`` for the roofline
    pass (benchmarks/roofline.py).

Run:  PYTHONPATH=src python -m repro.launch.dryrun --all
      PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k --mesh pod
      PYTHONPATH=src python -m repro.launch.dryrun --smoke   # CI: smallest
          # arch x train_4k on a 1-device host mesh, seconds not minutes
"""
import argparse
import json
import re
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs import list_archs, runnable
from repro.configs.base import SHAPES
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch import specs as S

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+ = (.+?) (all-reduce|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute)(?:-start|-done)?\((.*)$")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_census(hlo: str) -> Dict[str, Dict[str, float]]:
    """Per-op-kind byte counts from the optimized (per-device) HLO.

    Byte model (per device): all-reduce moves ~2x its result bytes on a ring
    (reduce-scatter + all-gather phases); all-gather / all-to-all /
    collective-permute move ~their result bytes; reduce-scatter moves ~its
    operand bytes."""
    out: Dict[str, Dict[str, float]] = {}
    for line in hlo.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        result_shapes, kind, operands = m.groups()
        if kind.endswith("-done"):
            continue
        res_b = _shape_bytes(result_shapes)
        opd_b = _shape_bytes(operands)
        factor = {"all-reduce": 2.0, "all-gather": 1.0, "all-to-all": 1.0,
                  "collective-permute": 1.0, "reduce-scatter": 0.0}[kind]
        moved = factor * res_b + (opd_b if kind == "reduce-scatter" else 0.0)
        d = out.setdefault(kind, {"count": 0, "bytes": 0.0})
        d["count"] += 1
        d["bytes"] += moved
    return out


def _mesh_for(mesh_name: str):
    if mesh_name == "host":          # --smoke: whatever this machine has
        return make_host_mesh(1, 1)
    if mesh_name == "host2":         # --smoke --mesh host2: 2-host data mesh
        return make_host_mesh(2, 1)
    return make_production_mesh(multi_pod=(mesh_name == "multipod"))


def dryrun_cell(arch: str, shape: str, mesh_name: str,
                variant: str = "baseline", **overrides) -> Dict:
    mesh = _mesh_for(mesh_name)
    sh = SHAPES[shape]
    t0 = time.time()
    with jax.set_mesh(mesh):
        if sh.kind == "train":
            step, arg_shapes, in_sh, out_sh = S.build_train_cell(
                arch, mesh, shape, **overrides)
        else:
            step, arg_shapes, in_sh, out_sh = S.build_serve_cell(
                arch, mesh, shape, **overrides)
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=(0,))
        lowered = jitted.lower(*arg_shapes)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):      # older jaxlib returns [dict]
            ca = ca[0] if ca else {}
        ma = compiled.memory_analysis()
        hlo = compiled.as_text()
        # trip-count-aware analysis (XLA's cost_analysis counts while bodies
        # once — verified; analyze_hlo multiplies by known_trip_count)
        hc = analyze_hlo(hlo)
        # persist the HLO for re-analysis without recompiling
        import gzip
        os.makedirs(RESULTS_DIR, exist_ok=True)
        hlo_path = cell_path(arch, shape, mesh_name, variant).replace(
            ".json", ".hlo.gz")
        with gzip.open(hlo_path, "wt") as f:
            f.write(hlo)

    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "variant": variant,
        "chips": int(mesh.devices.size),
        "seq_len": sh.seq_len, "global_batch": sh.global_batch,
        "kind": sh.kind,
        "flops_per_device": float(hc["flops"]),
        "bytes_per_device": float(hc["traffic_bytes"]),
        "fused_bytes_per_device": float(hc["fused_traffic_bytes"]),
        "fused_bf16_bytes_per_device": float(hc["fused_bf16_traffic_bytes"]),
        "transcendentals": float(hc["transcendentals"]),
        "xla_raw_flops": float(ca.get("flops", 0.0)),
        "collectives": hc["collectives"],
        "collective_bytes_per_device": float(hc["collective_bytes"]),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        },
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    }
    return rec


def cell_path(arch: str, shape: str, mesh_name: str,
              variant: str = "baseline") -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    suffix = "" if variant == "baseline" else f"__{variant}"
    return os.path.join(
        RESULTS_DIR, f"{arch}__{shape}__{mesh_name}{suffix}.json")


def run_and_save(arch: str, shape: str, mesh_name: str,
                 variant: str = "baseline", force: bool = False,
                 **overrides) -> Optional[Dict]:
    path = cell_path(arch, shape, mesh_name, variant)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    try:
        rec = dryrun_cell(arch, shape, mesh_name, variant, **overrides)
    except Exception as e:  # record the failure — it is a bug to fix
        rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
               "variant": variant, "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def smallest_arch() -> str:
    """The arch with the fewest parameters (the CI smoke cell)."""
    from repro.configs import get_config
    return min(list_archs(), key=lambda a: get_config(a).param_count())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None,
                    choices=[None, "pod", "multipod", "host", "host2"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="one cell: smallest arch x train_4k on a 1-device "
                         "host mesh (the CI launch-dryrun smoke step); "
                         "combine with --mesh host2 for the 2-host leg run "
                         "by the weekly bench-standard job")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()

    if args.smoke:
        args.arch = args.arch or smallest_arch()
        args.shape = args.shape or "train_4k"
        args.mesh = args.mesh or "host"
        args.variant = ("smoke" if args.mesh == "host"
                        else f"smoke_{args.mesh}")
        args.force = True

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [args.mesh] if args.mesh else ["pod", "multipod"]

    ok = fail = skip = 0
    for arch in archs:
        for shape in shapes:
            if not runnable(arch, shape):
                print(f"SKIP  {arch:24s} {shape:12s} (documented skip)")
                skip += 1
                continue
            for mesh_name in meshes:
                t0 = time.time()
                rec = run_and_save(arch, shape, mesh_name,
                                   variant=args.variant, force=args.force)
                if "error" in rec:
                    fail += 1
                    print(f"FAIL  {arch:24s} {shape:12s} {mesh_name:8s} "
                          f"{rec['error'][:90]}")
                else:
                    ok += 1
                    gf = rec["flops_per_device"] / 1e9
                    cb = rec["collective_bytes_per_device"] / 1e6
                    print(f"OK    {arch:24s} {shape:12s} {mesh_name:8s} "
                          f"{gf:10.1f} GF/dev  coll {cb:8.1f} MB/dev  "
                          f"mem {rec['memory']['argument_bytes']/1e9:6.2f}+"
                          f"{rec['memory']['temp_bytes']/1e9:5.2f} GB  "
                          f"[{time.time()-t0:5.1f}s]")
    print(f"\n{ok} ok, {fail} failed, {skip} skipped")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
