"""HLO-text cost analyzer with while-loop trip-count multiplication.

XLA's built-in ``compiled.cost_analysis()`` counts each while-loop *body
once* (verified by probe — scan length does not change reported FLOPs),
which silently undercounts every scan-over-layers model.  This module parses
the optimized per-device HLO instead:

* **FLOPs**: dot ops as 2 * |result| * |contracted dims| (shapes resolved
  through a per-computation symbol table); elementwise arithmetic at
  1 flop/element; reduces at |input|; fusions/calls recursed; **while bodies
  multiplied by** ``backend_config.known_trip_count`` (with a
  condition-constant fallback).
* **HBM traffic**: per top-level instruction, operands + results — fusion
  internals excluded, which models fused execution; parameters / tuples /
  bitcasts excluded.
* **Collective census**: op kind -> {count, bytes} with the same trip
  multiplication, using the ring byte model (all-reduce 2x result;
  gather/permute/a2a 1x result; reduce-scatter 1x operand).

All numbers are per-device (the compiled module is the per-device SPMD
program).
"""
from __future__ import annotations

import json
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0, "opaque": 0,
}

ELEMENTWISE_1FLOP = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "compare",
    "select", "and", "or", "xor", "not", "negate", "abs", "sign", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "clamp", "remainder",
    "shift-left", "shift-right-logical", "shift-right-arithmetic", "power",
    "atan2",
}
TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "logistic",
                  "sine", "cosine", "exponential-minus-one", "log-plus-one",
                  "erf", "cbrt"}
NO_COST = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
           "after-all", "partition-id", "replica-id", "iota", "copy-start",
           "copy-done", "rng-get-and-update-state", "opt-barrier"}
COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute"}

_SHAPE_TOKEN = re.compile(r"(\w+)\[([\d,]*)\]")
_OPNAME = re.compile(r"^([\w\-]+)\((.*)$")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\s*\{")


def _parse_instr(line: str):
    """Procedural instruction parse — tuple result shapes contain
    ``/*index=N*/`` comments that defeat naive regexes."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq]
    rhs = s[eq + 3:]
    if rhs.startswith("("):            # tuple shape: match parens
        depth = 0
        end = -1
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        shape_text = rhs[:end + 1]
        rest0 = rhs[end + 1:].lstrip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        shape_text = rhs[:sp]
        rest0 = rhs[sp + 1:].lstrip()
    m = _OPNAME.match(rest0)
    if not m:
        return None
    op, rest = m.groups()
    return name, shape_text, op, rest
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_NAMES = re.compile(r"%([\w.\-]+)")


def _shape_elems_bytes(text: str) -> Tuple[int, int]:
    """Total (elements, bytes) over every shape token in `text`."""
    elems = tot = 0
    for dt, dims in _SHAPE_TOKEN.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        tot += n * DTYPE_BYTES[dt]
    return elems, tot


class Instr:
    __slots__ = ("name", "shape_text", "op", "rest", "elems", "bytes",
                 "bytes_bf16")

    def __init__(self, name, shape_text, op, rest):
        self.name = name
        self.shape_text = shape_text
        self.op = op
        self.rest = rest
        self.elems, self.bytes = _shape_elems_bytes(shape_text)
        # bytes if every f32 tensor were bf16: corrects the CPU backend's
        # convert-to-f32 canonicalization of bf16 matmul operands (TPU MXUs
        # consume bf16 directly; the f32 copies are compile-target artifacts)
        self.bytes_bf16 = self._bf16_bytes(shape_text)

    @staticmethod
    def _bf16_bytes(text: str) -> int:
        tot = 0
        for dt, dims in _SHAPE_TOKEN.findall(text):
            if dt not in DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            tot += n * (2 if dt == "f32" else DTYPE_BYTES[dt])
        return tot


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Instr]] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._symtab: Dict[str, Dict[str, Instr]] = {
            cname: {i.name: i for i in instrs}
            for cname, instrs in self.computations.items()
        }
        self._memo: Dict[str, Tuple[float, float, float, Dict]] = {}

    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for line in text.splitlines():
            if cur is None:
                m = _COMP_HDR.match(line.strip())
                if m and "{" in line:
                    cur = m.group(1)
                    self.computations[cur] = []
                    if line.strip().startswith("ENTRY"):
                        self.entry = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
            parsed = _parse_instr(line)
            if parsed:
                self.computations[cur].append(Instr(*parsed))

    # -- cost of one computation (recursive, memoized) ----------------------
    def cost(self, cname: Optional[str] = None):
        """Returns (flops, traffic_bytes, transcendental_elems, census,
        fused_traffic_bytes).

        traffic_bytes: unfused upper bound (every top-level op pays
        operands+results).  fused_traffic_bytes: fused lower bound — only
        dots/convs (operands+result), slices/gathers (2x result), DUS
        (2x update), reduces and collectives pay; elementwise chains are
        assumed fused into their producers, which is the TPU steady state."""
        cname = cname or self.entry
        if cname in self._memo:
            return self._memo[cname]
        flops = traffic = trans = fused = fused16 = 0.0
        census: Dict[str, Dict[str, float]] = {}
        sym = self._symtab.get(cname, {})
        for ins in self.computations.get(cname, []):
            op = ins.op
            if op in NO_COST or op == "parameter":
                continue
            if op == "while":
                body = _BODY.search(ins.rest)
                cond = _COND.search(ins.rest)
                trips = 1
                mt = _TRIP.search(ins.rest)
                if mt:
                    trips = int(mt.group(1))
                bres = self.cost(body.group(1)) if body else (0, 0, 0, {}, 0, 0)
                cres = self.cost(cond.group(1)) if cond else (0, 0, 0, {}, 0, 0)
                (bf, bt, btr, bc, bfu, bfu16) = bres
                (cf, ct, ctr, cc, cfu, cfu16) = cres
                flops += trips * (bf + cf)
                traffic += trips * (bt + ct)
                fused += trips * (bfu + cfu)
                fused16 += trips * (bfu16 + cfu16)
                trans += trips * (btr + ctr)
                for sub in (bc, cc):
                    for k, v in sub.items():
                        d = census.setdefault(k, {"count": 0, "bytes": 0.0})
                        d["count"] += trips * v["count"]
                        d["bytes"] += trips * v["bytes"]
                continue
            if op in ("fusion", "call", "async-start"):
                mcalls = _CALLS.search(ins.rest)
                t_int = 0.0
                if mcalls:
                    f, t_int, tr, cen, fu, fu16 = self.cost(mcalls.group(1))
                    flops += f
                    trans += tr
                    fused += fu
                    fused16 += fu16
                    for k, v in cen.items():
                        d = census.setdefault(k, {"count": 0, "bytes": 0.0})
                        d["count"] += v["count"]
                        d["bytes"] += v["bytes"]
                # traffic: boundary model (operands + result) is right for
                # compute fusions; the internal model is right for gather/
                # slice fusions whose call-site operands include whole tables
                # they barely touch.  min() picks the correct regime.
                t_bnd = ins.bytes + self._operand_bytes(sym, ins)
                traffic += min(t_int, t_bnd) if t_int > 0 else t_bnd
                continue
            if op == "conditional":
                branches = re.findall(r"branch_computations=\{([^}]*)\}", ins.rest)
                names = []
                if branches:
                    names = [b.strip().lstrip("%") for b in branches[0].split(",")]
                else:
                    names = [m for m in
                             re.findall(r"(?:true|false)_computation=%?([\w.\-]+)", ins.rest)]
                if names:
                    costs = [self.cost(n) for n in names]
                    best = max(costs, key=lambda c: c[0])
                    flops += best[0]
                    traffic += best[1]
                    trans += best[2]
                    fused += best[4]
                    fused16 += best[5]
                continue
            if op in COLLECTIVES or any(op == c + "-start" for c in COLLECTIVES):
                kind = op.replace("-start", "")
                res_b = ins.bytes
                opd_b = self._operand_bytes(sym, ins)
                factor = {"all-reduce": 2.0, "all-gather": 1.0,
                          "all-to-all": 1.0, "collective-permute": 1.0,
                          "reduce-scatter": 0.0}[kind]
                moved = factor * res_b + (opd_b if kind == "reduce-scatter" else 0)
                d = census.setdefault(kind, {"count": 0, "bytes": 0.0})
                d["count"] += 1
                d["bytes"] += moved
                traffic += res_b + opd_b
                fused += res_b + opd_b
                fused16 += ins.bytes_bf16 + self._operand_bytes16(sym, ins)
                continue
            if op == "dot":
                mres = ins.elems
                lhs_names = _OPERAND_NAMES.findall(ins.rest.split(")")[0])
                k = 1
                mcon = _CONTRACT.search(ins.rest)
                if mcon and lhs_names and lhs_names[0] in sym:
                    lhs_shape = sym[lhs_names[0]].shape_text
                    dims_m = _SHAPE_TOKEN.search(lhs_shape)
                    if dims_m:
                        dims = [int(d) for d in dims_m.group(2).split(",") if d]
                        for ci in mcon.group(1).split(","):
                            if ci:
                                k *= dims[int(ci)]
                flops += 2.0 * mres * k
                traffic += ins.bytes + self._operand_bytes(sym, ins)
                fused += ins.bytes + self._operand_bytes(sym, ins)
                fused16 += ins.bytes_bf16 + self._operand_bytes16(sym, ins)
                continue
            if op == "convolution":
                # flops ~ 2 * |result| * kernel_elems (per output feature)
                names = _OPERAND_NAMES.findall(ins.rest.split(")")[0])
                kelems = 1
                if len(names) >= 2 and names[1] in sym:
                    kelems = max(1, sym[names[1]].elems)
                flops += 2.0 * ins.elems * kelems
                traffic += ins.bytes + self._operand_bytes(sym, ins)
                fused += ins.bytes + self._operand_bytes(sym, ins)
                fused16 += ins.bytes_bf16 + self._operand_bytes16(sym, ins)
                continue
            if op in ("reduce", "reduce-window"):
                inb = self._operand_bytes(sym, ins)
                flops += self._operand_elems(sym, ins)
                traffic += ins.bytes + inb
                fused += ins.bytes + inb
                fused16 += ins.bytes_bf16 + self._operand_bytes16(sym, ins)
                continue
            if op in ELEMENTWISE_1FLOP:
                flops += ins.elems
                traffic += ins.bytes + self._operand_bytes(sym, ins)
                continue
            if op in TRANSCENDENTAL:
                flops += ins.elems
                trans += ins.elems
                traffic += ins.bytes + self._operand_bytes(sym, ins)
                continue
            if op in ("dynamic-update-slice",):
                # in-place update: traffic = update operand + result window
                names = _OPERAND_NAMES.findall(ins.rest)
                ub = sym[names[1]].bytes if len(names) > 1 and names[1] in sym else 0
                ub16 = sym[names[1]].bytes_bf16 if len(names) > 1 and names[1] in sym else 0
                traffic += 2 * ub
                fused += 2 * ub
                fused16 += 2 * ub16
                continue
            if op in ("slice", "dynamic-slice", "gather"):
                # reads only the selected window, NOT the whole operand — a
                # scan body slicing its layer from the [L, ...] stack touches
                # one layer per trip, and embedding gathers touch rows, so
                # counting full operands would overcount by the stack/table
                # size.  result bytes (read) + result bytes (write).
                traffic += 2 * ins.bytes
                fused += 2 * ins.bytes
                fused16 += 2 * ins.bytes_bf16
                continue
            if op in ("transpose", "reshape", "broadcast", "convert",
                      "bitcast-convert", "reduce-precision", "reverse",
                      "dynamic-reshape"):
                # layout/dtype ops: usually fused away on TPU; charge the
                # result write only
                traffic += ins.bytes
                continue
            if op in ("copy", "concatenate", "pad", "scatter", "sort",
                      "rng", "custom-call", "cholesky", "triangular-solve",
                      "domain", "map", "all-reduce-done", "all-gather-done",
                      "copy-done", "collective-permute-done", "async-done",
                      "log1p"):
                traffic += ins.bytes + self._operand_bytes(sym, ins)
                continue
            # default: treat like elementwise
            flops += ins.elems
            traffic += ins.bytes + self._operand_bytes(sym, ins)

        self._memo[cname] = (flops, traffic, trans, census, fused, fused16)
        return self._memo[cname]

    def _operand_bytes16(self, sym, ins) -> int:
        total = 0
        opnames = _OPERAND_NAMES.findall(ins.rest.split("), ")[0])
        for n in opnames:
            if n in sym:
                total += sym[n].bytes_bf16
        return total

    def _operand_bytes(self, sym, ins) -> int:
        total = 0
        # operand list ends at matching close-paren; heuristically take the
        # text before ', ' attribute markers
        opnames = _OPERAND_NAMES.findall(ins.rest.split("), ")[0])
        for n in opnames:
            if n in sym:
                total += sym[n].bytes
        return total

    def _operand_elems(self, sym, ins) -> int:
        total = 0
        opnames = _OPERAND_NAMES.findall(ins.rest.split("), ")[0])
        for n in opnames:
            if n in sym:
                total += sym[n].elems
        return total


def computation_multipliers(mod: "HloModule") -> Dict[str, int]:
    """Trip multiplier per computation (product of enclosing while trips)."""
    mult: Dict[str, int] = {mod.entry: 1}
    stack = [mod.entry]
    while stack:
        cname = stack.pop()
        m = mult[cname]
        for ins in mod.computations.get(cname, []):
            subs = []
            trips = 1
            if ins.op == "while":
                mt = _TRIP.search(ins.rest)
                trips = int(mt.group(1)) if mt else 1
                b = _BODY.search(ins.rest)
                c = _COND.search(ins.rest)
                subs = [x.group(1) for x in (b, c) if x]
            else:
                mc = _CALLS.search(ins.rest)
                if mc:
                    subs = [mc.group(1)]
            for s in subs:
                if mult.get(s, 0) < m * trips:
                    mult[s] = m * trips
                    stack.append(s)
    return mult


def top_traffic(text: str, n: int = 15):
    """The hillclimb profiler: top-n instructions by fused-traffic x trips."""
    mod = HloModule(text)
    mult = computation_multipliers(mod)
    import re as _re
    rows = []
    for cname, instrs in mod.computations.items():
        m = mult.get(cname, 0)
        if m == 0:
            continue
        sym = mod._symtab[cname]
        for ins in instrs:
            if ins.op in NO_COST:
                continue
            if ins.op == "dot":
                t = ins.bytes + mod._operand_bytes(sym, ins)
            elif ins.op in ("slice", "dynamic-slice", "gather"):
                t = 2 * ins.bytes
            elif ins.op in COLLECTIVES:
                t = ins.bytes + mod._operand_bytes(sym, ins)
            elif ins.op == "dynamic-update-slice":
                names = _OPERAND_NAMES.findall(ins.rest)
                ub = sym[names[1]].bytes if len(names) > 1 and names[1] in sym else 0
                t = 2 * ub
            elif ins.op in ("reduce", "convolution"):
                t = ins.bytes + mod._operand_bytes(sym, ins)
            else:
                continue  # fused model: elementwise/layout excluded
            op_name = ""
            mm = _re.search(r'op_name="([^"]*)"', ins.rest)
            if mm:
                op_name = mm.group(1)
            rows.append((t * m, t, m, ins.op, ins.shape_text[:48], op_name[-80:]))
    rows.sort(reverse=True)
    return rows[:n]


def analyze_hlo(text: str) -> Dict:
    mod = HloModule(text)
    flops, traffic, trans, census, fused, fused16 = mod.cost()
    return {
        "flops": flops,
        "traffic_bytes": traffic,          # unfused upper bound
        "fused_traffic_bytes": fused,      # fused lower bound (CPU dtypes)
        "fused_bf16_traffic_bytes": fused16,  # + f32-convert-artifact correction
        "transcendentals": trans,
        "collectives": census,
        "collective_bytes": sum(v["bytes"] for v in census.values()),
    }
