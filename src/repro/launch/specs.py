"""input_specs + sharding trees for every (arch x shape x mesh) cell.

Everything here is ShapeDtypeStruct-based: no device allocation ever happens
in the dry-run (jax.eval_shape builds the state trees; jit().lower() consumes
the specs)."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config, ARCHS
from repro.configs.base import ModelConfig, RunConfig, SHAPES, ShapeConfig
from repro.dist.sharding import (_keypath_parts, batch_spec, param_shardings)
from repro.models import transformer as tf
from repro.train import step as train_mod
from repro.serve import engine as eng

COMPUTE_DTYPE = jnp.bfloat16


def run_config(arch: str, shape: str, gc_policy: str = "slrt") -> RunConfig:
    cfg = get_config(arch)
    big = cfg.param_count() * 2 > 8e9   # >= ~4B params: shard params over data
    return RunConfig(
        model=cfg, shape=SHAPES[shape], fsdp=big and shape == "train_4k",
        gc_policy=gc_policy,
        microbatches=1,
    )


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins)
# ---------------------------------------------------------------------------
def input_specs(arch: str, shape: str) -> Dict[str, Any]:
    """ShapeDtypeStructs for every model input of this cell."""
    cfg = get_config(arch)
    sh = SHAPES[shape]
    B = sh.global_batch
    if sh.kind == "train":
        T_text = sh.seq_len
        out: Dict[str, Any] = {}
        if cfg.encoder_layers:                      # whisper: frames go to enc
            out["frontend"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_tokens, cfg.d_model), COMPUTE_DTYPE)
        elif cfg.frontend != "none":                # vlm: patch prefix
            T_text = sh.seq_len - cfg.frontend_tokens
            out["frontend"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_tokens, cfg.d_model), COMPUTE_DTYPE)
        out["tokens"] = jax.ShapeDtypeStruct((B, T_text), jnp.int32)
        out["loss_mask"] = jax.ShapeDtypeStruct((B, T_text), jnp.float32)
        return out
    if sh.kind == "prefill":
        T_text = sh.seq_len
        out = {}
        if cfg.encoder_layers:
            out["frontend"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_tokens, cfg.d_model), COMPUTE_DTYPE)
        elif cfg.frontend != "none":
            T_text = sh.seq_len - cfg.frontend_tokens
            out["frontend"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_tokens, cfg.d_model), COMPUTE_DTYPE)
        out["tokens"] = jax.ShapeDtypeStruct((B, T_text), jnp.int32)
        return out
    # decode: one new token against a cache of seq_len
    return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}


# ---------------------------------------------------------------------------
# sharding rules for non-param state
# ---------------------------------------------------------------------------
def _dim_shardable(n: int, mesh: Mesh, axes) -> bool:
    total = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        total *= mesh.shape.get(a, 1)
    return n % total == 0 and n >= total


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def cache_shardings(cache_shapes, mesh: Mesh, cfg: ModelConfig, B: int):
    """Sharding tree for the decode cache pytree (KV/ring/recurrent states).

    Policy: batch over (pod, data) when divisible; KV sequence dim over
    'model' (sequence-parallel decode) unless kv-heads divide the model axis,
    in which case heads go on 'model'.  When the batch can't cover the data
    axes (long_500k B=1) the sequence dim takes BOTH (data, model)."""
    baxes = batch_axes(mesh)
    b_ok = _dim_shardable(B, mesh, baxes)
    heads_on_model = cfg.num_kv_heads % mesh.shape.get("model", 1) == 0 and \
        cfg.num_kv_heads >= mesh.shape.get("model", 1)

    def leaf_spec(path_parts, leaf) -> P:
        name = path_parts[-1]
        shp = leaf.shape
        stacked = path_parts[0] == "sb"          # leading scan dim
        core = shp[1:] if stacked else shp
        bspec = baxes if b_ok else None
        if name in ("k", "v") and len(core) == 4:        # [B, L, H, D]
            if heads_on_model:
                spec = P(bspec, None, "model", None)
            else:
                seq_ax = ("data", "model") if not b_ok and _dim_shardable(
                    core[1], mesh, ("data", "model")) else "model"
                if not _dim_shardable(core[1], mesh, seq_ax):
                    seq_ax = None
                spec = P(bspec, seq_ax, None, None)
        elif name == "pos" and len(core) == 2:            # local ring positions
            seq_ax = "model" if _dim_shardable(core[1], mesh, "model") else None
            spec = P(bspec, seq_ax)
        elif name in ("C",) and len(core) == 4:           # mlstm [B,H,dk,dv]
            spec = P(bspec, None, None, None)
        elif name in ("n",) and len(core) == 3:
            spec = P(bspec, None, None)
        elif name in ("c", "m", "h") and len(core) == 3:  # slstm [B,H,hd]
            spec = P(bspec, None, None)
        elif name == "h" and len(core) == 2:              # rglru [B, w]
            w_ax = "model" if _dim_shardable(core[1], mesh, "model") else None
            spec = P(bspec, w_ax)
        elif name == "conv" and len(core) == 3:           # rglru [B, W-1, w]
            w_ax = "model" if _dim_shardable(core[2], mesh, "model") else None
            spec = P(bspec, None, w_ax)
        else:
            spec = P(*([bspec] + [None] * (len(core) - 1))) if len(core) else P()
        if stacked:
            spec = P(None, *spec)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: leaf_spec(_keypath_parts(kp), leaf), cache_shapes)


def mv_shardings(mv_shapes, mesh: Mesh, B: int):
    """Descriptor store: slots follow the batch sharding; board/ring/scalars
    replicated (they are tiny and read by every shard's GC pass)."""
    baxes = batch_axes(mesh)
    b_ok = _dim_shardable(B, mesh, baxes)

    def leaf_spec(path_parts, leaf) -> P:
        shp = leaf.shape
        if len(shp) >= 1 and shp[0] == B and b_ok and path_parts[0] == "store":
            return NamedSharding(mesh, P(baxes, *([None] * (len(shp) - 1))))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: leaf_spec(_keypath_parts(kp), leaf), mv_shapes)


def batch_shardings(batch_specs, mesh: Mesh, B: int):
    baxes = batch_axes(mesh)
    b_ok = _dim_shardable(B, mesh, baxes)
    bspec = baxes if b_ok else None

    def leaf(x):
        return NamedSharding(mesh, P(*([bspec] + [None] * (len(x.shape) - 1))))

    return jax.tree.map(leaf, batch_specs)


def replicate(tree, mesh: Mesh):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


# ---------------------------------------------------------------------------
# cell builders: (step_fn, arg_specs, in_shardings, out_shardings)
# ---------------------------------------------------------------------------
def _pad_heads_cfg(cfg: ModelConfig, mesh: Mesh) -> ModelConfig:
    """Round head counts up to the model-axis multiple (zero-padded heads in
    deployment): keeps the softmax shard-local where 40-head models would
    otherwise replicate attention 16x."""
    m = mesh.shape.get("model", 1)
    pad = lambda h: ((h + m - 1) // m) * m
    return dataclasses.replace(cfg, num_heads=pad(cfg.num_heads),
                               num_kv_heads=pad(cfg.num_kv_heads),
                               head_dim=cfg.hd)


def build_train_cell(arch: str, mesh: Mesh, shape: str = "train_4k",
                     fsdp: Optional[bool] = None, microbatches: int = 1,
                     attn_hd_shard: bool = False, attn_gather_qkv: bool = False,
                     moe_dispatch: Optional[str] = None,
                     moe_replicate: bool = False, pad_heads: bool = False):
    cfg = get_config(arch)
    if pad_heads:
        cfg = _pad_heads_cfg(cfg, mesh)
    if moe_dispatch:
        cfg = dataclasses.replace(cfg, moe_dispatch=moe_dispatch)
    if attn_gather_qkv:
        cfg = dataclasses.replace(cfg, attn_gather_qkv=True)
    run = run_config(arch, shape)
    if fsdp is not None:
        run = dataclasses.replace(run, fsdp=fsdp)
    if microbatches != 1:
        run = dataclasses.replace(run, microbatches=microbatches)

    state_shapes = jax.eval_shape(
        lambda: train_mod.init_state(cfg, jax.random.PRNGKey(0),
                                     dtype=COMPUTE_DTYPE))
    bspecs = input_specs(arch, shape)

    pshard = param_shardings(state_shapes.params, mesh, fsdp=run.fsdp,
                             attn_hd_shard=attn_hd_shard,
                             moe_replicate=moe_replicate)
    state_shard = train_mod.TrainState(
        params=pshard,
        opt=type(state_shapes.opt)(
            step=NamedSharding(mesh, P()),
            mu=pshard, nu=pshard),
        err=pshard if run.grad_compression else replicate(state_shapes.err, mesh),
        step=NamedSharding(mesh, P()),
    )
    bshard = batch_shardings(bspecs, mesh, SHAPES[shape].global_batch)

    def step(state, batch):
        return train_mod.train_step(state, batch, cfg, run)

    out_shard = (state_shard, None)  # metrics: let XLA choose
    return step, (state_shapes, bspecs), (state_shard, bshard), out_shard


def build_serve_cell(arch: str, mesh: Mesh, shape: str,
                     gc_policy: str = "slrt", attn_hd_shard: bool = False,
                     attn_gather_qkv: bool = False,
                     moe_dispatch: Optional[str] = None,
                     moe_replicate: bool = False, pad_heads: bool = False):
    """decode (serve_step) or prefill cell."""
    cfg = get_config(arch)
    if pad_heads:
        cfg = _pad_heads_cfg(cfg, mesh)
    if moe_dispatch:
        cfg = dataclasses.replace(cfg, moe_dispatch=moe_dispatch)
    if attn_gather_qkv:
        cfg = dataclasses.replace(cfg, attn_gather_qkv=True)
    sh = SHAPES[shape]
    run = run_config(arch, shape, gc_policy)
    B, L = sh.global_batch, sh.seq_len

    params_shapes = jax.eval_shape(
        lambda: tf.init_params(cfg, jax.random.PRNGKey(0), dtype=COMPUTE_DTYPE))
    pshard = param_shardings(params_shapes, mesh, fsdp=False,
                             attn_hd_shard=attn_hd_shard,
                             moe_replicate=moe_replicate)

    if sh.kind == "prefill":
        cache_shapes = jax.eval_shape(
            lambda: tf.init_cache(cfg, B, L, COMPUTE_DTYPE))
        cshard = cache_shardings(cache_shapes, mesh, cfg, B)
        bspecs = input_specs(arch, shape)
        bshard = batch_shardings(bspecs, mesh, B)

        def step(params, cache, batch):
            return tf.prefill(params, cfg, batch["tokens"], cache,
                              frontend_embeds=batch.get("frontend"))

        return (step, (params_shapes, cache_shapes, bspecs),
                (pshard, cshard, bshard), None)

    # decode: full MV-Serve step (model decode + descriptor write + GC)
    state_shapes = jax.eval_shape(
        lambda: eng.make_serve_state(cfg, run, params_shapes, B, L,
                                     COMPUTE_DTYPE))
    cshard = cache_shardings(state_shapes.cache, mesh, cfg, B)
    mvshard = mv_shardings(state_shapes.mv, mesh, B)
    bspec = batch_axes(mesh) if _dim_shardable(B, mesh, batch_axes(mesh)) else None
    sshard = eng.ServeState(
        params=pshard,
        cache=cshard,
        cache_len=NamedSharding(mesh, P(bspec)),
        mv=mvshard,
        last_tokens=NamedSharding(mesh, P(bspec, None)),
    )

    def step(state):
        new_state, toks, freed, stats = eng.decode_one(state, cfg, run)
        return new_state, toks

    return step, (state_shapes,), (sshard,), (sshard, NamedSharding(mesh, P(bspec, None)))
