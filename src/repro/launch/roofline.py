"""Roofline analysis over the dry-run artifacts.

Per (arch x shape x mesh) cell, derives the three terms (seconds/step):

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory     = HLO_bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW

Hardware constants (TPU v5e, from the brief): 197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI.  ``cost_analysis()`` was verified per-device in this
jaxlib (probe: global FLOPs / device_count).  MODEL_FLOPS uses 6*N*D (dense)
or 6*N_active*D (MoE) + attention term, so the useful-compute ratio catches
remat/redundancy waste.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import ARCHS

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s / chip
ICI_BW = 50e9             # B/s / link (per-chip effective, 1-link model)

# Conservative sustained DRAM stream bandwidth for the CPU CI runners that
# time the kernel bench's jit fallback path (BENCH_kernel rows record which
# backend produced their timings).
HOST_DRAM_BW = 25e9       # B/s

# Stated roofline targets for the fused GC/read primitives (BENCH_kernel,
# DESIGN.md §12): the fraction of the timed backend's bandwidth peak each
# kernel is expected to sustain at standard-tier shapes.  The compact sweep
# streams four descriptor tiles per pass but burns O(P) VPU compares per
# element (announcement broadcast), so its stated fraction is below a pure
# copy; search+gather adds a data-dependent row gather per query on top of
# the streaming search, landing lower still.
KERNEL_BW_FRACTION = {
    "compact": 0.50,
    "search_gather": 0.35,
}


def kernel_bandwidth_target(kernel: str, backend: str = "cpu") -> Dict:
    """Per-row roofline target for a BENCH_kernel cell: the stated fraction
    of the timed backend's bandwidth peak (HBM on TPU, sustained DRAM stream
    on the CPU runners).  Returns ``{peak_bw_gb_s, target_frac,
    target_gb_s}`` — the deterministic cells the trajectory gate diffs."""
    if kernel not in KERNEL_BW_FRACTION:
        raise KeyError(f"no stated bandwidth fraction for kernel {kernel!r} "
                       f"(have {sorted(KERNEL_BW_FRACTION)})")
    peak = HBM_BW if backend == "tpu" else HOST_DRAM_BW
    frac = KERNEL_BW_FRACTION[kernel]
    return {
        "peak_bw_gb_s": round(peak / 1e9, 3),
        "target_frac": frac,
        "target_gb_s": round(frac * peak / 1e9, 3),
    }

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def model_flops_per_device(rec: Dict) -> float:
    """Analytic useful FLOPs per device per step (forward[+backward])."""
    cfg = ARCHS[rec["arch"]]
    n_active = cfg.active_param_count()
    B, S = rec["global_batch"], rec["seq_len"]
    chips = 512 if rec["mesh"] == "multipod" else 256
    if rec["kind"] == "train":
        tokens = B * S
        flops = 6 * n_active * tokens           # fwd 2ND + bwd 4ND
        # causal attention term: 6*B*S^2*H*hd per layer (fwd 2 + bwd 4),
        # halved for causality; local layers capped at the window
        attn = 0.0
        for i in range(cfg.num_layers):
            kind = cfg.layer_pattern[i % len(cfg.layer_pattern)]
            if kind in ("attn", "local"):
                span = min(S, cfg.local_window) if kind == "local" and cfg.local_window else S
                attn += 6 * B * S * span * cfg.num_heads * cfg.hd * 0.5 * 2
        flops += attn
    elif rec["kind"] == "prefill":
        tokens = B * S
        flops = 2 * n_active * tokens
        attn = 0.0
        for i in range(cfg.num_layers):
            kind = cfg.layer_pattern[i % len(cfg.layer_pattern)]
            if kind in ("attn", "local"):
                span = min(S, cfg.local_window) if kind == "local" and cfg.local_window else S
                attn += 2 * B * S * span * cfg.num_heads * cfg.hd * 0.5 * 2
        flops += attn
    else:  # decode: one token over a cache of S
        tokens = B * 1
        flops = 2 * n_active * tokens
        attn = 0.0
        for i in range(cfg.num_layers):
            kind = cfg.layer_pattern[i % len(cfg.layer_pattern)]
            if kind in ("attn", "local"):
                span = min(S, cfg.local_window) if kind == "local" and cfg.local_window else S
                attn += 2 * B * 1 * span * cfg.num_heads * cfg.hd * 2
        flops += attn
    return flops / chips


def analyze(rec: Dict) -> Dict:
    if "error" in rec:
        return {**rec, "status": "FAILED"}
    t_comp = rec["flops_per_device"] / PEAK_FLOPS
    # memory term: fused (TPU-like) lower bound with the f32-convert-artifact
    # correction (bf16 matmul operands charged at 2B/elem; the CPU backend
    # materializes f32 copies the MXU pipeline never would); the unfused
    # upper bound is reported alongside (t_memory_unfused_s)
    fused = rec.get("fused_bf16_bytes_per_device",
                    rec.get("fused_bytes_per_device", rec["bytes_per_device"]))
    t_mem = fused / HBM_BW
    t_mem_unfused = rec["bytes_per_device"] / HBM_BW
    t_coll = rec["collective_bytes_per_device"] / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec)
    useful = mf / rec["flops_per_device"] if rec["flops_per_device"] else 0.0
    bound = max(terms.values())
    # roofline fraction: useful model FLOPs vs what the dominant term allows
    frac = (mf / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "variant": rec.get("variant", "baseline"),
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "t_memory_unfused_s": t_mem_unfused,
        "dominant": dominant,
        "model_flops_per_device": mf,
        "useful_flops_ratio": useful,
        "roofline_fraction": frac,
        "hbm_per_device_gb": (rec["memory"]["argument_bytes"]
                              + rec["memory"]["temp_bytes"]) / 1e9,
        "collectives": rec["collectives"],
        "status": "ok",
    }


def load_all(variant: Optional[str] = None) -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if variant is not None and rec.get("variant", "baseline") != variant:
            continue
        out.append(analyze(rec))
    return out


def table(rows: List[Dict]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':8s} {'comp(ms)':>9s} "
           f"{'mem(ms)':>9s} {'coll(ms)':>9s} {'bound':>10s} {'useful':>7s} "
           f"{'roofline':>8s} {'HBM(GB)':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.get("status") != "ok":
            lines.append(f"{r['arch']:24s} {r['shape']:12s} "
                         f"{r.get('mesh', '?'):8s} FAILED: {r.get('error', '')[:60]}")
            continue
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
            f"{r['t_compute_s']*1e3:9.2f} {r['t_memory_s']*1e3:9.2f} "
            f"{r['t_collective_s']*1e3:9.2f} {r['dominant']:>10s} "
            f"{r['useful_flops_ratio']:7.2f} {r['roofline_fraction']:8.3f} "
            f"{r['hbm_per_device_gb']:8.2f}")
    return "\n".join(lines)


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()
    rows = load_all(args.variant)
    print(table(rows))


if __name__ == "__main__":
    main()
