"""Training driver: data -> jitted train_step -> checkpoints, with the full
fault-tolerance loop (watchdog, heartbeat, restart-from-latest, MVGC
checkpoint retention).

Local run (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch minitron-4b --reduced \
      --steps 50 --ckpt-dir /tmp/ckpt
Pod run: launched per host by launch_pod.sh with jax.distributed.initialize.
"""
from __future__ import annotations

import argparse
import functools
import os
import time

import jax
import jax.numpy as jnp

from repro.ckpt.manager import CheckpointManager
from repro.configs import get_config, reduced_config
from repro.configs.base import RunConfig, SHAPES
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.dist.straggler import HeartbeatFile, StepWatchdog
from repro.train.step import TrainState, init_state, train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--simulate-crash-at", type=int, default=-1,
                    help="abort at this step (fault-tolerance demo)")
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    run = RunConfig(model=cfg, shape=SHAPES["train_4k"], lr=args.lr,
                    microbatches=args.microbatches,
                    grad_compression=args.grad_compression)
    data = SyntheticLM(DataConfig(cfg.vocab_size, args.seq_len, args.batch))
    mgr = CheckpointManager(args.ckpt_dir)
    watchdog = StepWatchdog()
    hb = HeartbeatFile(os.path.join(args.ckpt_dir, "heartbeat.json"),
                       host_id=jax.process_index())

    state = init_state(cfg, jax.random.PRNGKey(0),
                       compression=args.grad_compression)
    start = 0
    latest = mgr.latest_step()
    if latest is not None:
        state_raw, extra = mgr.restore(latest, like=state)
        state = TrainState(*state_raw)
        data.load_state_dict(extra)
        start = latest
        print(f"[restore] resumed from step {latest}")

    step_fn = jax.jit(functools.partial(train_step, cfg=cfg, run=run))
    for i in range(start, args.steps):
        watchdog.start()
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        state, metrics = step_fn(state, batch)
        dt = watchdog.stop(i)
        hb.beat(i)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d}  loss {float(metrics['loss']):.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  {dt*1e3:.0f}ms")
        if args.simulate_crash_at == i:
            print(f"[crash] simulated failure at step {i}")
            raise SystemExit(42)
        if (i + 1) % args.ckpt_every == 0 or i == args.steps - 1:
            path = mgr.save(i + 1, state, extra=data.state_dict())
            deleted = mgr.gc(keep_last=2)
            print(f"[ckpt] saved {path}"
                  + (f"; MVGC reclaimed {deleted}" if deleted else ""))
    if watchdog.suspect_steps:
        print(f"[straggler] suspect steps: {watchdog.suspect_steps}")


if __name__ == "__main__":
    main()
