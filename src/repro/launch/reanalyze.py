"""Re-run hlo_cost over the saved .hlo.gz artifacts and refresh the JSONs —
iterate on the cost model without recompiling 66 cells."""
import glob
import gzip
import json
import os
import sys

from repro.launch.hlo_cost import analyze_hlo
from repro.launch.dryrun import RESULTS_DIR


def main() -> None:
    n = 0
    for jpath in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        hpath = jpath.replace(".json", ".hlo.gz")
        if not os.path.exists(hpath):
            continue
        with open(jpath) as f:
            rec = json.load(f)
        if "error" in rec:
            continue
        hc = analyze_hlo(gzip.open(hpath, "rt").read())
        rec["flops_per_device"] = float(hc["flops"])
        rec["bytes_per_device"] = float(hc["traffic_bytes"])
        rec["fused_bytes_per_device"] = float(hc["fused_traffic_bytes"])
        rec["fused_bf16_bytes_per_device"] = float(hc["fused_bf16_traffic_bytes"])
        rec["transcendentals"] = float(hc["transcendentals"])
        rec["collectives"] = hc["collectives"]
        rec["collective_bytes_per_device"] = float(hc["collective_bytes"])
        with open(jpath, "w") as f:
            json.dump(rec, f, indent=1)
        n += 1
    print(f"re-analyzed {n} cells")


if __name__ == "__main__":
    main()
