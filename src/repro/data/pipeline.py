"""Deterministic synthetic token pipeline — shardable, checkpointable.

Generates a learnable synthetic language (Zipfian unigrams + k-gram copy
structure) so ~100M-param training runs show decreasing loss without any
external datasets.  Every batch is a pure function of (seed, step), so (a)
restarts resume bit-exactly from the step counter alone, (b) each data shard
slices the same global batch by its shard index — no coordination needed,
which is how the real multi-host pipeline stays embarrassingly parallel.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    zipf_a: float = 1.2
    copy_period: int = 16    # induction structure: token repeats every period


class SyntheticLM:
    """Iterator over {tokens, loss_mask}; state = step counter only."""

    def __init__(self, cfg: DataConfig, step: int = 0):
        self.cfg = cfg
        self.step = step
        # fixed Zipfian unigram table
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks**cfg.zipf_a
        self._p = p / p.sum()
        self._perm = rng.permutation(cfg.vocab_size)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        base = rng.choice(cfg.vocab_size, size=(cfg.global_batch, cfg.seq_len),
                          p=self._p)
        base = self._perm[base]
        # copy structure: second half of each period repeats the first half
        t = np.arange(cfg.seq_len)
        half = cfg.copy_period // 2
        src = (t // cfg.copy_period) * cfg.copy_period + (t % half)
        copy_pos = (t % cfg.copy_period) >= half
        tokens = np.where(copy_pos[None, :], base[:, src], base)
        return {
            "tokens": tokens.astype(np.int32),
            "loss_mask": np.ones_like(tokens, np.float32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.batch_at(self.step)
        self.step += 1
        return b

    # -- checkpointable state ------------------------------------------------
    def state_dict(self) -> Dict:
        return {"step": self.step}

    def load_state_dict(self, s: Dict) -> None:
        self.step = int(s["step"])

    # -- per-host shard view ---------------------------------------------------
    def shard_batch(self, batch: Dict[str, np.ndarray], shard: int,
                    num_shards: int) -> Dict[str, np.ndarray]:
        n = self.cfg.global_batch // num_shards
        return {k: v[shard * n:(shard + 1) * n] for k, v in batch.items()}
