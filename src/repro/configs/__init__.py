"""Architecture registry: the 10 assigned configs (+ reduced smoke variants).

Sources per the brief; exact dims preserved.  ``runnable(arch, shape)``
encodes the long_500k sub-quadratic skip rules recorded in DESIGN.md §4.
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig, SHAPES, reduced

# --- the 10 assigned architectures ------------------------------------------

XLSTM_125M = ModelConfig(
    name="xlstm-125m", family="ssm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    layer_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    rope=False, proj_factor=2.0, mlstm_chunk=64, tie_embeddings=True,
)  # [arXiv:2405.04517]

GRANITE_MOE_1B = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
    d_ff=512, vocab_size=49155,
    num_experts=32, top_k=8,
)  # [hf:ibm-granite/granite-3.0-1b-a400m-base]

DEEPSEEK_MOE_16B = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102400,
    num_experts=64, num_shared_experts=2, top_k=6,
)  # [arXiv:2401.06066] fine-grained: 2 shared + 64 routed top-6

INTERNVL2_2B = ModelConfig(
    name="internvl2-2b", family="vlm",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
    d_ff=8192, vocab_size=92553,
    frontend="vit_patches", frontend_tokens=256,
)  # [arXiv:2404.16821] InternViT frontend stubbed (precomputed patch embeds)

MINITRON_4B = ModelConfig(
    name="minitron-4b", family="dense",
    num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8,
    d_ff=9216, vocab_size=256000,
)  # [arXiv:2407.14679] pruned nemotron

QWEN25_32B = ModelConfig(
    name="qwen2.5-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=27648, vocab_size=152064, qkv_bias=True,
)  # [hf:Qwen/Qwen2.5] GQA with QKV bias

STARCODER2_7B = ModelConfig(
    name="starcoder2-7b", family="dense",
    num_layers=32, d_model=4608, num_heads=36, num_kv_heads=4,
    d_ff=18432, vocab_size=49152,
    act="gelu", gated_mlp=False,
)  # [arXiv:2402.19173] GQA kv=4, RoPE, classic FFN

GEMMA2_2B = ModelConfig(
    name="gemma2-2b", family="dense",
    num_layers=26, d_model=2304, num_heads=8, num_kv_heads=4,
    d_ff=9216, vocab_size=256000, head_dim=256,
    layer_pattern=("local", "attn"), local_window=4096,
    attn_softcap=50.0, final_softcap=30.0, post_norms=True,
    act="geglu", embed_scale=True,
)  # [arXiv:2408.00118] alternating local/global, logit softcaps

WHISPER_TINY = ModelConfig(
    name="whisper-tiny", family="audio",
    num_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
    d_ff=1536, vocab_size=51865,
    encoder_layers=4, encoder_tokens=1500,
    frontend="audio_frames", frontend_tokens=1500,
    rope=True,  # adaptation: RoPE instead of learned abs positions (DESIGN.md §4)
    act="gelu", gated_mlp=False,
)  # [arXiv:2212.04356] enc-dec; conv frontend stubbed

RECURRENTGEMMA_9B = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    d_ff=12288, vocab_size=256000, head_dim=256,
    layer_pattern=("rglru", "rglru", "local"), local_window=2048,
    rnn_width=4096, conv_width=4, act="geglu", embed_scale=True,
)  # [arXiv:2402.19427] RG-LRU + local MQA, 2:1

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in [
        XLSTM_125M, GRANITE_MOE_1B, DEEPSEEK_MOE_16B, INTERNVL2_2B,
        MINITRON_4B, QWEN25_32B, STARCODER2_7B, GEMMA2_2B, WHISPER_TINY,
        RECURRENTGEMMA_9B,
    ]
}

# long_500k needs sub-quadratic handling of the 524288-token context:
# SSM (O(1) state), hybrid (bounded local windows + RG-LRU), gemma2 (local
# half bounded by window; global half linear per decoded token).  Pure
# full-attention archs and whisper (architecturally bounded decoder) skip it.
LONG_CONTEXT_ARCHS = {"xlstm-125m", "recurrentgemma-9b", "gemma2-2b"}


def get_config(name: str) -> ModelConfig:
    return ARCHS[name]


def list_archs() -> List[str]:
    return list(ARCHS)


def runnable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True


def reduced_config(name: str, **overrides) -> ModelConfig:
    return reduced(ARCHS[name], **overrides)
