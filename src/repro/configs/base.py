"""Configuration schema: model, shapes, mesh, train/serve knobs.

Every assigned architecture is expressed as a ``ModelConfig`` whose
``layer_pattern`` cycles block kinds over the depth — one composable model
framework covers dense / MoE / SSM / hybrid / VLM / enc-dec families.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.core.telemetry import GCConfig

# block kinds understood by repro.models.blocks
KINDS = ("attn", "local", "mlstm", "slstm", "rglru")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    layer_pattern: Tuple[str, ...] = ("attn",)
    # attention
    rope: bool = True
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    attn_softcap: float = 0.0       # gemma2 attention logit softcap
    final_softcap: float = 0.0      # gemma2 final logit softcap
    local_window: int = 0           # sliding window for "local" blocks
    post_norms: bool = False        # gemma2 sandwich norms
    attn_gather_qkv: bool = False   # perf: gather hd-sharded q/k/v so the
                                    # attention core runs shard-local
    # MLP
    act: str = "silu"               # silu | gelu | geglu
    gated_mlp: bool = True          # False: classic 2-matrix FFN (starcoder2, whisper)
    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_capacity_factor: float = 1.25
    moe_dispatch: str = "global"    # global (baseline) | grouped (per-sequence)
    # recurrent (ssm / hybrid)
    conv_width: int = 4             # rglru temporal conv
    rnn_width: Optional[int] = None # rglru recurrent width (default d_model)
    mlstm_chunk: int = 64           # chunkwise-parallel training chunk
    proj_factor: float = 2.0        # mlstm block up-projection
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_tokens: int = 0         # frontend sequence length (enc input)
    # modality frontend stub (vlm / audio): precomputed embeddings arrive as
    # inputs per the brief; this is the token count they occupy
    frontend: str = "none"          # none | vit_patches | audio_frames
    frontend_tokens: int = 0
    # embeddings
    tie_embeddings: bool = True
    embed_scale: bool = False       # gemma-style sqrt(d) embedding scaling
    # norm
    norm_eps: float = 1e-6

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def pattern_repeats(self) -> int:
        return self.num_layers // len(self.layer_pattern)

    @property
    def tail_layers(self) -> int:
        return self.num_layers % len(self.layer_pattern)

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS and sanity checks)."""
        d, hd = self.d_model, self.hd
        n_q, n_kv = self.num_heads, self.num_kv_heads
        attn = d * hd * n_q + 2 * d * hd * n_kv + n_q * hd * d
        if self.qkv_bias:
            attn += hd * (n_q + 2 * n_kv)
        mlp = (3 if self.gated_mlp else 2) * d * self.d_ff
        moe = 0
        if self.num_experts:
            moe = (self.num_experts + self.num_shared_experts) * 3 * d * self.d_ff
            moe += d * self.num_experts  # router
            mlp = 0
        rnn_w = self.rnn_width or d
        kind_params = {
            "attn": attn + mlp + moe,
            "local": attn + mlp + moe,
            "mlstm": int(2.5 * d * int(d * self.proj_factor)) + 4 * (int(d * self.proj_factor)) * hd,
            "slstm": 4 * d * d + 4 * d * hd + d * 2 * d + mlp * 0,
            "rglru": 2 * d * rnn_w + 2 * rnn_w + rnn_w * self.conv_width + rnn_w * d + mlp,
        }
        total = 0
        for i in range(self.num_layers):
            kind = self.layer_pattern[i % len(self.layer_pattern)]
            total += kind_params[kind]
            total += 2 * d  # norms
        total += self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d
        if self.encoder_layers:
            total += self.encoder_layers * (attn + mlp + 2 * d)
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top_k + shared experts only)."""
        if not self.num_experts:
            return self.param_count()
        d = self.d_model
        full_moe = (self.num_experts + self.num_shared_experts) * 3 * d * self.d_ff
        active_moe = (self.top_k + self.num_shared_experts) * 3 * d * self.d_ff
        n_moe_layers = sum(
            1 for i in range(self.num_layers)
            if self.layer_pattern[i % len(self.layer_pattern)] in ("attn", "local")
        )
        return self.param_count() - n_moe_layers * (full_moe - active_moe)


@dataclass(frozen=True)
class ShapeConfig:
    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                 # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


@dataclass(frozen=True)
class RunConfig:
    """Train/serve runtime knobs."""
    model: ModelConfig
    shape: ShapeConfig
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    # distribution
    fsdp: bool = False             # shard params over data axis too (ZeRO-3)
    remat: str = "block"           # none | block
    microbatches: int = 1
    # optimizer
    lr: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    grad_compression: bool = False
    # serving / MVGC.  ``gc`` is the redesigned home of every GC knob
    # (repro.core.telemetry.GCConfig, DESIGN.md §13); the flat fields below
    # remain for one release as deprecated spellings.  When ``gc`` is not
    # passed, ``__post_init__`` assembles it from them, so the two views
    # never disagree — engines read ``run.gc`` only.
    gc: Optional[GCConfig] = None
    gc_policy: str = "slrt"
    versions_per_slot: int = 8
    reader_lanes: int = 16
    page_size: int = 64
    # dispatch GC sweeps / snapshot reads to the fused Pallas kernels
    # (kernel_interpret=True validates them on CPU; set False on TPU)
    use_kernel: bool = False
    kernel_interpret: bool = True
    # retire-ring capacity for the RT policies; 0 = sized from the batch.
    # Undersizing it drops retire records (surfaced as ``dropped_retires``
    # in the engine step stats) — DL-RT can never reclaim a dropped version.
    ring_capacity: int = 0

    def __post_init__(self):
        if self.gc is None:
            gc = GCConfig(
                policy=self.gc_policy,
                versions_per_slot=self.versions_per_slot,
                reader_lanes=self.reader_lanes,
                ring_capacity=self.ring_capacity,
                use_kernel=self.use_kernel,
                kernel_interpret=self.kernel_interpret,
            )
            object.__setattr__(self, "gc", gc)
        else:
            # keep the deprecated flat fields readable either way
            object.__setattr__(self, "gc_policy", self.gc.policy)
            object.__setattr__(self, "versions_per_slot",
                               self.gc.versions_per_slot)
            object.__setattr__(self, "reader_lanes", self.gc.reader_lanes)
            object.__setattr__(self, "ring_capacity", self.gc.ring_capacity)
            object.__setattr__(self, "use_kernel", self.gc.use_kernel)
            object.__setattr__(self, "kernel_interpret",
                               self.gc.kernel_interpret)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    base = dict(
        num_layers=max(2, 2 * len(cfg.layer_pattern)) if cfg.layer_pattern else 2,
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, 4 * cfg.num_kv_heads // max(1, cfg.num_heads)),
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        head_dim=16,
        num_experts=min(cfg.num_experts, 4),
        num_shared_experts=min(cfg.num_shared_experts, 1),
        top_k=min(cfg.top_k, 2),
        local_window=min(cfg.local_window, 16) if cfg.local_window else 0,
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_tokens=min(cfg.encoder_tokens, 16) if cfg.encoder_tokens else 0,
        frontend_tokens=min(cfg.frontend_tokens, 8) if cfg.frontend_tokens else 0,
        rnn_width=64 if cfg.rnn_width else None,
        mlstm_chunk=8,
    )
    # keep the layer pattern but shrink repeats
    base["num_layers"] = max(len(cfg.layer_pattern), 2)
    if len(cfg.layer_pattern) == 1:
        base["num_layers"] = 2
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
