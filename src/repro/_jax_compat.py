"""Forward-compat aliases for older jax releases (0.4.x).

The repo is written against the modern jax surface; on older jax some names
are missing or spelled differently.  ``install()`` backfills them in place so
call sites stay on the modern spelling:

==============================  =============================================
modern name                     0.4.x fallback
==============================  =============================================
``jax.sharding.AxisType``       tiny enum (Auto/Explicit/Manual); mesh axis
                                types did not exist yet, so it is advisory
``jax.make_mesh(axis_types=)``  wrapper that drops the kwarg
``jax.set_mesh(mesh)``          the legacy ``Mesh`` context manager
``jax.P``                       ``jax.sharding.PartitionSpec``
``jax.NamedSharding``           ``jax.sharding.NamedSharding``
``jax.shard_map``               ``jax.experimental.shard_map.shard_map`` with
                                ``check_vma`` mapped onto ``check_rep``
==============================  =============================================

Everything is a no-op on a jax that already provides the modern names, so the
shim can stay installed permanently.
"""
from __future__ import annotations

import enum
import functools
import inspect

import jax
import jax.sharding


def _install_axis_type() -> None:
    if hasattr(jax.sharding, "AxisType"):
        return

    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType


def _install_make_mesh() -> None:
    if not hasattr(jax, "make_mesh"):           # pre-0.4.35
        def make_mesh(axis_shapes, axis_names, *, devices=None,
                      axis_types=None):
            del axis_types
            import numpy as np
            devs = np.asarray(devices if devices is not None else jax.devices())
            return jax.sharding.Mesh(devs.reshape(axis_shapes), axis_names)

        jax.make_mesh = make_mesh
        return
    params = inspect.signature(jax.make_mesh).parameters
    if "axis_types" in params:
        return
    _orig = jax.make_mesh

    @functools.wraps(_orig)
    def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
        del axis_types  # pre-AxisType jax: every axis behaves as Auto
        return _orig(axis_shapes, axis_names, devices=devices)

    jax.make_mesh = make_mesh


def _install_set_mesh() -> None:
    if hasattr(jax, "set_mesh"):
        return

    def set_mesh(mesh):
        """``with jax.set_mesh(m):`` — on 0.4.x the Mesh object itself is the
        context manager that scopes the default mesh."""
        return mesh

    jax.set_mesh = set_mesh


def _install_aliases() -> None:
    if not hasattr(jax, "P"):
        jax.P = jax.sharding.PartitionSpec
    if not hasattr(jax, "NamedSharding"):
        jax.NamedSharding = jax.sharding.NamedSharding


def _install_shard_map() -> None:
    if hasattr(jax, "shard_map"):
        return
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kwargs):
        kwargs.pop("axis_names", None)  # modern-only knob with no 0.4.x analog
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma, **kwargs)

    jax.shard_map = shard_map


def install() -> None:
    _install_axis_type()
    _install_make_mesh()
    _install_set_mesh()
    _install_aliases()
    _install_shard_map()
