from repro.mvkv import paged  # noqa
