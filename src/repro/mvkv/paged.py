"""Multiversioned paged KV cache: COW page tables over a shared page pool.

The missing piece between the descriptor store (`core.mvgc.vstore`) and the
attention kernels (`kernels.decode_attention`): KV lives in fixed-size pages
in a pool; each sequence's **page table is a versioned object** — decode
steps that fill a page (or fork a sequence) write a *new page-table version*;
snapshot readers resolve their pinned timestamp to a page-table version via
``vstore.snapshot_read`` and attend over exactly the pages visible then.
A page is recycled only when no reachable page-table version references it —
computed with the same reachability sweep the paper's GC uses.

Everything is fixed-shape and jit-friendly: page tables live in a dense
``tables[MAX_VERSIONS, MP]`` array indexed by the descriptor payloads; the
free pool is a bitmap with ranked-hole allocation (same trick as the retire
ring).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.mvgc import vstore
from repro.core.mvgc.pool import EMPTY

NO_PAGE = jnp.int32(-1)


class PagedKV(NamedTuple):
    k_pages: jax.Array     # [N, PS, Hkv, D] page pool
    v_pages: jax.Array     # [N, PS, Hkv, D]
    free: jax.Array        # bool[N]  (True = free)
    tables: jax.Array      # i32[MAX_VER, MP] page-table versions (NO_PAGE pad)
    table_free: jax.Array  # bool[MAX_VER] free page-table slots
    lengths: jax.Array     # i32[MAX_VER] tokens covered by each table version
    mv: vstore.MVState     # descriptor store: slot=sequence, payload=table idx

    @property
    def page_size(self) -> int:
        return self.k_pages.shape[1]

    @property
    def max_pages(self) -> int:
        return self.tables.shape[1]


def make_paged_kv(num_seqs: int, num_pages: int, page_size: int,
                  max_pages_per_seq: int, kv_heads: int, head_dim: int,
                  versions_per_seq: int = 8, reader_lanes: int = 8,
                  dtype=jnp.bfloat16) -> PagedKV:
    max_ver = num_seqs * versions_per_seq
    return PagedKV(
        k_pages=jnp.zeros((num_pages, page_size, kv_heads, head_dim), dtype),
        v_pages=jnp.zeros((num_pages, page_size, kv_heads, head_dim), dtype),
        free=jnp.ones((num_pages,), bool),
        tables=jnp.full((max_ver, max_pages_per_seq), NO_PAGE, jnp.int32),
        table_free=jnp.ones((max_ver,), bool),
        lengths=jnp.zeros((max_ver,), jnp.int32),
        mv=vstore.make_state(num_seqs, versions_per_seq, reader_lanes,
                             ring_capacity=max(16, num_seqs * 2)),
    )


def _alloc(free: jax.Array, want: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Rank-match allocation: want[i] lanes get the i-th free slot.
    Returns (new_free, slot_ids[K] (=len(want) with -1 fails), ok[K])."""
    n = free.shape[0]
    pos = jnp.sort(jnp.where(free, jnp.arange(n, dtype=jnp.int32), n))
    rank = jnp.cumsum(want.astype(jnp.int32)) - 1
    ok = want & (rank < free.sum())
    slots = jnp.where(ok, pos[jnp.minimum(rank, n - 1)], -1)
    new_free = free.at[jnp.where(ok, slots, n)].set(False, mode="drop")
    return new_free, slots, ok


def append_tokens(
    st: PagedKV,
    seq_ids: jax.Array,    # i32[B] sequences receiving one token each
    k_new: jax.Array,      # [B, Hkv, D]
    v_new: jax.Array,      # [B, Hkv, D]
    mask: jax.Array,       # bool[B]
    gc_policy: str = "slrt",
) -> Tuple[PagedKV, jax.Array]:
    """One decode step: write each sequence's token into its current page,
    allocating a fresh page (and a new page-table version) at page
    boundaries.  Returns (state', overflow[B]).

    COW discipline: page-table versions are immutable; only the *partial last
    page* is written in place, which is safe because every snapshot's visible
    length caps what readers consume from it."""
    PS = st.page_size
    MP = st.max_pages
    B = seq_ids.shape[0]

    cur_tbl, has = vstore.current_read(st.mv, seq_ids)        # i32[B]
    cur_tbl_safe = jnp.where(has, cur_tbl, 0)
    lengths = jnp.where(has, st.lengths[cur_tbl_safe], 0)     # i32[B]
    page_idx = lengths // PS
    off = lengths % PS
    needs_page = (off == 0) & mask                             # new page needed

    # allocate pages for boundary lanes
    new_free, pages, got_page = _alloc(st.free, needs_page)
    page_of = jnp.where(
        needs_page, pages,
        st.tables[cur_tbl_safe, jnp.minimum(page_idx, MP - 1)])
    ok = mask & jnp.where(needs_page, got_page, page_of >= 0) & (page_idx < MP)

    # write the token into (page_of, off)
    dest_page = jnp.where(ok, page_of, st.k_pages.shape[0])   # OOB = drop
    k_pages = st.k_pages.at[dest_page, off].set(
        k_new.astype(st.k_pages.dtype), mode="drop")
    v_pages = st.v_pages.at[dest_page, off].set(
        v_new.astype(st.v_pages.dtype), mode="drop")

    # page-boundary lanes commit a NEW page-table version (COW)
    tf, tslots, got_tbl = _alloc(st.table_free, needs_page & ok)
    commit = needs_page & ok & got_tbl
    old_rows = st.tables[cur_tbl_safe]                        # [B, MP]
    new_rows = old_rows.at[jnp.arange(B), jnp.minimum(page_idx, MP - 1)].set(
        jnp.where(commit, page_of, old_rows[jnp.arange(B),
                                            jnp.minimum(page_idx, MP - 1)]))
    tdest = jnp.where(commit, tslots, st.tables.shape[0])
    tables = st.tables.at[tdest].set(new_rows, mode="drop")
    table_free = tf

    # lengths: every ok lane advances by 1; table versions own their length
    new_len = lengths + ok.astype(jnp.int32)
    ver_ref = jnp.where(commit, tslots, cur_tbl_safe)
    lengths_arr = st.lengths.at[jnp.where(ok, ver_ref, st.lengths.shape[0])].set(
        new_len, mode="drop")

    # descriptor write: new version (payload = table slot) for commit lanes;
    # in-place length bump lanes keep their current descriptor version
    mv, freed, ovf = vstore.write_step(
        st.mv, seq_ids, ver_ref, commit, policy=gc_policy)
    mv, freed2 = vstore.gc_step(mv, policy=gc_policy)
    freed_all = jnp.concatenate([freed.reshape(-1), freed2.reshape(-1)])

    # recycle table slots whose descriptor versions were collected, then
    # recycle pages unreachable from any live table version
    table_free = table_free.at[
        jnp.where(freed_all != EMPTY, freed_all, table_free.shape[0])
    ].set(True, mode="drop")
    free_pages = _sweep_unreferenced(tables, table_free, new_free)

    st2 = PagedKV(k_pages, v_pages, free_pages, tables, table_free,
                  lengths_arr, mv)
    return st2, mask & ~ok


def _sweep_unreferenced(tables, table_free, page_free) -> jax.Array:
    """A page is live iff referenced by any live table version — the paper's
    reachability sweep at page granularity (one scatter, no traversal)."""
    n_pages = page_free.shape[0]
    live_refs = jnp.where(table_free[:, None], NO_PAGE, tables).reshape(-1)
    referenced = jnp.zeros((n_pages,), bool).at[
        jnp.where(live_refs >= 0, live_refs, n_pages)
    ].set(True, mode="drop")
    return ~referenced


def snapshot_view(st: PagedKV, seq_ids: jax.Array, t: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    """Resolve a pinned timestamp to (page_table[B, MP], lengths[B]) — the
    rtx read: feed straight into kernels.decode_attention.paged_decode."""
    tbl_idx, found = vstore.snapshot_read(st.mv, seq_ids, t)
    tbl_safe = jnp.where(found, tbl_idx, 0)
    tables = jnp.where(found[:, None], st.tables[tbl_safe], NO_PAGE)
    # visible length is capped at the snapshot's table version
    lengths = jnp.where(found, st.lengths[tbl_safe], 0)
    return tables, lengths


def begin_snapshot(st: PagedKV, lane: jax.Array) -> Tuple[PagedKV, jax.Array]:
    mv, ts = vstore.begin_snapshot(st.mv, jnp.atleast_1d(lane),
                                   jnp.array([True]))
    return st._replace(mv=mv), ts[0]


def end_snapshot(st: PagedKV, lane: jax.Array) -> PagedKV:
    mv = vstore.end_snapshot(st.mv, jnp.atleast_1d(lane), jnp.array([True]))
    return st._replace(mv=mv)


def live_pages(st: PagedKV) -> jax.Array:
    return (~st.free).sum()
