"""Multiversioned paged KV cache: COW page tables over a shared page pool.

The missing piece between the descriptor store (`core.mvgc.vstore`) and the
attention kernels (`kernels.decode_attention`): KV lives in fixed-size pages
in a pool; each sequence's **page table is a versioned object** — decode
steps that fill a page (or fork a sequence) write a *new page-table version*;
snapshot readers resolve their pinned timestamp to a page-table version via
``vstore.snapshot_read`` and attend over exactly the pages visible then.
A page is recycled only when no reachable page-table version references it —
computed with the same reachability sweep the paper's GC uses.

Everything is fixed-shape and jit-friendly: page tables live in a dense
``tables[MAX_VERSIONS, MP]`` array indexed by the descriptor payloads; the
free pool is a bitmap with ranked-hole allocation (same trick as the retire
ring).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.mvgc import vstore
from repro.core.mvgc.pool import EMPTY
from repro.core.telemetry import GCConfig, PressureSignal, resolve_gc_config

NO_PAGE = jnp.int32(-1)


class PagedKV(NamedTuple):
    k_pages: jax.Array     # [N, PS, Hkv, D] page pool
    v_pages: jax.Array     # [N, PS, Hkv, D]
    free: jax.Array        # bool[N]  (True = free)
    tables: jax.Array      # i32[MAX_VER, MP] page-table versions (NO_PAGE pad)
    table_free: jax.Array  # bool[MAX_VER] free page-table slots
    lengths: jax.Array     # i32[MAX_VER] tokens covered by each table version
    mv: vstore.MVState     # descriptor store: slot=sequence, payload=table idx

    @property
    def page_size(self) -> int:
        return self.k_pages.shape[1]

    @property
    def max_pages(self) -> int:
        return self.tables.shape[1]


def make_paged_kv(num_seqs: int, num_pages: int, page_size: int,
                  max_pages_per_seq: int, kv_heads: int, head_dim: int,
                  versions_per_seq: Optional[int] = None,
                  reader_lanes: Optional[int] = None,
                  ring_capacity: Optional[int] = None, dtype=jnp.bfloat16,
                  *, gc: Optional[GCConfig] = None) -> PagedKV:
    """Build an empty paged-KV state.  GC sizing comes from ``gc``
    (:class:`repro.core.telemetry.GCConfig`); the old ``versions_per_seq`` /
    ``reader_lanes`` / ``ring_capacity`` kwargs still work but are deprecated
    (DESIGN.md §13 migration table)."""
    cfg = resolve_gc_config(gc, "make_paged_kv",
                            versions_per_slot=versions_per_seq,
                            reader_lanes=reader_lanes,
                            ring_capacity=ring_capacity)
    max_ver = num_seqs * cfg.versions_per_slot
    # Reclamation is pressure-driven (no per-append cadence GC), so the
    # retire ring must absorb every close between two pressure flushes —
    # up to one per slab entry plus the in-flight step.  An undersized ring
    # drops retire records (`dropped_retires`), which the DLRT policy can
    # never recover (its reclaim walks only the ring); size it to the slab
    # by default and let callers shrink it deliberately.
    ring = cfg.ring_capacity if cfg.ring_capacity > 0 else max(16, 2 * max_ver)
    return PagedKV(
        k_pages=jnp.zeros((num_pages, page_size, kv_heads, head_dim), dtype),
        v_pages=jnp.zeros((num_pages, page_size, kv_heads, head_dim), dtype),
        free=jnp.ones((num_pages,), bool),
        tables=jnp.full((max_ver, max_pages_per_seq), NO_PAGE, jnp.int32),
        table_free=jnp.ones((max_ver,), bool),
        lengths=jnp.zeros((max_ver,), jnp.int32),
        mv=vstore.make_state(num_seqs, cfg.versions_per_slot,
                             cfg.reader_lanes, ring_capacity=ring),
    )


def _alloc(free: jax.Array, want: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Rank-match allocation: want[i] lanes get the i-th free slot.
    Returns (new_free, slot_ids[K] (=len(want) with -1 fails), ok[K])."""
    n = free.shape[0]
    pos = jnp.sort(jnp.where(free, jnp.arange(n, dtype=jnp.int32), n))
    rank = jnp.cumsum(want.astype(jnp.int32)) - 1
    ok = want & (rank < free.sum())
    slots = jnp.where(ok, pos[jnp.minimum(rank, n - 1)], -1)
    new_free = free.at[jnp.where(ok, slots, n)].set(False, mode="drop")
    return new_free, slots, ok


def append_tokens(
    st: PagedKV,
    seq_ids: jax.Array,    # i32[B] sequences receiving one token each
    k_new: jax.Array,      # [B, Hkv, D]
    v_new: jax.Array,      # [B, Hkv, D]
    mask: jax.Array,       # bool[B]
    gc_policy: str = "slrt",
    use_kernel: bool = False,
    interpret: bool = True,
    extra_pins: Optional[jax.Array] = None,
) -> Tuple[PagedKV, jax.Array]:
    """One decode step: write each sequence's token into its current page,
    allocating a fresh page at page boundaries, and commit a **new page-table
    version for every appended token** (COW).  Returns (state', failed[B]).

    Versioning every append (not just page boundaries) is what makes the rtx
    contract hold: the visible *length* lives on the table version, so a
    pinned snapshot's length can never grow underneath it.  Only the partial
    last page's slot at ``off`` is written in place — safe because every live
    table version's length is <= ``off``, so no reader can see the cell until
    a later version publishes it.  A lane fails (returned mask True) when the
    page pool, the table-slot pool, or the descriptor slab cannot take the
    append — the caller reclaims under pressure and retries
    (`reclaim_on_pressure`), the paper's abort => reclaim => retry loop."""
    PS = st.page_size
    MP = st.max_pages
    B = seq_ids.shape[0]
    MAX_VER = st.tables.shape[0]

    cur_tbl, has = vstore.current_read(st.mv, seq_ids)        # i32[B]
    cur_tbl_safe = jnp.where(has, cur_tbl, 0)
    lengths = jnp.where(has, st.lengths[cur_tbl_safe], 0)     # i32[B]
    page_idx = lengths // PS
    off = lengths % PS
    needs_page = (off == 0) & mask                             # new page needed

    # allocate pages for boundary lanes
    new_free, pages, got_page = _alloc(st.free, needs_page)
    page_of = jnp.where(
        needs_page, pages,
        st.tables[cur_tbl_safe, jnp.minimum(page_idx, MP - 1)])
    ok = mask & jnp.where(needs_page, got_page, page_of >= 0) & (page_idx < MP)

    # write the token into (page_of, off)
    dest_page = jnp.where(ok, page_of, st.k_pages.shape[0])   # OOB = drop
    k_pages = st.k_pages.at[dest_page, off].set(
        k_new.astype(st.k_pages.dtype), mode="drop")
    v_pages = st.v_pages.at[dest_page, off].set(
        v_new.astype(st.v_pages.dtype), mode="drop")

    # every ok lane commits a NEW page-table version (COW row copy; fresh
    # sequences start from an all-NO_PAGE row, not slot 0's content)
    tf, tslots, got_tbl = _alloc(st.table_free, ok)
    commit = ok & got_tbl
    old_rows = jnp.where(has[:, None], st.tables[cur_tbl_safe], NO_PAGE)
    pcol = jnp.minimum(page_idx, MP - 1)
    new_rows = old_rows.at[jnp.arange(B), pcol].set(
        jnp.where(needs_page & commit, page_of, old_rows[jnp.arange(B), pcol]))
    tdest = jnp.where(commit, tslots, MAX_VER)
    tables = st.tables.at[tdest].set(new_rows, mode="drop")

    # the new table version owns the advanced length
    lengths_arr = st.lengths.at[tdest].set(lengths + 1, mode="drop")

    # descriptor write: one new version (payload = table slot) per commit
    # lane.  No cadence GC here: the serving path reclaims only under
    # pressure (`reclaim_on_pressure`, the turso LWM rule) — paying a full
    # collection pass per decoded token is exactly the practical cost the
    # paper's schemes avoid.  Steam is the exception by design: its sweep
    # rides inside `write_step` itself (compact-on-write), so `freed` below
    # is nonempty for steam even without a pressure event.
    mv, freed, ovf = vstore.write_step(
        st.mv, seq_ids, tslots, commit, policy=gc_policy,
        use_kernel=use_kernel, interpret=interpret, extra_pins=extra_pins)
    freed_all = freed.reshape(-1)

    # a lane whose descriptor append overflowed must hand its table slot back
    # (otherwise retries leak unreferenced-but-allocated slots)
    table_free = tf.at[
        jnp.where(commit & ovf, tslots, MAX_VER)
    ].set(True, mode="drop")

    # recycle table slots whose descriptor versions were collected, then
    # recycle pages unreachable from any live table version
    table_free = table_free.at[
        jnp.where(freed_all != EMPTY, freed_all, MAX_VER)
    ].set(True, mode="drop")
    free_pages = _sweep_unreferenced(tables, table_free, new_free)

    st2 = PagedKV(k_pages, v_pages, free_pages, tables, table_free,
                  lengths_arr, mv)
    return st2, mask & ~(commit & ~ovf)


def reset_sequence(
    st: PagedKV,
    seq_ids: jax.Array,    # i32[B] sequence slots being recycled
    mask: jax.Array,       # bool[B]
    gc_policy: str = "slrt",
    use_kernel: bool = False,
    interpret: bool = True,
    extra_pins: Optional[jax.Array] = None,
) -> Tuple[PagedKV, jax.Array]:
    """Sequence completion: commit a new *empty* page-table version (zero
    pages, zero length) so the slot can serve the next request.  Returns
    (state', failed[B]).  The old pages are **not** freed here — they stay
    pinned by the stale table versions until the GC policy collects them
    (and by any snapshot still reading the finished sequence); this is the
    dominant page-release path of a continuous-decode storm, and exactly why
    pool pressure must drive descriptor compaction."""
    MAX_VER = st.tables.shape[0]
    B = seq_ids.shape[0]
    tf, tslots, got = _alloc(st.table_free, mask)
    ok = mask & got
    tdest = jnp.where(ok, tslots, MAX_VER)
    tables = st.tables.at[tdest].set(
        jnp.full((B, st.max_pages), NO_PAGE, jnp.int32), mode="drop")
    lengths_arr = st.lengths.at[tdest].set(0, mode="drop")
    mv, freed, ovf = vstore.write_step(
        st.mv, seq_ids, tslots, ok, policy=gc_policy,
        use_kernel=use_kernel, interpret=interpret, extra_pins=extra_pins)
    table_free = tf.at[jnp.where(ok & ovf, tslots, MAX_VER)].set(
        True, mode="drop")
    table_free = table_free.at[
        jnp.where(freed != EMPTY, freed, MAX_VER)
    ].set(True, mode="drop")
    free_pages = _sweep_unreferenced(tables, table_free, st.free)
    st2 = PagedKV(st.k_pages, st.v_pages, free_pages, tables, table_free,
                  lengths_arr, mv)
    return st2, mask & ~(ok & ~ovf)


def fork_sequence(
    st: PagedKV,
    src_ids: jax.Array,    # i32[B] parent sequences
    dst_ids: jax.Array,    # i32[B] child sequence slots
    mask: jax.Array,       # bool[B]
    gc_policy: str = "slrt",
    use_kernel: bool = False,
    interpret: bool = True,
    extra_pins: Optional[jax.Array] = None,
    copy_pages: bool = False,
) -> Tuple[PagedKV, jax.Array]:
    """COW fork: the child's first page-table version *shares every page*
    with the parent's current version, except a *partial last page*, which is
    copied — both sides append in place at the tail, so a shared partial page
    would let the child clobber the parent's next token (and vice versa).
    Full pages stay shared: they are immutable once published.  Returns
    (state', failed[B]).  Shared pages stay live until no reachable table
    version of *either* sequence references them — the reachability sweep
    needs no refcounts for this, exactly the property the paper's GC
    exploits.

    ``copy_pages=True`` (static) is the **eager-copy control**: the child
    deep-copies *every* page the parent references instead of sharing the
    full ones — the fork semantics of a non-COW cache.  Nothing downstream
    changes (same table-version commit, same sweep); the only difference is
    page demand, which is exactly what ``benchmarks/fork_bench.py`` measures
    COW against (DESIGN.md §14)."""
    MAX_VER = st.tables.shape[0]
    PS = st.page_size
    MP = st.max_pages
    B = src_ids.shape[0]
    N_PAGES = st.k_pages.shape[0]
    src_tbl, has = vstore.current_read(st.mv, src_ids)
    src_safe = jnp.where(has, src_tbl, 0)
    src_len = jnp.where(has, st.lengths[src_safe], 0)
    off = src_len % PS
    pcol = jnp.minimum(src_len // PS, MP - 1)

    if copy_pages:
        # eager control: allocate + copy every page the parent covers
        n_used = (src_len + PS - 1) // PS
        want2d = ((jnp.arange(MP, dtype=jnp.int32)[None, :] < n_used[:, None])
                  & (mask & has)[:, None])
        free2, cflat, got = _alloc(st.free, want2d.reshape(-1))
        got2d = got.reshape(B, MP)
        lane_ok = mask & has & (got2d | ~want2d).all(axis=1)
        tf, tslots, got_t = _alloc(st.table_free, lane_ok)
        ok = lane_ok & got_t
        # hand back pages allocated for lanes that didn't fully make it
        # (partial page allocation at pool exhaustion, or no table slot)
        giveback = got & ~jnp.repeat(ok, MP)
        free2 = free2.at[jnp.where(giveback, cflat, N_PAGES)].set(
            True, mode="drop")
        do_copy2d = want2d & ok[:, None]
        rows = jnp.where(do_copy2d, cflat.reshape(B, MP), NO_PAGE)
        src_flat = jnp.maximum(st.tables[src_safe], 0).reshape(-1)
        cdest = jnp.where(do_copy2d.reshape(-1), cflat, N_PAGES)
        k_pages = st.k_pages.at[cdest].set(st.k_pages[src_flat], mode="drop")
        v_pages = st.v_pages.at[cdest].set(st.v_pages[src_flat], mode="drop")
    else:
        needs_copy = mask & has & (off > 0)

        free2, cpages, got_page = _alloc(st.free, needs_copy)
        ok0 = mask & has & (~needs_copy | got_page)
        tf, tslots, got = _alloc(st.table_free, ok0)
        ok = ok0 & got

        rows = jnp.where(ok[:, None], st.tables[src_safe], NO_PAGE)
        do_copy = needs_copy & ok
        rows = rows.at[jnp.arange(B), pcol].set(
            jnp.where(do_copy, cpages, rows[jnp.arange(B), pcol]))
        src_page = st.tables[src_safe, pcol]
        src_page_safe = jnp.maximum(src_page, 0)
        cdest = jnp.where(do_copy, cpages, N_PAGES)
        k_pages = st.k_pages.at[cdest].set(st.k_pages[src_page_safe],
                                           mode="drop")
        v_pages = st.v_pages.at[cdest].set(st.v_pages[src_page_safe],
                                           mode="drop")

    tdest = jnp.where(ok, tslots, MAX_VER)
    tables = st.tables.at[tdest].set(rows, mode="drop")
    lengths_arr = st.lengths.at[tdest].set(src_len, mode="drop")

    mv, freed, ovf = vstore.write_step(
        st.mv, dst_ids, tslots, ok, policy=gc_policy,
        use_kernel=use_kernel, interpret=interpret, extra_pins=extra_pins)
    table_free = tf.at[jnp.where(ok & ovf, tslots, MAX_VER)].set(
        True, mode="drop")
    table_free = table_free.at[
        jnp.where(freed != EMPTY, freed, MAX_VER)
    ].set(True, mode="drop")
    free_pages = _sweep_unreferenced(tables, table_free, free2)
    st2 = PagedKV(k_pages, v_pages, free_pages, tables, table_free,
                  lengths_arr, mv)
    return st2, mask & ~(ok & ~ovf)


# ---------------------------------------------------------------------------
# Pressure path (DESIGN.md §11): pool watermark -> hot sequences -> reclaim
# ---------------------------------------------------------------------------
#: Deprecated alias: ``page_pressure`` now returns the unified
#: :class:`repro.core.telemetry.PressureSignal` (DESIGN.md §13).  The old
#: fields survive as properties: ``free_pages`` = capacity - live,
#: ``free_frac`` = 1 - level.
PagePressure = PressureSignal


def page_pressure(st: PagedKV, watermark: float = 0.25) -> PressureSignal:
    """Free-bitmap popcount under the watermark = pool pressure.  The deficit
    is measured in pages; `reclaim_on_pressure` chases it by freeing stale
    descriptor versions (each stale table version pins >= 0 pages).  Returns
    the unified :class:`repro.core.telemetry.PressureSignal` (``level`` is
    the occupied fraction of the pool)."""
    n = st.free.shape[0]
    lo = max(1, int(watermark * n))
    free = st.free.sum()
    return PressureSignal(
        level=1.0 - free.astype(jnp.float32) / n,
        under_pressure=free < lo,
        deficit=jnp.maximum(lo - free, 0),
        live=(jnp.int32(n) - free).astype(jnp.int32),
        capacity=jnp.int32(n),
    )


def hot_sequences(st: PagedKV, k: int) -> jax.Array:
    """Sequences holding the most live descriptor versions — the hot set for
    pressure-driven compaction (most stale table versions = most pinned-but-
    dead pages).  Delegates to `vstore.hot_slots` (slot = sequence)."""
    return vstore.hot_slots(st.mv, k)


def reclaim_on_pressure(
    st: PagedKV,
    hot_keys: jax.Array,   # i32[K] hot sequence ids (-1 = inert lane)
    deficit: jax.Array,    # i32[] pages wanted (page_pressure().deficit)
    gc_policy: str = "slrt",
    use_kernel: bool = False,
    interpret: bool = True,
    extra_pins: Optional[jax.Array] = None,
    ckpt_max: Optional[jax.Array] = None,
) -> Tuple[PagedKV, jax.Array]:
    """Synchronous page reclamation: hot-sequence-first descriptor compaction
    (`vstore.reclaim_on_pressure`), recycle the table slots whose descriptor
    versions were collected, then the reachability sweep recycles every page
    no live table version references.  Returns (state', pages_freed).

    The version deficit is the page deficit: every freed descriptor version
    releases exactly one table version which un-pins up to MP pages, so
    chasing ``deficit`` versions is a conservative target for ``deficit``
    pages.

    ``ckpt_max`` (optional, DESIGN.md §14) additionally evicts idle
    sole-survivor sequences whose current version is durably checkpointed —
    pages no policy can otherwise touch, because current versions are always
    needed."""
    MAX_VER = st.tables.shape[0]
    mv, freed, _ = vstore.reclaim_on_pressure(
        st.mv, hot_keys, deficit, policy=gc_policy,
        use_kernel=use_kernel, interpret=interpret, extra_pins=extra_pins,
        ckpt_max=ckpt_max)
    table_free = st.table_free.at[
        jnp.where(freed != EMPTY, freed, MAX_VER)
    ].set(True, mode="drop")
    free_pages = _sweep_unreferenced(st.tables, table_free, st.free)
    pages_freed = free_pages.sum() - st.free.sum()
    return (
        st._replace(mv=mv, table_free=table_free, free=free_pages),
        pages_freed,
    )


def evict_checkpointed(
    st: PagedKV,
    ckpt_max: jax.Array,   # i32[] highest durably checkpointed ts (EMPTY=none)
    extra_pins: Optional[jax.Array] = None,
) -> Tuple[PagedKV, jax.Array, jax.Array]:
    """turso's sole-survivor rule at page granularity (DESIGN.md §14): evict
    every sequence whose *only* version is durably checkpointed
    (``ts <= ckpt_max``) and unpinned, recycle its table slot, and sweep the
    pages it held.  Returns (state', pages_freed, versions_evicted).

    This frees pages **no GC policy can reach** — current versions are always
    needed — which is exactly what makes checkpoint coupling a new
    reclamation edge rather than a faster policy.  An evicted sequence reads
    as having no current version until ``restore()``d or rewritten; callers
    must only advertise a checkpoint they can actually restore from."""
    MAX_VER = st.tables.shape[0]
    mv, freed, n_ev = vstore.evict_checkpointed(st.mv, ckpt_max, extra_pins)
    table_free = st.table_free.at[
        jnp.where(freed != EMPTY, freed, MAX_VER)
    ].set(True, mode="drop")
    free_pages = _sweep_unreferenced(st.tables, table_free, st.free)
    pages_freed = free_pages.sum() - st.free.sum()
    return (
        st._replace(mv=mv, table_free=table_free, free=free_pages),
        pages_freed,
        n_ev,
    )


def _sweep_unreferenced(tables, table_free, page_free) -> jax.Array:
    """A page is live iff referenced by any live table version — the paper's
    reachability sweep at page granularity (one scatter, no traversal)."""
    n_pages = page_free.shape[0]
    live_refs = jnp.where(table_free[:, None], NO_PAGE, tables).reshape(-1)
    referenced = jnp.zeros((n_pages,), bool).at[
        jnp.where(live_refs >= 0, live_refs, n_pages)
    ].set(True, mode="drop")
    return ~referenced


def snapshot_view(st: PagedKV, seq_ids: jax.Array, t: jax.Array,
                  use_kernel: bool = False, interpret: bool = True,
                  ) -> Tuple[jax.Array, jax.Array]:
    """Resolve a pinned timestamp to (page_table[B, MP], lengths[B]) — the
    rtx read: feed straight into kernels.decode_attention.paged_decode.

    Built on the fused search+gather primitive: the visible length rides
    along as an extra value column, so one launch resolves search(t) AND
    fetches each hit's page-table row + length (no search-then-index)."""
    MP = st.max_pages
    values = jnp.concatenate([st.tables, st.lengths[:, None]], axis=1)
    rows, _, found = vstore.snapshot_gather(
        st.mv, seq_ids, t, values, use_kernel=use_kernel, interpret=interpret)
    # not-found rows come back EMPTY-filled (== NO_PAGE for the table part);
    # the visible length is capped at the snapshot's table version
    tables = rows[:, :MP]
    lengths = jnp.where(found, rows[:, MP], 0)
    return tables, lengths


def begin_snapshot(st: PagedKV, lane: jax.Array) -> Tuple[PagedKV, jax.Array]:
    mv, ts = vstore.begin_snapshot(st.mv, jnp.atleast_1d(lane),
                                   jnp.array([True]))
    return st._replace(mv=mv), ts[0]


def end_snapshot(st: PagedKV, lane: jax.Array) -> PagedKV:
    mv = vstore.end_snapshot(st.mv, jnp.atleast_1d(lane), jnp.array([True]))
    return st._replace(mv=mv)


def live_pages(st: PagedKV) -> jax.Array:
    return (~st.free).sum()
