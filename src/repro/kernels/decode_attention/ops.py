"""jit'd public wrapper for paged decode attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention.kernel import paged_decode_pallas
from repro.kernels.decode_attention.ref import paged_decode_ref


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def paged_decode(
    q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
    page_table: jax.Array, lengths: jax.Array, *,
    use_kernel: bool = True, interpret: bool = True,
) -> jax.Array:
    if use_kernel:
        return paged_decode_pallas(
            q, k_pages, v_pages, page_table, lengths, interpret=interpret)
    return paged_decode_ref(q, k_pages, v_pages, page_table, lengths)
