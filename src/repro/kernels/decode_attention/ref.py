"""Pure-jnp oracle: flash-decode over a paged, versioned KV pool.

One new query token per sequence attends to ``length`` cached tokens whose KV
live in pages selected by a page table — the page table entries being exactly
the payload handles returned by the MVGC snapshot read (the rtx read path at
serving scale).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_decode_ref(
    q: jax.Array,           # [B, Hq, D] one query token per sequence
    k_pages: jax.Array,     # [N, PS, Hkv, D] page pool
    v_pages: jax.Array,     # [N, PS, Hkv, D]
    page_table: jax.Array,  # i32[B, MP] page ids per sequence (padded arbitrary)
    lengths: jax.Array,     # i32[B] valid token count per sequence
) -> jax.Array:
    B, Hq, D = q.shape
    N, PS, Hkv, _ = k_pages.shape
    MP = page_table.shape[1]
    G = Hq // Hkv
    # gather per-sequence K/V: [B, MP*PS, Hkv, D]
    k = k_pages[page_table].reshape(B, MP * PS, Hkv, D)
    v = v_pages[page_table].reshape(B, MP * PS, Hkv, D)
    kf = jnp.repeat(k, G, axis=2)   # [B, T, Hq, D]
    vf = jnp.repeat(v, G, axis=2)
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    logits = jnp.einsum("bhd,bthd->bht", q.astype(jnp.float32),
                        kf.astype(jnp.float32)) * scale
    pos = jnp.arange(MP * PS)[None, :]
    mask = pos < lengths[:, None]
    logits = jnp.where(mask[:, None, :], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows (length 0)
    return jnp.einsum("bht,bthd->bhd", p, vf.astype(jnp.float32)).astype(q.dtype)
