"""Pallas TPU kernel: paged flash-decode over the versioned KV pool.

Grid ``(B, Hkv, MP)`` — batch x kv-head x page — with the page dimension
innermost.  The page table (the MVGC snapshot-read result) is **scalar
prefetched**, so each grid step's BlockSpec index_map steers the page DMA:
``k_pages`` block ``(1, PS, 1, D)`` at row ``table[b, p]``.  Online-softmax
statistics for the G grouped query heads accumulate in VMEM scratch and are
finalized on the last page.  Padding pages are masked via ``lengths`` (also
prefetched) — the pool row they point at is never trusted.

This is the serving hot path the paper's rtx corresponds to: a snapshot read
of many versioned objects (pages) followed by the actual attention compute.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    table_ref, len_ref,            # scalar-prefetch operands
    q_ref, k_ref, v_ref,           # tensor operands
    o_ref,                         # output
    m_scr, l_scr, acc_scr,         # VMEM scratch
    *, ps: int, n_pages: int, scale: float,
):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    page_start = p * ps

    @pl.when(page_start < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)         # (PS, D)
        v = v_ref[0, :, 0].astype(jnp.float32)         # (PS, D)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                       # (G, PS)
        pos = page_start + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        logits = jnp.where(pos < length, logits, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_cur = jnp.max(logits, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        pexp = jnp.exp(logits - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * alpha + pexp.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(p == n_pages - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / safe).astype(o_ref.dtype)


def paged_decode_pallas(
    q: jax.Array,           # [B, Hq, D]
    k_pages: jax.Array,     # [N, PS, Hkv, D]
    v_pages: jax.Array,     # [N, PS, Hkv, D]
    page_table: jax.Array,  # i32[B, MP]
    lengths: jax.Array,     # i32[B]
    *,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, D = q.shape
    N, PS, Hkv, _ = k_pages.shape
    MP = page_table.shape[1]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    # reshape q so a (b, j) block is the G query heads of kv head j
    q_g = q.reshape(B, Hkv, G, D)

    grid = (B, Hkv, MP)
    kernel = functools.partial(_decode_kernel, ps=PS, n_pages=MP, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # page_table, lengths
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, j, p, tbl, ln: (b, j, 0, 0)),
            pl.BlockSpec(
                (1, PS, 1, D),
                lambda b, j, p, tbl, ln: (tbl[b, p], 0, j, 0),
            ),
            pl.BlockSpec(
                (1, PS, 1, D),
                lambda b, j, p, tbl, ln: (tbl[b, p], 0, j, 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, j, p, tbl, ln: (b, j, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(page_table, lengths, q_g, k_pages, v_pages)
    return out.reshape(B, Hq, D)
