"""Pure-jnp oracle for version_search: batched search(t) over version slabs."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

EMPTY = jnp.int32(-1)
NEG_INF_I32 = jnp.int32(-2_147_483_648)


def search_ref(
    ts: jax.Array,        # i32[S, V]
    payload: jax.Array,   # i32[S, V]
    slot_ids: jax.Array,  # i32[B]
    t: jax.Array,         # i32[B]
) -> Tuple[jax.Array, jax.Array]:
    """(payload[B], found[B]): latest version with ts <= t per queried slot."""
    rows_ts = ts[slot_ids]                       # [B, V]
    ok = (rows_ts != EMPTY) & (rows_ts <= t[:, None])
    masked = jnp.where(ok, rows_ts, NEG_INF_I32)
    idx = jnp.argmax(masked, axis=1)
    found = ok.any(axis=1)
    pay = jnp.take_along_axis(payload[slot_ids], idx[:, None], axis=1)[:, 0]
    return jnp.where(found, pay, EMPTY), found
