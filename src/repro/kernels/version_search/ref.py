"""Pure-jnp oracle for version_search: batched search(t) over version slabs."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

EMPTY = jnp.int32(-1)
NEG_INF_I32 = jnp.int32(-2_147_483_648)


def search_ref(
    ts: jax.Array,        # i32[S, V]
    payload: jax.Array,   # i32[S, V]
    slot_ids: jax.Array,  # i32[B]
    t: jax.Array,         # i32[B]
) -> Tuple[jax.Array, jax.Array]:
    """(payload[B], found[B]): latest version with ts <= t per queried slot."""
    rows_ts = ts[slot_ids]                       # [B, V]
    ok = (rows_ts != EMPTY) & (rows_ts <= t[:, None])
    masked = jnp.where(ok, rows_ts, NEG_INF_I32)
    idx = jnp.argmax(masked, axis=1)
    found = ok.any(axis=1)
    pay = jnp.take_along_axis(payload[slot_ids], idx[:, None], axis=1)[:, 0]
    return jnp.where(found, pay, EMPTY), found


def search_gather_ref(
    ts: jax.Array,        # i32[S, V]
    payload: jax.Array,   # i32[S, V]
    values: jax.Array,    # i32[T, M] payload-indexed value rows
    slot_ids: jax.Array,  # i32[B]
    t: jax.Array,         # i32[B]
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused search(t) + value-row gather: ``(rows[B, M], payload[B], found[B])``.

    The resolved payload handle indexes ``values``; rows for not-found
    queries are EMPTY-filled.  Payload handles of found versions must be
    valid row indices into ``values`` (the vstore maintains this invariant).
    """
    pay, found = search_ref(ts, payload, slot_ids, t)
    safe = jnp.clip(pay, 0, values.shape[0] - 1)
    rows = jnp.where(found[:, None], values[safe], EMPTY)
    return rows, pay, found
