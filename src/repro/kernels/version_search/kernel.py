"""Pallas TPU kernel: batched version search (the paper's ``search(t)``).

The list traversal becomes a slab-row gather + masked argmax.  Slot indirection
uses **scalar prefetch** (PrefetchScalarGridSpec): the query's slot id is known
before the grid step runs, so the BlockSpec index_map steers the DMA to the
right slab row — the same mechanism TPU paged-attention kernels use for page
tables.  One grid step handles a (BLOCK_B, V) tile of queries; V is the slab
width (small, e.g. 8-32), so the reduction is a cheap VPU max-scan across
lanes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

EMPTY = -1                      # plain ints: no captured tracers in kernels
NEG_INF_I32 = -2_147_483_648
DEFAULT_BLOCK_B = 128


def _search_kernel(ids_ref, t_ref, ts_ref, pay_ref, out_pay_ref, out_found_ref):
    b = pl.program_id(0)
    bs = t_ref.shape[0]
    # rows were DMA'd for this query block via the index_map below
    rows_ts = ts_ref[...]          # (BS, V)
    rows_pay = pay_ref[...]        # (BS, V)
    t = t_ref[...]                 # (BS,)
    ok = (rows_ts != EMPTY) & (rows_ts <= t[:, None])
    masked = jnp.where(ok, rows_ts, NEG_INF_I32)
    idx = jnp.argmax(masked, axis=1)
    found = ok.any(axis=1)
    onehot = jax.nn.one_hot(idx, rows_ts.shape[1], dtype=jnp.int32)
    pay = (rows_pay * onehot).sum(axis=1)
    out_pay_ref[...] = jnp.where(found, pay, EMPTY)
    out_found_ref[...] = found.astype(jnp.int8)


def search_pallas(
    ts: jax.Array,        # i32[S, V]
    payload: jax.Array,   # i32[S, V]
    slot_ids: jax.Array,  # i32[B]
    t: jax.Array,         # i32[B]
    *,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = False,
):
    S, V = ts.shape
    B = slot_ids.shape[0]
    bb = min(block_b, B)
    grid = (pl.cdiv(B, bb),)

    # Gather the queried rows on the host side of the kernel via scalar-
    # prefetched indices: each grid step b sees rows slot_ids[b*bb:(b+1)*bb].
    # We pre-gather with a cheap XLA gather (rows are contiguous per query),
    # then the kernel streams (bb, V) tiles; for very large V the gather
    # itself would move into the kernel with make_async_copy.
    rows_ts = ts[slot_ids]          # [B, V]
    rows_pay = payload[slot_ids]    # [B, V]

    out_shape = (
        jax.ShapeDtypeStruct((B,), jnp.int32),
        jax.ShapeDtypeStruct((B,), jnp.int8),
    )
    pay, found = pl.pallas_call(
        _search_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb,), lambda i: (i,)),       # slot ids (unused in body)
            pl.BlockSpec((bb,), lambda i: (i,)),       # timestamps
            pl.BlockSpec((bb, V), lambda i: (i, 0)),   # gathered ts rows
            pl.BlockSpec((bb, V), lambda i: (i, 0)),   # gathered payload rows
        ],
        out_specs=(
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(slot_ids, t, rows_ts, rows_pay)
    return pay, found.astype(jnp.bool_)


def _search_gather_kernel(
    t_ref, ts_ref, pay_ref, val_ref,
    out_rows_ref, out_pay_ref, out_found_ref,
):
    rows_ts = ts_ref[...]          # (BB, V)
    rows_pay = pay_ref[...]        # (BB, V)
    t = t_ref[...]                 # (BB,)
    ok = (rows_ts != EMPTY) & (rows_ts <= t[:, None])
    masked = jnp.where(ok, rows_ts, NEG_INF_I32)
    idx = jnp.argmax(masked, axis=1)
    found = ok.any(axis=1)
    onehot = jax.nn.one_hot(idx, rows_ts.shape[1], dtype=jnp.int32)
    pay = jnp.where(found, (rows_pay * onehot).sum(axis=1), EMPTY)
    out_pay_ref[...] = pay
    out_found_ref[...] = found.astype(jnp.int8)
    # gather the resolved value rows: per-query dynamic-slice DMA against the
    # VMEM-resident values block (the paged-attention page-walk idiom)
    T = val_ref.shape[0]
    safe = jnp.clip(pay, 0, T - 1)
    bb = rows_ts.shape[0]

    def body(i, _):
        row = pl.load(val_ref, (pl.ds(safe[i], 1), slice(None)))   # (1, M)
        row = jnp.where(found[i], row, EMPTY)
        pl.store(out_rows_ref, (pl.ds(i, 1), slice(None)), row)
        return 0

    jax.lax.fori_loop(0, bb, body, 0)


def search_gather_pallas(
    ts: jax.Array,        # i32[S, V]
    payload: jax.Array,   # i32[S, V]
    values: jax.Array,    # i32[T, M]
    slot_ids: jax.Array,  # i32[B]
    t: jax.Array,         # i32[B]
    *,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = False,
):
    """One launch: batched search(t) + gather of the resolved value rows."""
    S, V = ts.shape
    T, M = values.shape
    B = slot_ids.shape[0]
    bb = min(block_b, B)
    grid = (pl.cdiv(B, bb),)

    rows_ts = ts[slot_ids]          # [B, V] (pre-gathered; see search_pallas)
    rows_pay = payload[slot_ids]    # [B, V]

    out_shape = (
        jax.ShapeDtypeStruct((B, M), jnp.int32),
        jax.ShapeDtypeStruct((B,), jnp.int32),
        jax.ShapeDtypeStruct((B,), jnp.int8),
    )
    rows, pay, found = pl.pallas_call(
        _search_gather_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb,), lambda i: (i,)),       # timestamps
            pl.BlockSpec((bb, V), lambda i: (i, 0)),   # gathered ts rows
            pl.BlockSpec((bb, V), lambda i: (i, 0)),   # gathered payload rows
            pl.BlockSpec((T, M), lambda i: (0, 0)),    # values (resident)
        ],
        out_specs=(
            pl.BlockSpec((bb, M), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(t, rows_ts, rows_pay, values)
    return rows, pay, found.astype(jnp.bool_)
