"""jit'd public wrapper for version_search."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.version_search.kernel import search_gather_pallas, search_pallas
from repro.kernels.version_search.ref import search_gather_ref, search_ref


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret", "block_b"))
def search(
    ts: jax.Array,
    payload: jax.Array,
    slot_ids: jax.Array,
    t: jax.Array,
    *,
    use_kernel: bool = True,
    interpret: bool = True,
    block_b: int = 128,
) -> Tuple[jax.Array, jax.Array]:
    if use_kernel:
        return search_pallas(
            ts, payload, slot_ids, t, block_b=block_b, interpret=interpret
        )
    return search_ref(ts, payload, slot_ids, t)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret", "block_b"))
def search_gather(
    ts: jax.Array,
    payload: jax.Array,
    values: jax.Array,
    slot_ids: jax.Array,
    t: jax.Array,
    *,
    use_kernel: bool = True,
    interpret: bool = True,
    block_b: int = 128,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused batched search(t) + value-row gather: one launch resolves a
    batch of (slot, ts) snapshot reads AND gathers the payload-indexed rows.
    Returns ``(rows[B, M], payload[B], found[B])``."""
    if use_kernel:
        return search_gather_pallas(
            ts, payload, values, slot_ids, t, block_b=block_b, interpret=interpret
        )
    return search_gather_ref(ts, payload, values, slot_ids, t)
