"""Pure-jnp oracle for the compact kernel: needed(A, t) over version slabs.

This is definitionally the same predicate as ``repro.core.mvgc.needed`` (the
jit fallback); re-implemented here with the broadcast-compare formulation so
the kernel and the searchsorted formulation check each other.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

EMPTY = jnp.int32(-1)
TS_MAX = jnp.int32(2_147_483_647)


def needed_ref(
    ts: jax.Array,          # i32[S, V]
    succ: jax.Array,        # i32[S, V]
    ann_sorted: jax.Array,  # i32[P] (TS_MAX padded)
    now: jax.Array,         # i32[]
) -> jax.Array:
    """bool[S, V]: needed(A, now) per entry (EMPTY entries are not needed)."""
    A = ann_sorted
    pinned = (
        (ts[..., None] <= A[None, None, :]) & (A[None, None, :] < succ[..., None])
    ).any(-1)
    return (ts != EMPTY) & (pinned | (succ > now))


def compact_ref(
    ts: jax.Array,          # i32[R, V] row batch (whole store or gathered slots)
    succ: jax.Array,        # i32[R, V]
    payload: jax.Array,     # i32[R, V]
    mask: jax.Array,        # bool[R]  rows eligible for splicing
    ann_sorted: jax.Array,  # i32[P] (TS_MAX padded)
    now: jax.Array,         # i32[]
):
    """Fused needed + splice: the compaction contract in one pass.

    Returns ``(ts', succ', payload', freed, n_freed)``: spliced descriptor
    arrays (killed entries reset to EMPTY/TS_MAX/EMPTY), the freed payload
    handles (EMPTY holes, same [R, V] layout), and the exact freed count.
    Rows with ``mask`` False pass through untouched.
    """
    need = needed_ref(ts, succ, ann_sorted, now)
    kill = (ts != EMPTY) & ~need & mask[:, None]
    new_ts = jnp.where(kill, EMPTY, ts)
    new_succ = jnp.where(kill, TS_MAX, succ)
    new_pay = jnp.where(kill, EMPTY, payload)
    freed = jnp.where(kill, payload, EMPTY)
    return new_ts, new_succ, new_pay, freed, kill.sum().astype(jnp.int32)
