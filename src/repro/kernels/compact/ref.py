"""Pure-jnp oracle for the compact kernel: needed(A, t) over version slabs.

This is definitionally the same predicate as ``repro.core.mvgc.needed`` (the
jit fallback); re-implemented here with the broadcast-compare formulation so
the kernel and the searchsorted formulation check each other.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

EMPTY = jnp.int32(-1)


def needed_ref(
    ts: jax.Array,          # i32[S, V]
    succ: jax.Array,        # i32[S, V]
    ann_sorted: jax.Array,  # i32[P] (TS_MAX padded)
    now: jax.Array,         # i32[]
) -> jax.Array:
    """bool[S, V]: needed(A, now) per entry (EMPTY entries are not needed)."""
    A = ann_sorted
    pinned = (
        (ts[..., None] <= A[None, None, :]) & (A[None, None, :] < succ[..., None])
    ).any(-1)
    return (ts != EMPTY) & (pinned | (succ > now))
