"""Pallas TPU kernel: the SSL compact sweep (needed(A,t) mask over slabs).

Hardware mapping (DESIGN.md §6): the paper's merge pass over (version list ×
sorted announcements) becomes a VPU broadcast-compare — the announcement
vector (P is at most a few thousand: KBs) stays resident in VMEM while the
[S, V] slab streams through in (BLOCK_S, V) tiles.  Arithmetic intensity is
O(P) per element, so for realistic P (>= 64) the sweep is compute-bound on
the VPU rather than HBM-bound — which is why fusing the mask computation into
one pass (instead of searchsorted's gather-heavy form) is the right TPU
shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

EMPTY = -1  # plain int: kernels must not capture traced constants
DEFAULT_BLOCK_S = 256


def _compact_kernel(now_ref, ts_ref, succ_ref, ann_ref, out_ref):
    ts = ts_ref[...]            # (BS, V)
    succ = succ_ref[...]        # (BS, V)
    A = ann_ref[...]            # (P,)
    now = now_ref[0]
    pinned = (
        (ts[..., None] <= A[None, None, :]) & (A[None, None, :] < succ[..., None])
    ).any(-1)
    out_ref[...] = ((ts != EMPTY) & (pinned | (succ > now))).astype(jnp.int8)


def needed_pallas(
    ts: jax.Array,
    succ: jax.Array,
    ann_sorted: jax.Array,
    now: jax.Array,
    *,
    block_s: int = DEFAULT_BLOCK_S,
    interpret: bool = False,
) -> jax.Array:
    """needed(A, now) as int8[S, V] (1 = needed)."""
    S, V = ts.shape
    P = ann_sorted.shape[0]
    bs = min(block_s, S)
    grid = (pl.cdiv(S, bs),)
    now_arr = jnp.reshape(jnp.asarray(now, jnp.int32), (1,))
    out = pl.pallas_call(
        _compact_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),            # now (scalar)
            pl.BlockSpec((bs, V), lambda i: (i, 0)),           # ts tile
            pl.BlockSpec((bs, V), lambda i: (i, 0)),           # succ tile
            pl.BlockSpec((P,), lambda i: (0,)),                # announcements (resident)
        ],
        out_specs=pl.BlockSpec((bs, V), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((S, V), jnp.int8),
        interpret=interpret,
    )(now_arr, ts, succ, ann_sorted)
    return out
