"""Pallas TPU kernel: the SSL compact sweep (needed(A,t) mask over slabs).

Hardware mapping (DESIGN.md §6): the paper's merge pass over (version list ×
sorted announcements) becomes a VPU broadcast-compare — the announcement
vector (P is at most a few thousand: KBs) stays resident in VMEM while the
[S, V] slab streams through in (BLOCK_S, V) tiles.  Arithmetic intensity is
O(P) per element, so for realistic P (>= 64) the sweep is compute-bound on
the VPU rather than HBM-bound — which is why fusing the mask computation into
one pass (instead of searchsorted's gather-heavy form) is the right TPU
shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

EMPTY = -1  # plain int: kernels must not capture traced constants
TS_MAX = 2_147_483_647
DEFAULT_BLOCK_S = 256


def _compact_kernel(now_ref, ts_ref, succ_ref, ann_ref, out_ref):
    ts = ts_ref[...]            # (BS, V)
    succ = succ_ref[...]        # (BS, V)
    A = ann_ref[...]            # (P,)
    now = now_ref[0]
    pinned = (
        (ts[..., None] <= A[None, None, :]) & (A[None, None, :] < succ[..., None])
    ).any(-1)
    out_ref[...] = ((ts != EMPTY) & (pinned | (succ > now))).astype(jnp.int8)


def needed_pallas(
    ts: jax.Array,
    succ: jax.Array,
    ann_sorted: jax.Array,
    now: jax.Array,
    *,
    block_s: int = DEFAULT_BLOCK_S,
    interpret: bool = False,
) -> jax.Array:
    """needed(A, now) as int8[S, V] (1 = needed)."""
    S, V = ts.shape
    P = ann_sorted.shape[0]
    bs = min(block_s, S)
    grid = (pl.cdiv(S, bs),)
    now_arr = jnp.reshape(jnp.asarray(now, jnp.int32), (1,))
    out = pl.pallas_call(
        _compact_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),            # now (scalar)
            pl.BlockSpec((bs, V), lambda i: (i, 0)),           # ts tile
            pl.BlockSpec((bs, V), lambda i: (i, 0)),           # succ tile
            pl.BlockSpec((P,), lambda i: (0,)),                # announcements (resident)
        ],
        out_specs=pl.BlockSpec((bs, V), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((S, V), jnp.int8),
        interpret=interpret,
    )(now_arr, ts, succ, ann_sorted)
    return out


def _fused_compact_kernel(
    num_rows,  # python int, closed over: guards padding rows in the count
    now_ref, ann_ref,                       # scalar-prefetched (SMEM)
    ts_ref, succ_ref, pay_ref, mask_ref,    # streamed tiles
    out_ts_ref, out_succ_ref, out_pay_ref, out_freed_ref, out_cnt_ref,
):
    ts = ts_ref[...]            # (BR, V)
    succ = succ_ref[...]        # (BR, V)
    pay = pay_ref[...]          # (BR, V)
    m = mask_ref[...]           # (BR,) i32: 1 = row eligible
    A = ann_ref[...]            # (P,)
    now = now_ref[0]
    pinned = (
        (ts[..., None] <= A[None, None, :]) & (A[None, None, :] < succ[..., None])
    ).any(-1)
    need = (ts != EMPTY) & (pinned | (succ > now))
    kill = (ts != EMPTY) & ~need & (m[:, None] != 0)
    out_ts_ref[...] = jnp.where(kill, EMPTY, ts)
    out_succ_ref[...] = jnp.where(kill, TS_MAX, succ)
    out_pay_ref[...] = jnp.where(kill, EMPTY, pay)
    out_freed_ref[...] = jnp.where(kill, pay, EMPTY)
    # per-block freed count; padding rows in the last tile must not count
    br = ts.shape[0]
    rid = jax.lax.broadcasted_iota(jnp.int32, (br, 1), 0) + pl.program_id(0) * br
    out_cnt_ref[0] = (kill & (rid < num_rows)).sum().astype(jnp.int32)


def compact_pallas(
    ts: jax.Array,          # i32[R, V]
    succ: jax.Array,        # i32[R, V]
    payload: jax.Array,     # i32[R, V]
    mask: jax.Array,        # bool[R]
    ann_sorted: jax.Array,  # i32[P] (TS_MAX padded)
    now: jax.Array,         # i32[]
    *,
    block_r: int = DEFAULT_BLOCK_S,
    interpret: bool = False,
):
    """Fused needed + splice in one launch (DESIGN.md §12).

    The announcement board and the clock ride in via **scalar prefetch**
    (``PrefetchScalarGridSpec``): both live in SMEM before the first grid step
    so every (BLOCK_R, V) descriptor tile is compared against the resident
    pin vector as it streams through — no separate mask materialization, no
    second splice dispatch.  Outputs the compacted ts/succ/payload tiles, the
    freed payload handles and the exact freed count in the same pass.
    """
    R, V = ts.shape
    br = min(block_r, R)
    steps = pl.cdiv(R, br)
    now_arr = jnp.reshape(jnp.asarray(now, jnp.int32), (1,))
    mask_i32 = mask.astype(jnp.int32)

    def tile(i, now_ref, ann_ref):
        return (i, 0)

    def lane(i, now_ref, ann_ref):
        return (i,)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((br, V), tile),    # ts
            pl.BlockSpec((br, V), tile),    # succ
            pl.BlockSpec((br, V), tile),    # payload
            pl.BlockSpec((br,), lane),      # row mask
        ],
        out_specs=(
            pl.BlockSpec((br, V), tile),    # ts'
            pl.BlockSpec((br, V), tile),    # succ'
            pl.BlockSpec((br, V), tile),    # payload'
            pl.BlockSpec((br, V), tile),    # freed handles
            pl.BlockSpec((1,), lane),       # per-block freed count
        ),
    )
    new_ts, new_succ, new_pay, freed, cnt = pl.pallas_call(
        functools.partial(_fused_compact_kernel, R),
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((R, V), jnp.int32),
            jax.ShapeDtypeStruct((R, V), jnp.int32),
            jax.ShapeDtypeStruct((R, V), jnp.int32),
            jax.ShapeDtypeStruct((R, V), jnp.int32),
            jax.ShapeDtypeStruct((steps,), jnp.int32),
        ),
        interpret=interpret,
    )(now_arr, ann_sorted, ts, succ, payload, mask_i32)
    return new_ts, new_succ, new_pay, freed, cnt.sum()
