"""jit'd public wrapper for the compact kernel with backend dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.compact.kernel import needed_pallas
from repro.kernels.compact.ref import needed_ref


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret", "block_s"))
def needed(
    ts: jax.Array,
    succ: jax.Array,
    ann_sorted: jax.Array,
    now: jax.Array,
    *,
    use_kernel: bool = True,
    interpret: bool = True,   # CPU container: interpret by default; False on TPU
    block_s: int = 256,
) -> jax.Array:
    """bool[S, V] needed mask; Pallas kernel on TPU, jnp reference otherwise."""
    if use_kernel:
        return needed_pallas(
            ts, succ, ann_sorted, now, block_s=block_s, interpret=interpret
        ).astype(jnp.bool_)
    return needed_ref(ts, succ, ann_sorted, now)
