"""jit'd public wrapper for the compact kernel with backend dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.compact.kernel import compact_pallas, needed_pallas
from repro.kernels.compact.ref import compact_ref, needed_ref


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret", "block_s"))
def needed(
    ts: jax.Array,
    succ: jax.Array,
    ann_sorted: jax.Array,
    now: jax.Array,
    *,
    use_kernel: bool = True,
    interpret: bool = True,   # CPU container: interpret by default; False on TPU
    block_s: int = 256,
) -> jax.Array:
    """bool[S, V] needed mask; Pallas kernel on TPU, jnp reference otherwise."""
    if use_kernel:
        return needed_pallas(
            ts, succ, ann_sorted, now, block_s=block_s, interpret=interpret
        ).astype(jnp.bool_)
    return needed_ref(ts, succ, ann_sorted, now)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret", "block_r"))
def compact(
    ts: jax.Array,
    succ: jax.Array,
    payload: jax.Array,
    mask: jax.Array,
    ann_sorted: jax.Array,
    now: jax.Array,
    *,
    use_kernel: bool = True,
    interpret: bool = True,   # CPU container: interpret by default; False on TPU
    block_r: int = 256,
):
    """Fused needed + splice over an [R, V] row batch.

    Returns ``(ts', succ', payload', freed, n_freed)`` — see ``compact_ref``
    for the contract.  Pallas kernel when ``use_kernel``, jnp reference
    otherwise (the two are parity-tested in tests/kernels)."""
    if use_kernel:
        return compact_pallas(
            ts, succ, payload, mask, ann_sorted, now,
            block_r=block_r, interpret=interpret,
        )
    return compact_ref(ts, succ, payload, mask, ann_sorted, now)
