"""Pallas TPU kernel: blockwise causal flash attention for long prefill.

MaxText-style grid ``(B, Hq, T/BT, S/BS)`` with the KV-block dimension
innermost; the output tile and the running (m, l) softmax statistics live in
VMEM scratch across the inner dimension and are finalized on the last KV
block.  GQA is resolved in the BlockSpec index_map (query head h reads KV
head h // G) so KV is never materialized per query head.  Sliding-window and
causal structure skip whole KV blocks via ``pl.when`` — with window w the per
-row work drops from O(T) to O(w), which is what makes gemma2-2b local layers
and the 32k prefill shapes tractable.

Logit softcapping (gemma2) is fused between the QK matmul and the softmax.
MXU alignment: BT/BS default to 128, D padded to 128 by the wrapper.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BT = 128
DEFAULT_BS = 128
NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, bt: int, bs: int, n_s: int, s_total: int, causal: bool, window: int,
    softcap: float, scale: float,
):
    tb = pl.program_id(2)
    sb = pl.program_id(3)

    @pl.when(sb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = tb * bt
    s_start = sb * bs
    run = True
    if causal:
        run = s_start <= q_start + bt - 1          # block not entirely future
    if window > 0:
        # block not entirely before every query row's window start
        run_w = s_start + bs - 1 >= q_start - window + 1
    else:
        run_w = True

    @pl.when(jnp.logical_and(run, run_w))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)        # (BT, D)
        k = k_ref[0, 0].astype(jnp.float32)        # (BS, D)
        v = v_ref[0, 0].astype(jnp.float32)        # (BS, D)
        # padded KV rows (S % BS != 0) hold unspecified bits; zero them so
        # 0-weight lanes cannot poison the accumulator (0 * NaN = NaN)
        col_valid = s_start + jax.lax.broadcasted_iota(jnp.int32, (bs, 1), 0) < s_total
        v = jnp.where(col_valid, v, 0.0)
        k = jnp.where(col_valid, k, 0.0)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                   # (BT, BS)
        if softcap > 0:
            logits = jnp.tanh(logits / softcap) * softcap
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bt, bs), 0)
        cols = s_start + jax.lax.broadcasted_iota(jnp.int32, (bt, bs), 1)
        mask = cols < s_total  # guard padded KV columns (T % BS != 0)
        if causal:
            mask &= cols <= rows
        if window > 0:
            mask &= cols > rows - window
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_scr[...]                         # (BT, 1)
        l_prev = l_scr[...]
        m_cur = jnp.max(logits, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new)                 # (BT, BS)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(sb == n_s - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / safe).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,   # [B, Hq, T, D]
    k: jax.Array,   # [B, Hkv, S, D]
    v: jax.Array,   # [B, Hkv, S, D]
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_t: int = DEFAULT_BT,
    block_s: int = DEFAULT_BS,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, T, D = q.shape
    _, Hkv, S, _ = k.shape
    G = Hq // Hkv
    bt = min(block_t, T)
    bs = min(block_s, S)
    n_t = pl.cdiv(T, bt)
    n_s = pl.cdiv(S, bs)
    grid = (B, Hq, n_t, n_s)
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _flash_kernel, bt=bt, bs=bs, n_s=n_s, s_total=S, causal=causal,
        window=window, softcap=softcap, scale=scale,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bt, D), lambda b, h, tb, sb: (b, h, tb, 0)),
            pl.BlockSpec((1, 1, bs, D), lambda b, h, tb, sb: (b, h // G, sb, 0)),
            pl.BlockSpec((1, 1, bs, D), lambda b, h, tb, sb: (b, h // G, sb, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bt, D), lambda b, h, tb, sb: (b, h, tb, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, T, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bt, 1), jnp.float32),
            pltpu.VMEM((bt, 1), jnp.float32),
            pltpu.VMEM((bt, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
