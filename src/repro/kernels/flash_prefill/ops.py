"""jit'd public wrapper for flash prefill attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_prefill.kernel import flash_attention_pallas
from repro.kernels.flash_prefill.ref import attention_ref

STATIC = ("causal", "window", "softcap", "use_kernel", "interpret",
          "block_t", "block_s")


@functools.partial(jax.jit, static_argnames=STATIC)
def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: int = 0, softcap: float = 0.0,
    use_kernel: bool = True, interpret: bool = True,
    block_t: int = 128, block_s: int = 128,
) -> jax.Array:
    if use_kernel:
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, softcap=softcap,
            block_t=block_t, block_s=block_s, interpret=interpret)
    return attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)
