"""Pure-jnp oracle: dense causal attention with GQA, sliding window, softcap."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,   # [B, Hq, T, D]
    k: jax.Array,   # [B, Hkv, T, D]
    v: jax.Array,   # [B, Hkv, T, D]
    *,
    causal: bool = True,
    window: int = 0,          # 0 = global; else attend to [i-window+1, i]
    softcap: float = 0.0,     # 0 = off; else tanh logit capping (gemma2)
) -> jax.Array:
    B, Hq, T, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    kf = jnp.repeat(k, G, axis=1)
    vf = jnp.repeat(v, G, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    logits = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                        kf.astype(jnp.float32)) * scale
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    i = jnp.arange(T)[:, None]
    j = jnp.arange(T)[None, :]
    mask = jnp.ones((T, T), bool)
    if causal:
        mask &= j <= i
    if window > 0:
        mask &= j > i - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", p, vf.astype(jnp.float32)).astype(q.dtype)
