"""Attention mixers: GQA with RoPE, sliding-window, softcap, KV cache,
cross-attention — XLA flash (scan-over-KV-blocks) for train/prefill and a
Pallas dispatch for TPU runs.

The XLA flash path is the compile-target for the dry-run: O(T * BS) live
memory instead of O(T^2), scan keeps the HLO size depth-independent, and the
online-softmax structure matches what the Pallas kernel executes on real
hardware (repro.kernels.flash_prefill — validated against the same oracle).
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import constrain_batch, dense_init, rope, softcap

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig, cross: bool = False, dtype=jnp.float32):
    d, hd, nq, nkv = cfg.d_model, cfg.hd, cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, nq, hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, nkv, hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, nkv, hd), dtype=dtype),
        "wo": dense_init(ks[3], (nq, hd, d), in_axis=1, dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq, hd), dtype)
        p["bk"] = jnp.zeros((nkv, hd), dtype)
        p["bv"] = jnp.zeros((nkv, hd), dtype)
    return p


class KVCache(NamedTuple):
    k: jax.Array        # [B, L, Hkv, D]
    v: jax.Array        # [B, L, Hkv, D]


def _project_qkv(params, cfg: ModelConfig, x, x_kv=None):
    x_kv = x if x_kv is None else x_kv
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    k = jnp.einsum("btd,dhk->bthk", x_kv, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", x_kv, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    return q, k, v


def _xla_flash(
    q: jax.Array,  # [B, T, Hq, D]
    k: jax.Array,  # [B, S, Hkv, D]
    v: jax.Array,  # [B, S, Hkv, D]
    *,
    causal: bool,
    window: int,
    attn_cap: float,
    q_offset: jax.Array | int = 0,
    block_s: int = 512,
) -> jax.Array:
    """Blockwise online-softmax attention: scan over KV blocks."""
    B, T, Hq, D = q.shape
    S = k.shape[1]
    Hkv = k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    bs = min(block_s, S)
    n_blocks = -(-S // bs)
    pad = n_blocks * bs - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, n_blocks, bs, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, n_blocks, bs, Hkv, D).transpose(1, 0, 2, 3, 4)

    qf = q.reshape(B, T, Hkv, G, D) * jnp.asarray(scale, q.dtype)
    rows = q_offset + jnp.arange(T)[:, None]  # absolute query positions

    def body(carry, blk):
        m, l, acc, sb = carry
        kblk, vblk = blk
        # bf16 operands, f32 accumulation: MXU-native; avoids materializing
        # f32 copies of Q/K (XLA otherwise hoists whole-array converts)
        logits = jnp.einsum(
            "bthgd,bshd->bthgs", qf, kblk,
            preferred_element_type=jnp.float32)
        if attn_cap > 0:
            logits = softcap(logits, attn_cap)
        cols = sb * bs + jnp.arange(bs)[None, :]
        mask = cols < S
        if causal:
            mask = mask & (cols <= rows)
        if window > 0:
            mask = mask & (cols > rows - window)
        logits = jnp.where(mask[None, :, None, None, :], logits, NEG_INF)
        m_cur = logits.max(-1)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bthgs,bshd->bthgd", p.astype(v.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new, sb + 1), None

    m0 = jnp.full((B, T, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, T, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, T, Hkv, G, D), jnp.float32)
    # checkpoint each KV block: backward recomputes p instead of storing the
    # [B,T,H,G,BS] residual per block — the flash-attention memory contract
    (m, l, acc, _), _ = jax.lax.scan(jax.checkpoint(body), (m0, l0, a0, 0),
                                     (kb, vb))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l[..., None]).reshape(B, T, Hq, D)
    return out.astype(q.dtype)


def attention(
    params,
    cfg: ModelConfig,
    x: jax.Array,                 # [B, T, d]
    positions: jax.Array,         # i32[B, T]
    *,
    kind: str = "attn",           # attn | local
    causal: bool = True,
    cache: Optional[KVCache] = None,
    cache_len: Optional[jax.Array] = None,  # i32[B] valid tokens in cache
    x_kv: Optional[jax.Array] = None,       # cross-attention source
    use_rope: Optional[bool] = None,
    fill_cache: Optional[KVCache] = None,   # prefill: flash + write K/V here
) -> Tuple[jax.Array, Optional[KVCache]]:
    """Returns (out [B,T,d], updated cache).

    Modes:
    * train (cache None): full blockwise flash attention over x.
    * prefill (fill_cache given): flash attention over the prompt AND scatter
      its K/V into the (empty) cache — O(T * BS) memory, never O(T * L).
    * decode (cache given, T small): append K/V at cache_len, attend over the
      cache prefix.
    * cross (x_kv given): bidirectional attention over x_kv (no cache logic).
    """
    window = cfg.local_window if kind == "local" else 0
    q, k, v = _project_qkv(params, cfg, x, x_kv)
    if cfg.attn_gather_qkv:
        # column-parallel projections leave q/k/v sharded on head_dim; gather
        # them so the softmax contraction stays shard-local (sharding hd
        # through the attention core turns every QK block into a distributed
        # reduction — measured 40x collective blowup, EXPERIMENTS.md §Perf)
        q, k, v = constrain_batch(q), constrain_batch(k), constrain_batch(v)
    use_rope = cfg.rope if use_rope is None else use_rope
    if use_rope and x_kv is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    if fill_cache is not None:
        B, T = x.shape[:2]
        L = fill_cache.k.shape[1]
        idx = positions
        bidx = jnp.arange(B)[:, None] * jnp.ones((1, T), jnp.int32)
        newk = fill_cache.k.at[bidx, idx].set(k.astype(fill_cache.k.dtype),
                                              mode="drop")
        newv = fill_cache.v.at[bidx, idx].set(v.astype(fill_cache.v.dtype),
                                              mode="drop")
        out = _xla_flash(q, k, v, causal=causal, window=window,
                         attn_cap=cfg.attn_softcap, q_offset=0)
        y = jnp.einsum("bthk,hkd->btd", out, params["wo"])
        return y, KVCache(newk, newv)

    if cache is not None:
        B, T, Hkv, D = k.shape
        L = cache.k.shape[1]
        # scatter new K/V at [cache_len, cache_len+T)
        idx = cache_len[:, None] + jnp.arange(T)[None, :]        # [B, T]
        bidx = jnp.arange(B)[:, None] * jnp.ones((1, T), jnp.int32)
        newk = cache.k.at[bidx, idx].set(k, mode="drop")
        newv = cache.v.at[bidx, idx].set(v, mode="drop")
        cache = KVCache(newk, newv)
        total = cache_len + T                                    # [B]
        # attend over the cache prefix; per-batch lengths via masking.
        # bf16 operands + f32 accumulation: reading the cache in bf16 halves
        # decode HBM traffic and stops XLA hoisting f32 cache copies.
        scale = 1.0 / math.sqrt(D)
        qf = q.reshape(B, T, Hkv, -1, D) * jnp.asarray(scale, q.dtype)
        logits = jnp.einsum("bthgd,bshd->bthgs", qf, cache.k,
                            preferred_element_type=jnp.float32)
        if cfg.attn_softcap > 0:
            logits = softcap(logits, cfg.attn_softcap)
        cols = jnp.arange(L)[None, None, :]
        rows = positions[..., None]                              # [B, T, 1]
        mask = cols < total[:, None, None]
        if causal:
            mask = mask & (cols[0] <= rows)
        if window > 0:
            mask = mask & (cols[0] > rows - window)
        logits = jnp.where(mask[:, :, None, None, :], logits, NEG_INF)
        m = logits.max(-1, keepdims=True)
        p = jnp.exp(logits - m)
        l = p.sum(-1, keepdims=True)
        out = jnp.einsum("bthgs,bshd->bthgd", (p / l).astype(cache.v.dtype),
                         cache.v, preferred_element_type=jnp.float32)
        out = out.reshape(B, T, cfg.num_heads, D).astype(x.dtype)
    else:
        out = _xla_flash(
            q, k, v,
            causal=causal and x_kv is None,
            window=window,
            attn_cap=cfg.attn_softcap,
            q_offset=0,
        )

    y = jnp.einsum("bthk,hkd->btd", out, params["wo"])
    return y, cache
