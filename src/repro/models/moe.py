"""Mixture-of-Experts layer: top-k router + capacity-bucketed sparse dispatch
(+ optional shared experts, DeepSeekMoE-style fine-grained experts).

Dispatch strategy (TPU/EP-aware): tokens are flattened, argsorted by expert
assignment, scattered into per-expert capacity buckets ``[E, C, d]``, run
through a single batched expert einsum (E shardable over the ``model`` axis =
expert parallelism), and combined back with router weights.  All shapes are
static; overflow beyond capacity drops tokens (GShard-style) with the
capacity factor sized so drops are rare.  FLOPs scale with active experts,
keeping the MODEL_FLOPS/HLO_FLOPS roofline ratio honest for MoE archs.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import constrain_batch, dense_init
from repro.models.mlp import init_mlp, mlp


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), dtype=jnp.float32),  # router in f32
        "wg": dense_init(ks[1], (E, d, f), in_axis=1, dtype=dtype),
        "wu": dense_init(ks[2], (E, d, f), in_axis=1, dtype=dtype),
        "wd": dense_init(ks[3], (E, f, d), in_axis=1, dtype=dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, dtype=dtype,
                               d_ff=cfg.d_ff * cfg.num_shared_experts)
    return p


def moe(params, cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    if cfg.moe_dispatch == "grouped":
        return moe_grouped(params, cfg, x)
    return moe_global(params, cfg, x)


def moe_global(params, cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Returns (out [B,T,d], aux_loss (load-balance)).

    Baseline dispatch: one global argsort over all B*T*k assignments.  Under
    GSPMD with tokens data-sharded this forces the capacity buckets to be
    assembled with full-array all-reduces (34 GB/layer for granite-moe at
    train_4k — see EXPERIMENTS.md §Perf); ``moe_grouped`` is the fix."""
    B, T, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    N = B * T
    xf = x.reshape(N, d)

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                 # [N, k]
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch): E * sum(frac_tokens * frac_probs)
    me = probs.mean(0)
    ce = jnp.zeros((E,), jnp.float32).at[gate_idx.reshape(-1)].add(1.0) / (N * k)
    aux = E * jnp.sum(me * ce)

    # --- capacity-bucketed dispatch -------------------------------------
    # dropless floor for small token pools (decode steps, tests): capacity
    # min(N*k, 128) guarantees no drops when N is small, while the capacity-
    # factor term dominates (and bounds memory) for training-size pools.
    C = max(1, int(cfg.moe_capacity_factor * N * k / E), min(N * k, 128))
    flat_e = gate_idx.reshape(-1)                                  # [N*k]
    order = jnp.argsort(flat_e)                                    # stable
    sorted_e = flat_e[order]
    # rank within expert = position - first position of that expert
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = jnp.arange(N * k) - first
    dest = jnp.where(rank < C, sorted_e * C + rank, E * C)         # E*C = drop
    tok = order // k                                               # source token
    buckets = jnp.zeros((E * C, d), x.dtype).at[dest].set(xf[tok], mode="drop")
    be = buckets.reshape(E, C, d)

    # --- expert compute (E shardable over the model axis = EP) ----------
    act = jax.nn.gelu if cfg.act in ("gelu", "geglu") else jax.nn.silu
    g = act(jnp.einsum("ecd,edf->ecf", be, params["wg"]))
    u = jnp.einsum("ecd,edf->ecf", be, params["wu"])
    eo = jnp.einsum("ecf,efd->ecd", g * u, params["wd"]).reshape(E * C, d)

    # --- combine ---------------------------------------------------------
    w = gate_vals.reshape(-1)[order]                               # weight per slot
    gathered = eo[jnp.minimum(dest, E * C - 1)]                    # [N*k, d]
    keep = (dest < E * C)[:, None]
    contrib = jnp.where(keep, gathered * w[:, None].astype(x.dtype), 0)
    out = jnp.zeros((N, d), x.dtype).at[tok].add(contrib)

    if cfg.num_shared_experts:
        out = out + mlp(params["shared"], cfg, x).reshape(N, d)
    return out.reshape(B, T, d), aux


def moe_grouped(params, cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-sequence (grouped) dispatch — the GSPMD-friendly formulation.

    Routing, sort, rank and capacity are computed independently per batch row
    (group); every dispatch op then carries the batch dim, so GSPMD keeps
    buckets sharded on the data axes end-to-end and the expert einsum runs
    with buckets data-sharded x experts model-sharded — no bucket all-reduce.
    Capacity is per-group (cf * T * k / E), so the drop behaviour matches the
    global formulation in distribution."""
    B, T, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    # materialize the residual stream HERE: if x arrives model-partial (from
    # a row-parallel projection) the psum must happen on [B,T,d] — deferring
    # it into the dispatch gathers costs k x the bytes (measured 8x, §Perf b4)
    x = constrain_batch(x)

    logits = jnp.einsum("btd,de->bte", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                 # [B, T, k]
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    me = probs.mean((0, 1))
    ce = jnp.zeros((E,), jnp.float32).at[gate_idx.reshape(-1)].add(1.0) / (B * T * k)
    aux = E * jnp.sum(me * ce)

    C = max(1, int(cfg.moe_capacity_factor * T * k / E), min(T * k, 128))
    flat_e = gate_idx.reshape(B, T * k)                            # per group
    order = jnp.argsort(flat_e, axis=1)                            # [B, T*k]
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    first = jax.vmap(lambda se: jnp.searchsorted(se, se, side="left"))(sorted_e)
    rank = jnp.arange(T * k)[None, :] - first
    dest = jnp.where(rank < C, sorted_e * C + rank, E * C)         # E*C = drop
    tok = order // k                                               # [B, T*k]
    bidx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, T * k))
    xg = constrain_batch(jnp.take_along_axis(x, tok[..., None], axis=1))
    buckets = jnp.zeros((B, E * C, d), x.dtype).at[bidx, dest].set(
        xg, mode="drop")
    # pin the dispatch to batch-DP: without this GSPMD reshards the buckets
    # and implements the gathers/scatters with full-array all-reduces
    buckets = constrain_batch(buckets)
    be = buckets.reshape(B, E, C, d)

    act = jax.nn.gelu if cfg.act in ("gelu", "geglu") else jax.nn.silu
    g = act(jnp.einsum("becd,edf->becf", be, params["wg"]))
    u = jnp.einsum("becd,edf->becf", be, params["wu"])
    eo = jnp.einsum("becf,efd->becd", g * u, params["wd"]).reshape(B, E * C, d)
    eo = constrain_batch(eo)

    w = jnp.take_along_axis(gate_vals.reshape(B, T * k), order, axis=1)
    gathered = jnp.take_along_axis(eo, jnp.minimum(dest, E * C - 1)[..., None],
                                   axis=1)                         # [B, T*k, d]
    keep = (dest < E * C)[..., None]
    contrib = jnp.where(keep, gathered * w[..., None].astype(x.dtype), 0)
    out = jnp.zeros((B, T, d), x.dtype).at[bidx, tok].add(contrib)
    out = constrain_batch(out)

    if cfg.num_shared_experts:
        out = out + mlp(params["shared"], cfg, x)
    return out, aux
