"""Residual block assembly: norm -> mixer -> (+residual) -> norm -> ffn/moe.

One ``block_apply`` dispatches every mixer kind (attn/local/mlstm/slstm/
rglru), handles gemma2 sandwich norms, decoder cross-attention, MoE aux
losses, and the per-kind decode caches — so the whole 10-arch pool shares a
single scanned superblock implementation.

Local-attention decode uses a **ring cache** sized min(window, L): for
gemma2-2b at 500k context the local layers hold 4096 slots instead of 524288
— the window-expiry property the MVGC layer also exploits.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models.attention import KVCache, attention, init_attention
from repro.models.common import rms_norm, softcap
from repro.models.mlp import init_mlp, mlp
from repro.models.moe import init_moe, moe
from repro.models.mlstm import (
    MLSTMState, init_mlstm, mlstm_chunkwise, mlstm_decode, mlstm_init_state,
    SLSTMState, init_slstm, slstm, slstm_init_state,
)
from repro.models.rglru import (
    RGLRUState, init_rglru, rglru, rglru_decode, rglru_init_state,
)

NEG_INF = -1e30


class LocalKVCache(NamedTuple):
    k: jax.Array     # [B, W, Hkv, D] ring buffer
    v: jax.Array
    pos: jax.Array   # i32[B, W] absolute position stored in each slot (-1 empty)


def _uses_mlp(cfg: ModelConfig, kind: str) -> bool:
    return kind in ("attn", "local", "rglru") and (cfg.d_ff > 0 or cfg.num_experts > 0)


def init_block(key, cfg: ModelConfig, kind: str, dtype=jnp.float32,
               cross: bool = False):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: dict = {"ln1": jnp.zeros((d,), dtype)}
    if kind in ("attn", "local"):
        p["mixer"] = init_attention(ks[0], cfg, dtype=dtype)
    elif kind == "mlstm":
        p["mixer"] = init_mlstm(ks[0], cfg, dtype=dtype)
    elif kind == "slstm":
        p["mixer"] = init_slstm(ks[0], cfg, dtype=dtype)
    elif kind == "rglru":
        p["mixer"] = init_rglru(ks[0], cfg, dtype=dtype)
    else:
        raise ValueError(kind)
    if cfg.post_norms:
        p["ln1_post"] = jnp.zeros((d,), dtype)
    if cross:
        p["cross_ln"] = jnp.zeros((d,), dtype)
        p["cross"] = init_attention(ks[1], cfg, cross=True, dtype=dtype)
    if _uses_mlp(cfg, kind):
        p["ln2"] = jnp.zeros((d,), dtype)
        if cfg.num_experts > 0:
            p["ffn"] = init_moe(ks[2], cfg, dtype=dtype)
        else:
            p["ffn"] = init_mlp(ks[2], cfg, dtype=dtype)
        if cfg.post_norms:
            p["ln2_post"] = jnp.zeros((d,), dtype)
    return p


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, cache_len: int,
                     dtype=jnp.bfloat16):
    hd, hkv = cfg.hd, cfg.num_kv_heads
    if kind == "attn":
        return KVCache(
            k=jnp.zeros((batch, cache_len, hkv, hd), dtype),
            v=jnp.zeros((batch, cache_len, hkv, hd), dtype),
        )
    if kind == "local":
        W = min(cfg.local_window or cache_len, cache_len)
        return LocalKVCache(
            k=jnp.zeros((batch, W, hkv, hd), dtype),
            v=jnp.zeros((batch, W, hkv, hd), dtype),
            pos=jnp.full((batch, W), -1, jnp.int32),
        )
    if kind == "mlstm":
        return mlstm_init_state(cfg, batch)
    if kind == "slstm":
        return slstm_init_state(cfg, batch)
    if kind == "rglru":
        return rglru_init_state(cfg, batch)
    raise ValueError(kind)


def _local_ring_decode(params, cfg: ModelConfig, x, positions, cache: LocalKVCache):
    """Decode step for local attention over the ring cache."""
    B, T, d = x.shape
    q, k, v = attn_mod._project_qkv(params, cfg, x)
    if cfg.rope:
        from repro.models.common import rope
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    W = cache.k.shape[1]
    slot = positions % W                                       # [B, T]
    bidx = jnp.arange(B)[:, None] * jnp.ones((1, T), jnp.int32)
    cache = LocalKVCache(
        k=cache.k.at[bidx, slot].set(k, mode="drop"),
        v=cache.v.at[bidx, slot].set(v, mode="drop"),
        pos=cache.pos.at[bidx, slot].set(positions, mode="drop"),
    )
    D = q.shape[-1]
    scale = 1.0 / math.sqrt(D)
    Hkv = k.shape[2]
    qf = q.reshape(B, T, Hkv, -1, D) * jnp.asarray(scale, q.dtype)
    logits = jnp.einsum("bthgd,bshd->bthgs", qf, cache.k,
                        preferred_element_type=jnp.float32)
    if cfg.attn_softcap > 0:
        logits = softcap(logits, cfg.attn_softcap)
    cpos = cache.pos[:, None, :]                               # [B,1,W]
    rows = positions[..., None]                                # [B,T,1]
    w = cfg.local_window
    mask = (cpos >= 0) & (cpos <= rows) & (cpos > rows - w)
    logits = jnp.where(mask[:, :, None, None, :], logits, NEG_INF)
    m = logits.max(-1, keepdims=True)
    p = jnp.exp(logits - m)
    out = jnp.einsum("bthgs,bshd->bthgd",
                     (p / p.sum(-1, keepdims=True)).astype(cache.v.dtype),
                     cache.v, preferred_element_type=jnp.float32)
    out = out.reshape(B, T, cfg.num_heads, D).astype(x.dtype)
    return jnp.einsum("bthk,hkd->btd", out, params["wo"]), cache


def _prefill_local_ring(params, cfg: ModelConfig, h, positions, cache: LocalKVCache):
    """Prefill a local layer: flash-attend the prompt, keep only the last W
    tokens' K/V in the ring (earlier ones are already out of every future
    token's window)."""
    from repro.models.attention import _project_qkv, _xla_flash
    from repro.models.common import rope
    B, T, _ = h.shape
    q, k, v = _project_qkv(params, cfg, h)
    if cfg.rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    W = cache.k.shape[1]
    slot = jnp.where(positions >= T - W, positions % W, W)  # W = drop (dup-safe)
    bidx = jnp.arange(B)[:, None] * jnp.ones((1, T), jnp.int32)
    cache = LocalKVCache(
        k=cache.k.at[bidx, slot].set(k.astype(cache.k.dtype), mode="drop"),
        v=cache.v.at[bidx, slot].set(v.astype(cache.v.dtype), mode="drop"),
        pos=cache.pos.at[bidx, slot].set(positions, mode="drop"),
    )
    out = _xla_flash(q, k, v, causal=True, window=cfg.local_window,
                     attn_cap=cfg.attn_softcap)
    return jnp.einsum("bthk,hkd->btd", out, params["wo"]), cache


def block_apply(
    params,
    cfg: ModelConfig,
    kind: str,
    x: jax.Array,
    positions: jax.Array,
    *,
    cache: Any = None,
    cache_len: Optional[jax.Array] = None,
    enc_out: Optional[jax.Array] = None,
    mode: str = "train",          # train | prefill | decode
    causal: bool = True,
) -> Tuple[jax.Array, Any, jax.Array]:
    """Returns (x', cache', aux_loss)."""
    assert mode in ("train", "prefill", "decode"), mode
    aux = jnp.float32(0)
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    new_cache = cache
    if kind in ("attn", "local"):
        if mode == "decode" and kind == "local":
            h, new_cache = _local_ring_decode(params["mixer"], cfg, h, positions, cache)
        elif mode == "prefill" and kind == "local":
            h, new_cache = _prefill_local_ring(params["mixer"], cfg, h, positions, cache)
        elif mode == "prefill":
            h, new_cache = attention(
                params["mixer"], cfg, h, positions, kind=kind, causal=causal,
                fill_cache=cache,
            )
        else:
            h, new_cache = attention(
                params["mixer"], cfg, h, positions, kind=kind, causal=causal,
                cache=cache if mode == "decode" else None, cache_len=cache_len,
            )
    elif kind == "mlstm":
        fn = mlstm_decode if mode == "decode" else mlstm_chunkwise
        h, new_cache = fn(params["mixer"], cfg, h, cache)
    elif kind == "slstm":
        h, new_cache = slstm(params["mixer"], cfg, h, cache)
    elif kind == "rglru":
        fn = rglru_decode if mode == "decode" else rglru
        h, new_cache = fn(params["mixer"], cfg, h, cache)
    if cfg.post_norms:
        h = rms_norm(h, params["ln1_post"], cfg.norm_eps)
    x = x + h

    if "cross" in params:
        h = rms_norm(x, params["cross_ln"], cfg.norm_eps)
        h, _ = attention(params["cross"], cfg, h, positions, causal=False,
                         x_kv=enc_out, use_rope=False)
        x = x + h

    if "ffn" in params:
        h = rms_norm(x, params["ln2"], cfg.norm_eps)
        if cfg.num_experts > 0:
            h, aux = moe(params["ffn"], cfg, h)
        else:
            h = mlp(params["ffn"], cfg, h)
        if cfg.post_norms:
            h = rms_norm(h, params["ln2_post"], cfg.norm_eps)
        x = x + h
    return x, new_cache, aux
