"""Gated MLP (SwiGLU / GeGLU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init


def init_mlp(key, cfg: ModelConfig, dtype=jnp.float32, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "wu": dense_init(ks[1], (d, f), dtype=dtype),
        "wd": dense_init(ks[2], (f, d), dtype=dtype),
    }
    if cfg.gated_mlp:
        p["wg"] = dense_init(ks[0], (d, f), dtype=dtype)
    return p


def mlp(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    act = jax.nn.gelu if cfg.act in ("gelu", "geglu") else jax.nn.silu
    u = jnp.einsum("btd,df->btf", x, params["wu"])
    if cfg.gated_mlp:
        g = act(jnp.einsum("btd,df->btf", x, params["wg"]))
        h = g * u
    else:
        h = act(u)
    return jnp.einsum("btf,fd->btd", h, params["wd"])
