"""TransformerLM: the composable model covering all 10 assigned archs.

Depth structure: ``layer_pattern`` is cycled ``pattern_repeats`` times via
``lax.scan`` over *superblocks* (stacked params, one scan step applies the
whole pattern once) with optional per-superblock remat; any remainder layers
(pattern not dividing depth, e.g. recurrentgemma's 38 = 12*3 + 2) run
unrolled.  Scan keeps HLO size depth-independent — essential for compiling
qwen2.5-32b under 512 fake devices on one CPU.

Enc-dec (whisper): a bidirectional encoder stack over precomputed frame
embeddings; decoder blocks grow cross-attention sublayers.
VLM (internvl2): precomputed patch embeddings are prefixed to the token
embeddings; labels are masked over the prefix.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.blocks import block_apply, init_block, init_block_cache
from repro.models.common import (constrain_batch, cross_entropy_loss,
                                 embed_init, rms_norm, softcap)


def _pattern(cfg: ModelConfig) -> Tuple[str, ...]:
    return cfg.layer_pattern


def _is_encdec(cfg: ModelConfig) -> bool:
    return cfg.encoder_layers > 0


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Dict[str, Any]:
    pat = _pattern(cfg)
    R, tail = cfg.pattern_repeats, cfg.tail_layers
    keys = jax.random.split(key, 8)
    cross = _is_encdec(cfg)

    def init_superblock(k):
        ks = jax.random.split(k, len(pat))
        return {f"l{i}": init_block(ks[i], cfg, kind, dtype, cross=cross)
                for i, kind in enumerate(pat)}

    sb_keys = jax.random.split(keys[0], R)
    params: Dict[str, Any] = {
        "embed": embed_init(keys[1], (cfg.vocab_size, cfg.d_model), dtype),
        "sb": jax.vmap(init_superblock)(sb_keys),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if tail:
        tkeys = jax.random.split(keys[2], tail)
        params["tail"] = [
            init_block(tkeys[i], cfg, pat[i % len(pat)], dtype, cross=cross)
            for i in range(tail)
        ]
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(keys[3], (cfg.vocab_size, cfg.d_model), dtype)
    if _is_encdec(cfg):
        enc_cfg = dataclasses.replace(cfg, num_experts=0, post_norms=False)

        def init_enc_block(k):
            return {"l0": init_block(k, enc_cfg, "attn", dtype, cross=False)}

        ekeys = jax.random.split(keys[4], cfg.encoder_layers)
        params["encoder"] = {
            "sb": jax.vmap(init_enc_block)(ekeys),
            "final_norm": jnp.zeros((cfg.d_model,), dtype),
        }
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# encoder (whisper frames / any bidirectional stack)
# ---------------------------------------------------------------------------
def _run_encoder(params, cfg: ModelConfig, enc_x: jax.Array) -> jax.Array:
    B, S, d = enc_x.shape
    # fixed sinusoidal positions for the frame sequence
    pos = jnp.arange(S)
    half = d // 2
    freqs = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half) / half)
    ang = pos[:, None] * freqs[None, :]
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(enc_x.dtype)
    x = enc_x + pe[None]
    positions = jnp.broadcast_to(pos[None], (B, S)).astype(jnp.int32)
    enc_cfg = dataclasses.replace(cfg, num_experts=0, post_norms=False)

    def body(carry, sbp):
        h, _, _ = block_apply(sbp["l0"], enc_cfg, "attn", carry, positions,
                              causal=False)
        return h, None

    x, _ = jax.lax.scan(body, x, params["encoder"]["sb"])
    return rms_norm(x, params["encoder"]["final_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# forward (train / teacher-forced)
# ---------------------------------------------------------------------------
def forward(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,                       # i32[B, T_text]
    *,
    frontend_embeds: Optional[jax.Array] = None,   # [B, Nf, d] (vlm/audio enc)
    remat: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits [B, T_total, V], aux_loss)."""
    B, Tt = tokens.shape
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.sqrt(jnp.asarray(cfg.d_model, x.dtype))

    enc_out = None
    if _is_encdec(cfg):
        assert frontend_embeds is not None, "enc-dec needs frame embeddings"
        enc_out = _run_encoder(params, cfg, frontend_embeds)
    elif cfg.frontend != "none" and frontend_embeds is not None:
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)

    T = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T)).astype(jnp.int32)
    pat = _pattern(cfg)

    x = constrain_batch(x)

    def superblock(carry, sbp):
        h, aux = carry
        for i, kind in enumerate(pat):
            h, _, a = block_apply(sbp[f"l{i}"], cfg, kind, h, positions,
                                  enc_out=enc_out)
            aux = aux + a
        return (constrain_batch(h), aux), None

    sb_fn = jax.checkpoint(superblock) if remat else superblock
    (x, aux), _ = jax.lax.scan(sb_fn, (x, jnp.float32(0)), params["sb"])
    for i, bp in enumerate(params.get("tail", [])):
        x, _, a = block_apply(bp, cfg, pat[i % len(pat)], x, positions,
                              enc_out=enc_out)
        aux = aux + a

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    unembed = params.get("unembed", params["embed"])
    logits = jnp.einsum("btd,vd->btv", x, unembed)
    if cfg.final_softcap > 0:
        logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits, aux


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            remat: bool = True) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token CE over the text region (frontend prefix masked)."""
    tokens = batch["tokens"]
    logits, aux = forward(params, cfg, tokens,
                          frontend_embeds=batch.get("frontend"), remat=remat)
    Nf = 0
    if cfg.frontend != "none" and not _is_encdec(cfg) and "frontend" in batch:
        Nf = batch["frontend"].shape[1]
    text_logits = logits[:, Nf:, :]
    pred = text_logits[:, :-1]
    labels = tokens[:, 1:]
    mask = batch.get("loss_mask")
    mask = mask[:, 1:] if mask is not None else jnp.ones_like(labels, jnp.float32)
    ce = cross_entropy_loss(pred, labels, mask)
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=jnp.bfloat16):
    pat = _pattern(cfg)
    R, tail = cfg.pattern_repeats, cfg.tail_layers

    def one_sb(_):
        return {f"l{i}": init_block_cache(cfg, kind, batch, cache_len, dtype)
                for i, kind in enumerate(pat)}

    stacked = jax.tree.map(
        lambda *xs: jnp.stack(xs), *[one_sb(r) for r in range(R)]
    ) if R > 1 else jax.tree.map(lambda x: x[None], one_sb(0))
    tail_caches = [init_block_cache(cfg, pat[i % len(pat)], batch, cache_len, dtype)
                   for i in range(tail)]
    return {"sb": stacked, "tail": tail_caches}


def _serve_pass(params, cfg: ModelConfig, tokens, cache, cache_len, mode,
                enc_out=None, frontend_embeds=None):
    B, T = tokens.shape
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.sqrt(jnp.asarray(cfg.d_model, x.dtype))
    if (cfg.frontend != "none" and not _is_encdec(cfg)
            and frontend_embeds is not None):
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
        T = x.shape[1]
    positions = cache_len[:, None] + jnp.arange(T)[None]
    pat = _pattern(cfg)
    x = constrain_batch(x)

    def superblock(carry, xs):
        h = carry
        sbp, sbc = xs
        new_c = {}
        for i, kind in enumerate(pat):
            h, c, _ = block_apply(sbp[f"l{i}"], cfg, kind, h, positions,
                                  cache=sbc[f"l{i}"], cache_len=cache_len,
                                  enc_out=enc_out, mode=mode)
            new_c[f"l{i}"] = c
        return constrain_batch(h), new_c

    x, new_sb = jax.lax.scan(superblock, x, (params["sb"], cache["sb"]))
    new_tail = []
    for i, bp in enumerate(params.get("tail", [])):
        x, c, _ = block_apply(bp, cfg, pat[i % len(pat)], x, positions,
                              cache=cache["tail"][i], cache_len=cache_len,
                              enc_out=enc_out, mode=mode)
        new_tail.append(c)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    unembed = params.get("unembed", params["embed"])
    logits = jnp.einsum("btd,vd->btv", x, unembed)
    if cfg.final_softcap > 0:
        logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits, {"sb": new_sb, "tail": new_tail}


def decode_step(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,        # i32[B, T] (T=1 for autoregressive decode)
    cache,
    cache_len: jax.Array,     # i32[B] tokens already in cache
    *,
    enc_out: Optional[jax.Array] = None,
):
    """One decode step over the stacked caches.  Returns (logits, cache')."""
    return _serve_pass(params, cfg, tokens, cache, cache_len, "decode",
                       enc_out=enc_out)


def prefill(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,        # i32[B, T]
    cache,
    *,
    frontend_embeds: Optional[jax.Array] = None,
):
    """Build caches for a prompt (flash path, O(T*BS) memory).
    Returns (last_logits, cache', lengths)."""
    B = tokens.shape[0]
    enc_out = None
    fe = frontend_embeds
    if _is_encdec(cfg):
        enc_out = _run_encoder(params, cfg, frontend_embeds)
        fe = None
    zeros = jnp.zeros((B,), jnp.int32)
    logits, cache = _serve_pass(params, cfg, tokens, cache, zeros, "prefill",
                                enc_out=enc_out, frontend_embeds=fe)
    total = tokens.shape[1] + (fe.shape[1] if fe is not None else 0)
    return logits[:, -1:], cache, zeros + total
