"""xLSTM mixers: mLSTM (matrix memory, chunkwise-parallel) and sLSTM (scalar
memory, sequential with exponential-gate stabilization).

Numerics note (recorded in DESIGN.md §4): the input gate uses log-sigmoid
(bounded) rather than the paper's raw-exp with max-stabilizer for the mLSTM —
every exponent in the chunkwise form is then <= 0, so the chunk matmuls are
overflow-free on bf16-accumulating hardware; the sLSTM keeps the original
exp-input-gate with the m_t stabilizer since it is sequential anyway.  The
chunkwise train path is validated against the step-recurrent reference
exactly (tests/models).

mLSTM chunkwise layout: scan over T/L chunks; within a chunk everything is
(L x L) / (L x dh) matmuls — MXU-shaped — and the (C, n) state crosses chunk
boundaries, giving O(T * L * dh) work instead of O(T * dh^2) outer products.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, rms_norm

NEG = -1e30


def init_mlstm(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    di = int(cfg.proj_factor * d)
    H, hd = cfg.num_heads, max(1, di // cfg.num_heads)
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], (d, di), dtype=dtype),       # mixer input
        "w_gate": dense_init(ks[1], (d, di), dtype=dtype),     # output gate z
        "wq": dense_init(ks[2], (di, H, hd), dtype=dtype),
        "wk": dense_init(ks[3], (di, H, hd), dtype=dtype),
        "wv": dense_init(ks[4], (di, H, hd), dtype=dtype),
        "w_if": dense_init(ks[5], (di, H, 2), dtype=jnp.float32),  # i,f gates
        "ln_out": jnp.zeros((di,), dtype),
        "w_down": dense_init(ks[6], (di, d), dtype=dtype),
    }


class MLSTMState(NamedTuple):
    C: jax.Array   # [B, H, dk, dv] matrix memory
    n: jax.Array   # [B, H, dk]     normalizer


def mlstm_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> MLSTMState:
    di = int(cfg.proj_factor * cfg.d_model)
    H, hd = cfg.num_heads, max(1, di // cfg.num_heads)
    return MLSTMState(
        C=jnp.zeros((batch, H, hd, hd), jnp.float32),
        n=jnp.zeros((batch, H, hd), jnp.float32),
    )


def _qkv_gates(params, cfg, xm):
    q = jnp.einsum("btd,dhk->bthk", xm, params["wq"])
    k = jnp.einsum("btd,dhk->bthk", xm, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", xm, params["wv"])
    gates = jnp.einsum("btd,dhg->bthg", xm.astype(jnp.float32), params["w_if"])
    li = jax.nn.log_sigmoid(gates[..., 0])  # [B,T,H] log input gate (<= 0)
    lf = jax.nn.log_sigmoid(gates[..., 1])  # [B,T,H] log forget gate (<= 0)
    return q, k, v, li, lf


def mlstm_chunkwise(params, cfg: ModelConfig, x: jax.Array,
                    state: MLSTMState | None = None
                    ) -> Tuple[jax.Array, MLSTMState]:
    """Train/prefill path: chunk-parallel over [B, T, d]."""
    B, T, d = x.shape
    L = min(cfg.mlstm_chunk, T)
    xm = jnp.einsum("btd,de->bte", x, params["w_up"])
    z = jnp.einsum("btd,de->bte", x, params["w_gate"])
    q, k, v, li, lf = _qkv_gates(params, cfg, xm)
    T_orig = T
    pad = (-T) % L
    if pad:
        # ragged tail: padded steps carry f=1 (log 0), i=0 (log -inf) so the
        # state passes through unchanged and padded outputs are dropped.
        q, k, v = (jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
                   for a in (q, k, v))
        li = jnp.pad(li, ((0, 0), (0, pad), (0, 0)), constant_values=NEG)
        lf = jnp.pad(lf, ((0, 0), (0, pad), (0, 0)), constant_values=0.0)
        T = T + pad
    nC = T // L
    H, hd = q.shape[2], q.shape[3]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))

    def split(a):  # [B,T,...] -> [nC, B, L, ...]
        return a.reshape(B, nC, L, *a.shape[2:]).transpose(1, 0, 2, *range(3, a.ndim + 1))

    qs, ks_, vs = split(q), split(k), split(v)
    lis, lfs = split(li), split(lf)

    if state is None:
        state = mlstm_init_state(cfg, B)

    tri = jnp.tril(jnp.ones((L, L), jnp.float32))           # i >= j
    idx = jnp.arange(L)

    def chunk_body(carry, blk):
        C, n = carry                                         # [B,H,dk,dv], [B,H,dk]
        qc, kc, vc, lic, lfc = blk                           # [B,L,H,*]
        b = jnp.cumsum(lfc, axis=1)                          # [B,L,H] log decay
        qf = qc.astype(jnp.float32) * scale
        kf = kc.astype(jnp.float32)
        vf = vc.astype(jnp.float32)
        # inter-chunk: exp(b_i) * q_i @ C_prev
        inter = jnp.einsum("blhk,bhkv->blhv", qf * jnp.exp(b)[..., None], C)
        n_inter = jnp.exp(b)[..., None] * n[:, None]         # [B,L,H,dk]
        # intra-chunk decay D_ij = exp(b_i - b_j + li_j), i >= j
        logD = (b[:, :, None] - b[:, None, :] + lic[:, None, :, :])  # [B,L(i),L(j),H]
        D = jnp.exp(jnp.where(tri[None, :, :, None] > 0, logD, NEG))
        S = jnp.einsum("blhk,bmhk->blmh", qf, kf) * D        # [B,L,L,H]
        intra = jnp.einsum("blmh,bmhv->blhv", S, vf)
        n_intra = jnp.einsum("blmh,bmhk->blhk", D, kf)
        # combine + normalize
        num = inter + intra
        nn = n_inter + n_intra
        denom = jnp.abs(jnp.einsum("blhk,blhk->blh", qf, nn))
        h = num / jnp.maximum(denom, 1.0)[..., None]         # [B,L,H,dv]
        # state update to chunk end
        decay_end = jnp.exp(b[:, -1])                        # [B,H]
        w_j = jnp.exp(b[:, -1][:, None] - b + lic)           # [B,L,H]
        C_new = decay_end[..., None, None] * C + jnp.einsum(
            "blhk,blhv->bhkv", kf * w_j[..., None], vf)
        n_new = decay_end[..., None] * n + jnp.einsum(
            "blh,blhk->bhk", w_j, kf)
        return (C_new, n_new), h

    (C, n), hs = jax.lax.scan(chunk_body, (state.C, state.n),
                              (qs, ks_, vs, lis, lfs))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, T, H * hd)[:, :T_orig]  # [B,T,di]
    h = rms_norm(h, params["ln_out"], cfg.norm_eps)
    out = h.astype(x.dtype) * jax.nn.silu(z)
    return jnp.einsum("bte,ed->btd", out, params["w_down"]), MLSTMState(C, n)


def mlstm_decode(params, cfg: ModelConfig, x: jax.Array,
                 state: MLSTMState) -> Tuple[jax.Array, MLSTMState]:
    """Recurrent single/multi-token step (the step-exact reference)."""
    B, T, d = x.shape
    xm = jnp.einsum("btd,de->bte", x, params["w_up"])
    z = jnp.einsum("btd,de->bte", x, params["w_gate"])
    q, k, v, li, lf = _qkv_gates(params, cfg, xm)
    H, hd = q.shape[2], q.shape[3]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))

    def step(carry, t):
        C, n = carry
        qt = q[:, t].astype(jnp.float32) * scale             # [B,H,dk]
        kt = k[:, t].astype(jnp.float32)
        vt = v[:, t].astype(jnp.float32)
        f = jnp.exp(lf[:, t])[..., None]                     # [B,H,1]
        i = jnp.exp(li[:, t])[..., None]
        C = f[..., None] * C + i[..., None] * kt[..., :, None] * vt[..., None, :]
        n = f * n + i * kt
        denom = jnp.abs(jnp.einsum("bhk,bhk->bh", qt, n))
        h = jnp.einsum("bhk,bhkv->bhv", qt, C) / jnp.maximum(denom, 1.0)[..., None]
        return (C, n), h

    (C, n), hs = jax.lax.scan(step, (state.C, state.n), jnp.arange(T))
    h = hs.transpose(1, 0, 2, 3).reshape(B, T, H * hd)
    h = rms_norm(h, params["ln_out"], cfg.norm_eps)
    out = h.astype(x.dtype) * jax.nn.silu(z)
    return jnp.einsum("bte,ed->btd", out, params["w_down"]), MLSTMState(C, n)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def init_slstm(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    H, hd = cfg.num_heads, d // cfg.num_heads
    ks = jax.random.split(key, 3)
    wx = dense_init(ks[0], (d, H, 4 * hd), dtype=dtype)     # z,i,f,o inputs
    wr = dense_init(ks[1], (H, hd, 4 * hd), in_axis=1, dtype=dtype)  # recurrent
    return {
        "wx_s": wx,
        "wr": wr,
        "b": jnp.zeros((H, 4 * hd), jnp.float32),
        "ln_out": jnp.zeros((d,), dtype),
        "w_down": dense_init(ks[2], (d, d), dtype=dtype),
    }


class SLSTMState(NamedTuple):
    c: jax.Array   # [B,H,hd]
    n: jax.Array   # [B,H,hd]
    m: jax.Array   # [B,H,hd] stabilizer
    h: jax.Array   # [B,H,hd]


def slstm_init_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    H, hd = cfg.num_heads, cfg.d_model // cfg.num_heads
    z = jnp.zeros((batch, H, hd), jnp.float32)
    return SLSTMState(z, z, jnp.full_like(z, -1e30), z)


def slstm(params, cfg: ModelConfig, x: jax.Array,
          state: SLSTMState | None = None) -> Tuple[jax.Array, SLSTMState]:
    """Sequential sLSTM over [B, T, d] (xLSTM exp-gating with m stabilizer)."""
    B, T, d = x.shape
    H, hd = cfg.num_heads, d // cfg.num_heads
    if state is None:
        state = slstm_init_state(cfg, B)
    xproj = jnp.einsum("btd,dhg->bthg", x, params["wx_s"]).astype(jnp.float32)

    def step(carry, t):
        c, n, m, h = carry
        rec = jnp.einsum("bhk,hkg->bhg", h, params["wr"].astype(jnp.float32))
        g = xproj[:, t] + rec + params["b"]
        zt, it, ft, ot = jnp.split(g, 4, axis=-1)            # each [B,H,hd]
        zt = jnp.tanh(zt)
        ot = jax.nn.sigmoid(ot)
        lf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(lf + m, it)                      # stabilizer
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(lf + m - m_new)
        c_new = f_s * c + i_s * zt
        n_new = f_s * n + i_s
        h_new = ot * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
        return (c_new, n_new, m_new, h_new), h_new

    carry, hs = jax.lax.scan(step, tuple(state), jnp.arange(T))
    h = hs.transpose(1, 0, 2, 3).reshape(B, T, d)
    h = rms_norm(h, params["ln_out"], cfg.norm_eps).astype(x.dtype)
    return jnp.einsum("btd,de->bte", h, params["w_down"]), SLSTMState(*carry)
