"""Griffin/RecurrentGemma recurrent block: linear proj -> causal depthwise
conv1d -> RG-LRU -> gated output.

RG-LRU: per-channel gated linear recurrence
    r_t = sigmoid(W_a x_t + b_a)            (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)            (input gate)
    log a_t = -c * softplus(Lambda) * r_t   (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training uses ``jax.lax.associative_scan`` over the linear recurrence —
O(log T) depth, sub-quadratic, which is what qualifies recurrentgemma for the
``long_500k`` shape.  Decode is an O(1) step carrying (h, conv window).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init

LRU_C = 8.0


def init_rglru(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    w = cfg.rnn_width or d
    ks = jax.random.split(key, 6)
    # Lambda init so a^c*softplus ~ uniform decay in [0.9, 0.999]
    u = jax.random.uniform(ks[4], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / LRU_C))  # softplus^-1(-log u / c)
    return {
        "w_in": dense_init(ks[0], (d, w), dtype=dtype),
        "w_gate": dense_init(ks[1], (d, w), dtype=dtype),
        "conv_w": dense_init(ks[2], (cfg.conv_width, w), dtype=dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "wa": dense_init(ks[3], (w, w), dtype=jnp.float32),
        "ba": jnp.zeros((w,), jnp.float32),
        "wx": dense_init(ks[5], (w, w), dtype=jnp.float32),
        "bx": jnp.zeros((w,), jnp.float32),
        "lambda": lam,
        "w_out": dense_init(jax.random.fold_in(key, 7), (w, d), dtype=dtype),
    }


class RGLRUState(NamedTuple):
    h: jax.Array      # [B, w] recurrent state
    conv: jax.Array   # [B, conv_width-1, w] trailing conv inputs


def rglru_init_state(cfg: ModelConfig, batch: int) -> RGLRUState:
    w = cfg.rnn_width or cfg.d_model
    return RGLRUState(
        h=jnp.zeros((batch, w), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_width - 1, w), jnp.float32),
    )


def _conv1d(params, cfg, u, conv_state=None):
    """Causal depthwise conv via shifted adds; returns (out, new_state)."""
    W = cfg.conv_width
    cw = params["conv_w"]                    # [W, w]
    if conv_state is None:
        hist = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        hist = jnp.concatenate([conv_state.astype(u.dtype), u], axis=1)
    out = sum(
        hist[:, W - 1 - j : hist.shape[1] - j] * cw[W - 1 - j]
        for j in range(W)
    ) + params["conv_b"]
    new_state = hist[:, -(W - 1):].astype(jnp.float32)
    return out, new_state


def _gates(params, u):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ params["wa"] + params["ba"])
    i = jax.nn.sigmoid(uf @ params["wx"] + params["bx"])
    log_a = -LRU_C * jax.nn.softplus(params["lambda"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 0.0)) * (i * uf)
    return a, b


def rglru(params, cfg: ModelConfig, x: jax.Array,
          state: RGLRUState | None = None) -> Tuple[jax.Array, RGLRUState]:
    """[B, T, d] -> [B, T, d]; associative-scan train path."""
    B, T, d = x.shape
    u = jnp.einsum("btd,dw->btw", x, params["w_in"])
    gate = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, params["w_gate"]))
    u, conv_new = _conv1d(params, cfg, u, None if state is None else state.conv)
    a, b = _gates(params, u)                                  # [B,T,w] f32
    if state is not None:
        # fold carried state into the first step: h_0' = a_0*h_prev + b_0
        b = b.at[:, 0].add(a[:, 0] * state.h)

    def combine(x1, x2):
        a1, b1 = x1
        a2, b2 = x2
        return a1 * a2, a2 * b1 + b2

    a_sc, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    out = jnp.einsum("btw,wd->btd", (h * gate.astype(jnp.float32)).astype(x.dtype),
                     params["w_out"])
    return out, RGLRUState(h=h[:, -1], conv=conv_new)


def rglru_decode(params, cfg: ModelConfig, x: jax.Array,
                 state: RGLRUState) -> Tuple[jax.Array, RGLRUState]:
    """O(1) per-token decode step ([B, 1, d])."""
    u = jnp.einsum("btd,dw->btw", x, params["w_in"])
    gate = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, params["w_gate"]))
    u, conv_new = _conv1d(params, cfg, u, state.conv)
    a, b = _gates(params, u)                                  # [B,1,w]
    h = a[:, 0] * state.h + b[:, 0]
    out = jnp.einsum("btw,wd->btd",
                     (h[:, None] * gate.astype(jnp.float32)).astype(x.dtype),
                     params["w_out"])
    return out, RGLRUState(h=h, conv=conv_new)
