"""Shared model primitives: norms, RoPE, initializers, losses."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def rope(
    x: jax.Array,            # [..., T, H, D] or [..., T, D]
    positions: jax.Array,    # i32[..., T]
    theta: float = 10_000.0,
) -> jax.Array:
    """Rotary position embedding, pair-interleaved layout.

    Pairs are adjacent (2i, 2i+1) and rotated via a trailing size-2 reshape,
    so the op stays **shard-local when the head dim is sharded** (the
    split-halves layout would permute across shards).  Mathematically a fixed
    basis permutation of the classic form."""
    dt = x.dtype
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., T, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if x.ndim == positions.ndim + 2:  # head dim present: [..., T, H, D]
        cos, sin = cos[..., None, :], sin[..., None, :]
    xp = x.astype(jnp.float32).reshape(*x.shape[:-1], half, 2)
    x1, x2 = xp[..., 0], xp[..., 1]
    out = jnp.stack([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.reshape(*x.shape).astype(dt)


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32) -> jax.Array:
    """Truncated-normal fan-in init."""
    fan_in = shape[in_axis]
    std = 1.0 / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std
            ).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(x / cap) * cap if cap > 0 else x


def cross_entropy_loss(
    logits: jax.Array,      # [B, T, Vocab] (float32 recommended)
    labels: jax.Array,      # i32[B, T]
    mask: Optional[jax.Array] = None,
    z_loss: float = 1e-4,
) -> jax.Array:
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss > 0:
        loss = loss + z_loss * lse**2  # logit drift regularizer (PaLM)
    if mask is not None:
        loss = loss * mask
        return loss.sum() / jnp.maximum(mask.sum(), 1)
    return loss.mean()


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def constrain_batch(x: jax.Array) -> jax.Array:
    """Pin the leading (batch) dim of an activation to the data axes.

    GSPMD left to its own devices sometimes propagates the *parameter*
    sharding into activations (e.g. vocab-sharded embeddings turning [B,T,d]
    into a batch-replicated, d-sharded layout), silently serializing data
    parallelism.  This constraint re-anchors activations to batch-DP at every
    superblock boundary.  No-op outside a mesh context (unit tests)."""
    try:
        from jax.sharding import PartitionSpec as P
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names or "data" not in mesh.axis_names:
            return x
        baxes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
        total = 1
        for a in baxes:
            total *= dict(mesh.shape)[a]
        if x.shape[0] % total != 0:
            return x
        spec = P(baxes, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, spec)
    except (ImportError, AttributeError, ValueError):
        return x
