"""Sharding trees for the parameter/optimizer/batch pytrees.

Policy (megatron-style tensor parallelism + optional ZeRO-3):

* **TP over ``model``** — attention head dims, MLP hidden dims, MoE expert
  dims, vocab rows of (un)embedding tables.
* **FSDP over ``data``** — when ``fsdp=True``, the first TP-free dim of every
  matrix additionally shards over the data axis (params and both Adam
  moments, since ``launch.specs`` reuses the same tree for mu/nu).
* **Safety** — every axis assignment is checked for divisibility against the
  mesh; anything that does not divide falls back to replicated on that dim,
  so the same rules work for the 512-chip production mesh and a 2x2 fake-CPU
  test mesh.

All rules are *keypath*-driven: leaves under a stacked superblock (``sb`` in
the path — params scanned over layers carry a leading ``[R]`` dim) get a
``None`` prefix so the scan dim stays unsharded.
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# keypath helpers
# ---------------------------------------------------------------------------
def _keypath_parts(kp) -> Tuple[str, ...]:
    """jax keypath -> plain string parts ('sb', 'l0', 'mixer', 'wq', ...)."""
    parts: List[str] = []
    for entry in kp:
        if hasattr(entry, "key"):
            parts.append(str(entry.key))
        elif hasattr(entry, "idx"):
            parts.append(str(entry.idx))
        elif hasattr(entry, "name"):
            parts.append(str(entry.name))
        else:
            parts.append(str(entry))
    return tuple(parts)


def _axis_size(mesh: Mesh, axes) -> int:
    total = 1
    for a in axes if isinstance(axes, tuple) else (axes,):
        total *= mesh.shape.get(a, 1)
    return total


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    n = _axis_size(mesh, axes)
    return n > 1 and dim % n == 0 and dim >= n


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


# ---------------------------------------------------------------------------
# batch sharding
# ---------------------------------------------------------------------------
def batch_spec(leaf, mesh: Mesh, batch_size: Optional[int] = None) -> P:
    """PartitionSpec for one batch leaf: leading dim over (pod, data) when it
    divides, everything else replicated."""
    shape = getattr(leaf, "shape", None)
    if not shape:
        return P()
    B = batch_size if batch_size is not None else shape[0]
    baxes = batch_axes(mesh)
    lead = baxes if _fits(B, mesh, baxes) else None
    return P(*([lead] + [None] * (len(shape) - 1)))


def batch_sharding(batch, mesh: Mesh, batch_size: Optional[int] = None):
    """NamedSharding tree for an input-batch pytree (tokens/masks/frontend)."""
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, batch_spec(leaf, mesh, batch_size)),
        batch)


# ---------------------------------------------------------------------------
# host-stacked state (sharded MVGC, DESIGN.md §13)
# ---------------------------------------------------------------------------
def host_spec(leaf, axis: str = "gc_hosts") -> P:
    """PartitionSpec for one leaf of a host-stacked state tree: the leading
    ``[H]`` host dim shards over ``axis``, everything else is replicated.
    Scalars (no shape) are replicated outright."""
    shape = getattr(leaf, "shape", None)
    if not shape:
        return P()
    return P(*([axis] + [None] * (len(shape) - 1)))


def host_stacked_sharding(tree, mesh: Mesh, axis: str = "gc_hosts"):
    """NamedSharding tree placing a host-stacked MVGC state (every leaf
    carries a leading ``[H]`` dim, one slice per host — see
    ``repro.dist.mvgc.stack_states``) so each host's slab/page-pool shard
    lands on its own device, while announcement lanes stay host-local (the
    board rides inside the per-host slice)."""
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, host_spec(leaf, axis)), tree)


# ---------------------------------------------------------------------------
# parameter sharding
# ---------------------------------------------------------------------------
def _tp_axes(parts: Sequence[str], shape: Tuple[int, ...], *,
             attn_hd_shard: bool, moe_replicate: bool) -> List[Optional[str]]:
    """Tensor-parallel axis per core dim (before divisibility sanitation)."""
    name = parts[-1]
    rank = len(shape)
    axes: List[Optional[str]] = [None] * rank

    if name in ("embed", "unembed") and rank == 2:        # [V, d]
        axes[0] = "model"
    elif name in ("wq", "wk", "wv") and rank == 3:        # [d, H, hd]
        axes[2 if attn_hd_shard else 1] = "model"
    elif name == "wo" and rank == 3:                      # [H, hd, d]
        axes[1 if attn_hd_shard else 0] = "model"
    elif name in ("bq", "bk", "bv") and rank == 2:        # [H, hd]
        axes[1 if attn_hd_shard else 0] = "model"
    elif name in ("wg", "wu") and rank == 2:              # mlp [d, f]
        axes[1] = "model"
    elif name == "wd" and rank == 2:                      # mlp [f, d]
        axes[0] = "model"
    elif name in ("wg", "wu", "wd") and rank == 3:        # moe [E, d|f, f|d]
        if not moe_replicate:
            axes[0] = "model"                              # expert parallelism
    elif name == "shared" or name == "router":
        pass                                               # handled generically
    elif name in ("w_up", "w_gate", "w_in") and rank == 2:  # [d, di|w]
        axes[1] = "model"
    elif name in ("w_down", "w_out") and rank == 2:       # [di|w, d]
        axes[0] = "model"
    elif name == "wx_s" and rank == 3:                    # slstm [d, H, 4hd]
        axes[1] = "model"
    elif name == "wr" and rank == 3:                      # slstm [H, hd, 4hd]
        axes[0] = "model"
    elif name == "w_if" and rank == 3:                    # mlstm [di, H, 2]
        axes[1] = "model"
    # norms, biases, lambda, conv weights, routers: replicated (tiny)
    return axes


def _leaf_spec(parts: Sequence[str], leaf, mesh: Mesh, *, fsdp: bool,
               attn_hd_shard: bool, moe_replicate: bool,
               fsdp_axis: str = "data") -> P:
    shape = tuple(getattr(leaf, "shape", ()))
    stacked = "sb" in parts                   # leading [R] scan dim
    core = shape[1:] if stacked and len(shape) >= 1 else shape
    if not core:
        spec: List[Any] = []
    else:
        axes = _tp_axes(parts, core, attn_hd_shard=attn_hd_shard,
                        moe_replicate=moe_replicate)
        # sanitize TP assignments against the mesh
        axes = [a if a and _fits(core[i], mesh, a) else None
                for i, a in enumerate(axes)]
        if fsdp and len(core) >= 2:
            # ZeRO-3: first TP-free dim that the data axis divides
            for i, a in enumerate(axes):
                if a is None and _fits(core[i], mesh, fsdp_axis):
                    axes[i] = fsdp_axis
                    break
        spec = axes
    if stacked:
        spec = [None] + spec
    return P(*spec) if spec else P()


def param_shardings(params, mesh: Mesh, *, fsdp: bool = False,
                    attn_hd_shard: bool = False,
                    moe_replicate: bool = False):
    """NamedSharding tree mirroring ``params`` (arrays or ShapeDtypeStructs).

    ``attn_hd_shard`` moves attention TP from the head dim to the head-size
    dim (for head counts the model axis does not divide); ``moe_replicate``
    keeps expert weights replicated instead of expert-parallel."""
    def leaf(kp, x):
        return NamedSharding(
            mesh,
            _leaf_spec(_keypath_parts(kp), x, mesh, fsdp=fsdp,
                       attn_hd_shard=attn_hd_shard,
                       moe_replicate=moe_replicate))

    return jax.tree_util.tree_map_with_path(leaf, params)
