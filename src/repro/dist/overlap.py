"""Overlap-friendly collectives from ``shard_map`` + ``ppermute``.

XLA lowers ``psum`` to one fused all-reduce that cannot interleave with
compute.  A ring all-reduce decomposed into 2(n-1) ``ppermute`` hops —
reduce-scatter then all-gather, one chunk in flight per hop — gives the
scheduler n-1 independent send/recv pairs to overlap with whatever compute
the caller interleaves (gradient compression, the next microbatch's
backward, ...).  Numerically it computes exactly ``psum``: every element is
the sum of all n shards, accumulated in ring order.  ``reduce="mean"``
divides by the axis size (= ``pmean``), the correct reduction for
data-parallel gradient averaging.  ``reduce="min"`` replaces the additive
combine with an elementwise minimum (= ``pmin``) — the reduction the
sharded MVGC stack uses to compute the mesh-wide low-water mark from each
host's oldest announced timestamp (DESIGN.md §13; hosts with no pins
contribute the TS_MAX sentinel, which is the identity of ``min``).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def make_ring_all_reduce(
    mesh: Mesh, axis: str, reduce: str = "sum", shard_mapped: bool = True
) -> Callable[[jax.Array], jax.Array]:
    """Build ``fn(x)``: an all-reduce over ``axis`` as a chunked ppermute ring.

    ``x``'s leading dim is sharded over ``axis`` (it must divide); every
    device ends up with the sum of all shards, so the global result is the
    per-axis shard sum tiled ``n`` times — bitwise the ``psum`` of the local
    shards.

    ``reduce="mean"`` divides the ring sum by the axis size, matching
    ``jax.lax.pmean`` — the right reduction for data-parallel gradients,
    where the bare sum trains with gradients ``n``× too large.

    ``reduce="min"`` takes the elementwise minimum instead of the sum,
    matching ``jax.lax.pmin`` — the global-LWM reduction of the sharded
    MVGC stack.  The zero-padded chunk tail is harmless for every mode:
    pad positions only ever combine with other shards' pad positions (the
    locals are the same size on every device) and are sliced off before the
    reshape back.

    ``shard_mapped=False`` returns the per-shard ``local`` body *without*
    the ``shard_map`` wrapper, for callers already inside a ``shard_map``
    over ``axis`` (a DP training loop's ``grad_reduce`` hook —
    ``train.step.make_grad_reduce``): shard_map does not nest, but the bare
    body composes with any enclosing one that binds ``axis``.
    """
    if reduce not in ("sum", "mean", "min"):
        raise ValueError(
            f"reduce must be 'sum', 'mean' or 'min', got {reduce!r}")
    n = mesh.shape[axis]
    perm = [(i, (i + 1) % n) for i in range(n)]

    def local(x: jax.Array) -> jax.Array:
        if n == 1:
            return x / 1.0 if reduce == "mean" else x
        shape = x.shape
        flat = x.reshape(-1)
        c = -(-flat.size // n)                       # chunk elements (ceil)
        buf = jnp.zeros((n * c,), flat.dtype).at[: flat.size].set(flat)
        buf = buf.reshape(n, c)
        r = jax.lax.axis_index(axis)

        # reduce-scatter: after n-1 hops device r owns chunk (r+1)%n complete
        def rs_hop(s, b):
            send = b[(r - s) % n]
            recv = jax.lax.ppermute(send, axis, perm)
            if reduce == "min":
                return b.at[(r - s - 1) % n].min(recv)
            return b.at[(r - s - 1) % n].add(recv)

        buf = jax.lax.fori_loop(0, n - 1, rs_hop, buf)

        # all-gather: circulate the completed chunks around the same ring
        def ag_hop(s, b):
            recv = jax.lax.ppermute(b[(r + 1 - s) % n], axis, perm)
            return b.at[(r - s) % n].set(recv)

        buf = jax.lax.fori_loop(0, n - 1, ag_hop, buf)
        out = buf.reshape(-1)[: flat.size].reshape(shape)
        return out / n if reduce == "mean" else out

    if not shard_mapped:
        return local
    return jax.shard_map(local, mesh=mesh, in_specs=P(axis),
                         out_specs=P(axis), check_vma=False)
