"""Distribution layer: sharding trees, overlapped collectives, fault signals.

Three orthogonal modules, each consumable on its own:

* :mod:`repro.dist.sharding` — NamedSharding trees for params / batches
  (TP over ``model``, optional FSDP over ``data``), used by ``launch.specs``
  to build every (arch x shape x mesh) cell.
* :mod:`repro.dist.overlap` — hand-rolled collectives built from
  ``jax.shard_map`` + ``ppermute`` (chunked ring all-reduce) for paths where
  XLA's fused collective cannot overlap with compute.
* :mod:`repro.dist.straggler` — ``StepWatchdog`` (per-step latency outlier
  detection) and ``HeartbeatFile`` (cross-host liveness via the checkpoint
  filesystem), the fault-tolerance substrate of ``launch.train``.
* :mod:`repro.dist.mvgc` — the sharded multi-host MVGC stack: host-stacked
  version-store/page-pool state, global-LWM reclamation over the
  ``reduce="min"`` ring, and straggler-tolerant announcement aging
  (DESIGN.md §13).
"""
from repro.dist.mvgc import (ShardedPagedKVEngine, age_out_stale, global_lwm,
                             lwm_contributions, stack_states)
from repro.dist.overlap import make_ring_all_reduce
from repro.dist.sharding import (batch_sharding, batch_spec,
                                 host_stacked_sharding, param_shardings)
from repro.dist.straggler import HeartbeatFile, StepWatchdog

__all__ = [
    "batch_sharding",
    "batch_spec",
    "host_stacked_sharding",
    "param_shardings",
    "make_ring_all_reduce",
    "StepWatchdog",
    "HeartbeatFile",
    "ShardedPagedKVEngine",
    "stack_states",
    "lwm_contributions",
    "age_out_stale",
    "global_lwm",
]
