"""Sharded multi-host MVGC: partitioned version store + global-LWM
reclamation (DESIGN.md §13, ROADMAP item 3).

The deployable stack (``core.mvgc.vstore`` slabs governing the
``mvkv.paged`` page pool) scales out by *partitioning state, not the
protocol*: every leaf of the paged-KV state gains a leading ``[H]`` host dim
(:func:`stack_states`), placed one-slice-per-mesh-position by
``repro.dist.sharding.host_stacked_sharding``, and the single-host step
functions run unchanged on each shard (``jax.vmap`` over the host dim — the
shard boundary and the vmap boundary coincide, so XLA keeps every op
host-local).  Announcement lanes stay **host-local**: a reader pins on its
own host's board and nothing else moves.

What crosses hosts is one number: the **global low-water mark**.  Each GC
step gathers every host's oldest pin (:func:`lwm_contributions`; a pin-free
host contributes the ``TS_MAX`` identity), ages out hosts whose announcement
is staler than their watchdog budget (:func:`age_out_stale` — a stalled host
*bounds* reclamation for its budget, never blocks it), reduces with the
``reduce="min"`` ring all-reduce (``repro.dist.overlap``), and injects the
result into every shard's GC as ``extra_pins`` — so no shard ever reclaims a
version pinned by *any* live host, and EBR's epoch bound becomes
``min(local oldest, global LWM)``.

Telemetry speaks the unified vocabulary: the vmapped capacity gates return
:class:`repro.core.telemetry.PressureSignal` with ``[H]`` vector fields, and
the engine accounts into one :class:`repro.core.telemetry.ReclaimStats`
(plus ``stale_lanes_aged`` / ``lwm_advances``), feeding ``BENCH_dist.json``.
"""
from __future__ import annotations

import functools
import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mvgc.pool import EMPTY, TS_MAX
from repro.core.telemetry import GCConfig, PressureSignal, ReclaimStats
from repro.dist.overlap import make_ring_all_reduce
from repro.dist.sharding import host_stacked_sharding
from repro.dist.straggler import StepWatchdog
from repro.mvkv import paged


# ---------------------------------------------------------------------------
# host-stacked state
# ---------------------------------------------------------------------------
def stack_states(base, hosts: int):
    """Host-stack a single-host state tree: every array leaf gains a leading
    ``[H]`` dim (one identical copy per host).  The result composes with
    ``host_stacked_sharding`` for placement and with ``jax.vmap`` for
    running the single-host step functions shard-locally."""
    return jax.tree.map(
        lambda x: jnp.tile(x[None], (hosts,) + (1,) * x.ndim), base)


def lwm_contributions(st: paged.PagedKV) -> jax.Array:
    """i32[H]: each host's LWM contribution — the oldest timestamp pinned on
    its (host-local) announcement board, or the ``TS_MAX`` sentinel when the
    board is pin-free.  The sentinel is the identity of ``min``, so idle
    hosts drop out of the global reduction instead of capping it at their
    own clock (see ``announce.lwm`` for the single-board form)."""
    slots = st.mv.board.slots                       # [H, P]
    return jnp.where(slots != EMPTY, slots, TS_MAX).min(axis=1) \
        .astype(jnp.int32)


def age_out_stale(contrib: jax.Array, ages_s, budget_s
                  ) -> Tuple[jax.Array, jax.Array]:
    """Straggler tolerance: replace stale hosts' contributions with the
    ``TS_MAX`` sentinel.  ``ages_s[H]`` is the age of each host's last
    announcement refresh; a host whose age exceeds ``budget_s`` (scalar or
    ``[H]``, typically ``GCConfig.stale_after_s`` or
    ``StepWatchdog.budget_s``) is presumed stalled and aged out — its pins
    stop holding back the mesh-wide LWM, so one wedged host *bounds* (never
    blocks) everyone else's reclamation.  Returns ``(aged[H], n_aged)``
    where ``n_aged`` counts the lanes actually aged out (hosts that were
    both stale and pinning)."""
    contrib = jnp.asarray(contrib, jnp.int32)
    ages = jnp.asarray(ages_s, jnp.float32)
    budget = jnp.broadcast_to(jnp.asarray(budget_s, jnp.float32), ages.shape)
    stale = ages > budget
    aged = jnp.where(stale, TS_MAX, contrib)
    n_aged = (stale & (contrib != TS_MAX)).sum().astype(jnp.int32)
    return aged, n_aged


def global_lwm(contrib: jax.Array, ring=None) -> jax.Array:
    """Mesh-wide LWM: ``min`` over the per-host contributions, i32[].

    ``ring`` is a ``make_ring_all_reduce(mesh, axis, reduce="min")`` callable
    when the contributions are sharded over a real mesh axis — the 2(n-1)-hop
    ppermute ring does the cross-host combine and leaves every position
    holding the reduced vector; the trailing ``min`` is then shard-locally
    trivial.  With ``ring=None`` (single device / unsharded test states) the
    plain reduction computes the same value."""
    red = ring(contrib) if ring is not None else contrib
    return red.min().astype(jnp.int32)


# ---------------------------------------------------------------------------
# sharded serving engine
# ---------------------------------------------------------------------------
class ShardedPagedKVEngine:
    """Multi-host paged-KV serving with global-LWM reclamation.

    ``hosts`` logical shards, each owning ``num_seqs`` sequences and
    ``num_pages`` pool pages, stacked along a leading ``[H]`` dim and placed
    over ``mesh`` (default :func:`repro.launch.mesh.make_gc_mesh`; when the
    machine has fewer devices than hosts the stack stays unsharded and every
    reduction degrades gracefully — the protocol is placement-independent).
    All batched entry points take ``[H, ...]``-leading arguments.

    Every GC-bearing step first refreshes the global LWM (contributions ->
    staleness aging -> ring-min) and threads it through the shard ops as
    ``extra_pins``, so reclamation on any shard respects every live host's
    pins.  Per-host :class:`StepWatchdog` instances supply the staleness
    budget when ``gc.stale_after_s`` is inf; ``virtual_ages_s`` lets tests
    and the dist bench inject deterministic announcement ages instead of
    wall clock."""

    def __init__(self, hosts: int, num_seqs: int, num_pages: int,
                 page_size: int, max_pages_per_seq: int, kv_heads: int,
                 head_dim: int, *, gc: Optional[GCConfig] = None,
                 mesh=None, dtype=jnp.float32):
        cfg = gc if gc is not None else GCConfig()
        self.gc = cfg
        self.hosts = hosts
        if mesh is None:
            from repro.launch.mesh import make_gc_mesh
            mesh = make_gc_mesh(hosts)
        self.mesh = mesh
        axis = mesh.axis_names[0]
        n = mesh.shape[axis]

        base = paged.make_paged_kv(num_seqs, num_pages, page_size,
                                   max_pages_per_seq, kv_heads, head_dim,
                                   gc=cfg, dtype=dtype)
        st = stack_states(base, hosts)
        if n > 1 and hosts % n == 0:
            st = jax.device_put(st, host_stacked_sharding(st, mesh, axis))
            self._ring = jax.jit(make_ring_all_reduce(mesh, axis,
                                                      reduce="min"))
        else:
            self._ring = None
        self.st = st

        kern = cfg.kernel_kwargs()

        def _append(s, seq, k, v, m, pins):
            return paged.append_tokens(s, seq, k, v, m,
                                       gc_policy=cfg.policy,
                                       extra_pins=pins, **kern)

        def _reset(s, seq, m, pins):
            return paged.reset_sequence(s, seq, m, gc_policy=cfg.policy,
                                        extra_pins=pins, **kern)

        def _fork(s, src, dst, m, pins):
            return paged.fork_sequence(s, src, dst, m, gc_policy=cfg.policy,
                                       extra_pins=pins, **kern)

        def _reclaim(s, hot, deficit, pins):
            return paged.reclaim_on_pressure(s, hot, deficit,
                                             gc_policy=cfg.policy,
                                             extra_pins=pins, **kern)

        def _evict(s, ckpt, pins):
            return paged.evict_checkpointed(s, ckpt, extra_pins=pins)

        self._append = jax.jit(jax.vmap(_append))
        self._reset = jax.jit(jax.vmap(_reset))
        self._fork = jax.jit(jax.vmap(_fork))
        self._reclaim_v = jax.jit(jax.vmap(_reclaim))
        self._evict_v = jax.jit(jax.vmap(_evict))
        self._gate = jax.jit(jax.vmap(functools.partial(
            paged.page_pressure, watermark=cfg.page_watermark)))
        self._hot = jax.jit(jax.vmap(functools.partial(
            paged.hot_sequences, k=cfg.hot_k)))

        self.watchdogs: List[StepWatchdog] = [StepWatchdog()
                                              for _ in range(hosts)]
        # deterministic announcement ages for tests/benches (None = fresh)
        self.virtual_ages_s: Optional[np.ndarray] = None
        self.stats = ReclaimStats(unit="pages")
        self.lwm_advances = 0
        self._last_lwm = -1
        self.forks = 0
        #: highest durably checkpointed timestamp across the mesh; -1 = no
        #: checkpoint.  Arms the sole-survivor eviction rule on every shard
        #: (DESIGN.md §14).
        self.ckpt_max: int = -1

    # -- global LWM ----------------------------------------------------------
    def ages_s(self) -> np.ndarray:
        """f32[H] announcement-refresh age per host: the injected virtual
        ages when set (deterministic tests/benches), else zero — in a real
        deployment this is each host's ``HeartbeatFile.age_s``."""
        if self.virtual_ages_s is not None:
            return np.asarray(self.virtual_ages_s, np.float32)
        return np.zeros((self.hosts,), np.float32)

    def budget_s(self) -> np.ndarray:
        """f32[H] staleness budget per host: ``gc.stale_after_s`` when
        finite, else each host's always-finite ``StepWatchdog.budget_s``
        (the inf-vs-inf warmup hole is closed there)."""
        if math.isfinite(self.gc.stale_after_s):
            return np.full((self.hosts,), self.gc.stale_after_s, np.float32)
        return np.asarray([wd.budget_s() for wd in self.watchdogs],
                          np.float32)

    def lwm_pins(self) -> jax.Array:
        """One global-LWM refresh: contributions -> staleness aging ->
        ring-min.  Returns the per-host ``extra_pins`` array ``i32[H, 1]``
        (every host gets the same mesh-wide LWM) and accounts
        ``stale_lanes_aged`` / ``lwm_advances``."""
        contrib = lwm_contributions(self.st)
        aged, n_aged = age_out_stale(contrib, self.ages_s(), self.budget_s())
        self.stats.stale_lanes_aged += int(n_aged)
        lwm = global_lwm(aged, self._ring)
        val = int(lwm)
        # an "advance" is the LWM moving up from a real pin (TS_MAX is the
        # pin-free sentinel, not a position); decreases — a new pin arriving
        # — just retrack
        if 0 <= self._last_lwm < int(TS_MAX) and val > self._last_lwm:
            self.lwm_advances += 1
        self._last_lwm = val
        return jnp.broadcast_to(lwm, (self.hosts, 1))

    # -- accounting ----------------------------------------------------------
    def _note_peak(self) -> None:
        self.stats.note_live(int(self.live_pages()))

    def _reclaim_once(self, pins: jax.Array, extra_deficit: int = 0) -> None:
        gate = self._gate(self.st)
        deficit = jnp.maximum(gate.deficit,
                              max(1, extra_deficit)).astype(jnp.int32)
        self.st, pages = self._reclaim_v(self.st, self._hot(self.st),
                                         deficit, pins)
        freed = int(pages.sum())
        # checkpoint-coupled eviction (DESIGN.md §14): shards still under
        # pressure drop idle sole-survivor sequences that durable storage
        # already holds — pages no policy pass can reach
        if self.ckpt_max >= 0 and bool(
                self._gate(self.st).under_pressure.any()):
            ck = jnp.full((self.hosts,), int(self.ckpt_max), jnp.int32)
            self.st, ck_pages, n_ev = self._evict_v(self.st, ck, pins)
            self.stats.note_ckpt_eviction(int(n_ev.sum()),
                                          int(ck_pages.sum()))
            freed += int(ck_pages.sum())
        self.stats.note_reclaim(freed, int(self.live_pages()))

    # -- batched serving ops (all args [H, ...]-leading) ---------------------
    def step(self, seq_ids: jax.Array, k_new: jax.Array, v_new: jax.Array,
             mask: jax.Array) -> jax.Array:
        """Append one token per masked sequence on every host, with the
        same reclaim-and-retry pressure discipline as ``PagedKVEngine.step``
        — every append and reclaim carries the fresh global LWM.  Returns
        failed[H, B]."""
        pins = self.lwm_pins()
        self.st, failed = self._append(self.st, seq_ids, k_new, v_new,
                                       mask, pins)
        self._note_peak()
        rounds = 0
        while bool(failed.any()) and rounds < self.gc.max_reclaim_rounds:
            self.stats.note_event()
            self._reclaim_once(pins, extra_deficit=int(failed.sum()))
            pins = self.lwm_pins()
            self.st, failed = self._append(self.st, seq_ids, k_new, v_new,
                                           failed, pins)
            self._note_peak()
            rounds += 1
        if bool(self._gate(self.st).under_pressure.any()):
            self.stats.note_event()
            self._reclaim_once(pins)
        if bool(failed.any()):
            self.stats.give_ups += int(failed.sum())
        return failed

    def reset(self, seq_ids: jax.Array, mask: jax.Array) -> jax.Array:
        """Recycle finished sequences on every host (empty table version)."""
        pins = self.lwm_pins()
        self.st, failed = self._reset(self.st, seq_ids, mask, pins)
        rounds = 0
        while bool(failed.any()) and rounds < self.gc.max_reclaim_rounds:
            self.stats.note_event()
            self._reclaim_once(pins, extra_deficit=int(failed.sum()))
            pins = self.lwm_pins()
            self.st, failed = self._reset(self.st, seq_ids, failed, pins)
            rounds += 1
        if bool(failed.any()):
            self.stats.give_ups += int(failed.sum())
        return failed

    def fork(self, src_ids: jax.Array, dst_ids: jax.Array,
             mask: jax.Array) -> jax.Array:
        """COW fork on every host (src and dst are host-local sequences)."""
        pins = self.lwm_pins()
        self.st, failed = self._fork(self.st, src_ids, dst_ids, mask, pins)
        self._note_peak()
        rounds = 0
        while bool(failed.any()) and rounds < self.gc.max_reclaim_rounds:
            self.stats.note_event()
            self._reclaim_once(pins, extra_deficit=int(failed.sum()))
            pins = self.lwm_pins()
            self.st, failed = self._fork(self.st, src_ids, dst_ids,
                                         failed, pins)
            self._note_peak()
            rounds += 1
        if bool(failed.any()):
            self.stats.give_ups += int(failed.sum())
        self.forks += int((np.asarray(mask) & ~np.asarray(failed)).sum())
        return failed

    def reclaim(self, deficit: Optional[int] = None) -> int:
        """Explicit GC pass on every shard against the fresh global LWM
        (the sharded ``gc_step``).  ``deficit=None`` chases each shard's
        gate deficit; a large explicit deficit forces the full cold-spill
        sweep on every shard.  Returns total pages freed."""
        pins = self.lwm_pins()
        before = int(self.live_pages())
        if deficit is None:
            self._reclaim_once(pins)
        else:
            d = jnp.full((self.hosts,), int(deficit), jnp.int32)
            self.st, pages = self._reclaim_v(self.st, self._hot(self.st),
                                             d, pins)
            self.stats.note_reclaim(int(pages.sum()),
                                    int(self.live_pages()))
        return before - int(self.live_pages())

    # -- host-local pins and snapshot reads ----------------------------------
    def pin(self, host: int, lane: int) -> int:
        """Pin ``host``'s current timestamp on its local board lane — the
        announcement never leaves the host; only the LWM reduction sees it.
        Returns the pinned timestamp."""
        now = self.st.mv.now[host]
        slots = self.st.mv.board.slots.at[host, lane].set(now)
        board = self.st.mv.board._replace(slots=slots)
        self.st = self.st._replace(mv=self.st.mv._replace(board=board))
        return int(now)

    def unpin(self, host: int, lane: int) -> None:
        slots = self.st.mv.board.slots.at[host, lane].set(EMPTY)
        board = self.st.mv.board._replace(slots=slots)
        self.st = self.st._replace(mv=self.st.mv._replace(board=board))

    def host_state(self, host: int) -> paged.PagedKV:
        """This host's shard as a plain single-host ``PagedKV`` view."""
        return jax.tree.map(lambda x: x[host], self.st)

    def view_at(self, host: int, t: int,
                seq_ids: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
        """Snapshot-read ``host``'s shard at pinned time ``t`` (page tables
        + visible lengths), exactly ``paged.snapshot_view`` on the slice."""
        local = self.host_state(host)
        if seq_ids is None:
            seq_ids = jnp.arange(local.mv.store.ts.shape[0], dtype=jnp.int32)
        return paged.snapshot_view(local, seq_ids, jnp.int32(t),
                                   **self.gc.kernel_kwargs())

    # -- durability (DESIGN.md §14) -------------------------------------------
    def checkpoint(self, directory, step: Optional[int] = None) -> int:
        """Durably checkpoint the whole host-stacked pytree (every shard's
        pages, tables, retire ring, announce board) plus the engine's
        accounting, then advance ``ckpt_max`` to the slowest shard's clock —
        a version is only durable mesh-wide once *every* shard has passed
        it.  Returns the manifest step."""
        import dataclasses
        import os as _os
        from repro.ckpt.manager import CheckpointManager
        mgr = (directory if isinstance(directory, CheckpointManager)
               else CheckpointManager(_os.fspath(directory)))
        ts = int(jnp.min(self.st.mv.now))
        step = ts if step is None else int(step)
        extra = {
            "stats": dataclasses.asdict(self.stats),
            "forks": self.forks,
            "lwm_advances": self.lwm_advances,
            "last_lwm": self._last_lwm,
            "ckpt_max": ts,
        }
        mgr.save(step, self.st, extra=extra)
        self.ckpt_max = ts
        return step

    def restore(self, directory, step: Optional[int] = None) -> int:
        """Inverse of `checkpoint`: replace the stacked pytree and replay
        the accounting, so mesh-wide reclamation resumes where the saved
        engine left off.  ``step=None`` restores the latest manifest."""
        import os as _os
        from repro.ckpt.manager import CheckpointManager
        mgr = (directory if isinstance(directory, CheckpointManager)
               else CheckpointManager(_os.fspath(directory)))
        if step is None:
            step = mgr.latest_step()
            if step is None:
                raise FileNotFoundError(
                    f"no checkpoint manifest under {mgr.dir!r}")
        tree, extra = mgr.restore(int(step), like=self.st)
        self.st = jax.tree.map(jnp.asarray, tree)
        self.stats = ReclaimStats(**extra.get("stats", {}))
        self.forks = int(extra.get("forks", 0))
        self.lwm_advances = int(extra.get("lwm_advances", 0))
        self._last_lwm = int(extra.get("last_lwm", -1))
        self.ckpt_max = int(extra.get("ckpt_max", -1))
        return int(step)

    # -- telemetry ------------------------------------------------------------
    def live_pages(self) -> jax.Array:
        return (~self.st.free).sum()

    def pressure(self) -> PressureSignal:
        """The unified gate over all shards: ``PressureSignal`` with
        ``[H]`` vector fields (one entry per host)."""
        return self._gate(self.st)

    def space(self) -> Dict[str, int]:
        """Flat counters for BENCH_dist rows: the unified ReclaimStats
        vocabulary plus the dist-only fields."""
        sig = self.pressure()
        rep = dict(self.stats.as_row())
        rep["hosts"] = self.hosts
        rep["live_pages"] = int(self.live_pages())
        rep["free_pages"] = int(self.st.free.sum())
        rep["page_pool"] = int(np.prod(self.st.free.shape))
        rep["under_pressure_hosts"] = int(sig.under_pressure.sum())
        rep["lwm"] = self._last_lwm
        rep["lwm_advances"] = self.lwm_advances
        rep["overflows"] = int(self.st.mv.overflow_count.sum())
        rep["dropped_retires"] = int(self.st.mv.dropped_retires.sum())
        rep["forks"] = self.forks
        return rep
