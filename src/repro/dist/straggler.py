"""Straggler detection + cross-host liveness for the training loop.

``StepWatchdog`` flags step-time outliers online (Welford mean/variance over
non-suspect steps; a step is suspect when it exceeds mean + k_sigma * std and
the absolute ``min_budget_s`` floor).  Suspect steps are excluded from the
running statistics so one hiccup does not inflate the threshold and mask the
next one.

``HeartbeatFile`` writes a tiny JSON record through the shared checkpoint
filesystem (atomic tmp+rename), the standard multi-host liveness channel when
hosts share only storage: an external supervisor — or any peer host —
declares a host dead when its heartbeat age exceeds a few step budgets and
triggers restart-from-latest-checkpoint (see ``launch.train``).
"""
from __future__ import annotations

import json
import math
import os
import time
from typing import List, Optional


class StepWatchdog:
    """Per-step wall-clock outlier detector.

    >>> wd = StepWatchdog()
    >>> wd.start(); ...train step...; dt = wd.stop(step)
    """

    def __init__(self, k_sigma: float = 3.0, min_budget_s: float = 0.25,
                 warmup_steps: int = 5):
        self.k_sigma = k_sigma
        self.min_budget_s = min_budget_s
        self.warmup_steps = warmup_steps
        self.suspect_steps: List[int] = []
        self._t0: Optional[float] = None
        self._n = 0
        self._mean = 0.0
        self._m2 = 0.0

    def start(self) -> None:
        self._t0 = time.monotonic()

    def threshold(self) -> float:
        """Current suspect threshold in seconds (inf during warmup)."""
        if self._n < self.warmup_steps:
            return math.inf
        std = math.sqrt(self._m2 / max(1, self._n - 1))
        return max(self.min_budget_s, self._mean + self.k_sigma * std)

    def budget_s(self, grace_steps: float = 3.0) -> float:
        """Always-finite staleness budget for cross-host liveness checks,
        in seconds: ``grace_steps`` suspect-thresholds' worth of wall clock.

        Unlike :meth:`threshold`, this never returns inf: during warmup (or
        when ``stop()`` was never called after ``start()``) it falls back to
        ``grace_steps * min_budget_s``.  Comparing a ``HeartbeatFile.age_s``
        of inf (host never beat) against an inf warmup threshold evaluates
        ``inf > inf == False`` — a dead host reads as live exactly while the
        watchdog knows least.  The finite floor closes that hole; the
        sharded-GC staleness aging (DESIGN.md §13) and ``launch.train`` both
        compare ages against *this*."""
        thr = self.threshold()
        if not math.isfinite(thr):
            thr = self.min_budget_s
        return grace_steps * thr

    def is_stale(self, age_s: float, grace_steps: float = 3.0) -> bool:
        """True when a heartbeat/announcement of age ``age_s`` seconds is
        past the staleness budget (inf ages — never beaten — are always
        stale; see :meth:`budget_s`)."""
        return age_s > self.budget_s(grace_steps)

    def stop(self, step: int) -> float:
        """Returns the step duration; records ``step`` if it is a straggler."""
        assert self._t0 is not None, "stop() without start()"
        dt = time.monotonic() - self._t0
        self._t0 = None
        if dt > self.threshold():
            self.suspect_steps.append(step)
            return dt  # outliers stay out of the running stats
        self._n += 1
        delta = dt - self._mean
        self._mean += delta / self._n
        self._m2 += delta * (dt - self._mean)
        return dt


class HeartbeatFile:
    """Liveness beacon on the shared filesystem, one file per host."""

    def __init__(self, path: str, host_id: int = 0):
        self.path = path
        self.host_id = int(host_id)
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)

    def beat(self, step: int) -> None:
        rec = {"host_id": self.host_id, "step": int(step), "time": time.time()}
        tmp = f"{self.path}.tmp-{self.host_id}"
        with open(tmp, "w") as f:
            json.dump(rec, f)
        os.replace(tmp, self.path)  # atomic on POSIX

    def read(self) -> Optional[dict]:
        try:
            with open(self.path) as f:
                return json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return None

    def age_s(self, now: Optional[float] = None) -> float:
        """Seconds since the last beat (inf when never beaten/corrupt)."""
        rec = self.read()
        if rec is None:
            return math.inf
        return (now if now is not None else time.time()) - rec["time"]
