"""Checkpoint manager: atomic commits, MVGC-driven retention, elastic restore.

Fault-tolerance contract (1000+-node posture):
* **atomic commit** — write to ``<dir>/.tmp-<step>`` then ``os.rename``; a
  crash mid-save can never corrupt the latest checkpoint.
* **restart** — ``latest_step()`` + ``restore()``; the training driver resumes
  from (params, opt state, data-pipeline step) exactly.
* **elastic restore** — checkpoints store the *logical* pytree (numpy per
  leaf + tree manifest); ``restore(shardings=...)`` device_puts onto any mesh
  shape, so a job can restart on a different pod count.
* **MVGC retention** — checkpoints are versions of the "model" object with
  interval [step, next_step); evaluators/serving pin steps through the
  announce file; ``gc()`` computes the paper's needed(A, t) predicate and
  deletes obsolete checkpoints while *always* keeping the newest.  This is
  the paper's technique applied verbatim at the artifact-retention layer.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self._ann_path = os.path.join(directory, "announced.json")

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> str:
        tmp = os.path.join(self.dir, f".tmp-{step}")
        final = os.path.join(self.dir, f"ckpt_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves, treedef = jax.tree.flatten(tree)
        manifest = {
            "step": step,
            "treedef": _treedef_to_str(treedef),
            "num_leaves": len(leaves),
            "extra": extra or {},
            "time": time.time(),
        }
        for i, leaf in enumerate(leaves):
            np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), np.asarray(leaf))
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)            # atomic commit
        return final

    # -- restore ---------------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("ckpt_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int, like: Any, shardings: Any = None) -> Tuple[Any, Dict]:
        """Rebuild the pytree saved at ``step``.  ``like`` supplies the tree
        structure; ``shardings`` (optional, same structure) device_puts each
        leaf onto the current mesh — elastic resharding."""
        path = os.path.join(self.dir, f"ckpt_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        _, treedef = jax.tree.flatten(like)
        leaves = [np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
                  for i in range(manifest["num_leaves"])]
        tree = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, manifest["extra"]

    # -- MVGC retention ------------------------------------------------------
    def announce(self, reader: str, step: int) -> None:
        """An evaluator/serving job pins checkpoint `step` (the rtx announce)."""
        ann = self._read_ann()
        ann[reader] = step
        with open(self._ann_path, "w") as f:
            json.dump(ann, f)

    def unannounce(self, reader: str) -> None:
        ann = self._read_ann()
        ann.pop(reader, None)
        with open(self._ann_path, "w") as f:
            json.dump(ann, f)

    def _read_ann(self) -> Dict[str, int]:
        if os.path.exists(self._ann_path):
            with open(self._ann_path) as f:
                return json.load(f)
        return {}

    def gc(self, keep_last: int = 1) -> List[int]:
        """Delete obsolete checkpoints per needed(A, t): checkpoint s_i with
        interval [s_i, s_{i+1}) is needed iff some announced step a satisfies
        s_i <= a < s_{i+1}, or it is among the newest ``keep_last``.
        Returns the deleted steps."""
        steps = self.steps()
        if not steps:
            return []
        announced = sorted(self._read_ann().values())
        deleted = []
        for i, s in enumerate(steps):
            if i >= len(steps) - keep_last:
                continue                      # newest versions always needed
            nxt = steps[i + 1]
            needed = any(s <= a < nxt for a in announced)
            if not needed:
                shutil.rmtree(os.path.join(self.dir, f"ckpt_{s:08d}"))
                deleted.append(s)
        return deleted


def _treedef_to_str(treedef) -> str:
    return str(treedef)
