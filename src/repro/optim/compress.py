"""Int8 error-feedback gradient compression.

Distributed-optimization trick for cross-pod all-reduce: gradients are
quantized to int8 with a per-tensor scale before the (slow, DCN-bound)
``pod``-axis reduction, and the quantization error is fed back into the next
step's gradient (error feedback preserves convergence; Karimireddy et al.).
Intra-pod (ICI) reductions stay full-precision — only the inter-pod hop pays
the 4x byte reduction.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g.astype(jnp.float32))), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads: Any, error: Any) -> Tuple[Any, Any, Any]:
    """Returns (quantized, scales, new_error).  error is carried state with
    the same structure as grads (zeros initially)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize(corrected)
        deq = dequantize(q, s)
        return q, s, corrected - deq

    out = jax.tree.map(one, grads, error)
    istuple = lambda x: isinstance(x, tuple)
    return (jax.tree.map(lambda t: t[0], out, is_leaf=istuple),
            jax.tree.map(lambda t: t[1], out, is_leaf=istuple),
            jax.tree.map(lambda t: t[2], out, is_leaf=istuple))


def decompress_tree(qs: Any, scales: Any) -> Any:
    return jax.tree.map(dequantize, qs, scales)


def init_error(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
