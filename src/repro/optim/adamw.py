"""AdamW with global-norm clipping — functional, pytree-based, shardable
(optimizer state inherits parameter shardings; FSDP shards it too)."""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any       # first moment (pytree like params)
    nu: Any       # second moment


def init(params) -> AdamWState:
    z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(
        step=jnp.int32(0),
        mu=jax.tree.map(z, params),
        nu=jax.tree.map(z, params),
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply(
    params,
    grads,
    state: AdamWState,
    *,
    lr: jax.Array | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
) -> Tuple[Any, AdamWState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9)) if grad_clip else 1.0
    step = state.step + 1
    b1c = 1 - b1 ** step.astype(jnp.float32)
    b2c = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), {"grad_norm": gnorm}


def cosine_schedule(step, *, base_lr: float, warmup: int = 100,
                    total: int = 10_000, min_frac: float = 0.1):
    stepf = step.astype(jnp.float32)
    warm = stepf / max(1, warmup)
    prog = jnp.clip((stepf - warmup) / max(1, total - warmup), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * jnp.where(stepf < warmup, warm, cos)
