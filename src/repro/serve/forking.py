"""Fork-DAG bookkeeping and replay validation for COW sequence forking.

`mvkv.paged.fork_sequence` makes a fork a *page-table version write*: the
child's first table version shares every full page with the parent's current
version (DESIGN.md §14).  The device side needs no refcounts — the
reachability sweep (`paged._sweep_unreferenced`) frees a page exactly when no
live table version references it, which is precisely "when the last
descendant releases it".  What the device side cannot give us is *checking*:

* :func:`page_refcounts` recomputes per-page reference counts from the table
  versions, so tests can assert refcount == reachability (no leaked page, no
  page freed while referenced).
* :class:`ForkDAG` is the host-side parent-pointer DAG: which slot forked
  from which, at what fork timestamp and prefix length.  The engines update
  it in `fork`/`join`/`release` so telemetry and validators can see the
  lineage structure the device arrays erase.
* :class:`ForkValidator` extends the `ScanValidator` replay contract to
  DAGs: a child's pre-fork prefix must stay **byte-stable** against the
  parent's content at fork time, no matter how both sides append, fork
  further, or how much GC runs in between.  A wrongly recycled shared page
  changes the child's values even though its table row is untouched — the
  exact failure mode refcount-free reclamation risks.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.mvgc.pool import EMPTY
from repro.mvkv.paged import NO_PAGE, PagedKV

__all__ = [
    "ForkDAG",
    "ForkValidator",
    "check_no_leak",
    "page_refcounts",
    "prefix_values",
    "shared_page_count",
]


# ---------------------------------------------------------------------------
# Page accounting (host-side ground truth the device sweep must agree with)
# ---------------------------------------------------------------------------

def page_refcounts(st: PagedKV) -> np.ndarray:
    """i32[num_pages]: how many *live table versions* reference each page.

    This is the refcount a copying implementation would maintain; the repo's
    sweep is refcount-free, so recomputing it host-side is the independent
    oracle: ``refcounts > 0`` must equal ``~st.free`` after every op."""
    tables = np.asarray(st.tables)
    table_free = np.asarray(st.table_free)
    n_pages = int(np.asarray(st.free).shape[0])
    refs = np.where(table_free[:, None], NO_PAGE, tables).reshape(-1)
    refs = refs[refs >= 0]
    return np.bincount(refs, minlength=n_pages).astype(np.int32)


def check_no_leak(st: PagedKV) -> Tuple[bool, np.ndarray, np.ndarray]:
    """The fork-DAG safety invariant: a page is free iff its refcount is 0.

    Returns ``(ok, leaked, premature)`` where *leaked* pages are unreferenced
    yet still marked live (space leak) and *premature* pages are referenced
    yet marked free (use-after-free waiting to happen)."""
    refs = page_refcounts(st)
    free = np.asarray(st.free)
    leaked = np.flatnonzero((refs == 0) & ~free)
    premature = np.flatnonzero((refs > 0) & free)
    return leaked.size == 0 and premature.size == 0, leaked, premature


def shared_page_count(st: PagedKV) -> int:
    """Pages referenced by the table versions of more than one *sequence
    slot* — COW fork sharing, which the eager-copy control cannot have.
    (A plain version chain also drives raw refcounts above 1: successive
    versions of one sequence share their common prefix.  That sharing
    exists with zero forks, so it is excluded here — this is the
    ``pages_shared_peak`` metric of BENCH_fork rows.)"""
    payload = np.asarray(st.mv.store.payload)         # [S, V] table indices
    live = np.asarray(st.mv.store.ts) != EMPTY
    tables = np.asarray(st.tables)
    table_free = np.asarray(st.table_free)
    n_pages = int(np.asarray(st.free).shape[0])
    owners = np.zeros((n_pages,), np.int32)
    for s in range(payload.shape[0]):
        rows = payload[s][live[s]]
        rows = rows[(rows >= 0) & ~table_free[rows]]
        pages = np.unique(tables[rows])
        owners[pages[pages >= 0]] += 1
    return int((owners > 1).sum())


def prefix_values(st: PagedKV, table_row: np.ndarray, length: int) -> tuple:
    """Exact K values of the first ``length`` tokens under ``table_row`` —
    the byte-stability fingerprint (same contract as serve_bench's
    ``view_checksum``: content, not page ids)."""
    k = np.asarray(st.k_pages)[:, :, 0, 0]
    ps = st.page_size
    return tuple(
        float(k[int(table_row[j // ps]), j % ps]) for j in range(int(length)))


# ---------------------------------------------------------------------------
# The host-side lineage DAG
# ---------------------------------------------------------------------------

@dataclass
class _Node:
    parent: Optional[int]       # slot id of the parent at fork time (None=root)
    fork_ts: int                # version-store ts of the child's first version
    fork_len: int               # prefix length shared with the parent


@dataclass
class ForkDAG:
    """Parent-pointer DAG over sequence slots.

    Slots are reused (a released slot can be re-forked later), so nodes are
    keyed by slot id and a release simply drops the node: the device-side
    sweep — not this structure — decides page lifetime.  The DAG exists so
    hosts can ask lineage questions (ancestors, live descendants) and so
    :class:`ForkValidator` knows which prefixes must stay stable."""
    nodes: Dict[int, _Node] = field(default_factory=dict)
    forks: int = 0
    joins: int = 0
    releases: int = 0

    def fork(self, parent: int, child: int, fork_ts: int,
             fork_len: int) -> None:
        self.nodes[child] = _Node(parent, int(fork_ts), int(fork_len))
        self.forks += 1

    def join(self, child: int, parent: int) -> None:
        """Child's content adopted by the parent; the child slot is released.
        Grandchildren forked off the child keep their pages alive through
        their own table versions, so their nodes just lose lineage depth:
        they are re-parented to the join target."""
        for node in self.nodes.values():
            if node.parent == child:
                node.parent = parent
        self.nodes.pop(child, None)
        self.joins += 1

    def release(self, slot: int) -> None:
        for node in self.nodes.values():
            if node.parent == slot:
                node.parent = None
        self.nodes.pop(slot, None)
        self.releases += 1

    def ancestors(self, slot: int) -> List[int]:
        out: List[int] = []
        seen = {slot}
        node = self.nodes.get(slot)
        while node is not None and node.parent is not None:
            if node.parent in seen:   # defensive: slot reuse cannot cycle,
                break                 # but never loop on a corrupted DAG
            out.append(node.parent)
            seen.add(node.parent)
            node = self.nodes.get(node.parent)
        return out

    def descendants(self, slot: int) -> List[int]:
        out = [c for c, n in self.nodes.items() if n.parent == slot]
        i = 0
        while i < len(out):
            out.extend(c for c, n in self.nodes.items()
                       if n.parent == out[i] and c not in out)
            i += 1
        return out

    def as_dict(self) -> Dict[str, Any]:
        """JSON-serializable form for `checkpoint()` round-trips."""
        return {
            "nodes": {str(slot): [node.parent, node.fork_ts, node.fork_len]
                      for slot, node in self.nodes.items()},
            "forks": self.forks,
            "joins": self.joins,
            "releases": self.releases,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ForkDAG":
        dag = cls(forks=int(d.get("forks", 0)), joins=int(d.get("joins", 0)),
                  releases=int(d.get("releases", 0)))
        for slot, (parent, fork_ts, fork_len) in d.get("nodes", {}).items():
            dag.nodes[int(slot)] = _Node(
                None if parent is None else int(parent),
                int(fork_ts), int(fork_len))
        return dag


# ---------------------------------------------------------------------------
# Replay validation over the DAG
# ---------------------------------------------------------------------------

class ForkValidator:
    """Byte-stability replay checking for fork DAGs (DESIGN.md §14).

    At fork time, record the parent's prefix content (the exact K values the
    child inherits).  At every later check, resolve the child's *current*
    view and compare its pre-fork prefix against the recording — appends on
    either side, deeper forks, reclamation storms, checkpoint eviction of the
    parent: none of them may perturb a single inherited byte while the child
    is live."""

    def __init__(self, keep_examples: int = 5):
        self.keep_examples = keep_examples
        self.checked = 0
        self.violations = 0
        self.examples: List[Dict[str, Any]] = []
        self._expect: Dict[int, tuple] = {}

    def note_fork(self, st: PagedKV, child: int, table_row: np.ndarray,
                  fork_len: int) -> None:
        """Record the inherited prefix from the *child's own* just-committed
        table row (identical to the parent's snapshot at fork-ts by
        construction; reading it through the child exercises the shared
        pages the validator is guarding)."""
        self._expect[int(child)] = prefix_values(st, table_row, fork_len)

    def drop(self, child: int) -> None:
        """The child was released/joined/reset — its prefix obligation ends."""
        self._expect.pop(int(child), None)

    def check(self, st: PagedKV, child: int, table_row: np.ndarray,
              length: int) -> bool:
        """Compare the child's current view against its recorded prefix."""
        want = self._expect.get(int(child))
        if want is None:
            return True
        self.checked += 1
        n = min(len(want), int(length))
        got = prefix_values(st, table_row, n)
        ok = got == want[:n] and int(length) >= len(want)
        if not ok:
            self.violations += 1
            if len(self.examples) < self.keep_examples:
                self.examples.append({
                    "child": int(child), "want": want[:n], "got": got,
                    "length": int(length), "fork_len": len(want),
                })
        return ok
