"""MV-Serve: the multiversioned serving engine.

The paper's workload shape — frequent updates + long read-only transactions —
maps onto serving as:

* **updates**: every decode step advances each sequence's *cache descriptor*
  (a versioned CAS object holding the visible cache length; with the paged
  backend, the page table).  One version per step, timestamped by the global
  decode clock — `vstore.write_step`.
* **rtxs**: scoring passes, speculative-branch evaluation, and prefix-cache
  lookups pin a timestamp (`begin_snapshot`) and read a *consistent
  cross-sequence snapshot* of descriptors (`snapshot_read` = the paper's
  ``search(t)``), attending only over each sequence's prefix as of the pinned
  step — while decode keeps writing.
* **MVGC**: obsolete descriptor versions are reclaimed by the configured
  policy (SL-RT by default); Theorem 1's bound means descriptor space is
  O(pinned snapshots + lanes log lanes), never O(steps).

The descriptor store is tiny next to the KV pages it governs — but it is what
*pins pages*: a page can be recycled only when no reachable descriptor
version references it.  `freed_pages()` exposes exactly the handles whose
last referencing version was collected, closing the loop to the page
allocator.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.core.mvgc import vstore
from repro.core.mvgc.pool import EMPTY
from repro.models import transformer as tf


class ServeState(NamedTuple):
    params: Any
    cache: Any
    cache_len: jax.Array      # i32[B]
    mv: vstore.MVState        # versioned cache descriptors (1 slot / sequence)
    last_tokens: jax.Array    # i32[B, 1]


def make_serve_state(cfg: ModelConfig, run: RunConfig, params, batch: int,
                     max_len: int, dtype=jnp.bfloat16) -> ServeState:
    cache = tf.init_cache(cfg, batch, max_len, dtype)
    mv = vstore.make_state(
        num_slots=batch,
        versions_per_slot=run.versions_per_slot,
        num_reader_lanes=run.reader_lanes,
        ring_capacity=max(16, batch * 2),
    )
    return ServeState(
        params=params,
        cache=cache,
        cache_len=jnp.zeros((batch,), jnp.int32),
        mv=mv,
        last_tokens=jnp.zeros((batch, 1), jnp.int32),
    )


# ---------------------------------------------------------------------------
# core steps (pure; jit these)
# ---------------------------------------------------------------------------
def prefill_step(state: ServeState, cfg: ModelConfig, run: RunConfig,
                 tokens: jax.Array,
                 frontend_embeds: Optional[jax.Array] = None) -> ServeState:
    logits, cache, lens = tf.prefill(state.params, cfg, tokens, state.cache,
                                     frontend_embeds=frontend_embeds)
    B = tokens.shape[0]
    ids = jnp.arange(B, dtype=jnp.int32)
    mv, _, _ = vstore.write_step(
        state.mv, ids, lens, jnp.ones((B,), bool), policy=run.gc_policy)
    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    return ServeState(state.params, cache, lens, mv, nxt)


def decode_one(state: ServeState, cfg: ModelConfig, run: RunConfig,
               enc_out: Optional[jax.Array] = None
               ) -> Tuple[ServeState, jax.Array, jax.Array]:
    """One greedy decode step for the whole batch.  Returns
    (state', new_tokens[B,1], freed_descriptor_payloads)."""
    logits, cache = tf.decode_step(state.params, cfg, state.last_tokens,
                                   state.cache, state.cache_len,
                                   enc_out=enc_out)
    new_len = state.cache_len + 1
    B = new_len.shape[0]
    ids = jnp.arange(B, dtype=jnp.int32)
    # the update: a new descriptor version (visible length) per sequence
    mv, freed_w, _ = vstore.write_step(
        state.mv, ids, new_len, jnp.ones((B,), bool), policy=run.gc_policy)
    mv, freed_g = vstore.gc_step(mv, policy=run.gc_policy)
    freed = jnp.concatenate([freed_w.reshape(-1), freed_g.reshape(-1)])
    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    return ServeState(state.params, cache, new_len, mv, nxt), nxt, freed


# ---------------------------------------------------------------------------
# snapshot (rtx) interface
# ---------------------------------------------------------------------------
def begin_snapshot(state: ServeState, lane: jax.Array
                   ) -> Tuple[ServeState, jax.Array]:
    mv, ts = vstore.begin_snapshot(
        state.mv, jnp.atleast_1d(lane), jnp.array([True]))
    return state._replace(mv=mv), ts[0]


def end_snapshot(state: ServeState, lane: jax.Array) -> ServeState:
    mv = vstore.end_snapshot(state.mv, jnp.atleast_1d(lane), jnp.array([True]))
    return state._replace(mv=mv)


def snapshot_lengths(state: ServeState, t: jax.Array,
                     seq_ids: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, jax.Array]:
    """Consistent cross-sequence snapshot: each sequence's visible length as
    of pinned time t (the paper's rtx over many vCAS objects)."""
    if seq_ids is None:
        seq_ids = jnp.arange(state.cache_len.shape[0], dtype=jnp.int32)
    return vstore.snapshot_read(state.mv, seq_ids, t)


def snapshot_score(state: ServeState, cfg: ModelConfig, tokens: jax.Array,
                   t: jax.Array) -> jax.Array:
    """Score candidate tokens against the snapshot at t: attention masks use
    the snapshot lengths, so the result is atomic w.r.t. ongoing decodes."""
    lens, found = snapshot_lengths(state, t)
    lens = jnp.where(found, lens, 0)
    logits, _ = tf.decode_step(state.params, cfg, tokens, state.cache, lens)
    return logits


# ---------------------------------------------------------------------------
# host-side engine wrapper
# ---------------------------------------------------------------------------
class MVServeEngine:
    """Orchestrates jitted prefill/decode/GC with the MVGC policy, and
    exposes the space report the benchmarks track."""

    def __init__(self, cfg: ModelConfig, run: RunConfig, params, batch: int,
                 max_len: int, dtype=jnp.float32):
        self.cfg, self.run = cfg, run
        self.state = make_serve_state(cfg, run, params, batch, max_len, dtype)
        self._decode = jax.jit(
            functools.partial(decode_one, cfg=cfg, run=run))
        self._prefill = jax.jit(
            functools.partial(prefill_step, cfg=cfg, run=run))

    def prefill(self, tokens: jax.Array) -> None:
        self.state = self._prefill(self.state, tokens=tokens)

    def step(self) -> jax.Array:
        self.state, toks, _ = self._decode(self.state)
        return toks

    def pin(self, lane: int) -> int:
        self.state, ts = begin_snapshot(self.state, jnp.int32(lane))
        return int(ts)

    def unpin(self, lane: int) -> None:
        self.state = end_snapshot(self.state, jnp.int32(lane))

    def lengths_at(self, t: int) -> jax.Array:
        lens, found = snapshot_lengths(self.state, jnp.int32(t))
        return jnp.where(found, lens, 0)

    def space(self) -> Dict[str, int]:
        return vstore.space_report(self.state.mv)
