"""MV-Serve: the multiversioned serving engine.

The paper's workload shape — frequent updates + long read-only transactions —
maps onto serving as:

* **updates**: every decode step advances each sequence's *cache descriptor*
  (a versioned CAS object holding the visible cache length; with the paged
  backend, the page table).  One version per step, timestamped by the global
  decode clock — `vstore.write_step`.
* **rtxs**: scoring passes, speculative-branch evaluation, and prefix-cache
  lookups pin a timestamp (`begin_snapshot`) and read a *consistent
  cross-sequence snapshot* of descriptors (`snapshot_read` = the paper's
  ``search(t)``), attending only over each sequence's prefix as of the pinned
  step — while decode keeps writing.
* **MVGC**: obsolete descriptor versions are reclaimed by the configured
  policy (SL-RT by default); Theorem 1's bound means descriptor space is
  O(pinned snapshots + lanes log lanes), never O(steps).

The descriptor store is tiny next to the KV pages it governs — but it is what
*pins pages*: a page can be recycled only when no reachable descriptor
version references it.  `freed_pages()` exposes exactly the handles whose
last referencing version was collected, closing the loop to the page
allocator.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any, Dict, List, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.manager import CheckpointManager
from repro.configs.base import ModelConfig, RunConfig
from repro.core.mvgc import vstore
from repro.core.mvgc.pool import EMPTY
from repro.core.telemetry import GCConfig, ReclaimStats, resolve_gc_config
from repro.models import transformer as tf
from repro.mvkv import paged
from repro.serve.forking import ForkDAG


class ServeState(NamedTuple):
    params: Any
    cache: Any
    cache_len: jax.Array      # i32[B]
    mv: vstore.MVState        # versioned cache descriptors (1 slot / sequence)
    last_tokens: jax.Array    # i32[B, 1]


def make_serve_state(cfg: ModelConfig, run: RunConfig, params, batch: int,
                     max_len: int, dtype=jnp.bfloat16) -> ServeState:
    cache = tf.init_cache(cfg, batch, max_len, dtype)
    gc = run.gc
    mv = vstore.make_state(
        num_slots=batch,
        versions_per_slot=gc.versions_per_slot,
        num_reader_lanes=gc.reader_lanes,
        ring_capacity=gc.ring_capacity or max(16, batch * 2),
    )
    return ServeState(
        params=params,
        cache=cache,
        cache_len=jnp.zeros((batch,), jnp.int32),
        mv=mv,
        last_tokens=jnp.zeros((batch, 1), jnp.int32),
    )


# ---------------------------------------------------------------------------
# core steps (pure; jit these)
# ---------------------------------------------------------------------------
def prefill_step(state: ServeState, cfg: ModelConfig, run: RunConfig,
                 tokens: jax.Array,
                 frontend_embeds: Optional[jax.Array] = None) -> ServeState:
    logits, cache, lens = tf.prefill(state.params, cfg, tokens, state.cache,
                                     frontend_embeds=frontend_embeds)
    B = tokens.shape[0]
    ids = jnp.arange(B, dtype=jnp.int32)
    mv, _, _ = vstore.write_step(
        state.mv, ids, lens, jnp.ones((B,), bool), policy=run.gc.policy,
        use_kernel=run.gc.use_kernel, interpret=run.gc.kernel_interpret)
    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    return ServeState(state.params, cache, lens, mv, nxt)


def decode_one(state: ServeState, cfg: ModelConfig, run: RunConfig,
               enc_out: Optional[jax.Array] = None
               ) -> Tuple[ServeState, jax.Array, jax.Array, Dict[str, jax.Array]]:
    """One greedy decode step for the whole batch.  Returns
    (state', new_tokens[B,1], freed_descriptor_payloads, stats).

    GC runs trigger-on-event (DESIGN.md §11): after the descriptor write the
    capacity gate decides — under pressure (a watermark crossed, or any lane's
    append overflowed its slab) the step reclaims *synchronously* via
    `vstore.reclaim_on_pressure` and retries the overflowed lanes in-graph;
    otherwise the policy's normal cadence pass runs.  ``stats`` surfaces the
    pressure accounting (reclaims, deficit, retry outcome, and the previously
    buried ``overflow_count``/``dropped_retires`` monitors) as i32 scalars."""
    logits, cache = tf.decode_step(state.params, cfg, state.last_tokens,
                                   state.cache, state.cache_len,
                                   enc_out=enc_out)
    new_len = state.cache_len + 1
    B = new_len.shape[0]
    ids = jnp.arange(B, dtype=jnp.int32)
    # the update: a new descriptor version (visible length) per sequence
    mv, freed_w, ovf = vstore.write_step(
        state.mv, ids, new_len, jnp.ones((B,), bool), policy=run.gc.policy,
        use_kernel=run.gc.use_kernel, interpret=run.gc.kernel_interpret)
    gate = vstore.capacity_gate(mv)
    trigger = gate.under_pressure | ovf.any()

    def _pressure(m: vstore.MVState):
        hs = vstore.hot_slots(m, min(8, B))
        m2, _, n = vstore.reclaim_on_pressure(
            m, hs, gate.deficit, policy=run.gc.policy,
            use_kernel=run.gc.use_kernel, interpret=run.gc.kernel_interpret)
        return m2, jnp.int32(1), n

    def _cadence(m: vstore.MVState):
        m2, freed_g = vstore.gc_step(m, policy=run.gc.policy,
                                     use_kernel=run.gc.use_kernel,
                                     interpret=run.gc.kernel_interpret)
        return m2, jnp.int32(0), (freed_g != EMPTY).sum().astype(jnp.int32)

    mv, reclaimed, n_freed = jax.lax.cond(trigger, _pressure, _cadence, mv)

    # retry the overflowed lanes now that the reclaim made room
    def _retry(args):
        m, o = args
        m2, _, o2 = vstore.write_step(
            m, ids, new_len, o, policy=run.gc.policy,
            use_kernel=run.gc.use_kernel, interpret=run.gc.kernel_interpret)
        return m2, o2

    mv, ovf_left = jax.lax.cond(
        ovf.any(), _retry, lambda args: args, (mv, ovf))

    stats = {
        "overflow_lanes": ovf.sum().astype(jnp.int32),
        "retry_failed": ovf_left.sum().astype(jnp.int32),
        "reclaims_triggered": reclaimed,
        "versions_reclaimed": n_freed,
        "deficit": gate.deficit,
        "live_versions": vstore.live_versions(mv).astype(jnp.int32),
        "overflow_count": mv.overflow_count,
        "dropped_retires": mv.dropped_retires,
    }
    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    return (ServeState(state.params, cache, new_len, mv, nxt), nxt,
            freed_w.reshape(-1), stats)


# ---------------------------------------------------------------------------
# snapshot (rtx) interface
# ---------------------------------------------------------------------------
def begin_snapshot(state: ServeState, lane: jax.Array
                   ) -> Tuple[ServeState, jax.Array]:
    mv, ts = vstore.begin_snapshot(
        state.mv, jnp.atleast_1d(lane), jnp.array([True]))
    return state._replace(mv=mv), ts[0]


def end_snapshot(state: ServeState, lane: jax.Array) -> ServeState:
    mv = vstore.end_snapshot(state.mv, jnp.atleast_1d(lane), jnp.array([True]))
    return state._replace(mv=mv)


def snapshot_lengths(state: ServeState, t: jax.Array,
                     seq_ids: Optional[jax.Array] = None,
                     use_kernel: bool = False, interpret: bool = True,
                     ) -> Tuple[jax.Array, jax.Array]:
    """Consistent cross-sequence snapshot: each sequence's visible length as
    of pinned time t (the paper's rtx over many vCAS objects)."""
    if seq_ids is None:
        seq_ids = jnp.arange(state.cache_len.shape[0], dtype=jnp.int32)
    return vstore.snapshot_read(state.mv, seq_ids, t,
                                use_kernel=use_kernel, interpret=interpret)


def snapshot_score(state: ServeState, cfg: ModelConfig, tokens: jax.Array,
                   t: jax.Array) -> jax.Array:
    """Score candidate tokens against the snapshot at t: attention masks use
    the snapshot lengths, so the result is atomic w.r.t. ongoing decodes."""
    lens, found = snapshot_lengths(state, t)
    lens = jnp.where(found, lens, 0)
    logits, _ = tf.decode_step(state.params, cfg, tokens, state.cache, lens)
    return logits


# ---------------------------------------------------------------------------
# host-side engine wrapper
# ---------------------------------------------------------------------------
class MVServeEngine:
    """Orchestrates jitted prefill/decode/GC with the MVGC policy, and
    exposes the space report the benchmarks track."""

    def __init__(self, cfg: ModelConfig, run: RunConfig, params, batch: int,
                 max_len: int, dtype=jnp.float32):
        self.cfg, self.run = cfg, run
        self.state = make_serve_state(cfg, run, params, batch, max_len, dtype)
        self._decode = jax.jit(
            functools.partial(decode_one, cfg=cfg, run=run))
        self._prefill = jax.jit(
            functools.partial(prefill_step, cfg=cfg, run=run))
        self.last_stats: Dict[str, int] = {}

    def prefill(self, tokens: jax.Array) -> None:
        self.state = self._prefill(self.state, tokens=tokens)

    def step(self) -> jax.Array:
        self.state, toks, _, stats = self._decode(self.state)
        self.last_stats = {k: int(v) for k, v in stats.items()}
        return toks

    def pin(self, lane: int) -> int:
        self.state, ts = begin_snapshot(self.state, jnp.int32(lane))
        return int(ts)

    def unpin(self, lane: int) -> None:
        self.state = end_snapshot(self.state, jnp.int32(lane))

    def lengths_at(self, t: int) -> jax.Array:
        lens, found = snapshot_lengths(self.state, jnp.int32(t))
        return jnp.where(found, lens, 0)

    def space(self) -> Dict[str, int]:
        return vstore.space_report(self.state.mv)


class PagedKVEngine:
    """Paged-KV serving loop with synchronous pressure reclamation — the
    `freed_pages()` contract the module docstring promises, made concrete.

    ``step`` appends one token per masked sequence.  A failed append (page
    pool, table pool, or descriptor slab exhausted) is a **pressure event**:
    the engine reclaims synchronously — hot-sequence-first descriptor
    compaction, then the reachability sweep that recycles pages — and retries
    the failed lanes, up to ``max_reclaim_rounds`` before giving up (turso's
    trigger-on-event rule; the sim's abort => reclaim => retry loop).  A
    post-step watermark crossing triggers the same pass without a failure.
    Accounting lives in one :class:`repro.core.telemetry.ReclaimStats`
    (``self.stats``); the schema-v4 counter names (``pressure_events``,
    ``reclaims_triggered``, ``pages_reclaimed``, ``peak_pages``,
    ``peak_pages_post_reclaim``, ``give_ups``) survive as read-only
    properties feeding BENCH_serve rows directly.

    Configuration lives in one :class:`repro.core.telemetry.GCConfig`
    (``gc=``); the old per-kwarg spellings (``versions_per_seq``,
    ``gc_policy``, ``page_watermark``, ...) still work for one release but
    emit ``DeprecationWarning`` (DESIGN.md §13)."""

    def __init__(self, num_seqs: int, num_pages: int, page_size: int,
                 max_pages_per_seq: int, kv_heads: int, head_dim: int, *,
                 gc: Optional[GCConfig] = None,
                 versions_per_seq: Optional[int] = None,
                 reader_lanes: Optional[int] = None,
                 ring_capacity: Optional[int] = None,
                 gc_policy: Optional[str] = None,
                 page_watermark: Optional[float] = None,
                 hot_k: Optional[int] = None,
                 max_reclaim_rounds: Optional[int] = None,
                 use_kernel: Optional[bool] = None,
                 kernel_interpret: Optional[bool] = None,
                 eager_fork: bool = False, dtype=jnp.float32):
        cfg = resolve_gc_config(
            gc, "PagedKVEngine",
            versions_per_slot=versions_per_seq, reader_lanes=reader_lanes,
            ring_capacity=ring_capacity, policy=gc_policy,
            page_watermark=page_watermark, hot_k=hot_k,
            max_reclaim_rounds=max_reclaim_rounds, use_kernel=use_kernel,
            kernel_interpret=kernel_interpret)
        self.gc = cfg
        self.st = paged.make_paged_kv(
            num_seqs, num_pages, page_size, max_pages_per_seq, kv_heads,
            head_dim, gc=cfg, dtype=dtype)
        self.gc_policy = cfg.policy
        self.max_reclaim_rounds = cfg.max_reclaim_rounds
        self.use_kernel = cfg.use_kernel
        self.kernel_interpret = cfg.kernel_interpret
        kern = cfg.kernel_kwargs()
        self._append = jax.jit(
            functools.partial(paged.append_tokens, gc_policy=cfg.policy,
                              **kern))
        self._fork = jax.jit(
            functools.partial(paged.fork_sequence, gc_policy=cfg.policy,
                              copy_pages=eager_fork, **kern))
        self._reset = jax.jit(
            functools.partial(paged.reset_sequence, gc_policy=cfg.policy,
                              **kern))
        self._reclaim = jax.jit(
            functools.partial(paged.reclaim_on_pressure, gc_policy=cfg.policy,
                              **kern))
        self._evict = jax.jit(paged.evict_checkpointed)
        self._gate = jax.jit(
            functools.partial(paged.page_pressure,
                              watermark=cfg.page_watermark))
        self._hot = jax.jit(functools.partial(paged.hot_sequences,
                                              k=cfg.hot_k))
        self._freed_pages: List[int] = []
        self.stats = ReclaimStats(unit="pages")
        self.eager_fork = eager_fork
        self.dag = ForkDAG()
        #: highest durably checkpointed timestamp; -1 = no checkpoint taken.
        #: Setting it (via `checkpoint()`) arms the sole-survivor eviction
        #: rule in `_reclaim_once` (DESIGN.md §14).
        self.ckpt_max: int = -1

    # schema-v4 counter names, now backed by the unified ReclaimStats
    @property
    def pressure_events(self) -> int:
        return self.stats.pressure_events

    @property
    def reclaims_triggered(self) -> int:
        return self.stats.reclaims_triggered

    @property
    def pages_reclaimed(self) -> int:
        return self.stats.reclaimed

    @property
    def give_ups(self) -> int:
        return self.stats.give_ups

    @property
    def peak_pages(self) -> int:
        return self.stats.peak_live

    @property
    def peak_pages_post_reclaim(self) -> int:
        return self.stats.peak_live_post_reclaim

    @property
    def forks(self) -> int:
        return self.dag.forks

    @property
    def joins(self) -> int:
        return self.dag.joins

    @property
    def releases(self) -> int:
        return self.dag.releases

    def _note_peak(self) -> None:
        self.stats.note_live(int(paged.live_pages(self.st)))

    def _reclaim_once(self, extra_deficit: int = 0) -> None:
        gate = self._gate(self.st)
        deficit = max(int(gate.deficit), extra_deficit, 1)
        self.st, pages = self._reclaim(self.st, self._hot(self.st),
                                       jnp.int32(deficit))
        freed = int(pages)
        # Checkpoint-coupled eviction (turso sole-survivor rule, DESIGN.md
        # §14): if the policy pass left us under pressure, idle sequences
        # whose only version is durably checkpointed are holding pages no
        # policy can touch — current versions are always needed.  Durable
        # storage has their data; drop them.
        if self.ckpt_max >= 0 and bool(self._gate(self.st).under_pressure):
            self.st, ck_pages, n_ev = self._evict(self.st,
                                                  jnp.int32(self.ckpt_max))
            self.stats.note_ckpt_eviction(int(n_ev), int(ck_pages))
            freed += int(ck_pages)
        self.stats.note_reclaim(freed, int(paged.live_pages(self.st)))

    def step(self, seq_ids: jax.Array, k_new: jax.Array, v_new: jax.Array,
             mask: jax.Array) -> jax.Array:
        """Append one token per masked sequence; reclaim-and-retry on
        pressure.  Returns failed[B] (True = gave up after reclaims)."""
        free_before = np.asarray(self.st.free)
        st, failed = self._append(self.st, seq_ids, k_new, v_new, mask)
        self.st = st
        self._note_peak()
        rounds = 0
        while bool(failed.any()) and rounds < self.max_reclaim_rounds:
            self.stats.note_event()
            self._reclaim_once(extra_deficit=int(failed.sum()))
            self.st, failed = self._append(self.st, seq_ids, k_new, v_new,
                                           failed)
            self._note_peak()
            rounds += 1
        # LWM rule: a watermark crossing is itself a trigger event
        if bool(self._gate(self.st).under_pressure):
            self.stats.note_event()
            self._reclaim_once()
        if bool(failed.any()):
            self.stats.give_ups += int(failed.sum())
        newly = np.flatnonzero(np.asarray(self.st.free) & ~free_before)
        self._freed_pages.extend(int(p) for p in newly)
        return failed

    def _fork_retry(self, src_ids: jax.Array, dst_ids: jax.Array,
                    mask: jax.Array) -> jax.Array:
        """The fork op proper (COW, or eager when ``eager_fork``) with the
        same reclaim-and-retry discipline as `step` — shared by `fork` and
        `join`, which differ only in lineage bookkeeping."""
        free_before = np.asarray(self.st.free)
        st, failed = self._fork(self.st, src_ids, dst_ids, mask)
        self.st = st
        self._note_peak()
        rounds = 0
        while bool(failed.any()) and rounds < self.max_reclaim_rounds:
            self.stats.note_event()
            self._reclaim_once(extra_deficit=int(failed.sum()))
            self.st, failed = self._fork(self.st, src_ids, dst_ids, failed)
            self._note_peak()
            rounds += 1
        if bool(failed.any()):
            self.stats.give_ups += int(failed.sum())
        newly = np.flatnonzero(np.asarray(self.st.free) & ~free_before)
        self._freed_pages.extend(int(p) for p in newly)
        return failed

    def _current_lengths(self, seq_ids: jax.Array) -> np.ndarray:
        tbl, has = vstore.current_read(self.st.mv, jnp.asarray(seq_ids))
        lens = np.asarray(self.st.lengths)[np.maximum(np.asarray(tbl), 0)]
        return np.where(np.asarray(has), lens, 0)

    def fork(self, src_ids: jax.Array, dst_ids: jax.Array,
             mask: jax.Array) -> jax.Array:
        """First-class COW fork: child ``dst`` adopts parent ``src``'s
        content (sharing full pages unless ``eager_fork``) and enters the
        lineage DAG, so `joins`/`releases`/validators can see it.  Returns
        failed[B]."""
        failed = self._fork_retry(src_ids, dst_ids, mask)
        ok = np.asarray(mask) & ~np.asarray(failed)
        if ok.any():
            ts = int(self.st.mv.now)
            lens = self._current_lengths(dst_ids)
            src_np, dst_np = np.asarray(src_ids), np.asarray(dst_ids)
            for i in np.flatnonzero(ok):
                self.dag.fork(int(src_np[i]), int(dst_np[i]), ts,
                              int(lens[i]))
        return failed

    def join(self, src_ids: jax.Array, dst_ids: jax.Array,
             mask: jax.Array) -> jax.Array:
        """Join child ``src`` back into ``dst``: the target adopts the
        child's content as its next descriptor version (a fork write onto
        the target slot — pages stay shared) and the child slot is released.
        Grandchildren are re-parented to the join target.  Returns
        failed[B]."""
        failed = self._fork_retry(src_ids, dst_ids, mask)
        done = np.asarray(mask) & ~np.asarray(failed)
        if done.any():
            self.reset(jnp.asarray(src_ids), jnp.asarray(done))
            src_np, dst_np = np.asarray(src_ids), np.asarray(dst_ids)
            for i in np.flatnonzero(done):
                self.dag.join(int(src_np[i]), int(dst_np[i]))
        return failed

    def release(self, seq_ids: jax.Array, mask: jax.Array) -> jax.Array:
        """Release a branch: recycle the slot and drop it from the lineage
        DAG — its shared pages are freed by the sweep exactly when the last
        descendant holding them goes.  Returns failed[B]."""
        failed = self.reset(seq_ids, mask)
        done = np.asarray(mask) & ~np.asarray(failed)
        ids_np = np.asarray(seq_ids)
        for i in np.flatnonzero(done):
            self.dag.release(int(ids_np[i]))
        return failed

    def reset(self, seq_ids: jax.Array, mask: jax.Array) -> jax.Array:
        """Recycle finished sequences' slots (empty table version); same
        reclaim-and-retry discipline as `step`."""
        free_before = np.asarray(self.st.free)
        st, failed = self._reset(self.st, seq_ids, mask)
        self.st = st
        rounds = 0
        while bool(failed.any()) and rounds < self.max_reclaim_rounds:
            self.stats.note_event()
            self._reclaim_once(extra_deficit=int(failed.sum()))
            self.st, failed = self._reset(self.st, seq_ids, failed)
            rounds += 1
        if bool(failed.any()):
            self.stats.give_ups += int(failed.sum())
        newly = np.flatnonzero(np.asarray(self.st.free) & ~free_before)
        self._freed_pages.extend(int(p) for p in newly)
        return failed

    def reclaim(self, deficit: Optional[int] = None) -> int:
        """Explicit GC pass (the engine-level ``gc_step``; API parity with
        ``ShardedPagedKVEngine.reclaim``): chases the gate deficit, or an
        explicit one — a large deficit forces the full cold-spill sweep,
        and with ``ckpt_max`` armed the checkpoint-eviction post-pass runs
        if the pool is still under pressure afterwards.  Counted as one
        pressure event so the reclaims <= pressure_events invariant holds.
        Returns pages freed."""
        free_before = np.asarray(self.st.free)
        before = int(paged.live_pages(self.st))
        self.stats.note_event()
        self._reclaim_once(
            extra_deficit=0 if deficit is None else int(deficit))
        newly = np.flatnonzero(np.asarray(self.st.free) & ~free_before)
        self._freed_pages.extend(int(p) for p in newly)
        return before - int(paged.live_pages(self.st))

    def freed_pages(self) -> List[int]:
        """Drain the handles of pages recycled since the last call — exactly
        the loop the module docstring promises: a page appears here once its
        last referencing page-table version was collected, and the allocator
        (the free bitmap) may hand it to any sequence's next append."""
        out, self._freed_pages = self._freed_pages, []
        return out

    # -- durability (DESIGN.md §14) -------------------------------------
    def checkpoint(self, directory: Union[str, os.PathLike,
                                          CheckpointManager],
                   step: Optional[int] = None) -> int:
        """Durably checkpoint the whole engine: the paged-KV pytree (pages,
        free bitmaps, page tables, the full MVState including the retire
        ring and announce board) plus the host-side GC state (ReclaimStats,
        fork DAG, pending freed-page handles).  Returns the manifest step.

        Success *arms* the sole-survivor rule: ``ckpt_max`` advances to the
        store clock, so every version written up to now is durable and an
        idle sequence's sole surviving version may be evicted under pressure
        — `restore` can always bring it back."""
        mgr = (directory if isinstance(directory, CheckpointManager)
               else CheckpointManager(os.fspath(directory)))
        ts = int(self.st.mv.now)
        step = ts if step is None else int(step)
        extra = {
            "stats": dataclasses.asdict(self.stats),
            "dag": self.dag.as_dict(),
            "freed_pages_pending": [int(p) for p in self._freed_pages],
            "ckpt_max": ts,
        }
        mgr.save(step, self.st, extra=extra)
        self.ckpt_max = ts
        return step

    def restore(self, directory: Union[str, os.PathLike, CheckpointManager],
                step: Optional[int] = None) -> int:
        """Inverse of `checkpoint`: replace the device pytree and replay the
        host-side GC state (retire ring and announce board ride in the
        pytree; stats/DAG/pending-frees come from the manifest extras), so
        reclamation resumes exactly where the saved engine left off.
        ``step=None`` restores the latest manifest."""
        mgr = (directory if isinstance(directory, CheckpointManager)
               else CheckpointManager(os.fspath(directory)))
        if step is None:
            step = mgr.latest_step()
            if step is None:
                raise FileNotFoundError(
                    f"no checkpoint manifest under {mgr.dir!r}")
        tree, extra = mgr.restore(int(step), like=self.st)
        self.st = jax.tree_util.tree_map(jnp.asarray, tree)
        self.stats = ReclaimStats(**extra.get("stats", {}))
        self.dag = ForkDAG.from_dict(extra.get("dag", {}))
        self._freed_pages = [int(p) for p in
                             extra.get("freed_pages_pending", [])]
        self.ckpt_max = int(extra.get("ckpt_max", -1))
        return int(step)

    def pin(self, lane: int) -> int:
        self.st, ts = paged.begin_snapshot(self.st, jnp.int32(lane))
        return int(ts)

    def unpin(self, lane: int) -> None:
        self.st = paged.end_snapshot(self.st, jnp.int32(lane))

    def view_at(self, t: int, seq_ids: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
        if seq_ids is None:
            seq_ids = jnp.arange(self.st.mv.store.ts.shape[0],
                                 dtype=jnp.int32)
        return paged.snapshot_view(self.st, seq_ids, jnp.int32(t),
                                   use_kernel=self.use_kernel,
                                   interpret=self.kernel_interpret)

    def space(self) -> Dict[str, int]:
        rep = vstore.space_report(self.st.mv)
        rep["live_pages"] = int(paged.live_pages(self.st))
        rep["free_pages"] = int(self.st.free.sum())
        rep["peak_pages"] = self.peak_pages
        rep["peak_pages_post_reclaim"] = self.peak_pages_post_reclaim
        rep["pages_reclaimed"] = self.pages_reclaimed
        rep["pressure_events"] = self.pressure_events
        rep["reclaims_triggered"] = self.reclaims_triggered
        rep["give_ups"] = self.give_ups
        rep["forks"] = self.forks
        rep["joins"] = self.joins
        rep["releases"] = self.releases
        rep["ckpt_evictions"] = self.stats.ckpt_evictions
        rep["ckpt_pages_freed"] = self.stats.ckpt_freed
        return rep
