"""Per-kernel interpret-mode validation: shape/dtype sweeps vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from repro.core.mvgc.needed import needed_intervals
from repro.kernels.compact.ops import compact as compact_fused
from repro.kernels.compact.ops import needed as compact_needed
from repro.kernels.compact.ref import compact_ref, needed_ref
from repro.kernels.decode_attention.ops import paged_decode
from repro.kernels.decode_attention.ref import paged_decode_ref
from repro.kernels.flash_prefill.ops import flash_attention
from repro.kernels.flash_prefill.ref import attention_ref
from repro.kernels.version_search.ops import search, search_gather
from repro.kernels.version_search.ref import search_gather_ref, search_ref

TS_MAX = np.iinfo(np.int32).max


def _mk_slabs(rng, S, V, max_ts=200):
    """Random valid version slabs: per slot, k versions with increasing ts,
    chained succ, newest current."""
    ts = np.full((S, V), -1, np.int32)
    succ = np.full((S, V), TS_MAX, np.int32)
    pay = np.full((S, V), -1, np.int32)
    for s in range(S):
        k = rng.integers(0, V + 1)
        times = np.sort(rng.choice(np.arange(1, max_ts), size=k, replace=False))
        perm = rng.permutation(V)[:k]  # versions scattered across the slab row
        for i, (slot_v, t) in enumerate(zip(perm, times)):
            ts[s, slot_v] = t
            succ[s, slot_v] = times[i + 1] if i + 1 < k else TS_MAX
            pay[s, slot_v] = 1000 * s + i
    return jnp.array(ts), jnp.array(succ), jnp.array(pay)


class TestCompactKernel:
    @pytest.mark.parametrize("S,V,P", [(8, 4, 4), (64, 8, 16), (200, 16, 8),
                                       (256, 8, 128), (33, 5, 3)])
    def test_matches_ref(self, S, V, P):
        rng = np.random.default_rng(S * 31 + V)
        ts, succ, _ = _mk_slabs(rng, S, V)
        ann = np.sort(rng.choice(np.arange(0, 220), size=P, replace=False)).astype(np.int32)
        # pad half the lanes to TS_MAX (idle readers)
        ann[P // 2 :] = TS_MAX
        ann = jnp.array(np.sort(ann))
        now = jnp.int32(150)
        got = compact_needed(ts, succ, ann, now, use_kernel=True, interpret=True)
        want = needed_ref(ts, succ, ann, now)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        # the searchsorted formulation in core/mvgc agrees too
        want2 = needed_intervals(ts, succ, ann, now)
        np.testing.assert_array_equal(np.asarray(want), np.asarray(want2))

    def test_block_boundary(self):
        rng = np.random.default_rng(0)
        ts, succ, _ = _mk_slabs(rng, 70, 4)  # S not divisible by block
        ann = jnp.array([5, 50, TS_MAX, TS_MAX], jnp.int32)
        got = compact_needed(ts, succ, ann, jnp.int32(60), block_s=32)
        want = needed_ref(ts, succ, ann, jnp.int32(60))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def _assert_compact_matches(ts, succ, pay, mask, ann, now, **kw):
    got = compact_fused(ts, succ, pay, mask, ann, now,
                        use_kernel=True, interpret=True, **kw)
    want = compact_ref(ts, succ, pay, mask, ann, now)
    for g, w, name in zip(got, want, ("ts", "succ", "payload", "freed", "n")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w), err_msg=name)
    return got


class TestCompactFusedKernel:
    """Fused needed+splice (DESIGN.md §12) vs the compact_ref oracle."""

    @pytest.mark.parametrize("S,V,P", [(8, 4, 4), (64, 8, 16), (200, 16, 8),
                                       (33, 5, 3)])
    def test_matches_ref(self, S, V, P):
        rng = np.random.default_rng(S * 17 + V)
        ts, succ, pay = _mk_slabs(rng, S, V)
        ann = np.sort(rng.choice(np.arange(0, 220), size=P, replace=False)).astype(np.int32)
        ann[P // 2 :] = TS_MAX
        ann = jnp.array(np.sort(ann))
        mask = jnp.array(rng.random(S) < 0.8)
        _assert_compact_matches(ts, succ, pay, mask, ann, jnp.int32(150))

    def test_block_boundary(self):
        rng = np.random.default_rng(5)
        ts, succ, pay = _mk_slabs(rng, 70, 4)  # R not divisible by block_r
        ann = jnp.array([5, 50, TS_MAX, TS_MAX], jnp.int32)
        mask = jnp.ones((70,), bool)
        _assert_compact_matches(ts, succ, pay, mask, ann, jnp.int32(200),
                                block_r=32)

    def test_empty_chains(self):
        """All-EMPTY slabs: nothing spliced, nothing freed."""
        S, V = 16, 4
        ts = jnp.full((S, V), -1, jnp.int32)
        succ = jnp.full((S, V), TS_MAX, jnp.int32)
        pay = jnp.full((S, V), -1, jnp.int32)
        ann = jnp.full((4,), TS_MAX, jnp.int32)
        got = _assert_compact_matches(ts, succ, pay, jnp.ones((S,), bool),
                                      ann, jnp.int32(10))
        assert int(got[4]) == 0

    def test_all_needed(self):
        """now == 0: every version is still open (succ > now), so the fused
        pass must splice nothing even with idle readers."""
        rng = np.random.default_rng(9)
        ts, succ, pay = _mk_slabs(rng, 24, 6)
        ann = jnp.full((4,), TS_MAX, jnp.int32)
        got = _assert_compact_matches(ts, succ, pay, jnp.ones((24,), bool),
                                      ann, jnp.int32(0))
        assert int(got[4]) == 0
        np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(ts))

    def test_single_version_slots(self):
        """One current version per slot (succ == TS_MAX): always needed."""
        S, V = 12, 4
        ts = np.full((S, V), -1, np.int32)
        pay = np.full((S, V), -1, np.int32)
        succ = np.full((S, V), TS_MAX, np.int32)
        for s in range(S):
            ts[s, s % V] = s + 1
            pay[s, s % V] = 100 + s
        ann = jnp.full((4,), TS_MAX, jnp.int32)
        got = _assert_compact_matches(jnp.array(ts), jnp.array(succ),
                                      jnp.array(pay), jnp.ones((S,), bool),
                                      ann, jnp.int32(500))
        assert int(got[4]) == 0

    def test_pinned_lane_masks(self):
        """A pin inside a closed interval keeps exactly that version; rows
        with mask False pass through untouched even when fully dead."""
        rng = np.random.default_rng(21)
        ts, succ, pay = _mk_slabs(rng, 40, 6)
        ann = jnp.array([40, 90, TS_MAX, TS_MAX], jnp.int32)
        mask = jnp.array([s % 3 != 0 for s in range(40)])
        got = _assert_compact_matches(ts, succ, pay, mask, ann, jnp.int32(250))
        new_ts = np.asarray(got[0])
        for s in range(0, 40, 3):  # masked-off rows byte-identical
            np.testing.assert_array_equal(new_ts[s], np.asarray(ts)[s])
        # every version covering a pinned ts survived
        for a in (40, 90):
            covered = (np.asarray(ts) <= a) & (a < np.asarray(succ)) \
                      & (np.asarray(ts) != -1)
            assert (new_ts[covered] != -1).all()


class TestVersionSearchKernel:
    @pytest.mark.parametrize("S,V,B", [(16, 4, 8), (128, 8, 64), (64, 16, 200)])
    def test_matches_ref(self, S, V, B):
        rng = np.random.default_rng(S + V + B)
        ts, succ, pay = _mk_slabs(rng, S, V)
        ids = jnp.array(rng.integers(0, S, B), jnp.int32)
        t = jnp.array(rng.integers(0, 220, B), jnp.int32)
        got_p, got_f = search(ts, pay, ids, t, use_kernel=True, interpret=True)
        want_p, want_f = search_ref(ts, pay, ids, t)
        np.testing.assert_array_equal(np.asarray(got_p), np.asarray(want_p))
        np.testing.assert_array_equal(np.asarray(got_f), np.asarray(want_f))


def _mk_gather_inputs(rng, S, V, M, B, max_ts=200):
    """Slabs whose payload handles are valid row indices into values[T, M]."""
    ts, succ, pay = _mk_slabs(rng, S, V, max_ts=max_ts)
    T = S * V
    pay_np = np.asarray(pay)
    remapped = np.where(pay_np != -1,
                        rng.integers(0, T, pay_np.shape).astype(np.int32), -1)
    values = jnp.array(rng.integers(0, 10_000, (T, M)), jnp.int32)
    ids = jnp.array(rng.integers(0, S, B), jnp.int32)
    t = jnp.array(rng.integers(0, max_ts + 20, B), jnp.int32)
    return ts, succ, jnp.array(remapped), values, ids, t


def _assert_gather_matches(ts, pay, values, ids, t, **kw):
    got = search_gather(ts, pay, values, ids, t,
                        use_kernel=True, interpret=True, **kw)
    want = search_gather_ref(ts, pay, values, ids, t)
    for g, w, name in zip(got, want, ("rows", "payload", "found")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w), err_msg=name)
    return got


class TestSearchGatherFusedKernel:
    """Fused search(t) + value-row gather (DESIGN.md §12) vs its oracle."""

    @pytest.mark.parametrize("S,V,M,B", [(16, 4, 4, 8), (128, 8, 8, 64),
                                         (64, 16, 16, 200), (33, 5, 3, 17)])
    def test_matches_ref(self, S, V, M, B):
        rng = np.random.default_rng(S + V + M + B)
        ts, _, pay, values, ids, t = _mk_gather_inputs(rng, S, V, M, B)
        _assert_gather_matches(ts, pay, values, ids, t)

    def test_block_boundary(self):
        rng = np.random.default_rng(4)
        ts, _, pay, values, ids, t = _mk_gather_inputs(rng, 32, 4, 4, 70)
        _assert_gather_matches(ts, pay, values, ids, t, block_b=32)

    def test_before_first_write(self):
        """Queries below every version ts: not-found, rows EMPTY-filled."""
        rng = np.random.default_rng(6)
        ts, _, pay, values, ids, _ = _mk_gather_inputs(rng, 32, 4, 4, 16)
        t = jnp.zeros((16,), jnp.int32)
        rows, _, found = _assert_gather_matches(ts, pay, values, ids, t)
        assert not bool(np.asarray(found).any())
        assert (np.asarray(rows) == -1).all()

    def test_single_version_slots(self):
        """Exactly one version per slot: found iff t >= that version's ts,
        and the gathered row is the payload-indexed values row."""
        S, V, M = 8, 4, 4
        ts = np.full((S, V), -1, np.int32)
        pay = np.full((S, V), -1, np.int32)
        for s in range(S):
            ts[s, s % V] = 10 * (s + 1)
            pay[s, s % V] = s
        values = jnp.array(np.arange(S * M, dtype=np.int32).reshape(S, M))
        ids = jnp.arange(S, dtype=jnp.int32)
        t = jnp.array([10 * (s + 1) - (s % 2) for s in range(S)], jnp.int32)
        rows, pay_got, found = _assert_gather_matches(
            jnp.array(ts), jnp.array(pay), values, ids, t)
        want_found = np.array([s % 2 == 0 for s in range(S)])
        np.testing.assert_array_equal(np.asarray(found), want_found)
        for s in range(S):
            if want_found[s]:
                np.testing.assert_array_equal(np.asarray(rows)[s],
                                              np.asarray(values)[s])


class TestFlashPrefill:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "B,Hq,Hkv,T,D,window,softcap",
        [
            (2, 4, 2, 64, 32, 0, 0.0),      # GQA global causal
            (1, 2, 1, 128, 16, 32, 0.0),    # sliding window
            (1, 4, 4, 64, 32, 0, 50.0),     # MHA + softcap (gemma2)
            (2, 8, 2, 96, 64, 48, 30.0),    # everything at once, ragged T
        ],
    )
    def test_matches_ref(self, dtype, B, Hq, Hkv, T, D, window, softcap):
        rng = np.random.default_rng(hash((B, Hq, T, D)) % 2**31)
        q = jnp.array(rng.standard_normal((B, Hq, T, D)), dtype) * 0.5
        k = jnp.array(rng.standard_normal((B, Hkv, T, D)), dtype) * 0.5
        v = jnp.array(rng.standard_normal((B, Hkv, T, D)), dtype) * 0.5
        got = flash_attention(q, k, v, causal=True, window=window,
                              softcap=softcap, block_t=32, block_s=32)
        want = attention_ref(q, k, v, causal=True, window=window, softcap=softcap)
        atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            atol=atol, rtol=1e-2)

    def test_block_not_dividing_seq(self):
        rng = np.random.default_rng(3)
        q = jnp.array(rng.standard_normal((1, 2, 80, 16)), jnp.float32)
        k = jnp.array(rng.standard_normal((1, 2, 80, 16)), jnp.float32)
        v = jnp.array(rng.standard_normal((1, 2, 80, 16)), jnp.float32)
        got = flash_attention(q, k, v, block_t=32, block_s=32)
        want = attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5, rtol=1e-2)


class TestPagedDecode:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "B,Hq,Hkv,D,N,PS,MP",
        [
            (2, 4, 2, 32, 16, 8, 4),
            (4, 8, 1, 64, 32, 16, 6),   # MQA (recurrentgemma local attn)
            (1, 2, 2, 16, 8, 4, 3),
        ],
    )
    def test_matches_ref(self, dtype, B, Hq, Hkv, D, N, PS, MP):
        rng = np.random.default_rng(hash((B, Hq, D, N)) % 2**31)
        q = jnp.array(rng.standard_normal((B, Hq, D)), dtype) * 0.5
        kp = jnp.array(rng.standard_normal((N, PS, Hkv, D)), dtype) * 0.5
        vp = jnp.array(rng.standard_normal((N, PS, Hkv, D)), dtype) * 0.5
        table = jnp.array(rng.integers(0, N, (B, MP)), jnp.int32)
        lengths = jnp.array(rng.integers(1, MP * PS + 1, (B,)), jnp.int32)
        got = paged_decode(q, kp, vp, table, lengths, use_kernel=True)
        want = paged_decode_ref(q, kp, vp, table, lengths)
        atol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            atol=atol, rtol=1e-2)

    def test_zero_length_sequence(self):
        q = jnp.ones((1, 2, 8), jnp.float32)
        kp = jnp.ones((4, 4, 2, 8), jnp.float32)
        vp = jnp.ones((4, 4, 2, 8), jnp.float32)
        table = jnp.zeros((1, 2), jnp.int32)
        lengths = jnp.array([0], jnp.int32)
        out = paged_decode(q, kp, vp, table, lengths)
        assert not bool(jnp.isnan(out).any())


class TestKernelEdgeCases:
    """Degenerate inputs every kernel must agree with its oracle on."""

    def test_compact_empty_slabs(self):
        S, V = 16, 4
        ts = jnp.full((S, V), -1, jnp.int32)
        succ = jnp.full((S, V), TS_MAX, jnp.int32)
        ann = jnp.full((4,), TS_MAX, jnp.int32)
        got = compact_needed(ts, succ, ann, jnp.int32(10), use_kernel=True,
                             interpret=True)
        want = needed_ref(ts, succ, ann, jnp.int32(10))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert not bool(np.asarray(got).any())   # nothing exists: nothing needed

    def test_compact_all_readers_idle(self):
        rng = np.random.default_rng(7)
        ts, succ, _ = _mk_slabs(rng, 40, 6)
        ann = jnp.full((8,), TS_MAX, jnp.int32)  # no pinned snapshots
        now = jnp.int32(150)
        got = compact_needed(ts, succ, ann, now, use_kernel=True, interpret=True)
        want = needed_ref(ts, succ, ann, now)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_version_search_before_first_write(self, seed):
        """Queries at t below every version ts must report not-found."""
        rng = np.random.default_rng(seed)
        ts, succ, pay = _mk_slabs(rng, 32, 4, max_ts=200)
        ids = jnp.array(rng.integers(0, 32, 16), jnp.int32)
        t = jnp.zeros((16,), jnp.int32)          # everything written at ts>=1
        got_p, got_f = search(ts, pay, ids, t, use_kernel=True, interpret=True)
        want_p, want_f = search_ref(ts, pay, ids, t)
        np.testing.assert_array_equal(np.asarray(got_p), np.asarray(want_p))
        np.testing.assert_array_equal(np.asarray(got_f), np.asarray(want_f))
        assert not bool(np.asarray(got_f).any())

    def test_flash_single_query_block(self):
        """T smaller than one block: masking, not padding garbage."""
        rng = np.random.default_rng(11)
        q = jnp.array(rng.standard_normal((1, 2, 17, 16)), jnp.float32)
        k = jnp.array(rng.standard_normal((1, 2, 17, 16)), jnp.float32)
        v = jnp.array(rng.standard_normal((1, 2, 17, 16)), jnp.float32)
        got = flash_attention(q, k, v, causal=True, block_t=32, block_s=32)
        want = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5, rtol=1e-2)

    def test_paged_decode_single_page(self):
        rng = np.random.default_rng(13)
        q = jnp.array(rng.standard_normal((2, 2, 8)), jnp.float32)
        kp = jnp.array(rng.standard_normal((3, 4, 2, 8)), jnp.float32)
        vp = jnp.array(rng.standard_normal((3, 4, 2, 8)), jnp.float32)
        table = jnp.array([[1], [2]], jnp.int32)
        lengths = jnp.array([4, 2], jnp.int32)
        got = paged_decode(q, kp, vp, table, lengths, use_kernel=True)
        want = paged_decode_ref(q, kp, vp, table, lengths)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5, rtol=1e-2)
