"""Grouped vs global MoE dispatch: same routing semantics (modulo capacity
locality), finite grads, and gate-weighted combine correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from repro.configs import reduced_config
from repro.models.moe import init_moe, moe_global, moe_grouped


@pytest.fixture
def setup():
    cfg = reduced_config("deepseek-moe-16b")
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.5
    return cfg, params, x


def test_grouped_matches_global_when_dropless(setup):
    cfg, params, x = setup
    # dropless capacities in both formulations at this size
    out_g, aux_g = moe_global(params, cfg, x)
    out_p, aux_p = moe_grouped(params, cfg, x)
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_p),
                               atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(float(aux_g), float(aux_p), rtol=1e-5)


def test_grouped_grads_finite(setup):
    cfg, params, x = setup
    g = jax.grad(lambda p: moe_grouped(p, cfg, x)[0].sum())(params)
    assert all(jnp.isfinite(l).all() for l in jax.tree.leaves(g))


def test_grouped_capacity_drops_gracefully(setup):
    cfg, params, x = setup
    tight = dataclasses.replace(cfg, moe_capacity_factor=0.1,
                                moe_dispatch="grouped")
    # capacity floor keeps small pools dropless; shrink T*k floor via bigger T
    x2 = jax.random.normal(jax.random.PRNGKey(2), (2, 256, cfg.d_model)) * 0.5
    out, aux = moe_grouped(params, tight, x2)
    assert bool(jnp.isfinite(out).all())


def test_config_dispatch_switch(setup):
    cfg, params, x = setup
    from repro.models.moe import moe
    cfgG = dataclasses.replace(cfg, moe_dispatch="grouped")
    out1, _ = moe(params, cfg, x)
    out2, _ = moe(params, cfgG, x)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               atol=1e-5, rtol=1e-4)
