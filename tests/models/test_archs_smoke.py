"""Per-architecture smoke tests on reduced configs: one forward + one train
gradient step on CPU, asserting output shapes and no NaNs; plus a
prefill/decode-vs-forward consistency check for cacheable archs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from repro.configs import ARCHS, list_archs, reduced_config
from repro.models import transformer as tf


def make_batch(cfg, rng, B=2, T=32):
    tokens = jnp.array(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    batch = {"tokens": tokens}
    if cfg.frontend != "none":
        Nf = cfg.frontend_tokens if cfg.encoder_layers else cfg.frontend_tokens
        batch["frontend"] = jnp.array(
            rng.standard_normal((B, max(Nf, 4), cfg.d_model)) * 0.02, jnp.float32)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_forward_and_grad_step(arch):
    cfg = reduced_config(arch)
    rng = np.random.default_rng(0)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, rng)

    loss, metrics = tf.loss_fn(params, cfg, batch, remat=False)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss {loss}"

    grads = jax.grad(lambda p: tf.loss_fn(p, cfg, batch, remat=True)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(jnp.isfinite(g).all() for g in flat), f"{arch}: NaN/inf grads"
    # at least 99% of param tensors receive nonzero gradient signal
    nz = sum(bool(jnp.any(g != 0)) for g in flat)
    assert nz >= 0.8 * len(flat), f"{arch}: too many dead grads ({nz}/{len(flat)})"


@pytest.mark.parametrize("arch", list_archs())
def test_logit_shapes(arch):
    cfg = reduced_config(arch)
    rng = np.random.default_rng(1)
    params = tf.init_params(cfg, jax.random.PRNGKey(1))
    batch = make_batch(cfg, rng, B=2, T=16)
    logits, aux = tf.forward(params, cfg, batch["tokens"],
                             frontend_embeds=batch.get("frontend"), remat=False)
    Nf = 0
    if cfg.frontend != "none" and not cfg.encoder_layers:
        Nf = batch["frontend"].shape[1]
    assert logits.shape == (2, 16 + Nf, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode_matches_forward(arch):
    """prefill(prompt) then decode one token == forward(prompt + token)."""
    cfg = reduced_config(arch)
    if cfg.frontend != "none" and not cfg.encoder_layers:
        pytest.skip("vlm prefix handled in forward test")
    rng = np.random.default_rng(2)
    params = tf.init_params(cfg, jax.random.PRNGKey(2))
    B, T = 2, 16
    tokens = jnp.array(rng.integers(0, cfg.vocab_size, (B, T + 1)), jnp.int32)
    fe = None
    if cfg.encoder_layers:
        fe = jnp.array(rng.standard_normal(
            (B, max(cfg.encoder_tokens, 4), cfg.d_model)) * 0.02, jnp.float32)

    # teacher-forced forward over the full sequence
    logits_full, _ = tf.forward(params, cfg, tokens, frontend_embeds=fe,
                                remat=False)

    # prefill T tokens, then decode token T
    cache = tf.init_cache(cfg, B, cache_len=T + 8, dtype=jnp.float32)
    last, cache, lens = tf.prefill(params, cfg, tokens[:, :T], cache,
                                   frontend_embeds=fe)
    enc_out = None
    if cfg.encoder_layers:
        enc_out = tf._run_encoder(params, cfg, fe)
    step_logits, cache = tf.decode_step(params, cfg, tokens[:, T:T + 1],
                                        cache, lens, enc_out=enc_out)

    np.testing.assert_allclose(
        np.asarray(last[:, -1], np.float32),
        np.asarray(logits_full[:, T - 1], np.float32),
        atol=2e-3, rtol=2e-3,
        err_msg=f"{arch}: prefill last-logit mismatch")
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0], np.float32),
        np.asarray(logits_full[:, T], np.float32),
        atol=2e-3, rtol=2e-3,
        err_msg=f"{arch}: decode-step logit mismatch")


def test_param_counts_full_configs():
    """Full configs instantiate *analytically* close to their nameplate size
    (no allocation — just the formula)."""
    expect = {
        "xlstm-125m": (0.06e9, 0.22e9),
        "granite-moe-1b-a400m": (0.8e9, 1.6e9),
        "deepseek-moe-16b": (14e9, 20e9),
        "internvl2-2b": (1.5e9, 2.6e9),
        "minitron-4b": (3.5e9, 5.5e9),
        "qwen2.5-32b": (28e9, 36e9),
        "starcoder2-7b": (6e9, 8.5e9),
        "gemma2-2b": (2.0e9, 3.5e9),
        "whisper-tiny": (0.02e9, 0.06e9),
        "recurrentgemma-9b": (7.5e9, 11e9),
    }
    for name, (lo, hi) in expect.items():
        n = ARCHS[name].param_count()
        assert lo <= n <= hi, f"{name}: analytic count {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]B"


def test_mlstm_chunkwise_equals_recurrent():
    """The chunk-parallel train path must equal the step recurrence exactly."""
    from repro.models import mlstm as m
    cfg = reduced_config("xlstm-125m", mlstm_chunk=8)
    key = jax.random.PRNGKey(3)
    params = m.init_mlstm(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 32, cfg.d_model)) * 0.5
    out_c, st_c = m.mlstm_chunkwise(params, cfg, x)
    out_r, st_r = m.mlstm_decode(params, cfg, x, m.mlstm_init_state(cfg, 2))
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_r),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st_c.C), np.asarray(st_r.C),
                               atol=1e-4, rtol=1e-3)


def test_rglru_scan_equals_stepwise():
    from repro.models import rglru as r
    cfg = reduced_config("recurrentgemma-9b")
    params = r.init_rglru(jax.random.PRNGKey(5), cfg)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 16, cfg.d_model)) * 0.5
    out_scan, st_scan = r.rglru(params, cfg, x)
    # stepwise
    st = r.rglru_init_state(cfg, 2)
    outs = []
    for t in range(16):
        o, st = r.rglru_decode(params, cfg, x[:, t:t+1], st)
        outs.append(o)
    out_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_scan), np.asarray(out_step),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st_scan.h), np.asarray(st.h),
                               atol=1e-4, rtol=1e-3)
