"""Sharded multi-host MVGC: global-LWM safety and straggler tolerance
(repro.dist.mvgc, DESIGN.md §13).

Everything here runs on one CPU device — the protocol is placement-
independent (``global_lwm`` degrades to a plain ``min`` when the stack is
unsharded), so these tests exercise the exact shard/LWM/aging logic the
fake-device subprocess tests in ``test_dist_unit.py`` run over a real
``reduce="min"`` ring."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from repro.core.mvgc.pool import EMPTY, TS_MAX
from repro.core.telemetry import GCConfig, PressureSignal
from repro.dist.mvgc import (ShardedPagedKVEngine, age_out_stale, global_lwm,
                             lwm_contributions, stack_states)
from repro.mvkv import paged

B, NP, PS, MP, KVH, HD = 4, 12, 4, 3, 1, 4
GC = GCConfig(policy="slrt", versions_per_slot=6, reader_lanes=4)


def _engine(hosts: int, gc: GCConfig = GC) -> ShardedPagedKVEngine:
    return ShardedPagedKVEngine(hosts, B, NP, PS, MP, KVH, HD, gc=gc)


def _kv(hosts: int, step: int) -> jnp.ndarray:
    """Per-(host, step, seq) distinct payloads: a wrongly reclaimed page
    shows up as a value mismatch, not just a shape change."""
    base = (np.arange(hosts * B, dtype=np.float32).reshape(hosts, B)
            + hosts * B * (step + 1))
    return jnp.asarray(np.broadcast_to(
        base[:, :, None, None], (hosts, B, KVH, HD)))


def _seq_ids(hosts: int) -> jnp.ndarray:
    return jnp.broadcast_to(jnp.arange(B, dtype=jnp.int32), (hosts, B))


def _checksum(local_st, tables: np.ndarray, lengths: np.ndarray) -> tuple:
    k = np.asarray(local_st.k_pages)[:, :, 0, 0]
    out = []
    for s in range(tables.shape[0]):
        n = int(lengths[s])
        out.append((n, tuple(
            float(k[int(tables[s, j // PS]), j % PS]) for j in range(n))))
    return tuple(out)


def _churn(eng: ShardedPagedKVEngine, steps: int, start: int = 0) -> None:
    """Append/reset churn that retires versions and recycles pages on every
    host — the workload under which reclamation must stay pin-safe."""
    hosts = eng.hosts
    seq = _seq_ids(hosts)
    all_on = jnp.ones((hosts, B), bool)
    for step in range(start, start + steps):
        eng.step(seq, _kv(hosts, step), _kv(hosts, step), all_on)
        if step % 3 == 2:
            done = np.zeros((hosts, B), bool)
            done[:, step % B] = True
            eng.reset(seq, jnp.asarray(done))


# ---------------------------------------------------------------------------
# building blocks (single device, fast)
# ---------------------------------------------------------------------------
class TestBuildingBlocks:
    def test_stack_states_adds_host_dim(self):
        base = paged.make_paged_kv(B, NP, PS, MP, KVH, HD, gc=GC)
        st = stack_states(base, 3)
        for leaf, orig in zip(jax.tree.leaves(st), jax.tree.leaves(base)):
            assert leaf.shape == (3,) + orig.shape
            np.testing.assert_array_equal(np.asarray(leaf[1]),
                                          np.asarray(orig))

    def test_lwm_contributions_sentinel_and_pins(self):
        eng = _engine(3)
        contrib = np.asarray(lwm_contributions(eng.st))
        assert (contrib == int(TS_MAX)).all()       # pin-free boards
        ts = eng.pin(1, 0)
        contrib = np.asarray(lwm_contributions(eng.st))
        assert contrib[1] == ts
        assert contrib[0] == contrib[2] == int(TS_MAX)

    def test_age_out_stale_replaces_and_counts(self):
        contrib = jnp.asarray([15, 7, int(TS_MAX)], jnp.int32)
        aged, n = age_out_stale(contrib, [0.0, 100.0, 100.0], 5.0)
        np.testing.assert_array_equal(
            np.asarray(aged), [15, int(TS_MAX), int(TS_MAX)])
        # only the stale *pinning* lane counts (TS_MAX was already inert)
        assert int(n) == 1

    def test_global_lwm_without_ring(self):
        contrib = jnp.asarray([23, 5, int(TS_MAX)], jnp.int32)
        assert int(global_lwm(contrib)) == 5
        assert int(global_lwm(jnp.full((4,), TS_MAX, jnp.int32))) \
            == int(TS_MAX)

    def test_pressure_is_unified_signal_with_host_dim(self):
        eng = _engine(2)
        sig = eng.pressure()
        assert isinstance(sig, PressureSignal)
        assert sig.under_pressure.shape == (2,)
        assert sig.capacity.shape == (2,)
        np.testing.assert_array_equal(np.asarray(sig.capacity), [NP, NP])


# ---------------------------------------------------------------------------
# differential: sharded shards replay the single-host vstore bit-for-bit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["ebr", "slrt"])
def test_sharded_trace_matches_single_host(policy):
    """The same op trace through (a) the single-host paged stack and (b) the
    host-stacked vmapped stack with the inert TS_MAX global pin must land in
    bit-identical states on every host — sharding changes placement, never
    the protocol."""
    gc = GCConfig(policy=policy, versions_per_slot=6, reader_lanes=4)
    hosts = 3
    single = paged.make_paged_kv(B, NP, PS, MP, KVH, HD, gc=gc)
    stacked = stack_states(single, hosts)
    sentinel = jnp.full((hosts, 1), TS_MAX, jnp.int32)

    app1 = jax.jit(functools.partial(paged.append_tokens, gc_policy=policy))
    rst1 = jax.jit(functools.partial(paged.reset_sequence, gc_policy=policy))
    rec1 = jax.jit(functools.partial(paged.reclaim_on_pressure,
                                     gc_policy=policy))
    apph = jax.jit(jax.vmap(lambda s, q, k, v, m, p: paged.append_tokens(
        s, q, k, v, m, gc_policy=policy, extra_pins=p)))
    rsth = jax.jit(jax.vmap(lambda s, q, m, p: paged.reset_sequence(
        s, q, m, gc_policy=policy, extra_pins=p)))
    rech = jax.jit(jax.vmap(lambda s, h, d, p: paged.reclaim_on_pressure(
        s, h, d, gc_policy=policy, extra_pins=p)))

    seq1 = jnp.arange(B, dtype=jnp.int32)
    seqh = _seq_ids(hosts)
    on1 = jnp.ones((B,), bool)
    onh = jnp.ones((hosts, B), bool)
    for step in range(12):
        kv1 = _kv(1, step)[0]
        kvh = jnp.broadcast_to(kv1[None], (hosts, B, KVH, HD))
        single, f1 = app1(single, seq1, kv1, kv1, on1)
        stacked, fh = apph(stacked, seqh, kvh, kvh, onh, sentinel)
        np.testing.assert_array_equal(np.asarray(fh[1]), np.asarray(f1))
        if step % 4 == 3:
            done1 = on1 & (seq1 == step % B)
            single, _ = rst1(single, seq1, done1)
            stacked, _ = rsth(stacked, seqh,
                              jnp.broadcast_to(done1[None], (hosts, B)),
                              sentinel)
        if step % 5 == 4:
            hot1 = paged.hot_sequences(single, k=2)
            single, _ = rec1(single, hot1, jnp.int32(4))
            hoth = jax.vmap(functools.partial(paged.hot_sequences,
                                              k=2))(stacked)
            stacked, _ = rech(stacked, hoth,
                              jnp.full((hosts,), 4, jnp.int32), sentinel)

    for leaf_h, leaf_1 in zip(jax.tree.leaves(stacked),
                              jax.tree.leaves(single)):
        for h in range(hosts):
            np.testing.assert_array_equal(np.asarray(leaf_h[h]),
                                          np.asarray(leaf_1))


# ---------------------------------------------------------------------------
# global-LWM safety: a pin on one host protects snapshots on every host
# ---------------------------------------------------------------------------
def test_pin_on_one_host_protects_every_shard():
    """A reader pins on host 0's board and snapshot-reads *every* host's
    shard at that timestamp (announcement lanes are host-local; only the
    global LWM carries the pin across).  Under churn + forced reclaims, all
    those views must stay byte-identical.  The control run with the LWM
    neutered must corrupt a remote view — proving the global LWM is the
    load-bearing protection, not local boards or luck."""
    def run(neuter_lwm: bool) -> int:
        eng = _engine(4)
        if neuter_lwm:
            sentinel = jnp.full((eng.hosts, 1), TS_MAX, jnp.int32)
            eng.lwm_pins = lambda: sentinel
        _churn(eng, 4)
        ts = eng.pin(0, 0)
        refs = {}
        for h in range(eng.hosts):
            tbl, ln = eng.view_at(h, ts)
            refs[h] = _checksum(eng.host_state(h), np.asarray(tbl),
                                np.asarray(ln))
        _churn(eng, 8, start=4)
        eng.reclaim(deficit=NP)          # full cold-spill sweep, every shard
        _churn(eng, 4, start=12)
        bad = 0
        for h in range(eng.hosts):
            tbl, ln = eng.view_at(h, ts)
            now = _checksum(eng.host_state(h), np.asarray(tbl),
                            np.asarray(ln))
            if now != refs[h]:
                bad += 1
        return bad

    assert run(neuter_lwm=False) == 0
    assert run(neuter_lwm=True) > 0


def test_lwm_tracks_min_over_hosts():
    eng = _engine(3)
    _churn(eng, 3)
    t0 = eng.pin(0, 0)
    _churn(eng, 2, start=3)
    t1 = eng.pin(1, 0)
    assert t1 > t0
    pins = np.asarray(eng.lwm_pins())
    assert pins.shape == (3, 1)
    assert (pins == t0).all()            # min over hosts, broadcast to all
    eng.unpin(0, 0)
    assert (np.asarray(eng.lwm_pins()) == t1).all()
    assert eng.lwm_advances >= 1         # the LWM moved up off a real pin


# ---------------------------------------------------------------------------
# straggler tolerance: a stalled host bounds reclamation, never blocks it
# ---------------------------------------------------------------------------
def test_stalled_host_is_aged_out_and_reclamation_proceeds():
    gc = GCConfig(policy="slrt", versions_per_slot=6, reader_lanes=4,
                  stale_after_s=5.0)
    eng = _engine(4, gc=gc)
    _churn(eng, 4)
    ts = eng.pin(1, 0)                   # the soon-to-stall host pins
    assert (np.asarray(eng.lwm_pins()) == ts).all()

    # host 1 stalls past its staleness budget; its announcement ages out
    ages = np.zeros((4,), np.float32)
    ages[1] = 100.0
    eng.virtual_ages_s = ages
    pins = np.asarray(eng.lwm_pins())
    assert (pins == int(TS_MAX)).all()   # stale pin no longer bounds the LWM
    assert eng.stats.stale_lanes_aged >= 1

    # the remaining hosts keep reclaiming as if the pin were gone
    before = eng.stats.reclaimed
    _churn(eng, 6, start=4)
    eng.reclaim(deficit=NP)
    assert eng.stats.reclaimed > before

    # the stalled host's *local* board still protects its own shard: its
    # held snapshot stays byte-stable even though the mesh moved on
    tbl, ln = eng.view_at(1, ts)
    ref = _checksum(eng.host_state(1), np.asarray(tbl), np.asarray(ln))
    _churn(eng, 3, start=10)
    tbl, ln = eng.view_at(1, ts)
    assert _checksum(eng.host_state(1), np.asarray(tbl),
                     np.asarray(ln)) == ref

    row = eng.space()
    assert row["stale_lanes_aged"] >= 1
    assert row["pages_reclaimed"] > 0


def test_fresh_hosts_never_aged_with_infinite_budget():
    eng = _engine(2)                     # stale_after_s=inf -> watchdog
    _churn(eng, 3)
    assert eng.stats.stale_lanes_aged == 0
    assert (eng.budget_s() > 0).all()    # warmup budget is finite, not inf
