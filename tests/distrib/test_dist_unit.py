"""Single-process unit tests for repro.dist (multi-device behaviour is
covered by tests/launch/test_distributed.py in fake-device subprocesses)."""
import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import reduced_config
from repro.configs.base import RunConfig, SHAPES
from repro.dist.overlap import make_ring_all_reduce
from repro.dist.sharding import (_keypath_parts, batch_sharding, batch_spec,
                                 param_shardings)
from repro.dist.straggler import HeartbeatFile, StepWatchdog
from repro.train.step import init_state, train_step

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


# ---------------------------------------------------------------------------
# sharding
# ---------------------------------------------------------------------------
class TestSharding:
    def test_keypath_parts(self):
        tree = {"sb": {"l0": {"mixer": {"wq": jnp.zeros((2, 4, 4, 2))}}},
                "tail": [jnp.zeros((3,))]}
        seen = {}
        jax.tree_util.tree_map_with_path(
            lambda kp, x: seen.setdefault(_keypath_parts(kp), x.shape), tree)
        assert ("sb", "l0", "mixer", "wq") in seen
        assert ("tail", "0") in seen

    def test_param_shardings_cover_tree(self):
        cfg = reduced_config("minitron-4b")
        mesh = _mesh11()
        shapes = jax.eval_shape(
            lambda: init_state(cfg, jax.random.PRNGKey(0))).params
        shard = param_shardings(shapes, mesh, fsdp=True)
        leaves_p = jax.tree.leaves(shapes)
        leaves_s = jax.tree.leaves(
            shard, is_leaf=lambda x: isinstance(x, NamedSharding))
        assert len(leaves_p) == len(leaves_s)
        assert all(isinstance(s, NamedSharding) for s in leaves_s)

    def test_stacked_superblock_scan_dim_unsharded(self):
        cfg = reduced_config("minitron-4b")
        mesh = _mesh11()
        shapes = jax.eval_shape(
            lambda: init_state(cfg, jax.random.PRNGKey(0))).params
        shard = param_shardings(shapes, mesh)
        found = {}
        jax.tree_util.tree_map_with_path(
            lambda kp, s: found.setdefault(_keypath_parts(kp), s.spec), shard)
        wq = next(v for k, v in found.items()
                  if "sb" in k and k[-1] == "wq")
        assert len(wq) == 0 or wq[0] is None     # leading [R] stays unsharded

    def test_indivisible_dims_fall_back_to_replicated(self):
        """A mesh axis that does not divide a dim must never be assigned."""
        code = """
            import jax, jax.numpy as jnp
            from repro.configs import reduced_config
            from repro.dist.sharding import param_shardings
            from repro.train.step import init_state
            # 3 model shards cannot divide 4 heads / 64 dm / 128 ff evenly
            mesh = jax.make_mesh((2, 3), ("data", "model"))
            cfg = reduced_config("minitron-4b", num_heads=4, num_kv_heads=4)
            shapes = jax.eval_shape(
                lambda: init_state(cfg, jax.random.PRNGKey(0))).params
            shard = param_shardings(shapes, mesh, fsdp=True)
            for s, p in zip(jax.tree.leaves(shard,
                                is_leaf=lambda x: hasattr(x, "spec")),
                            jax.tree.leaves(shapes)):
                shp = p.shape
                for i, ax in enumerate(s.spec):
                    if ax is None:
                        continue
                    n = 1
                    for a in (ax if isinstance(ax, tuple) else (ax,)):
                        n *= mesh.shape[a]
                    assert shp[i] % n == 0, (shp, s.spec)
            print("divisibility OK")
        """
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                             capture_output=True, text=True, timeout=300,
                             env=env)
        assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"

    def test_batch_spec_and_sharding(self):
        mesh = _mesh11()
        batch = {"tokens": jnp.zeros((8, 16), jnp.int32),
                 "loss_mask": jnp.zeros((8, 16), jnp.float32)}
        spec = batch_spec(batch["tokens"], mesh)
        assert isinstance(spec, P) and len(spec) == 2
        tree = batch_sharding(batch, mesh)
        assert all(isinstance(s, NamedSharding)
                   for s in jax.tree.leaves(
                       tree, is_leaf=lambda x: isinstance(x, NamedSharding)))


# ---------------------------------------------------------------------------
# ring all-reduce: padded-chunk path (local size not divisible by n)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_ring_all_reduce_padded_chunks():
    code = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.overlap import make_ring_all_reduce
        mesh = jax.make_mesh((4,), ("data",))
        n = 4
        # local shard 9 elements: not divisible by 4 -> padded chunk path
        x = jnp.arange(36.0)
        fn = make_ring_all_reduce(mesh, "data")
        got = jax.jit(fn)(x)
        want = np.tile(np.arange(36.0).reshape(4, 9).sum(0), 4)
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6)
        print("padded ring OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=300, env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"


@pytest.mark.slow
def test_ring_all_reduce_mean_matches_pmean():
    """reduce='mean' must reproduce jax.lax.pmean semantics exactly (the sum
    variant trains DP gradients n x too large — PR-2 known issue)."""
    code = """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.dist.overlap import make_ring_all_reduce
        mesh = jax.make_mesh((4,), ("data",))
        x = jnp.arange(36.0) * 0.25 - 2.0
        fn = make_ring_all_reduce(mesh, "data", reduce="mean")
        got = jax.jit(fn)(x)
        ref = jax.shard_map(lambda s: jax.lax.pmean(s, "data"), mesh=mesh,
                            in_specs=P("data"), out_specs=P("data"))
        want = jax.jit(ref)(x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6)
        # and the sum path stays the sum path
        fs = make_ring_all_reduce(mesh, "data", reduce="sum")
        np.testing.assert_allclose(np.asarray(jax.jit(fs)(x)),
                                   np.asarray(want) * 4, rtol=1e-6)
        print("mean ring OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=300, env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"


@pytest.mark.slow
def test_ring_all_reduce_min_is_global_lwm():
    """reduce='min' over 4 fake devices: the reduced value equals the min of
    the shard-local ``announce.lwm`` contributions (= pmin), including the
    all-unpinned case where every board contributes the TS_MAX sentinel
    (DESIGN.md §13)."""
    code = """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core.mvgc import announce as ann
        from repro.core.mvgc.pool import TS_MAX
        from repro.dist.overlap import make_ring_all_reduce
        mesh = jax.make_mesh((4,), ("gc_hosts",))
        one = jnp.ones((1,), jnp.int32)
        t = jnp.ones((1,), bool)
        # 4 host-local boards: three pinned at distinct ts, one pin-free
        boards = [ann.make_board(4) for _ in range(4)]
        for i, ts in ((0, 17), (1, 5), (2, 23)):
            boards[i] = ann.announce(boards[i], one * i, one * ts, t)
        contrib = jnp.stack([ann.lwm(b) for b in boards])
        fn = jax.jit(make_ring_all_reduce(mesh, "gc_hosts", reduce="min"))
        got = np.asarray(fn(contrib))
        assert got.shape == (4,) and (got == 5).all(), got
        ref = jax.shard_map(lambda s: jax.lax.pmin(s, "gc_hosts"),
                            mesh=mesh, in_specs=P("gc_hosts"),
                            out_specs=P("gc_hosts"))
        np.testing.assert_array_equal(got, np.asarray(jax.jit(ref)(contrib)))
        # sentinel case: every board pin-free -> the reduction stays TS_MAX
        empty = jnp.stack([ann.lwm(ann.make_board(4)) for _ in range(4)])
        got2 = np.asarray(fn(empty))
        assert (got2 == int(TS_MAX)).all(), got2
        print("min ring OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=300, env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"


def test_ring_all_reduce_min_single_device_identity():
    """On a 1-position mesh the min ring is the identity (no hops) — the
    degraded path ShardedPagedKVEngine relies on when under-deviced."""
    mesh = jax.make_mesh((1,), ("gc_hosts",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    fn = make_ring_all_reduce(mesh, "gc_hosts", reduce="min")
    x = jnp.asarray([7, 3, 11], jnp.int32)
    np.testing.assert_array_equal(np.asarray(jax.jit(fn)(x)),
                                  np.asarray(x))


def test_ring_all_reduce_rejects_unknown_reduce():
    mesh = _mesh11()
    with pytest.raises(ValueError):
        make_ring_all_reduce(mesh, "data", reduce="max")


# ---------------------------------------------------------------------------
# train_step grad_reduce wiring
# ---------------------------------------------------------------------------
def test_train_step_grad_reduce_hook():
    """An identity grad_reduce changes nothing; a zeroing one freezes params
    (proving the hook sits on the actual gradient path)."""
    cfg = reduced_config("minitron-4b")
    run = RunConfig(model=cfg, shape=SHAPES["train_4k"], lr=1e-2)
    data = {"tokens": jnp.ones((4, 16), jnp.int32)}
    state = init_state(cfg, jax.random.PRNGKey(0))

    s_plain, m_plain = train_step(state, data, cfg, run)
    s_id, m_id = train_step(state, data, cfg, run, grad_reduce=lambda g: g)
    np.testing.assert_allclose(float(m_plain["loss"]), float(m_id["loss"]))
    for a, b in zip(jax.tree.leaves(s_plain.params), jax.tree.leaves(s_id.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    zero = lambda g: jax.tree.map(jnp.zeros_like, g)
    s_z, _ = train_step(state, data, cfg, run, grad_reduce=zero)
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(s_z.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# straggler
# ---------------------------------------------------------------------------
class TestStraggler:
    def test_watchdog_no_false_positives_during_warmup(self):
        wd = StepWatchdog(k_sigma=3.0, min_budget_s=0.0)
        wd.start()
        time.sleep(0.02)       # would be an outlier — but stats are empty
        wd.stop(0)
        assert wd.suspect_steps == []

    def test_watchdog_outlier_excluded_from_stats(self):
        wd = StepWatchdog(k_sigma=3.0, min_budget_s=0.0)
        for i in range(10):
            wd.start(); time.sleep(0.001); wd.stop(i)
        thr_before = wd.threshold()
        wd.start(); time.sleep(0.05); wd.stop(42)
        assert 42 in wd.suspect_steps
        assert wd.threshold() == pytest.approx(thr_before, rel=1e-6)

    def test_watchdog_min_budget_floor(self):
        wd = StepWatchdog(k_sigma=0.0, min_budget_s=10.0)
        for i in range(20):
            wd.start(); wd.stop(i)
        assert wd.suspect_steps == []            # nothing beats a 10s floor

    def test_heartbeat_roundtrip(self, tmp_path):
        hb = HeartbeatFile(str(tmp_path / "sub" / "hb.json"), host_id=3)
        assert hb.read() is None and hb.age_s() == float("inf")
        hb.beat(17)
        rec = hb.read()
        assert rec["host_id"] == 3 and rec["step"] == 17
        assert hb.age_s() < 60
        # atomic write: no tmp droppings left behind
        assert os.listdir(tmp_path / "sub") == ["hb.json"]

    def test_heartbeat_corrupt_file_is_dead(self, tmp_path):
        p = tmp_path / "hb.json"
        p.write_text("{not json")
        hb = HeartbeatFile(str(p), host_id=0)
        assert hb.read() is None
        assert hb.age_s() == float("inf")

    def test_budget_is_finite_during_warmup(self):
        """Regression: threshold() is inf during warmup, and a never-beaten
        HeartbeatFile has age_s() == inf; ``inf > inf == False`` made a dead
        host read as live.  budget_s() must stay finite so is_stale catches
        it (the sharded-GC staleness-aging rule, DESIGN.md §13)."""
        wd = StepWatchdog(min_budget_s=0.25)
        assert wd.threshold() == float("inf")        # warmup
        assert wd.budget_s() == pytest.approx(3.0 * 0.25)
        assert wd.is_stale(float("inf"))             # dead host is stale
        assert not wd.is_stale(0.0)

    def test_never_beaten_heartbeat_counts_stale(self, tmp_path):
        hb = HeartbeatFile(str(tmp_path / "hb.json"), host_id=1)
        wd = StepWatchdog()
        assert wd.is_stale(hb.age_s())               # the closed inf-inf hole

    def test_budget_tracks_threshold_after_warmup(self):
        wd = StepWatchdog(k_sigma=0.0, min_budget_s=2.0, warmup_steps=1)
        wd.start(); wd.stop(0)
        assert wd.threshold() == pytest.approx(2.0)   # floor dominates
        assert wd.budget_s(grace_steps=4.0) == pytest.approx(8.0)
        assert wd.is_stale(8.5, grace_steps=4.0)
        assert not wd.is_stale(7.5, grace_steps=4.0)


def test_make_grad_reduce_inside_shard_map_matches_pmean():
    """The ``train.step.make_grad_reduce`` hook (ROADMAP item 3 leftover):
    the ``shard_mapped=False`` ring body, applied leaf-wise to a gradient
    pytree *inside* an enclosing shard_map over the DP axis, must equal
    ``jax.lax.pmean`` on every leaf."""
    code = """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.train.step import make_grad_reduce
        mesh = jax.make_mesh((4,), ("dp",))
        grads = {"w": jnp.arange(48.0).reshape(8, 6) * 0.5 - 3.0,
                 "b": jnp.arange(8.0) * -0.125}
        reduce_fn = make_grad_reduce(mesh, "dp", reduce="mean")

        ring = jax.shard_map(reduce_fn, mesh=mesh,
                             in_specs=({"w": P("dp"), "b": P("dp")},),
                             out_specs={"w": P("dp"), "b": P("dp")})
        ref = jax.shard_map(lambda g: jax.tree.map(
                                lambda x: jax.lax.pmean(x, "dp"), g),
                            mesh=mesh,
                            in_specs=({"w": P("dp"), "b": P("dp")},),
                            out_specs={"w": P("dp"), "b": P("dp")})
        got = jax.jit(ring)(grads)
        want = jax.jit(ref)(grads)
        for k in grads:
            np.testing.assert_allclose(np.asarray(got[k]),
                                       np.asarray(want[k]), rtol=1e-6)
        print("grad_reduce OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=300, env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
