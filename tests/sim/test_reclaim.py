"""The abort ⇒ reclaim ⇒ retry loop (DESIGN.md §10).

Covers the reclamation feedback loop end to end: a capacity abort drives a
synchronous ``SchemeBase.reclaim_on_pressure`` pass whose freed versions
refund the version budget so the retry commits (no second capacity abort
when obsolete versions exist); hot-set-aware compaction reclaims hot keys
before cold ones; the budget-refill accounting reconciles with the
``versions_reclaimed_on_abort`` counters; the abort-reason taxonomy still
partitions ``txns_aborted`` with the loop active; and the docs-coverage
tool (``tools/check_docstrings.py``) passes on the four tentpole modules.
"""
import os
import subprocess
import sys

import pytest

from repro.core.sim.contention import ContentionManager, ReclaimRequest
from repro.core.sim.measure import Measurement, OpMix
from repro.core.sim.mvhash import MVHashTable
from repro.core.sim.schemes import make_scheme
from repro.core.sim.ssl_list import MVEnv
from repro.core.sim.txn import Txn
from repro.core.sim.workload import WorkloadConfig, run_workload

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

HC_MIX = OpMix(0.25, 0.10, 0.05, scan_size=16, rwtxn_frac=0.60,
               txn_size=4, txn_ranges=2, txn_point_reads=2)


def _hc_config(scheme: str, **over) -> WorkloadConfig:
    """The storm regime with the capacity gate active (mirrors
    benchmarks/txn_mix.py's ``hc`` tier at test scale)."""
    kw = {"batch_size": 8} if scheme in ("dlrt", "slrt", "bbf") else {}
    base = dict(
        ds="hash", scheme=scheme, n_keys=128, num_procs=12, mode="mixed",
        op_mix=HC_MIX, ops_per_proc=80, zipf=1.2, seed=11, max_retries=24,
        txn_capacity=256, txn_refill_every=1, validate_scans=True,
        scheme_kwargs=kw, sample_every=2048,
    )
    base.update(over)
    return WorkloadConfig(**base)


def _make_garbage(env, ds, key: int, n: int) -> None:
    """Overwrite ``key`` n times, one timestamp apart, leaving n obsolete
    versions behind (nobody is announced, so they are pure garbage)."""
    for i in range(n):
        env.advance_ts()
        ds.insert(0, key, i)


# ---------------------------------------------------------------------------
# ContentionManager: hot set, deficit, refund
# ---------------------------------------------------------------------------
def test_hot_set_is_decayed_and_ordered():
    cm = ContentionManager(4, hot_half_life=100)
    for _ in range(8):
        cm.record_conflict(0, "wcc", [7], now=0.0)
    cm.record_conflict(1, "footprint", [3], now=0.0)
    # at t=0 key 7 dominates
    assert [k for k, _ in cm.hot_set(0.0)] == [7, 3]
    # 8 half-lives later key 7 has cooled to ~0.03 and dropped out while a
    # fresh conflict on key 3 keeps it hot: recency beats lifetime counts
    cm.record_conflict(1, "footprint", [3], now=800.0)
    hot = cm.hot_set(800.0)
    assert [k for k, _ in hot] == [3]
    # ...even though the raw lifetime counts still favour key 7
    assert cm.hot_keys(1)[0][0] == 7


def test_deficit_and_refund_roundtrip():
    cm = ContentionManager(2, capacity=16, refill_every=10**9)
    assert cm.try_consume(13, now=0.0)           # 16 -> 3
    assert not cm.try_consume(4, now=0.0)        # short by 1
    assert cm.deficit() == 13                    # refill target: back to full
    cm.refund(9)                                 # partial reclaim
    assert cm.budget == 12
    cm.refund(10**6)                             # refund saturates at capacity
    assert cm.budget == 16
    # unbounded manager: no deficit, refunds are no-ops
    free = ContentionManager(2)
    assert free.deficit() == 0
    free.refund(5)
    assert free.budget == 0


def test_reclaim_request_carries_deficit_and_hot_set():
    cm = ContentionManager(2, capacity=8, refill_every=10**9)
    cm.record_conflict(0, "wcc", [42], now=0.0)
    assert cm.try_consume(8, now=0.0)
    req = cm.reclaim_request(0.0)
    assert isinstance(req, ReclaimRequest)
    assert req.deficit == 8 and req.hot_keys == [42]
    cm.record_reclaim(6, latency_slices=3)
    assert cm.budget == 6
    assert cm.reclaims_triggered == 1
    assert cm.versions_reclaimed == 6
    assert cm.reclaim_latency_slices == 3
    s = cm.stats()
    assert s["reclaims_triggered"] == 1
    assert s["versions_reclaimed_on_abort"] == 6
    assert s["reclaim_latency_slices"] == 3


# ---------------------------------------------------------------------------
# The loop itself: capacity abort => reclaim => retry commits
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scheme_name", ["ebr", "steam", "slrt"])
def test_capacity_abort_reclaims_and_retry_commits(scheme_name):
    """A txn that dies on the version budget must trigger a synchronous
    reclaim that refunds enough tokens for the immediate retry to commit —
    no second capacity abort while obsolete versions exist."""
    env = MVEnv(4)
    # slrt: a batch too large to flush during setup, so the tracker defers
    # all compaction and the garbage genuinely accumulates until reclaim
    kw = {"batch_size": 1000} if scheme_name == "slrt" else {}
    scheme = make_scheme(scheme_name, env, **kw)
    ds = MVHashTable(env, scheme, 32)
    scheme.set_key_resolver(ds.version_lists_for)
    # plenty of obsolete versions on a handful of keys
    for k in (1, 2, 3):
        _make_garbage(env, ds, k, 40)
    # a nearly-drained budget that cannot passively refill
    cm = ContentionManager(4, capacity=64, refill_every=10**9)
    cm.budget = 1
    scheme.set_contention(cm)

    txn = Txn(0, ds, env, scheme, cm=cm)
    txn.put(5, 99)
    txn.put(6, 99)
    assert not txn.try_commit()
    assert txn.abort_reason == "capacity"
    assert cm.reclaims_triggered == 1
    assert txn.reclaimed_versions > 0, "no obsolete versions reclaimed"
    assert txn.reclaim_stall_slices >= 1
    assert cm.budget >= 2, "reclaim did not refund the budget"
    assert scheme.reclaims == 1
    assert scheme.reclaimed_on_pressure == txn.reclaimed_versions

    retry = Txn(0, ds, env, scheme, cm=cm)
    retry.put(5, 99)
    retry.put(6, 99)
    assert retry.try_commit(), f"retry aborted with {retry.abort_reason}"
    assert retry.reclaim_stall_slices == 0  # no reclaim on the commit path


def test_reclaim_count_is_honest_space_accounting():
    """The versions a reclaim reports must actually leave reachability —
    the refund is only sound if the count is real reclaimed space."""
    env = MVEnv(4)
    scheme = make_scheme("steam", env, scan_every=10**9)
    ds = MVHashTable(env, scheme, 32)
    scheme.set_key_resolver(ds.version_lists_for)
    # pin a snapshot so steam's per-append compaction can't collect, then
    # release: garbage persists because the cached announce scan is stale
    t = scheme.begin_rtx(3)
    for k in (1, 2, 3, 4):
        _make_garbage(env, ds, k, 25)
    scheme.end_rtx(3)
    before = sum(l.reachable_count() for l in scheme.lists)
    freed = scheme.reclaim_on_pressure([1, 2, 3, 4], deficit=10**9)
    after = sum(l.reachable_count() for l in scheme.lists)
    assert freed > 0
    assert before - after == freed


def test_hot_set_compaction_reclaims_hot_keys_before_cold():
    """STEAM's pressure reclaim must compact the version lists governing the
    hot set first, and stop once the deficit is met — cold lists keep their
    garbage until a later (larger-deficit) pass."""
    env = MVEnv(4)
    scheme = make_scheme("steam", env, scan_every=10**9)
    ds = MVHashTable(env, scheme, 32)
    scheme.set_key_resolver(ds.version_lists_for)
    hot_k, cold_k = 1, 2
    hot_lst = ds.version_lists_for(hot_k)[0]
    cold_lst = ds.version_lists_for(cold_k)[0]
    assert hot_lst is not cold_lst, "keys collided into one bucket"
    # stale-cache garbage on both keys (see previous test for the recipe)
    t = scheme.begin_rtx(3)
    _make_garbage(env, ds, hot_k, 30)
    _make_garbage(env, ds, cold_k, 30)
    scheme.end_rtx(3)
    cold_before = cold_lst.reachable_count()
    assert hot_lst.reachable_count() > 10

    freed = scheme.reclaim_on_pressure([hot_k], deficit=5)
    assert freed >= 5
    assert hot_lst.reachable_count() == 1      # compacted to the live version
    assert cold_lst.reachable_count() == cold_before  # untouched: deficit met

    # a second, unbounded pass spills over to the cold list
    freed2 = scheme.reclaim_on_pressure([hot_k], deficit=10**9)
    assert cold_lst.reachable_count() == 1
    assert freed2 >= cold_before - 1


def test_zipf_storm_hot_set_tracks_hot_keys():
    """Under Zipf 1.2 draws the decayed hot set must surface genuinely hot
    keys: feeding sampled conflict keys to the manager, every exported key
    carries above-average draw probability and the head of the hot set is
    among the sampler's true hottest keys."""
    from repro.core.sim.workload import KeySampler
    key_range = 256
    sampler = KeySampler(key_range, 1.2, seed=12)
    cm = ContentionManager(4, hot_half_life=10**9)
    for i in range(2000):
        cm.record_conflict(i % 4, "wcc", [sampler()], now=float(i))
    hot = cm.hot_set(2000.0, n=8)
    assert len(hot) == 8
    p = sampler.p                      # per-key draw probability, index k-1
    avg = 1.0 / key_range
    hot_probs = [p[k - 1] for k, _ in hot]
    assert min(hot_probs) > avg        # every exported key is above average
    assert max(hot_probs) > 10 * avg   # ...and the head is genuinely hot
    top16 = {int(i) + 1 for i in (-p).argsort()[:16]}
    assert hot[0][0] in top16


# ---------------------------------------------------------------------------
# Workload-level accounting
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scheme_name", ["ebr", "slrt"])
def test_budget_refill_accounting_matches_counters(scheme_name):
    """Driver counters, contention-manager totals, scheme totals and the
    schema-v4 Measurement row must all agree on the reclaim accounting."""
    r = run_workload(_hc_config(scheme_name))
    c = r["counters"]
    cs = r["contention_stats"]
    ss = r["scheme_stats"]
    assert c["txn_aborts_capacity"] > 0, "gate never engaged; config too weak"
    # every capacity abort triggers exactly one reclaim pass, and the
    # contention manager is the single source of truth for the counts
    assert cs["reclaims_triggered"] == c["txn_aborts_capacity"]
    assert cs["versions_reclaimed_on_abort"] > 0
    # the scheme's own counters cover the manager's (quiesce/unit reclaims
    # could add more, never less)
    assert ss["reclaims"] >= cs["reclaims_triggered"]
    assert ss["reclaimed_on_pressure"] >= cs["versions_reclaimed_on_abort"]
    assert cs["reclaim_latency_slices"] >= cs["reclaims_triggered"]
    # schema v4 row carries the same numbers
    row = Measurement.from_result("txn_mix", "hc", r).to_row()
    assert row["reclaims_triggered"] == cs["reclaims_triggered"]
    assert row["versions_reclaimed_on_abort"] == cs["versions_reclaimed_on_abort"]
    assert row["reclaim_latency_slices"] == cs["reclaim_latency_slices"]
    assert row["peak_space_post_reclaim"] == c["peak_space_post_reclaim"]
    assert 0 < row["peak_space_post_reclaim"]
    assert r["scan_violations"] == 0 and r["txn_violations"] == 0


@pytest.mark.parametrize("scheme_name", ["steam", "dlrt"])
def test_taxonomy_partition_survives_the_reclaim_loop(scheme_name):
    """With reclaim active the abort-reason taxonomy must still partition
    ``txns_aborted`` exactly, and the storm must stay starvation-free."""
    cfg = _hc_config(scheme_name)
    r = run_workload(cfg)
    c = r["counters"]
    assert c["txn_aborts"] > 100, "storm did not form; config too weak"
    assert (c["txn_aborts_footprint"] + c["txn_aborts_wcc"]
            + c["txn_aborts_capacity"]) == c["txn_aborts"]
    assert c["txn_giveups"] == 0
    assert r["contention_stats"]["max_consecutive_aborts"] < cfg.max_retries


def test_reclaim_loop_prevents_capacity_giveups():
    """The acceptance story: with a budget so tight the pre-reclaim engine
    would burn whole retry ladders, the loop keeps give-ups at zero because
    every capacity abort refills the budget before the retry."""
    r = run_workload(_hc_config("ebr", txn_capacity=128))
    c = r["counters"]
    cs = r["contention_stats"]
    assert c["txn_aborts_capacity"] > 0
    assert cs["reclaims_triggered"] == c["txn_aborts_capacity"]
    assert cs["versions_reclaimed_on_abort"] > 0
    assert c["txn_giveups"] == 0


# ---------------------------------------------------------------------------
# Tooling satellites
# ---------------------------------------------------------------------------
def test_docs_coverage_tool_passes_on_tentpole_modules():
    """tools/check_docstrings.py must run clean on contention/txn/schemes/
    measure (the CI docs-coverage step)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "check_docstrings.py")],
        capture_output=True, text=True, env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_bench_checker_validates_v4_reclaim_fields():
    """The txn-schema invariants (registry, DESIGN.md §12) must reject
    inconsistent v4 rows — what ``check_bench_json`` runs on txn payloads."""
    from repro.core.sim.measure import check_txn_rows

    def check_txn_fields(rows, min_txn_sizes=0):
        return check_txn_rows(rows, {"min_txn_sizes": min_txn_sizes})

    base = {k: 0 for k in (
        "txn_size", "rw_ratio", "txns_committed", "txns_aborted",
        "abort_rate", "txn_ranges", "point_reads", "aborts_footprint",
        "aborts_wcc", "aborts_capacity", "txn_giveups", "backoff_slices",
        "reclaims_triggered", "versions_reclaimed_on_abort",
        "reclaim_latency_slices", "peak_space_post_reclaim")}
    ok = dict(base, txn_size=2, txn_ranges=2, rw_ratio=0.5, txns_committed=10,
              txns_aborted=4, abort_rate=round(4 / 14, 4), aborts_capacity=3,
              aborts_wcc=1, reclaims_triggered=3,
              versions_reclaimed_on_abort=17, reclaim_latency_slices=5,
              peak_space_post_reclaim=100)
    assert check_txn_fields([ok], min_txn_sizes=1) == []
    # more reclaims than capacity aborts: impossible
    bad = dict(ok, reclaims_triggered=4)
    assert any("aborts_capacity" in p for p in
               check_txn_fields([bad], min_txn_sizes=1))
    # reclaim outputs without any reclaim pass
    bad = dict(ok, aborts_capacity=0, aborts_footprint=3,
               reclaims_triggered=0)
    assert any("reclaims_triggered=0" in p for p in
               check_txn_fields([bad], min_txn_sizes=1))
    # a reclaim pass that stalled zero slices
    bad = dict(ok, reclaim_latency_slices=2)
    assert any("reclaim_latency_slices" in p for p in
               check_txn_fields([bad], min_txn_sizes=1))
