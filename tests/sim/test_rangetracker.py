"""RangeTracker tests: safety (never returns a needed version), liveness
(obsolete versions eventually returned), amortized work, and space bounds."""
import math
import random

from hypothesis import given, settings, strategies as st

from repro.core.sim.rangetracker import RangeTracker, TrackedVersion


def test_interval_intersection():
    v = TrackedVersion(None, 3, 7)
    assert v.intersects([3])
    assert v.intersects([5])
    assert v.intersects([6])
    assert not v.intersects([7])       # high is exclusive
    assert not v.intersects([2])
    assert not v.intersects([])
    assert v.intersects([1, 2, 6, 9])


@settings(max_examples=80, deadline=None)
@given(
    seed=st.integers(0, 100_000),
    n_adds=st.integers(1, 300),
    p=st.integers(1, 8),
)
def test_never_returns_needed(seed, n_adds, p):
    rng = random.Random(seed)
    announced = sorted(rng.sample(range(0, 1000), rng.randint(0, 5)))
    rt = RangeTracker(p, batch_size=8)
    returned = []
    for i in range(n_adds):
        lo = rng.randint(0, 990)
        hi = lo + rng.randint(1, 10)
        returned += rt.add(rng.randrange(p), ("v", i, lo, hi), lo, hi,
                           lambda: announced)
    for (_, i, lo, hi) in returned:
        assert not TrackedVersion(None, lo, hi).intersects(announced), (
            f"returned needed version [{lo},{hi}) with announced={announced}"
        )


def test_drain_returns_everything_when_unannounced():
    rt = RangeTracker(4, batch_size=16)
    out = set()
    for i in range(100):
        out |= set(rt.add(i % 4, i, i, i + 1, lambda: []))
    out |= set(rt.drain(lambda: []))
    # every unneeded version comes back exactly once; none lost, none duplicated
    assert out == set(range(100))
    assert rt.size() == 0


def test_needed_versions_retained_until_unannounced():
    announced = [50]
    rt = RangeTracker(2, batch_size=4)
    ret = []
    for i in range(40):
        # all versions cover ts=50 -> all needed
        ret += rt.add(i % 2, i, 45, 55, lambda: announced)
    assert ret == []
    assert rt.size() == 40
    announced.clear()
    out = rt.drain(lambda: [])
    assert len(out) == 40


def test_space_bound_h_plus_p2logp():
    """Theorem 1 ingredient: RT holds O(H + P^2 log P) versions."""
    P = 8
    rt = RangeTracker(P)   # B = P log P
    rng = random.Random(1)
    announced = [10_000]   # one pinned rtx keeps H versions needed
    H = 64
    # interleave needed and unneeded adds
    max_size = 0
    for i in range(5000):
        if i % 10 == 0 and i // 10 < H:
            lo, hi = 9_000, 11_000          # needed (covers 10_000)
        else:
            lo = rng.randint(0, 8000)
            hi = lo + rng.randint(1, 5)     # unneeded
        rt.add(rng.randrange(P), i, lo, hi, lambda: announced)
        max_size = max(max_size, rt.size())
    bound = 4 * (H + P * P * max(1, int(math.log2(P)))) + 4 * rt.B
    assert max_size <= bound, f"RT size {max_size} exceeded O(H+P^2logP) ~ {bound}"


def test_amortized_constant_work():
    P = 8
    rt = RangeTracker(P)
    n = 20_000
    for i in range(n):
        rt.add(i % P, i, i, i + 1, lambda: [])
    # work per add is O(1) amortized (B-sized flush every B adds)
    assert rt.work / n < 12, f"non-constant amortized work: {rt.work / n:.2f}/add"
