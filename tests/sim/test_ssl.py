"""SSL (Algorithm 3) tests: compact vs the needed(A,t) oracle, Proposition 17,
Theorem 13 (search correctness), scanAnnounce consistency, concurrency."""
import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.sim.machine import Scheduler
from repro.core.sim.ssl_list import SSL, SNode, MVEnv


def drain(gen):
    try:
        while True:
            next(gen)
    except StopIteration as s:
        return s.value


def build_list(timestamps):
    l = SSL()
    prev = l.head
    for i, ts in enumerate(timestamps):
        n = SNode(ts, f"v{i}@{ts}")
        assert drain(l.tryAppend_steps(prev, n))
        prev = n
    return l


class TestCompactSequential:
    def test_keeps_exactly_needed(self):
        l = build_list([1, 2, 3, 5, 8, 9])
        A, t = [2, 5], 9
        l.compact(A, t, l.head)
        kept = [n.ts for n in l.abstract_list()[1:]]
        # needed: ts>9: none; last <=9 -> 9; last <=2 -> 2; last <=5 -> 5
        assert kept == [2, 5, 9]
        for n in l.abstract_list()[1:]:
            assert l.needed(n, A, t)

    def test_skips_above_threshold(self):
        l = build_list([1, 2, 3, 10, 11])
        # t=3: versions 10, 11 are "future" (skip); last<=3 is 3; A empty
        l.compact([], 3, l.head)
        kept = [n.ts for n in l.abstract_list()[1:]]
        assert kept == [3, 10, 11]

    def test_empty_announcements(self):
        l = build_list(list(range(1, 20)))
        l.compact([], 19, l.head)
        kept = [n.ts for n in l.abstract_list()[1:]]
        assert kept == [19]

    def test_all_needed(self):
        ts = [1, 3, 5]
        l = build_list(ts)
        l.compact([1, 3, 4], 5, l.head)
        assert [n.ts for n in l.abstract_list()[1:]] == ts

    @settings(max_examples=120, deadline=None)
    @given(
        data=st.data(),
        n=st.integers(1, 24),
        n_ann=st.integers(0, 6),
    )
    def test_compact_matches_oracle(self, data, n, n_ann):
        """After a solo compact, the retained set == the needed(A,t) oracle."""
        deltas = data.draw(st.lists(st.integers(0, 3), min_size=n, max_size=n))
        ts, cur = [], 0
        for d in deltas:
            cur += d
            ts.append(cur)
        l = build_list(ts)
        t = data.draw(st.integers(0, cur + 2))
        A = sorted(
            data.draw(
                st.lists(st.integers(0, cur + 2), min_size=n_ann, max_size=n_ann)
            )
        )
        # precondition 4: announcements must be in A or >= t; enforce by
        # clipping t to min(A + [t]).
        t = min([t] + A)
        l.compact(A, t, l.head)
        l.check_sorted()
        expected = [n_ for n_ in l.added[1:] if l.needed(n_, A, t)]
        got = l.abstract_list()[1:]
        assert [n_.ts for n_ in got] == [n_.ts for n_ in expected]


class TestConcurrentCompact:
    @settings(max_examples=60, deadline=None)
    @given(seed=st.integers(0, 100_000), n=st.integers(2, 16))
    def test_concurrent_compacts_proposition17(self, seed, n):
        """Several compacts with *identical* (A,t,h) — as produced by the
        GlobalAnnScan discipline — plus concurrent appends and searches.
        Afterwards: every reachable node older than h is needed(A,t)."""
        rng = random.Random(seed)
        ts = []
        cur = 0
        for _ in range(n):
            cur += rng.randint(0, 3)
            ts.append(cur)
        l = build_list(ts)
        A = sorted(rng.sample(range(0, cur + 1), k=min(rng.randint(0, 3), cur + 1)))
        t = min([cur] + A)  # precondition 4
        h = l.head
        sched = Scheduler(seed=seed)
        sched.invariant_hooks.append(l.check_sorted)
        for _ in range(rng.randint(1, 3)):
            sched.spawn("compact", l.compact_steps(list(A), t, h), (tuple(A), t))
        # concurrent appends beyond h (nondecreasing ts)
        prev = h
        for i in range(rng.randint(0, 2)):
            y = SNode(cur + i, f"app{i}")
            sched.spawn("tryAppend", l.tryAppend_steps(prev, y), (prev, y))
            prev = y
        # concurrent searches with announced-like timestamps
        for a in A[:2]:
            sched.spawn("search", l.search_steps(a), (a,))
        sched.run_random()
        # Proposition 17
        for node in l.abstract_list()[1:]:
            if node.order < h.order or node is h:
                if node is not h:
                    assert l.needed(node, A, t), (
                        f"unneeded {node} reachable after compact"
                    )

    @settings(max_examples=60, deadline=None)
    @given(seed=st.integers(0, 100_000))
    def test_search_theorem13(self, seed):
        """Theorem 13: a search(k) with announced k returns the value of the
        last node with ts<=k appended before the search read head."""
        rng = random.Random(seed)
        env = MVEnv(4)
        l = build_list([1, 2, 4, 6])
        env.global_ts = 6
        k = rng.choice([1, 2, 3, 4, 5, 6])
        env.announce[0] = k                     # precondition 3
        scan = env.scan_announce()              # (A, t) consistent snapshot
        sched = Scheduler(seed=seed)

        result = {}

        def searcher():
            val = yield from l.search_steps(k)
            result["val"] = val
            # head cannot change during our test (appends below h) -> expected
            # computed at the end is valid.

        sched.spawn("search", searcher(), (k,))
        for _ in range(rng.randint(1, 2)):
            sched.spawn(
                "compact", l.compact_steps(list(scan.A), scan.t, l.head), ()
            )
        sched.run_random()
        expected = None
        for node in l.added:
            if node.ts <= k:
                expected = node.val
        assert result["val"] == expected


class TestScanAnnounce:
    def test_scan_announce_consistency(self):
        """Lemma 11 precondition: t is read before A, via GlobalAnnScan CAS."""
        env = MVEnv(3)
        env.global_ts = 10
        env.announce[0] = 9
        s1 = env.scan_announce()
        assert s1.t == 10 and s1.A == [9]
        env.global_ts = 12
        env.announce[1] = 11
        s2 = env.scan_announce()
        assert s2.t == 12 and s2.A == [9, 11]

    def test_announce_validates(self):
        env = MVEnv(2)
        env.global_ts = 5
        t = env.announce_ts(0)
        assert t == 5 and env.announce[0] == 5

    def test_stepped_scan_announce(self):
        env = MVEnv(2)
        env.global_ts = 3
        env.announce[1] = 2
        def run():
            s = yield from env.scan_announce_steps()
            return s
        g = run()
        try:
            while True:
                next(g)
        except StopIteration as s:
            scan = s.value
        assert scan.t == 3 and scan.A == [2]
