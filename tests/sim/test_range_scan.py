"""Range-scan edge cases + snapshot-consistency validation (DESIGN.md §7).

Covers the corners where snapshot semantics are easiest to get wrong:
empty ranges, keys deleted *while a scan is mid-flight* (must still appear —
the scan reads the snapshot at its rtx timestamp, not the live state), scans
pinned across EBR epoch advances, and Zipfian hot-key scans under STEAM+LF's
per-append compaction.  The final parametrized test is the acceptance bar:
>= 1000 randomized scans per structure x scheme, each replayed against the
reference UpdateLog, zero violations.
"""
import random

import pytest

from repro.core.sim.linearize import (ScanValidator, UpdateLog,
                                      check_range_scan)
from repro.core.sim.machine import drain
from repro.core.sim.measure import OpMix
from repro.core.sim.mvhash import MVHashTable
from repro.core.sim.mvtree import MVTree
from repro.core.sim.schemes import SCHEMES, make_scheme
from repro.core.sim.ssl_list import MVEnv
from repro.core.sim.workload import WorkloadConfig, run_workload

ALL = list(SCHEMES)
RT_SCHEMES = ("dlrt", "slrt", "bbf")


def _mk(ds_kind, scheme_name, P=4, n=32, **scheme_kw):
    env = MVEnv(P)
    if scheme_name in RT_SCHEMES:
        scheme_kw.setdefault("batch_size", 2)
    scheme = make_scheme(scheme_name, env, **scheme_kw)
    ds = MVHashTable(env, scheme, n) if ds_kind == "hash" else MVTree(env, scheme)
    return env, scheme, ds


def _upd(env, scheme, ds, log, pid, k, v):
    """One committed, logged update (v=None deletes), epoch-participating."""
    ctx = scheme.begin_update(pid)
    env.advance_ts()
    if v is None:
        ds.delete(pid, k)
    else:
        ds.insert(pid, k, v)
    log.record(env.read_ts(), k, v)
    scheme.end_update(pid, ctx)


# ---------------------------------------------------------------------------
# Empty ranges
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("ds_kind", ["hash", "tree"])
def test_empty_range_scan(ds_kind):
    env, scheme, ds = _mk(ds_kind, "slrt")
    log = UpdateLog()
    for k in range(20, 30):
        _upd(env, scheme, ds, log, 0, k, k * 7)
    t = scheme.begin_rtx(1)
    # degenerate interval [5, 5) and a populated-structure miss [1, 15)
    assert drain(ds.range_scan(1, 5, 5, t)) == []
    assert drain(ds.range_scan(1, 1, 15, t)) == []
    ok, _ = check_range_scan(log, 1, 15, t, [])
    assert ok
    scheme.end_rtx(1)


def test_scan_on_empty_structures():
    for ds_kind in ("hash", "tree"):
        env, scheme, ds = _mk(ds_kind, "ebr")
        t = scheme.begin_rtx(0)
        assert drain(ds.range_scan(0, 1, 100, t)) == []
        scheme.end_rtx(0)


# ---------------------------------------------------------------------------
# Deletion mid-scan: snapshot semantics
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("ds_kind", ["hash", "tree"])
@pytest.mark.parametrize("scheme_name", ["steam", "slrt", "ebr"])
def test_key_deleted_mid_scan_still_appears(ds_kind, scheme_name):
    """A scan pinned at t must report keys deleted (or overwritten) after t —
    including keys its cursor has not reached yet."""
    env, scheme, ds = _mk(ds_kind, scheme_name)
    log = UpdateLog()
    for k in range(1, 13):
        _upd(env, scheme, ds, log, 0, k, 100 + k)

    t = scheme.begin_rtx(1)
    expected = log.snapshot_range(1, 13, t)
    gen = ds.range_scan(1, 1, 13, t)
    for _ in range(3):                       # cursor part-way through
        next(gen)
    _upd(env, scheme, ds, log, 0, 10, None)  # delete ahead of the cursor
    _upd(env, scheme, ds, log, 0, 2, None)   # delete behind it
    _upd(env, scheme, ds, log, 0, 7, 999)    # overwrite mid-range
    result = drain(gen)
    scheme.end_rtx(1)

    assert sorted(result) == expected
    assert (10, 110) in result and (2, 102) in result, \
        "deleted keys must still appear at the scan's snapshot"
    assert (7, 107) in result and (7, 999) not in result, \
        "post-snapshot overwrite must not leak into the scan"
    # and a fresh scan *after* the deletes sees the new state
    t2 = scheme.begin_rtx(1)
    result2 = drain(ds.range_scan(1, 1, 13, t2))
    scheme.end_rtx(1)
    assert sorted(result2) == log.snapshot_range(1, 13, t2)
    assert not any(k in (2, 10) for k, _ in result2)


# ---------------------------------------------------------------------------
# EBR epoch advance under a pinned scan
# ---------------------------------------------------------------------------
def test_scan_concurrent_with_ebr_epoch_advance():
    """With advance_every=2, concurrent updates drive the epoch protocol
    while a scan is pinned: the epoch may advance past the pin at most once
    (the announced epoch then blocks further advances), and the scan's
    snapshot must survive the frees of older epochs."""
    env, scheme, ds = _mk("hash", "ebr", advance_every=2)
    log = UpdateLog()
    for k in range(1, 17):
        _upd(env, scheme, ds, log, 0, k, k)
    # churn so earlier epochs retire and frees happen
    for i in range(20):
        _upd(env, scheme, ds, log, i % 3, 1 + i % 16, 50 + i)

    t = scheme.begin_rtx(3)
    e0 = scheme.epoch
    expected = log.snapshot_range(1, 17, t)
    gen = ds.range_scan(3, 1, 17, t)
    for step in range(8):                    # interleave scan and updates
        next(gen)
        _upd(env, scheme, ds, log, step % 3, 1 + (5 * step) % 16, 1000 + step)
    result = drain(gen)
    assert scheme.epoch == e0 + 1, \
        "epoch should advance exactly once past the pinned announcement"
    assert sorted(result) == expected
    scheme.end_rtx(3)

    # unpinned, the epoch moves freely again
    for i in range(12):
        _upd(env, scheme, ds, log, i % 3, 1 + i % 16, 2000 + i)
    assert scheme.epoch >= e0 + 2


# ---------------------------------------------------------------------------
# Zipfian hot keys under STEAM+LF compaction
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("ds_kind", ["hash", "tree"])
def test_zipfian_hot_key_scan_under_steam_compaction(ds_kind):
    """STEAM+LF compacts a version list on every append; under Zipf 0.99 the
    hot keys' lists compact constantly while scans read them.  Every scan
    must still be snapshot-consistent."""
    cfg = WorkloadConfig(
        ds=ds_kind, scheme="steam", n_keys=32, num_procs=6, mode="mixed",
        op_mix=OpMix(0.45, 0.10, 0.45, scan_size=16), ops_per_proc=60,
        zipf=0.99, seed=11, scan_chunk=3, sample_every=4096,
        validate_scans=True, scheme_kwargs={"scan_every": 4},
    )
    r = run_workload(cfg)
    assert r["scheme_stats"]["compactions"] > 0
    assert r["scans_validated"] >= 100
    assert r["scan_violations"] == 0, r["violation_examples"]


# ---------------------------------------------------------------------------
# The validator itself must be falsifiable
# ---------------------------------------------------------------------------
def test_validator_catches_corrupt_results():
    log = UpdateLog()
    log.record(1, 5, "a")
    log.record(3, 5, "b")
    log.record(4, 6, "c")
    log.record(6, 5, None)
    # correct snapshots
    assert check_range_scan(log, 1, 10, 2, [(5, "a")])[0]
    assert check_range_scan(log, 1, 10, 5, [(5, "b"), (6, "c")])[0]
    assert check_range_scan(log, 1, 10, 7, [(6, "c")])[0]
    # future-value leak, stale value, phantom, and missing key all fail
    assert not check_range_scan(log, 1, 10, 2, [(5, "b")])[0]
    assert not check_range_scan(log, 1, 10, 5, [(5, "a"), (6, "c")])[0]
    assert not check_range_scan(log, 1, 10, 7, [(5, "b"), (6, "c")])[0]
    assert not check_range_scan(log, 1, 10, 5, [(6, "c")])[0]
    v = ScanValidator(log)
    v.check(1, 10, 7, [(6, "c")])
    v.check(1, 10, 7, [(6, "WRONG")])
    assert v.checked == 2 and v.violations == 1
    assert v.examples[0]["extra"] == [(6, "WRONG")]


# ---------------------------------------------------------------------------
# Acceptance: >= 1000 randomized validated scans per structure x scheme
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("ds_kind", ["hash", "tree"])
@pytest.mark.parametrize("scheme_name", ALL)
def test_thousand_randomized_scans_snapshot_consistent(ds_kind, scheme_name):
    kw = {"batch_size": 8} if scheme_name in RT_SCHEMES else {}
    cfg = WorkloadConfig(
        ds=ds_kind, scheme=scheme_name, n_keys=32, num_procs=8, mode="mixed",
        op_mix=OpMix(0.15, 0.05, 0.80, scan_size=12), ops_per_proc=175,
        zipf=0.99, seed=29, scan_chunk=3, sample_every=1_000_000,
        validate_scans=True, scheme_kwargs=kw,
    )
    r = run_workload(cfg)
    assert r["scans_validated"] >= 1000, \
        f"only {r['scans_validated']} scans completed; config too small"
    assert r["scan_violations"] == 0, (
        f"{scheme_name}/{ds_kind}: {r['scan_violations']} of "
        f"{r['scans_validated']} scans broke snapshot consistency: "
        f"{r['violation_examples']}")
