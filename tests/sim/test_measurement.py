"""OpMix / Measurement / BENCH-json serializer tests, plus end-to-end smoke
of the ``benchmarks/range_query.py`` driver and the repo's docs checker
(the same commands CI runs)."""
import json
import os
import subprocess
import sys

import pytest

from repro.core.sim.measure import (EEMARQ_MIXES, EEMARQ_RW_MIXES,
                                    EEMARQ_SCAN_SIZES, EEMARQ_ZIPFS,
                                    Measurement, OpMix, REQUIRED_ROW_KEYS,
                                    bench_payload, validate_bench_payload,
                                    write_bench_json)
from repro.core.sim.workload import (WorkloadConfig, eemarq_matrix,
                                     run_workload)

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


# ---------------------------------------------------------------------------
# OpMix
# ---------------------------------------------------------------------------
def test_opmix_validates_fractions():
    OpMix(0.5, 0.25, 0.25)                      # ok
    with pytest.raises(ValueError):
        OpMix(0.5, 0.5, 0.5)                    # sums to 1.5
    with pytest.raises(ValueError):
        OpMix(-0.1, 0.6, 0.5)                   # negative
    with pytest.raises(ValueError):
        OpMix(0.5, 0.25, 0.25, scan_size=0)     # scans but no size
    OpMix(0.3, 0.2, 0.25, rwtxn_frac=0.25)      # 4-way ok
    with pytest.raises(ValueError):
        OpMix(0.5, 0.25, 0.25, rwtxn_frac=0.25)  # sums to 1.25
    with pytest.raises(ValueError):
        OpMix(0.3, 0.2, 0.25, rwtxn_frac=0.25, txn_size=0)


def test_opmix_labels():
    assert OpMix(0.5, 0.25, 0.25).label == "50/25/25"
    assert OpMix(0.1, 0.1, 0.8, name="custom").label == "custom"
    assert [m.label for m in EEMARQ_MIXES] == ["50/25/25", "10/10/80"]
    assert OpMix(0.3, 0.2, 0.25, rwtxn_frac=0.25).label == "30/20/25/25"
    assert [m.label for m in EEMARQ_RW_MIXES] == ["30/20/25/25", "10/10/20/60"]


def test_opmix_rw_ratio():
    assert OpMix(0.5, 0.25, 0.25).rw_ratio == 0.0
    assert OpMix(0.3, 0.2, 0.25, rwtxn_frac=0.25).rw_ratio == 0.5
    assert OpMix(0.1, 0.1, 0.2, rwtxn_frac=0.6).rw_ratio == 0.75
    assert OpMix(1.0, 0.0, 0.0).rw_ratio == 0.0   # no txns at all


def test_eemarq_matrix_enumeration():
    full = eemarq_matrix()
    # 2 structures x 2 mixes x 4 scan sizes x 2 zipfs x 5 schemes
    assert len(full) == 2 * len(EEMARQ_MIXES) * len(EEMARQ_SCAN_SIZES) \
        * len(EEMARQ_ZIPFS) * 5
    assert {c.ds for c in full} == {"hash", "tree"}
    assert {c.op_mix.scan_size for c in full} == set(EEMARQ_SCAN_SIZES)
    sub = eemarq_matrix(structures=("hash",), scan_sizes=(8,),
                        zipfs=(0.99,), schemes=("ebr", "slrt"))
    assert len(sub) == 1 * 2 * 1 * 1 * 2
    assert all(c.mode == "mixed" for c in sub)


# ---------------------------------------------------------------------------
# Measurement + serializer
# ---------------------------------------------------------------------------
def _tiny_result():
    cfg = WorkloadConfig(
        ds="hash", scheme="slrt", n_keys=24, num_procs=4, mode="mixed",
        op_mix=OpMix(0.4, 0.2, 0.4, scan_size=8), ops_per_proc=20,
        seed=5, sample_every=512, validate_scans=True,
        scheme_kwargs={"batch_size": 4},
    )
    return run_workload(cfg)


def test_measurement_from_result_and_schema(tmp_path):
    r = _tiny_result()
    m = Measurement.from_result("range_query", "hash/40-20-40/s=8", r)
    row = m.to_row()
    for k in REQUIRED_ROW_KEYS:
        assert k in row, f"Measurement row missing required key {k}"
    assert row["scheme"] == "slrt" and row["ds"] == "hash"
    assert row["scan_size"] == 8
    assert row["scans"] > 0 and row["scans_validated"] == row["scans"]
    assert row["scan_violations"] == 0
    assert row["peak_space_words"] >= row["end_space_words"] > 0

    path = tmp_path / "BENCH_test.json"
    write_bench_json(str(path), "range_query", [m], meta={"tier": "unit"})
    payload = json.loads(path.read_text())
    assert validate_bench_payload(payload) == []
    assert payload["meta"]["tier"] == "unit"
    assert payload["rows"][0]["scheme"] == "slrt"


def test_validate_bench_payload_flags_problems():
    assert "rows is empty" in " ".join(
        validate_bench_payload({"bench": "x", "rows": []}))
    r = _tiny_result()
    m = Measurement.from_result("b", "f", r)
    payload = bench_payload("b", [m])
    del payload["rows"][0]["peak_space_words"]
    problems = validate_bench_payload(payload)
    assert any("peak_space_words" in p for p in problems)


def test_split_mode_measurement_labels():
    cfg = WorkloadConfig(ds="tree", scheme="ebr", n_keys=24, num_procs=6,
                         mode="split", scan_size=8, ops_per_proc=12,
                         sample_every=512)
    m = Measurement.from_result("gc_comparison", "fig4", run_workload(cfg))
    assert m.mix == "split" and m.scan_size == 8


# ---------------------------------------------------------------------------
# Schema registry: the bench-measurement API (DESIGN.md §12)
# ---------------------------------------------------------------------------
def _kernel_measurement(mix="standard", speedup=1.2):
    from repro.core.sim.measure import KernelMeasurement

    us_f, bytes_moved, peak = 100.0, 1_000_000, 25.0
    gb_s = round(bytes_moved / us_f / 1e3, 4)
    return KernelMeasurement(
        bench="kernel", figure=f"compact/{mix}", ds="slab", scheme="compact",
        mix=mix, scan_size=0, zipf=0.0, n_keys=256, num_procs=1,
        ops_per_proc=0, seed=0, updates=0, lookups=0, scans=0, scan_keys=0,
        total_work=0, ops_per_mwork=0.0, updates_per_mwork=0.0,
        scan_keys_per_mwork=0.0, peak_space_words=0, peak_versions=0,
        avg_space_words=0, end_space_words=0, end_versions_per_list=0.0,
        scans_validated=0, scan_violations=0, wall_s=0.0,
        kernel="compact", shape="S256xV8xP64", backend="cpu",
        path="ref_fused", bytes_moved=bytes_moved, iters=10, us_fused=us_f,
        us_unfused=round(us_f * speedup, 2), speedup=speedup, gb_s=gb_s,
        peak_bw_gb_s=peak, bw_frac=round(gb_s / peak, 6), target_frac=0.5,
        target_gb_s=12.5, kernel_validated=True)


def test_schema_of_payload_dispatch():
    from repro.core.sim.measure import bench_payload, schema_of_payload

    p = bench_payload("kernel", [_kernel_measurement()], schema="kernel")
    assert p["row_schema"] == "kernel"
    s = schema_of_payload(p)
    assert s.name == "kernel" and s.panel == "kernel"
    assert "bytes_moved" in s.compare_fields and "kernel" in s.key_fields
    # legacy payloads (no row_schema key) infer from the bench name
    assert schema_of_payload({"bench": "txn_mix"}).name == "txn"
    assert schema_of_payload({"bench": "serve"}).name == "serve"
    assert schema_of_payload({"bench": "range_query"}).name == "sim"
    with pytest.raises(KeyError):
        bench_payload("kernel", [], schema="no_such_schema")


def test_kernel_schema_invariants():
    from repro.core.sim.measure import bench_payload, schema_of_payload

    good = _kernel_measurement(mix="standard", speedup=1.2)
    slow_smoke = _kernel_measurement(mix="smoke", speedup=0.9)
    slow_std = _kernel_measurement(mix="standard", speedup=0.9)
    payload = bench_payload("kernel", [good, slow_smoke], schema="kernel")
    assert validate_bench_payload(payload) == []
    schema = schema_of_payload(payload)

    def run_invariants(rows, options):
        probs = []
        for inv in schema.invariants:
            probs.extend(inv(rows, options))
        return probs

    # smoke rows are exempt from the speedup gate; standard rows are not
    assert run_invariants([r for r in payload["rows"]], {}) == []
    bad = bench_payload("kernel", [slow_std], schema="kernel")
    probs = run_invariants(bad["rows"], {})
    assert any("unfused" in p for p in probs)
    # self-consistency: a doctored speedup cell is caught
    doctored = dict(good.to_row())
    doctored["speedup"] = 9.9
    probs = run_invariants([doctored], {})
    assert any("speedup" in p for p in probs)


# ---------------------------------------------------------------------------
# Driver + docs-check smoke (what CI's bench-smoke / docs steps run)
# ---------------------------------------------------------------------------
def _run(cmd, **kw):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(cmd, cwd=REPO, env=env, capture_output=True,
                          text=True, timeout=300, **kw)


def test_range_query_smoke_emits_valid_bench_json(tmp_path):
    out = str(tmp_path / "BENCH_range_query.json")
    p = _run([sys.executable, "benchmarks/range_query.py", "--smoke",
              "--out", out])
    assert p.returncode == 0, p.stderr
    payload = json.loads(open(out).read())
    assert validate_bench_payload(payload) == []
    rows = payload["rows"]
    # acceptance coverage: all 5 schemes x 2 structures x 2 mixes
    assert {r["scheme"] for r in rows} == {"ebr", "steam", "dlrt", "slrt", "bbf"}
    assert {r["ds"] for r in rows} == {"hash", "tree"}
    assert {r["mix"] for r in rows} == {"50/25/25", "10/10/80"}
    assert all(r["scan_violations"] == 0 for r in rows)
    assert all(r["scans_validated"] > 0 for r in rows)
    # and the schema checker tool agrees
    p = _run([sys.executable, "tools/check_bench_json.py", out,
              "--schemes", "ebr,steam,dlrt,slrt,bbf",
              "--structures", "hash,tree", "--min-mixes", "2"])
    assert p.returncode == 0, p.stdout + p.stderr


def test_design_doc_citations_resolve():
    p = _run([sys.executable, "tools/check_design_refs.py"])
    assert p.returncode == 0, p.stdout + p.stderr


def test_txn_mix_smoke_emits_valid_bench_json(tmp_path):
    out = str(tmp_path / "BENCH_txn_mix.json")
    p = _run([sys.executable, "benchmarks/txn_mix.py", "--smoke",
              "--out", out])
    assert p.returncode == 0, p.stderr
    payload = json.loads(open(out).read())
    assert validate_bench_payload(payload) == []
    rows = payload["rows"]
    assert {r["scheme"] for r in rows} == {"ebr", "steam", "dlrt", "slrt", "bbf"}
    assert {r["ds"] for r in rows} == {"hash", "tree"}
    assert {r["mix"] for r in rows} == {"30/20/25/25", "10/10/20/60"}
    assert all(r["scan_violations"] == 0 for r in rows)
    assert sum(r["txns_committed"] for r in rows) > 0
    assert all(0.0 <= r["abort_rate"] <= 1.0 for r in rows)
    assert {r["rw_ratio"] for r in rows} == {0.5, 0.75}
    # schema v3: multi-interval footprints + the abort taxonomy partition
    assert all(r["txn_ranges"] >= 2 for r in rows)
    assert all(r["aborts_footprint"] + r["aborts_wcc"] + r["aborts_capacity"]
               == r["txns_aborted"] for r in rows)
    # the schema checker agrees, including the txn-field validation
    p = _run([sys.executable, "tools/check_bench_json.py", out,
              "--schemes", "ebr,steam,dlrt,slrt,bbf",
              "--structures", "hash,tree", "--min-mixes", "2", "--txn"])
    assert p.returncode == 0, p.stdout + p.stderr


# ---------------------------------------------------------------------------
# compare_bench (the bench-trajectory CI gate)
# ---------------------------------------------------------------------------
def _write_payload(path, rows, bench="txn_mix"):
    with open(path, "w") as f:
        json.dump(bench_payload(bench, rows), f)


def test_compare_bench_trajectory_gate(tmp_path):
    r = _tiny_result()
    m = Measurement.from_result("txn_mix", "hash/tiny", r)
    committed, fresh = str(tmp_path / "c.json"), str(tmp_path / "f.json")
    _write_payload(committed, [m])
    _write_payload(fresh, [m])
    p = _run([sys.executable, "tools/compare_bench.py", committed, fresh])
    assert p.returncode == 0, p.stdout + p.stderr

    # drifted space beyond tolerance -> fail; waiving the cell -> pass
    import dataclasses
    drifted = dataclasses.replace(
        m, peak_space_words=int(m.peak_space_words * 2))
    _write_payload(fresh, [drifted])
    p = _run([sys.executable, "tools/compare_bench.py", committed, fresh,
              "--tolerance", "0.15"])
    assert p.returncode == 1 and "drifted" in p.stdout, p.stdout + p.stderr
    p = _run([sys.executable, "tools/compare_bench.py", committed, fresh,
              "--tolerance", "0.15", "--waive", "ds=hash,scheme=slrt"])
    assert p.returncode == 0, p.stdout + p.stderr

    # a fresh cell absent from the committed file -> stale-file failure
    moved = dataclasses.replace(m, seed=m.seed + 1)
    _write_payload(fresh, [moved])
    p = _run([sys.executable, "tools/compare_bench.py", committed, fresh])
    assert p.returncode == 1 and "stale" in p.stdout, p.stdout + p.stderr


# ---------------------------------------------------------------------------
# plot_bench (the CI bench-plots step)
# ---------------------------------------------------------------------------
def test_plot_bench_renders_pngs(tmp_path):
    pytest.importorskip("matplotlib")
    import dataclasses
    r = _tiny_result()
    m = Measurement.from_result("range_query", "hash/40-20-40/s=8", r)
    txn_row = dataclasses.replace(
        m, bench="txn_mix", txn_size=2, txn_ranges=2, rw_ratio=0.5,
        txns_committed=10, txns_aborted=4, abort_rate=0.2857,
        aborts_footprint=2, aborts_wcc=1, aborts_capacity=1,
        backoff_slices=9)
    gc_row = dataclasses.replace(m, bench="gc_comparison", figure="fig4")
    paths = []
    for bench, rows in (("range_query", [m]), ("txn_mix", [txn_row]),
                        ("gc_comparison", [gc_row])):
        p = str(tmp_path / f"BENCH_{bench}.json")
        _write_payload(p, rows, bench=bench)
        paths.append(p)
    outdir = str(tmp_path / "plots")
    p = _run([sys.executable, "tools/plot_bench.py", *paths,
              "--outdir", outdir, "--require-matplotlib"])
    assert p.returncode == 0, p.stdout + p.stderr
    pngs = sorted(os.listdir(outdir))
    assert any("space_vs_scan_size" in f for f in pngs)
    assert any("space_vs_txn_size" in f for f in pngs)
    assert any("abort_rate" in f for f in pngs)
    assert any("figures" in f for f in pngs)
    assert all(f.endswith(".png") for f in pngs)


@pytest.mark.slow   # CI's bench-smoke + bench-trajectory steps run this flow
def test_committed_bench_files_pass_the_trajectory_gate(tmp_path):
    """All three repo-root BENCH files must contain every cell a fresh
    smoke/fast run emits, within tolerance — exactly what the CI
    bench-trajectory step enforces (here against fresh emissions)."""
    for driver, committed, flags in (
            ("benchmarks/txn_mix.py", "BENCH_txn_mix.json", ["--smoke"]),
            ("benchmarks/range_query.py", "BENCH_range_query.json",
             ["--smoke"]),
            ("benchmarks/gc_comparison.py", "BENCH_gc_comparison.json", [])):
        fresh = str(tmp_path / f"fresh_{os.path.basename(committed)}")
        p = _run([sys.executable, driver, *flags, "--out", fresh])
        assert p.returncode == 0, p.stderr
        p = _run([sys.executable, "tools/compare_bench.py",
                  os.path.join(REPO, committed), fresh,
                  "--tolerance", "0.15"])
        assert p.returncode == 0, p.stdout + p.stderr


def _fork_measurement(**over):
    from repro.core.sim.measure import ForkMeasurement

    base = dict(
        bench="fork", figure="fork_dag/beam", ds="paged_kv", scheme="slrt",
        mix="beam", scan_size=0, zipf=0.0, n_keys=40, num_procs=8,
        ops_per_proc=20, seed=0, updates=100, lookups=0, scans=4,
        scan_keys=10, total_work=110, ops_per_mwork=0.0,
        updates_per_mwork=0.0, scan_keys_per_mwork=0.0,
        peak_space_words=10, peak_versions=3, avg_space_words=0,
        end_space_words=6, end_versions_per_list=1.0, scans_validated=10,
        scan_violations=0, wall_s=0.1, reclaims_triggered=2,
        peak_space_post_reclaim=8, pressure_events=2, pages_reclaimed=4,
        peak_pages=10, peak_pages_post_reclaim=8, page_pool=40, page_size=4,
        decode_steps=20, tokens_appended=100, sequences_completed=0,
        forks=4, give_ups=0, snapshot_pins=0, overflow_count=0,
        dropped_retires=0, joins=2, releases=2, pages_shared_peak=3,
        eager_peak_pages=14, shared_savings_pages=4, prefix_checks=10,
        prefix_violations=0, ckpt_saves=1, ckpt_evictions=2,
        ckpt_pages_freed=3, control_ckpt_pages_freed=0,
        control_end_pages=9)
    base.update(over)
    return ForkMeasurement(**base)


def test_fork_schema_invariants():
    """check_fork_rows (DESIGN.md §14): the layered fork invariants catch
    each doctored cell that a valid row passes."""
    from repro.core.sim.measure import bench_payload, schema_of_payload

    payload = bench_payload("fork", [_fork_measurement()], schema="fork")
    assert validate_bench_payload(payload) == []
    schema = schema_of_payload(payload)
    assert schema.name == "fork" and schema.panel == "serve"

    def run(rows, options=None):
        probs = []
        for inv in schema.invariants:
            probs.extend(inv(rows, options or {}))
        return probs

    assert run(payload["rows"]) == []
    assert run(payload["rows"], {"require_pressure": True}) == []

    def bad(substr, **over):
        probs = run([dict(_fork_measurement(**over).to_row())])
        assert any(substr in p for p in probs), (substr, probs, over)

    bad("prefix_violations", prefix_violations=1)
    bad("pages_shared_peak", pages_shared_peak=11)
    bad("every join consumes", joins=5)
    bad("zero-fork", forks=0, joins=0, releases=0, pages_shared_peak=0,
        shared_savings_pages=4, eager_peak_pages=0)
    bad("strictly beat", eager_peak_pages=10, shared_savings_pages=0)
    bad("shared_savings_pages", eager_peak_pages=14, shared_savings_pages=1)
    bad("no-checkpoint", control_ckpt_pages_freed=1)
    bad("ckpt_saves=0", ckpt_saves=0)
    bad("stuck holding", control_end_pages=6)

    # require_pressure needs at least one row proving the checkpoint edge
    no_edge = dict(_fork_measurement(ckpt_saves=0, ckpt_evictions=0,
                                     ckpt_pages_freed=0,
                                     control_end_pages=0).to_row())
    probs = run([no_edge], {"require_pressure": True})
    assert any("checkpoint" in p and "edge" in p for p in probs)
