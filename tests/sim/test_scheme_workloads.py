"""Workload-level smoke + space-bound tests for every entry in SCHEMES.

Complements test_schemes.py: these run every scheme through the paper's
discrete-event driver (`run_workload`) on a small deterministic config and
check (a) range-query snapshot safety — no version needed by an *active*
range query is ever reclaimed, (b) the headline space claim — SL-RT/BBF peak
reachable versions stay within a constant factor of live versions (one
current version per list), (c) driver bookkeeping invariants.
"""
import random

import pytest

from repro.core.sim.mvhash import MVHashTable
from repro.core.sim.schemes import SCHEMES, make_scheme
from repro.core.sim.ssl_list import MVEnv
from repro.core.sim.workload import WorkloadConfig, measure_space, run_workload

ALL = list(SCHEMES)


def _cfg(scheme, ds="hash", **over):
    kw = {"batch_size": 4} if scheme in ("dlrt", "slrt", "bbf") else {}
    base = dict(ds=ds, scheme=scheme, n_keys=48, num_procs=6,
                ops_per_proc=30, mode="split", scan_size=24,
                sample_every=128, seed=3, scheme_kwargs=kw)
    base.update(over)
    return WorkloadConfig(**base)


@pytest.mark.parametrize("ds_kind", ["hash", "tree"])
@pytest.mark.parametrize("scheme_name", ALL)
def test_workload_smoke_all_schemes(scheme_name, ds_kind):
    """Every scheme completes the split workload; counters and space sane."""
    r = run_workload(_cfg(scheme_name, ds_kind))
    assert r["counters"]["updates"] > 0 and r["counters"]["scans"] > 0
    assert r["total_work"] > 0
    assert r["peak_space"]["versions"] >= r["end_space"]["versions"]
    # quiescent state: at most the current version per list survives
    assert r["end_space"]["versions_per_list"] <= 1.0 + 1e-9


@pytest.mark.parametrize("scheme_name", ALL)
def test_active_range_query_versions_survive(scheme_name):
    """Pin a range query at t, storm updates over its key range, then read:
    every key must resolve to its value as of t (shadow-validated).  Fails if
    the scheme reclaims any version the active rtx still needs."""
    rng = random.Random(1234)
    env = MVEnv(4)
    scheme = make_scheme(scheme_name, env,
                         **({"batch_size": 2}
                            if scheme_name in ("dlrt", "slrt", "bbf") else {}))
    ds = MVHashTable(env, scheme, 32)

    shadow = {}

    def do_update(pid):
        ctx = scheme.begin_update(pid)
        env.advance_ts()
        k = rng.randint(1, 24)
        if rng.random() < 0.7:
            v = rng.randrange(1 << 16)
            ds.insert(pid, k, v)
            shadow.setdefault(k, []).append((env.read_ts(), v))
        else:
            ds.delete(pid, k)
            shadow.setdefault(k, []).append((env.read_ts(), None))
        scheme.end_update(pid, ctx)

    for _ in range(40):
        do_update(0)

    for _ in range(25):
        t = scheme.begin_rtx(3)                  # pin the snapshot
        want = {}
        for k in range(1, 25):
            best = None
            for ts, v in shadow.get(k, []):
                if ts <= t:
                    best = v
            if best is not None:
                want[k] = best
        for _ in range(rng.randint(4, 16)):      # versions churn under the pin
            do_update(rng.randrange(3))
        got = dict(ds.range_query(3, 1, 25, t))
        assert got == want, (
            f"{scheme_name}: range query at t={t} diverged "
            f"(missing={set(want) - set(got)}, extra={set(got) - set(want)}) "
            f"— a needed version was reclaimed")
        scheme.end_rtx(3)


@pytest.mark.parametrize("scheme_name", ["slrt", "bbf"])
def test_space_within_constant_factor_of_live(scheme_name):
    """Paper's headline bound: RT-based schemes keep reachable versions within
    a small constant factor of live versions (= one current per list) even at
    peak, unlike EBR whose peak scales with rtx length (test_schemes.py)."""
    r = run_workload(_cfg(scheme_name))
    peak = r["peak_space"]
    assert peak["versions"] <= 2 * peak["lists"], (
        f"{scheme_name}: peak {peak['versions']} versions vs "
        f"{peak['lists']} lists — space bound violated")
    # after quiesce the factor collapses to exactly live
    assert r["end_space"]["versions"] <= r["end_space"]["lists"]


def test_measure_space_counts_current_versions():
    """measure_space agrees with a hand-built structure: after quiescence a
    freshly-built table holds exactly one version per reachable list (bucket
    chains + one key list per inserted key)."""
    env = MVEnv(2)
    scheme = make_scheme("slrt", env, batch_size=2)
    ds = MVHashTable(env, scheme, 16)
    for k in range(1, 9):
        env.advance_ts()
        ds.insert(0, k, k * 10)
    scheme.quiesce()
    s = measure_space(ds, scheme)
    assert s["versions"] == s["lists"]          # quiescent: 1 current each
    assert s["versions"] >= 8                   # at least the 8 key lists
    assert s["words"] >= s["versions"] * scheme.node_words
