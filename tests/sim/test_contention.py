"""ContentionManager semantics + contention-aware txn-engine properties
(DESIGN.md §9).

Covers: bounded-exponential backoff (growth, cap, per-pid jitter, reset on
commit), the version-budget capacity gate (token bucket, refill with
timestamp progress), the pressure signal and the EBR/STEAM cadence
consultation, abort-reason taxonomy reconciliation against the workload
counters, and the fairness acceptance bar: under a high-contention storm no
transaction starves — every process commits, nobody exhausts its retry
budget.
"""
import pytest

from repro.core.sim.contention import ABORT_REASONS, ContentionManager
from repro.core.sim.measure import Measurement, OpMix
from repro.core.sim.schemes import make_scheme
from repro.core.sim.ssl_list import MVEnv
from repro.core.sim.workload import WorkloadConfig, run_workload

HC_MIX = OpMix(0.25, 0.10, 0.05, scan_size=16, rwtxn_frac=0.60,
               txn_size=4, txn_ranges=2, txn_point_reads=2)


def _hc_config(scheme: str, **over) -> WorkloadConfig:
    """The high-contention storm regime (Zipf 1.2, hot keys, capacity gate),
    mirroring benchmarks/txn_mix.py's ``hc`` tier at test scale."""
    kw = {"batch_size": 8} if scheme in ("dlrt", "slrt", "bbf") else {}
    base = dict(
        ds="hash", scheme=scheme, n_keys=128, num_procs=12, mode="mixed",
        op_mix=HC_MIX, ops_per_proc=80, zipf=1.2, seed=11, max_retries=24,
        txn_capacity=256, txn_refill_every=1, validate_scans=True,
        scheme_kwargs=kw, sample_every=2048,
    )
    base.update(over)
    return WorkloadConfig(**base)


# ---------------------------------------------------------------------------
# ContentionManager unit semantics
# ---------------------------------------------------------------------------
def test_backoff_grows_exponentially_and_is_bounded():
    cm = ContentionManager(4, backoff_base=2, backoff_cap=64)
    assert cm.backoff_slices(0) == 0          # no conflicts yet: no backoff
    seen = []
    for _ in range(12):
        cm.record_conflict(0, "footprint", [5])
        seen.append(cm.backoff_slices(0))
    # grows (modulo jitter <= base) and saturates at the cap
    assert seen[0] >= 2 and seen[3] > seen[0]
    assert max(seen) == 64 and seen[-1] == 64
    assert all(s <= 64 for s in seen)
    # a commit resets the ladder
    cm.record_commit(0)
    assert cm.backoff_slices(0) == 0
    cm.record_conflict(0, "wcc", [5])
    assert cm.backoff_slices(0) <= 2 + 2


def test_backoff_jitter_desynchronizes_pids():
    cm = ContentionManager(8, backoff_base=4, backoff_cap=1024)
    for pid in range(8):
        for _ in range(3):
            cm.record_conflict(pid, "footprint", [])
    # same retry count, but not all pids get the identical backoff
    assert len({cm.backoff_slices(pid) for pid in range(8)}) > 1


def test_unknown_abort_reason_rejected():
    cm = ContentionManager(2)
    with pytest.raises(ValueError):
        cm.record_conflict(0, "cosmic-rays")


def test_capacity_token_bucket_refills_with_timestamp_progress():
    cm = ContentionManager(2, capacity=8, refill_every=2)
    assert cm.try_consume(6, now=0.0)        # 8 -> 2
    assert not cm.try_consume(4, now=0.0)    # 2 < 4: capacity abort
    assert cm.try_consume(2, now=0.0)        # exact spend ok: 2 -> 0
    # 8 ts ticks at refill_every=2 -> 4 tokens back
    assert not cm.try_consume(5, now=8.0)
    assert cm.try_consume(4, now=8.0)
    # unbounded manager never rejects
    assert ContentionManager(2).try_consume(10**9, now=0.0)


def test_pressure_decays_with_timestamp_progress():
    cm = ContentionManager(2, pressure_window=100)
    assert cm.pressure(1000.0) == 0.0        # no conflict ever
    cm.record_conflict(0, "footprint", [3], now=1000.0)
    assert cm.pressure(1000.0) == 1.0
    assert 0.4 < cm.pressure(1050.0) < 0.6
    assert cm.pressure(1100.0) == 0.0
    assert cm.hot_keys() == [(3, 1)]


def test_stats_expose_the_taxonomy():
    cm = ContentionManager(2)
    cm.record_conflict(0, "wcc", [1])
    cm.record_conflict(1, "capacity", [])
    cm.record_conflict(1, "footprint", [2, 3])
    cm.record_commit(0)
    s = cm.stats()
    assert s["conflicts"] == 3 and s["commits"] == 1
    assert [s[f"aborts_{r}"] for r in ABORT_REASONS] == [1, 1, 1]


# ---------------------------------------------------------------------------
# Scheme consultation: GC cadence shortens under pressure
# ---------------------------------------------------------------------------
def test_ebr_epoch_cadence_accelerates_under_pressure():
    env = MVEnv(2)
    scheme = make_scheme("ebr", env, advance_every=40)
    cm = ContentionManager(2, pressure_window=10**9)
    scheme.set_contention(cm)
    cm.record_conflict(0, "footprint", [], now=env.read_ts())

    def ops_until_advance():
        e0, n = scheme.epoch, 0
        while scheme.epoch == e0 and n < 200:
            scheme.begin_update(0)
            scheme.end_update(0, None)
            n += 1
        return n

    stressed = ops_until_advance()
    scheme.set_contention(None)              # pressure gone
    calm = ops_until_advance()
    assert stressed < calm <= 41
    assert stressed <= 11                    # 0.75 pressure cut: 40 -> 10


def test_steam_refreshes_announce_scan_faster_under_pressure():
    env = MVEnv(2)
    scheme = make_scheme("steam", env, scan_every=40)
    scheme._scan()                           # prime the cache
    base_work = scheme.work

    def refresh_cost(n):
        w0 = scheme.work
        for _ in range(n):
            scheme._scan()
        return scheme.work - w0

    calm = refresh_cost(40)                  # ~1 refresh per 40 calls
    cm = ContentionManager(2, pressure_window=10**9)
    scheme.set_contention(cm)
    cm.record_conflict(0, "wcc", [], now=env.read_ts())
    stressed = refresh_cost(40)              # ~4 refreshes per 40 calls
    assert stressed >= 3 * max(1, calm)
    assert base_work > 0                     # the prime actually scanned


# ---------------------------------------------------------------------------
# Workload-level: taxonomy reconciliation + fairness under the storm
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scheme", ["ebr", "steam", "slrt"])
def test_abort_reasons_reconcile_with_txns_aborted(scheme):
    r = run_workload(_hc_config(scheme))
    c = r["counters"]
    assert c["txn_aborts"] > 100, "storm did not form; config too weak"
    assert (c["txn_aborts_footprint"] + c["txn_aborts_wcc"]
            + c["txn_aborts_capacity"]) == c["txn_aborts"]
    assert c["txn_aborts_capacity"] > 0     # the budget gate engaged
    assert c["txn_aborts_footprint"] > 0    # ...and real validation failures
    # the Measurement row carries the same partition (schema v3)
    m = Measurement.from_result("txn_mix", "hc", r)
    row = m.to_row()
    assert (row["aborts_footprint"] + row["aborts_wcc"]
            + row["aborts_capacity"]) == row["txns_aborted"]
    assert row["backoff_slices"] > 0 and row["txn_ranges"] == 2
    assert r["scan_violations"] == 0 and r["txn_violations"] == 0


@pytest.mark.parametrize("scheme", ["ebr", "dlrt"])
def test_no_txn_starves_under_high_contention(scheme):
    """Fairness acceptance: with bounded-exponential backoff active, a
    high-contention storm must not starve anyone — every process commits
    transactions, nobody exhausts its retry budget (zero give-ups), and the
    longest abort streak stays strictly inside ``max_retries``."""
    cfg = _hc_config(scheme)
    r = run_workload(cfg)
    c = r["counters"]
    cs = r["contention_stats"]
    assert c["txn_aborts"] > 100, "storm did not form; config too weak"
    assert c["txn_giveups"] == 0, f"{c['txn_giveups']} txns starved"
    assert cs["max_consecutive_aborts"] < cfg.max_retries
    assert cs["backoff_slices"] > 0
    # every process got read-write txns through the storm
    assert r["cm_commits_by_pid"] is not None
    assert all(n > 0 for n in r["cm_commits_by_pid"]), r["cm_commits_by_pid"]
