"""Read-write transaction semantics (DESIGN.md §8).

Covers the txn model's load-bearing promises: read-your-own-writes overlay,
single-commit-timestamp atomicity, abort-then-retry leaving no visible
versions, write-phase pins blocking EBR epoch advance and STEAM compaction of
the txn's snapshot, conflict validation (abort on footprint change,
ABA-tolerant revalidation), and the randomized acceptance bar: >= 1000
committed validated read-write txns per structure x scheme.
"""
import random

import pytest

from repro.core.sim.linearize import ScanValidator, UpdateLog
from repro.core.sim.measure import EEMARQ_RW_MIXES, OpMix
from repro.core.sim.mvhash import MVHashTable
from repro.core.sim.mvtree import MVTree
from repro.core.sim.schemes import SCHEMES, make_scheme
from repro.core.sim.ssl_list import MVEnv
from repro.core.sim.txn import Txn
from repro.core.sim.workload import (WorkloadConfig, eemarq_rw_matrix,
                                     measure_space, run_workload)

ALL = list(SCHEMES)
RT_SCHEMES = ("dlrt", "slrt", "bbf")


def _mk(ds_kind, scheme_name, P=4, n=32, **scheme_kw):
    env = MVEnv(P)
    if scheme_name in RT_SCHEMES:
        scheme_kw.setdefault("batch_size", 2)
    scheme = make_scheme(scheme_name, env, **scheme_kw)
    ds = MVHashTable(env, scheme, n) if ds_kind == "hash" else MVTree(env, scheme)
    return env, scheme, ds


def _upd(env, scheme, ds, log, pid, k, v):
    ctx = scheme.begin_update(pid)
    env.advance_ts()
    if v is None:
        ds.delete(pid, k)
    else:
        ds.insert(pid, k, v)
    log.record(env.read_ts(), k, v)
    scheme.end_update(pid, ctx)


# ---------------------------------------------------------------------------
# Basic commit path: snapshot reads, buffered writes, single commit timestamp
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("ds_kind", ["hash", "tree"])
def test_txn_commit_single_timestamp(ds_kind):
    env, scheme, ds = _mk(ds_kind, "slrt")
    log = UpdateLog()
    for k in range(1, 11):
        _upd(env, scheme, ds, log, 0, k, 100 + k)

    txn = Txn(1, ds, env, scheme, log=log)
    scanned = txn.range_query(1, 11)
    assert scanned == log.snapshot_range(1, 11, txn.begin_ts)
    txn.put(3, 999)
    txn.delete(7)
    txn.put(20, 555)          # blind write outside the scanned interval
    assert txn.try_commit()
    tc = txn.commit_ts
    assert tc > txn.begin_ts
    # all writes visible at exactly tc, in structure and log
    assert ds.rtx_lookup(1, 3, tc) == 999
    assert ds.rtx_lookup(1, 7, tc) is None
    assert ds.rtx_lookup(1, 20, tc) == 555
    for k in (3, 7, 20):
        assert log.value_at(k, tc) == {3: 999, 7: None, 20: 555}[k]
        # invisible one tick before commit
        assert log.value_at(k, tc - 1) != {3: 999, 7: None, 20: 555}[k] or k == 7
    v = ScanValidator(log)
    assert v.check_txn(txn)
    assert v.txns_checked == 1 and v.violations == 0


@pytest.mark.parametrize("ds_kind", ["hash", "tree"])
def test_txn_read_your_own_writes(ds_kind):
    env, scheme, ds = _mk(ds_kind, "ebr")
    log = UpdateLog()
    for k in (2, 4, 6):
        _upd(env, scheme, ds, log, 0, k, 10 * k)

    txn = Txn(1, ds, env, scheme, log=log)
    txn.put(4, -44)
    txn.put(5, -55)
    txn.delete(6)
    # get: overlay wins over the snapshot
    assert txn.get(4) == -44
    assert txn.get(5) == -55
    assert txn.get(6) is None
    assert txn.get(2) == 20
    # range_query: overlay merged into the snapshot scan
    assert txn.range_query(1, 8) == [(2, 20), (4, -44), (5, -55)]
    assert txn.try_commit()
    # committed state matches what the txn read
    t2 = scheme.begin_rtx(2)
    assert ds.range_query(2, 1, 8, t2) == [(2, 20), (4, -44), (5, -55)]
    scheme.end_rtx(2)


# ---------------------------------------------------------------------------
# Abort: no visible versions, retry succeeds
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("ds_kind", ["hash", "tree"])
@pytest.mark.parametrize("scheme_name", ALL)
def test_abort_then_retry_leaves_no_visible_versions(ds_kind, scheme_name):
    env, scheme, ds = _mk(ds_kind, scheme_name)
    log = UpdateLog()
    for k in range(1, 9):
        _upd(env, scheme, ds, log, 0, k, k)

    space_before = measure_space(ds, scheme)
    log_events_before = log.events
    txn = Txn(1, ds, env, scheme, log=log)
    txn.range_query(1, 9)
    txn.put(3, 777)
    txn.delete(5)
    # conflicting committed update inside the footprint => validation fails
    _upd(env, scheme, ds, log, 2, 3, 42)
    assert not txn.try_commit()
    assert txn.state == "aborted"
    # aborted txn created no versions and recorded nothing: the only delta
    # is the conflicting update's own version
    space_after = measure_space(ds, scheme)
    assert space_after["versions"] == space_before["versions"] + 1
    assert log.events == log_events_before + 1
    assert ds.lookup(1, 3) == 42 and ds.lookup(1, 5) == 5
    v = ScanValidator(log)
    assert v.check_txn(txn)      # its completed scan is still a valid read

    # retry with a fresh snapshot commits cleanly
    txn2 = Txn(1, ds, env, scheme, log=log)
    txn2.range_query(1, 9)
    txn2.put(3, 777)
    txn2.delete(5)
    assert txn2.try_commit()
    assert ds.lookup(1, 3) == 777 and ds.lookup(1, 5) is None
    assert v.check_txn(txn2) and v.violations == 0


def test_readonly_txn_commits_without_validation():
    env, scheme, ds = _mk("hash", "slrt")
    log = UpdateLog()
    for k in range(1, 6):
        _upd(env, scheme, ds, log, 0, k, k)
    txn = Txn(1, ds, env, scheme, log=log)
    res = txn.range_query(1, 6)
    _upd(env, scheme, ds, log, 2, 3, 99)   # concurrent change: no conflict
    ts_before = env.read_ts()
    assert txn.try_commit()                # read-only: linearizes at begin_ts
    assert txn.commit_ts == txn.begin_ts
    assert env.read_ts() == ts_before      # no timestamp consumed
    assert res == log.snapshot_range(1, 6, txn.begin_ts)


def test_scan_interval_aba_revalidates():
    """A scanned interval restored to its snapshot contents revalidates:
    interval validation is value-level and ABA-tolerant (DESIGN.md §8).
    Uses the tree, whose governing-version granule is the exact leaf
    pointer, so the unrelated write key stays conflict-free."""
    env, scheme, ds = _mk("tree", "ebr")
    log = UpdateLog()
    for k in range(1, 9):
        _upd(env, scheme, ds, log, 0, k, 100 + k)
    txn = Txn(1, ds, env, scheme, log=log)
    txn.range_query(1, 9)
    txn.put(20, 22)                        # blind write far from the churn
    _upd(env, scheme, ds, log, 2, 1, 8)    # away...
    _upd(env, scheme, ds, log, 2, 1, 101)  # ...and back
    assert txn.try_commit(), txn.abort_reason
    assert ScanValidator(log).check_txn(txn)


@pytest.mark.parametrize("ds_kind", ["hash", "tree"])
def test_point_read_aba_aborts_version_wise(ds_kind):
    """Point reads are tracked version-wise (DESIGN.md §9): an away-and-back
    overwrite of a point-read key replaces its governing version, so the
    txn aborts even though the value matches the snapshot — no ABA
    tolerance for point reads, unlike scanned intervals."""
    env, scheme, ds = _mk(ds_kind, "ebr")
    log = UpdateLog()
    for k in range(1, 9):
        _upd(env, scheme, ds, log, 0, k, 100 + k)
    txn = Txn(1, ds, env, scheme, log=log)
    assert txn.get(1) == 101
    txn.put(8, 22)
    _upd(env, scheme, ds, log, 2, 1, 8)    # away...
    _upd(env, scheme, ds, log, 2, 1, 101)  # ...and back: same value
    assert not txn.try_commit()
    assert txn.state == "aborted"
    # the hash's CAS granule is the bucket, so the write key may share the
    # churned bucket (wcc fires first); the tree granule is the exact leaf
    # pointer, so only the point read can have conflicted
    if ds_kind == "tree":
        assert txn.abort_reason == "footprint" and txn.conflict_keys == [1]
    else:
        assert txn.abort_reason in ("wcc", "footprint")
    v = ScanValidator(log)
    assert v.check_txn(txn)       # its snapshot reads were still consistent


# ---------------------------------------------------------------------------
# Write-phase pins: EBR epoch advance and STEAM compaction respect them
# ---------------------------------------------------------------------------
def test_write_phase_pin_blocks_ebr_epoch_advance():
    """The txn pin (taken at begin) must keep blocking epoch advance through
    the write phase — a per-write begin_update would re-pin at the current
    epoch and release the snapshot; the txn path must not do that."""
    env, scheme, ds = _mk("hash", "ebr", advance_every=2)
    log = UpdateLog()
    for k in range(1, 17):
        _upd(env, scheme, ds, log, 0, k, k)
    for i in range(20):                       # let epochs churn first
        _upd(env, scheme, ds, log, i % 3, 1 + i % 16, 50 + i)

    txn = Txn(3, ds, env, scheme, log=log)
    e0 = scheme.epoch
    gen = txn.range_scan(1, 17)
    for step in range(8):                     # updates interleave mid-scan
        next(gen)
        _upd(env, scheme, ds, log, step % 3, 1 + (5 * step) % 16, 1000 + step)
    try:
        while True:
            next(gen)
    except StopIteration:
        pass
    # write phase: buffer writes, keep churning from other pids
    txn.put(1, -1)
    txn.put(16, -16)
    for i in range(10):
        _upd(env, scheme, ds, log, i % 3, 2 + i % 10, 3000 + i)
    assert scheme.epoch <= e0 + 1, \
        "pinned txn announcement must block epoch advance past one step"
    v = ScanValidator(log)
    txn.try_commit()                          # may conflict (churned keys)
    assert v.check_txn(txn) and v.violations == 0, v.examples
    # released: epochs move again
    for i in range(12):
        _upd(env, scheme, ds, log, i % 3, 1 + i % 16, 4000 + i)
    assert scheme.epoch >= e0 + 2


@pytest.mark.parametrize("ds_kind", ["hash", "tree"])
def test_write_phase_pin_survives_steam_compaction(ds_kind):
    """STEAM+LF compacts on every append — including the txn's own commit
    writes and concurrent hot-key churn.  The txn's begin-ts snapshot must
    survive until commit, and its scan must validate."""
    env, scheme, ds = _mk(ds_kind, "steam", scan_every=2)
    log = UpdateLog()
    for k in range(1, 13):
        _upd(env, scheme, ds, log, 0, k, 100 + k)

    txn = Txn(1, ds, env, scheme, log=log)
    gen = txn.range_scan(1, 13)
    next(gen)
    # hot-key churn on keys the scan has not reached yet: compaction runs
    # per append, with the txn's announce pinning its snapshot
    for i in range(30):
        _upd(env, scheme, ds, log, 2, 1 + i % 12, 500 + i)
    try:
        while True:
            next(gen)
    except StopIteration:
        pass
    assert scheme.compactions > 0
    txn.put(30, 1)                      # write outside the churned interval
    txn.try_commit()                    # footprint churned => likely aborts
    v = ScanValidator(log)
    assert v.check_txn(txn) and v.violations == 0, v.examples


# ---------------------------------------------------------------------------
# Randomized acceptance: >= 1000 committed validated *multi-interval* rw txns
# per ds x scheme (2 disjoint scan intervals + a tracked point read each)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("ds_kind", ["hash", "tree"])
@pytest.mark.parametrize("scheme_name", ALL)
def test_thousand_randomized_rw_txns_validated(ds_kind, scheme_name):
    kw = {"batch_size": 8} if scheme_name in RT_SCHEMES else {}
    cfg = WorkloadConfig(
        ds=ds_kind, scheme=scheme_name, n_keys=32, num_procs=8, mode="mixed",
        op_mix=OpMix(0.10, 0.05, 0.05, scan_size=8, rwtxn_frac=0.80,
                     txn_size=3, txn_ranges=2, txn_point_reads=1),
        ops_per_proc=200, zipf=0.99, seed=31, scan_chunk=3, max_retries=24,
        sample_every=1_000_000, validate_scans=True, scheme_kwargs=kw,
    )
    r = run_workload(cfg)
    c = r["counters"]
    assert c["txn_commits"] >= 1000, \
        f"only {c['txn_commits']} txns committed; config too small"
    assert r["txns_validated"] >= c["txn_commits"] + c["txn_aborts"] - 8 * 24
    assert r["txn_violations"] == 0, r["violation_examples"]
    assert r["scan_violations"] == 0, (
        f"{scheme_name}/{ds_kind}: {r['scan_violations']} violations over "
        f"{r['scans_validated']} checked scans: {r['violation_examples']}")
    # the abort taxonomy partitions the abort counter exactly
    assert (c["txn_aborts_footprint"] + c["txn_aborts_wcc"]
            + c["txn_aborts_capacity"]) == c["txn_aborts"]


# ---------------------------------------------------------------------------
# Matrix enumeration
# ---------------------------------------------------------------------------
def test_eemarq_rw_matrix_enumeration():
    full = eemarq_rw_matrix()
    # 2 structures x 2 mixes x 2 scan sizes x 2 txn sizes x 2 interval
    # counts x 2 zipfs x 5 schemes
    assert len(full) == 2 * len(EEMARQ_RW_MIXES) * 2 * 2 * 2 * 2 * 5
    assert {c.ds for c in full} == {"hash", "tree"}
    assert all(c.op_mix.rwtxn_frac > 0 for c in full)
    assert {c.op_mix.txn_size for c in full} == {2, 8}
    assert {c.op_mix.txn_ranges for c in full} == {2, 4}
    assert all(c.op_mix.txn_point_reads == 2 for c in full)
    assert {round(c.op_mix.rw_ratio, 2) for c in full} == {0.5, 0.75}
    sub = eemarq_rw_matrix(structures=("tree",), scan_sizes=(16,),
                           txn_sizes=(4,), txn_ranges=(1,), zipfs=(0.99,),
                           schemes=("ebr", "dlrt"))
    assert len(sub) == 1 * 2 * 1 * 1 * 1 * 1 * 2
    assert all(c.mode == "mixed" for c in sub)


# ---------------------------------------------------------------------------
# check_txn must be falsifiable
# ---------------------------------------------------------------------------
def test_check_txn_catches_corruption():
    env, scheme, ds = _mk("hash", "slrt")
    log = UpdateLog()
    for k in range(1, 6):
        _upd(env, scheme, ds, log, 0, k, k)
    txn = Txn(1, ds, env, scheme, log=log)
    txn.range_query(1, 6)
    txn.put(2, 22)
    assert txn.try_commit()
    # tamper: pretend the txn wrote a value the log never saw
    txn.writes[2] = 23
    v = ScanValidator(log)
    assert not v.check_txn(txn)
    assert v.txn_violations == 1 and v.examples
    # tamper: a scan result inconsistent with the snapshot
    txn2 = Txn(1, ds, env, scheme, log=log)
    txn2.range_query(1, 6)
    txn2.scan_footprint[0] = (1, 6, [(1, 999)])
    txn2.try_commit()
    v2 = ScanValidator(log)
    assert not v2.check_txn(txn2)
    assert v2.violations >= 1
