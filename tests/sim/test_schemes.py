"""Scheme-level tests: snapshot correctness under every GC scheme (GC must
never reclaim a needed version), quiescent cleanup, and the paper's
qualitative space ordering on adversarial workloads."""
import random

import pytest

from repro.core.sim.mvhash import MVHashTable
from repro.core.sim.mvtree import MVTree
from repro.core.sim.schemes import SCHEMES, make_scheme
from repro.core.sim.ssl_list import MVEnv
from repro.core.sim.workload import WorkloadConfig, measure_space, run_workload

ALL = list(SCHEMES)


@pytest.mark.parametrize("scheme_name", ALL)
@pytest.mark.parametrize("ds_kind", ["hash", "tree"])
def test_snapshot_reads_correct_under_gc(scheme_name, ds_kind):
    """Shadow-validated rtx reads: interleave updates with long-running rtxs;
    every rtx read at timestamp t must equal the shadow state at t.  This
    fails if a scheme ever reclaims a needed version."""
    rng = random.Random(42)
    env = MVEnv(4)
    scheme = make_scheme(scheme_name, env, **({"batch_size": 4}
                         if scheme_name in ("dlrt", "slrt", "bbf") else {}))
    ds = MVHashTable(env, scheme, 64) if ds_kind == "hash" else MVTree(env, scheme)

    shadow = {}                 # key -> list of (ts, val_or_None)
    def record(k, v):
        shadow.setdefault(k, []).append((env.read_ts(), v))

    def shadow_at(k, t):
        best = None
        for ts, v in shadow.get(k, []):
            if ts <= t:
                best = v
        return best

    def do_update(pid):
        ctx = scheme.begin_update(pid)
        env.advance_ts()
        k = rng.randint(1, 40)
        if rng.random() < 0.6:
            v = rng.randrange(10_000)
            ds.insert(pid, k, v)
            record(k, v)
        else:
            ds.delete(pid, k)
            record(k, None)
        scheme.end_update(pid, ctx)

    # prefill
    for _ in range(30):
        do_update(0)

    # interleave: start rtx on pid 3, do updates on pids 0-2, read mid-rtx
    for round_ in range(60):
        t = scheme.begin_rtx(3)
        keys = [rng.randint(1, 40) for _ in range(6)]
        expected = {k: shadow_at(k, t) for k in keys}
        for _ in range(rng.randint(1, 12)):
            do_update(rng.randrange(3))
        for k in keys:
            if ds_kind == "hash":
                got = ds.rtx_lookup(3, k, t)
            else:
                res = dict(ds.range_query(3, k, k + 1, t))
                got = res.get(k)
            assert got == expected[k], (
                f"{scheme_name}/{ds_kind}: snapshot read at t={t} key={k} "
                f"got {got}, expected {expected[k]} (GC reclaimed a needed version?)"
            )
        scheme.end_rtx(3)


@pytest.mark.parametrize("scheme_name", ALL)
def test_quiescent_cleanup(scheme_name):
    """After quiescence every list holds exactly its current version."""
    cfg = WorkloadConfig(
        ds="hash", scheme=scheme_name, n_keys=128, num_procs=9,
        ops_per_proc=40, mode="split", sample_every=512, seed=11,
    )
    r = run_workload(cfg)
    assert r["end_space"]["versions_per_list"] <= 1.0 + 1e-9
    # the GC actually freed things during the run
    assert r["end_space"]["words"] <= r["peak_space"]["words"]


def test_space_bound_L_R_P_all_lists():
    """Paper §3: PDL/SSL keep at most L-R+P reachable nodes per execution."""
    for scheme_name in ("dlrt", "slrt"):
        cfg = WorkloadConfig(
            ds="hash", scheme=scheme_name, n_keys=128, num_procs=9,
            ops_per_proc=60, mode="split", sample_every=2048, seed=5,
        )
        r = run_workload(cfg)
        env_P = cfg.num_procs
        # after quiesce: reachable == L - R (every obsolete version collected)
        s = r["end_space"]
        assert s["versions"] <= s["lists"] * 1 + env_P


def test_ebr_blows_up_with_long_rtxs():
    """Paper §6.2: EBR space degrades badly with long rtxs + updates, while
    the RT-based schemes stay bounded."""
    def peak(scheme):
        kw = {"batch_size": 8} if scheme in ("slrt", "dlrt", "bbf") else {}
        cfg = WorkloadConfig(
            ds="hash", scheme=scheme, n_keys=64, num_procs=9,
            ops_per_proc=400, mode="split", scan_size=512,
            variable_scan_max=512, zipf=0.99, sample_every=64, seed=7,
            # scans clamp to the 128-key range; chunk=2 keeps each scan
            # pinned across ~64 slices (the long-rtx dynamic under test)
            scan_chunk=2,
            scheme_kwargs=kw,
        )
        return run_workload(cfg)["peak_space"]["versions"]

    ebr, slrt = peak("ebr"), peak("slrt")
    assert ebr > 1.5 * slrt, f"expected EBR({ebr}) >> SL-RT({slrt}) under long rtxs"


def test_scheme_factory():
    env = MVEnv(2)
    for name in ALL:
        s = make_scheme(name, env)
        assert s.name == name
        lst = s.new_list()
        n = s.new_node(1, "x")
        assert lst.try_append(lst.head, n)
