"""PDL (Algorithm 1) tests: sequential semantics, concurrent invariants under
random interleavings, linearizability (Wing-Gong), and the L-R+P space bound."""
import math
import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.sim.machine import Scheduler
from repro.core.sim.pdl import PDL, Node
from repro.core.sim.linearize import check_linearizable


def drain(gen):
    """Run a stepped op to completion standalone; return its value."""
    try:
        while True:
            next(gen)
    except StopIteration as s:
        return s.value


class TestSequential:
    def test_append_search_peek(self):
        l = PDL()
        n1, n2, n3 = Node(1, "a"), Node(3, "b"), Node(3, "c")
        assert drain(l.tryAppend_steps(l.head, n1))
        assert drain(l.tryAppend_steps(n1, n2))
        assert drain(l.tryAppend_steps(n2, n3))
        assert drain(l.peekHead_steps()) == "c"
        assert drain(l.search_steps(0)) is None        # sentinel val
        assert drain(l.search_steps(1)) == "a"
        assert drain(l.search_steps(2)) == "a"
        assert drain(l.search_steps(3)) == "c"          # latest with key<=3
        assert drain(l.search_steps(99)) == "c"

    def test_failed_append(self):
        l = PDL()
        n1, n2 = Node(1, "a"), Node(2, "b")
        assert drain(l.tryAppend_steps(l.head, n1))
        # stale head -> fail
        assert not drain(l.tryAppend_steps(l.sentinel, n2))
        assert l.head is n1

    def test_remove_middle(self):
        l = PDL()
        ns = [Node(i, i) for i in range(1, 6)]
        prev = l.head
        for n in ns:
            assert drain(l.tryAppend_steps(prev, n))
            prev = n
        drain(l.remove_steps(ns[2]))  # remove key 3
        al = l.abstract_list()
        assert [n.key for n in al[1:]] == [1, 2, 4, 5]
        assert drain(l.search_steps(3)) == 2
        l.check_invariant2()
        l.check_al_sorted()

    def test_remove_all_but_last(self):
        l = PDL()
        ns = [Node(i, i) for i in range(1, 8)]
        prev = l.head
        for n in ns:
            assert drain(l.tryAppend_steps(prev, n))
            prev = n
        for n in ns[:-1]:
            drain(l.remove_steps(n))
            l.check_invariant2()
        assert [n.key for n in l.abstract_list()[1:]] == [7]
        # paper bound: L - R + P reachable at quiescence (P=1 here)
        assert l.reachable_count() <= l.appends - l.removes_completed + 1


def _concurrent_world(seed, n_appenders, n_removers, n_searchers):
    """Random concurrent scenario with preconditions enforced.
    Returns (list, scheduler, initial_AL) — initial_AL excludes the sentinel."""
    rng = random.Random(seed)
    l = PDL()
    # build a base list sequentially so removers have targets
    base = [Node(i * 2, f"v{i}") for i in range(1, n_removers + 2)]
    prev = l.head
    for n in base:
        assert drain(l.tryAppend_steps(prev, n))
        prev = n
    sched = Scheduler(seed=seed)
    # invariant hooks run after every atomic step
    sched.invariant_hooks.append(l.check_invariant2)
    sched.invariant_hooks.append(l.check_al_sorted)

    # removers target distinct non-head base nodes (all have successors)
    targets = base[:-1]
    rng.shuffle(targets)
    for i in range(n_removers):
        sched.spawn("remove", l.remove_steps(targets[i]), (targets[i],))
    # appenders chain from the current head (some will fail -> fine)
    hk = base[-1].key
    for i in range(n_appenders):
        y = Node(hk + i + 1, f"new{i}")
        sched.spawn("tryAppend", l.tryAppend_steps(l.head, y), (l.head, y))
    for i in range(n_searchers):
        k = rng.choice([n.key for n in base] + [hk + 1, 0])
        sched.spawn("search", l.search_steps(k), (k,))
    return l, sched, tuple(base)


@settings(max_examples=60, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n_app=st.integers(0, 3),
    n_rem=st.integers(1, 4),
    n_sea=st.integers(0, 3),
)
def test_concurrent_invariants_random_schedules(seed, n_app, n_rem, n_sea):
    l, sched, _base = _concurrent_world(seed, n_app, n_rem, n_sea)
    sched.run_random()
    # all removers finished: their targets are out of AL (Lemma 7)
    al = set(id(n) for n in l.abstract_list())
    for opid, op in sched.ops.items():
        if op.name == "remove":
            assert id(op.args[0]) not in al
    # space bound: L - R + P with P = #ops (conservative upper bound)
    P = len(sched.ops)
    assert l.reachable_count() <= l.appends - l.removes_completed + P


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_linearizability_small_histories(seed):
    l, sched, base = _concurrent_world(seed, 2, 2, 2)
    sched.run_random()
    assert check_linearizable(sched.history, l.sentinel, initial_state=base), (
        "non-linearizable PDL history found"
    )


def test_linearizability_rejects_bad_history():
    """Sanity: the checker must reject an impossible history."""
    from repro.core.sim.machine import Event

    l = PDL()
    n1 = Node(1, "a")
    # search returns 'a' before any append is invoked -> impossible
    h = [
        Event("inv", 0, "search", (1,), None, 0),
        Event("res", 0, "search", (1,), "a", 1),
        Event("inv", 1, "tryAppend", (l.sentinel, n1), None, 2),
        Event("res", 1, "tryAppend", (l.sentinel, n1), True, 3),
    ]
    assert not check_linearizable(h, l.sentinel)


def test_remove_chain_stat_small():
    """Average removal chain length c stays ~1 under light contention
    (the paper observed c <= 1.01 across workloads)."""
    rng = random.Random(0)
    l = PDL()
    prev = l.head
    nodes = []
    for i in range(1, 101):
        n = Node(i, i)
        assert drain(l.tryAppend_steps(prev, n))
        nodes.append(n)
        prev = n
    sched = Scheduler(seed=7)
    # remove 50 random distinct non-head nodes concurrently
    for n in rng.sample(nodes[:-1], 50):
        sched.spawn("remove", l.remove_steps(n), (n,))
    sched.run_random()
    assert l.avg_remove_chain() < 3.0  # adjacent-marked chains stay short
    assert l.reachable_count() == 100 - 50
