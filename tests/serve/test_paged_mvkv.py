"""Paged MVKV tests: COW page-table versioning, snapshot isolation at page
granularity, page recycling via the reachability sweep, the kernel
integration (snapshot_view -> paged_decode), and property tests over random
decode/fork/pin/unpin/pressure interleavings (reachability soundness +
pinned-snapshot stability across forced reclaims)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as hst

jax.config.update("jax_platform_name", "cpu")

from repro.mvkv import paged
from repro.kernels.decode_attention.ref import paged_decode_ref


def mk(num_seqs=2, num_pages=16, page_size=4, mp=4, hkv=2, hd=8, V=8):
    return paged.make_paged_kv(num_seqs, num_pages, page_size, mp, hkv, hd,
                               versions_per_seq=V, dtype=jnp.float32)


def step(st, toks_val, mask=None, policy="slrt"):
    B = 2
    ids = jnp.arange(B, dtype=jnp.int32)
    k = jnp.full((B, 2, 8), float(toks_val), jnp.float32)
    v = jnp.full((B, 2, 8), float(toks_val), jnp.float32)
    m = jnp.ones((B,), bool) if mask is None else mask
    st, ovf = paged.append_tokens(st, ids, k, v, m, gc_policy=policy)
    assert not bool(ovf.any()), "unexpected overflow"
    return st


def test_append_and_current_view():
    st = mk()
    for i in range(6):           # crosses a page boundary at 4
        st = step(st, i)
    ids = jnp.arange(2, dtype=jnp.int32)
    tables, lengths = paged.snapshot_view(st, ids, st.mv.now)
    assert list(lengths) == [6, 6]
    # two pages referenced per sequence
    assert int((tables[0] >= 0).sum()) == 2
    # pool accounting: 4 pages in use (2 seqs x 2 pages)
    assert int(paged.live_pages(st)) >= 4


def test_snapshot_sees_old_pages_under_writes():
    st = mk(V=16)
    for i in range(4):
        st = step(st, i)
    st, t = paged.begin_snapshot(st, jnp.int32(0))
    ids = jnp.arange(2, dtype=jnp.int32)
    tbl0, len0 = paged.snapshot_view(st, ids, t)
    assert list(len0) == [4, 4]
    for i in range(4, 12):       # two more pages of writes
        st = step(st, i)
    tbl1, len1 = paged.snapshot_view(st, ids, t)
    np.testing.assert_array_equal(np.asarray(tbl0), np.asarray(tbl1),
                                  "pinned snapshot's page table changed")
    np.testing.assert_array_equal(np.asarray(len0), np.asarray(len1))
    # and the pinned pages still hold the old token values
    page0 = int(tbl1[0, 0])
    assert float(st.k_pages[page0, 0, 0, 0]) == 0.0
    st = paged.end_snapshot(st, jnp.int32(0))


def test_kernel_integration_snapshot_decode():
    """snapshot_view output drives the paged flash-decode reference."""
    st = mk()
    for i in range(6):
        st = step(st, i)
    ids = jnp.arange(2, dtype=jnp.int32)
    tables, lengths = paged.snapshot_view(st, ids, st.mv.now)
    q = jnp.ones((2, 4, 8), jnp.float32)  # Hq=4, G=2 over Hkv=2
    out = paged_decode_ref(q, st.k_pages, st.v_pages,
                           jnp.maximum(tables, 0), lengths)
    assert out.shape == (2, 4, 8)
    assert bool(jnp.isfinite(out).all())


def test_pages_recycle_after_gc():
    """Old page-table versions collected under pressure release their pages.

    The serving path runs no per-append cadence GC (reclamation is
    pressure-driven), so stale versions pile up until the reclaim pass —
    which must then drop live pages back to exactly the current tables'
    footprint."""
    st = mk(num_pages=32, V=16)
    for i in range(16):          # 4 page boundaries per sequence
        st = step(st, i)
    st, freed = paged.reclaim_on_pressure(
        st, paged.hot_sequences(st, 2), jnp.int32(10 ** 9),
        gc_policy="slrt")
    assert int(freed) == 0, "append-only history shares all its pages"
    # no pins: after the reclaim, only the current table version per seq is
    # live, so live pages == pages referenced by the two current tables
    ids = jnp.arange(2, dtype=jnp.int32)
    tables, lengths = paged.snapshot_view(st, ids, st.mv.now)
    referenced = int((tables >= 0).sum())
    assert int(paged.live_pages(st)) == referenced, (
        f"live {int(paged.live_pages(st))} != referenced {referenced}: "
        "unreferenced pages not recycled")


def test_pinned_snapshot_blocks_page_recycling():
    st = mk(num_pages=32, mp=8, V=16)
    for i in range(4):
        st = step(st, i)
    st, t = paged.begin_snapshot(st, jnp.int32(1))
    for i in range(4, 16):
        st = step(st, i)
    # pinned tables keep their pages alive
    ids = jnp.arange(2, dtype=jnp.int32)
    tbl_pin, _ = paged.snapshot_view(st, ids, t)
    for p in np.asarray(tbl_pin).reshape(-1):
        if p >= 0:
            assert not bool(st.free[int(p)]), f"pinned page {p} was recycled!"
    st = paged.end_snapshot(st, jnp.int32(1))
    st, _ = paged.reclaim_on_pressure(
        st, paged.hot_sequences(st, 2), jnp.int32(10 ** 9),
        gc_policy="slrt")
    # after unpin + a forced reclaim the old pages may free; at minimum the
    # current tables' pages stay live
    tables, _ = paged.snapshot_view(st, ids, st.mv.now)
    for p in np.asarray(tables).reshape(-1):
        if p >= 0:
            assert not bool(st.free[int(p)])


# ---------------------------------------------------------------------------
# Property tests: random decode/fork/pin/unpin/pressure interleavings
# ---------------------------------------------------------------------------
from repro.core.mvgc.pool import EMPTY  # noqa: E402
from repro.serve.engine import PagedKVEngine  # noqa: E402

PROP_B, PROP_PS, PROP_MP = 3, 2, 3


def _mk_engine(policy: str) -> PagedKVEngine:
    return PagedKVEngine(PROP_B, 12, PROP_PS, PROP_MP, 1, 4,
                         versions_per_seq=5, reader_lanes=2,
                         gc_policy=policy, dtype=jnp.float32)


def _check_reachability(eng: PagedKVEngine) -> None:
    """Soundness of the sweep: no page (or table slot) referenced by a table
    version that a live descriptor version can still reach may sit in the
    free pool — freeing one would hand a reader's page to another writer."""
    st = eng.st
    ts = np.asarray(st.mv.store.ts).reshape(-1)
    pay = np.asarray(st.mv.store.payload).reshape(-1)
    tables = np.asarray(st.tables)
    table_free = np.asarray(st.table_free)
    page_free = np.asarray(st.free)
    for tbl_slot in pay[ts != EMPTY]:
        assert not table_free[tbl_slot], (
            f"table slot {tbl_slot} is referenced by a live descriptor "
            f"version but sits in the free pool")
        for p in tables[tbl_slot]:
            if p >= 0:
                assert not page_free[p], (
                    f"page {p} is reachable via table version {tbl_slot} "
                    f"but sits in the free bitmap")


def _view_sig(eng: PagedKVEngine, t: int) -> tuple:
    """Exact content signature of the snapshot view at t: per sequence, the
    visible length and every visible K value (catches both a mutated table
    row and a recycled-then-overwritten page)."""
    tbl, ln = eng.view_at(t)
    tbl, ln = np.asarray(tbl), np.asarray(ln)
    k = np.asarray(eng.st.k_pages)[:, :, 0, 0]
    out = []
    for s in range(tbl.shape[0]):
        n = int(ln[s])
        out.append((n, tuple(
            float(k[int(tbl[s, j // PROP_PS]), j % PROP_PS])
            for j in range(n))))
    return tuple(out)


def _force_reclaim(eng: PagedKVEngine) -> None:
    eng.st, _ = paged.reclaim_on_pressure(
        eng.st, paged.hot_sequences(eng.st, PROP_B), jnp.int32(10 ** 9),
        gc_policy=eng.gc_policy)


@settings(max_examples=4, deadline=None)
@given(data=hst.data(), policy=hst.sampled_from(["ebr", "steam", "slrt"]))
def test_random_interleaving_reachability_and_pins(data, policy):
    """Random decode/fork/reset/pin/unpin/pressure interleavings preserve
    (a) reachability soundness after *every* operation, (b) byte-exact
    pinned-snapshot views — including across forced reclaims — and (c) the
    freed_pages() contract (drained handles are free at drain time)."""
    eng = _mk_engine(policy)
    seq_ids = jnp.arange(PROP_B, dtype=jnp.int32)
    pins = {}          # lane -> (ts, reference signature)
    token = 0.0
    steps = data.draw(hst.integers(12, 24))
    for _ in range(steps):
        op = data.draw(hst.sampled_from(
            ["step", "step", "step", "fork", "reset", "pin", "unpin",
             "pressure"]))
        if op == "step":
            token += 1.0
            base = np.arange(PROP_B, dtype=np.float32) + PROP_B * token
            kv = jnp.asarray(np.broadcast_to(
                base[:, None, None], (PROP_B, 1, 4)))
            m = jnp.asarray(np.array(
                [data.draw(hst.booleans()) for _ in range(PROP_B)]))
            eng.step(seq_ids, kv, kv, m)
        elif op == "fork":
            src = data.draw(hst.integers(0, PROP_B - 1))
            dst = data.draw(hst.integers(0, PROP_B - 1))
            if src != dst:
                eng.fork(jnp.array([src], jnp.int32),
                         jnp.array([dst], jnp.int32), jnp.array([True]))
        elif op == "reset":
            s = data.draw(hst.integers(0, PROP_B - 1))
            m = np.zeros(PROP_B, bool)
            m[s] = True
            eng.reset(seq_ids, jnp.asarray(m))
        elif op == "pin":
            lane = data.draw(hst.integers(0, 1))
            if lane not in pins:
                t = eng.pin(lane)
                pins[lane] = (t, _view_sig(eng, t))
        elif op == "unpin":
            if pins:
                lane = sorted(pins)[0]
                eng.unpin(lane)
                del pins[lane]
        else:
            _force_reclaim(eng)
        # (c) freed handles name genuinely-free pages at drain time
        free_now = np.asarray(eng.st.free)
        for h in eng.freed_pages():
            assert free_now[h], f"freed_pages() handed out live page {h}"
        # (a) sweep soundness after every single operation
        _check_reachability(eng)
        # (b) pinned views resolve byte-identically, reclaims included
        for lane, (t, ref) in pins.items():
            assert _view_sig(eng, t) == ref, (
                f"pinned snapshot at t={t} drifted after {op} "
                f"(policy {policy})")
    for lane in list(pins):
        eng.unpin(lane)


@settings(max_examples=3, deadline=None)
@given(data=hst.data())
def test_pinned_view_survives_forced_reclaim_storm(data):
    """A pin taken mid-decode stays byte-stable through a storm of resets
    and back-to-back forced reclaims (the harshest recycling pressure),
    then releases its pages after unpin + one more reclaim."""
    eng = _mk_engine("slrt")
    seq_ids = jnp.arange(PROP_B, dtype=jnp.int32)
    all_m = jnp.ones((PROP_B,), bool)
    for i in range(1, 5):
        kv = jnp.full((PROP_B, 1, 4), float(i), jnp.float32)
        eng.step(seq_ids, kv, kv, all_m)
    lane = data.draw(hst.integers(0, 1))
    t = eng.pin(lane)
    ref = _view_sig(eng, t)
    live_at_pin = int(paged.live_pages(eng.st))
    for i in range(5, 5 + data.draw(hst.integers(3, 8))):
        kv = jnp.full((PROP_B, 1, 4), float(i), jnp.float32)
        eng.step(seq_ids, kv, kv, all_m)
        eng.reset(seq_ids, all_m)
        _force_reclaim(eng)
        _check_reachability(eng)
        assert _view_sig(eng, t) == ref, "pinned view drifted mid-storm"
    eng.unpin(lane)
    _force_reclaim(eng)
    _check_reachability(eng)
    # with the pin gone the pre-pin pages are collectable: live pages must
    # drop strictly below the pinned plateau (everything reset + reclaimed)
    assert int(paged.live_pages(eng.st)) < live_at_pin
