"""Paged MVKV tests: COW page-table versioning, snapshot isolation at page
granularity, page recycling via the reachability sweep, and the kernel
integration (snapshot_view -> paged_decode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from repro.mvkv import paged
from repro.kernels.decode_attention.ref import paged_decode_ref


def mk(num_seqs=2, num_pages=16, page_size=4, mp=4, hkv=2, hd=8, V=8):
    return paged.make_paged_kv(num_seqs, num_pages, page_size, mp, hkv, hd,
                               versions_per_seq=V, dtype=jnp.float32)


def step(st, toks_val, mask=None, policy="slrt"):
    B = 2
    ids = jnp.arange(B, dtype=jnp.int32)
    k = jnp.full((B, 2, 8), float(toks_val), jnp.float32)
    v = jnp.full((B, 2, 8), float(toks_val), jnp.float32)
    m = jnp.ones((B,), bool) if mask is None else mask
    st, ovf = paged.append_tokens(st, ids, k, v, m, gc_policy=policy)
    assert not bool(ovf.any()), "unexpected overflow"
    return st


def test_append_and_current_view():
    st = mk()
    for i in range(6):           # crosses a page boundary at 4
        st = step(st, i)
    ids = jnp.arange(2, dtype=jnp.int32)
    tables, lengths = paged.snapshot_view(st, ids, st.mv.now)
    assert list(lengths) == [6, 6]
    # two pages referenced per sequence
    assert int((tables[0] >= 0).sum()) == 2
    # pool accounting: 4 pages in use (2 seqs x 2 pages)
    assert int(paged.live_pages(st)) >= 4


def test_snapshot_sees_old_pages_under_writes():
    st = mk(V=16)
    for i in range(4):
        st = step(st, i)
    st, t = paged.begin_snapshot(st, jnp.int32(0))
    ids = jnp.arange(2, dtype=jnp.int32)
    tbl0, len0 = paged.snapshot_view(st, ids, t)
    assert list(len0) == [4, 4]
    for i in range(4, 12):       # two more pages of writes
        st = step(st, i)
    tbl1, len1 = paged.snapshot_view(st, ids, t)
    np.testing.assert_array_equal(np.asarray(tbl0), np.asarray(tbl1),
                                  "pinned snapshot's page table changed")
    np.testing.assert_array_equal(np.asarray(len0), np.asarray(len1))
    # and the pinned pages still hold the old token values
    page0 = int(tbl1[0, 0])
    assert float(st.k_pages[page0, 0, 0, 0]) == 0.0
    st = paged.end_snapshot(st, jnp.int32(0))


def test_kernel_integration_snapshot_decode():
    """snapshot_view output drives the paged flash-decode reference."""
    st = mk()
    for i in range(6):
        st = step(st, i)
    ids = jnp.arange(2, dtype=jnp.int32)
    tables, lengths = paged.snapshot_view(st, ids, st.mv.now)
    q = jnp.ones((2, 4, 8), jnp.float32)  # Hq=4, G=2 over Hkv=2
    out = paged_decode_ref(q, st.k_pages, st.v_pages,
                           jnp.maximum(tables, 0), lengths)
    assert out.shape == (2, 4, 8)
    assert bool(jnp.isfinite(out).all())


def test_pages_recycle_after_gc():
    """Old page-table versions collected by SL-RT release their pages."""
    st = mk(num_pages=32, V=16)
    for i in range(16):          # 4 page boundaries per sequence
        st = step(st, i)
    # no pins: after GC, only the current table version per seq is live,
    # so live pages == pages referenced by the two current tables
    ids = jnp.arange(2, dtype=jnp.int32)
    tables, lengths = paged.snapshot_view(st, ids, st.mv.now)
    referenced = int((tables >= 0).sum())
    assert int(paged.live_pages(st)) == referenced, (
        f"live {int(paged.live_pages(st))} != referenced {referenced}: "
        "unreferenced pages not recycled")


def test_pinned_snapshot_blocks_page_recycling():
    st = mk(num_pages=32, mp=8, V=16)
    for i in range(4):
        st = step(st, i)
    st, t = paged.begin_snapshot(st, jnp.int32(1))
    for i in range(4, 16):
        st = step(st, i)
    # pinned tables keep their pages alive
    ids = jnp.arange(2, dtype=jnp.int32)
    tbl_pin, _ = paged.snapshot_view(st, ids, t)
    for p in np.asarray(tbl_pin).reshape(-1):
        if p >= 0:
            assert not bool(st.free[int(p)]), f"pinned page {p} was recycled!"
    st = paged.end_snapshot(st, jnp.int32(1))
    st = step(st, 99)            # GC runs inside
    # after unpin + another step the old pages may free; at minimum the
    # current tables' pages stay live
    tables, _ = paged.snapshot_view(st, ids, st.mv.now)
    for p in np.asarray(tables).reshape(-1):
        if p >= 0:
            assert not bool(st.free[int(p)])
