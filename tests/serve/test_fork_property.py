"""Property tests for the fork-DAG lifecycle (DESIGN.md §14): random
fork/append/join/release/reclaim interleavings over `PagedKVEngine` must
never leak a page (host-recomputed refcounts agree with the refcount-free
reachability sweep: a page is free iff no live table version references it),
never free a reachable page, and never perturb a byte of any live child's
inherited prefix.  Runs on the vendored mini-hypothesis when the real
package is absent (tests/conftest.py)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as hst

jax.config.update("jax_platform_name", "cpu")

from repro.core.telemetry import GCConfig
from repro.serve import forking
from repro.serve.engine import PagedKVEngine

B, PAGES, PS, MP, V = 4, 14, 2, 3, 5
NOW = 2**31 - 2


def _mk(policy):
    return PagedKVEngine(B, PAGES, PS, MP, 1, 4,
                         gc=GCConfig(policy=policy, versions_per_slot=V,
                                     reader_lanes=2),
                         dtype=jnp.float32)


class _Model:
    """Host-side mirror of the engine: which slots are live, the lineage
    DAG the engine should be maintaining, and the prefix obligations."""

    def __init__(self, policy):
        self.eng = _mk(policy)
        self.live = {0}                    # slot 0 seeded with one token
        self.validator = forking.ForkValidator()
        self.token = 0.0
        self._append([0])

    def _views(self):
        tbl, ln = self.eng.view_at(NOW)
        return np.asarray(tbl), np.asarray(ln)

    def _append(self, slots):
        self.token += 1.0
        mask = np.zeros((B,), bool)
        for s in slots:
            mask[s] = True
        base = np.arange(B, dtype=np.float32) + B * self.token
        kv = jnp.asarray(np.broadcast_to(base[:, None, None], (B, 1, 4)))
        failed = np.asarray(self.eng.step(
            jnp.arange(B, dtype=jnp.int32), kv, kv, jnp.asarray(mask)))
        return [s for s in slots if not failed[s]]

    def append(self, slots):
        self._append([s for s in slots if s in self.live])

    def fork(self, parent, child):
        if parent not in self.live or child in self.live:
            return
        failed = np.asarray(self.eng.fork(
            jnp.asarray([parent], jnp.int32), jnp.asarray([child], jnp.int32),
            jnp.ones((1,), bool)))
        if not failed[0]:
            self.live.add(child)
            tbl, ln = self._views()
            self.validator.note_fork(self.eng.st, child, tbl[child],
                                     int(ln[child]))

    def join(self, child, target):
        if child not in self.live or target not in self.live or \
                child == target:
            return
        failed = np.asarray(self.eng.join(
            jnp.asarray([child], jnp.int32), jnp.asarray([target], jnp.int32),
            jnp.ones((1,), bool)))
        if not failed[0]:
            self.live.discard(child)
            self.validator.drop(child)
            # the target's content changed wholesale: it took the child's
            # prefix obligation (the child's bytes now live under target)
            self.validator.drop(target)

    def release(self, slot):
        if slot not in self.live or len(self.live) == 1:
            return
        failed = np.asarray(self.eng.release(
            jnp.asarray([slot], jnp.int32), jnp.ones((1,), bool)))
        if not failed[0]:
            self.live.discard(slot)
            self.validator.drop(slot)

    def reclaim(self):
        self.eng.reclaim(PAGES)

    def check(self):
        ok, leaked, premature = forking.check_no_leak(self.eng.st)
        assert ok, (f"leaked={leaked.tolist()} "
                    f"premature={premature.tolist()}")
        # drained freed handles must be free at drain time
        free_now = np.asarray(self.eng.st.free)
        for h in self.eng.freed_pages():
            assert free_now[h], f"freed_pages() handed out live page {h}"
        # every live child's inherited prefix is byte-stable
        tbl, ln = self._views()
        for s in sorted(self.live):
            assert self.validator.check(self.eng.st, s, tbl[s], int(ln[s])), \
                self.validator.examples
        # DAG bookkeeping matches the model
        assert set(self.eng.dag.nodes) <= self.live
        for s in self.eng.dag.nodes:
            assert s not in self.eng.dag.ancestors(s)   # acyclic


@settings(max_examples=5, deadline=None)
@given(data=hst.data(),
       policy=hst.sampled_from(["ebr", "steam", "dlrt", "slrt"]))
def test_random_fork_interleavings_never_leak_or_free_reachable(data, policy):
    m = _Model(policy)
    ops = data.draw(hst.integers(15, 30))
    for _ in range(ops):
        op = data.draw(hst.sampled_from(
            ["append", "append", "fork", "fork", "join", "release",
             "reclaim"]))
        if op == "append":
            k = data.draw(hst.integers(1, B))
            m.append(sorted(m.live)[:k])
        elif op == "fork":
            frees = sorted(set(range(B)) - m.live)
            if frees:
                m.fork(data.draw(hst.sampled_from(sorted(m.live))),
                       data.draw(hst.sampled_from(frees)))
        elif op == "join":
            if len(m.live) > 1:
                pair = sorted(m.live)
                m.join(data.draw(hst.sampled_from(pair)),
                       data.draw(hst.sampled_from(pair)))
        elif op == "release":
            m.release(data.draw(hst.sampled_from(sorted(m.live))))
        else:
            m.reclaim()
        m.check()
    assert m.validator.violations == 0
    assert m.eng.forks >= m.eng.joins


@settings(max_examples=3, deadline=None)
@given(data=hst.data())
def test_deep_fork_chains_share_then_free(data):
    """A chain root -> c1 -> c2 -> ... shares the root prefix page all the
    way down; releasing the whole chain (in random order) returns every
    page — end live pages equals what the surviving root alone references."""
    m = _Model("slrt")
    for _ in range(PS * 2):                    # root owns 2 full pages
        m.append([0])
    chain = []
    for child in range(1, B):
        parent = chain[-1] if chain else 0
        m.fork(parent, child)
        chain.append(child)
        m.append([child])
        m.check()
    assert forking.shared_page_count(m.eng.st) > 0
    order = list(chain)
    while order:
        i = data.draw(hst.integers(0, len(order) - 1))
        m.release(order.pop(i))
        m.check()
    m.reclaim()
    m.check()
    refs = forking.page_refcounts(m.eng.st)
    live = int((~np.asarray(m.eng.st.free)).sum())
    assert live == int((refs > 0).sum())
