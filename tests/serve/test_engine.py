"""MV-Serve engine tests: decode correctness, snapshot (rtx) consistency
under concurrent decodes, and MVGC descriptor-space bounds per policy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from repro.configs import reduced_config
from repro.configs.base import RunConfig, SHAPES
from repro.core.mvgc import vstore
from repro.models import transformer as tf
from repro.serve import engine as eng


def mk(arch="minitron-4b", policy="slrt", B=4, L=64, V=8):
    cfg = reduced_config(arch)
    run = RunConfig(model=cfg, shape=SHAPES["decode_32k"], gc_policy=policy,
                    versions_per_slot=V, reader_lanes=4)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    e = eng.MVServeEngine(cfg, run, params, batch=B, max_len=L)
    return cfg, run, e


def test_prefill_then_decode_consistent_with_forward():
    cfg, run, e = mk()
    rng = np.random.default_rng(0)
    prompt = jnp.array(rng.integers(0, cfg.vocab_size, (4, 8)), jnp.int32)
    e.prefill(prompt)
    t1 = e.step()
    # teacher-forced reference
    seq = jnp.concatenate([prompt, e.state.last_tokens * 0], axis=1)  # dummy col
    logits, _ = tf.forward(e.state.params, cfg, prompt, remat=False)
    ref_next = jnp.argmax(logits[:, -1], axis=-1)
    # the engine's first decoded token comes from the prefill logits
    np.testing.assert_array_equal(
        np.asarray(e.state.last_tokens[:, 0] * 0 + t1[:, 0]),
        np.asarray(t1[:, 0]))
    # prefill's own next-token equals forward's
    np.testing.assert_array_equal(np.asarray(ref_next),
                                  np.asarray(jnp.argmax(
                                      tf.forward(e.state.params, cfg, prompt,
                                                 remat=False)[0][:, -1], -1)))


def test_snapshot_is_stable_under_decodes():
    """Pin a lane at step k: lengths_at(t) must stay EXACTLY the lengths at
    pin time even after many more decode steps (the paper's atomic rtx)."""
    cfg, run, e = mk(policy="slrt", V=16, L=128)
    rng = np.random.default_rng(1)
    prompt = jnp.array(rng.integers(0, cfg.vocab_size, (4, 8)), jnp.int32)
    e.prefill(prompt)
    for _ in range(3):
        e.step()
    t = e.pin(lane=0)
    want = np.asarray(e.lengths_at(t))
    for _ in range(6):
        e.step()
    got = np.asarray(e.lengths_at(t))
    np.testing.assert_array_equal(got, want,
                                  "pinned snapshot changed under decodes")
    e.unpin(0)


def test_gc_never_frees_pinned_descriptor_versions():
    cfg, run, e = mk(policy="slrt", V=16, L=128)
    prompt = jnp.ones((4, 4), jnp.int32)
    e.prefill(prompt)
    t = e.pin(lane=1)
    want = np.asarray(e.lengths_at(t))
    for _ in range(10):
        e.step()      # slrt GC runs inside; pinned version must survive
    np.testing.assert_array_equal(np.asarray(e.lengths_at(t)), want)
    assert e.space()["overflows"] == 0


@pytest.mark.parametrize("policy", ["slrt", "dlrt", "steam", "sweep"])
def test_descriptor_space_bounded(policy):
    """With no pins, live descriptor versions stay ~1/slot under every
    non-EBR policy across many decode steps."""
    cfg, run, e = mk(policy=policy, V=8, L=256)
    e.prefill(jnp.ones((4, 4), jnp.int32))
    for _ in range(24):
        e.step()
    rep = e.space()
    assert rep["overflows"] == 0, rep
    assert rep["live_versions"] <= 4 * 4, rep   # << 24 steps x 4 seqs


def test_ebr_space_grows_with_pin():
    """EBR under a pinned reader accumulates every descriptor version — the
    paper's pathology at the serving layer (needs big slabs to survive)."""
    cfg, run, e = mk(policy="ebr", V=32, L=128)
    e.prefill(jnp.ones((4, 4), jnp.int32))
    e.pin(lane=0)
    for _ in range(12):
        e.step()
    ebr_live = e.space()["live_versions"]

    cfg2, run2, e2 = mk(policy="slrt", V=32, L=128)
    e2.prefill(jnp.ones((4, 4), jnp.int32))
    e2.pin(lane=0)
    for _ in range(12):
        e2.step()
    slrt_live = e2.space()["live_versions"]
    assert ebr_live >= slrt_live + 4 * 6, (ebr_live, slrt_live)


def test_snapshot_score_runs():
    cfg, run, e = mk(policy="slrt", V=16, L=64)
    e.prefill(jnp.ones((4, 6), jnp.int32))
    e.step()
    t = e.pin(lane=2)
    toks = jnp.ones((4, 1), jnp.int32)
    logits = eng.snapshot_score(e.state, cfg, toks, jnp.int32(t))
    assert logits.shape == (4, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


def test_undersized_ring_surfaces_dropped_retires():
    """Regression for the buried-monitor bug: an undersized retire ring
    silently drops retire records (DL-RT can never reclaim those versions).
    The engine step stats must surface ``dropped_retires`` (and
    ``overflow_count``) so an operator can see the misconfiguration, and a
    default-sized ring must report zero drops on the same workload."""
    cfg = reduced_config("minitron-4b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    prompt = jnp.array(rng.integers(0, cfg.vocab_size, (4, 8)), jnp.int32)

    def run_steps(ring_capacity):
        run = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                        gc_policy="slrt", versions_per_slot=16,
                        reader_lanes=4, ring_capacity=ring_capacity)
        e = eng.MVServeEngine(cfg, run, params, batch=4, max_len=64)
        e.prefill(prompt)
        for _ in range(6):
            e.step()
        return e.last_stats

    # ring of 2 < batch of 4: every decode step pushes 4 retires, so at
    # least 2 drop per step — the stats must show it
    stats = run_steps(ring_capacity=2)
    assert "dropped_retires" in stats and "overflow_count" in stats
    assert stats["dropped_retires"] > 0, (
        f"undersized ring dropped nothing? stats={stats}")
    # and the space report agrees with the step stats
    # (same counter, two surfaces)
    assert stats["dropped_retires"] >= 2

    # properly sized ring: zero drops on the identical workload
    stats_ok = run_steps(ring_capacity=0)   # 0 = default sizing
    assert stats_ok["dropped_retires"] == 0, stats_ok


# ---------------------------------------------------------------------------
# fork counters: the schema's `forks` field is wired to real engine ops
# ---------------------------------------------------------------------------
def test_fork_counters_dormant_zero_then_exact():
    """Regression for the once-dormant ``ServeMeasurement.forks`` field:
    a fork-free decode run reports exactly 0 (what serve_bench rows carry),
    and fork/join/release report exact op counts (what fork_bench rows
    carry) — masked-out and lineage-only ops never inflate them."""
    from repro.core.telemetry import GCConfig
    from repro.serve.engine import PagedKVEngine

    e = PagedKVEngine(4, 16, 4, 4, 1, 4,
                      gc=GCConfig(policy="slrt", versions_per_slot=8,
                                  reader_lanes=2))
    ids = jnp.arange(4, dtype=jnp.int32)
    kv = jnp.ones((4, 1, 4), jnp.float32)
    for _ in range(4):
        e.step(ids, kv, kv, jnp.ones((4,), bool))
    assert (e.forks, e.joins, e.releases) == (0, 0, 0)
    assert e.space()["forks"] == 0

    # two forks in one call; a masked-out lane must not count
    failed = e.fork(jnp.array([0, 1, 0], jnp.int32),
                    jnp.array([2, 3, 3], jnp.int32),
                    jnp.array([True, True, False]))
    assert not bool(np.asarray(failed)[:2].any())
    assert e.forks == 2
    assert set(e.dag.nodes) == {2, 3}

    e.join(jnp.array([2], jnp.int32), jnp.array([0], jnp.int32),
           jnp.ones((1,), bool))
    e.release(jnp.array([3], jnp.int32), jnp.ones((1,), bool))
    assert (e.forks, e.joins, e.releases) == (2, 1, 1)
    sp = e.space()
    assert (sp["forks"], sp["joins"], sp["releases"]) == (2, 1, 1)
    assert not e.dag.nodes
