"""hlo_cost analyzer tests: exact FLOPs on known programs, trip-count
multiplication, collective census, traffic sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from repro.launch.hlo_cost import analyze_hlo, HloModule


def _hlo(f, *specs):
    return jax.jit(f).lower(*specs).compile().as_text()


def test_matmul_exact():
    f = lambda a, b: a @ b
    txt = _hlo(f, jax.ShapeDtypeStruct((128, 256), jnp.float32),
               jax.ShapeDtypeStruct((256, 512), jnp.float32))
    r = analyze_hlo(txt)
    assert r["flops"] == 2 * 128 * 256 * 512


def test_scan_trip_multiplication():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=13)
        return y
    txt = _hlo(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
               jax.ShapeDtypeStruct((64, 64), jnp.float32))
    r = analyze_hlo(txt)
    expect = 13 * (2 * 64**3 + 64 * 64)
    assert abs(r["flops"] - expect) / expect < 0.01


def test_nested_scan_multiplies_both_levels():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y
    txt = _hlo(f, jax.ShapeDtypeStruct((32, 32), jnp.float32),
               jax.ShapeDtypeStruct((32, 32), jnp.float32))
    r = analyze_hlo(txt)
    expect = 5 * 3 * 2 * 32**3
    assert abs(r["flops"] - expect) / expect < 0.02


def test_xla_builtin_undercounts_scans():
    """Document the bug we work around: XLA cost_analysis ignores trips."""
    def mk(n):
        def f(x, w):
            def body(c, _):
                return c @ w, None
            y, _ = jax.lax.scan(body, x, None, length=n)
            return y
        return f
    s = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def xla_flops(n):
        ca = jax.jit(mk(n)).lower(s, s).compile().cost_analysis()
        # older jaxlib returns [dict], newer returns dict
        return (ca[0] if isinstance(ca, (list, tuple)) else ca)["flops"]

    # n=1 may unroll; compare two genuine while loops with 8x trip difference
    c2 = xla_flops(2)
    c16 = xla_flops(16)
    assert c16 < 1.5 * c2  # the undercount our analyzer fixes


def test_gather_counts_result_not_table():
    """Embedding gathers must charge the rows read, not the whole table."""
    def f(table, ids):
        return table[ids]
    txt = _hlo(f, jax.ShapeDtypeStruct((50_000, 64), jnp.float32),
               jax.ShapeDtypeStruct((8,), jnp.int32))
    r = analyze_hlo(txt)
    # 8 rows * 64 * 4B * 2 (read+write) plus slack; far below the 12.8MB table
    assert r["traffic_bytes"] < 1e6, r["traffic_bytes"]


def test_tuple_shape_instruction_parses():
    """Large tuple results carry /*index=N*/ comments; parser must survive."""
    def f(x):
        def body(carry, _):
            a, b, c, d, e, g = carry
            # chain dependencies so DCE keeps all six carries live
            return (a + g, b * a, c - b, d + c, e * d, g + e), None
        out, _ = jax.lax.scan(body, (x,) * 6, None, length=4)
        return sum(out)
    txt = _hlo(f, jax.ShapeDtypeStruct((128,), jnp.float32))
    mod = HloModule(txt)
    whiles = [i for c in mod.computations.values() for i in c if i.op == "while"]
    assert whiles, "while not parsed from tuple-result instruction"
    r = analyze_hlo(txt)
    assert r["flops"] >= 4 * 6 * 128  # 6 elementwise ops x 4 trips
