"""Distribution tests that need multiple (fake) devices: run in subprocesses
with XLA_FLAGS=--xla_force_host_platform_device_count (the main test process
must keep its single-device view)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def run_sub(code: str, devices: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_ring_all_reduce_matches_psum():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.dist.overlap import make_ring_all_reduce
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        x = jnp.arange(64.0)
        fn = make_ring_all_reduce(mesh, "data")
        with jax.set_mesh(mesh):
            got = jax.jit(fn)(x)
        want = np.tile(np.asarray(jnp.arange(64.0)).reshape(8, 8).sum(0), 8)
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6)
        print("ring OK")
    """)


def test_pipeline_parallel_matches_sequential():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.train.pipeline_parallel import pipeline_forward
        S, M, mb, d = 4, 6, 2, 16
        mesh = jax.make_mesh((S,), ("stage",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        k = jax.random.PRNGKey(0)
        ws = jax.random.normal(k, (S, d, d)) * 0.3
        x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))
        apply_fn = lambda w, h: jnp.tanh(h @ w)
        with jax.set_mesh(mesh):
            got = pipeline_forward(apply_fn, ws, x, mesh=mesh, axis="stage")
        want = x
        for s in range(S):
            want = jnp.tanh(want @ ws[s])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)
        print("pipeline OK")
    """)


def test_sharded_train_step_runs_and_matches_single_device():
    """FSDP+TP sharded train step on a 2x2 fake mesh == unsharded result."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np, functools
        from repro.configs import reduced_config
        from repro.configs.base import RunConfig, SHAPES
        from repro.dist.sharding import param_shardings, batch_sharding
        from repro.train.step import init_state, train_step
        import dataclasses

        cfg = reduced_config("minitron-4b", d_model=64, num_heads=4,
                             num_kv_heads=4, d_ff=128, vocab_size=256)
        run = RunConfig(model=cfg, shape=SHAPES["train_4k"], lr=1e-3)
        state = init_state(cfg, jax.random.PRNGKey(0))
        batch = {"tokens": jnp.ones((8, 16), jnp.int32)}

        # single device reference
        s1, m1 = jax.jit(functools.partial(train_step, cfg=cfg, run=run))(
            state, batch)

        mesh = jax.make_mesh((2, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        with jax.set_mesh(mesh):
            psh = param_shardings(state.params, mesh, fsdp=True)
            state_sh = jax.device_put(
                state, state._replace(
                    params=psh, opt=state.opt._replace(
                        step=jax.NamedSharding(mesh, jax.P()),
                        mu=psh, nu=psh),
                    err=jax.tree.map(lambda _: jax.NamedSharding(mesh, jax.P()),
                                     state.err),
                    step=jax.NamedSharding(mesh, jax.P())))
            s2, m2 = jax.jit(functools.partial(train_step, cfg=cfg, run=run))(
                state_sh, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-4)
        print("sharded train OK", float(m1["loss"]))
    """, devices=4)


@pytest.mark.slow
def test_dryrun_one_small_cell():
    """End-to-end dryrun of the smallest cell on the 512-device mesh."""
    run_sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.dryrun import dryrun_cell
        rec = dryrun_cell("whisper-tiny", "decode_32k", "pod")
        assert rec["flops_per_device"] > 0
        assert rec["memory"]["temp_bytes"] > 0
        print("dryrun cell OK", rec["flops_per_device"])
    """, devices=512, timeout=900)
