"""Crash-recovery tests for the checkpoint-coupled serving engine
(DESIGN.md §14): save -> drop the engine -> restore must be byte-identical
(device pytree including the retire ring and announce board, pinned snapshot
views, host-side GC counters and fork DAG), and a restored engine must be
able to evict checkpointed sole-survivor versions that an un-checkpointed
control provably cannot free."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from repro.core.telemetry import GCConfig
from repro.serve import forking
from repro.serve.engine import PagedKVEngine

B, PAGES, PS, MP, V = 8, 20, 4, 6, 8
IDLE = 5          # seqs 0..4 go idle after warmup; 5..7 keep decoding
KV_HEADS, HEAD_DIM = 1, 4


def mk(policy="slrt"):
    return PagedKVEngine(
        B, PAGES, PS, MP, KV_HEADS, HEAD_DIM,
        gc=GCConfig(policy=policy, versions_per_slot=V, reader_lanes=4,
                    hot_k=B),
        dtype=jnp.float32)


def step(eng, mask, val):
    """One decode step with per-(step, seq) distinct values so recycled
    pages change content."""
    base = np.arange(B, dtype=np.float32) + B * val
    kv = jnp.asarray(np.broadcast_to(base[:, None, None],
                                     (B, KV_HEADS, HEAD_DIM)))
    return eng.step(jnp.arange(B, dtype=jnp.int32), kv, kv,
                    jnp.asarray(mask))


def current_sig(eng, seqs):
    """Exact content fingerprint of the named sequences' current views."""
    tbl, ln = eng.view_at(2**31 - 2)
    tbl, ln = np.asarray(tbl), np.asarray(ln)
    return tuple(
        (int(ln[s]),) + forking.prefix_values(eng.st, tbl[s], int(ln[s]))
        for s in seqs)


def warmup(eng, steps=8):
    all_mask = np.ones((B,), bool)
    for i in range(steps):
        failed = step(eng, all_mask, i + 1)
        assert not np.asarray(failed).any()


def assert_trees_equal(a, b):
    leaves_a, treedef_a = jax.tree_util.tree_flatten(a)
    leaves_b, treedef_b = jax.tree_util.tree_flatten(b)
    assert treedef_a == treedef_b
    for la, lb in zip(leaves_a, leaves_b):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_checkpoint_restore_roundtrip_byte_identical(tmp_path):
    """save -> drop engine -> restore: the full device pytree (version
    store, retire ring, announce board, page tables, KV pages, bitmaps) and
    the host GC state come back byte-identical; a pinned snapshot resolves
    to the same bytes through the restored engine."""
    eng = mk()
    warmup(eng)
    # fork a lineage edge and pin a reader so both survive the round-trip
    assert not np.asarray(eng.fork(
        jnp.asarray([5], jnp.int32), jnp.asarray([0], jnp.int32),
        jnp.ones((1,), bool))).any()
    lane_ts = eng.pin(0)
    want_view = current_sig(eng, range(B))
    want_stats = dataclasses.asdict(eng.stats)
    want_dag = eng.dag.as_dict()
    step_no = eng.checkpoint(tmp_path)

    del eng                       # "crash"
    eng2 = mk()
    got_step = eng2.restore(tmp_path)
    assert got_step == step_no

    eng3 = mk()                   # reference: what a fresh engine looks like
    with pytest.raises(AssertionError):
        assert_trees_equal(eng2.st, eng3.st)   # restore actually changed it

    eng4 = mk()
    eng4.restore(tmp_path, step=step_no)
    assert_trees_equal(eng2.st, eng4.st)       # deterministic restore

    assert dataclasses.asdict(eng2.stats) == want_stats
    assert eng2.dag.as_dict() == want_dag
    assert eng2.ckpt_max == int(eng2.st.mv.now)
    assert current_sig(eng2, range(B)) == want_view
    # the pinned lane's announce rides in the pytree: the pinned view
    # resolves identically post-restore
    tbl, ln = eng2.view_at(lane_ts)
    assert np.asarray(ln).sum() > 0
    ok, leaked, premature = forking.check_no_leak(eng2.st)
    assert ok, (leaked, premature)


def test_restore_missing_manifest_raises(tmp_path):
    eng = mk()
    with pytest.raises(FileNotFoundError):
        eng.restore(tmp_path / "nowhere")


def test_restore_then_reclaim_frees_checkpointed_only(tmp_path):
    """The tentpole safety/liveness pair, through a crash: after restore,
    a forced reclaim evicts idle-since-checkpoint sole survivors
    (ckpt_freed > 0) while active sequences — whose current versions moved
    past ckpt_max — keep every byte; the identical run without a checkpoint
    frees none of those pages."""
    eng = mk()
    warmup(eng)
    eng.checkpoint(tmp_path)
    del eng                                    # crash after the save

    eng = mk()
    eng.restore(tmp_path)
    assert eng.ckpt_max >= 0
    active = np.zeros((B,), bool)
    active[IDLE:] = True
    live_before = int(eng.space()["live_pages"])
    step(eng, active, 100)                     # active seqs pass ckpt_max
    want_active = current_sig(eng, range(IDLE, B))

    # the watermark crossing inside step() may already have fired the
    # eviction; the explicit reclaim makes it deterministic either way
    eng.reclaim(B * V)
    assert eng.stats.ckpt_evictions >= IDLE
    assert eng.stats.ckpt_freed > 0
    assert int(eng.space()["live_pages"]) < live_before
    # idle sole survivors are gone from the version store...
    tbl, ln = eng.view_at(2**31 - 2)
    assert np.asarray(ln)[:IDLE].sum() == 0
    # ...but every active byte survived the eviction
    assert current_sig(eng, range(IDLE, B)) == want_active
    ok, leaked, premature = forking.check_no_leak(eng.st)
    assert ok, (leaked, premature)

    # control: the same workload with no checkpoint cannot free those pages
    ctl = mk()
    warmup(ctl)
    step(ctl, active, 100)
    ctl.reclaim(B * V)
    assert ctl.stats.ckpt_freed == 0
    assert ctl.stats.ckpt_evictions == 0
    tbl, ln = ctl.view_at(2**31 - 2)
    assert np.asarray(ln)[:IDLE].sum() > 0     # idle current versions pinned
    assert int(ctl.space()["live_pages"]) > int(eng.space()["live_pages"])


def test_evicted_sequences_restorable_from_checkpoint(tmp_path):
    """Eviction is safe *because* restore can always bring the data back:
    after evicting the idle sole survivors, restoring the same checkpoint
    reproduces their pre-eviction bytes exactly."""
    eng = mk()
    warmup(eng)
    want_idle = current_sig(eng, range(IDLE))
    eng.checkpoint(tmp_path)
    active = np.zeros((B,), bool)
    active[IDLE:] = True
    step(eng, active, 100)
    eng.reclaim(B * V)
    assert eng.stats.ckpt_freed > 0
    tbl, ln = eng.view_at(2**31 - 2)
    assert np.asarray(ln)[:IDLE].sum() == 0    # idle views really gone

    eng.restore(tmp_path)
    assert current_sig(eng, range(IDLE)) == want_idle


def test_sharded_engine_checkpoint_roundtrip(tmp_path):
    """The host-sharded engine round-trips its vmapped state + host GC
    counters through the same manager format."""
    from repro.dist.mvgc import ShardedPagedKVEngine

    eng = ShardedPagedKVEngine(
        hosts=2, num_seqs=4, num_pages=12, page_size=4, max_pages_per_seq=3,
        kv_heads=KV_HEADS, head_dim=HEAD_DIM,
        gc=GCConfig(policy="slrt", versions_per_slot=6, reader_lanes=2,
                    hot_k=4))
    rng = np.random.default_rng(0)
    for i in range(5):
        kv = jnp.asarray(rng.standard_normal(
            (2, 4, KV_HEADS, HEAD_DIM)).astype(np.float32))
        eng.step(jnp.broadcast_to(jnp.arange(4, dtype=jnp.int32), (2, 4)),
                 kv, kv, jnp.ones((2, 4), bool))
    step_no = eng.checkpoint(tmp_path)
    want_forks = eng.forks
    del eng

    eng2 = ShardedPagedKVEngine(
        hosts=2, num_seqs=4, num_pages=12, page_size=4, max_pages_per_seq=3,
        kv_heads=KV_HEADS, head_dim=HEAD_DIM,
        gc=GCConfig(policy="slrt", versions_per_slot=6, reader_lanes=2,
                    hot_k=4))
    assert eng2.restore(tmp_path) == step_no
    assert eng2.forks == want_forks
    assert eng2.ckpt_max == int(jnp.min(eng2.st.mv.now))
