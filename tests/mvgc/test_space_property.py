"""Property tests on Layer-B space invariants: the pool analogue of the
paper's L-R+P bound, and ring conservation (no version lost or duplicated)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

jax.config.update("jax_platform_name", "cpu")

from repro.core.mvgc import vstore
from repro.core.mvgc.pool import EMPTY


@settings(max_examples=8, deadline=None)
@given(data=st.data())
def test_live_versions_bounded_by_needed_plus_buffer(data):
    """Theorem-1 analogue: live versions <= needed (pinned+current) + ring
    buffer occupancy, at every step of a random write/pin/gc interleaving."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    S, P = 8, 4
    # capacity planning per Theorem 1: the ring must hold needed-retired
    # versions (<= S per pinned reader) plus a flush batch; slabs must cover
    # the flush threshold (retirees stay slab-resident until flushed) plus
    # one pinned + one current version
    B = S * (P + 1) + 16
    V = B // 2 + P + 2
    state = vstore.make_state(S, V, P, ring_capacity=B)
    pins = set()
    steps = data.draw(st.integers(5, 25))
    for i in range(steps):
        k = int(rng.integers(1, 5))
        slots = rng.choice(S, size=k, replace=False).astype(np.int32)
        ids = jnp.array(np.pad(slots, (0, 4 - k)), jnp.int32)
        m = jnp.array([True] * k + [False] * (4 - k))
        state, _, ovf = vstore.write_step(state, ids,
                                          jnp.arange(4, dtype=jnp.int32), m)
        assert not bool(ovf.any()), "slab overflow under SL-RT"
        if rng.random() < 0.3:
            lane = int(rng.integers(P))
            if lane in pins:
                state = vstore.end_snapshot(
                    state, jnp.array([lane], jnp.int32), jnp.array([True]))
                pins.discard(lane)
            else:
                state, _ = vstore.begin_snapshot(
                    state, jnp.array([lane], jnp.int32), jnp.array([True]))
                pins.add(lane)
        state, _ = vstore.gc_step(state)
        live = int(vstore.live_versions(state))
        # needed <= S current + S per pin; buffered retirees <= ring capacity
        bound = S * (1 + len(pins)) + B
        assert live <= bound, f"live {live} > bound {bound} (pins={len(pins)})"
    assert int(state.dropped_retires) == 0


def test_exhaustive_small_schedules_pdl():
    """Seeded-schedule exploration of a tiny PDL world (machine.explore_schedules):
    every explored interleaving preserves Invariant 2 and the AL ordering."""
    from repro.core.sim.machine import explore_schedules
    from repro.core.sim.pdl import PDL, Node

    def make_world():
        l = PDL()
        base = [Node(i * 2, i) for i in range(1, 4)]
        prev = l.head
        for n in base:
            gen = l.tryAppend_steps(prev, n)
            try:
                while True:
                    next(gen)
            except StopIteration:
                pass
            prev = n
        y = Node(7, "new")
        ops = [
            ("remove", lambda n=base[0]: l.remove_steps(n), (base[0],)),
            ("remove", lambda n=base[1]: l.remove_steps(n), (base[1],)),
            ("tryAppend", lambda: l.tryAppend_steps(base[2], y), (base[2], y)),
            ("search", lambda: l.search_steps(4), (4,)),
        ]
        return l, ops

    def check(l, sched):
        l.check_invariant2()
        l.check_al_sorted()
        al = l.abstract_list()
        assert all(n.key not in (2, 4) for n in al[1:])  # removed keys gone

    n = explore_schedules(make_world, check, max_schedules=400, seed=3)
    assert n == 400
