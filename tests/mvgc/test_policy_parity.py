"""Differential parity: sim schemes vs. deployable vstore policies.

Replays one identical operation trace (writes, pins, unpins, pressure
events) through a sim scheme (`repro.core.sim.schemes`) and its vstore
policy counterpart (`repro.core.mvgc.vstore`) and asserts that the **sets of
freed version identities match at every pressure event** — the correctness
anchor for the pressure-machinery port (DESIGN.md §11): if the deployable
layer frees a version the sim retains (or vice versa) at a sync point, the
port broke the paper's `needed()` contract.

Alignment conventions (both layers are deterministic, so parity is exact):

* **shared clock** — the sim advances `env.global_ts` once per write; the
  vstore ticks `now` once per `write_step`; pins announce the current time
  on both sides, so version intervals coincide timestamp-for-timestamp.
* **GC only at pressure events** — sim cadences are set astronomically high
  (EBR ``advance_every``, the RangeTracker ``batch_size``) and the driver
  never calls `vstore.gc_step`, so *all* reclamation flows through
  ``reclaim_on_pressure`` on both sides.  Steam is the one exception: it
  compacts on the write path by design in both layers (sim ``on_overwrite``
  vs. vstore's sweep-before-append), with a one-write timing skew — which is
  why parity is asserted at pressure-event sync points, where both sides
  complete a full pass, not after every write.
* **deficit = infinity** — every pressure event asks for more than exists,
  so hot-first/cold-spill orderings cannot change *what* is freed, only the
  order; both sides converge on the full ¬needed set.
* **EBR discipline** — the trace generator inserts a pressure event
  immediately before each pin (with no intervening writes) and allows one
  pin at a time.  This neutralizes EBR's epoch granularity (a version that
  closed *at* the pin timestamp is reclaimable by the interval rule but sits
  in a current-epoch bucket) without weakening the other three policies'
  traces.  Under that discipline EBR parity is exact: nothing frees during
  a pin on either side, and everything closed frees at the next unpinned
  pressure event.

Identity is the version's payload handle: the driver issues a unique
integer per write, so "freed sets match" == "surviving payload sets match".
"""
import dataclasses
import inspect
import random

import jax
import numpy as np
import jax.numpy as jnp
import pytest

jax.config.update("jax_platform_name", "cpu")

from repro.core.mvgc import vstore
from repro.core.mvgc.pool import EMPTY
from repro.core.sim.schemes import (
    DLRTScheme, EBRScheme, SLRTScheme, SteamLFScheme)
from repro.core.sim.ssl_list import MVEnv

POLICIES = ("ebr", "steam", "dlrt", "slrt")
HUGE = 10 ** 9


# ---------------------------------------------------------------------------
# sim-side replay
# ---------------------------------------------------------------------------
class SimReplay:
    """Drives one sim scheme with per-slot version lists (slot k <-> one
    list), matching the vstore's slot-indexed slabs."""

    def __init__(self, policy: str, n_slots: int, n_lanes: int):
        self.env = MVEnv(n_lanes + 1)     # lanes pin; the last pid writes
        self.wpid = n_lanes
        if policy == "ebr":
            self.scheme = EBRScheme(self.env, advance_every=HUGE)
        elif policy == "steam":
            self.scheme = SteamLFScheme(self.env, scan_every=1)
        elif policy == "dlrt":
            self.scheme = DLRTScheme(self.env, batch_size=HUGE)
        elif policy == "slrt":
            self.scheme = SLRTScheme(self.env, batch_size=HUGE)
        else:
            raise ValueError(policy)
        self.lists = [self.scheme.new_list() for _ in range(n_slots)]
        for lst in self.lists:
            self.scheme.register_list(lst)
        self.scheme.set_key_resolver(lambda k: [self.lists[k]])
        self.n_slots = n_slots
        self.issued = set()

    def write(self, slot: int, payload: int) -> None:
        ts = self.env.advance_ts()
        lst = self.lists[slot]
        ctx = self.scheme.begin_update(self.wpid)
        old = lst.head if lst.head is not lst.sentinel else None
        node = self.scheme.new_node(ts, payload)
        assert lst.try_append(lst.head, node)
        if old is not None:
            self.scheme.on_overwrite(self.wpid, lst, old, old.ts, ts)
        self.scheme.end_update(self.wpid, ctx)
        self.issued.add(payload)

    def pin(self, lane: int) -> int:
        return self.scheme.begin_rtx(lane)

    def unpin(self, lane: int) -> None:
        self.scheme.end_rtx(lane)

    def pressure(self) -> int:
        return self.scheme.reclaim_on_pressure(
            list(range(self.n_slots)), HUGE)

    def remaining(self) -> set:
        out = set()
        for lst in self.lists:
            out.update(n.val for n in lst.reachable_nodes())
        return out & self.issued


# ---------------------------------------------------------------------------
# vstore-side replay
# ---------------------------------------------------------------------------
class VstoreReplay:
    def __init__(self, policy: str, n_slots: int, n_lanes: int, V: int = 48):
        self.policy = policy
        self.state = vstore.make_state(
            n_slots, V, n_lanes, ring_capacity=256)
        self.n_slots = n_slots
        self.issued = set()

    def write(self, slot: int, payload: int) -> None:
        self.state, _, ovf = vstore.write_step(
            self.state,
            jnp.array([slot], jnp.int32),
            jnp.array([payload], jnp.int32),
            jnp.array([True]),
            policy=self.policy,
        )
        assert not bool(ovf.any()), "slab overflow would skew parity"
        self.issued.add(payload)

    def pin(self, lane: int) -> int:
        self.state, ts = vstore.begin_snapshot(
            self.state, jnp.array([lane], jnp.int32), jnp.array([True]))
        return int(ts[0])

    def unpin(self, lane: int) -> None:
        self.state = vstore.end_snapshot(
            self.state, jnp.array([lane], jnp.int32), jnp.array([True]))

    def pressure(self) -> int:
        hot = jnp.arange(self.n_slots, dtype=jnp.int32)
        self.state, _, n = vstore.reclaim_on_pressure(
            self.state, hot, jnp.int32(HUGE), policy=self.policy)
        return int(n)

    def remaining(self) -> set:
        ts = np.asarray(self.state.store.ts)
        pay = np.asarray(self.state.store.payload)
        return set(pay[ts != EMPTY].tolist()) & self.issued


# ---------------------------------------------------------------------------
# trace generation
# ---------------------------------------------------------------------------
def gen_trace(seed: int, policy: str, n_slots: int, n_lanes: int,
              n_events: int):
    """Deterministic random trace.  EBR additionally gets the drain-before-
    pin discipline (module docstring) and a single pin lane."""
    rng = random.Random(seed)
    max_pins = 1 if policy == "ebr" else n_lanes
    ops, pinned = [], []
    for _ in range(n_events):
        r = rng.random()
        free_lanes = [l for l in range(n_lanes) if l not in pinned]
        if r < 0.55 or (r < 0.70 and (not free_lanes or
                                      len(pinned) >= max_pins)):
            ops.append(("write", rng.randrange(n_slots)))
        elif r < 0.70:
            lane = rng.choice(free_lanes)
            pinned.append(lane)
            ops.append(("pin", lane))
        elif r < 0.85 and pinned:
            lane = pinned.pop(rng.randrange(len(pinned)))
            ops.append(("unpin", lane))
        else:
            ops.append(("pressure",))
    ops.append(("pressure",))          # mid-state sync point
    for lane in pinned:                # full-cleanup check at the end
        ops.append(("unpin", lane))
    ops.append(("pressure",))
    if policy == "ebr":
        out = []
        for op in ops:
            if op[0] == "pin":
                out.append(("pressure",))
            out.append(op)
        ops = out
    return ops


def replay_and_compare(policy: str, seed: int, n_slots=5, n_lanes=3,
                       n_events=60):
    sim = SimReplay(policy, n_slots, n_lanes)
    dep = VstoreReplay(policy, n_slots, n_lanes)
    trace = gen_trace(seed, policy, n_slots, n_lanes, n_events)
    payload = 0
    sync_points = 0
    for i, op in enumerate(trace):
        if op[0] == "write":
            payload += 1
            sim.write(op[1], payload)
            dep.write(op[1], payload)
        elif op[0] == "pin":
            ts_s = sim.pin(op[1])
            ts_d = dep.pin(op[1])
            assert ts_s == ts_d, (
                f"event {i}: pin timestamps diverged (sim {ts_s}, "
                f"vstore {ts_d}) — the shared clock broke")
        elif op[0] == "unpin":
            sim.unpin(op[1])
            dep.unpin(op[1])
        else:  # pressure
            sim.pressure()
            dep.pressure()
            sync_points += 1
            s_rem, d_rem = sim.remaining(), dep.remaining()
            assert s_rem == d_rem, (
                f"{policy} seed {seed} event {i} (sync {sync_points}): "
                f"freed sets diverged — sim kept {sorted(s_rem - d_rem)} "
                f"that vstore freed; vstore kept {sorted(d_rem - s_rem)} "
                f"that sim freed")
    assert sync_points >= 3, "trace produced too few pressure sync points"
    # final state: no pins, fully drained — only current versions survive
    cur = {s for s in range(n_slots)}
    final = dep.remaining()
    written_slots = len({op[1] for op in trace if op[0] == "write"})
    assert len(final) == written_slots <= len(cur), (
        "post-drain survivors must be exactly one current version per "
        f"written slot: {sorted(final)}")
    return payload, sync_points


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_policy_parity(policy, seed):
    """Identical traces through sim scheme and vstore policy free identical
    version sets at every pressure event."""
    replay_and_compare(policy, seed)


@pytest.mark.parametrize("policy", POLICIES)
def test_parity_write_burst_single_slot(policy):
    """Degenerate trace: all writes hammer one slot (the paper's single
    hot vCAS object), pin mid-burst."""
    sim = SimReplay(policy, 1, 2)
    dep = VstoreReplay(policy, 1, 2)
    for p in range(1, 9):
        sim.write(0, p)
        dep.write(0, p)
    sim.pressure(), dep.pressure()
    assert sim.remaining() == dep.remaining() == {8}
    sim.pin(0), dep.pin(0)
    for p in range(9, 15):
        sim.write(0, p)
        dep.write(0, p)
    sim.pressure(), dep.pressure()
    assert sim.remaining() == dep.remaining()
    # the pinned snapshot's version (payload 8, current at the pin) plus the
    # running current version must both survive on both sides
    assert {8, 14} <= sim.remaining()
    sim.unpin(0), dep.unpin(0)
    sim.pressure(), dep.pressure()
    assert sim.remaining() == dep.remaining() == {14}


@pytest.mark.parametrize("policy", POLICIES)
def test_parity_interleaved_pins(policy):
    """Two staggered pins (one for EBR) with writes between every event."""
    n_lanes = 1 if policy == "ebr" else 2
    sim = SimReplay(policy, 3, n_lanes)
    dep = VstoreReplay(policy, 3, n_lanes)
    p = 0

    def w(slot):
        nonlocal p
        p += 1
        sim.write(slot, p)
        dep.write(slot, p)

    def sync():
        sim.pressure(), dep.pressure()
        assert sim.remaining() == dep.remaining()

    for s in (0, 1, 2, 0, 1):
        w(s)
    sync()                       # EBR discipline: drain right before pin
    sim.pin(0), dep.pin(0)
    for s in (0, 0, 1, 2):
        w(s)
    sync()
    if n_lanes > 1:
        sim.pin(1), dep.pin(1)
        for s in (1, 1, 0):
            w(s)
        sync()
        sim.unpin(1), dep.unpin(1)
    sim.unpin(0), dep.unpin(0)
    for s in (2, 2):
        w(s)
    sync()


# ---------------------------------------------------------------------------
# API-vocabulary parity: the deployable hook must share the sim's pressure
# vocabulary *by signature*, not through renaming adapters (DESIGN.md §12)
# ---------------------------------------------------------------------------
def test_reclaim_on_pressure_signature_parity():
    """`vstore.reclaim_on_pressure(state, hot_keys, deficit, ...)` uses the
    exact argument names of `SchemeBase.reclaim_on_pressure(hot_keys,
    deficit)` and of `ReclaimRequest` — a rename on either side breaks the
    shared vocabulary this suite replays through."""
    from repro.core.sim.contention import ReclaimRequest
    from repro.core.sim.schemes import SchemeBase

    sim_params = list(inspect.signature(
        SchemeBase.reclaim_on_pressure).parameters)
    assert sim_params[:3] == ["self", "hot_keys", "deficit"]

    dep_params = list(inspect.signature(
        vstore.reclaim_on_pressure).parameters)
    assert dep_params[:3] == ["state", "hot_keys", "deficit"]

    req_fields = [f.name for f in dataclasses.fields(ReclaimRequest)]
    assert req_fields[:2] == ["deficit", "hot_keys"]
