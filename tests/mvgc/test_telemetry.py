"""The unified pressure/telemetry vocabulary (repro.core.telemetry,
DESIGN.md §13): one PressureSignal / ReclaimStats / GCConfig across the
contention manager, the version store, the paged-KV engines and the bench
rows — plus the deprecation shims that keep the old kwarg surface alive for
one release."""
import dataclasses
import math
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from repro.configs import reduced_config
from repro.configs.base import RunConfig, SHAPES
from repro.core.mvgc import vstore
from repro.core.sim.contention import ContentionManager
from repro.core.telemetry import (GCConfig, PressureSignal, ReclaimStats,
                                  resolve_gc_config)
from repro.mvkv import paged
from repro.serve.engine import PagedKVEngine


# ---------------------------------------------------------------------------
# the vocabulary types
# ---------------------------------------------------------------------------
class TestPressureSignal:
    def test_derived_properties(self):
        sig = PressureSignal(level=0.75, under_pressure=True, deficit=3,
                             live=9, capacity=12)
        assert sig.free_frac == pytest.approx(0.25)
        assert sig.free_pages == 3

    def test_deprecated_aliases_are_the_same_type(self):
        assert vstore.PressureReport is PressureSignal
        assert paged.PagePressure is PressureSignal


class TestReclaimStats:
    def test_accounting_and_row(self):
        st = ReclaimStats(unit="pages")
        st.note_live(10)
        st.note_event()
        st.note_reclaim(4, 6)
        st.note_live(8)
        st.give_ups += 2
        st.stale_lanes_aged += 1
        row = st.as_row()
        assert row["pressure_events"] == 1
        assert row["reclaims_triggered"] == 1
        assert row["pages_reclaimed"] == 4
        assert row["peak_pages"] == 10
        assert row["peak_pages_post_reclaim"] == 6
        assert row["give_ups"] == 2
        assert row["stale_lanes_aged"] == 1

    def test_unit_keys_follow_unit(self):
        row = ReclaimStats(unit="versions").as_row()
        assert "versions_reclaimed" in row and "peak_versions" in row


    def test_ckpt_eviction_fields(self):
        st = ReclaimStats(unit="pages")
        st.note_ckpt_eviction(3, 5)
        st.note_ckpt_eviction(2, 5)
        row = st.as_row()
        assert row["ckpt_evictions"] == 5
        assert row["ckpt_pages_freed"] == 10
        assert "ckpt_versions_freed" in ReclaimStats(unit="versions").as_row()


class TestGCConfig:
    def test_kernel_kwargs(self):
        gc = GCConfig(use_kernel=True, kernel_interpret=False)
        assert gc.kernel_kwargs() == {"use_kernel": True, "interpret": False}

    def test_replace(self):
        gc = GCConfig().replace(policy="ebr", hot_k=2)
        assert gc.policy == "ebr" and gc.hot_k == 2
        assert math.isinf(gc.stale_after_s)       # untouched defaults


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------
class TestResolveGCConfig:
    def test_gc_passes_through_silently(self):
        gc = GCConfig(policy="ebr")
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            assert resolve_gc_config(gc, "here") is gc
            assert resolve_gc_config(None, "here") == GCConfig()

    def test_legacy_kwarg_warns_and_overrides(self):
        with pytest.warns(DeprecationWarning, match="versions_per_slot"):
            gc = resolve_gc_config(None, "here", versions_per_slot=4)
        assert gc.versions_per_slot == 4
        with pytest.warns(DeprecationWarning, match="here"):
            gc = resolve_gc_config(GCConfig(policy="ebr"), "here", hot_k=2)
        assert gc.policy == "ebr" and gc.hot_k == 2

    def test_make_paged_kv_legacy_matches_gc_config(self):
        with pytest.warns(DeprecationWarning):
            legacy = paged.make_paged_kv(2, 8, 4, 2, 1, 4,
                                         versions_per_seq=4, reader_lanes=2)
        new = paged.make_paged_kv(
            2, 8, 4, 2, 1, 4,
            gc=GCConfig(versions_per_slot=4, reader_lanes=2))
        for a, b in zip(jax.tree.leaves(legacy), jax.tree.leaves(new)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_engine_legacy_kwargs_warn_but_work(self):
        with pytest.warns(DeprecationWarning, match="PagedKVEngine"):
            eng = PagedKVEngine(2, 8, 4, 2, 1, 4, gc_policy="ebr",
                                versions_per_seq=4)
        assert eng.gc.policy == "ebr"
        assert eng.gc.versions_per_slot == 4

    def test_engine_gc_config_is_silent(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            eng = PagedKVEngine(2, 8, 4, 2, 1, 4,
                                gc=GCConfig(policy="ebr"))
        assert eng.gc.policy == "ebr"
        assert isinstance(eng.stats, ReclaimStats)


# ---------------------------------------------------------------------------
# RunConfig <-> GCConfig round trip
# ---------------------------------------------------------------------------
class TestRunConfigGC:
    def test_flat_fields_build_gc(self):
        run = RunConfig(model=reduced_config("minitron-4b"),
                        shape=SHAPES["train_4k"], gc_policy="ebr",
                        versions_per_slot=4, use_kernel=True)
        assert run.gc is not None
        assert run.gc.policy == "ebr"
        assert run.gc.versions_per_slot == 4
        assert run.gc.use_kernel is True

    def test_gc_backfills_flat_fields(self):
        gc = GCConfig(policy="steam", reader_lanes=3, ring_capacity=32)
        run = RunConfig(model=reduced_config("minitron-4b"),
                        shape=SHAPES["train_4k"], gc=gc)
        assert run.gc_policy == "steam"
        assert run.reader_lanes == 3
        assert run.ring_capacity == 32


# ---------------------------------------------------------------------------
# producers speak the vocabulary
# ---------------------------------------------------------------------------
class TestProducers:
    def test_capacity_gate_returns_signal(self):
        st = vstore.make_state(4, 4, 2)
        sig = vstore.capacity_gate(st)
        assert isinstance(sig, PressureSignal)
        assert int(sig.capacity) == 16
        assert int(sig.live) >= 0
        assert float(sig.free_frac) == pytest.approx(1.0 - float(sig.level))

    def test_page_pressure_returns_signal(self):
        st = paged.make_paged_kv(2, 8, 4, 2, 1, 4)
        sig = paged.page_pressure(st)
        assert isinstance(sig, PressureSignal)
        assert int(sig.capacity) == 8
        assert int(sig.live) + int(sig.free_pages) == 8

    def test_contention_manager_signal_and_alias(self):
        cm = ContentionManager(2, capacity=8, pressure_window=16)
        sig = cm.pressure_signal(now=0.0)
        assert isinstance(sig, PressureSignal)
        assert sig.level == 0.0                  # no conflict ever seen
        assert cm.pressure(0.0) == sig.level     # deprecated alias agrees
        cm.record_conflict(0, "wcc", now=10.0)
        assert cm.pressure_signal(10.0).level == pytest.approx(1.0)
        assert cm.pressure_signal(18.0).level == pytest.approx(0.5)

    def test_engine_stats_properties_delegate(self):
        eng = PagedKVEngine(2, 8, 4, 2, 1, 4, gc=GCConfig())
        eng.stats.note_event()
        eng.stats.note_reclaim(3, 2)
        assert eng.pressure_events == 1
        assert eng.reclaims_triggered == 1
        assert eng.pages_reclaimed == 3
