"""Layer B (TPU-native bulk-synchronous MVGC) tests.

Includes a *differential* test: the JAX needed(A,t) predicate must agree with
the Layer-A sim oracle (SSL.needed) on random version histories — the two
layers implement the same paper definition.
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.mvgc import announce as ann
from repro.core.mvgc import pool, rangetracker as rt, vstore
from repro.core.mvgc.needed import needed_intervals, sort_announcements
from repro.core.mvgc.pool import EMPTY, TS_MAX

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# pool
# ---------------------------------------------------------------------------
class TestPool:
    def test_write_read_roundtrip(self):
        s = pool.make_store(8, 4)
        ids = jnp.array([0, 3, 7], jnp.int32)
        s, ovf = pool.write(s, ids, jnp.int32(1), jnp.array([10, 11, 12], jnp.int32),
                            jnp.array([True, True, True]))
        assert not bool(ovf.any())
        got, found = pool.read_current(s, ids)
        assert found.all() and list(got) == [10, 11, 12]
        # second write closes the first versions
        s, _ = pool.write(s, ids, jnp.int32(5), jnp.array([20, 21, 22], jnp.int32),
                          jnp.array([True, True, True]))
        old, f = pool.read_at(s, ids, jnp.int32(4))
        assert list(old) == [10, 11, 12] and f.all()
        new, f = pool.read_at(s, ids, jnp.int32(5))
        assert list(new) == [20, 21, 22]
        assert int(pool.occupancy(s).max()) == 2

    def test_overflow_flag(self):
        s = pool.make_store(2, 2)
        ids = jnp.array([0], jnp.int32)
        m = jnp.array([True])
        for t in range(1, 3):
            s, ovf = pool.write(s, ids, jnp.int32(t), jnp.array([t], jnp.int32), m)
            assert not bool(ovf.any())
        s, ovf = pool.write(s, ids, jnp.int32(3), jnp.array([3], jnp.int32), m)
        assert bool(ovf.all())

    def test_masked_lanes_do_not_write(self):
        s = pool.make_store(4, 2)
        ids = jnp.array([1, 1], jnp.int32)  # duplicate, but second is masked
        s, _ = pool.write(s, ids, jnp.int32(1), jnp.array([5, 6], jnp.int32),
                          jnp.array([True, False]))
        got, found = pool.read_current(s, jnp.array([1], jnp.int32))
        assert int(got[0]) == 5
        assert int(pool.occupancy(s)[1]) == 1


# ---------------------------------------------------------------------------
# needed(A, t): differential vs Layer-A oracle
# ---------------------------------------------------------------------------
@settings(max_examples=100, deadline=None)
@given(data=st.data())
def test_needed_matches_sim_oracle(data):
    from repro.core.sim.ssl_list import SSL, SNode

    n = data.draw(st.integers(1, 12))
    deltas = data.draw(st.lists(st.integers(0, 3), min_size=n, max_size=n))
    ts_list, cur = [], 0
    for d in deltas:
        cur += max(1, d)  # bulk-sync layer ticks at least 1 per write
        ts_list.append(cur)
    # Layer A oracle list
    l = SSL()
    prev = l.head
    for i, t in enumerate(ts_list):
        node = SNode(t, i)
        assert l.try_append(prev, node)
        prev = node
    now = cur
    n_ann = data.draw(st.integers(0, 4))
    A = sorted(data.draw(st.lists(st.integers(0, cur), min_size=n_ann, max_size=n_ann)))

    # interval representation (succ = next version's ts; TS_MAX for current)
    succ_list = ts_list[1:] + [int(TS_MAX)]
    ts_arr = jnp.array(ts_list, jnp.int32)
    succ_arr = jnp.array(succ_list, jnp.int32)
    padded = jnp.array(A + [int(TS_MAX)] * (8 - len(A)), jnp.int32)
    got = needed_intervals(ts_arr, succ_arr, padded, jnp.int32(now))

    for i, node in enumerate(l.added[1:]):
        expect = l.needed(node, A, now)
        assert bool(got[i]) == expect, (
            f"needed mismatch at v{i}: ts={ts_list[i]} succ={succ_list[i]} "
            f"A={A} now={now}: jax={bool(got[i])} sim={expect}"
        )


# ---------------------------------------------------------------------------
# retire ring
# ---------------------------------------------------------------------------
class TestRing:
    def test_push_and_flush(self):
        s = pool.make_store(4, 4)
        ids = jnp.array([0, 1], jnp.int32)
        m = jnp.array([True, True])
        s, _ = pool.write(s, ids, jnp.int32(1), jnp.array([100, 101], jnp.int32), m)
        s, _ = pool.write(s, ids, jnp.int32(2), jnp.array([200, 201], jnp.int32), m)
        # versions @ts=1 are retired with interval [1, 2)
        ring = rt.make_ring(8)
        flat = ids * 4 + jnp.array([0, 0], jnp.int32)
        ring, dropped = rt.push(ring, flat, jnp.array([1, 1], jnp.int32),
                                jnp.array([2, 2], jnp.int32), m)
        assert not bool(dropped.any())
        assert int(rt.ring_size(ring)) == 2
        # nobody announced -> both reclaimed
        A = sort_announcements(jnp.full((4,), EMPTY, jnp.int32))
        ring, s, freed = rt.flush(ring, s, A, jnp.int32(2))
        freed = [int(x) for x in freed if int(x) != int(EMPTY)]
        assert sorted(freed) == [100, 101]
        assert int(rt.ring_size(ring)) == 0
        assert int(pool.occupancy(s).sum()) == 2  # only current versions left

    def test_flush_keeps_pinned(self):
        s = pool.make_store(2, 4)
        ids = jnp.array([0], jnp.int32)
        m = jnp.array([True])
        s, _ = pool.write(s, ids, jnp.int32(1), jnp.array([100], jnp.int32), m)
        s, _ = pool.write(s, ids, jnp.int32(5), jnp.array([200], jnp.int32), m)
        ring = rt.make_ring(4)
        ring, _ = rt.push(ring, jnp.array([0], jnp.int32), jnp.array([1], jnp.int32),
                          jnp.array([5], jnp.int32), m)
        # a reader pinned t=3 in [1, 5) -> version needed
        A = sort_announcements(jnp.array([3, EMPTY, EMPTY, EMPTY], jnp.int32))
        ring, s, freed = rt.flush(ring, s, A, jnp.int32(5))
        assert all(int(x) == int(EMPTY) for x in freed)
        assert int(rt.ring_size(ring)) == 1
        got, found = pool.read_at(s, ids, jnp.int32(3))
        assert bool(found[0]) and int(got[0]) == 100

    def test_ring_overflow_reports_drop(self):
        ring = rt.make_ring(2)
        m = jnp.array([True, True, True])
        ring, dropped = rt.push(
            ring, jnp.arange(3, dtype=jnp.int32),
            jnp.arange(3, dtype=jnp.int32), jnp.arange(1, 4, dtype=jnp.int32), m)
        assert int(dropped.sum()) == 1


# ---------------------------------------------------------------------------
# end-to-end policies
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", list(vstore.POLICIES))
def test_policy_snapshot_correctness(policy):
    """Randomized end-to-end: writers + pinned snapshot readers; reads at a
    pinned timestamp must always return the value that was current then,
    under every policy.  (GC must never free a needed version.)"""
    rng = random.Random(0)
    S, V, P = 16, 8, 4
    state = vstore.make_state(S, V, P, ring_capacity=32)
    shadow = {}  # slot -> list[(ts, payload)]
    pins = {}    # lane -> ts

    wstep = jax.jit(lambda st, i, p, m: vstore.write_step(st, i, p, m, policy=policy))
    gstep = jax.jit(lambda st: vstore.gc_step(st, policy=policy))

    payload_ctr = 1
    for step in range(60):
        # random writes (unique slots per step)
        k = rng.randint(1, 4)
        slots = rng.sample(range(S), k)
        pl = list(range(payload_ctr, payload_ctr + k))
        payload_ctr += k
        ids = jnp.array(slots + [0] * (4 - k), jnp.int32)
        pls = jnp.array(pl + [0] * (4 - k), jnp.int32)
        msk = jnp.array([True] * k + [False] * (4 - k))
        state, _, ovf = wstep(state, ids, pls, msk)
        now = int(state.now)
        for j, (s_, p_) in enumerate(zip(slots, pl)):
            if not bool(ovf[j]):  # overflowed appends fail visibly (EBR pathology)
                shadow.setdefault(s_, []).append((now, p_))

        # occasionally pin/unpin a reader lane
        if rng.random() < 0.3:
            lane = rng.randrange(P)
            if lane in pins:
                state = vstore.end_snapshot(
                    state, jnp.array([lane], jnp.int32), jnp.array([True]))
                del pins[lane]
            else:
                state, ts = vstore.begin_snapshot(
                    state, jnp.array([lane], jnp.int32), jnp.array([True]))
                pins[lane] = int(ts[0])

        state, _ = gstep(state)

        # validate all pinned readers see their snapshot
        for lane, t in pins.items():
            for s_ in list(shadow)[:6]:
                expect = None
                for ts_, p_ in shadow[s_]:
                    if ts_ <= t:
                        expect = p_
                got, found = vstore.snapshot_read(
                    state, jnp.array([s_], jnp.int32), jnp.int32(t))
                got = int(got[0]) if bool(found[0]) else None
                assert got == expect, (
                    f"{policy}: slot {s_} @t={t}: got {got}, want {expect}"
                )

    if policy != "ebr":
        assert int(state.overflow_count) == 0, f"{policy}: slab overflow"
    # EBR may legitimately overflow its slabs when a pinned reader blocks
    # reclamation — the paper's unbounded-space pathology.


@pytest.mark.parametrize("policy", ["slrt", "dlrt", "sweep", "steam"])
def test_policy_reclaims_unpinned(policy):
    """With no readers pinned, every obsolete version must eventually free."""
    S, V = 8, 8
    state = vstore.make_state(S, V, 2, ring_capacity=16)
    ids = jnp.arange(4, dtype=jnp.int32)
    m = jnp.ones((4,), jnp.bool_)
    for i in range(6):
        state, _, _ = vstore.write_step(
            state, ids, jnp.full((4,), i, jnp.int32), m, policy=policy)
        state, _ = vstore.gc_step(state, policy=policy)
    state, _ = vstore.gc_step(state, policy=policy, force=True)
    # only the 4 current versions remain
    assert int(vstore.live_versions(state)) == 4
    assert int(state.overflow_count) == 0


def test_ebr_cannot_reclaim_middle_versions():
    """The paper's EBR pathology, reproduced in the bulk-sync layer: an old
    pinned reader blocks reclamation of every later-closed version, even ones
    no reader needs."""
    S, V = 4, 16
    state = vstore.make_state(S, V, 2)
    ids = jnp.array([0], jnp.int32)
    m = jnp.array([True])
    # write once, pin a reader at t=1, then write many more versions
    state, _, _ = vstore.write_step(state, ids, jnp.array([1], jnp.int32), m, policy="ebr")
    state, _ = vstore.begin_snapshot(state, jnp.array([0], jnp.int32), m)
    for i in range(2, 12):
        state, _, _ = vstore.write_step(state, ids, jnp.array([i], jnp.int32), m, policy="ebr")
    state, _ = vstore.gc_step(state, policy="ebr")
    ebr_live = int(vstore.live_versions(state))

    # same history under slrt
    state2 = vstore.make_state(S, V, 2, ring_capacity=8)
    state2, _, _ = vstore.write_step(state2, ids, jnp.array([1], jnp.int32), m, policy="slrt")
    state2, _ = vstore.begin_snapshot(state2, jnp.array([0], jnp.int32), m)
    for i in range(2, 12):
        state2, _, _ = vstore.write_step(state2, ids, jnp.array([i], jnp.int32), m, policy="slrt")
        state2, _ = vstore.gc_step(state2, policy="slrt")
    state2, _ = vstore.gc_step(state2, policy="slrt", force=True)
    slrt_live = int(vstore.live_versions(state2))

    # EBR keeps every version since the pin; SL-RT keeps pinned + current
    assert ebr_live == 11, f"EBR live={ebr_live}"
    assert slrt_live == 2, f"SL-RT live={slrt_live}"


# ---------------------------------------------------------------------------
# kernel-path differential: use_kernel=True (Pallas, interpret) must produce
# byte-identical states to the lax fallback on every sweep/pressure/read path
# (DESIGN.md §12)
# ---------------------------------------------------------------------------
def _states_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.store.ts), np.asarray(b.store.ts))
    np.testing.assert_array_equal(np.asarray(a.store.succ), np.asarray(b.store.succ))
    np.testing.assert_array_equal(np.asarray(a.store.payload),
                                  np.asarray(b.store.payload))
    assert int(a.now) == int(b.now)
    assert int(a.overflow_count) == int(b.overflow_count)


@pytest.mark.parametrize("policy", ["slrt", "steam", "sweep"])
def test_use_kernel_differential_random_trace(policy):
    """Random retire trace (writes, pins/unpins, gc steps, pressure events)
    replayed through two states — kernel path vs lax fallback — must keep the
    descriptor slabs byte-identical at every step, and snapshot reads /
    gathers must agree."""
    rng = random.Random(sum(map(ord, policy)))
    S, V, P = 12, 16, 4
    kern = vstore.make_state(S, V, P, ring_capacity=64)
    base = vstore.make_state(S, V, P, ring_capacity=64)
    values = jnp.arange(S * V * 3, dtype=jnp.int32).reshape(S * V, 3)
    pins = {}
    payload_ctr = 0

    for step in range(30):
        k = rng.randint(1, 3)
        slots = rng.sample(range(S), k)
        pls = [payload_ctr + j for j in range(k)]
        payload_ctr += k
        ids = jnp.array(slots, jnp.int32)
        pl = jnp.array([p % (S * V) for p in pls], jnp.int32)
        m = jnp.ones((k,), bool)
        kern, _, _ = vstore.write_step(kern, ids, pl, m, policy=policy,
                                       use_kernel=True, interpret=True)
        base, _, _ = vstore.write_step(base, ids, pl, m, policy=policy,
                                       use_kernel=False)
        if rng.random() < 0.3:
            lane = rng.randrange(P)
            if lane in pins:
                am = jnp.array([True])
                al = jnp.array([lane], jnp.int32)
                kern = vstore.end_snapshot(kern, al, am)
                base = vstore.end_snapshot(base, al, am)
                del pins[lane]
            else:
                al = jnp.array([lane], jnp.int32)
                am = jnp.array([True])
                kern, ts_k = vstore.begin_snapshot(kern, al, am)
                base, ts_b = vstore.begin_snapshot(base, al, am)
                assert int(ts_k[0]) == int(ts_b[0])
                pins[lane] = int(ts_k[0])
        if rng.random() < 0.4:
            kern, _ = vstore.gc_step(kern, policy=policy, use_kernel=True,
                                     interpret=True)
            base, _ = vstore.gc_step(base, policy=policy)
        if rng.random() < 0.15:
            hot = vstore.hot_slots(base, 4)
            deficit = jnp.int32(rng.randint(1, 8))
            kern, _, nk = vstore.reclaim_on_pressure(
                kern, hot, deficit, policy=policy, use_kernel=True,
                interpret=True)
            base, _, nb = vstore.reclaim_on_pressure(
                base, hot, deficit, policy=policy)
            assert int(nk) == int(nb)
        _states_equal(kern, base)

        # reader-path parity at every pinned timestamp
        for t in pins.values():
            q = jnp.arange(S, dtype=jnp.int32)
            pk, fk = vstore.snapshot_read(kern, q, jnp.int32(t),
                                          use_kernel=True)
            pb, fb = vstore.snapshot_read(base, q, jnp.int32(t))
            np.testing.assert_array_equal(np.asarray(pk), np.asarray(pb))
            np.testing.assert_array_equal(np.asarray(fk), np.asarray(fb))
            rk = vstore.snapshot_gather(kern, q, jnp.int32(t), values,
                                        use_kernel=True)
            rb = vstore.snapshot_gather(base, q, jnp.int32(t), values)
            for gk, gb in zip(rk, rb):
                np.testing.assert_array_equal(np.asarray(gk), np.asarray(gb))


# ---------------------------------------------------------------------------
# checkpoint-coupled eviction: turso's sole-survivor rule (DESIGN.md §14)
# ---------------------------------------------------------------------------
class TestCheckpointEviction:
    S, V, P = 4, 4, 2

    def _state(self):
        return vstore.make_state(self.S, self.V, self.P, ring_capacity=16)

    def _write(self, st, slots, payloads):
        st, _, ovf = vstore.write_step(
            st, jnp.asarray(slots, jnp.int32),
            jnp.asarray(payloads, jnp.int32),
            jnp.ones((len(slots),), bool))
        assert not bool(ovf.any())
        return st

    def test_kill_mask_requires_every_condition(self):
        st = self._write(self._state(), [0, 1, 2], [10, 11, 12])
        ck = int(st.now)
        st = self._write(st, [2], [22])      # slot 2 written after the ckpt
        kill = np.asarray(vstore.ckpt_kill_mask(st, jnp.int32(ck)))
        # idle sole survivors at ts <= ckpt_max: evictable
        assert int(kill[0].sum()) == 1 and int(kill[1].sum()) == 1
        # written-since-checkpoint slot: chain length 2 AND current version
        # past ckpt_max — nothing evictable (durable copy is stale)
        assert int(kill[2].sum()) == 0
        assert int(kill[3].sum()) == 0       # empty slot
        # the EMPTY sentinel disables the rule without retracing
        assert int(np.asarray(
            vstore.ckpt_kill_mask(st, jnp.int32(EMPTY))).sum()) == 0

    def test_pins_block_eviction_like_every_policy(self):
        st = self._write(self._state(), [0, 1], [10, 11])
        st, _ = vstore.begin_snapshot(
            st, jnp.array([0], jnp.int32), jnp.array([True]))
        ck = int(st.now)
        assert int(np.asarray(
            vstore.ckpt_kill_mask(st, jnp.int32(ck))).sum()) == 0
        st = vstore.end_snapshot(
            st, jnp.array([0], jnp.int32), jnp.array([True]))
        # unpinned but the epoch hasn't advanced: the EBR bound is `now`,
        # so ts == now versions stay protected (a writer may still be in
        # this epoch) ...
        assert int(np.asarray(
            vstore.ckpt_kill_mask(st, jnp.int32(ck))).sum()) == 0
        # ... one later write advances the clock and unlocks both
        st = self._write(st, [3], [33])
        assert int(np.asarray(
            vstore.ckpt_kill_mask(st, jnp.int32(ck))).sum()) == 2
        # extra_pins (the sharded stack's global LWM) is honoured identically
        pinned = np.asarray(vstore.ckpt_kill_mask(
            st, jnp.int32(ck), extra_pins=jnp.array([ck], jnp.int32)))
        assert int(pinned.sum()) == 0

    def test_evict_checkpointed_frees_and_reports(self):
        st = self._write(self._state(), [0, 1, 2, 3], [10, 11, 12, 13])
        ck = int(st.now)
        st = self._write(st, [3], [33])      # clock past the ckpt epoch
        st2, freed, n = vstore.evict_checkpointed(st, jnp.int32(ck))
        freed = np.asarray(freed)
        assert sorted(freed[freed != EMPTY].tolist()) == [10, 11, 12]
        assert int(n) == 3
        _, found = pool.read_current(st2.store,
                                     jnp.arange(3, dtype=jnp.int32))
        assert not bool(np.asarray(found).any())   # cold-miss until restore

    @pytest.mark.parametrize("policy", ["ebr", "steam", "dlrt", "slrt"])
    def test_gc_step_ckpt_post_pass_inherited_by_every_policy(self, policy):
        """No policy can evict a current version on its own; with ckpt_max
        threaded through gc_step every policy inherits the new reclamation
        edge with zero policy-specific code."""
        st = self._write(self._state(), [0, 1], [10, 11])
        ck = int(st.now)
        st = self._write(st, [2], [22])      # clock past the ckpt epoch
        _, freed_plain = vstore.gc_step(st, policy=policy, force=True)
        plain = np.asarray(freed_plain).reshape(-1)
        assert (plain == EMPTY).all()
        st2, freed_ck = vstore.gc_step(st, policy=policy, force=True,
                                       ckpt_max=jnp.int32(ck))
        got = np.asarray(freed_ck).reshape(-1)
        assert sorted(got[got != EMPTY].tolist()) == [10, 11]
        assert int(vstore.live_versions(st2)) == 1   # slot 2 survives

    @pytest.mark.parametrize("policy", ["ebr", "steam", "dlrt", "slrt"])
    def test_reclaim_on_pressure_ckpt_post_pass(self, policy):
        st = self._write(self._state(), [0, 1, 2], [10, 11, 12])
        ck = int(st.now)
        st = self._write(st, [3], [33])      # clock past the ckpt epoch
        hot = vstore.hot_slots(st, 2)
        _, _, n_plain = vstore.reclaim_on_pressure(
            st, hot, jnp.int32(8), policy=policy)
        st2, _, n_ck = vstore.reclaim_on_pressure(
            st, hot, jnp.int32(8), policy=policy, ckpt_max=jnp.int32(ck))
        assert int(n_plain) == 0               # sole current versions: stuck
        assert int(n_ck) == 3                  # the checkpoint unlocks them
        assert int(vstore.live_versions(st2)) == 1   # slot 3 survives
