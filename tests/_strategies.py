"""Vendored mini-hypothesis: just enough of the `hypothesis` API for this
repo's property tests to collect *and run* when the real package is absent.

``tests/conftest.py`` installs this module as ``sys.modules["hypothesis"]``
only when ``import hypothesis`` fails, so installing the real package
transparently upgrades the tests to full shrinking/replay behaviour.

Supported surface (everything the test suite uses):
  * ``@settings(max_examples=N, deadline=None)``
  * ``@given(name=strategy, ...)`` (keyword style only)
  * ``strategies.integers(lo, hi)``, ``strategies.lists(elem, min_size=,
    max_size=)``, ``strategies.sampled_from(seq)``, ``strategies.booleans()``,
    ``strategies.data()`` with ``data.draw(strategy)``

Draws are deterministic per test (seeded from the test's qualified name), so
failures reproduce run-to-run; there is no shrinking.
"""
from __future__ import annotations

import functools
import inspect
import random
import types
import zlib

DEFAULT_MAX_EXAMPLES = 50


class SearchStrategy:
    def __init__(self, draw_fn, label="strategy"):
        self._draw_fn = draw_fn
        self._label = label

    def do_draw(self, rnd: random.Random):
        return self._draw_fn(rnd)

    def __repr__(self):
        return f"<mini-hypothesis {self._label}>"


def integers(min_value, max_value):
    return SearchStrategy(lambda r: r.randint(min_value, max_value),
                          f"integers({min_value}, {max_value})")


def booleans():
    return SearchStrategy(lambda r: bool(r.getrandbits(1)), "booleans()")


def sampled_from(elements):
    seq = list(elements)
    return SearchStrategy(lambda r: seq[r.randrange(len(seq))], "sampled_from")


def floats(min_value=0.0, max_value=1.0):
    return SearchStrategy(lambda r: r.uniform(min_value, max_value), "floats")


def lists(elements, *, min_size=0, max_size=None):
    hi = max_size if max_size is not None else min_size + 10

    def draw(r):
        return [elements.do_draw(r) for _ in range(r.randint(min_size, hi))]

    return SearchStrategy(draw, f"lists(min={min_size}, max={hi})")


class DataObject:
    """Interactive draws: ``data.draw(st.integers(0, 3))``."""

    def __init__(self, rnd: random.Random):
        self._rnd = rnd

    def draw(self, strategy: SearchStrategy, label=None):
        return strategy.do_draw(self._rnd)


class _DataStrategy(SearchStrategy):
    def __init__(self):
        super().__init__(lambda r: DataObject(r), "data()")


def data():
    return _DataStrategy()


def _example_count(fn) -> int:
    return getattr(fn, "_mini_hyp_max_examples", DEFAULT_MAX_EXAMPLES)


def given(*args, **strategy_kwargs):
    if args:
        raise TypeError("mini-hypothesis supports @given(keyword=strategy) only")

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*call_args, **call_kwargs):
            seed0 = zlib.crc32(fn.__qualname__.encode())
            for example in range(_example_count(wrapper)):
                rnd = random.Random((seed0 << 20) + example)
                drawn = {name: strat.do_draw(rnd)
                         for name, strat in strategy_kwargs.items()}
                try:
                    fn(*call_args, **drawn, **call_kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"mini-hypothesis example {example} "
                        f"(kwargs={_fmt(drawn)}) failed: {e!r}") from e

        # pytest must not treat the strategy kwargs as fixtures: expose a
        # signature with them stripped, and drop __wrapped__ so introspection
        # does not tunnel back to the original function.
        sig = inspect.signature(fn)
        kept = [p for name, p in sig.parameters.items()
                if name not in strategy_kwargs]
        wrapper.__signature__ = sig.replace(parameters=kept)
        del wrapper.__wrapped__
        wrapper.is_hypothesis_test = True
        return wrapper

    return decorate


def _fmt(drawn, limit=200):
    s = repr({k: v for k, v in drawn.items() if not isinstance(v, DataObject)})
    return s if len(s) <= limit else s[:limit] + "..."


def settings(max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def decorate(fn):
        fn._mini_hyp_max_examples = max_examples
        return fn

    return decorate


def build_module() -> types.ModuleType:
    """Assemble a module object mimicking the ``hypothesis`` package."""
    hyp = types.ModuleType("hypothesis")
    hyp.__doc__ = __doc__
    hyp.given = given
    hyp.settings = settings
    hyp.HealthCheck = types.SimpleNamespace(all=lambda: [])

    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "booleans", "sampled_from", "floats", "lists",
                 "data"):
        setattr(strategies, name, globals()[name])
    strategies.SearchStrategy = SearchStrategy
    strategies.DataObject = DataObject

    hyp.strategies = strategies
    return hyp
