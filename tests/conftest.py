"""Shared test setup.

* Puts ``src`` on ``sys.path`` so ``python -m pytest`` works without the
  ``PYTHONPATH=src`` prefix (the tier-1 command keeps working too).
* Makes ``hypothesis`` a *soft* dependency: when the real package is not
  installed, the vendored mini-implementation in ``tests/_strategies.py`` is
  registered as ``sys.modules["hypothesis"]`` before collection, so the
  property-test modules import, collect, and run (deterministic seeded draws,
  no shrinking).  Installing real hypothesis transparently takes precedence.
"""
import importlib.util
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(os.path.dirname(_HERE), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401  (the real thing, if present)
except ModuleNotFoundError:
    _spec = importlib.util.spec_from_file_location(
        "_repro_mini_hypothesis", os.path.join(_HERE, "_strategies.py"))
    _mini = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mini)
    _mod = _mini.build_module()
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies
