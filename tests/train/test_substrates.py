"""Substrate tests: optimizer, compression, data pipeline, checkpointing
(+restart, +elastic, +MVGC retention), straggler watchdog, train_step."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from repro.ckpt.manager import CheckpointManager
from repro.configs import reduced_config
from repro.configs.base import RunConfig, SHAPES
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.dist.straggler import StepWatchdog
from repro.optim import adamw
from repro.optim.compress import (compress_tree, decompress_tree, init_error)
from repro.train.step import TrainState, init_state, train_step


class TestAdamW:
    def test_minimizes_quadratic(self):
        params = {"w": jnp.array([5.0, -3.0])}
        opt = adamw.init(params)
        for _ in range(200):
            grads = jax.tree.map(lambda w: 2 * w, params)
            params, opt, _ = adamw.apply(params, grads, opt, lr=0.1,
                                         weight_decay=0.0)
        assert float(jnp.abs(params["w"]).max()) < 0.1

    def test_clipping(self):
        params = {"w": jnp.zeros(3)}
        opt = adamw.init(params)
        _, _, m = adamw.apply(params, {"w": jnp.full((3,), 1e6)}, opt, lr=0.1,
                              grad_clip=1.0)
        assert m["grad_norm"] > 1e5  # reported pre-clip

    def test_schedule(self):
        lr0 = adamw.cosine_schedule(jnp.int32(0), base_lr=1.0, warmup=10, total=100)
        lrw = adamw.cosine_schedule(jnp.int32(10), base_lr=1.0, warmup=10, total=100)
        lre = adamw.cosine_schedule(jnp.int32(100), base_lr=1.0, warmup=10, total=100)
        assert float(lr0) == 0.0 and abs(float(lrw) - 1.0) < 1e-5
        assert float(lre) <= 0.11


class TestCompression:
    def test_error_feedback_converges(self):
        """Sum of dequantized grads + final error == sum of true grads."""
        rng = np.random.default_rng(0)
        tree = {"a": jnp.zeros((64,)), "b": jnp.zeros((8, 8))}
        err = init_error(tree)
        total_true = jax.tree.map(jnp.zeros_like, tree)
        total_sent = jax.tree.map(jnp.zeros_like, tree)
        for i in range(20):
            g = jax.tree.map(
                lambda z: jnp.array(rng.standard_normal(z.shape), jnp.float32),
                tree)
            q, s, err = compress_tree(g, err)
            deq = decompress_tree(q, s)
            total_true = jax.tree.map(jnp.add, total_true, g)
            total_sent = jax.tree.map(jnp.add, total_sent, deq)
        for k in tree:
            resid = np.abs(np.asarray(total_true[k] - total_sent[k] - err[k]))
            assert resid.max() < 1e-4, "error feedback must capture all residual"

    def test_4x_byte_reduction(self):
        g = {"w": jnp.ones((1024,), jnp.float32)}
        q, s, _ = compress_tree(g, init_error(g))
        assert q["w"].dtype == jnp.int8 and q["w"].nbytes == g["w"].nbytes // 4


class TestData:
    def test_deterministic_and_resumable(self):
        cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=8)
        a = SyntheticLM(cfg)
        b1 = next(a)
        b2 = next(a)
        b = SyntheticLM(cfg)
        b.load_state_dict({"step": 1})
        np.testing.assert_array_equal(next(b)["tokens"], b2["tokens"])

    def test_sharding_partitions_batch(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8)
        p = SyntheticLM(cfg)
        batch = p.batch_at(0)
        parts = [p.shard_batch(batch, i, 4)["tokens"] for i in range(4)]
        np.testing.assert_array_equal(np.concatenate(parts), batch["tokens"])

    def test_copy_structure_is_learnable_signal(self):
        cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=4,
                         copy_period=16)
        b = SyntheticLM(cfg).batch_at(0)["tokens"]
        # positions in the second half of each period repeat the first half
        assert (b[:, 8:16] == b[:, 0:8]).all()


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = {"w": jnp.arange(6.0).reshape(2, 3), "b": [jnp.ones(2)]}
        mgr.save(10, tree, extra={"data_step": 7})
        got, extra = mgr.restore(10, like=tree)
        np.testing.assert_array_equal(got["w"], tree["w"])
        assert extra["data_step"] == 7
        assert mgr.latest_step() == 10

    def test_restart_resumes_training(self, tmp_path):
        cfg = reduced_config("minitron-4b")
        run = RunConfig(model=cfg, shape=SHAPES["train_4k"], lr=1e-3)
        data = SyntheticLM(DataConfig(cfg.vocab_size, 16, 4))
        state = init_state(cfg, jax.random.PRNGKey(0))
        mgr = CheckpointManager(str(tmp_path))
        for i in range(3):
            state, m = train_step(state, _jb(next(data)), cfg, run)
        mgr.save(3, state, extra=data.state_dict())
        state4, _ = train_step(state, _jb(next(data)), cfg, run)

        # crash + restart
        state_r, extra = mgr.restore(3, like=state)
        data_r = SyntheticLM(DataConfig(cfg.vocab_size, 16, 4))
        data_r.load_state_dict(extra)
        state4_r, _ = train_step(TrainState(*state_r), _jb(next(data_r)), cfg, run)
        for a, b in zip(jax.tree.leaves(state4.params),
                        jax.tree.leaves(state4_r.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)

    def test_mvgc_retention(self, tmp_path):
        """Checkpoint GC = the paper's needed(A,t) at the artifact layer."""
        mgr = CheckpointManager(str(tmp_path))
        tree = {"w": jnp.ones(2)}
        for s in [10, 20, 30, 40]:
            mgr.save(s, tree)
        mgr.announce("evaluator", 20)     # pins [20, 30)
        deleted = mgr.gc(keep_last=1)
        assert 10 in deleted and 30 in deleted
        assert sorted(mgr.steps()) == [20, 40]
        mgr.unannounce("evaluator")
        mgr.gc(keep_last=1)
        assert mgr.steps() == [40]

    def test_atomic_commit_no_partial(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"w": jnp.ones(4)})
        # a stale tmp dir from a crashed save must not count as a checkpoint
        os.makedirs(tmp_path / ".tmp-2")
        assert mgr.steps() == [1]


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(k_sigma=3.0, min_budget_s=0.0)
    import time
    for i in range(10):
        wd.start(); time.sleep(0.001); wd.stop(i)
    wd.start(); time.sleep(0.08); wd.stop(99)
    assert 99 in wd.suspect_steps


class TestTrainStep:
    def test_loss_decreases(self):
        cfg = reduced_config("minitron-4b")
        run = RunConfig(model=cfg, shape=SHAPES["train_4k"], lr=3e-3)
        data = SyntheticLM(DataConfig(cfg.vocab_size, 32, 8, copy_period=8))
        state = init_state(cfg, jax.random.PRNGKey(0))
        step = jax.jit(lambda s, b: train_step(s, b, cfg, run))
        losses = []
        for i in range(30):
            state, m = step(state, _jb(next(data)))
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.2, f"no learning: {losses[0]:.3f} -> {losses[-1]:.3f}"

    def test_microbatching_matches_full_batch_loss(self):
        cfg = reduced_config("minitron-4b")
        data = SyntheticLM(DataConfig(cfg.vocab_size, 16, 8))
        batch = _jb(next(data))
        state = init_state(cfg, jax.random.PRNGKey(0))
        run1 = RunConfig(model=cfg, shape=SHAPES["train_4k"], microbatches=1)
        run4 = RunConfig(model=cfg, shape=SHAPES["train_4k"], microbatches=4)
        _, m1 = train_step(state, batch, cfg, run1)
        _, m4 = train_step(state, batch, cfg, run4)
        assert abs(float(m1["loss"]) - float(m4["loss"])) < 2e-3

    def test_compression_path_trains(self):
        cfg = reduced_config("minitron-4b")
        run = RunConfig(model=cfg, shape=SHAPES["train_4k"], lr=3e-3,
                        grad_compression=True)
        data = SyntheticLM(DataConfig(cfg.vocab_size, 32, 8, copy_period=8))
        state = init_state(cfg, jax.random.PRNGKey(0), compression=True)
        step = jax.jit(lambda s, b: train_step(s, b, cfg, run))
        l0 = ln = None
        for i in range(25):
            state, m = step(state, _jb(next(data)))
            l0 = l0 if l0 is not None else float(m["loss"])
            ln = float(m["loss"])
        assert ln < l0 - 0.1


def _jb(batch):
    return {k: jnp.asarray(v) for k, v in batch.items()}
