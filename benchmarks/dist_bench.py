"""Sharded multi-host MVGC bench: global-LWM reclamation under pressure
(DESIGN.md §13).

Drives ``repro.dist.mvgc.ShardedPagedKVEngine`` — continuous decode over a
fixed batch of sequences **per host**, each restarting (``reset``) on
reaching a random target length, with per-host page pools undersized exactly
like ``serve_bench``'s storm tier so pressure events drive the synchronous
reclaim loop on every shard.  Every GC-bearing step refreshes the mesh-wide
low-water mark (per-host oldest pin -> staleness aging -> ``reduce="min"``
ring all-reduce) and threads it through the shard GC as ``extra_pins``.

Snapshot-scoring readers pin on rotating hosts mid-storm: while a pin is
held — across reclaims on *every* shard — the pinned host's view is
re-resolved each step and must be byte-identical.  A mismatch means a shard
reclaimed a version pinned by some host, i.e. the global-LWM protocol is
broken; rows record it as ``pin_violations`` (must be 0 — the dist schema
invariant and ``_post_check`` both fail on any).

The ``stall`` tier wedges one host mid-run (its announcement age is frozen
past the staleness budget via ``virtual_ages_s`` — deterministic, no wall
clock) while it holds a pin: the stale lane is aged out of the reduction
(``stale_lanes_aged`` > 0), the LWM advances past its pin
(``lwm_advances``), and the remaining hosts' reclamation proceeds.  The
stalled host's *local* board still protects its own shard, so its held
snapshot stays byte-stable — stalling bounds reclamation, never breaks it.

Rows are ``DistMeasurement`` (serve fields summed over all hosts — space in
**global pages** — plus the dist fields in ``units["dist_bench"]``).

  python benchmarks/dist_bench.py                  # standard tier
  python benchmarks/dist_bench.py --smoke          # tiny CI matrix (seconds)
  python benchmarks/dist_bench.py --tiers smoke,standard,stall
  python benchmarks/dist_bench.py --out PATH

The committed repo-root ``BENCH_dist.json`` is generated with
``--tiers smoke,standard,stall`` so the CI ``bench-trajectory`` step can
compare a fresh ``--smoke`` run cell-for-cell against the committed smoke
rows while the trajectory keeps the stall tier proving straggler-tolerant
reclamation (``check_bench_json --require-pressure`` on the dist schema).
"""
from __future__ import annotations

import os
import random
import sys
import time
from typing import Dict, List

# The bench exercises the real reduce="min" ring: fake one host device per
# shard before jax initializes.  (No-op when jax is already imported — the
# engine then degrades to the unsharded path, which computes identical
# values; the flag only decides *where* the reduction runs.)
if "jax" not in sys.modules:
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=4" + (
            " " + os.environ["XLA_FLAGS"] if "XLA_FLAGS" in os.environ
            else ""))

import jax
import numpy as np
import jax.numpy as jnp

jax.config.update("jax_platform_name", "cpu")

from repro.core.mvgc.pool import EMPTY
from repro.core.sim.measure import BenchDriver, DistMeasurement
from repro.core.telemetry import GCConfig
from repro.dist.mvgc import ShardedPagedKVEngine

DEFAULT_OUT = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_dist.json")

POLICIES = ("ebr", "steam", "dlrt", "slrt")

TABLE_COLS = [
    "scheme", "hosts", "decode_steps", "tokens_appended", "pressure_events",
    "reclaims_triggered", "pages_reclaimed", "peak_pages", "lwm_advances",
    "stale_lanes_aged", "stalled_hosts", "give_ups", "scans_validated",
    "pin_violations", "wall_s",
]

# Tier geometry: per-host pools undersized against worst-case demand
# (num_seqs * max_pages_per_seq > num_pages) with shallow version slabs, so
# every shard actually runs out and reclaims against the global LWM.  The
# stall tier freezes one host's announcement age past the (finite)
# staleness budget a third of the way in, while that host holds a pin.
TIERS = {
    "smoke": dict(hosts=2, num_seqs=4, num_pages=10, page_size=4,
                  max_pages_per_seq=3, versions_per_seq=6, steps=18,
                  min_len=4, max_len=9, pin_every=5, pin_hold=3,
                  stall_host=None, stall_after=0, seed=0),
    "standard": dict(hosts=4, num_seqs=4, num_pages=10, page_size=4,
                     max_pages_per_seq=3, versions_per_seq=6, steps=48,
                     min_len=4, max_len=10, pin_every=6, pin_hold=3,
                     stall_host=None, stall_after=0, seed=0),
    "stall": dict(hosts=4, num_seqs=4, num_pages=10, page_size=4,
                  max_pages_per_seq=3, versions_per_seq=6, steps=60,
                  min_len=4, max_len=10, pin_every=6, pin_hold=3,
                  stall_host=1, stall_after=20, seed=0),
}

KV_HEADS, HEAD_DIM, READER_LANES = 1, 4, 4
STALE_AFTER_S = 5.0          # finite staleness budget (stall tier ages past)
STALLED_AGE_S = 100.0        # injected announcement age of the wedged host


def view_checksum(local_st, tables: np.ndarray, lengths: np.ndarray,
                  page_size: int) -> tuple:
    """Content fingerprint of one host's resolved snapshot view: the exact
    K values of every visible token (a wrongly recycled page changes the
    values even when the table row is unchanged)."""
    k = np.asarray(local_st.k_pages)[:, :, 0, 0]
    sums = []
    for s in range(tables.shape[0]):
        n = int(lengths[s])
        vals = tuple(
            float(k[int(tables[s, j // page_size]), j % page_size])
            for j in range(n))
        sums.append((n, vals))
    return tuple(sums)


def run_cell(tier: str, policy: str) -> DistMeasurement:
    p = TIERS[tier]
    H, B, ps = p["hosts"], p["num_seqs"], p["page_size"]
    gc = GCConfig(policy=policy, versions_per_slot=p["versions_per_seq"],
                  reader_lanes=READER_LANES, stale_after_s=STALE_AFTER_S)
    eng = ShardedPagedKVEngine(
        H, B, p["num_pages"], ps, p["max_pages_per_seq"], KV_HEADS,
        HEAD_DIM, gc=gc, dtype=jnp.float32)
    rng = random.Random(p["seed"])
    targets = [[rng.randrange(p["min_len"], p["max_len"] + 1)
                for _ in range(B)] for _ in range(H)]
    cur_len = [[0] * B for _ in range(H)]
    seq_ids = jnp.broadcast_to(jnp.arange(B, dtype=jnp.int32), (H, B))
    live_mask = np.ones((H, B), bool)      # stalled host rows drop out

    tokens = completed = pins = validated = violations = 0
    # (host, lane) -> [pinned ts, reference checksum, steps left to hold]
    live_pins: Dict[tuple, list] = {}
    next_pin = 0

    def check_pins() -> None:
        nonlocal validated, violations
        for (host, lane), rec in live_pins.items():
            ts, ref, _ = rec
            tbl, ln = eng.view_at(host, ts)
            now = view_checksum(eng.host_state(host), np.asarray(tbl),
                                np.asarray(ln), ps)
            validated += 1
            if now != ref:
                violations += 1

    t0 = time.time()
    for step in range(p["steps"]):
        if p["stall_host"] is not None and step == p["stall_after"]:
            # wedge one host: its appends stop, its announcement age jumps
            # past the staleness budget, but its pin (below) stays held
            ages = np.zeros((H,), np.float32)
            ages[p["stall_host"]] = STALLED_AGE_S
            eng.virtual_ages_s = ages
            live_mask[p["stall_host"], :] = False

        # one token per live sequence; per-(host, step, seq) distinct
        # payloads so a cross-host reclaim error shows as a mismatch
        base = (np.arange(H * B, dtype=np.float32).reshape(H, B)
                + H * B * (step + 1))
        kv = jnp.asarray(np.broadcast_to(
            base[:, :, None, None], (H, B, KV_HEADS, HEAD_DIM)))
        failed = np.asarray(eng.step(seq_ids, kv, kv,
                                     jnp.asarray(live_mask)))
        for h in range(H):
            for s in range(B):
                if live_mask[h, s] and not failed[h, s]:
                    tokens += 1
                    cur_len[h][s] += 1

        done = np.array([[cur_len[h][s] >= targets[h][s] for s in range(B)]
                         for h in range(H)]) & live_mask
        if done.any():
            eng.reset(seq_ids, jnp.asarray(done))
            for h, s in zip(*np.nonzero(done)):
                completed += 1
                cur_len[h][s] = 0
                targets[h][s] = rng.randrange(p["min_len"], p["max_len"] + 1)

        # snapshot readers pin on rotating hosts and hold across reclaims
        if step % p["pin_every"] == 0 and len(live_pins) < H:
            host = next_pin % H
            lane = (next_pin // H) % READER_LANES
            next_pin += 1
            if (host, lane) not in live_pins:
                ts = eng.pin(host, lane)
                tbl, ln = eng.view_at(host, ts)
                ref = view_checksum(eng.host_state(host), np.asarray(tbl),
                                    np.asarray(ln), ps)
                live_pins[(host, lane)] = [ts, ref, p["pin_hold"]]
                pins += 1
        check_pins()
        for key in list(live_pins):
            live_pins[key][2] -= 1
            # the stalled host never gets to unpin — that is the point:
            # only staleness aging moves the LWM past it
            if live_pins[key][2] <= 0 and key[0] != p["stall_host"]:
                eng.unpin(*key)
                del live_pins[key]

    check_pins()                       # final resolve of every held pin
    for key in list(live_pins):
        eng.unpin(*key)
    wall = time.time() - t0

    space = eng.space()
    stalled = int((eng.ages_s() > eng.budget_s()).sum())
    ts_arr = np.asarray(eng.st.mv.store.ts)
    occ = (ts_arr != EMPTY).sum(axis=-1)
    steps_n = p["steps"]
    work = tokens + validated
    return DistMeasurement(
        bench="dist", figure=f"dist_kv/{tier}", ds="paged_kv",
        scheme=policy, mix=tier, scan_size=0, zipf=0.0,
        n_keys=space["page_pool"], num_procs=H * B, ops_per_proc=steps_n,
        seed=p["seed"], updates=tokens, lookups=0, scans=pins,
        scan_keys=validated, total_work=work,
        ops_per_mwork=round((tokens + pins) / max(1, work) * 1e6, 3),
        updates_per_mwork=round(tokens / max(1, work) * 1e6, 3),
        scan_keys_per_mwork=round(validated / max(1, work) * 1e6, 3),
        peak_space_words=space["peak_pages"],
        peak_versions=int(occ.max()),
        avg_space_words=0,
        end_space_words=space["live_pages"],
        end_versions_per_list=round(int((ts_arr != EMPTY).sum()) / (H * B), 4),
        scans_validated=validated, scan_violations=violations,
        wall_s=round(wall, 2),
        reclaims_triggered=space["reclaims_triggered"],
        peak_space_post_reclaim=space["peak_pages_post_reclaim"],
        pressure_events=space["pressure_events"],
        pages_reclaimed=space["pages_reclaimed"],
        peak_pages=space["peak_pages"],
        peak_pages_post_reclaim=space["peak_pages_post_reclaim"],
        page_pool=space["page_pool"], page_size=ps,
        decode_steps=steps_n, tokens_appended=tokens,
        sequences_completed=completed, forks=0,
        give_ups=space["give_ups"], snapshot_pins=pins,
        overflow_count=space["overflows"],
        dropped_retires=space["dropped_retires"],
        hosts=H, lwm=space["lwm"], lwm_advances=space["lwm_advances"],
        stale_lanes_aged=space["stale_lanes_aged"], stalled_hosts=stalled,
        under_pressure_hosts=space["under_pressure_hosts"],
        pin_violations=violations,
    )


def run_tier(tier: str) -> List[DistMeasurement]:
    rows = []
    for policy in POLICIES:
        m = run_cell(tier, policy)
        rows.append(m)
        if m.pin_violations:
            print(f"!! pin violations in {tier}/{policy}: "
                  f"{m.pin_violations}", file=sys.stderr)
    return rows


def _summarize(rows: List[DistMeasurement]) -> str:
    return (f"{sum(m.tokens_appended for m in rows)} tokens over "
            f"{max(m.hosts for m in rows)} hosts, "
            f"{sum(m.pressure_events for m in rows)} pressure events, "
            f"{sum(m.reclaims_triggered for m in rows)} reclaims freed "
            f"{sum(m.pages_reclaimed for m in rows)} pages, "
            f"{sum(m.stale_lanes_aged for m in rows)} stale lanes aged, "
            f"{sum(m.pin_violations for m in rows)} pin violations")


def _post_check(rows: List[DistMeasurement]) -> List[str]:
    problems = []
    violations = sum(m.pin_violations for m in rows)
    if violations:
        problems.append(f"global-LWM pin violations detected ({violations})")
    stall_rows = [m for m in rows if m.stalled_hosts]
    for m in stall_rows:
        if m.stale_lanes_aged == 0:
            problems.append(
                f"{m.figure}/{m.scheme}: stalled host never aged out "
                f"of the LWM reduction")
    return problems


DRIVER = BenchDriver(
    bench="dist", schema="dist", tiers=TIERS, run_tier=run_tier,
    default_out=DEFAULT_OUT, table_cols=TABLE_COLS, col_width=14,
    summarize=_summarize, post_check=_post_check,
)


def main(argv=None) -> int:
    return DRIVER.main(argv)


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
