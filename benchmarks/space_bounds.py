"""Theorem 1 / space-bound validation (paper §3, §5).

Measures, as the workload scales:
  * PDL/SSL reachable nodes vs the L - R + P bound,
  * RT-scheme reachable versions vs O(H + P^2 log P) (Theorem 1),
  * EBR's unbounded growth under a pinned long rtx (the contrast).
"""
from __future__ import annotations

import math
from typing import Dict, List

from repro.core.sim.rangetracker import RangeTracker
from repro.core.sim.schemes import make_scheme
from repro.core.sim.ssl_list import MVEnv
from repro.core.sim.vcas import VCas
from repro.core.sim.workload import WorkloadConfig, measure_space, run_workload


def theorem1_sweep() -> List[Dict]:
    """Reachable versions under one pinned reader while updates flow."""
    rows = []
    for P in (4, 8, 16, 32):
        for scheme_name in ("slrt", "ebr"):
            env = MVEnv(P)
            scheme = make_scheme(scheme_name, env)
            objs = [VCas(env, scheme, 0) for _ in range(64)]
            # reader pins t=now; H = 64 needed versions (one per object)
            env.advance_ts()
            t_pin = scheme.begin_rtx(0)
            n_updates = 200 * P
            for i in range(n_updates):
                env.advance_ts()
                objs[i % 64].cas(1 + (i % (P - 1)) if P > 1 else 0,
                                 objs[i % 64].read(), i)
            reach = sum(len(o.lst.reachable_nodes()) for o in objs)
            aux = scheme.aux_space_words()
            H = 2 * 64  # pinned + current version per object
            bound = 4 * (H + P * P * max(1, int(math.log2(P)))) + 64
            rows.append({
                "P": P, "scheme": scheme_name, "updates": n_updates,
                "reachable_versions": reach, "rt_aux_words": aux,
                "thm1_bound": bound,
                "within_bound": reach <= bound if scheme_name == "slrt" else "-",
            })
            scheme.end_rtx(0)
    return rows


def lrp_bound_sweep() -> List[Dict]:
    """L - R + P bound on reachable list nodes at quiescence."""
    rows = []
    for scheme_name in ("slrt", "dlrt"):
        for n_ops in (500, 2000):
            cfg = WorkloadConfig(
                ds="hash", scheme=scheme_name, n_keys=256, num_procs=12,
                ops_per_proc=n_ops // 12, mode="split", sample_every=10_000,
                seed=3, scheme_kwargs={"batch_size": 12},
            )
            r = run_workload(cfg)
            s = r["end_space"]
            rows.append({
                "scheme": scheme_name, "ops": n_ops,
                "end_versions": s["versions"], "lists": s["lists"],
                "bound_L_R_P": s["lists"] + cfg.num_procs,
                "ok": s["versions"] <= s["lists"] + cfg.num_procs,
            })
    return rows


def main() -> Dict[str, List[Dict]]:
    t1 = theorem1_sweep()
    print("\n== Theorem 1: reachable versions under a pinned reader ==")
    for r in t1:
        print("   ", r)
    l1 = lrp_bound_sweep()
    print("\n== L - R + P bound at quiescence ==")
    for r in l1:
        print("   ", r)
    return {"theorem1": t1, "lrp": l1}


if __name__ == "__main__":
    main()
