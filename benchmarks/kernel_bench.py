"""Kernel & vectorized-MVGC microbenchmarks.

Wall-clock on this container measures the *XLA CPU* path (the production jit
fallback) — real TPU kernel timing needs hardware; the Pallas kernels are
validated in interpret mode (tests/kernels) and their roofline behaviour is
derived in EXPERIMENTS.md.  What IS meaningful here:

  * vectorized MVGC policy cost (needed-sweep / ring-flush / write) per
    version — the serving control-plane budget,
  * version_search (the rtx read path) throughput,
  * the jnp flash-attention reference per-token cost (sanity scaling).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, iters: int = 20, warmup: int = 3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bench_mvgc_policies() -> List[Dict]:
    from repro.core.mvgc import vstore
    rows = []
    S, V, P = 4096, 8, 64
    for policy in ("slrt", "dlrt", "steam", "ebr", "sweep"):
        state = vstore.make_state(S, V, P, ring_capacity=S)
        ids = jnp.arange(256, dtype=jnp.int32)
        pl = jnp.arange(256, dtype=jnp.int32)
        m = jnp.ones((256,), bool)
        wstep = jax.jit(lambda st: vstore.write_step(st, ids, pl, m,
                                                     policy=policy)[0])
        gstep = jax.jit(lambda st: vstore.gc_step(st, policy=policy)[0])
        us_w = _time(wstep, state)
        us_g = _time(gstep, state)
        rows.append({
            "name": f"mvgc_write_{policy}", "us_per_call": round(us_w, 1),
            "derived": f"{256 / us_w:.2f} writes/us (S={S},V={V})",
        })
        rows.append({
            "name": f"mvgc_gc_{policy}", "us_per_call": round(us_g, 1),
            "derived": f"{S * V / us_g:.1f} entries/us swept",
        })
    return rows


def bench_version_search() -> List[Dict]:
    from repro.kernels.version_search.ref import search_ref
    rows = []
    for S, V, B in [(4096, 8, 1024), (65536, 8, 4096)]:
        rng = np.random.default_rng(0)
        ts = jnp.array(rng.integers(0, 1000, (S, V)), jnp.int32)
        pay = jnp.array(rng.integers(0, 1 << 20, (S, V)), jnp.int32)
        ids = jnp.array(rng.integers(0, S, B), jnp.int32)
        t = jnp.array(rng.integers(0, 1000, B), jnp.int32)
        f = jax.jit(search_ref)
        us = _time(f, ts, pay, ids, t)
        rows.append({
            "name": f"version_search_S{S}_B{B}",
            "us_per_call": round(us, 1),
            "derived": f"{B / us:.2f} lookups/us (rtx read path)",
        })
    return rows


def bench_flash_ref() -> List[Dict]:
    from repro.kernels.flash_prefill.ref import attention_ref
    rows = []
    for B, H, T, D, win in [(1, 8, 512, 64, 0), (1, 8, 1024, 64, 256)]:
        rng = np.random.default_rng(1)
        q = jnp.array(rng.standard_normal((B, H, T, D)), jnp.float32)
        k = jnp.array(rng.standard_normal((B, H, T, D)), jnp.float32)
        v = jnp.array(rng.standard_normal((B, H, T, D)), jnp.float32)
        f = jax.jit(lambda a, b, c: attention_ref(a, b, c, window=win))
        us = _time(f, q, k, v, iters=5)
        rows.append({
            "name": f"attn_ref_T{T}_win{win}",
            "us_per_call": round(us, 1),
            "derived": f"{B * H * T / us:.2f} tok/us",
        })
    return rows


def main() -> List[Dict]:
    rows = bench_mvgc_policies() + bench_version_search() + bench_flash_ref()
    print("\n== kernel / mvgc microbench ==")
    print(f"{'name':32s} {'us_per_call':>12s}  derived")
    for r in rows:
        print(f"{r['name']:32s} {r['us_per_call']:>12.1f}  {r['derived']}")
    return rows


if __name__ == "__main__":
    main()
