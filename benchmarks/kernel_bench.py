"""Tiered kernel bench: the fused GC primitives vs their unfused baselines.

Times the two fused Pallas primitives that carry the serving GC path —
``compact`` (needed + splice in one launch, DESIGN.md §12) and
``search_gather`` (snapshot search + value-row gather in one launch) —
against the explicitly *unfused* two-dispatch lax baseline they replaced
(needed-mask then splice; search then index — two synchronous launches with
the intermediate round-tripping through memory, the pipeline a host-driven
two-pass sweep pays).  Emits ``BENCH_kernel.json``
through the shared serializer with ``KernelMeasurement`` rows, each carrying
its analytic traffic model and a roofline-derived bandwidth target
(``launch/roofline.py``: a stated fraction of the timed backend's bandwidth
peak — HBM on TPU, sustained DRAM stream on the CPU CI runners).

On this container the timings measure the *XLA CPU* path (the production jit
fallback: ``use_kernel=False``, a single fused dispatch); ``path`` records
``ref_fused`` so rows are never mistaken for TPU kernel timings.  On a TPU
backend the Pallas path is timed instead (``path=pallas``).  Either way the
Pallas kernels are parity-checked in interpret mode against the fused run on
the shapes small enough to interpret (``kernel_validated``); tests/kernels
covers the edge shapes.

Only deterministic cells (``bytes_moved``, ``target_gb_s``, ``target_frac``)
are trajectory-gated by ``tools/compare_bench.py``; timings re-measured on CI
runners feed the ``speedup >= 1`` invariant on standard/full-tier rows
(``check_kernel_rows``).
"""
from __future__ import annotations

import functools
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sim.measure import BenchDriver, KernelMeasurement
from repro.kernels.compact import ops as compact_ops
from repro.kernels.compact.ref import compact_ref, needed_ref
from repro.kernels.version_search import ops as search_ops
from repro.kernels.version_search.ref import search_gather_ref, search_ref
from repro.launch.roofline import kernel_bandwidth_target

SEED = 0
EMPTY = jnp.int32(-1)
TS_MAX = 2_147_483_647
NOW = 1_000_000

# interpret-mode parity is re-run per bench only on shapes small enough to
# interpret quickly; larger shapes rely on tests/kernels (kernel_validated
# records which rows got the in-run check)
VALIDATE_MAX_COMPACT_ROWS = 4096
VALIDATE_MAX_GATHER_BATCH = 2048

# compact shapes are (S, V, P): slots x versions-per-slot x announcement
# board; search_gather shapes are (S, V, M, B): slots x versions x value-row
# width x query batch (the value table has S rows — payload handles index it)
TIERS: Dict[str, Dict] = {
    "smoke": {
        "iters": 30,
        "compact": [(256, 8, 64)],
        "search_gather": [(512, 8, 8, 256)],
    },
    "standard": {
        "iters": 50,
        "compact": [(4096, 8, 64), (4096, 16, 256), (16384, 8, 256)],
        "search_gather": [(4096, 8, 16, 2048), (8192, 16, 32, 2048),
                          (16384, 8, 32, 4096)],
    },
    "full": {
        "iters": 50,
        "compact": [(32768, 16, 1024), (65536, 8, 256)],
        "search_gather": [(32768, 16, 128, 4096), (65536, 8, 32, 8192)],
    },
}


def _time_pair_us(fn_a, fn_b, args, iters: int,
                  warmup: int = 3) -> Tuple[float, float]:
    """Best-of-`iters` wall time per call for two paths over the same
    inputs, microseconds.  Samples are interleaved (a, b, a, b, ...) so
    sustained machine drift hits both paths equally instead of biasing
    whichever was timed second."""
    for _ in range(warmup):
        jax.block_until_ready(fn_a(*args))
        jax.block_until_ready(fn_b(*args))
    best_a = best_b = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a(*args))
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b(*args))
        best_b = min(best_b, time.perf_counter() - t0)
    return best_a * 1e6, best_b * 1e6


def _backend() -> Tuple[str, bool]:
    b = jax.default_backend()
    return b, b == "tpu"


def _row(tier: str, kernel: str, shape: str, n_keys: int, bytes_moved: int,
         iters: int, us_fused: float, us_unfused: float, wall_s: float,
         validated: bool) -> KernelMeasurement:
    backend, on_tpu = _backend()
    tgt = kernel_bandwidth_target(kernel, backend="tpu" if on_tpu else "cpu")
    us_f = round(us_fused, 2)
    us_u = round(us_unfused, 2)
    gb_s = round(bytes_moved / max(us_f, 1e-6) / 1e3, 4)
    return KernelMeasurement(
        bench="kernel", figure=f"{kernel}/{tier}", ds="slab", scheme=kernel,
        mix=tier, scan_size=0, zipf=0.0, n_keys=n_keys, num_procs=1,
        ops_per_proc=0, seed=SEED, updates=0, lookups=0, scans=0,
        scan_keys=0, total_work=0, ops_per_mwork=0.0, updates_per_mwork=0.0,
        scan_keys_per_mwork=0.0, peak_space_words=0, peak_versions=0,
        avg_space_words=0, end_space_words=0, end_versions_per_list=0.0,
        scans_validated=0, scan_violations=0, wall_s=round(wall_s, 2),
        kernel=kernel, shape=shape, backend=backend,
        path="pallas" if on_tpu else "ref_fused",
        bytes_moved=bytes_moved, iters=iters,
        us_fused=us_f, us_unfused=us_u,
        speedup=round(us_u / max(us_f, 1e-6), 4),
        gb_s=gb_s, peak_bw_gb_s=tgt["peak_bw_gb_s"],
        bw_frac=round(gb_s / tgt["peak_bw_gb_s"], 6),
        target_frac=tgt["target_frac"], target_gb_s=tgt["target_gb_s"],
        kernel_validated=validated,
    )


# ---------------------------------------------------------------------------
# compact: fused needed+splice vs needed-then-splice (two dispatches)
# ---------------------------------------------------------------------------
def _compact_inputs(S: int, V: int, P: int, seed: int):
    rng = np.random.default_rng(seed)
    ts = rng.integers(0, NOW, (S, V)).astype(np.int32)
    hole = rng.random((S, V)) < 0.25          # never-written entries
    succ = (ts + rng.integers(1, NOW // 2, (S, V))).astype(np.int32)
    live = rng.random((S, V)) < 0.30          # per-slot chain heads
    succ[live] = TS_MAX
    ts[hole] = -1
    succ[hole] = TS_MAX
    pay = rng.integers(0, S, (S, V)).astype(np.int32)
    pay[hole] = -1
    n_ann = P - P // 4                        # TS_MAX-padded board
    ann = np.sort(rng.integers(0, NOW, n_ann).astype(np.int32))
    ann = np.concatenate([ann, np.full(P - n_ann, TS_MAX, np.int32)])
    mask = np.ones(S, bool)
    return (jnp.asarray(ts), jnp.asarray(succ), jnp.asarray(pay),
            jnp.asarray(mask), jnp.asarray(ann), jnp.int32(NOW))


_needed_unfused = jax.jit(needed_ref)


@jax.jit
def _splice_unfused(ts, succ, pay, mask, need):
    kill = (ts != EMPTY) & ~need & mask[:, None]
    return (jnp.where(kill, EMPTY, ts), jnp.where(kill, TS_MAX, succ),
            jnp.where(kill, EMPTY, pay), jnp.where(kill, pay, EMPTY),
            kill.sum().astype(jnp.int32))


def _compact_unfused(ts, succ, pay, mask, ann, now):
    # two synchronous launches: the bool[S, V] needed mask round-trips
    # through memory and the splice launch waits on it, as a host-driven
    # two-pass sweep does (the fused kernel removes both the intermediate
    # and the pipeline bubble)
    need = jax.block_until_ready(_needed_unfused(ts, succ, ann, now))
    return _splice_unfused(ts, succ, pay, mask, need)


def _bench_compact(tier: str, S: int, V: int, P: int,
                   iters: int) -> KernelMeasurement:
    t0 = time.perf_counter()
    args = _compact_inputs(S, V, P, SEED)
    _, on_tpu = _backend()
    fused = functools.partial(compact_ops.compact,
                              use_kernel=on_tpu, interpret=False)
    us_f, us_u = _time_pair_us(fused, _compact_unfused, args, iters=iters)
    validated = False
    if S <= VALIDATE_MAX_COMPACT_ROWS:
        got = compact_ops.compact(*args, use_kernel=True, interpret=not on_tpu)
        want = compact_ref(*args)
        validated = all(bool(jnp.array_equal(g, w))
                        for g, w in zip(got, want))
    # one launch: read ts/succ/pay tiles + mask + board, write four tiles
    # and the freed count
    bytes_moved = 4 * (7 * S * V + S + P + 1)
    return _row(tier, "compact", f"S{S}xV{V}xP{P}", S, bytes_moved, iters,
                us_f, us_u, time.perf_counter() - t0, validated)


# ---------------------------------------------------------------------------
# search_gather: fused search+gather vs search-then-index (two dispatches)
# ---------------------------------------------------------------------------
def _gather_inputs(S: int, V: int, M: int, B: int, seed: int):
    rng = np.random.default_rng(seed)
    ts = np.sort(rng.integers(0, NOW, (S, V)).astype(np.int32), axis=1)
    hole = rng.random((S, V)) < 0.25
    ts[hole] = -1
    pay = rng.integers(0, S, (S, V)).astype(np.int32)
    pay[hole] = -1
    values = rng.integers(0, 1 << 20, (S, M)).astype(np.int32)
    ids = rng.integers(0, S, B).astype(np.int32)
    t = rng.integers(0, NOW, B).astype(np.int32)
    return (jnp.asarray(ts), jnp.asarray(pay), jnp.asarray(values),
            jnp.asarray(ids), jnp.asarray(t))


_search_unfused = jax.jit(search_ref)


@jax.jit
def _index_unfused(values, pay, found):
    # the baseline snapshot_view read: resolved handles index the table
    safe = jnp.clip(pay, 0, values.shape[0] - 1)
    return jnp.where(found[:, None], values[safe], EMPTY)


def _gather_unfused(ts, pay, values, ids, t):
    # two synchronous launches: the resolved (payload, found) intermediates
    # round-trip through memory and the gather launch waits on them — the
    # search-then-index read path the fused kernel replaces
    p, f = _search_unfused(ts, pay, ids, t)
    jax.block_until_ready((p, f))
    return _index_unfused(values, p, f)


def _bench_search_gather(tier: str, S: int, V: int, M: int, B: int,
                         iters: int) -> KernelMeasurement:
    t0 = time.perf_counter()
    args = _gather_inputs(S, V, M, B, SEED)
    _, on_tpu = _backend()
    fused = functools.partial(search_ops.search_gather,
                              use_kernel=on_tpu, interpret=False)
    us_f, us_u = _time_pair_us(fused, _gather_unfused, args, iters=iters)
    validated = False
    if B <= VALIDATE_MAX_GATHER_BATCH:
        got = search_ops.search_gather(*args, use_kernel=True,
                                       interpret=not on_tpu)
        want = search_gather_ref(*args)
        validated = all(bool(jnp.array_equal(g, w))
                        for g, w in zip(got, want))
    # one launch: gather ts/pay version rows + ids/t, gather value rows,
    # write gathered rows + resolved payload + found
    bytes_moved = 4 * (2 * B * V + 2 * B * M + 4 * B)
    return _row(tier, "search_gather", f"S{S}xV{V}xM{M}xB{B}", S, bytes_moved,
                iters, us_f, us_u, time.perf_counter() - t0, validated)


def run_tier(tier: str) -> List[KernelMeasurement]:
    spec = TIERS[tier]
    rows = [_bench_compact(tier, S, V, P, spec["iters"])
            for (S, V, P) in spec["compact"]]
    rows += [_bench_search_gather(tier, S, V, M, B, spec["iters"])
             for (S, V, M, B) in spec["search_gather"]]
    return rows


DRIVER = BenchDriver(
    bench="kernel", schema="kernel", tiers=TIERS, run_tier=run_tier,
    default_out="BENCH_kernel.json", default_tier="standard",
    table_cols=("figure", "shape", "bytes_moved", "us_fused", "us_unfused",
                "speedup", "gb_s", "target_gb_s", "kernel_validated"),
    col_width=14,
)


def main(argv=None) -> int:
    return DRIVER.main(argv)


if __name__ == "__main__":
    raise SystemExit(main())
