"""Benchmark entrypoint: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows plus the full comparison tables.
  python -m benchmarks.run            # fast mode (scaled-down workloads)
  python -m benchmarks.run --full     # paper-scale workloads
  python -m benchmarks.run --roofline # include roofline table (needs dryrun)
"""
from __future__ import annotations

import sys


def main() -> None:
    full = "--full" in sys.argv
    from benchmarks import gc_comparison, kernel_bench, space_bounds

    csv_rows = []

    figs = gc_comparison.main(fast=not full)
    for name, rows in figs.items():
        for r in rows:
            csv_rows.append((f"{name}/{r['scheme']}/updates",
                             1e6 / max(1e-9, r["updates_per_mwork"]),
                             f"peak_space={r['peak_space_words']}w"))

    space_bounds.main()
    kernel_rows = kernel_bench.DRIVER.run(
        ["standard" if full else "smoke"])
    for m in kernel_rows:
        csv_rows.append((
            f"{m.figure}/{m.shape}", m.us_fused,
            f"speedup={m.speedup}x gb_s={m.gb_s}/{m.target_gb_s} target"))

    if "--roofline" in sys.argv:
        try:
            from repro.launch import roofline
            rows = roofline.load_all("baseline")
            print("\n== roofline (from dry-run artifacts) ==")
            print(roofline.table(rows))
        except Exception as e:  # dryrun artifacts may not exist yet
            print(f"[roofline skipped: {e}]")

    print("\nname,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
