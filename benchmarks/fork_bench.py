"""Fork-DAG + checkpoint-coupled GC bench for the paged-KV serving stack
(DESIGN.md §14).

Drives ``repro.serve.engine.PagedKVEngine``'s first-class lineage ops
(``fork`` / ``join`` / ``release``) and its checkpoint coupling
(``checkpoint()`` arming turso's sole-survivor eviction rule) through three
workload families, every cell embedding its own measured controls:

* **beam** — beam-search decoding: roots fork ``beam_width`` children per
  round, children decode a few tokens, the best child joins back, the rest
  release.  The same op sequence re-runs on an ``eager_fork=True`` engine
  (every fork deep-copies the parent's pages) and the row records both
  peaks: ``shared_savings_pages = eager_peak_pages - peak_pages`` is the
  space COW sharing saved, and must be strictly positive on every forking
  row.
* **spec** — speculative decoding: each root forks a draft, the draft runs
  ahead, and the root either adopts it (``join``) or rejects it
  (``release``) — the fork/join-heavy shape.
* **ckpt_churn** — a batch where most sequences go idle after a warmup
  phase while the rest keep decoding under an undersized pool.  Idle
  sole-survivor sequences hold pages **no GC policy can reclaim** (current
  versions are always needed); after ``checkpoint()`` the same reclaim
  pass evicts them (``ckpt_pages_freed > 0``), and the identical run
  *without* a checkpoint proves the converse: ``control_ckpt_pages_freed
  == 0`` and ``control_end_pages`` stays pinned high.

Replay validation extends the pinned-snapshot checking of
``serve_bench.py`` to fork DAGs (``repro.serve.forking.ForkValidator``):
at fork time the child's inherited prefix is fingerprinted (exact K
values), and on every later step the child's current view must reproduce
it byte-for-byte (``prefix_checks`` / ``prefix_violations``; the driver
exits nonzero on any violation).  ``forking.check_no_leak`` — refcount
oracle vs. the refcount-free reachability sweep — runs after every round.

Rows are ``ForkMeasurement`` (serve fields + ``units["fork_bench"]``; the
serve-dormant ``forks`` field carries the real engine fork count here).

  python benchmarks/fork_bench.py                  # standard = beam tier
  python benchmarks/fork_bench.py --smoke          # tiny CI matrix (seconds)
  python benchmarks/fork_bench.py --tiers smoke,beam,spec,ckpt_churn
  python benchmarks/fork_bench.py --out PATH

The committed repo-root ``BENCH_fork.json`` is generated with
``--tiers smoke,beam,spec,ckpt_churn`` so CI can compare a fresh
``--smoke`` run cell-for-cell against the committed smoke rows while the
trajectory keeps the full tiers for plotting and the fork-invariant gate
(``tools/check_bench_json.py --serve``).
"""
from __future__ import annotations

import os
import sys
import tempfile
import time
from typing import Dict, List, Optional

import jax
import numpy as np
import jax.numpy as jnp

jax.config.update("jax_platform_name", "cpu")

from repro.core.sim.measure import BenchDriver, ForkMeasurement
from repro.core.telemetry import GCConfig
from repro.serve import forking
from repro.serve.engine import PagedKVEngine

DEFAULT_OUT = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_fork.json")

POLICIES = ("ebr", "steam", "dlrt", "slrt")

TABLE_COLS = [
    "scheme", "forks", "joins", "releases", "pages_shared_peak",
    "peak_pages", "eager_peak_pages", "shared_savings_pages",
    "prefix_checks", "prefix_violations", "ckpt_pages_freed",
    "control_end_pages", "end_space_words", "give_ups", "wall_s",
]

# Tier geometry.  The fork tiers size the pool so neither the COW run nor
# the eager control saturates — the peak gap is then exactly the pages
# sharing saved.  Forks always happen with the parent past one full page,
# so the eager copy is strictly larger than COW's partial-tail copy.
# ``ckpt_churn`` undersizes the pool against the *control's* demand
# (idle + active > num_pages) while keeping the checkpointed run's demand
# (active only, after eviction) inside it.
TIERS = {
    "smoke": dict(kind="beam", num_seqs=6, num_pages=24, page_size=4,
                  max_pages_per_seq=6, versions_per_seq=8, roots=(0,),
                  prefill=6, rounds=2, beam_width=2, child_tokens=2,
                  join_every=2, seed=0),
    "beam": dict(kind="beam", num_seqs=8, num_pages=40, page_size=4,
                 max_pages_per_seq=6, versions_per_seq=8, roots=(0, 1),
                 prefill=6, rounds=6, beam_width=2, child_tokens=2,
                 join_every=2, seed=0),
    "spec": dict(kind="spec", num_seqs=6, num_pages=48, page_size=4,
                 max_pages_per_seq=8, versions_per_seq=10, roots=(0, 1, 2),
                 prefill=5, rounds=8, draft_tokens=3, seed=0),
    "ckpt_churn": dict(kind="ckpt", num_seqs=8, num_pages=20, page_size=4,
                       max_pages_per_seq=6, versions_per_seq=8, idle=5,
                       phase1_steps=8, phase2_steps=16, seed=0),
}

KV_HEADS, HEAD_DIM, READER_LANES = 1, 4, 4
NOW = 2**31 - 2          # "current" snapshot timestamp (any ts works)


class _Run:
    """One engine run's host-side accounting (the COW main run, the eager
    control, or the no-checkpoint control share this loop harness)."""

    def __init__(self, p: Dict, policy: str, eager: bool):
        self.p = p
        self.eng = PagedKVEngine(
            p["num_seqs"], p["num_pages"], p["page_size"],
            p["max_pages_per_seq"], KV_HEADS, HEAD_DIM,
            gc=GCConfig(policy=policy,
                        versions_per_slot=p["versions_per_seq"],
                        reader_lanes=READER_LANES, hot_k=p["num_seqs"]),
            eager_fork=eager, dtype=jnp.float32)
        self.validator = forking.ForkValidator()
        self.B = p["num_seqs"]
        self.ids = jnp.arange(self.B, dtype=jnp.int32)
        self.tokens = 0
        self.step_no = 0
        self.shared_peak = 0
        self.leaks = 0
        self.ckpt_saves = 0

    def _sample(self) -> None:
        self.shared_peak = max(self.shared_peak,
                               forking.shared_page_count(self.eng.st))
        ok, _, _ = forking.check_no_leak(self.eng.st)
        if not ok:
            self.leaks += 1

    def views(self) -> tuple:
        tbl, ln = self.eng.view_at(NOW)
        return np.asarray(tbl), np.asarray(ln)

    def append(self, mask: np.ndarray) -> np.ndarray:
        """One decode step over ``mask``; per-(step, seq) distinct payload
        values so a wrongly recycled page shows up in a prefix check."""
        self.step_no += 1
        base = np.arange(self.B, dtype=np.float32) + self.B * self.step_no
        kv = jnp.asarray(np.broadcast_to(
            base[:, None, None], (self.B, KV_HEADS, HEAD_DIM)))
        failed = np.asarray(self.eng.step(self.ids, kv, kv,
                                          jnp.asarray(mask)))
        self.tokens += int((mask & ~failed).sum())
        self._sample()
        return failed

    def fork(self, pairs: List[tuple]) -> None:
        """Fork (src, dst) pairs and register each child's inherited prefix
        with the validator."""
        src = jnp.asarray([s for s, _ in pairs], jnp.int32)
        dst = jnp.asarray([d for _, d in pairs], jnp.int32)
        mask = jnp.ones((len(pairs),), bool)
        failed = np.asarray(self.eng.fork(src, dst, mask))
        self._sample()
        tbl, ln = self.views()
        for (s, d), bad in zip(pairs, failed):
            if not bad:
                self.validator.note_fork(self.eng.st, d, tbl[d], int(ln[d]))

    def check_children(self, children: List[int]) -> None:
        tbl, ln = self.views()
        for c in children:
            self.validator.check(self.eng.st, c, tbl[c], int(ln[c]))

    def join(self, pairs: List[tuple]) -> None:
        src = jnp.asarray([s for s, _ in pairs], jnp.int32)
        dst = jnp.asarray([d for _, d in pairs], jnp.int32)
        self.eng.join(src, dst, jnp.ones((len(pairs),), bool))
        for s, _ in pairs:
            self.validator.drop(s)
        self._sample()

    def release(self, slots: List[int]) -> None:
        ids = jnp.asarray(slots, jnp.int32)
        self.eng.release(ids, jnp.ones((len(slots),), bool))
        for s in slots:
            self.validator.drop(s)
        self._sample()


def _beam_workload(run: _Run) -> None:
    """Beam search: each root forks ``beam_width`` children, children
    decode ``child_tokens`` steps (prefix-checked each step), then the
    first child joins back into its root (every ``join_every``-th round)
    and the rest release."""
    p = run.p
    roots = list(p["roots"])
    child_slots = [s for s in range(run.B) if s not in roots]
    mask0 = np.zeros((run.B,), bool)
    for r in roots:
        mask0[r] = True
    for _ in range(p["prefill"]):
        run.append(mask0)
    for rnd in range(p["rounds"]):
        pairs, by_root = [], {}
        free = list(child_slots)
        for r in roots:
            kids = [free.pop(0) for _ in range(p["beam_width"])]
            by_root[r] = kids
            pairs.extend((r, k) for k in kids)
        run.fork(pairs)
        kids_mask = np.zeros((run.B,), bool)
        for _, k in pairs:
            kids_mask[k] = True
        for _ in range(p["child_tokens"]):
            run.append(kids_mask)
            run.check_children([k for _, k in pairs])
        # the root advances too, desynchronizing parent and child tails
        run.append(mask0)
        run.check_children([k for _, k in pairs])
        if (rnd + 1) % p["join_every"] == 0:
            run.join([(by_root[r][0], r) for r in roots])
            run.release([k for r in roots for k in by_root[r][1:]])
        else:
            run.release([k for r in roots for k in by_root[r]])


def _spec_workload(run: _Run) -> None:
    """Speculative decoding: each root forks a draft that runs
    ``draft_tokens`` ahead; even rounds accept (join), odd rounds reject
    (release)."""
    p = run.p
    roots = list(p["roots"])
    drafts = [s for s in range(run.B) if s not in roots][:len(roots)]
    mask0 = np.zeros((run.B,), bool)
    for r in roots:
        mask0[r] = True
    for _ in range(p["prefill"]):
        run.append(mask0)
    for rnd in range(p["rounds"]):
        pairs = list(zip(roots, drafts))
        run.fork(pairs)
        draft_mask = np.zeros((run.B,), bool)
        for d in drafts:
            draft_mask[d] = True
        for _ in range(p["draft_tokens"]):
            run.append(draft_mask)
            run.check_children(drafts)
        if rnd % 2 == 0:
            run.join([(d, r) for r, d in pairs])
        else:
            run.release(drafts)


def _ckpt_workload(run: _Run, with_ckpt: bool) -> None:
    """Checkpoint churn: all sequences decode ``phase1_steps``, then the
    first ``idle`` go quiet while the rest keep decoding.  With
    ``with_ckpt`` the engine checkpoints at the phase boundary, decodes one
    active step (so active current versions move past ``ckpt_max``), and
    forces a full reclaim — the sole-survivor eviction frees the idle
    pages durable storage already holds.  The control runs the identical
    schedule minus the ``checkpoint()`` call."""
    p = run.p
    all_mask = np.ones((run.B,), bool)
    active_mask = np.zeros((run.B,), bool)
    active_mask[p["idle"]:] = True
    for _ in range(p["phase1_steps"]):
        run.append(all_mask)
    with tempfile.TemporaryDirectory() as d:
        if with_ckpt:
            run.eng.checkpoint(d)
            run.ckpt_saves += 1
        # active sequences write first: their current versions get
        # ts > ckpt_max, so the forced reclaim below can only evict the
        # idle-since-checkpoint ones (DESIGN.md §14)
        run.append(active_mask)
        run.eng.reclaim(p["num_seqs"] * p["versions_per_seq"])
        for _ in range(p["phase2_steps"] - 1):
            run.append(active_mask)


def run_cell(tier: str, policy: str) -> ForkMeasurement:
    p = TIERS[tier]
    t0 = time.time()

    main = _Run(p, policy, eager=False)
    if p["kind"] == "beam":
        _beam_workload(main)
        eager = _Run(p, policy, eager=True)
        _beam_workload(eager)
        control: Optional[_Run] = None
    elif p["kind"] == "spec":
        _spec_workload(main)
        eager = _Run(p, policy, eager=True)
        _spec_workload(eager)
        control = None
    else:
        _ckpt_workload(main, with_ckpt=True)
        eager = None
        control = _Run(p, policy, eager=False)
        _ckpt_workload(control, with_ckpt=False)
    wall = time.time() - t0

    eng = main.eng
    space = eng.space()
    v = main.validator
    checks = v.checked
    violations = v.violations + main.leaks
    eager_peak = eager.eng.peak_pages if eager is not None else 0
    work = main.tokens + checks
    B = p["num_seqs"]
    return ForkMeasurement(
        bench="fork", figure=f"fork_dag/{tier}", ds="paged_kv",
        scheme=policy, mix=tier, scan_size=0, zipf=0.0,
        n_keys=p["num_pages"], num_procs=B, ops_per_proc=main.step_no,
        seed=p["seed"], updates=main.tokens, lookups=0, scans=eng.forks,
        scan_keys=checks, total_work=work,
        ops_per_mwork=round((main.tokens + eng.forks)
                            / max(1, work) * 1e6, 3),
        updates_per_mwork=round(main.tokens / max(1, work) * 1e6, 3),
        scan_keys_per_mwork=round(checks / max(1, work) * 1e6, 3),
        peak_space_words=eng.peak_pages,
        peak_versions=space["max_slot_occupancy"],
        avg_space_words=0,
        end_space_words=space["live_pages"],
        end_versions_per_list=round(space["live_versions"] / B, 4),
        scans_validated=checks, scan_violations=violations,
        wall_s=round(wall, 2),
        reclaims_triggered=eng.reclaims_triggered,
        peak_space_post_reclaim=eng.peak_pages_post_reclaim,
        pressure_events=eng.pressure_events,
        pages_reclaimed=eng.pages_reclaimed,
        peak_pages=eng.peak_pages,
        peak_pages_post_reclaim=eng.peak_pages_post_reclaim,
        page_pool=p["num_pages"], page_size=p["page_size"],
        decode_steps=main.step_no, tokens_appended=main.tokens,
        sequences_completed=0, forks=eng.forks, give_ups=eng.give_ups,
        snapshot_pins=0,
        overflow_count=space["overflows"],
        dropped_retires=space["dropped_retires"],
        joins=eng.joins, releases=eng.releases,
        pages_shared_peak=main.shared_peak,
        eager_peak_pages=eager_peak,
        shared_savings_pages=max(0, eager_peak - eng.peak_pages)
        if eager is not None else 0,
        prefix_checks=checks, prefix_violations=v.violations,
        ckpt_saves=main.ckpt_saves,
        ckpt_evictions=eng.stats.ckpt_evictions,
        ckpt_pages_freed=eng.stats.ckpt_freed,
        control_ckpt_pages_freed=(control.eng.stats.ckpt_freed
                                  if control is not None else 0),
        control_end_pages=(int(control.eng.space()["live_pages"])
                           if control is not None else 0),
        scheme_stats={"leak_checks_failed": main.leaks},
    )


def run_tier(tier: str) -> List[ForkMeasurement]:
    rows = []
    for policy in POLICIES:
        m = run_cell(tier, policy)
        rows.append(m)
        if m.prefix_violations or m.scan_violations:
            print(f"!! fork-DAG violations in {tier}/{policy}: "
                  f"prefix={m.prefix_violations} "
                  f"total={m.scan_violations}", file=sys.stderr)
    return rows


def _summarize(rows: List[ForkMeasurement]) -> str:
    return (f"{sum(m.forks for m in rows)} forks / "
            f"{sum(m.joins for m in rows)} joins / "
            f"{sum(m.releases for m in rows)} releases, "
            f"COW saved {sum(m.shared_savings_pages for m in rows)} peak "
            f"pages vs eager, ckpt eviction freed "
            f"{sum(m.ckpt_pages_freed for m in rows)} pages "
            f"(controls: {sum(m.control_ckpt_pages_freed for m in rows)}), "
            f"{sum(m.prefix_checks for m in rows)} prefix checks, "
            f"{sum(m.prefix_violations for m in rows)} violations")


def _post_check(rows: List[ForkMeasurement]) -> List[str]:
    problems = []
    violations = sum(m.scan_violations for m in rows)
    if violations:
        problems.append(
            f"fork-DAG replay/leak violations detected ({violations})")
    return problems


DRIVER = BenchDriver(
    bench="fork", schema="fork", tiers=TIERS, run_tier=run_tier,
    default_out=DEFAULT_OUT, table_cols=TABLE_COLS, col_width=14,
    summarize=_summarize, post_check=_post_check, default_tier="beam",
)


def main(argv=None) -> int:
    return DRIVER.main(argv)


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
