"""EEMARQ-style range-query benchmark driver (DESIGN.md §7).

Runs the range-scan workload family over the five MVGC schemes and both
multiversion structures: range-heavy operation mixes (update/lookup/scan
50/25/25 and 10/10/80), scan sizes s ∈ {8, 64, 1024, 8192}, uniform and
Zipfian-0.99 key distributions.  This is the regime the paper's central
experiment stresses (long-lived readers pinning versions while updates
allocate) and where EEMARQ (Sheffi et al., 2022) shows reclamation schemes
diverge most.

Every completed scan is replayed against the reference UpdateLog
(snapshot-consistency validation, repro.core.sim.linearize); the driver exits
nonzero if any scan observed a non-snapshot result.  Results are emitted as
``BENCH_range_query.json`` (schema: repro.core.sim.measure; space in words,
throughput in completed ops per million simulated work units).

  python benchmarks/range_query.py            # standard matrix (~2 min)
  python benchmarks/range_query.py --smoke    # tiny CI matrix (seconds)
  python benchmarks/range_query.py --full     # full EEMARQ matrix (slow)
  python benchmarks/range_query.py --tiers smoke,standard  # concatenated
  python benchmarks/range_query.py --out PATH # where to write the JSON

The committed repo-root ``BENCH_range_query.json`` is generated with
``--tiers smoke,standard`` so the CI ``bench-trajectory`` step can compare a
fresh ``--smoke`` emission cell-for-cell against the committed smoke rows
(``tools/compare_bench.py``).
"""
from __future__ import annotations

import os
import sys
import time
from typing import List, Optional

from repro.core.sim.measure import BenchDriver, EEMARQ_MIXES, Measurement
from repro.core.sim.workload import eemarq_matrix, run_workload

DEFAULT_OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                           "BENCH_range_query.json")

TABLE_COLS = [
    "scheme", "ds", "mix", "scan_size", "zipf", "ops_per_mwork",
    "scan_keys_per_mwork", "peak_space_words", "peak_versions",
    "end_space_words", "scans_validated", "scan_violations", "wall_s",
]

# matrix tiers: (n_keys, num_procs, ops_per_proc, scan_sizes, zipfs)
TIERS = {
    "smoke": dict(n_keys=32, num_procs=4, ops_per_proc=16,
                  scan_sizes=(8,), zipfs=(0.99,)),
    "standard": dict(n_keys=512, num_procs=12, ops_per_proc=96,
                     scan_sizes=(8, 64, 1024), zipfs=(0.0, 0.99)),
    "full": dict(n_keys=1024, num_procs=16, ops_per_proc=160,
                 scan_sizes=(8, 64, 1024, 8192), zipfs=(0.0, 0.99)),
}


def run_matrix(tier: str = "standard") -> List[Measurement]:
    params = TIERS[tier]
    cfgs = eemarq_matrix(
        mixes=EEMARQ_MIXES,
        scan_sizes=params["scan_sizes"],
        zipfs=params["zipfs"],
        n_keys=params["n_keys"],
        num_procs=params["num_procs"],
        ops_per_proc=params["ops_per_proc"],
        validate_scans=True,
        sample_every=1024,
    )
    rows = []
    for cfg in cfgs:
        mix = cfg.op_mix
        figure = (f"{cfg.ds}/{mix.label}/s={mix.scan_size}"
                  f"/zipf={cfg.zipf}")
        t0 = time.time()
        r = run_workload(cfg)
        m = Measurement.from_result("range_query", figure, r,
                                    wall_s=time.time() - t0)
        rows.append(m)
        if r["scan_violations"]:
            print(f"!! snapshot violations in {figure}/{cfg.scheme}: "
                  f"{r['violation_examples']}", file=sys.stderr)
    return rows


def _summarize(rows: List[Measurement]) -> Optional[str]:
    return (f"{sum(m.scans_validated for m in rows)} scans validated, "
            f"{sum(m.scan_violations for m in rows)} violations")


def _post_check(rows: List[Measurement]) -> List[str]:
    violations = sum(m.scan_violations for m in rows)
    return ([f"snapshot-consistency violations detected ({violations})"]
            if violations else [])


DRIVER = BenchDriver(
    bench="range_query", tiers=TIERS, run_tier=run_matrix,
    default_out=DEFAULT_OUT, table_cols=TABLE_COLS, col_width=20,
    summarize=_summarize, post_check=_post_check,
)


def main(argv: Optional[List[str]] = None) -> int:
    return DRIVER.main(argv)


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
