"""Pressure-driven reclamation bench for the paged-KV serving stack
(DESIGN.md §11).

Drives ``repro.serve.engine.PagedKVEngine`` — continuous decode over a fixed
batch of sequences, each restarting (``reset``) when it reaches a random
target length, so completed sequences keep feeding stale page-table versions
into the descriptor slabs.  The page pool and descriptor slabs are sized so
the ``storm`` tier runs out: failed appends and watermark crossings become
**pressure events** that drive the synchronous hot-sequence-first reclaim
loop, and every row records how much that loop actually got back
(``pressure_events`` / ``reclaims_triggered`` / ``pages_reclaimed`` /
``peak_pages`` / ``peak_pages_post_reclaim``).

Snapshot-scoring readers pin mid-storm: every ``pin_every`` steps a reader
lane pins the current timestamp and records a checksum of its visible
(page-table, lengths) view; while the pin is held — across forced reclaims —
the view is re-resolved every step and must be byte-identical
(``scans_validated`` / ``scan_violations``; the driver exits nonzero on any
violation).  This is the serving-side analogue of the sim drivers' replay
validation: reclamation may never free a page a pinned snapshot can reach.

Rows are ``ServeMeasurement`` (schema v4 + serve fields; space measured in
**pages**: ``peak_space_words`` = ``peak_pages``, ``end_space_words`` = end
live pages, ``peak_space_post_reclaim`` = ``peak_pages_post_reclaim``).

  python benchmarks/serve_bench.py                  # standard tier
  python benchmarks/serve_bench.py --smoke          # tiny CI matrix (seconds)
  python benchmarks/serve_bench.py --tiers smoke,standard,storm
  python benchmarks/serve_bench.py --out PATH

The committed repo-root ``BENCH_serve.json`` is generated with
``--tiers smoke,standard,storm`` so the CI ``bench-trajectory`` step can
compare a fresh ``--smoke`` run cell-for-cell against the committed smoke
rows (``tools/compare_bench.py``) while the trajectory keeps the storm tier
for plotting (``tools/plot_bench.py``) and the reclaim-accounting gate
(``tools/check_bench_json.py --serve``).
"""
from __future__ import annotations

import os
import random
import sys
import time
from typing import Dict, List

import jax
import numpy as np
import jax.numpy as jnp

jax.config.update("jax_platform_name", "cpu")

from repro.core.sim.measure import BenchDriver, ServeMeasurement
from repro.core.telemetry import GCConfig
from repro.serve.engine import PagedKVEngine

DEFAULT_OUT = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_serve.json")

POLICIES = ("ebr", "steam", "dlrt", "slrt")

TABLE_COLS = [
    "scheme", "decode_steps", "tokens_appended", "sequences_completed",
    "snapshot_pins", "pressure_events", "reclaims_triggered",
    "pages_reclaimed", "peak_pages", "peak_pages_post_reclaim",
    "end_space_words", "give_ups", "scans_validated", "scan_violations",
    "wall_s",
]

# Tier geometry.  ``storm`` undersizes the page pool relative to the
# batch's worst-case demand (num_seqs * max_pages_per_seq > num_pages) and
# keeps the per-sequence descriptor slabs shallow, so both exhaustion paths
# (page bitmap and version slab) actually fire; target lengths are staggered
# so retries stay feasible after a reclaim.
TIERS = {
    "smoke": dict(num_seqs=4, num_pages=16, page_size=4, max_pages_per_seq=3,
                  versions_per_seq=6, steps=24, min_len=4, max_len=10,
                  pin_every=6, pin_hold=3, seed=0),
    "standard": dict(num_seqs=6, num_pages=32, page_size=4,
                     max_pages_per_seq=4, versions_per_seq=8, steps=96,
                     min_len=6, max_len=14, pin_every=8, pin_hold=4, seed=0),
    "storm": dict(num_seqs=8, num_pages=24, page_size=4, max_pages_per_seq=3,
                  versions_per_seq=6, steps=160, min_len=4, max_len=12,
                  pin_every=5, pin_hold=3, seed=0),
}

KV_HEADS, HEAD_DIM, READER_LANES = 1, 4, 4


def view_checksum(st, tables: np.ndarray, lengths: np.ndarray,
                  page_size: int) -> tuple:
    """Content fingerprint of a resolved snapshot view: the exact K values
    of every visible token (not just the page ids — a wrongly recycled page
    changes the values even if the table row is unchanged)."""
    k = np.asarray(st.k_pages)[:, :, 0, 0]
    sums = []
    for s in range(tables.shape[0]):
        n = int(lengths[s])
        vals = tuple(
            float(k[int(tables[s, j // page_size]), j % page_size])
            for j in range(n))
        sums.append((n, vals))
    return tuple(sums)


def run_cell(tier: str, policy: str) -> ServeMeasurement:
    p = TIERS[tier]
    B, ps = p["num_seqs"], p["page_size"]
    eng = PagedKVEngine(
        B, p["num_pages"], ps, p["max_pages_per_seq"], KV_HEADS, HEAD_DIM,
        gc=GCConfig(policy=policy, versions_per_slot=p["versions_per_seq"],
                    reader_lanes=READER_LANES),
        dtype=jnp.float32)
    rng = random.Random(p["seed"])
    targets = [rng.randrange(p["min_len"], p["max_len"] + 1)
               for _ in range(B)]
    cur_len = [0] * B
    seq_ids = jnp.arange(B, dtype=jnp.int32)
    all_mask = jnp.ones((B,), bool)

    tokens = completed = pins = validated = violations = 0
    recycled_seen: set = set()
    # lane -> (pinned ts, reference checksum, steps left to hold)
    live_pins: Dict[int, list] = {}
    next_lane = 0

    def drain_freed() -> int:
        """Drain the recycling loop the engine promises, immediately after
        the call that freed the pages: at that point every drained handle
        must name a page the free bitmap actually holds (a *later* append
        may legitimately re-allocate it)."""
        bad = 0
        free_now = np.asarray(eng.st.free)
        for h in eng.freed_pages():
            if not bool(free_now[h]):
                bad += 1
            recycled_seen.add(h)
        return bad

    t0 = time.time()
    for step in range(p["steps"]):
        # one token per sequence, per-(step, seq) distinct payload values so
        # a recycled-too-early page shows up as a checksum mismatch
        base = np.arange(B, dtype=np.float32) + B * (step + 1)
        kv = jnp.asarray(
            np.broadcast_to(base[:, None, None], (B, KV_HEADS, HEAD_DIM)))
        failed = np.asarray(eng.step(seq_ids, kv, kv, all_mask))
        violations += drain_freed()
        for s in range(B):
            if not failed[s]:
                tokens += 1
                cur_len[s] += 1

        # completed sequences recycle their slot (the storm's dominant
        # page-release path: the pre-reset versions go stale together)
        done = np.array([cur_len[s] >= targets[s] for s in range(B)])
        if done.any():
            eng.reset(seq_ids, jnp.asarray(done))
            violations += drain_freed()
            for s in np.flatnonzero(done):
                completed += 1
                cur_len[int(s)] = 0
                targets[int(s)] = rng.randrange(p["min_len"],
                                                p["max_len"] + 1)

        # snapshot-scoring readers: pin mid-storm, hold across reclaims
        if step % p["pin_every"] == 0 and len(live_pins) < READER_LANES:
            lane = next_lane % READER_LANES
            next_lane += 1
            while lane in live_pins:
                lane = (lane + 1) % READER_LANES
            ts = eng.pin(lane)
            tbl, ln = eng.view_at(ts)
            ref = view_checksum(eng.st, np.asarray(tbl), np.asarray(ln), ps)
            live_pins[lane] = [ts, ref, p["pin_hold"]]
            pins += 1
        for lane in list(live_pins):
            ts, ref, hold = live_pins[lane]
            tbl, ln = eng.view_at(ts)
            now = view_checksum(eng.st, np.asarray(tbl), np.asarray(ln), ps)
            validated += 1
            if now != ref:
                violations += 1
            live_pins[lane][2] = hold - 1
            if live_pins[lane][2] <= 0:
                eng.unpin(lane)
                del live_pins[lane]

    for lane in list(live_pins):
        eng.unpin(lane)
    wall = time.time() - t0

    space = eng.space()
    steps_n = p["steps"]
    # work unit: one token append or one snapshot re-resolution
    work = tokens + validated
    return ServeMeasurement(
        bench="serve", figure=f"paged_kv/{tier}", ds="paged_kv",
        scheme=policy, mix=tier, scan_size=0, zipf=0.0,
        n_keys=p["num_pages"], num_procs=B, ops_per_proc=steps_n,
        seed=p["seed"], updates=tokens, lookups=0, scans=pins,
        scan_keys=validated, total_work=work,
        ops_per_mwork=round((tokens + pins) / max(1, work) * 1e6, 3),
        updates_per_mwork=round(tokens / max(1, work) * 1e6, 3),
        scan_keys_per_mwork=round(validated / max(1, work) * 1e6, 3),
        peak_space_words=eng.peak_pages,
        peak_versions=space["max_slot_occupancy"],
        avg_space_words=0,
        end_space_words=space["live_pages"],
        end_versions_per_list=round(space["live_versions"] / B, 4),
        scans_validated=validated, scan_violations=violations,
        wall_s=round(wall, 2),
        reclaims_triggered=eng.reclaims_triggered,
        peak_space_post_reclaim=eng.peak_pages_post_reclaim,
        pressure_events=eng.pressure_events,
        pages_reclaimed=eng.pages_reclaimed,
        peak_pages=eng.peak_pages,
        peak_pages_post_reclaim=eng.peak_pages_post_reclaim,
        page_pool=p["num_pages"], page_size=ps,
        decode_steps=steps_n, tokens_appended=tokens,
        sequences_completed=completed, forks=0, give_ups=eng.give_ups,
        snapshot_pins=pins,
        overflow_count=space["overflows"],
        dropped_retires=space["dropped_retires"],
        scheme_stats={"pages_recycled_distinct": len(recycled_seen)},
    )


def run_tier(tier: str) -> List[ServeMeasurement]:
    rows = []
    for policy in POLICIES:
        m = run_cell(tier, policy)
        rows.append(m)
        if m.scan_violations:
            print(f"!! snapshot violations in {tier}/{policy}: "
                  f"{m.scan_violations}", file=sys.stderr)
    return rows


def _summarize(rows: List[ServeMeasurement]) -> str:
    return (f"{sum(m.tokens_appended for m in rows)} tokens, "
            f"{sum(m.pressure_events for m in rows)} pressure events, "
            f"{sum(m.reclaims_triggered for m in rows)} reclaims freed "
            f"{sum(m.pages_reclaimed for m in rows)} pages, "
            f"{sum(m.scans_validated for m in rows)} snapshot checks, "
            f"{sum(m.scan_violations for m in rows)} violations")


def _post_check(rows: List[ServeMeasurement]) -> List[str]:
    violations = sum(m.scan_violations for m in rows)
    return ([f"pinned-snapshot stability violations detected ({violations})"]
            if violations else [])


DRIVER = BenchDriver(
    bench="serve", schema="serve", tiers=TIERS, run_tier=run_tier,
    default_out=DEFAULT_OUT, table_cols=TABLE_COLS, col_width=16,
    summarize=_summarize, post_check=_post_check,
)


def main(argv=None) -> int:
    return DRIVER.main(argv)


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
