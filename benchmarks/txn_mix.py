"""EEMARQ-style read-write transaction benchmark driver (DESIGN.md §8).

Runs the update-in-scan txn workload family over the five MVGC schemes and
both multiversion structures: read-write mixes (update/lookup/scan/rwtxn
30/20/25/25 and 10/10/20/60 — half vs. three quarters of all transactions
read-write), scan sizes s ∈ {16, 128}, txn write-set sizes w ∈ {2, 8},
uniform and Zipfian-0.99 key draws.  Every txn pins its begin-timestamp
snapshot *through its write phase* and commits all writes at one validated
commit timestamp — the regime where version-list reclamation must hold both
the scan's pin and the txn's own writes live, and where the abort-rate axis
opens (long scans + churn ⇒ footprint validation failures).

Every completed scan and txn is replayed against the reference UpdateLog
(repro.core.sim.linearize: scans against the begin-ts snapshot, committed
writes visible exactly at commit-ts); the driver exits nonzero on any
violation.  Results are emitted as ``BENCH_txn_mix.json`` (schema v2:
repro.core.sim.measure — adds ``txn_size``/``rw_ratio``/``txns_committed``/
``txns_aborted``/``abort_rate`` rows).

  python benchmarks/txn_mix.py                     # standard matrix
  python benchmarks/txn_mix.py --smoke             # tiny CI matrix (seconds)
  python benchmarks/txn_mix.py --full              # full matrix (slow)
  python benchmarks/txn_mix.py --tiers smoke,standard   # concatenated tiers
  python benchmarks/txn_mix.py --out PATH          # where to write the JSON

The committed repo-root ``BENCH_txn_mix.json`` is generated with
``--tiers smoke,standard`` so the CI ``bench-trajectory`` step can compare a
fresh ``--smoke`` run cell-for-cell against the committed smoke rows
(``tools/compare_bench.py``).
"""
from __future__ import annotations

import os
import sys
import time
from typing import List

from repro.core.sim.measure import (EEMARQ_RW_MIXES, Measurement,
                                    parse_out_argv, parse_tier_argv,
                                    print_rows_by_figure, tier_meta,
                                    write_bench_json)
from repro.core.sim.workload import eemarq_rw_matrix, run_workload

DEFAULT_OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                           "BENCH_txn_mix.json")

TABLE_COLS = [
    "scheme", "ds", "mix", "scan_size", "txn_size", "zipf", "ops_per_mwork",
    "txns_committed", "txns_aborted", "abort_rate", "peak_space_words",
    "end_space_words", "scan_violations", "wall_s",
]

# matrix tiers: (n_keys, num_procs, ops_per_proc, scan_sizes, txn_sizes, zipfs)
TIERS = {
    "smoke": dict(n_keys=32, num_procs=4, ops_per_proc=16,
                  scan_sizes=(8,), txn_sizes=(2,), zipfs=(0.99,)),
    "standard": dict(n_keys=512, num_procs=12, ops_per_proc=96,
                     scan_sizes=(16, 128), txn_sizes=(2, 8), zipfs=(0.99,)),
    "full": dict(n_keys=1024, num_procs=16, ops_per_proc=160,
                 scan_sizes=(16, 128), txn_sizes=(2, 8), zipfs=(0.0, 0.99)),
}


def run_tier(tier: str) -> List[Measurement]:
    params = TIERS[tier]
    cfgs = eemarq_rw_matrix(
        mixes=EEMARQ_RW_MIXES,
        scan_sizes=params["scan_sizes"],
        txn_sizes=params["txn_sizes"],
        zipfs=params["zipfs"],
        n_keys=params["n_keys"],
        num_procs=params["num_procs"],
        ops_per_proc=params["ops_per_proc"],
        validate_scans=True,
        sample_every=1024,
    )
    rows = []
    for cfg in cfgs:
        mix = cfg.op_mix
        figure = (f"{cfg.ds}/{mix.label}/s={mix.scan_size}"
                  f"/w={mix.txn_size}/zipf={cfg.zipf}")
        t0 = time.time()
        r = run_workload(cfg)
        m = Measurement.from_result("txn_mix", figure, r,
                                    wall_s=time.time() - t0)
        rows.append(m)
        if r["scan_violations"] or r["txn_violations"]:
            print(f"!! violations in {figure}/{cfg.scheme}: "
                  f"{r['violation_examples']}", file=sys.stderr)
    return rows


def main(argv: List[str]) -> int:
    tiers, err = parse_tier_argv(argv, TIERS)
    if err is None:
        out, err = parse_out_argv(argv, DEFAULT_OUT)
    if err:
        print(err, file=sys.stderr)
        return 2

    t0 = time.time()
    rows: List[Measurement] = []
    for tier in tiers:
        rows.extend(run_tier(tier))
    print_rows_by_figure(rows, TABLE_COLS)
    payload = write_bench_json(out, "txn_mix", rows,
                               meta=tier_meta(tiers, TIERS))
    violations = sum(m.scan_violations for m in rows)
    committed = sum(m.txns_committed for m in rows)
    aborted = sum(m.txns_aborted for m in rows)
    validated = sum(m.scans_validated for m in rows)
    print(f"\nwrote {out} ({len(payload['rows'])} rows, "
          f"{committed} txns committed / {aborted} aborted, "
          f"{validated} scans validated, {violations} violations, "
          f"{time.time() - t0:.1f}s)")
    if violations:
        print("FAIL: snapshot/txn-consistency violations detected",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
