"""MV-RLU-style read-write transaction benchmark driver (DESIGN.md §8-§9).

Runs the multi-interval txn workload family over the five MVGC schemes and
both multiversion structures: read-write mixes (update/lookup/scan/rwtxn
30/20/25/25 and 10/10/20/60 — half vs. three quarters of all transactions
read-write), scan sizes s ∈ {16, 128}, txn write-set sizes w ∈ {2, 8},
interval counts r ∈ {2, 4} (each txn scans r *disjoint* intervals plus two
tracked version-wise point reads), uniform and Zipfian key draws.  Every txn
pins its begin-timestamp snapshot *through its write phase* and commits all
writes at one validated commit timestamp; aborts are classified
(``footprint`` / ``wcc`` / ``capacity``) and followed by contention-managed
bounded-exponential backoff.

The ``hc`` tier is the high-contention storm regime (Zipf 1.2 on a small key
space, version-budget capacity gate active): abort/retry storms stretch pin
lifetimes, which is where per-scheme space divergence — the paper's
bounded-space story — becomes visible in the trajectory.  Under the gate,
every ``capacity`` abort drives the abort ⇒ reclaim ⇒ retry loop (DESIGN.md
§10): the scheme synchronously reclaims obsolete versions (hot-set-first for
STEAM/SL-RT), the freed versions refund the budget, and the retry commits
instead of burning its ladder — which is why the ``hc`` rows report zero
give-ups and materially lower peak space than the pre-reclaim trajectory.

Every completed scan, point read and txn is replayed against the reference
UpdateLog (repro.core.sim.linearize); the driver exits nonzero on any
violation.  Results are emitted as ``BENCH_txn_mix.json`` (schema v4:
repro.core.sim.measure — v3 added ``txn_ranges``/``point_reads``/
``aborts_footprint``/``aborts_wcc``/``aborts_capacity``/``txn_giveups``/
``backoff_slices``; v4 adds ``reclaims_triggered``/
``versions_reclaimed_on_abort``/``reclaim_latency_slices``/
``peak_space_post_reclaim``).

  python benchmarks/txn_mix.py                     # standard matrix
  python benchmarks/txn_mix.py --smoke             # tiny CI matrix (seconds)
  python benchmarks/txn_mix.py --full              # full matrix (slow)
  python benchmarks/txn_mix.py --tiers smoke,standard,hc  # concatenated
  python benchmarks/txn_mix.py --out PATH          # where to write the JSON

The committed repo-root ``BENCH_txn_mix.json`` is generated with
``--tiers smoke,standard,hc`` so the CI ``bench-trajectory`` step can compare
a fresh ``--smoke`` run cell-for-cell against the committed smoke rows
(``tools/compare_bench.py``) while the trajectory keeps the standard and
high-contention tiers for plotting (``tools/plot_bench.py``).
"""
from __future__ import annotations

import os
import sys
import time
from typing import List

from repro.core.sim.measure import (EEMARQ_HC_ZIPF, EEMARQ_RW_MIXES,
                                    BenchDriver, Measurement)
from repro.core.sim.workload import eemarq_rw_matrix, run_workload

DEFAULT_OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                           "BENCH_txn_mix.json")

TABLE_COLS = [
    "scheme", "ds", "mix", "scan_size", "txn_size", "txn_ranges", "zipf",
    "txns_committed", "txns_aborted", "abort_rate", "aborts_footprint",
    "aborts_wcc", "aborts_capacity", "backoff_slices", "reclaims_triggered",
    "versions_reclaimed_on_abort", "peak_space_words",
    "peak_space_post_reclaim", "end_space_words", "scan_violations", "wall_s",
]

# matrix tiers: (n_keys, num_procs, ops_per_proc, scan_sizes, txn_sizes,
# txn_ranges, zipfs) + optional workload-config overrides.  ``hc`` is the
# high-contention storm regime: hot Zipf draws on a small key space with the
# contention manager's version-budget capacity gate active.
TIERS = {
    "smoke": dict(n_keys=32, num_procs=4, ops_per_proc=16,
                  scan_sizes=(8,), txn_sizes=(2,), txn_ranges=(2,),
                  zipfs=(0.99,)),
    "standard": dict(n_keys=512, num_procs=12, ops_per_proc=96,
                     scan_sizes=(16, 128), txn_sizes=(2, 8),
                     txn_ranges=(2, 4), zipfs=(0.99,)),
    # max_retries=48 (was 32 pre-reclaim): with capacity aborts no longer
    # burning whole ladders (each triggers a budget-refilling reclaim,
    # DESIGN.md §10) the only remaining give-ups were rare footprint-streak
    # tails; a wider ladder — backoff stays capped, so fairness is intact —
    # absorbs them, and the committed trajectory holds txn_giveups == 0
    "hc": dict(n_keys=128, num_procs=16, ops_per_proc=64,
               scan_sizes=(16,), txn_sizes=(4,), txn_ranges=(2, 4),
               zipfs=(EEMARQ_HC_ZIPF,),
               overrides=dict(txn_capacity=384, txn_refill_every=2,
                              max_retries=48)),
    "full": dict(n_keys=1024, num_procs=16, ops_per_proc=160,
                 scan_sizes=(16, 128), txn_sizes=(2, 8), txn_ranges=(2, 4),
                 zipfs=(0.0, 0.99)),
}


def run_tier(tier: str) -> List[Measurement]:
    params = TIERS[tier]
    cfgs = eemarq_rw_matrix(
        mixes=EEMARQ_RW_MIXES,
        scan_sizes=params["scan_sizes"],
        txn_sizes=params["txn_sizes"],
        txn_ranges=params["txn_ranges"],
        zipfs=params["zipfs"],
        n_keys=params["n_keys"],
        num_procs=params["num_procs"],
        ops_per_proc=params["ops_per_proc"],
        validate_scans=True,
        sample_every=1024,
        **params.get("overrides", {}),
    )
    rows = []
    for cfg in cfgs:
        mix = cfg.op_mix
        figure = (f"{cfg.ds}/{mix.label}/s={mix.scan_size}"
                  f"/w={mix.txn_size}/r={mix.txn_ranges}/zipf={cfg.zipf}")
        t0 = time.time()
        r = run_workload(cfg)
        m = Measurement.from_result("txn_mix", figure, r,
                                    wall_s=time.time() - t0)
        rows.append(m)
        if r["scan_violations"] or r["txn_violations"]:
            print(f"!! violations in {figure}/{cfg.scheme}: "
                  f"{r['violation_examples']}", file=sys.stderr)
    return rows


def _summarize(rows: List[Measurement]) -> str:
    by_reason = {r: sum(getattr(m, f"aborts_{r}") for m in rows)
                 for r in ("footprint", "wcc", "capacity")}
    return (f"{sum(m.txns_committed for m in rows)} txns committed / "
            f"{sum(m.txns_aborted for m in rows)} aborted {by_reason}, "
            f"{sum(m.reclaims_triggered for m in rows)} reclaims freed "
            f"{sum(m.versions_reclaimed_on_abort for m in rows)} versions, "
            f"{sum(m.scans_validated for m in rows)} scans validated, "
            f"{sum(m.scan_violations for m in rows)} violations")


def _post_check(rows: List[Measurement]) -> List[str]:
    violations = sum(m.scan_violations for m in rows)
    return ([f"snapshot/txn-consistency violations detected ({violations})"]
            if violations else [])


DRIVER = BenchDriver(
    bench="txn_mix", schema="txn", tiers=TIERS, run_tier=run_tier,
    default_out=DEFAULT_OUT, table_cols=TABLE_COLS, col_width=16,
    summarize=_summarize, post_check=_post_check,
)


def main(argv=None) -> int:
    return DRIVER.main(argv)


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
