"""GC scheme comparison — the paper's experimental core (Figures 4-8).

One harness per figure family, apples-to-apples: only the MVGC scheme varies;
the multiversion data structures, workload generator and space accounting are
shared (repro.core.sim.workload).  Simulated-time methodology documented in
DESIGN.md (single hyperthread container: work units = shared-memory accesses
of the lock-free algorithms; space = Java-style reachability in words).

  fig4/5 : tree,  split workload (40/40/40 threads in the paper; scaled)
  fig6   : hash,  split workload with large rtxs
  fig7   : tree,  mixed workload (50% upd / 49% lookup / 1% rtx-1024)
  fig8   : hash,  mixed workload
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core.sim.workload import WorkloadConfig, run_workload

SCHEMES = ["ebr", "steam", "dlrt", "slrt", "bbf"]


def _row(scheme: str, r: Dict) -> Dict:
    return {
        "scheme": scheme,
        "updates_per_Mwork": round(r["updates_per_mwork"], 1),
        "rtx_keys_per_Mwork": round(r["rtx_keys_per_mwork"], 1),
        "ops_per_Mwork": round(r["ops_per_mwork"], 1),
        "peak_space_words": r["peak_space"]["words"],
        "peak_versions": r["peak_space"].get("versions", 0),
        "avg_space_words": int(r["avg_space"]),
        "end_versions_per_list": round(r["end_space"]["versions_per_list"], 3),
        "avg_remove_chain_c": r["scheme_stats"].get("avg_remove_chain_c", "-"),
        "wall_s": r["wall_s"],
    }


def run_figure(ds: str, mode: str, *, n_keys: int, rtx_size: int,
               num_procs: int, ops_per_proc: int, seed: int = 7,
               zipf: float = 0.99) -> List[Dict]:
    rows = []
    for scheme in SCHEMES:
        kw = {}
        if scheme in ("dlrt", "slrt", "bbf"):
            kw["batch_size"] = max(8, num_procs)
        cfg = WorkloadConfig(
            ds=ds, scheme=scheme, n_keys=n_keys, num_procs=num_procs,
            mode=mode, rtx_size=rtx_size, variable_rtx_max=n_keys,
            mixed_rtx_size=min(1024, n_keys), ops_per_proc=ops_per_proc,
            zipf=zipf, seed=seed, sample_every=256, scheme_kwargs=kw,
        )
        t0 = time.time()
        r = run_workload(cfg)
        r["wall_s"] = round(time.time() - t0, 1)
        rows.append(_row(scheme, r))
    return rows


FIGURES = {
    "fig4_tree_split_small": dict(ds="tree", mode="split", n_keys=1024,
                                  rtx_size=16, num_procs=24, ops_per_proc=200),
    "fig5_tree_split_large": dict(ds="tree", mode="split", n_keys=4096,
                                  rtx_size=16, num_procs=24, ops_per_proc=150),
    "fig6_hash_split_bigrtx": dict(ds="hash", mode="split", n_keys=1024,
                                   rtx_size=512, num_procs=24, ops_per_proc=200),
    "fig7_tree_mixed": dict(ds="tree", mode="mixed", n_keys=1024,
                            rtx_size=16, num_procs=24, ops_per_proc=300),
    "fig8_hash_mixed": dict(ds="hash", mode="mixed", n_keys=1024,
                            rtx_size=16, num_procs=24, ops_per_proc=300),
}


def print_table(name: str, rows: List[Dict]) -> None:
    cols = list(rows[0].keys())
    print(f"\n== {name} ==")
    print("  ".join(f"{c:>22s}" for c in cols))
    for r in rows:
        print("  ".join(f"{str(r[c]):>22s}" for c in cols))


def main(fast: bool = True) -> Dict[str, List[Dict]]:
    out = {}
    for name, kw in FIGURES.items():
        if fast:
            kw = dict(kw)
            kw["ops_per_proc"] = max(60, kw["ops_per_proc"] // 3)
            kw["n_keys"] = max(256, kw["n_keys"] // 2)
        rows = run_figure(**kw)
        print_table(name, rows)
        out[name] = rows
    return out


if __name__ == "__main__":
    import sys
    main(fast="--full" not in sys.argv)
