"""GC scheme comparison — the paper's experimental core (Figures 4-8).

One harness per figure family, apples-to-apples: only the MVGC scheme varies;
the multiversion data structures, workload generator and space accounting are
shared (repro.core.sim.workload).  Simulated-time methodology documented in
DESIGN.md §5 (single hyperthread container: work units = shared-memory
accesses of the lock-free algorithms; space = Java-style reachability in
words).

  fig4/5 : tree,  split workload (40/40/40 threads in the paper; scaled)
  fig6   : hash,  split workload with large scans
  fig7   : tree,  mixed workload (50% upd / 49% lookup / 1% scan-of-1024)
  fig8   : hash,  mixed workload

Results are emitted as ``BENCH_gc_comparison.json`` through the same
``Measurement`` serializer as ``benchmarks/range_query.py`` (schema in
repro.core.sim.measure), so the two benchmark trajectories are directly
comparable.
"""
from __future__ import annotations

import os
import sys
import time
from dataclasses import replace
from typing import Dict, List

from repro.core.sim.measure import BenchDriver, Measurement
from repro.core.sim.workload import PAPER_MIXED, WorkloadConfig, run_workload

SCHEMES = ["ebr", "steam", "dlrt", "slrt", "bbf"]

DEFAULT_OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                           "BENCH_gc_comparison.json")

TABLE_COLS = [
    "scheme", "updates_per_mwork", "scan_keys_per_mwork", "ops_per_mwork",
    "peak_space_words", "peak_versions", "avg_space_words",
    "end_versions_per_list", "wall_s",
]


def run_figure(name: str, ds: str, mode: str, *, n_keys: int, scan_size: int,
               num_procs: int, ops_per_proc: int, seed: int = 7,
               zipf: float = 0.99) -> List[Measurement]:
    rows = []
    for scheme in SCHEMES:
        kw = {}
        if scheme in ("dlrt", "slrt", "bbf"):
            kw["batch_size"] = max(8, num_procs)
        cfg = WorkloadConfig(
            ds=ds, scheme=scheme, n_keys=n_keys, num_procs=num_procs,
            mode=mode, scan_size=scan_size, variable_scan_max=n_keys,
            op_mix=replace(PAPER_MIXED, scan_size=min(1024, n_keys)),
            ops_per_proc=ops_per_proc,
            zipf=zipf, seed=seed, sample_every=256, scheme_kwargs=kw,
        )
        t0 = time.time()
        r = run_workload(cfg)
        rows.append(Measurement.from_result("gc_comparison", name, r,
                                            wall_s=time.time() - t0))
    return rows


FIGURES = {
    "fig4_tree_split_small": dict(ds="tree", mode="split", n_keys=1024,
                                  scan_size=16, num_procs=24, ops_per_proc=200),
    "fig5_tree_split_large": dict(ds="tree", mode="split", n_keys=4096,
                                  scan_size=16, num_procs=24, ops_per_proc=150),
    "fig6_hash_split_bigscan": dict(ds="hash", mode="split", n_keys=1024,
                                    scan_size=512, num_procs=24, ops_per_proc=200),
    "fig7_tree_mixed": dict(ds="tree", mode="mixed", n_keys=1024,
                            scan_size=16, num_procs=24, ops_per_proc=300),
    "fig8_hash_mixed": dict(ds="hash", mode="mixed", n_keys=1024,
                            scan_size=16, num_procs=24, ops_per_proc=300),
}


# ``fast`` scales the figure workloads down for the per-PR trajectory (the
# committed BENCH file holds fast rows); ``full`` runs the paper-scale
# matrix (the weekly bench-standard job)
TIERS = {
    "fast": dict(ops_divisor=3, keys_divisor=2, figures=list(FIGURES)),
    "full": dict(ops_divisor=1, keys_divisor=1, figures=list(FIGURES)),
}


def run_tier(tier: str) -> List[Measurement]:
    params = TIERS[tier]
    rows: List[Measurement] = []
    for name, kw in FIGURES.items():
        kw = dict(kw)
        kw["ops_per_proc"] = max(60, kw["ops_per_proc"] // params["ops_divisor"])
        kw["n_keys"] = max(256, kw["n_keys"] // params["keys_divisor"])
        rows.extend(run_figure(name, **kw))
    return rows


DRIVER = BenchDriver(
    bench="gc_comparison", tiers=TIERS, run_tier=run_tier,
    default_out=DEFAULT_OUT, table_cols=TABLE_COLS, default_tier="fast",
    col_width=22,
)


def main(fast: bool = True, out: str = DEFAULT_OUT) -> Dict[str, List[Dict]]:
    """In-process entry (benchmarks/run.py): run one tier, return the
    per-figure row tables."""
    from repro.core.sim.measure import tier_meta, write_bench_json

    tier = "fast" if fast else "full"
    rows = DRIVER.run([tier])
    tables: Dict[str, List[Dict]] = {}
    for m in rows:
        tables.setdefault(m.figure, []).append(m.to_row())
    if out:
        payload = write_bench_json(out, "gc_comparison", rows,
                                   meta=tier_meta([tier], TIERS))
        print(f"wrote {out} ({len(payload['rows'])} rows)")
    return tables


if __name__ == "__main__":
    raise SystemExit(DRIVER.main(sys.argv[1:]))
