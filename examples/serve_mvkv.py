"""MV-Serve example: batched decoding with concurrent snapshot readers.

Demonstrates the paper's workload at the serving layer: decode steps are the
*updates* (one descriptor version per sequence per step), pinned scoring
passes are the *rtxs*, and the SL-RT policy keeps descriptor space bounded
(compare --gc-policy ebr to watch the paper's pathology).

Run:  PYTHONPATH=src python examples/serve_mvkv.py [--gc-policy slrt|ebr]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.configs.base import RunConfig, SHAPES
from repro.models import transformer as tf
from repro.serve import engine as eng
from repro.serve.engine import MVServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--gc-policy", default="slrt")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=40)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    run = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                    gc_policy=args.gc_policy, versions_per_slot=64,
                    reader_lanes=8)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    engine = MVServeEngine(cfg, run, params, batch=args.batch, max_len=128)

    rng = np.random.default_rng(0)
    prompt = jnp.array(rng.integers(0, cfg.vocab_size, (args.batch, 12)),
                       jnp.int32)
    engine.prefill(prompt)
    print(f"arch={cfg.name} (reduced)  policy={args.gc_policy}  "
          f"batch={args.batch}")

    # a long-running snapshot reader pins early
    t_pin = engine.pin(lane=0)
    snap0 = np.asarray(engine.lengths_at(t_pin))
    print(f"[rtx] pinned t={t_pin}; snapshot lengths {snap0}")

    for i in range(args.steps):
        toks = engine.step()
        if i % 10 == 0:
            rep = engine.space()
            print(f"step {i:3d}  live_versions={rep['live_versions']:4d}  "
                  f"max_slot_occ={rep['max_slot_occupancy']}  "
                  f"overflow={rep['overflows']}")
    # the pinned snapshot is still exactly what it was
    snap1 = np.asarray(engine.lengths_at(t_pin))
    assert (snap0 == snap1).all(), "snapshot violated!"
    print(f"[rtx] snapshot after {args.steps} decodes unchanged: {snap1}")

    # score candidate tokens against the frozen snapshot while decode moved on
    logits = eng.snapshot_score(engine.state, cfg,
                                jnp.ones((args.batch, 1), jnp.int32),
                                jnp.int32(t_pin))
    print(f"[rtx] snapshot_score logits shape: {logits.shape}")

    engine.unpin(0)
    engine.step()
    print(f"[gc] after unpin: {engine.space()}")


if __name__ == "__main__":
    main()
