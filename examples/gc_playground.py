"""GC playground: watch the five schemes diverge on an adversarial workload.

Reproduces the paper's headline effects interactively:
  * EBR's space blowup under a long-running rtx,
  * Steam's dusty corners on the tree (indirect vCAS references),
  * SL-RT/DL-RT staying near the L-R+P floor throughout.

Run:  PYTHONPATH=src python examples/gc_playground.py
"""
from repro.core.sim.workload import WorkloadConfig, run_workload

print(f"{'scheme':8s} {'ds':5s} {'peak words':>11s} {'peak vers':>10s} "
      f"{'upd/Mwork':>10s} {'c':>6s}")
for ds in ("hash", "tree"):
    for scheme in ("ebr", "steam", "dlrt", "slrt", "bbf"):
        kw = {"batch_size": 8} if scheme in ("dlrt", "slrt", "bbf") else {}
        cfg = WorkloadConfig(
            ds=ds, scheme=scheme, n_keys=96, num_procs=9, ops_per_proc=400,
            mode="split", rtx_size=768, variable_rtx_max=768, zipf=0.99,
            sample_every=64, seed=7, scheme_kwargs=kw,
        )
        r = run_workload(cfg)
        c = r["scheme_stats"].get("avg_remove_chain_c", "-")
        print(f"{scheme:8s} {ds:5s} {r['peak_space']['words']:>11d} "
              f"{r['peak_space'].get('versions', 0):>10d} "
              f"{r['updates_per_mwork']:>10.0f} {str(c):>6s}")
print("\nExpected: EBR peaks highest under the long rtxs; BBF+ carries the\n"
      "TreeDL deferral overhead; SL-RT/DL-RT stay near the needed-version\n"
      "floor with c ~= 1.0 (the paper's <=1.01 observation).")
