"""Quickstart: the paper's MVGC in 60 lines.

1. Layer A — the faithful lock-free algorithms (PDL / SSL / RangeTracker)
   under simulated concurrency.
2. Layer B — the TPU-native bulk-synchronous versioned store with the SL-RT
   policy, doing snapshot reads under concurrent writes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core.sim.machine import Scheduler
from repro.core.sim.pdl import PDL, Node
from repro.core.sim.ssl_list import SSL, SNode
from repro.core.mvgc import vstore

print("== Layer A: PDL (Algorithm 1) under random interleaving ==")
lst = PDL()
nodes = [Node(ts, f"v@{ts}") for ts in (1, 3, 5, 7)]
prev = lst.head
for n in nodes:
    assert lst.try_append(prev, n)
    prev = n
sched = Scheduler(seed=0)
sched.spawn("remove", lst.remove_steps(nodes[1]), (nodes[1],))
sched.spawn("remove", lst.remove_steps(nodes[2]), (nodes[2],))
sched.spawn("search", lst.search_steps(6), (6,))
sched.run_random()
print("   abstract list:", [n.key for n in lst.abstract_list()[1:]])
print("   search(6) during removals returned:",
      [op.result for op in sched.ops.values() if op.name == 'search'][0])

print("\n== Layer A: SSL compact (Algorithm 3) ==")
sl = SSL()
prev = sl.head
for ts in (1, 2, 3, 5, 8, 9):
    n = SNode(ts, f"v@{ts}")
    assert sl.try_append(prev, n)
    prev = n
sl.compact(A=[2, 5], t=9, h=sl.head)   # readers pinned at 2 and 5
print("   retained after compact(A=[2,5], t=9):",
      [n.ts for n in sl.abstract_list()[1:]], " (needed(A,t) only)")

print("\n== Layer B: bulk-synchronous versioned store (SL-RT policy) ==")
state = vstore.make_state(num_slots=4, versions_per_slot=8, num_reader_lanes=2,
                          ring_capacity=8)  # small ring => visible flushes
ids = jnp.arange(4, dtype=jnp.int32)
m = jnp.ones((4,), bool)
# write v1 everywhere, pin a snapshot, keep writing
state, _, _ = vstore.write_step(state, ids, jnp.full((4,), 100, jnp.int32), m)
state, ts = vstore.begin_snapshot(state, jnp.array([0], jnp.int32),
                                  jnp.array([True]))
for i in range(5):
    state, _, _ = vstore.write_step(state, ids,
                                    jnp.full((4,), 200 + i, jnp.int32), m)
    state, _ = vstore.gc_step(state)
pinned, _ = vstore.snapshot_read(state, ids, ts[0])
current, _ = vstore.current_read(state, ids)
print(f"   pinned snapshot @t={int(ts[0])}: {list(map(int, pinned))}")
print(f"   current values:            {list(map(int, current))}")
print(f"   live versions: {int(vstore.live_versions(state))} "
      f"(pinned + current per slot; obsolete middles collected)")
state = vstore.end_snapshot(state, jnp.array([0], jnp.int32), jnp.array([True]))
state, _ = vstore.gc_step(state, force=True)
print(f"   after unpin + GC: {int(vstore.live_versions(state))} versions")
