"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on
synthetic data, with checkpointing + MVGC retention + a simulated crash and
restart at the midpoint.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(CPU: ~100M params is the xlstm-125m config at seq 128 / batch 8; pass
--small for a 1-minute smoke run.)
"""
import argparse
import dataclasses
import functools
import shutil
import time

import jax
import jax.numpy as jnp

from repro.ckpt.manager import CheckpointManager
from repro.configs import get_config, reduced_config
from repro.configs.base import RunConfig, SHAPES
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.train.step import TrainState, init_state, train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.small:
        cfg = reduced_config("xlstm-125m")
        seq, batch, steps = 64, 8, min(args.steps, 60)
    else:
        # ~100M-param config: the xlstm-125m arch with a trimmed vocab so the
        # CPU embedding matmul stays tractable
        cfg = dataclasses.replace(get_config("xlstm-125m"), vocab_size=8192,
                                  mlstm_chunk=32)
        seq, batch, steps = 128, 8, args.steps

    n_params_est = cfg.param_count()
    print(f"arch={cfg.name}  ~{n_params_est/1e6:.0f}M params  "
          f"seq={seq} batch={batch} steps={steps}")

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    run = RunConfig(model=cfg, shape=SHAPES["train_4k"], lr=3e-3)
    data = SyntheticLM(DataConfig(cfg.vocab_size, seq, batch, copy_period=16))
    mgr = CheckpointManager(args.ckpt_dir)
    state = init_state(cfg, jax.random.PRNGKey(0))
    print(f"actual params: "
          f"{sum(x.size for x in jax.tree.leaves(state.params))/1e6:.1f}M")
    step_fn = jax.jit(functools.partial(train_step, cfg=cfg, run=run))

    crash_at = steps // 2
    losses = []

    def run_until(state, data, start, end):
        for i in range(start, end):
            t0 = time.time()
            batch_i = {k: jnp.asarray(v) for k, v in next(data).items()}
            state, m = step_fn(state, batch_i)
            losses.append(float(m["loss"]))
            if i % 20 == 0 or i == end - 1:
                print(f"step {i:4d}  loss {losses[-1]:.4f}  "
                      f"({(time.time()-t0)*1e3:.0f} ms)")
            if (i + 1) % 50 == 0:
                mgr.save(i + 1, state, extra=data.state_dict())
                mgr.gc(keep_last=2)
        return state

    state = run_until(state, data, 0, crash_at)
    mgr.save(crash_at, state, extra=data.state_dict())
    print(f"\n[simulated crash at step {crash_at}; restarting from checkpoint]\n")

    # restart path: fresh state objects, restore from disk
    state2 = init_state(cfg, jax.random.PRNGKey(0))
    restored, extra = mgr.restore(mgr.latest_step(), like=state2)
    state2 = TrainState(*restored)
    data2 = SyntheticLM(DataConfig(cfg.vocab_size, seq, batch, copy_period=16))
    data2.load_state_dict(extra)
    state2 = run_until(state2, data2, crash_at, steps)

    first, last = losses[0], sum(losses[-10:]) / 10
    print(f"\nloss: {first:.3f} -> {last:.3f} "
          f"({'LEARNED' if last < first - 0.3 else 'check config'})")
    print(f"checkpoints kept after MVGC retention: {mgr.steps()}")


if __name__ == "__main__":
    main()
