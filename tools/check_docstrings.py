#!/usr/bin/env python
"""Docs-coverage check: public API symbols must carry docstrings.

  PYTHONPATH=src python tools/check_docstrings.py            # default modules
  PYTHONPATH=src python tools/check_docstrings.py repro.core.sim.txn ...

Imports each module and fails (exit 1) if

  * the module itself lacks a docstring, or
  * any public (non-underscore) module-level class or function defined *in*
    that module lacks one, or
  * any public method/property a public class defines lacks one.

Docstring inheritance counts: an override with no docstring of its own is
fine when a base class documents the same method (``inspect.getdoc`` walks
the MRO), so scheme subclasses may rely on ``SchemeBase``'s contract text.

The default module list is the read-write-transaction core — the modules
DESIGN.md §10 and the README "Internals" section document — so the reference
docs and the source can't drift apart silently.  Run by the CI
``docs-coverage`` step and by ``tests/sim/test_reclaim.py``.
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import sys

DEFAULT_MODULES = (
    "repro.core.sim.contention",
    "repro.core.sim.txn",
    "repro.core.sim.schemes",
    "repro.core.sim.measure",
)


def check_module(modname: str) -> list:
    """Return a list of "module.symbol" strings that lack docstrings."""
    mod = importlib.import_module(modname)
    missing = []
    if not (mod.__doc__ or "").strip():
        missing.append(f"{modname} (module docstring)")
    for name, obj in vars(mod).items():
        if name.startswith("_"):
            continue
        if not (inspect.isclass(obj) or inspect.isfunction(obj)):
            continue
        if getattr(obj, "__module__", None) != modname:
            continue  # re-exported from elsewhere; charged to its home module
        if not (inspect.getdoc(obj) or "").strip():
            missing.append(f"{modname}.{name}")
        if inspect.isclass(obj):
            missing.extend(_check_class(modname, obj))
    return missing


def _check_class(modname: str, cls) -> list:
    missing = []
    for mname, member in vars(cls).items():
        if mname.startswith("_"):
            continue
        is_callable = inspect.isfunction(member) or isinstance(
            member, (staticmethod, classmethod, property))
        if not is_callable:
            continue  # class attributes / dataclass fields need no docstring
        # resolve through the class so getdoc can walk the MRO for
        # inherited docstrings
        resolved = getattr(cls, mname, member)
        if not (inspect.getdoc(resolved) or "").strip():
            missing.append(f"{modname}.{cls.__name__}.{mname}")
    return missing


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("modules", nargs="*", default=list(DEFAULT_MODULES),
                    help=f"modules to check (default: {DEFAULT_MODULES})")
    args = ap.parse_args()
    modules = args.modules or list(DEFAULT_MODULES)

    problems = []
    for modname in modules:
        try:
            problems.extend(check_module(modname))
        except ImportError as e:
            problems.append(f"{modname}: import failed ({e})")

    if problems:
        print(f"FAIL: {len(problems)} public symbols lack docstrings:")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"OK: every public symbol in {len(modules)} module(s) is "
          f"documented ({', '.join(modules)})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
