#!/usr/bin/env python
"""Bench-trajectory gate: compare a committed BENCH_*.json against a fresh
(smoke) emission of the same driver, so sim perf/space regressions are caught
at PR time (run by the CI ``bench-trajectory`` step).

  PYTHONPATH=src python tools/compare_bench.py BENCH_txn_mix.json \\
      /tmp/BENCH_txn_mix.json --tolerance 0.15

The payloads declare their row schema (``measure.schema_of_payload``) and the
comparison dispatches on it: the schema's ``key_fields`` define row identity
and its ``compare_fields`` are the value cells diffed per matched pair —
space words for the sim/txn schemas, page-pool accounting for serve, the
traffic model and roofline target for kernel.  Adding a bench means
registering a schema; this tool needs no changes.

Checks, in order:

1. both payloads satisfy the BENCH schema (``measure.validate_bench_payload``),
   declare the *same* row schema, and report zero snapshot violations;
2. coverage: the fresh run's scheme and structure sets equal the committed
   file's, and every mix the fresh run emits appears in the committed file
   (the committed file may carry more — e.g. extra tiers);
3. cell-for-cell: every fresh row must have a committed row with the same
   identity key — a missing cell means the committed file is stale and must
   be regenerated;
4. for each matched cell, every compare field must agree within
   ``--tolerance`` (relative).  The sim is deterministic, so matched cells
   normally agree exactly; the tolerance absorbs cross-version RNG/library
   drift.  A knowingly-changed cell can be waived with
   ``--waive field=value[,field=value...]`` (conjunctive; repeatable).

At least ``--require-overlap`` cells must match (default 1) so the value
comparison cannot silently become vacuous.
"""
from __future__ import annotations

import argparse
import json
from typing import Any, Dict, List, Sequence, Tuple

from repro.core.sim.measure import schema_of_payload, validate_bench_payload


def row_key(row: Dict[str, Any], key_fields: Sequence[str]) -> Tuple:
    return tuple(row.get(f) for f in key_fields)


def parse_waive(spec: str) -> Dict[str, str]:
    out = {}
    for part in spec.split(","):
        if "=" not in part:
            raise ValueError(f"bad --waive clause {part!r} (want field=value)")
        f, v = part.split("=", 1)
        out[f.strip()] = v.strip()
    return out


def waived(row: Dict[str, Any], waivers: List[Dict[str, str]]) -> bool:
    return any(all(str(row.get(f)) == v for f, v in w.items())
               for w in waivers)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("committed", help="BENCH json committed at the repo root")
    ap.add_argument("fresh", help="freshly emitted BENCH json (smoke run)")
    ap.add_argument("--tolerance", type=float, default=0.15,
                    help="max relative delta on compare fields (default 0.15)")
    ap.add_argument("--waive", action="append", default=[],
                    help="field=value[,field=value...] — skip the value "
                         "comparison for matching rows (repeatable)")
    ap.add_argument("--require-overlap", type=int, default=1,
                    help="minimum matched cells (default 1)")
    args = ap.parse_args()
    waivers = [parse_waive(w) for w in args.waive]

    committed = json.load(open(args.committed))
    fresh = json.load(open(args.fresh))
    problems: List[str] = []

    for name, payload in (("committed", committed), ("fresh", fresh)):
        for p in validate_bench_payload(payload):
            problems.append(f"{name}: schema problem: {p}")
        bad = [r for r in payload.get("rows", [])
               if r.get("scan_violations", 0)]
        if bad:
            problems.append(f"{name}: {len(bad)} rows report violations")
    if committed.get("bench") != fresh.get("bench"):
        problems.append(f"bench name mismatch: committed "
                        f"{committed.get('bench')!r} vs fresh "
                        f"{fresh.get('bench')!r}")
    schema = schema_of_payload(committed)
    if schema_of_payload(fresh).name != schema.name:
        problems.append(f"row schema mismatch: committed {schema.name!r} vs "
                        f"fresh {schema_of_payload(fresh).name!r}")
    if problems:
        return fail(args, problems)

    crows, frows = committed["rows"], fresh["rows"]
    for field in ("scheme", "ds"):
        cset = {r.get(field) for r in crows}
        fset = {r.get(field) for r in frows}
        if cset != fset:
            problems.append(
                f"{field} coverage differs: committed {sorted(cset)} vs "
                f"fresh {sorted(fset)}")
    cmixes = {r.get("mix") for r in crows}
    fmixes = {r.get("mix") for r in frows}
    if not fmixes <= cmixes:
        problems.append(f"fresh mixes {sorted(fmixes - cmixes)} absent from "
                        f"the committed file")

    key_fields = schema.key_fields
    by_key = {row_key(r, key_fields): r for r in crows}
    matched = 0
    for fr in frows:
        cr = by_key.get(row_key(fr, key_fields))
        if cr is None:
            problems.append(
                "no committed cell for fresh row "
                + "/".join(f"{f}={fr.get(f)}" for f in key_fields[:6])
                + " — committed file is stale, regenerate it")
            continue
        matched += 1
        if waived(fr, waivers):
            continue
        for sf in schema.compare_fields:
            a, b = fr.get(sf, 0), cr.get(sf, 0)
            denom = max(abs(b), 1)
            if abs(a - b) / denom > args.tolerance:
                problems.append(
                    f"{sf} drifted {abs(a - b) / denom:.1%} (> "
                    f"{args.tolerance:.0%}) on "
                    + "/".join(f"{fr.get(f)}" for f in key_fields[:6])
                    + f": fresh {a} vs committed {b}")
    if matched < args.require_overlap:
        problems.append(f"only {matched} cells matched; need >= "
                        f"{args.require_overlap} for a meaningful comparison")

    if problems:
        return fail(args, problems)
    print(f"OK {args.committed} vs {args.fresh} [{schema.name}]: {matched} "
          f"cells compared within {args.tolerance:.0%}"
          + (f" ({len(waivers)} waiver(s) active)" if waivers else ""))
    return 0


def fail(args, problems: List[str]) -> int:
    print(f"FAIL {args.committed} vs {args.fresh}:")
    for p in problems:
        print(f"  - {p}")
    return 1


if __name__ == "__main__":
    raise SystemExit(main())
