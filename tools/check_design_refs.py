#!/usr/bin/env python
"""Docs check: every in-repo DESIGN.md citation must resolve.

Scans src/, benchmarks/, tests/ and tools/ for references to DESIGN.md,
extracts any cited section number, and fails (exit 1) if

  * DESIGN.md does not exist at the repo root, or
  * a cited section (e.g. "DESIGN.md §7") has no matching "## §7" heading.

Run by the CI docs step and by tests/sim/test_measurement.py.
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCAN_DIRS = ("src", "benchmarks", "tests", "tools")
SCAN_EXTS = (".py", ".md", ".yml", ".yaml", ".toml")

# assembled so this file's own source doesn't read as a section citation
_DOC = "DESIGN" + ".md"
CITE_RE = re.compile(_DOC + r"\s*§\s*(\d+)")
PLAIN_RE = re.compile(_DOC)
HEADING_RE = re.compile(r"^#{1,6}\s*§\s*(\d+)\b", re.MULTILINE)


def main() -> int:
    design_path = os.path.join(REPO, _DOC)
    citations = []   # (relpath, lineno, section-or-None)
    for d in SCAN_DIRS:
        for root, _dirs, files in os.walk(os.path.join(REPO, d)):
            for fn in files:
                if not fn.endswith(SCAN_EXTS):
                    continue
                path = os.path.join(root, fn)
                rel = os.path.relpath(path, REPO)
                try:
                    text = open(path, encoding="utf-8", errors="replace").read()
                except OSError:
                    continue
                for lineno, line in enumerate(text.splitlines(), 1):
                    if not PLAIN_RE.search(line):
                        continue
                    secs = CITE_RE.findall(line)
                    if secs:
                        for s in secs:
                            citations.append((rel, lineno, int(s)))
                    else:
                        citations.append((rel, lineno, None))

    if not citations:
        print("no DESIGN.md citations found — nothing to check")
        return 0

    if not os.path.exists(design_path):
        print(f"FAIL: {len(citations)} citations but {_DOC} does not exist")
        for rel, ln, sec in citations[:20]:
            print(f"  {rel}:{ln}" + (f" (§{sec})" if sec else ""))
        return 1

    sections = {int(s) for s in HEADING_RE.findall(open(design_path).read())}
    missing = [(rel, ln, sec) for rel, ln, sec in citations
               if sec is not None and sec not in sections]
    cited = sorted({sec for _, _, sec in citations if sec is not None})
    print(f"{len(citations)} {_DOC} citations "
          f"({len([c for c in citations if c[2] is not None])} with sections: "
          f"{cited}); document defines sections {sorted(sections)}")
    if missing:
        print(f"FAIL: {len(missing)} citations target missing sections:")
        for rel, ln, sec in missing:
            print(f"  {rel}:{ln} cites §{sec}")
        return 1
    print("OK: every cited section exists")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
