#!/usr/bin/env python
"""Render the BENCH_*.json trajectory as per-scheme curves (the CI
``bench-plots`` step; PNGs are uploaded as workflow artifacts so every PR
carries its perf pictures).

  PYTHONPATH=src python tools/plot_bench.py \\
      BENCH_range_query.json BENCH_txn_mix.json BENCH_gc_comparison.json \\
      --outdir /tmp/bench_plots

Per input file, grouped by (structure, mix, zipf) with one line per scheme:

* ``space_vs_scan_size``  — peak space (words) vs range-scan size s
  (range_query + txn_mix rows; the paper's Fig. 6 axis);
* ``space_vs_txn_size``   — peak space vs txn write-set size w, split by
  interval count r (txn_mix rows; the MV-RLU footprint axis);
* ``abort_rate``          — abort rate vs scan size, plus the abort-reason
  taxonomy (footprint/wcc/capacity) as stacked bars per scheme (txn_mix);
* ``space_vs_pressure``   — the abort ⇒ reclaim ⇒ retry view (schema v4,
  DESIGN.md §10): peak space and post-reclaim peak space vs capacity-abort
  pressure per scheme (the Fig. 9-style space-under-pressure curves), plus
  reclaim totals (versions reclaimed on abort / reclaim passes) per scheme;
* ``gc_figures``          — peak/end space per scheme for each gc_comparison
  figure family (the paper's Figs 4-8 bar view);
* ``pages_vs_pressure``   — BENCH_serve rows (DESIGN.md §11): per tier,
  peak vs post-reclaim live pages per GC policy against the pool size,
  plus total pages reclaimed with pressure events annotated;
* ``kernel_bandwidth``    — BENCH_kernel rows (DESIGN.md §12): per shape,
  achieved bandwidth against the roofline-derived target, plus the
  fused-over-unfused speedup per shape.

Panels are selected by the payload's declared row schema
(``measure.schema_of_payload(...).panel``), so registering a bench schema is
the whole integration.  Degrades gracefully: exits 0 with a notice when
matplotlib is missing (ENOPLOT) unless ``--require-matplotlib`` is passed
(CI passes it, having installed matplotlib).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from collections import defaultdict
from typing import Any, Dict, List

from repro.core.sim.measure import schema_of_payload

SCHEME_ORDER = ("ebr", "steam", "dlrt", "slrt", "bbf")
# one stable color per scheme across every panel
SCHEME_COLORS = {
    "ebr": "#4269d0", "steam": "#efb118", "dlrt": "#ff725c",
    "slrt": "#6cc5b0", "bbf": "#9c6b4e",
}
REASONS = ("footprint", "wcc", "capacity")
REASON_COLORS = {"footprint": "#4269d0", "wcc": "#efb118",
                 "capacity": "#ff725c"}


def _family(row: Dict[str, Any]) -> str:
    return f"{row['ds']}/{row['mix']}/zipf={row['zipf']}"


def _dominant_nkeys(rows: List[Dict[str, Any]]):
    """Restrict to the most-populated n_keys tier: committed BENCH files
    concatenate tiers with different key spaces, and averaging across them
    would fake the x-axis trends the line plots claim to show."""
    counts = defaultdict(int)
    for r in rows:
        counts[r["n_keys"]] += 1
    if not counts:
        return rows, None
    nk = max(counts, key=counts.get)
    return [r for r in rows if r["n_keys"] == nk], nk


def _schemes(rows: List[Dict[str, Any]]) -> List[str]:
    present = {r["scheme"] for r in rows}
    return [s for s in SCHEME_ORDER if s in present] + sorted(
        present - set(SCHEME_ORDER))


def _lineplot(ax, rows, xfield, yfield):
    """One line per scheme: yfield vs xfield (mean over duplicate x)."""
    for scheme in _schemes(rows):
        pts = defaultdict(list)
        for r in rows:
            if r["scheme"] == scheme:
                pts[r[xfield]].append(r[yfield])
        xs = sorted(pts)
        ys = [sum(pts[x]) / len(pts[x]) for x in xs]
        ax.plot(xs, ys, marker="o", ms=3.5, lw=1.5, label=scheme,
                color=SCHEME_COLORS.get(scheme))
    ax.set_xlabel(xfield)
    ax.set_ylabel(yfield)
    if len({r[xfield] for r in rows}) > 1:
        ax.set_xscale("log", base=2)


def plot_space_vs_scan_size(plt, rows, outdir, stem) -> List[str]:
    rows = [r for r in rows if r.get("scans", 0) or r.get("txns_committed", 0)]
    rows, nk = _dominant_nkeys(rows)
    fams = sorted({_family(r) for r in rows})
    if not fams:
        return []
    fig, axes = plt.subplots(1, len(fams), figsize=(4.2 * len(fams), 3.4),
                             squeeze=False)
    for ax, fam in zip(axes[0], fams):
        sub = [r for r in rows if _family(r) == fam]
        _lineplot(ax, sub, "scan_size", "peak_space_words")
        ax.set_title(fam, fontsize=9)
    axes[0][0].legend(fontsize=7)
    fig.suptitle(f"{stem}: peak space vs scan size (n_keys={nk} tier)",
                 fontsize=11)
    fig.tight_layout()
    path = os.path.join(outdir, f"{stem}_space_vs_scan_size.png")
    fig.savefig(path, dpi=120)
    plt.close(fig)
    return [path]


def plot_space_vs_txn_size(plt, rows, outdir, stem) -> List[str]:
    rows = [r for r in rows
            if r.get("txns_committed", 0) + r.get("txns_aborted", 0)]
    if not rows:
        return []
    rows, nk = _dominant_nkeys(rows)
    rvals = sorted({r.get("txn_ranges", 0) for r in rows})
    dss = sorted({r["ds"] for r in rows})
    fig, axes = plt.subplots(len(dss), len(rvals),
                             figsize=(4.2 * len(rvals), 3.2 * len(dss)),
                             squeeze=False)
    for i, ds in enumerate(dss):
        for j, rv in enumerate(rvals):
            sub = [r for r in rows
                   if r["ds"] == ds and r.get("txn_ranges", 0) == rv]
            ax = axes[i][j]
            if sub:
                _lineplot(ax, sub, "txn_size", "peak_space_words")
            ax.set_title(f"{ds}, r={rv} intervals", fontsize=9)
    axes[0][0].legend(fontsize=7)
    fig.suptitle(f"{stem}: peak space vs txn write-set size "
                 f"(n_keys={nk} tier)", fontsize=11)
    fig.tight_layout()
    path = os.path.join(outdir, f"{stem}_space_vs_txn_size.png")
    fig.savefig(path, dpi=120)
    plt.close(fig)
    return [path]


def plot_abort_rates(plt, rows, outdir, stem) -> List[str]:
    rows = [r for r in rows
            if r.get("txns_committed", 0) + r.get("txns_aborted", 0)]
    if not rows:
        return []
    fig, (ax1, ax2) = plt.subplots(1, 2, figsize=(9.5, 3.6))
    line_rows, nk = _dominant_nkeys(rows)
    _lineplot(ax1, line_rows, "scan_size", "abort_rate")
    ax1.set_title(f"abort rate vs scan size (n_keys={nk} tier)", fontsize=9)
    ax1.legend(fontsize=7)
    # abort-reason taxonomy, aggregated per scheme (stacked bars)
    schemes = _schemes(rows)
    bottoms = [0.0] * len(schemes)
    for reason in REASONS:
        vals = [sum(r.get(f"aborts_{reason}", 0)
                    for r in rows if r["scheme"] == s) for s in schemes]
        ax2.bar(schemes, vals, bottom=bottoms, label=reason,
                color=REASON_COLORS[reason])
        bottoms = [b + v for b, v in zip(bottoms, vals)]
    ax2.set_title("aborts by reason (footprint/wcc/capacity)", fontsize=9)
    ax2.set_ylabel("aborted commit attempts")
    ax2.legend(fontsize=7)
    fig.suptitle(f"{stem}: transaction aborts", fontsize=11)
    fig.tight_layout()
    path = os.path.join(outdir, f"{stem}_abort_rate.png")
    fig.savefig(path, dpi=120)
    plt.close(fig)
    return [path]


def plot_space_vs_pressure(plt, rows, outdir, stem) -> List[str]:
    """Schema-v4 panel (DESIGN.md §10): does reclamation bound space under
    capacity pressure?  Left: per scheme, peak space (solid) and post-reclaim
    peak space (dashed) vs capacity-abort pressure — the share of commit
    attempts that died on the version budget.  Right: versions reclaimed on
    abort (bars) with reclaim passes annotated."""
    rows = [r for r in rows if r.get("reclaims_triggered", 0)
            or r.get("aborts_capacity", 0)]
    if not rows:
        return []
    fig, (ax1, ax2) = plt.subplots(1, 2, figsize=(9.5, 3.6))
    for scheme in _schemes(rows):
        pts = defaultdict(lambda: ([], []))
        for r in rows:
            if r["scheme"] != scheme:
                continue
            attempts = r.get("txns_committed", 0) + r.get("txns_aborted", 0)
            pressure = r.get("aborts_capacity", 0) / max(1, attempts)
            peaks, posts = pts[round(pressure, 3)]
            peaks.append(r["peak_space_words"])
            posts.append(r.get("peak_space_post_reclaim", 0))
        xs = sorted(pts)
        peak_ys = [sum(pts[x][0]) / len(pts[x][0]) for x in xs]
        post_ys = [sum(pts[x][1]) / len(pts[x][1]) for x in xs]
        color = SCHEME_COLORS.get(scheme)
        ax1.plot(xs, peak_ys, marker="o", ms=3.5, lw=1.5, label=scheme,
                 color=color)
        if any(post_ys):
            ax1.plot(xs, post_ys, marker="x", ms=3.5, lw=1.0, ls="--",
                     color=color, alpha=0.7)
    ax1.set_xlabel("capacity-abort pressure (aborts_capacity / attempts)")
    ax1.set_ylabel("space (words)")
    ax1.set_title("peak (solid) vs post-reclaim peak (dashed)", fontsize=9)
    ax1.legend(fontsize=7)
    schemes = _schemes(rows)
    reclaimed = [sum(r.get("versions_reclaimed_on_abort", 0)
                     for r in rows if r["scheme"] == s) for s in schemes]
    passes = [sum(r.get("reclaims_triggered", 0)
                  for r in rows if r["scheme"] == s) for s in schemes]
    bars = ax2.bar(schemes, reclaimed,
                   color=[SCHEME_COLORS.get(s) for s in schemes])
    for bar, n in zip(bars, passes):
        ax2.annotate(f"{n} passes", (bar.get_x() + bar.get_width() / 2,
                                     bar.get_height()),
                     ha="center", va="bottom", fontsize=6)
    ax2.set_title("versions reclaimed on abort", fontsize=9)
    ax2.set_ylabel("versions")
    fig.suptitle(f"{stem}: space under capacity pressure "
                 "(abort ⇒ reclaim ⇒ retry)", fontsize=11)
    fig.tight_layout()
    path = os.path.join(outdir, f"{stem}_space_vs_pressure.png")
    fig.savefig(path, dpi=120)
    plt.close(fig)
    return [path]


def plot_serve_pressure(plt, rows, outdir, stem) -> List[str]:
    """BENCH_serve panel (DESIGN.md §11): pages vs pressure in the paged-KV
    serving stack.  Left: per tier, grouped bars per policy — peak live
    pages (solid) vs post-reclaim peak (faded), against the pool size
    (dotted line): the bounded-space claim in page units.  Right: pages
    reclaimed per policy (bars) vs pressure events (annotated), the
    trigger-to-yield view of the reclaim loop."""
    rows = [r for r in rows if "pressure_events" in r]
    if not rows:
        return []
    tiers = sorted({r["mix"] for r in rows})
    fig, axes = plt.subplots(1, len(tiers) + 1,
                             figsize=(4.0 * (len(tiers) + 1), 3.6),
                             squeeze=False)
    for ax, tier in zip(axes[0], tiers):
        sub = [r for r in rows if r["mix"] == tier]
        schemes = _schemes(sub)
        peak = [next(r["peak_pages"] for r in sub if r["scheme"] == s)
                for s in schemes]
        post = [next(r["peak_pages_post_reclaim"] for r in sub
                     if r["scheme"] == s) for s in schemes]
        x = range(len(schemes))
        ax.bar([i - 0.2 for i in x], peak, width=0.4, label="peak",
               color=[SCHEME_COLORS.get(s) for s in schemes])
        ax.bar([i + 0.2 for i in x], post, width=0.4, label="post-reclaim",
               color=[SCHEME_COLORS.get(s) for s in schemes], alpha=0.45)
        pool = max(r["page_pool"] for r in sub)
        ax.axhline(pool, ls=":", lw=1.0, color="#555555")
        ax.annotate(f"pool={pool}", (0, pool), fontsize=6, va="bottom")
        ax.set_xticks(list(x))
        ax.set_xticklabels(schemes, fontsize=7)
        ax.set_title(f"{tier}: peak vs post-reclaim pages", fontsize=8)
        ax.set_ylabel("pages")
    ax2 = axes[0][-1]
    schemes = _schemes(rows)
    freed = [sum(r["pages_reclaimed"] for r in rows if r["scheme"] == s)
             for s in schemes]
    events = [sum(r["pressure_events"] for r in rows if r["scheme"] == s)
              for s in schemes]
    bars = ax2.bar(schemes, freed,
                   color=[SCHEME_COLORS.get(s) for s in schemes])
    for bar, n in zip(bars, events):
        ax2.annotate(f"{n} events", (bar.get_x() + bar.get_width() / 2,
                                     bar.get_height()),
                     ha="center", va="bottom", fontsize=6)
    ax2.set_title("pages reclaimed (pressure events annotated)", fontsize=8)
    ax2.set_ylabel("pages")
    axes[0][0].legend(fontsize=7)
    fig.suptitle(f"{stem}: paged-KV pages vs pressure "
                 "(exhaust ⇒ reclaim ⇒ retry)", fontsize=11)
    fig.tight_layout()
    path = os.path.join(outdir, f"{stem}_pages_vs_pressure.png")
    fig.savefig(path, dpi=120)
    plt.close(fig)
    return [path]


def plot_gc_figures(plt, rows, outdir, stem) -> List[str]:
    figures = sorted({r["figure"] for r in rows})
    if not figures:
        return []
    fig, axes = plt.subplots(1, len(figures),
                             figsize=(3.4 * len(figures), 3.4), squeeze=False)
    for ax, name in zip(axes[0], figures):
        sub = [r for r in rows if r["figure"] == name]
        schemes = _schemes(sub)
        peak = [next(r["peak_space_words"] for r in sub
                     if r["scheme"] == s) for s in schemes]
        end = [next(r["end_space_words"] for r in sub
                    if r["scheme"] == s) for s in schemes]
        x = range(len(schemes))
        ax.bar([i - 0.2 for i in x], peak, width=0.4, label="peak",
               color=[SCHEME_COLORS.get(s) for s in schemes])
        ax.bar([i + 0.2 for i in x], end, width=0.4, label="end",
               color=[SCHEME_COLORS.get(s) for s in schemes], alpha=0.45)
        ax.set_xticks(list(x))
        ax.set_xticklabels(schemes, fontsize=7)
        ax.set_title(name, fontsize=8)
    axes[0][0].set_ylabel("space (words)")
    axes[0][0].legend(fontsize=7)
    fig.suptitle(f"{stem}: space per scheme (solid=peak, faded=end)",
                 fontsize=11)
    fig.tight_layout()
    path = os.path.join(outdir, f"{stem}_figures.png")
    fig.savefig(path, dpi=120)
    plt.close(fig)
    return [path]


def plot_kernel_bandwidth(plt, rows, outdir, stem) -> List[str]:
    """BENCH_kernel panel (DESIGN.md §12).  Left: achieved bandwidth per
    shape (bars) against the roofline-derived target (markers) — log scale,
    the compute-bound compact shapes sit orders below the streaming target
    on CPU.  Right: fused-over-unfused speedup per shape with the break-even
    line; standard/full-tier bars must clear it (``check_kernel_rows``)."""
    rows = [r for r in rows if r.get("kernel")]
    if not rows:
        return []
    colors = {"compact": "#4269d0", "search_gather": "#ff725c"}
    rows = sorted(rows, key=lambda r: (r["kernel"], r["mix"], r["shape"]))
    labels = [f"{r['shape']}\n{r['mix']}" for r in rows]
    x = range(len(rows))
    fig, (ax1, ax2) = plt.subplots(1, 2, figsize=(11.5, 4.0))
    ax1.bar(x, [r["gb_s"] for r in rows],
            color=[colors.get(r["kernel"], "#888888") for r in rows])
    ax1.scatter(x, [r["target_gb_s"] for r in rows], marker="_", s=220,
                color="#222222", label="roofline target", zorder=3)
    ax1.set_yscale("log")
    ax1.set_ylabel("GB/s (bytes_moved / us_fused)")
    ax1.set_xticks(list(x))
    ax1.set_xticklabels(labels, fontsize=6)
    backend = rows[0].get("backend", "?")
    ax1.set_title(f"achieved vs target bandwidth ({backend} timings)",
                  fontsize=9)
    ax1.legend(fontsize=7)
    ax2.bar(x, [r["speedup"] for r in rows],
            color=[colors.get(r["kernel"], "#888888") for r in rows])
    ax2.axhline(1.0, ls=":", lw=1.0, color="#555555")
    ax2.set_ylabel("speedup (us_unfused / us_fused)")
    ax2.set_xticks(list(x))
    ax2.set_xticklabels(labels, fontsize=6)
    ax2.set_title("fused over unfused two-dispatch baseline", fontsize=9)
    handles = [plt.Rectangle((0, 0), 1, 1, color=c)
               for k, c in colors.items() if any(r["kernel"] == k for r in rows)]
    names = [k for k in colors if any(r["kernel"] == k for r in rows)]
    ax2.legend(handles, names, fontsize=7)
    fig.suptitle(f"{stem}: fused GC kernels vs roofline", fontsize=11)
    fig.tight_layout()
    path = os.path.join(outdir, f"{stem}_kernel_bandwidth.png")
    fig.savefig(path, dpi=120)
    plt.close(fig)
    return [path]


def render(plt, path: str, outdir: str) -> List[str]:
    payload = json.load(open(path))
    rows = payload.get("rows", [])
    stem = os.path.splitext(os.path.basename(path))[0]
    bench = payload.get("bench", stem)
    panel = schema_of_payload(payload).panel
    written: List[str] = []
    if panel == "serve":
        written += plot_serve_pressure(plt, rows, outdir, stem)
    elif panel == "kernel":
        written += plot_kernel_bandwidth(plt, rows, outdir, stem)
    elif bench == "gc_comparison":
        written += plot_gc_figures(plt, rows, outdir, stem)
    else:
        written += plot_space_vs_scan_size(plt, rows, outdir, stem)
        written += plot_space_vs_txn_size(plt, rows, outdir, stem)
        written += plot_abort_rates(plt, rows, outdir, stem)
        written += plot_space_vs_pressure(plt, rows, outdir, stem)
    return written


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("benches", nargs="+", help="BENCH_*.json files to render")
    ap.add_argument("--outdir", default="bench_plots")
    ap.add_argument("--require-matplotlib", action="store_true",
                    help="fail (exit 3) when matplotlib is unavailable "
                         "instead of skipping (CI passes this)")
    args = ap.parse_args()

    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        msg = "plot_bench: matplotlib unavailable, no plots rendered"
        if args.require_matplotlib:
            print(f"FAIL {msg}", file=sys.stderr)
            return 3
        print(f"SKIP {msg}")
        return 0

    os.makedirs(args.outdir, exist_ok=True)
    written: List[str] = []
    for path in args.benches:
        written += render(plt, path, args.outdir)
    for p in written:
        print(f"wrote {p}")
    if not written:
        print("FAIL: no plots produced from "
              f"{args.benches}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
